//! Bench for **Figs 7-9 + Table 5**: regenerates every hardware sweep row
//! and times the cost model (it is called inside accuracy/pareto sweeps, so
//! it must be cheap).

use cvapprox::approx::Family;
use cvapprox::hw::array::{array_cost, PAPER_NS};
use cvapprox::util::bench::Bencher;

fn main() {
    println!("== bench: hw_model ==");
    let b = Bencher::default();
    let r = b.run("full Fig7+8+9 sweep (36 design points)", 36.0, || {
        for family in Family::APPROX {
            for &m in family.paper_levels() {
                for &n in &PAPER_NS {
                    std::hint::black_box(array_cost(family, m, n));
                }
            }
        }
    });
    println!("{}", r.report());
    println!();
    // Regenerate the actual artifacts.
    for family in Family::APPROX {
        println!("{}", cvapprox::report::tables::render_hw_figure(family));
    }
    println!("{}", cvapprox::report::tables::render_table5());
}
