//! Paired-policy bench: positive/negative multiplier pairing end to end.
//!
//! Runs entirely on the checked-in hermetic artifacts (no `make artifacts`,
//! no network — CI always executes it): the mixed greedy search from
//! `report::layerwise` derives the PR 3 baseline policy, the paired ladder
//! search upgrades it into the even/odd pairing space, and both are
//! compared on the (estimated power, synthetic accuracy loss) plane and
//! served through the coordinator pool.
//!
//! Emits `BENCH_paired.json`. Asserted, not just reported:
//! * the greedy paired policy **dominates or matches** the mixed policy on
//!   the (power, loss) plane (guaranteed by the search's floor + power
//!   guards; on the hermetic set it strictly dominates — the pinned result
//!   is one previously exact layer running a mirrored perforated m=1
//!   pairing at zero loss);
//! * pool replies are **bit-identical** to per-image paired forwards;
//! * existing uniform and mixed policies are untouched (their bit-exactness
//!   vs the PR 3 golden vectors is enforced by the hermetic golden suite,
//!   which CI runs by name).
//!
//! Env knobs: `CVAPPROX_BENCH_QUICK=1` (short serving budgets);
//! `CVAPPROX_THREADS` pinned to 1 unless set.

use std::sync::Arc;
use std::time::Duration;

use cvapprox::approx::stats::{pairing_residual, signed_moments};
use cvapprox::approx::{Family, Polarity};
use cvapprox::coordinator::{InferenceService, ServiceConfig};
use cvapprox::datasets::Dataset;
use cvapprox::hermetic_dir;
use cvapprox::nn::{loader, Engine, ForwardOpts, LayerPolicy, Model, SharedPolicy, Tensor};
use cvapprox::report::accuracy::evaluate;
use cvapprox::report::layerwise::{greedy_paired_policy, greedy_policy, sensitivity};
use cvapprox::util::json::Json;

const N_ARRAY: u32 = 64;

fn load_hermetic() -> (Model, Dataset) {
    let root = hermetic_dir();
    let model = loader::load_model(&root.join("models/hermnet_hsynth.cvm"))
        .expect("hermetic model (regenerate with scripts/gen_hermetic_golden.py)");
    let ds = Dataset::load(&root.join("data/hsynth_test.cvd")).expect("hermetic dataset");
    (model, ds)
}

/// Serve `n_req` requests through a fresh pool and measure throughput.
fn serve(
    model: &Model,
    ds: &Dataset,
    policy: Option<SharedPolicy>,
    n_req: usize,
    workers: usize,
    batch_size: usize,
) -> (f64, f64, f64) {
    let cfg = ServiceConfig {
        policy,
        n_array: N_ARRAY,
        workers,
        batch_size,
        batch_timeout: Duration::from_millis(1),
        ..Default::default()
    };
    let svc =
        InferenceService::start(Engine::new(model.clone()), cfg).expect("service starts");
    let pending: Vec<_> = (0..n_req)
        .map(|i| svc.submit(ds.image(i % ds.n)).expect("service accepting"))
        .collect();
    for p in pending {
        p.wait().expect("reply");
    }
    let snap = svc.shutdown();
    (
        snap.throughput_rps,
        snap.mean_latency.as_secs_f64() * 1e3,
        snap.p95_latency.as_secs_f64() * 1e3,
    )
}

fn main() {
    if std::env::var("CVAPPROX_THREADS").is_err() {
        std::env::set_var("CVAPPROX_THREADS", "1");
    }
    println!("== bench: paired_policy (hermetic) ==");
    let quick = std::env::var("CVAPPROX_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let (model, ds) = load_hermetic();
    let n_eval = ds.n;
    let n_req = if quick { 96 } else { 384 };
    let (workers, batch_size) = (2usize, 8usize);
    let engine = Engine::new(model.clone());
    let exact_acc = evaluate(&engine, &ds, &ForwardOpts::exact(), n_eval, 1).unwrap();
    println!(
        "(hermetic model {} MACs/img, {} eval images, {} requests/config, exact \
         acc {exact_acc:.4})",
        model.macs(),
        n_eval,
        n_req
    );

    // ---- signed-error profiles: the cancellation the pairing exploits ----
    let (fam, m_hi) = (Family::Perforated, 3u32);
    let neg = signed_moments(fam, m_hi, Polarity::Neg);
    let pos = signed_moments(fam, m_hi, Polarity::Pos);
    let resid = pairing_residual((fam, m_hi, Polarity::Neg), (fam, m_hi, Polarity::Pos));
    println!(
        "signed profiles {} m={m_hi}: neg μ={:+.1} σ={:.1}, pos μ={:+.1} σ={:.1}, \
         pairing residual {resid:+.3}",
        fam.name(),
        neg.mean,
        neg.std,
        pos.mean,
        pos.std
    );
    assert!(
        resid.abs() < 1e-6 * neg.mean.abs(),
        "mirrored pairing must cancel the mean exactly"
    );

    // ---- PR 3 baseline: the mixed greedy policy ---------------------------
    let sens = sensitivity(&engine, &ds, fam, m_hi, n_eval).unwrap();
    let mixed =
        greedy_policy(&engine, &ds, fam, m_hi, 0.8, n_eval, N_ARRAY, &sens).unwrap();
    let mixed_policy = Arc::new(mixed.layer_policy().unwrap());
    let mixed_power = mixed_policy.power_norm(&model, N_ARRAY);
    println!(
        "mixed policy {} acc {:.4} power {:.3}x",
        mixed_policy.describe(),
        mixed.acc,
        mixed_power
    );

    // ---- the paired ladder search ----------------------------------------
    let paired = greedy_paired_policy(
        &engine, &ds, fam, m_hi, n_eval, N_ARRAY, &sens, &mixed_policy, exact_acc,
    )
    .unwrap();
    let paired_policy = Arc::new(paired.policy.clone());
    println!(
        "paired policy {} acc {:.4} power {:.3}x ({} paired layers)",
        paired_policy.describe(),
        paired.acc,
        paired.power_norm,
        paired_policy.paired_layers()
    );
    // The acceptance gate: dominates or matches the mixed policy on the
    // (estimated power, synthetic accuracy loss) plane. Deterministic data
    // + integer arithmetic: cannot flake.
    let mixed_loss = exact_acc - paired.base_acc;
    let paired_loss = exact_acc - paired.acc;
    assert!(
        paired_loss <= mixed_loss + 1e-12,
        "paired loss {paired_loss} must not exceed mixed loss {mixed_loss}"
    );
    assert!(
        paired.power_norm <= mixed_power + 1e-12,
        "paired power {} must not exceed mixed power {mixed_power}",
        paired.power_norm
    );
    let strict = paired.power_norm < mixed_power - 1e-12 && paired_loss <= mixed_loss;
    println!(
        "dominance: paired (loss {:.4}, power {:.3}) vs mixed (loss {:.4}, \
         power {:.3}) -> {}",
        paired_loss,
        paired.power_norm,
        mixed_loss,
        mixed_power,
        if strict { "STRICTLY dominates" } else { "matches" }
    );

    // ---- pool bit-identity: replies == per-image paired forwards ---------
    let svc = InferenceService::start(
        Engine::new(model.clone()),
        ServiceConfig {
            policy: Some(paired_policy.clone()),
            n_array: N_ARRAY,
            workers,
            batch_size,
            batch_timeout: Duration::from_millis(10),
            ..Default::default()
        },
    )
    .expect("paired service starts");
    let opts = ForwardOpts::with_policy(paired_policy.clone());
    let imgs: Vec<Tensor> = (0..16).map(|i| ds.image(i)).collect();
    let pending: Vec<_> = imgs.iter().map(|im| svc.submit(im.clone()).unwrap()).collect();
    for (img, p) in imgs.iter().zip(pending) {
        let reply = p.wait().unwrap();
        let want = engine.forward(img, &opts).unwrap();
        assert_eq!(
            reply.logits, want,
            "pool reply must be bit-identical to the per-image paired forward"
        );
    }
    svc.shutdown();
    println!("bit-identity: pool replies == per-image paired forwards (16 images)");

    // ---- mirrored-pairing grid (reference rows, no serving) --------------
    let mut grid_rows = Vec::new();
    for family in Family::APPROX {
        for &m in family.paper_levels() {
            let uni = Arc::new(
                LayerPolicy::uniform(family, m, true, model.mac_layers()).unwrap(),
            );
            let pair = Arc::new(
                LayerPolicy::paired_uniform(family, m, true, model.mac_layers())
                    .unwrap(),
            );
            let acc_uni =
                evaluate(&engine, &ds, &ForwardOpts::with_policy(uni), n_eval, 1)
                    .unwrap();
            let acc_pair =
                evaluate(&engine, &ds, &ForwardOpts::with_policy(pair.clone()), n_eval, 1)
                    .unwrap();
            let power = pair.power_norm(&model, N_ARRAY);
            println!(
                "  {} m={m}: uniform+V {acc_uni:.4}  mirrored-pair+V \
                 {acc_pair:.4}  (power {power:.3}x both)",
                family.name()
            );
            grid_rows.push(
                Json::obj()
                    .field("family", family.name())
                    .field("m", m as i64)
                    .field("acc_uniform_cv", acc_uni)
                    .field("acc_paired_cv", acc_pair)
                    .field("power_norm", power),
            );
        }
    }

    // ---- serving throughput: exact vs mixed vs paired --------------------
    let mut served = Vec::new();
    for (label, policy) in [
        ("uniform exact", None),
        ("mixed policy", Some(mixed_policy.clone())),
        ("paired policy", Some(paired_policy.clone())),
    ] {
        let (rps, mean_ms, p95_ms) =
            serve(&model, &ds, policy, n_req, workers, batch_size);
        println!("  serve {label:<14} {rps:>8.1} img/s  mean {mean_ms:.2} ms");
        served.push(
            Json::obj()
                .field("config", label)
                .field("images_s", rps)
                .field("mean_ms", mean_ms)
                .field("p95_ms", p95_ms),
        );
    }

    let json = Json::obj()
        .field("bench", "paired_policy")
        .field("model", "hermnet_hsynth (hermetic)")
        .field("model_macs", model.macs() as i64)
        .field("eval_images", n_eval)
        .field("requests_per_config", n_req)
        .field("quick", quick)
        .field("exact_acc", exact_acc)
        .field(
            "signed_profiles",
            Json::obj()
                .field("family", fam.name())
                .field("m", m_hi as i64)
                .field("neg_mean", neg.mean)
                .field("pos_mean", pos.mean)
                .field("std", neg.std)
                .field("pairing_residual", resid),
        )
        .field(
            "mixed",
            Json::obj()
                .field("policy", mixed_policy.describe())
                .field("acc", paired.base_acc)
                .field("power_norm", mixed_power),
        )
        .field(
            "paired",
            Json::obj()
                .field("policy", paired_policy.describe())
                .field("layers", paired_policy.to_json())
                .field("acc", paired.acc)
                .field("power_norm", paired.power_norm)
                .field("paired_layers", paired_policy.paired_layers()),
        )
        .field("paired_dominates_strictly", strict)
        .field("mirrored_grid", Json::Arr(grid_rows))
        .field("serving", Json::Arr(served));
    let path = cvapprox::util::bench::artifact_path("BENCH_paired.json");
    match std::fs::write(&path, json.render()) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => println!("(could not write {}: {e})", path.display()),
    }
    // On the hermetic set the upgrade is pinned (python mirror): at least
    // one layer pairs, so dominance is strict.
    assert!(
        paired_policy.paired_layers() >= 1 && strict,
        "hermetic paired search must strictly dominate the mixed policy"
    );
}
