//! Co-design search bench: the seeded NSGA-II genome search vs the greedy
//! ladder, end to end on the checked-in hermetic artifacts (no `make
//! artifacts`, no network — CI always executes it).
//!
//! Emits `BENCH_search.json` and the `SEARCH_pareto.json` front artifact
//! the `cvapprox qos-ladder --search` path consumes. Asserted, not just
//! reported:
//! * the same seed produces a **byte-identical** `SEARCH_pareto.json` at
//!   1 worker and at N workers (the determinism contract the integration
//!   suite pins per-commit; here it is also timed);
//! * some searched front member **strictly dominates** the greedy-paired
//!   rung on the (est_loss, power) plane — the search starts from the
//!   greedy ladder's own policies (plus per-layer deepenings of them), so
//!   it can only add to that baseline, never lose it;
//! * the searched front's hypervolume is **no smaller** than the greedy
//!   ladder's (guaranteed by the same seeding: every greedy rung's genome
//!   is in the archive the front is drawn from);
//! * the merged ladder (`qos_ladder_with_search`) keeps every greedy rung,
//!   installs at least one searched rung, and stays power-monotone.
//!
//! Env knobs: `CVAPPROX_BENCH_QUICK=1` (smaller population/fewer
//! generations); `CVAPPROX_THREADS` pinned to 1 unless set.

use std::time::Instant;

use cvapprox::approx::Family;
use cvapprox::datasets::Dataset;
use cvapprox::hermetic_dir;
use cvapprox::nn::policy::MAX_M;
use cvapprox::nn::{loader, Engine};
use cvapprox::report::layerwise::{qos_ladder, qos_ladder_with_search};
use cvapprox::search::{self, nsga, Gene, Genome, Objectives, SearchConfig, SearchResult};
use cvapprox::util::json::Json;

const N_ARRAY: u32 = 64;
const FAMILY: Family = Family::Perforated;
const M_HI: u32 = 3;
const BUDGET_PCT: f64 = 0.8;
/// Hypervolume reference point: both axes of every rung stay inside it
/// (power_norm <= 1.0 == exact, est_loss < 1.0).
const REF_LOSS: f64 = 1.0;
const REF_POWER: f64 = 1.25;

/// Per-layer deepenings of a seed genome: for every approximate gene, the
/// same shape at every deeper m (as the gene is, and as a mirrored pair),
/// plus the power-neutral pairing of the gene itself. These are the moves
/// the greedy searches cannot make, handed to generation 0 so the front
/// explores strictly beyond the baseline from the start.
fn deepened(seed: &Genome) -> Vec<Genome> {
    let mut out = Vec::new();
    for (i, g) in seed.genes.iter().enumerate() {
        if g.m() == 0 {
            continue;
        }
        for m in g.m() + 1..=MAX_M {
            for paired in [g.paired, true] {
                let mut v = seed.clone();
                v.genes[i] = Gene::approx(g.shape, m, g.polarity, g.use_cv, paired);
                out.push(v);
            }
        }
        if !g.paired {
            let mut v = seed.clone();
            v.genes[i] = Gene::approx(g.shape, g.m(), g.polarity, g.use_cv, true);
            out.push(v);
        }
    }
    out
}

fn timed_run(engine: &Engine, ds: &Dataset, cfg: &SearchConfig) -> (SearchResult, f64) {
    let t0 = Instant::now();
    let result = search::run_search(engine, ds, cfg).expect("search runs hermetically");
    (result, t0.elapsed().as_secs_f64())
}

fn objectives(est_loss: f64, power_norm: f64) -> Objectives {
    Objectives { est_loss, power_norm }
}

fn main() {
    if std::env::var("CVAPPROX_THREADS").is_err() {
        std::env::set_var("CVAPPROX_THREADS", "1");
    }
    println!("== bench: codesign_search (hermetic) ==");
    let quick = std::env::var("CVAPPROX_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let root = hermetic_dir();
    let model = loader::load_model(&root.join("models/hermnet_hsynth.cvm"))
        .expect("hermetic model (regenerate with scripts/gen_hermetic_golden.py)");
    let ds = Dataset::load(&root.join("data/hsynth_test.cvd")).expect("hermetic dataset");
    let engine = Engine::new(model);
    let n_eval = ds.n;

    // ---- the greedy baseline the search must beat ------------------------
    let base = qos_ladder(&engine, &ds, FAMILY, M_HI, BUDGET_PCT, n_eval, N_ARRAY)
        .expect("greedy ladder");
    println!("greedy ladder ({} rungs):", base.len());
    for r in base.rungs() {
        println!("  {:<18} loss {:.4}  power {:.3}x", r.name, r.est_loss, r.power_norm);
    }
    let gp = base
        .rungs()
        .iter()
        .find(|r| r.name == "greedy-paired")
        .expect("hermetic ladder pins a greedy-paired rung");

    // ---- the search, seeded from the ladder it must dominate ------------
    let mut cfg = SearchConfig::new(n_eval);
    cfg.generations = if quick { 4 } else { 8 };
    cfg.pop = if quick { 12 } else { 20 };
    cfg.seed = 2024;
    for r in base.rungs() {
        if let Some(g) = Genome::from_policy(&r.policy) {
            for d in deepened(&g) {
                cfg.seeds.push(d);
            }
            cfg.seeds.push(g);
        }
    }
    println!(
        "search: {} generations, pop {}, seed {}, {} ladder-derived seeds",
        cfg.generations,
        cfg.pop,
        cfg.seed,
        cfg.seeds.len()
    );

    cfg.workers = 1;
    let (result, secs_1) = timed_run(&engine, &ds, &cfg);
    let render_1 = result.to_json().render();
    let workers_n = 4usize;
    cfg.workers = workers_n;
    let (result_n, secs_n) = timed_run(&engine, &ds, &cfg);
    assert_eq!(
        render_1,
        result_n.to_json().render(),
        "SEARCH_pareto.json must be byte-identical at 1 and {workers_n} workers"
    );
    let gens_total = (cfg.generations + 1) as f64; // generation 0 included
    println!(
        "front: {} members from {} evals ({} memo hits); {:.2}s/gen at 1 worker, \
         {:.2}s/gen at {workers_n} (byte-identical artifacts)",
        result.front.len(),
        result.evals,
        result.memo_hits,
        secs_1 / gens_total,
        secs_n / gens_total
    );
    for (i, m) in result.front.iter().enumerate() {
        println!(
            "  search-{i}: loss {:.4}  power {:.3}x  {}",
            m.est_loss,
            m.power_norm,
            m.genome.describe()
        );
    }

    // ---- acceptance gate: strict dominance over greedy-paired ------------
    let dominator = result.front.iter().find(|m| {
        let s = objectives(m.est_loss, m.power_norm);
        let g = objectives(gp.est_loss, gp.power_norm);
        nsga::dominates(s, g)
    });
    let dominator = dominator.unwrap_or_else(|| {
        panic!(
            "no searched front member strictly dominates greedy-paired \
             (loss {:.4}, power {:.3})",
            gp.est_loss, gp.power_norm
        )
    });
    println!(
        "dominance: search (loss {:.4}, power {:.3}) STRICTLY dominates greedy-paired \
         (loss {:.4}, power {:.3})",
        dominator.est_loss, dominator.power_norm, gp.est_loss, gp.power_norm
    );

    // ---- hypervolume: searched front vs the greedy staircase -------------
    let front_pts: Vec<Objectives> =
        result.front.iter().map(|m| objectives(m.est_loss, m.power_norm)).collect();
    let base_pts: Vec<Objectives> =
        base.rungs().iter().map(|r| objectives(r.est_loss, r.power_norm)).collect();
    let hv_search = nsga::hypervolume(&front_pts, REF_LOSS, REF_POWER);
    let hv_base = nsga::hypervolume(&base_pts, REF_LOSS, REF_POWER);
    println!("hypervolume: search {hv_search:.4} vs greedy ladder {hv_base:.4}");
    assert!(
        hv_search >= hv_base - 1e-12,
        "searched front hypervolume {hv_search} fell below the greedy ladder's {hv_base} \
         despite being seeded with its rungs"
    );

    // ---- the merge: searched rungs installed through the QoS ladder ------
    let merged = qos_ladder_with_search(
        &engine,
        &ds,
        FAMILY,
        M_HI,
        BUDGET_PCT,
        n_eval,
        N_ARRAY,
        &result.front,
    )
    .expect("merged ladder");
    let searched_kept =
        merged.rungs().iter().filter(|r| r.name.starts_with("search-")).count();
    println!("merged ladder ({} rungs, {} searched):", merged.len(), searched_kept);
    for r in merged.rungs() {
        println!("  {:<18} loss {:.4}  power {:.3}x", r.name, r.est_loss, r.power_norm);
    }
    for b in base.rungs() {
        assert!(
            merged.rungs().iter().any(|r| r.name == b.name),
            "merge must keep every greedy rung (lost {:?})",
            b.name
        );
    }
    assert!(searched_kept >= 1, "merge must install at least one searched rung");
    for w in merged.rungs().windows(2) {
        assert!(
            w[1].power_norm < w[0].power_norm + 1e-12,
            "merged ladder must stay power-monotone"
        );
    }

    // ---- artifacts -------------------------------------------------------
    let pareto_path = cvapprox::util::bench::artifact_path("SEARCH_pareto.json");
    match std::fs::write(&pareto_path, &render_1) {
        Ok(()) => println!("wrote {}", pareto_path.display()),
        Err(e) => println!("(could not write {}: {e})", pareto_path.display()),
    }
    let rungs_json = |rungs: &[cvapprox::qos::Rung]| {
        Json::Arr(
            rungs
                .iter()
                .map(|r| {
                    Json::obj()
                        .field("name", r.name.as_str())
                        .field("est_loss", r.est_loss)
                        .field("power_norm", r.power_norm)
                })
                .collect(),
        )
    };
    let json = Json::obj()
        .field("bench", "codesign_search")
        .field("model", "hermnet_hsynth (hermetic)")
        .field("eval_images", n_eval)
        .field("quick", quick)
        .field("generations", cfg.generations)
        .field("pop", cfg.pop)
        .field("seed", format!("{}", cfg.seed))
        .field("front_size", result.front.len())
        .field("evals", result.evals as i64)
        .field("memo_hits", result.memo_hits as i64)
        .field("hypervolume_search", hv_search)
        .field("hypervolume_greedy", hv_base)
        .field(
            "greedy_paired",
            Json::obj().field("est_loss", gp.est_loss).field("power_norm", gp.power_norm),
        )
        .field(
            "dominator",
            Json::obj()
                .field("est_loss", dominator.est_loss)
                .field("power_norm", dominator.power_norm)
                .field("describe", dominator.genome.describe()),
        )
        .field("dominates_greedy_paired", true)
        .field("byte_identical_across_workers", true)
        .field(
            "walltime",
            Json::obj()
                .field("workers_n", workers_n)
                .field("total_s_1w", secs_1)
                .field("total_s_nw", secs_n)
                .field("per_generation_s_1w", secs_1 / gens_total)
                .field("per_generation_s_nw", secs_n / gens_total),
        )
        .field("greedy_ladder", rungs_json(base.rungs()))
        .field("merged_ladder", rungs_json(merged.rungs()))
        .field("searched_kept", searched_kept);
    let path = cvapprox::util::bench::artifact_path("BENCH_search.json");
    match std::fs::write(&path, json.render()) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => println!("(could not write {}: {e})", path.display()),
    }
}
