//! Bench: end-to-end service throughput/latency through the batching
//! coordinator, across batch sizes — the L3 hot path.

use std::time::Duration;

use cvapprox::approx::Family;
use cvapprox::coordinator::{InferenceService, ServiceConfig};
use cvapprox::datasets::Dataset;
use cvapprox::nn::{loader, Engine};

fn main() {
    println!("== bench: coordinator_serve ==");
    let art = cvapprox::artifacts_dir();
    if !art.join("models").is_dir() {
        println!("(skipped: run `make artifacts` first)");
        return;
    }
    let ds = Dataset::load(&art.join("data/synth10_test.cvd")).unwrap();
    let n = 120usize;
    println!(
        "{:<10} {:>10} {:>12} {:>12} {:>9}",
        "batch", "img/s", "mean ms", "~p95 ms", "batches"
    );
    for batch in [1usize, 4, 8, 16] {
        let model = loader::load_model(&art.join("models/shufflenet_synth10.cvm")).unwrap();
        let engine = Engine::new(model);
        let cfg = ServiceConfig {
            family: Family::Perforated,
            m: 2,
            use_cv: true,
            // One worker isolates the batch-size effect; the serving bench
            // (benches/serving.rs) sweeps the worker dimension.
            workers: 1,
            batch_size: batch,
            batch_timeout: Duration::from_millis(1),
            ..Default::default()
        };
        let svc = InferenceService::start(engine, cfg).expect("service starts");
        let pending: Vec<_> = (0..n)
            .map(|i| svc.submit(ds.image(i % ds.n)).expect("service accepting"))
            .collect();
        for p in pending {
            p.wait().unwrap();
        }
        let snap = svc.shutdown();
        println!(
            "{:<10} {:>10.1} {:>12.2} {:>12.2} {:>9}",
            batch,
            snap.throughput_rps,
            snap.mean_latency.as_secs_f64() * 1e3,
            snap.p95_latency.as_secs_f64() * 1e3,
            snap.batches
        );
    }
}
