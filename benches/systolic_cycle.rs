//! Bench: cycle-level systolic simulator throughput (simulated MAC
//! cycles/s) and the cost of toggle counting — sizes how much inference
//! traffic the Questasim-substitute can absorb.

use cvapprox::approx::Family;
use cvapprox::cv::{self, CvConstants};
use cvapprox::systolic::SystolicArray;
use cvapprox::util::bench::Bencher;
use cvapprox::util::rng::Rng;

fn main() {
    println!("== bench: systolic_cycle ==");
    let b = Bencher::default();
    let mut rng = Rng::new(0x5C);
    let n_arr = 64usize;
    let rows = 32usize;
    let k = 64usize;
    let n_cols = 64usize;
    let w: Vec<Vec<u8>> = (0..rows).map(|_| (0..k).map(|_| rng.u8()).collect()).collect();
    let cols: Vec<Vec<u8>> =
        (0..n_cols).map(|_| (0..k).map(|_| rng.u8()).collect()).collect();
    let cycles = (k * n_cols * rows) as f64; // MAC-cell updates per run

    for family in [Family::Exact, Family::Perforated, Family::Truncated] {
        let m = *family.paper_levels().last().unwrap();
        let arr = SystolicArray::new(family, m, n_arr);
        let consts: Vec<CvConstants> =
            w.iter().map(|wr| cv::constants(family, m, wr, k)).collect();
        for apply_cv in [false, true] {
            let r = b.run(
                &format!(
                    "systolic {} m={m} {}x{} tile x{} cols cv={}",
                    family.name(),
                    rows,
                    k,
                    n_cols,
                    apply_cv
                ),
                cycles,
                || {
                    std::hint::black_box(arr.run_tile(&w, &cols, &consts, apply_cv, 0));
                },
            );
            println!("{}", r.report());
        }
    }
    // Latency model sanity line (paper: +1 cycle per layer for MAC+).
    let exact = SystolicArray::new(Family::Exact, 0, 64);
    let appr = SystolicArray::new(Family::Perforated, 2, 64);
    println!(
        "\nlatency model: exact {} cycles vs approx {} cycles for k=64, 1024 outputs \
         (+{} cycle MAC+)",
        exact.latency_cycles(64, 1024),
        appr.latency_cycles(64, 1024),
        appr.latency_cycles(64, 1024) - exact.latency_cycles(64, 1024)
    );
}
