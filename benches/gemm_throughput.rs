//! Core hot-path bench: approximate GEMM throughput (MAC/s) across engines —
//! native identity (planned, blocked, multithreaded) vs LUT vs the two PJRT
//! artifact variants (fast / pallas) — plus the kernel-backend comparison:
//! single-thread `planned-scalar` vs `planned-simd` rows per family, with
//! the measured speedup ratio recorded in the JSON (≥4× target on AVX2
//! hosts; hosts without AVX2 run the portable chunked lanes and record
//! whatever ratio they measure, annotated via `simd_accelerated`).
//!
//! Besides the stdout report it emits `BENCH_gemm_throughput.json` at the
//! repo root (`util::bench::artifact_path`) so the perf trajectory is
//! trackable across PRs: one record per configuration with GMAC/s, median
//! ns and thread count.
//!
//! Env knobs: `CVAPPROX_THREADS` (worker count for the threaded rows),
//! `CVAPPROX_BENCH_QUICK=1` (short CI smoke budgets); the kernel rows pin
//! their backends explicitly, independent of `CVAPPROX_KERNEL`.

use cvapprox::approx::{Family, MulLut};
use cvapprox::nn::gemm::{
    am_acc_identity, am_acc_lut, approx_gemm_planned, approx_gemm_planned_with_kernel,
    GemmCtx, GemmKind,
};
use cvapprox::nn::kernel;
use cvapprox::nn::{LayerPlan, Scratch};
use cvapprox::runtime::{TileGemm, Variant, TK, TM, TN};
use cvapprox::util::bench::{BenchResult, Bencher};
use cvapprox::util::json::Json;
use cvapprox::util::rng::Rng;
use cvapprox::util::threadpool::configured_workers;

struct Record {
    result: BenchResult,
    engine: String,
    family: &'static str,
    m: u32,
    /// Requested worker count.
    threads: usize,
    /// What the row-block fan-out can actually use: the kernel splits
    /// 4-row blocks, so a 48-row panel saturates at 12 workers. Recorded
    /// separately so scaling curves flattening at the block limit are
    /// visible in the trajectory data.
    threads_effective: usize,
}

fn push(
    records: &mut Vec<Record>,
    r: BenchResult,
    engine: &str,
    family: &'static str,
    m: u32,
    threads: usize,
    m_rows: usize,
) {
    println!("{}", r.report());
    let threads_effective = threads.max(1).min((m_rows + 3) / 4);
    records.push(Record {
        result: r,
        engine: engine.to_string(),
        family,
        m,
        threads,
        threads_effective,
    });
}

fn main() {
    println!("== bench: gemm_throughput ==");
    let quick = std::env::var("CVAPPROX_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let b = if quick { Bencher::quick() } else { Bencher::default() };
    let workers = configured_workers();
    let mut rng = Rng::new(0x6E);
    let mut records: Vec<Record> = Vec::new();
    // Layer-realistic GEMM: 48 filters, K=288 (3x3x32), N=256 positions.
    let (m_rows, k, n) = (48usize, 288usize, 256usize);
    let macs = (m_rows * k * n) as f64;
    let w: Vec<u8> = (0..m_rows * k).map(|_| rng.u8()).collect();
    let a: Vec<u8> = (0..k * n).map(|_| rng.u8()).collect();
    println!("(shape {m_rows}x{k}x{n}, CVAPPROX_THREADS={workers})");

    // Identity engine through the public wrapper (plan built per call, the
    // worst case) at the configured thread count.
    for family in Family::ALL {
        let m = *family.paper_levels().last().unwrap();
        let r = b.run(
            &format!("identity {} m={m} {}x{}x{}", family.name(), m_rows, k, n),
            macs,
            || {
                std::hint::black_box(am_acc_identity(family, m, &w, &a, m_rows, k, n));
            },
        );
        push(&mut records, r, "identity", family.name(), m, workers, m_rows);
    }

    // Planned + scratch-reusing path (what Engine::forward runs in steady
    // state) across thread counts — the perf-trajectory rows.
    let bias = vec![0i32; m_rows];
    let mut threads_list = vec![1usize, 2, 4];
    if !threads_list.contains(&workers) {
        threads_list.push(workers);
    }
    for family in Family::ALL {
        let m = *family.paper_levels().last().unwrap();
        let ctx = GemmCtx { family, m, use_cv: true, zp_w: 9, zp_a: 101 };
        let plan = LayerPlan::build(family, m, &w, m_rows, k);
        let mut scratch = Scratch::new();
        for &t in &threads_list {
            let r = b.run(
                &format!("planned  {} m={m} t{t} {}x{}x{}", family.name(), m_rows, k, n),
                macs,
                || {
                    approx_gemm_planned(
                        GemmKind::Identity, &ctx, &plan, 0, None, &w, &a, m_rows, k, n,
                        &bias, &mut scratch, t,
                    );
                    std::hint::black_box(scratch.acc.last().copied());
                },
            );
            push(&mut records, r, "planned", family.name(), m, t, m_rows);
        }
    }

    // Kernel-backend comparison: the same planned path pinned to each
    // backend, single-threaded so the ratio is a pure kernel property (the
    // row-block fan-out above is backend-independent). These are the rows
    // the ≥4× SIMD acceptance claim reads.
    let mut speedups: Vec<(&'static str, f64)> = Vec::new();
    for family in Family::ALL {
        let m = *family.paper_levels().last().unwrap();
        let ctx = GemmCtx { family, m, use_cv: true, zp_w: 9, zp_a: 101 };
        let plan = LayerPlan::build(family, m, &w, m_rows, k);
        let mut scratch = Scratch::new();
        let mut medians = [0.0f64; 2];
        for (i, (kr, engine)) in
            [(kernel::scalar(), "planned-scalar"), (kernel::simd(), "planned-simd")]
                .into_iter()
                .enumerate()
        {
            let r = b.run(
                &format!("{engine} {} m={m} t1 {}x{}x{}", family.name(), m_rows, k, n),
                macs,
                || {
                    approx_gemm_planned_with_kernel(
                        kr, GemmKind::Identity, &ctx, &plan, 0, None, &w, &a, m_rows,
                        k, n, &bias, &mut scratch, 1,
                    );
                    std::hint::black_box(scratch.acc.last().copied());
                },
            );
            medians[i] = r.median_ns;
            push(&mut records, r, engine, family.name(), m, 1, m_rows);
        }
        if medians[1] > 0.0 {
            speedups.push((family.name(), medians[0] / medians[1]));
        }
    }
    let simd_accelerated = kernel::simd_is_accelerated();
    for (fam, s) in &speedups {
        println!("simd speedup {fam}: {s:.2}x (1 thread)");
    }
    if !simd_accelerated {
        println!(
            "(no AVX2 on this host — planned-simd ran the portable chunked \
             lanes; the ≥4x target applies to AVX2 hosts)"
        );
    }

    for family in Family::APPROX {
        let m = *family.paper_levels().last().unwrap();
        let lut = MulLut::build(family, m);
        let r = b.run(
            &format!("lut      {} m={m} {}x{}x{}", family.name(), m_rows, k, n),
            macs,
            || {
                std::hint::black_box(am_acc_lut(&lut, &w, &a, m_rows, k, n));
            },
        );
        push(&mut records, r, "lut", family.name(), m, workers, m_rows);
    }

    // PJRT tile executions (one artifact tile per call). Skipped without the
    // `pjrt` feature / HLO artifacts.
    match TileGemm::new(&cvapprox::artifacts_dir()) {
        Ok(rt) => {
            let tile_macs = (TM * TK * TN) as f64;
            let wt: Vec<i32> = (0..TM * TK).map(|_| rng.u8() as i32).collect();
            let at: Vec<i32> = (0..TK * TN).map(|_| rng.u8() as i32).collect();
            for variant in [Variant::Fast, Variant::Pallas] {
                for family in [Family::Exact, Family::Perforated, Family::Truncated] {
                    let m = *family.paper_levels().last().unwrap();
                    rt.warmup(family, variant).unwrap();
                    let r = b.run(
                        &format!(
                            "pjrt-{} {} m={m} tile {}x{}x{}",
                            variant.name(),
                            family.name(),
                            TM,
                            TK,
                            TN
                        ),
                        tile_macs,
                        || {
                            std::hint::black_box(
                                rt.run_tile(family, variant, m, &wt, &at).unwrap(),
                            );
                        },
                    );
                    let engine = format!("pjrt-{}", variant.name());
                    push(&mut records, r, &engine, family.name(), m, 1, TM);
                }
            }
        }
        Err(e) => println!("(pjrt benches skipped: {e:#})"),
    }

    // Machine-readable trajectory dump.
    let json = Json::obj()
        .field("bench", "gemm_throughput")
        .field("shape", Json::arr([m_rows, k, n]))
        .field("threads_configured", workers)
        .field("quick", quick)
        .field("kernel_active", kernel::active().name())
        .field("simd_accelerated", simd_accelerated)
        .field(
            "simd_speedup_1t",
            Json::Arr(
                speedups
                    .iter()
                    .map(|(fam, s)| {
                        Json::obj().field("family", *fam).field("speedup", *s)
                    })
                    .collect(),
            ),
        )
        .field(
            "simd_speedup_note",
            if simd_accelerated {
                "planned-scalar vs planned-simd medians at 1 thread (AVX2)"
            } else {
                "host lacks AVX2: planned-simd is the portable chunked-lane \
                 path, so the >=4x AVX2 target does not apply to this ratio"
            },
        )
        .field(
            "results",
            Json::Arr(
                records
                    .iter()
                    .map(|rec| {
                        Json::obj()
                            .field("name", rec.result.name.as_str())
                            .field("engine", rec.engine.as_str())
                            .field("family", rec.family)
                            .field("m", rec.m as i64)
                            .field("threads", rec.threads)
                            .field("threads_effective", rec.threads_effective)
                            .field("median_ns", rec.result.median_ns)
                            .field("p95_ns", rec.result.p95_ns)
                            .field("samples", rec.result.samples)
                            .field("gmacs", rec.result.throughput() / 1e9)
                    })
                    .collect(),
            ),
        );
    let path = cvapprox::util::bench::artifact_path("BENCH_gemm_throughput.json");
    match std::fs::write(&path, json.render()) {
        Ok(()) => println!("\nwrote {} ({} records)", path.display(), records.len()),
        Err(e) => println!("\n(could not write {}: {e})", path.display()),
    }
}
