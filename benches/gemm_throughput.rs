//! Core hot-path bench: approximate GEMM throughput (MAC/s) across engines —
//! native identity vs LUT vs the two PJRT artifact variants (fast / pallas).
//! This is the measurement the §Perf optimization loop drives on.

use cvapprox::approx::Family;
use cvapprox::nn::gemm::{am_acc_identity, am_acc_lut};
use cvapprox::runtime::{TileGemm, Variant, TK, TM, TN};
use cvapprox::approx::MulLut;
use cvapprox::util::bench::Bencher;
use cvapprox::util::rng::Rng;

fn main() {
    println!("== bench: gemm_throughput ==");
    let b = Bencher::default();
    let mut rng = Rng::new(0x6E);
    // Layer-realistic GEMM: 48 filters, K=288 (3x3x32), N=256 positions.
    let (m_rows, k, n) = (48usize, 288usize, 256usize);
    let macs = (m_rows * k * n) as f64;
    let w: Vec<u8> = (0..m_rows * k).map(|_| rng.u8()).collect();
    let a: Vec<u8> = (0..k * n).map(|_| rng.u8()).collect();

    for family in Family::ALL {
        let m = *family.paper_levels().last().unwrap();
        let r = b.run(
            &format!("identity {} m={m} {}x{}x{}", family.name(), m_rows, k, n),
            macs,
            || {
                std::hint::black_box(am_acc_identity(family, m, &w, &a, m_rows, k, n));
            },
        );
        println!("{}", r.report());
    }
    for family in Family::APPROX {
        let m = *family.paper_levels().last().unwrap();
        let lut = MulLut::build(family, m);
        let r = b.run(
            &format!("lut      {} m={m} {}x{}x{}", family.name(), m_rows, k, n),
            macs,
            || {
                std::hint::black_box(am_acc_lut(&lut, &w, &a, m_rows, k, n));
            },
        );
        println!("{}", r.report());
    }

    // PJRT tile executions (one artifact tile per call).
    match TileGemm::new(&cvapprox::artifacts_dir()) {
        Ok(rt) => {
            let tile_macs = (TM * TK * TN) as f64;
            let wt: Vec<i32> = (0..TM * TK).map(|_| rng.u8() as i32).collect();
            let at: Vec<i32> = (0..TK * TN).map(|_| rng.u8() as i32).collect();
            for variant in [Variant::Fast, Variant::Pallas] {
                for family in [Family::Exact, Family::Perforated, Family::Truncated] {
                    let m = *family.paper_levels().last().unwrap();
                    rt.warmup(family, variant).unwrap();
                    let r = b.run(
                        &format!(
                            "pjrt-{} {} m={m} tile {}x{}x{}",
                            variant.name(),
                            family.name(),
                            TM,
                            TK,
                            TN
                        ),
                        tile_macs,
                        || {
                            std::hint::black_box(
                                rt.run_tile(family, variant, m, &wt, &at).unwrap(),
                            );
                        },
                    );
                    println!("{}", r.report());
                }
            }
        }
        Err(e) => println!("(pjrt benches skipped: {e})"),
    }
}
