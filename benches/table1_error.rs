//! Bench for **Table 1**: regenerates the error-moment table (reduced
//! sample count) and measures the multiplier models' throughput — the cost
//! of the error analysis itself.

use cvapprox::approx::stats::{error_moments, error_moments_exhaustive_uniform, Dist};
use cvapprox::approx::{am, Family, MulLut};
use cvapprox::util::bench::Bencher;
use cvapprox::util::rng::Rng;

fn main() {
    println!("== bench: table1_error ==");
    let b = Bencher::default();

    // Regenerate Table 1 (100k samples/cell) and time it.
    let r = b.run("table1 cell (100k samples, trunc m=6, U)", 100_000.0, || {
        std::hint::black_box(error_moments(Family::Truncated, 6, Dist::Uniform, 100_000, 7));
    });
    println!("{}", r.report());

    // Exhaustive 2^16 closed-form sweep (the validation path).
    let r = b.run("exhaustive 256x256 moments (perforated m=2)", 65_536.0, || {
        std::hint::black_box(error_moments_exhaustive_uniform(Family::Perforated, 2));
    });
    println!("{}", r.report());

    // Scalar multiplier model throughput per family.
    let mut rng = Rng::new(0xBE);
    let ops: Vec<(u8, u8)> = (0..4096).map(|_| (rng.u8(), rng.u8())).collect();
    for family in Family::APPROX {
        let m = family.paper_levels()[1];
        let r = b.run(&format!("am({}) closed form x4096", family.name()), 4096.0, || {
            let mut acc = 0i64;
            for &(w, a) in &ops {
                acc += am(family, w, a, m) as i64;
            }
            std::hint::black_box(acc);
        });
        println!("{}", r.report());
        let lut = MulLut::build(family, m);
        let r = b.run(&format!("am({}) LUT x4096", family.name()), 4096.0, || {
            let mut acc = 0i64;
            for &(w, a) in &ops {
                acc += lut.mul(w, a) as i64;
            }
            std::hint::black_box(acc);
        });
        println!("{}", r.report());
    }
    println!();
    // Print the actual (reduced) Table 1 so the bench regenerates the artifact.
    let rows = cvapprox::approx::stats::table1(100_000, 2024);
    println!("{}", cvapprox::report::tables::render_table1(&rows));
}
