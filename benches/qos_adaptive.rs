//! Adaptive-QoS bench: the governor under a bursty open-loop load trace.
//!
//! Runs entirely on the checked-in hermetic artifacts (no `make artifacts`,
//! no network — CI always executes it):
//!
//! 1. `report::layerwise::qos_ladder` builds the four-rung ladder (exact →
//!    greedy mixed → greedy paired → aggressive uniform) and round-trips it
//!    through the JSON artifact (`QOS_ladder_hermnet_hsynth.json`).
//! 2. A bursty trace drives a governed pool: escalating request bursts
//!    until the governor steps DOWN the ladder, then an idle phase until it
//!    recovers to rung 0 — repeated for several cycles. The realized trace
//!    (exact wave sizes per cycle) is recorded and REPLAYED against two
//!    static baselines (static-exact, static-aggressive) so the comparison
//!    rows measure the same work.
//! 3. Hard assertions, not just reporting: ≥ 2 rung transitions (≥ 1
//!    `latency-over-target` down + ≥ 1 `idle-recovery` up), every governed
//!    reply **bit-identical** to the static forward of its epoch's rung,
//!    blended energy strictly below static-exact, and idle phases ending at
//!    rung 0 (the governor matches exact accuracy when idle — unlike
//!    static-aggressive, which keeps its loss around the clock).
//!
//! Emits `BENCH_qos.json`: per-config throughput / p50 / p95 / energy, the
//! transition log, per-rung dwell fractions, and the power-capped modeled
//! throughput (rps / energy_vs_exact — the sustained rate a fixed power
//! envelope affords, where the governor dominates static-exact because its
//! bursts ran on cheaper rungs).
//!
//! 4. **Mixed tenants** (PR 9): a light and a heavy class share one pool,
//!    each with its own governor stepping its own ladder. The heavy flood
//!    must drive the heavy governor down while the light governor never
//!    leaves rung 0 and every light reply stays bit-identical to the exact
//!    rung's forward — per-class p99 and both rung trajectories land in
//!    the artifact.
//!
//! Env knobs: `CVAPPROX_BENCH_QUICK=1` (fewer cycles, smaller first burst);
//! `CVAPPROX_THREADS` pinned to 1 unless set.

use std::sync::Arc;
use std::time::{Duration, Instant};

use cvapprox::approx::Family;
use cvapprox::coordinator::service::Reply;
use cvapprox::coordinator::{InferenceService, MetricsSnapshot, ServiceConfig, TenantClass};
use cvapprox::datasets::Dataset;
use cvapprox::hermetic_dir;
use cvapprox::nn::{loader, Engine, ForwardOpts, Model};
use cvapprox::qos::{Governor, GovernorReport, Ladder, QosConfig};
use cvapprox::report::layerwise::qos_ladder;
use cvapprox::util::json::Json;

const N_ARRAY: u32 = 64;
const WORKERS: usize = 2;
const BATCH: usize = 8;

fn load_hermetic() -> (Model, Dataset) {
    let root = hermetic_dir();
    let model = loader::load_model(&root.join("models/hermnet_hsynth.cvm"))
        .expect("hermetic model (regenerate with scripts/gen_hermetic_golden.py)");
    let ds = Dataset::load(&root.join("data/hsynth_test.cvd")).expect("hermetic dataset");
    (model, ds)
}

fn service(model: &Model, policy: Option<Arc<cvapprox::nn::LayerPolicy>>) -> InferenceService {
    InferenceService::start(
        Engine::new(model.clone()),
        ServiceConfig {
            policy,
            n_array: N_ARRAY,
            workers: WORKERS,
            batch_size: BATCH,
            batch_timeout: Duration::from_micros(500),
            ..Default::default()
        },
    )
    .expect("service starts")
}

/// Submit one open-loop burst of `n` requests and wait for every reply;
/// returns (image index, reply) in submit order.
fn burst(svc: &InferenceService, ds: &Dataset, n: usize) -> Vec<(usize, Reply)> {
    let pend: Vec<_> = (0..n)
        .map(|i| svc.submit(ds.image(i % ds.n)).expect("service accepting"))
        .collect();
    pend.into_iter()
        .enumerate()
        .map(|(i, p)| (i % ds.n, p.wait().expect("reply")))
        .collect()
}

/// The realized bursty trace: per cycle, the wave sizes that were submitted.
type Trace = Vec<Vec<usize>>;

/// Drive the governed pool: per cycle, escalate bursts until the governor
/// leaves rung 0, push one more burst at that size (so approximate rungs
/// actually serve traffic), then idle until it recovers to rung 0.
fn drive_governed(
    svc: &InferenceService,
    gov: &Governor,
    ds: &Dataset,
    cycles: usize,
    first_wave: usize,
    idle: Duration,
) -> (Vec<(usize, Reply)>, Trace) {
    let mut replies = Vec::new();
    let mut trace: Trace = Vec::new();
    for cycle in 0..cycles {
        let mut waves: Vec<usize> = Vec::new();
        let mut wave = first_wave;
        while gov.rung() == 0 && waves.len() < 24 {
            replies.extend(burst(svc, ds, wave));
            waves.push(wave);
            wave = (wave * 2).min(16 * 1024);
        }
        assert!(
            gov.rung() > 0,
            "cycle {cycle}: governor never stepped down (waves {waves:?})"
        );
        let last = *waves.last().unwrap();
        replies.extend(burst(svc, ds, last));
        waves.push(last);
        trace.push(waves);
        // Idle phase: wait for the governor to recover to exact.
        let t0 = Instant::now();
        while gov.rung() != 0 && t0.elapsed() < Duration::from_secs(20) {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(gov.rung(), 0, "cycle {cycle}: governor did not recover when idle");
        std::thread::sleep(idle);
    }
    (replies, trace)
}

/// Replay the recorded trace against a static service (same bursts, same
/// idle gaps) so the baseline rows measure identical work.
fn drive_static(svc: &InferenceService, ds: &Dataset, trace: &Trace, idle: Duration) {
    for waves in trace {
        for &w in waves {
            burst(svc, ds, w);
        }
        std::thread::sleep(idle);
    }
}

struct Row {
    label: String,
    snap: MetricsSnapshot,
}

impl Row {
    fn json(&self) -> Json {
        let s = &self.snap;
        let rps = s.throughput_rps;
        Json::obj()
            .field("config", self.label.as_str())
            .field("completed", s.completed as i64)
            .field("images_s", rps)
            .field("p50_ms", s.p50_latency.as_secs_f64() * 1e3)
            .field("p95_ms", s.p95_latency.as_secs_f64() * 1e3)
            .field("p99_ms", s.p99_latency.as_secs_f64() * 1e3)
            .field("mean_batch_size", s.mean_batch_size)
            .field("energy_vs_exact", s.energy_vs_exact)
            .field(
                "capped_images_s",
                if s.energy_vs_exact > 0.0 { rps / s.energy_vs_exact } else { rps },
            )
    }
}

fn main() {
    if std::env::var("CVAPPROX_THREADS").is_err() {
        std::env::set_var("CVAPPROX_THREADS", "1");
    }
    println!("== bench: qos_adaptive (hermetic) ==");
    let quick = std::env::var("CVAPPROX_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let (model, ds) = load_hermetic();
    let cycles = if quick { 2 } else { 3 };
    let first_wave = if quick { 256 } else { 512 };
    let idle = Duration::from_millis(150);

    // ---- ladder artifact -------------------------------------------------
    let engine = Engine::new(model.clone());
    let ladder = qos_ladder(&engine, &ds, Family::Perforated, 3, 0.8, ds.n, N_ARRAY)
        .expect("ladder search");
    let ladder_path =
        cvapprox::util::bench::artifact_path("QOS_ladder_hermnet_hsynth.json");
    ladder.save_json(&ladder_path).expect("write ladder");
    let ladder = Ladder::load(&ladder_path).expect("reload ladder");
    println!("ladder: {} -> {}", ladder.describe(), ladder_path.display());
    assert!(ladder.len() >= 3, "hermetic ladder should have >= 3 rungs");

    // ---- governed run ----------------------------------------------------
    let svc = service(&model, None);
    // The error-proxy ceiling is exercised by the unit suite; here it is
    // opened up so the transition log is driven by the latency signal
    // alone, while max_est_loss keeps the lossy bottom rung out of bounds
    // (the accuracy constraint holds even under overload).
    let cfg = QosConfig {
        latency_target: Duration::from_millis(2),
        step_up_frac: 0.5,
        error_ceiling: f64::INFINITY,
        max_est_loss: 0.2,
        min_dwell: Duration::from_millis(40),
        tick: Duration::from_millis(8),
        min_window: 8,
    };
    let gov = Governor::start(&svc, ladder.clone(), cfg).expect("governor starts");
    let t_gov = Instant::now();
    let (replies, trace) = drive_governed(&svc, &gov, &ds, cycles, first_wave, idle);
    let governed_wall = t_gov.elapsed();
    let report: GovernorReport = gov.stop();
    let governed = Row { label: "governed".into(), snap: svc.shutdown() };

    // ---- transition + dwell acceptance ----------------------------------
    println!(
        "\n{} transitions over {:.2}s:",
        report.transitions.len(),
        governed_wall.as_secs_f64()
    );
    for t in &report.transitions {
        println!(
            "  t+{:>7.3}s  rung {} -> {} (epoch {:>3}, p95 {:>7.2} ms, proxy {:.4}) [{}]",
            t.at.as_secs_f64(),
            t.from,
            t.to,
            t.epoch,
            t.p95.as_secs_f64() * 1e3,
            t.cv_proxy,
            t.reason
        );
    }
    assert!(
        report.transitions.len() >= 2,
        "need >= 2 rung transitions, got {}",
        report.transitions.len()
    );
    assert!(
        report.transitions.iter().any(|t| t.reason == "latency-over-target"),
        "no step-down under load"
    );
    assert!(
        report.transitions.iter().any(|t| t.reason == "idle-recovery"),
        "no step-up when idle"
    );
    assert_eq!(report.final_rung, 0, "must end idle at the exact rung");
    let dwell = report.dwell_fractions();
    println!("dwell fractions: {dwell:?}");
    assert!(dwell[0] > 0.0, "no dwell at exact");
    assert!(dwell.iter().skip(1).any(|&f| f > 0.0), "no dwell below exact");

    // ---- bit-identity: every reply == its epoch rung's static forward ----
    let reference = Engine::new(model.clone());
    let mut cache: std::collections::HashMap<(usize, usize), Vec<f64>> =
        std::collections::HashMap::new();
    let mut by_rung = vec![0u64; ladder.len()];
    for (img, r) in &replies {
        let rung = report
            .rung_for_epoch(r.epoch)
            .unwrap_or_else(|| panic!("reply epoch {} unknown to the governor", r.epoch));
        by_rung[rung] += 1;
        let want = cache.entry((rung, *img)).or_insert_with(|| {
            reference
                .forward(
                    &ds.image(*img),
                    &ForwardOpts::with_policy(ladder.rung(rung).policy.clone()),
                )
                .unwrap()
        });
        assert_eq!(
            &r.logits, want,
            "reply (epoch {}, rung {rung}, img {img}) not bit-identical to its \
             rung's static forward",
            r.epoch
        );
    }
    println!(
        "bit-identity: {} replies verified against their epoch rungs {:?}",
        replies.len(),
        by_rung
    );
    assert!(by_rung[0] > 0, "no traffic served at exact");
    assert!(
        by_rung.iter().skip(1).any(|&n| n > 0),
        "no traffic served below exact — swaps never caught live batches"
    );

    // ---- static baselines over the identical realized trace --------------
    let svc_exact = service(&model, Some(ladder.rung(0).policy.clone()));
    drive_static(&svc_exact, &ds, &trace, idle);
    let exact = Row { label: "static-exact".into(), snap: svc_exact.shutdown() };
    let last = ladder.len() - 1;
    let svc_aggr = service(&model, Some(ladder.rung(last).policy.clone()));
    drive_static(&svc_aggr, &ds, &trace, idle);
    let aggr = Row {
        label: format!("static-{}", ladder.rung(last).name),
        snap: svc_aggr.shutdown(),
    };

    // ---- report ----------------------------------------------------------
    let rows = [&exact, &aggr, &governed];
    println!(
        "\n{:<28} {:>10} {:>9} {:>9} {:>9} {:>12}",
        "config", "img/s", "p95 ms", "energy", "capped/s", "completed"
    );
    for r in rows {
        let s = &r.snap;
        println!(
            "{:<28} {:>10.1} {:>9.2} {:>9.4} {:>12.1} {:>9}",
            r.label,
            s.throughput_rps,
            s.p95_latency.as_secs_f64() * 1e3,
            s.energy_vs_exact,
            s.throughput_rps / s.energy_vs_exact.max(1e-9),
            s.completed
        );
    }
    // The governor's blended energy must sit strictly below static-exact
    // (its bursts ran on cheaper rungs), which is what makes its
    // power-capped throughput dominate static-exact on the same trace; and
    // its idle accuracy floor is exact (rung 0), unlike static-aggressive
    // which keeps the last rung's est_loss around the clock.
    assert!(
        governed.snap.energy_vs_exact < 1.0 - 1e-6,
        "governed energy {} did not drop below exact",
        governed.snap.energy_vs_exact
    );
    assert!(
        (exact.snap.energy_vs_exact - 1.0).abs() < 1e-9,
        "static-exact energy must be 1.0"
    );
    let governed_capped = governed.snap.throughput_rps / governed.snap.energy_vs_exact;
    println!(
        "\npower-capped modeled throughput: governed {:.1}/s vs static-exact {:.1}/s \
         (x{:.3}); idle accuracy floor: exact (rung 0) vs static-{} est_loss {:.2}%",
        governed_capped,
        exact.snap.throughput_rps,
        governed_capped / exact.snap.throughput_rps.max(1e-9),
        ladder.rung(last).name,
        100.0 * ladder.rung(last).est_loss
    );

    // ---- mixed tenants: one pool, two classes, two governors -------------
    // The heavy tenant floods until ITS governor steps down; the light
    // tenant trickles throughout. Class isolation means the light governor
    // never moves and light replies never change bits.
    println!("\n-- mixed tenants: light trickle + heavy flood, per-class governors --");
    let svc_mt = InferenceService::start(
        Engine::new(model.clone()),
        ServiceConfig {
            n_array: N_ARRAY,
            workers: WORKERS,
            batch_size: BATCH,
            batch_timeout: Duration::from_micros(500),
            tenants: vec![TenantClass::new("light"), TenantClass::new("heavy")],
            ..Default::default()
        },
    )
    .expect("tenant service starts");
    let light_gov = Governor::start_for_class(
        &svc_mt,
        0,
        ladder.clone(),
        QosConfig {
            // Same control law, untrippable target: the light class shares
            // the pool, so its *latency* does see the heavy backlog (queue
            // wait is FIFO-fair, not preemptive) — what must NOT move is
            // its rung, epoch and bits, which is exactly what per-class
            // governors guarantee and this section asserts.
            latency_target: Duration::from_secs(3600),
            step_up_frac: 0.5,
            error_ceiling: f64::INFINITY,
            max_est_loss: 0.2,
            min_dwell: Duration::from_millis(40),
            tick: Duration::from_millis(8),
            min_window: 8,
        },
    )
    .expect("light governor starts");
    let heavy_gov = Governor::start_for_class(
        &svc_mt,
        1,
        ladder.clone(),
        QosConfig {
            latency_target: Duration::from_millis(2),
            step_up_frac: 0.5,
            error_ceiling: f64::INFINITY,
            max_est_loss: 0.2,
            min_dwell: Duration::from_millis(40),
            tick: Duration::from_millis(8),
            min_window: 8,
        },
    )
    .expect("heavy governor starts");
    let flood_done = std::sync::atomic::AtomicBool::new(false);
    let (light_replies, light_rung_max, heavy_waves, heavy_stepped) = std::thread::scope(|s| {
        let svc = &svc_mt;
        let heavy = s.spawn(|| {
            let mut waves = 0usize;
            let mut wave = first_wave;
            while heavy_gov.rung() == 0 && waves < 24 {
                let pend: Vec<_> = (0..wave)
                    .map(|i| svc.submit_for(1, ds.image(i % ds.n)).expect("heavy accepted"))
                    .collect();
                for p in pend {
                    p.wait().expect("heavy reply");
                }
                waves += 1;
                wave = (wave * 2).min(16 * 1024);
            }
            // Sample before the flood stops: idle-recovery could lift the
            // rung back to 0 between here and the post-scope asserts.
            let stepped = heavy_gov.rung() > 0;
            flood_done.store(true, std::sync::atomic::Ordering::Release);
            (waves, stepped)
        });
        let light = s.spawn(|| {
            let mut replies = Vec::new();
            let mut rung_max = 0usize;
            let mut i = 0usize;
            while !flood_done.load(std::sync::atomic::Ordering::Acquire) || i < 8 {
                let img = i % ds.n;
                let r = svc
                    .submit_for(0, ds.image(img))
                    .expect("light accepted")
                    .wait()
                    .expect("light reply");
                assert_eq!(r.tenant, 0, "light reply routed to the wrong tenant");
                replies.push((img, r));
                rung_max = rung_max.max(light_gov.rung());
                i += 1;
                std::thread::sleep(Duration::from_millis(3));
            }
            (replies, rung_max)
        });
        let (heavy_waves, heavy_stepped) = heavy.join().expect("heavy producer");
        let (light_replies, light_rung_max) = light.join().expect("light producer");
        (light_replies, light_rung_max, heavy_waves, heavy_stepped)
    });
    assert!(
        heavy_stepped,
        "heavy flood never drove the heavy governor off rung 0 ({heavy_waves} waves)"
    );
    assert_eq!(light_rung_max, 0, "light governor moved under the heavy flood");
    let light_report = light_gov.stop();
    let heavy_report = heavy_gov.stop();
    assert!(
        light_report.transitions.is_empty(),
        "light class must log zero transitions, got {:?}",
        light_report.transitions.len()
    );
    assert!(
        heavy_report.transitions.iter().any(|t| t.reason == "latency-over-target"),
        "heavy class never stepped down under its own load"
    );
    // Light bit-identity + epoch stability: every light reply matches the
    // exact rung's static forward and carries the install epoch of rung 0.
    let light_epoch = light_replies.first().map(|(_, r)| r.epoch).unwrap_or(0);
    for (img, r) in &light_replies {
        assert_eq!(r.epoch, light_epoch, "light epoch moved during the flood");
        let want = reference
            .forward(
                &ds.image(*img),
                &ForwardOpts::with_policy(ladder.rung(0).policy.clone()),
            )
            .unwrap();
        assert_eq!(
            r.logits, want,
            "light reply (img {img}) not bit-identical to the exact rung"
        );
    }
    let snap_mt = svc_mt.shutdown();
    assert_eq!(snap_mt.classes.len(), 2);
    println!(
        "light: {} replies, rung stayed 0, p99 {:.2} ms; heavy: {} waves, \
         {} transitions, p99 {:.2} ms",
        light_replies.len(),
        snap_mt.classes[0].p99_latency.as_secs_f64() * 1e3,
        heavy_waves,
        heavy_report.transitions.len(),
        snap_mt.classes[1].p99_latency.as_secs_f64() * 1e3
    );

    let json = Json::obj()
        .field("bench", "qos_adaptive")
        .field("model", "hermnet_hsynth (hermetic)")
        .field("model_macs", model.macs() as i64)
        .field("workers", WORKERS)
        .field("batch_size", BATCH)
        .field("quick", quick)
        .field("cycles", cycles)
        .field("ladder_file", ladder_path)
        .field(
            "ladder",
            Json::Arr(
                ladder
                    .rungs()
                    .iter()
                    .map(|r| {
                        Json::obj()
                            .field("name", r.name.as_str())
                            .field("est_loss", r.est_loss)
                            .field("power_norm", r.power_norm)
                            .field("policy", r.policy.describe())
                    })
                    .collect(),
            ),
        )
        .field(
            "trace_waves",
            Json::Arr(
                trace
                    .iter()
                    .map(|c| Json::arr(c.iter().map(|&w| w as i64)))
                    .collect(),
            ),
        )
        .field(
            "transitions",
            Json::Arr(
                report
                    .transitions
                    .iter()
                    .map(|t| {
                        Json::obj()
                            .field("at_s", t.at.as_secs_f64())
                            .field("epoch", t.epoch as i64)
                            .field("from", t.from as i64)
                            .field("to", t.to as i64)
                            .field("p95_ms", t.p95.as_secs_f64() * 1e3)
                            .field("cv_proxy", t.cv_proxy)
                            .field("reason", t.reason)
                    })
                    .collect(),
            ),
        )
        .field("dwell_fractions", Json::arr(report.dwell_fractions()))
        .field(
            "replies_by_rung",
            Json::arr(by_rung.iter().map(|&n| n as i64)),
        )
        .field(
            "mixed_tenant",
            Json::obj()
                .field("heavy_waves", heavy_waves as i64)
                .field("heavy_transitions", heavy_report.transitions.len() as i64)
                .field(
                    "heavy_rung_trajectory",
                    Json::Arr(
                        heavy_report
                            .transitions
                            .iter()
                            .map(|t| {
                                Json::obj()
                                    .field("at_s", t.at.as_secs_f64())
                                    .field("from", t.from as i64)
                                    .field("to", t.to as i64)
                                    .field("reason", t.reason)
                            })
                            .collect(),
                    ),
                )
                .field("light_transitions", light_report.transitions.len() as i64)
                .field("light_rung_max", light_rung_max as i64)
                .field("light_replies", light_replies.len() as i64)
                .field(
                    "classes",
                    Json::Arr(
                        snap_mt
                            .classes
                            .iter()
                            .map(|c| {
                                Json::obj()
                                    .field("name", c.name.as_str())
                                    .field("completed", c.completed as i64)
                                    .field("p99_ms", c.p99_latency.as_secs_f64() * 1e3)
                                    .field("images_s", c.throughput_rps)
                            })
                            .collect(),
                    ),
                ),
        )
        .field("results", Json::Arr(rows.iter().map(|r| r.json()).collect()));
    let path = cvapprox::util::bench::artifact_path("BENCH_qos.json");
    match std::fs::write(&path, json.render()) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => println!("(could not write {}: {e})", path.display()),
    }
    println!("qos_adaptive OK");
}
