//! Chaos bench: the self-healing serving plane under deterministic fault
//! injection.
//!
//! Runs entirely on the checked-in hermetic artifacts (no `make artifacts`,
//! no network — CI always executes it) and pins the PR-6 robustness
//! contract with hard assertions, not just reporting:
//!
//! 1. **Chaos property** — a seeded [`cvapprox::fault::FaultPlan`] flips
//!    LUT/plan bits, panics workers, injects spikes and drops replies while
//!    an open-loop burst flows through the pool. Every request resolves to
//!    exactly one reply; every `Ok` is **bit-identical** to the fault-free
//!    static forward (zero silent corruption — the assertion this bench
//!    exists for); every `Err` is a typed `WorkerCrashed`/`Integrity`.
//! 2. **Time-to-heal** — targeted corruption of a prepared LUT stripe and a
//!    cached plan panel against a quiet pool; counts the requests until the
//!    heal counter moves and bounds it (≤ [`HEAL_BUDGET`] batches).
//! 3. **Admission smoke** — bounded-queue overload rejection, deadline
//!    expiry at dequeue, and `infer_with_retry` surviving a panic schedule.
//!
//! Emits `BENCH_fault.json`: availability, error counts by kind,
//! injected/healed/replayed/restart counters, per-cache time-to-heal and
//! the `silent_corruptions == 0` field CI checks.
//!
//! Env knobs: `CVAPPROX_FAULT_SEED` (schedule seed, default 1002 — CI runs
//! two fixed seeds), `CVAPPROX_BENCH_QUICK=1` (smaller burst),
//! `CVAPPROX_THREADS` pinned to 1 unless set.

use std::collections::HashMap;
use std::time::Duration;

use cvapprox::approx::Family;
use cvapprox::coordinator::{InferenceService, MetricsSnapshot, ReplyError, ServiceConfig};
use cvapprox::datasets::Dataset;
use cvapprox::fault::FaultConfig;
use cvapprox::hermetic_dir;
use cvapprox::nn::{loader, Engine, ForwardOpts, Model};
use cvapprox::util::json::Json;

const N_ARRAY: u32 = 64;
const WORKERS: usize = 2;
const BATCH: usize = 4;
const FAMILY: Family = Family::Perforated;
const M: u32 = 2;
/// Max batches the targeted-corruption probe may take to observe a heal.
const HEAL_BUDGET: usize = 80;

fn load_hermetic() -> (Model, Dataset) {
    let root = hermetic_dir();
    let model = loader::load_model(&root.join("models/hermnet_hsynth.cvm"))
        .expect("hermetic model (regenerate with scripts/gen_hermetic_golden.py)");
    let ds = Dataset::load(&root.join("data/hsynth_test.cvd")).expect("hermetic dataset");
    (model, ds)
}

/// Start a pool at the uniform (FAMILY, M, cv) point with LUTs prepared —
/// so LUT corruption always has a target — and the given fault plan.
fn service(model: &Model, faults: FaultConfig, queue_cap: usize) -> InferenceService {
    let mut engine = Engine::new(model.clone());
    engine.prepare_lut(FAMILY, M);
    InferenceService::start(
        engine,
        ServiceConfig {
            family: FAMILY,
            m: M,
            use_cv: true,
            n_array: N_ARRAY,
            workers: WORKERS,
            batch_size: BATCH,
            batch_timeout: Duration::from_micros(500),
            queue_cap,
            faults: Some(faults),
            ..Default::default()
        },
    )
    .expect("service starts")
}

/// Silence the backtrace spam from *injected* worker panics (they are the
/// point of this bench); every other panic still reports normally.
fn quiet_injected_panics() {
    let default_hook = std::panic::take_hook();
    std::panic::set_hook(Box::new(move |info| {
        let on_worker = std::thread::current()
            .name()
            .is_some_and(|n| n.starts_with("cvapprox-worker-"));
        let injected = info
            .payload()
            .downcast_ref::<&str>()
            .is_some_and(|s| s.contains("injected worker panic"));
        if !(on_worker && injected) {
            default_hook(info);
        }
    }));
}

/// Fault-free reference logits, memoized per dataset index.
struct Reference {
    engine: Engine,
    opts: ForwardOpts,
    cache: HashMap<usize, Vec<f64>>,
}

impl Reference {
    fn new(model: &Model) -> Reference {
        Reference {
            engine: Engine::new(model.clone()),
            opts: ForwardOpts::approx(FAMILY, M, true),
            cache: HashMap::new(),
        }
    }

    fn logits(&mut self, ds: &Dataset, idx: usize) -> &Vec<f64> {
        let (engine, opts) = (&self.engine, &self.opts);
        self.cache
            .entry(idx)
            .or_insert_with(|| engine.forward(&ds.image(idx), opts).unwrap())
    }
}

struct ChaosOutcome {
    total: u64,
    ok: u64,
    worker_crashed: u64,
    integrity: u64,
    availability: f64,
    snap: MetricsSnapshot,
}

/// Phase 1: the chaos property — exactly one reply per request, zero
/// silent corruption, only typed errors.
fn chaos_property(model: &Model, ds: &Dataset, seed: u64, n: usize) -> ChaosOutcome {
    let faults = FaultConfig {
        seed,
        lut_flip_per_mille: 40,
        plan_flip_per_mille: 25,
        panic_per_mille: 40,
        spike_per_mille: 25,
        spike: Duration::from_millis(1),
        drop_per_mille: 20,
    };
    let svc = service(model, faults, 0);
    let mut reference = Reference::new(model);
    let pendings: Vec<_> = (0..n)
        .map(|i| svc.submit(ds.image(i % ds.n)).expect("open admission under chaos"))
        .collect();
    let (mut ok, mut worker_crashed, mut integrity) = (0u64, 0u64, 0u64);
    for (i, p) in pendings.into_iter().enumerate() {
        match p.wait_reply() {
            Ok(reply) => {
                assert_eq!(
                    &reply.logits,
                    reference.logits(ds, i % ds.n),
                    "SILENT CORRUPTION: Ok reply for img {i} diverged from the \
                     fault-free reference"
                );
                ok += 1;
            }
            Err(ReplyError::WorkerCrashed) => worker_crashed += 1,
            Err(ReplyError::Integrity) => integrity += 1,
            Err(e) => panic!("untyped/unexpected error under chaos: {e}"),
        }
    }
    let total = n as u64;
    assert_eq!(ok + worker_crashed + integrity, total, "exactly one reply per request");
    let availability = ok as f64 / total as f64;
    assert!(availability >= 0.80, "availability collapsed under chaos: {ok}/{total}");
    let snap = svc.shutdown();
    assert!(snap.injected_faults > 0, "the fault schedule never fired");
    ChaosOutcome { total, ok, worker_crashed, integrity, availability, snap }
}

enum Target {
    Lut,
    Plan,
}

/// Phase 2: targeted corruption against a quiet pool; returns the number of
/// serial requests until the heal counter moved. Every reply along the way
/// must stay bit-identical (detection happens before the answer).
fn time_to_heal(model: &Model, ds: &Dataset, seed: u64, target: Target) -> usize {
    let svc = service(model, FaultConfig::quiet(seed), 0);
    let mut reference = Reference::new(model);
    // Warm one request so the serving path (plans, scratch) is steady.
    let r = svc.infer(ds.image(0)).expect("warm request");
    assert_eq!(&r.logits, reference.logits(ds, 0));
    let hit = match target {
        Target::Lut => svc.engine().corrupt_lut(seed, 100, 256, 24).map(|_| ()),
        Target::Plan => svc.engine().corrupt_plan(seed, 11, 3).map(|_| ()),
    };
    assert!(hit.is_some(), "corruption target must exist (LUTs prepared, plans warmed)");
    assert!(!svc.engine().verify_integrity().is_clean(), "corruption must be visible");
    let mut served = 0usize;
    while svc.snapshot().heal_events == 0 {
        assert!(
            served < HEAL_BUDGET,
            "no heal within {HEAL_BUDGET} batches of targeted corruption"
        );
        let idx = served % ds.n;
        let reply = svc.infer(ds.image(idx)).expect("quiet pool keeps serving");
        assert_eq!(
            &reply.logits,
            reference.logits(ds, idx),
            "reply served off corrupted state (request {served})"
        );
        served += 1;
    }
    assert!(svc.engine().verify_integrity().is_clean(), "healing must restore checksums");
    let snap = svc.shutdown();
    assert!(snap.heal_events >= 1);
    assert!(snap.replayed_batches >= 1, "the corrupted batch was never replayed");
    served
}

struct SmokeOutcome {
    overload_submitted: u64,
    overload_rejected: u64,
    deadline_expired: u64,
    retry_served: u64,
}

/// Phase 3: admission-control and client-robustness smoke.
fn admission_smoke(model: &Model, ds: &Dataset, seed: u64) -> SmokeOutcome {
    // Bounded queue + one slow worker (every batch spikes): a burst must
    // split into accepted-and-served vs typed Overloaded.
    let slow = FaultConfig {
        spike_per_mille: 1000,
        spike: Duration::from_millis(10),
        ..FaultConfig::quiet(seed)
    };
    let svc = InferenceService::start(
        Engine::new(model.clone()),
        ServiceConfig {
            family: FAMILY,
            m: M,
            use_cv: true,
            n_array: N_ARRAY,
            workers: 1,
            batch_size: 1,
            batch_timeout: Duration::from_micros(200),
            queue_cap: 2,
            faults: Some(slow),
            ..Default::default()
        },
    )
    .expect("service starts");
    let submitted = 16u64;
    let mut accepted = Vec::new();
    let mut rejected = 0u64;
    for i in 0..submitted {
        match svc.try_submit(ds.image(i as usize % ds.n), None) {
            Ok(p) => accepted.push(p),
            Err(ReplyError::Overloaded) => rejected += 1,
            Err(e) => panic!("unexpected admission error: {e}"),
        }
    }
    assert!(rejected > 0, "queue_cap=2 must shed part of an instant 16-burst");
    for p in accepted {
        p.wait_reply().expect("accepted requests must serve");
    }
    // Deadline expiry: enqueue behind a 10 ms batch with a 2 ms budget.
    let pa = svc.submit(ds.image(0)).expect("reopenable");
    std::thread::sleep(Duration::from_millis(3));
    let pb = svc
        .submit_with_deadline(ds.image(1), Duration::from_millis(2))
        .expect("admission is open");
    pa.wait_reply().expect("undeadlined request serves");
    assert_eq!(pb.wait_reply().unwrap_err(), ReplyError::Deadline);
    let snap = svc.shutdown();
    assert_eq!(snap.rejected_overload, rejected);
    assert!(snap.expired_deadline >= 1);

    // Client retry rides out a panic schedule.
    let crashy = FaultConfig { panic_per_mille: 300, ..FaultConfig::quiet(seed ^ 0xABCD) };
    let svc = service(model, crashy, 0);
    let mut reference = Reference::new(model);
    let retry_served = 12u64;
    for i in 0..retry_served {
        let idx = i as usize % ds.n;
        let reply = svc
            .infer_with_retry(&ds.image(idx), 20, Duration::from_micros(200))
            .expect("retry must eventually land on a surviving worker");
        assert_eq!(&reply.logits, reference.logits(ds, idx));
    }
    let crashy_snap = svc.shutdown();
    assert_eq!(crashy_snap.completed, retry_served);
    SmokeOutcome {
        overload_submitted: submitted,
        overload_rejected: rejected,
        deadline_expired: snap.expired_deadline,
        retry_served,
    }
}

fn main() {
    if std::env::var("CVAPPROX_THREADS").is_err() {
        std::env::set_var("CVAPPROX_THREADS", "1");
    }
    quiet_injected_panics();
    let quick = std::env::var("CVAPPROX_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let seed = std::env::var("CVAPPROX_FAULT_SEED")
        .ok()
        .and_then(|v| v.trim().parse::<u64>().ok())
        .unwrap_or(1002);
    println!("== bench: chaos (hermetic, seed {seed}) ==");
    let (model, ds) = load_hermetic();
    let n = if quick { 240 } else { 720 };

    // ---- phase 1: chaos property ----------------------------------------
    let chaos = chaos_property(&model, &ds, seed, n);
    println!(
        "chaos: {}/{} ok ({:.1}% available), {} worker-crashed, {} integrity; \
         {} faults injected, {} restarts, {} heals, {} alarms, {} replays",
        chaos.ok,
        chaos.total,
        100.0 * chaos.availability,
        chaos.worker_crashed,
        chaos.integrity,
        chaos.snap.injected_faults,
        chaos.snap.worker_restarts,
        chaos.snap.heal_events,
        chaos.snap.integrity_alarms,
        chaos.snap.replayed_batches,
    );

    // ---- phase 2: time-to-heal -------------------------------------------
    let heal_lut = time_to_heal(&model, &ds, seed, Target::Lut);
    let heal_plan = time_to_heal(&model, &ds, seed, Target::Plan);
    println!("time-to-heal: LUT stripe in {heal_lut} batch(es), plan panel in {heal_plan}");

    // ---- phase 3: admission smoke ----------------------------------------
    let smoke = admission_smoke(&model, &ds, seed);
    println!(
        "admission: {}/{} shed as Overloaded, {} deadline-expired, {} served via retry",
        smoke.overload_rejected,
        smoke.overload_submitted,
        smoke.deadline_expired,
        smoke.retry_served,
    );

    // ---- report ----------------------------------------------------------
    let s = &chaos.snap;
    let json = Json::obj()
        .field("bench", "chaos")
        .field("model", "hermnet_hsynth (hermetic)")
        .field("seed", seed as i64)
        .field("quick", quick)
        .field("workers", WORKERS)
        .field("batch_size", BATCH)
        .field("requests", chaos.total as i64)
        .field("ok", chaos.ok as i64)
        .field("worker_crashed", chaos.worker_crashed as i64)
        .field("integrity_refused", chaos.integrity as i64)
        .field("availability", chaos.availability)
        // Every Ok reply was bit-compared against the fault-free reference
        // above; reaching this line means none diverged.
        .field("silent_corruptions", 0i64)
        .field("injected_faults", s.injected_faults as i64)
        .field("worker_restarts", s.worker_restarts as i64)
        .field("heal_events", s.heal_events as i64)
        .field("integrity_alarms", s.integrity_alarms as i64)
        .field("replayed_batches", s.replayed_batches as i64)
        .field("crashed_replies", s.crashed_replies as i64)
        .field("chaos_images_s", s.throughput_rps)
        .field("chaos_p95_ms", s.p95_latency.as_secs_f64() * 1e3)
        .field(
            "time_to_heal_batches",
            Json::obj().field("lut", heal_lut).field("plan", heal_plan),
        )
        .field(
            "admission",
            Json::obj()
                .field("submitted", smoke.overload_submitted as i64)
                .field("rejected_overload", smoke.overload_rejected as i64)
                .field("deadline_expired", smoke.deadline_expired as i64)
                .field("retry_served", smoke.retry_served as i64),
        );
    let path = cvapprox::util::bench::artifact_path("BENCH_fault.json");
    match std::fs::write(&path, json.render()) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => println!("(could not write {}: {e})", path.display()),
    }
    println!("chaos OK");
}
