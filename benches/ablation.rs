//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. **CV fixed-point width** — C is carried in Q.4; how much accuracy do
//!    Q.0 / Q.1 / Q.8 give up or gain? (hardware cost of fractional bits is
//!    one extra adder column each)
//! 2. **C0 bias folding** (truncated family) — the paper folds C0 into the
//!    bias; what happens without it (C0 = 0)?
//! 3. **C optimality** — replace C = E[W] with ±25% perturbations (eq. 21
//!    says E[W] is the variance minimizer).

use cvapprox::approx::{am, xvar, Family};
use cvapprox::util::rng::Rng;
use cvapprox::util::stats::Welford;

/// Convolution-error variance with C carried in `frac_bits` fixed point.
fn conv_err_stats(
    family: Family,
    m: u32,
    frac_bits: u32,
    c_scale: f64,
    use_c0: bool,
    trials: usize,
) -> (f64, f64) {
    let mut rng = Rng::new(0xAB1A);
    let k = 64usize;
    let w: Vec<u8> = (0..k).map(|_| rng.u8_normal(128.0, 24.0)).collect();
    let q = 1i64 << frac_bits;
    // C per eq. 21/26/32 (scaled by c_scale for the optimality ablation).
    let num: i64 = match family {
        Family::Perforated => w.iter().map(|&x| x as i64).sum(),
        Family::Recursive => w.iter().map(|&x| (x as i64) & ((1 << m) - 1)).sum(),
        Family::Truncated => {
            w.iter().map(|&x| cvapprox::approx::w_hat_q1(x, m) as i64).sum()
        }
        Family::Exact => 0,
    };
    let den = k as i64 * if family == Family::Truncated { 2 } else { 1 };
    let c_q = ((num as f64 * c_scale * q as f64 / den as f64) + 0.5).floor() as i64;
    let c0_q = if use_c0 && family == Family::Truncated {
        ((num * q) as f64 / (1i64 << (m + 1)) as f64 + 0.5).floor() as i64
    } else {
        0
    };
    let mut acc = Welford::new();
    for _ in 0..trials {
        let a: Vec<u8> = (0..k).map(|_| rng.u8()).collect();
        let exact: i64 = w.iter().zip(&a).map(|(&w, &a)| (w as i64) * (a as i64)).sum();
        let approx: i64 = w.iter().zip(&a).map(|(&w, &a)| am(family, w, a, m) as i64).sum();
        let sx: i64 = a.iter().map(|&x| xvar(family, x, m) as i64).sum();
        let v = (c_q * sx + c0_q + q / 2) >> frac_bits;
        acc.push((exact - (approx + v)) as f64);
    }
    (acc.mean(), acc.std())
}

fn main() {
    println!("== bench: ablation ==");
    println!("\n[1] CV fixed-point width (perforated m=3, conv error vs exact):");
    println!("    frac_bits   mean      sigma");
    for frac in [0u32, 1, 4, 8] {
        let (mu, sd) = conv_err_stats(Family::Perforated, 3, frac, 1.0, true, 4000);
        println!("    Q.{frac:<9} {mu:>8.2} {sd:>9.2}");
    }
    println!("    -> Q.4 (the shipped choice) is within noise of Q.8; Q.0 biases the mean.");

    println!("\n[2] C0 bias folding (truncated m=7):");
    for (label, use_c0) in [("with C0 (ours)", true), ("without C0", false)] {
        let (mu, sd) = conv_err_stats(Family::Truncated, 7, 4, 1.0, use_c0, 4000);
        println!("    {label:<16} mean {mu:>8.2}  sigma {sd:>8.2}");
    }
    println!("    -> dropping C0 leaves the residual mean error of eq. 28.");

    println!("\n[3] C optimality around E[W] (perforated m=2, eq. 21):");
    println!("    c_scale   sigma(conv err)");
    for scale in [0.5, 0.75, 1.0, 1.25, 1.5] {
        let (_, sd) = conv_err_stats(Family::Perforated, 2, 4, scale, true, 4000);
        println!("    {scale:<8} {sd:>10.2}");
    }
    println!("    -> variance is minimized at scale 1.0 (C = E[W]), as eq. 21 proves.");
}
