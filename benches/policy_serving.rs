//! Policy-serving bench: per-layer heterogeneous approximation end to end.
//!
//! Runs entirely on the checked-in hermetic artifacts (no `make artifacts`,
//! no network — CI always executes it): the greedy layerwise search from
//! `report::layerwise` derives a mixed [`LayerPolicy`] on the hermetic
//! model, the coordinator worker pool serves it (`ServiceConfig::policy`),
//! and the result is compared against every uniform (family, m) grid point
//! on three axes: synthetic accuracy loss, MAC-weighted estimated power,
//! and measured images/s.
//!
//! Emits `BENCH_policy.json`. The headline acceptance claim is asserted,
//! not just reported: the mixed policy must beat **every** uniform point
//! that achieves equal-or-lower accuracy loss on estimated power (on the
//! hermetic set the greedy policy reaches zero loss while every uniform
//! approximate point loses accuracy, so it strictly dominates the grid).
//! The pool's replies are also checked bit-identical to the per-image
//! policy forward — the coordinator-level forward/forward_batch identity.
//!
//! Env knobs: `CVAPPROX_BENCH_QUICK=1` (short serving budgets);
//! `CVAPPROX_THREADS` pinned to 1 unless set (measure pool scaling, not
//! intra-GEMM threading).

use std::sync::Arc;
use std::time::Duration;

use cvapprox::approx::Family;
use cvapprox::coordinator::{InferenceService, PowerModel, ServiceConfig};
use cvapprox::datasets::Dataset;
use cvapprox::hermetic_dir;
use cvapprox::nn::{loader, Engine, ForwardOpts, LayerPolicy, Model, Tensor};
use cvapprox::report::accuracy::evaluate;
use cvapprox::report::layerwise::{greedy_policy, sensitivity};
use cvapprox::util::json::Json;

const N_ARRAY: u32 = 64;

fn load_hermetic() -> (Model, Dataset) {
    let root = hermetic_dir();
    let model = loader::load_model(&root.join("models/hermnet_hsynth.cvm"))
        .expect("hermetic model (regenerate with scripts/gen_hermetic_golden.py)");
    let ds = Dataset::load(&root.join("data/hsynth_test.cvd")).expect("hermetic dataset");
    (model, ds)
}

struct Measured {
    label: String,
    acc: f64,
    power_norm: f64,
    images_s: f64,
    mean_ms: f64,
    p95_ms: f64,
    json: Json,
}

/// Serve `n_req` requests through a fresh pool and measure throughput.
fn serve(model: &Model, ds: &Dataset, cfg: ServiceConfig, n_req: usize) -> (f64, f64, f64) {
    let svc = InferenceService::start(Engine::new(model.clone()), cfg)
        .expect("service starts");
    let pending: Vec<_> = (0..n_req)
        .map(|i| svc.submit(ds.image(i % ds.n)).expect("service accepting"))
        .collect();
    for p in pending {
        p.wait().expect("reply");
    }
    let snap = svc.shutdown();
    (
        snap.throughput_rps,
        snap.mean_latency.as_secs_f64() * 1e3,
        snap.p95_latency.as_secs_f64() * 1e3,
    )
}

fn main() {
    if std::env::var("CVAPPROX_THREADS").is_err() {
        std::env::set_var("CVAPPROX_THREADS", "1");
    }
    println!("== bench: policy_serving (hermetic) ==");
    let quick = std::env::var("CVAPPROX_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let (model, ds) = load_hermetic();
    let n_eval = ds.n; // 64 hermetic images, deterministic accuracies
    let n_req = if quick { 96 } else { 384 };
    let workers = 2usize;
    let batch_size = 8usize;
    println!(
        "(hermetic model {} MACs/img, {} eval images, {} requests/config, \
         {workers} workers x batch {batch_size})",
        model.macs(),
        n_eval,
        n_req
    );

    let engine = Engine::new(model.clone());
    let exact_acc = evaluate(&engine, &ds, &ForwardOpts::exact(), n_eval, 1).unwrap();
    println!("exact accuracy {exact_acc:.4} (labels are the exact argmax)");

    let mut rows: Vec<Measured> = Vec::new();

    // ---- uniform grid: every paper (family, m) point, with V ------------
    let mut grid: Vec<(Family, u32)> = vec![(Family::Exact, 0)];
    for family in Family::APPROX {
        for &m in family.paper_levels() {
            grid.push((family, m));
        }
    }
    for &(family, m) in &grid {
        let use_cv = family != Family::Exact;
        let acc =
            evaluate(&engine, &ds, &ForwardOpts::approx(family, m, use_cv), n_eval, 1)
                .unwrap();
        let power = PowerModel::new(family, m, N_ARRAY).power_norm;
        let cfg = ServiceConfig {
            family,
            m,
            use_cv,
            n_array: N_ARRAY,
            workers,
            batch_size,
            batch_timeout: Duration::from_millis(1),
            ..Default::default()
        };
        let (rps, mean_ms, p95_ms) = serve(&model, &ds, cfg, n_req);
        let label = if family == Family::Exact {
            "uniform exact".to_string()
        } else {
            format!("uniform {} m={m}", family.name())
        };
        rows.push(Measured {
            label: label.clone(),
            acc,
            power_norm: power,
            images_s: rps,
            mean_ms,
            p95_ms,
            json: Json::obj()
                .field("kind", "uniform")
                .field("family", family.name())
                .field("m", m as i64)
                .field("use_cv", use_cv)
                .field("acc", acc)
                .field("acc_loss_pct", 100.0 * (exact_acc - acc))
                .field("power_norm", power)
                .field("images_s", rps)
                .field("mean_ms", mean_ms)
                .field("p95_ms", p95_ms),
        });
    }

    // ---- greedy mixed policy (the layerwise search artifact) ------------
    let (fam_hi, m_hi, budget_pct) = (Family::Perforated, 3u32, 0.8f64);
    let sens = sensitivity(&engine, &ds, fam_hi, m_hi, n_eval).unwrap();
    let pol = greedy_policy(
        &engine, &ds, fam_hi, m_hi, budget_pct, n_eval, N_ARRAY, &sens,
    )
    .unwrap();
    let policy = Arc::new(pol.layer_policy().unwrap());
    assert!(
        policy.approx_layers() > 0 && policy.approx_layers() < policy.len(),
        "greedy result must be genuinely mixed, got {}",
        policy.describe()
    );
    // Round-trip through the serialized artifact, like a deployment would.
    let policy_path =
        cvapprox::util::bench::artifact_path("POLICY_hermnet_hsynth.json");
    policy.save_json(&policy_path).unwrap();
    let policy = Arc::new(LayerPolicy::load(&policy_path).unwrap());
    println!(
        "greedy {} m_hi={m_hi} budget={budget_pct}%: {} (acc {:.4}) -> {}",
        fam_hi.name(),
        policy.describe(),
        pol.acc,
        policy_path.display()
    );

    let policy_opts = ForwardOpts::with_policy(policy.clone());
    let mixed_acc = evaluate(&engine, &ds, &policy_opts, n_eval, 1).unwrap();
    let mixed_power = PowerModel::for_policy(&policy, &model, N_ARRAY).power_norm;

    // Coordinator-level bit-identity: pool replies (batched forwards) must
    // equal the per-image policy forward.
    let svc = InferenceService::start(
        Engine::new(model.clone()),
        ServiceConfig {
            policy: Some(policy.clone()),
            n_array: N_ARRAY,
            workers,
            batch_size,
            batch_timeout: Duration::from_millis(10),
            ..Default::default()
        },
    )
    .expect("policy service starts");
    let imgs: Vec<Tensor> = (0..16).map(|i| ds.image(i)).collect();
    let pending: Vec<_> =
        imgs.iter().map(|im| svc.submit(im.clone()).unwrap()).collect();
    for (img, p) in imgs.iter().zip(pending) {
        let reply = p.wait().unwrap();
        let want = engine.forward(img, &policy_opts).unwrap();
        assert_eq!(
            reply.logits, want,
            "pool reply must be bit-identical to the per-image policy forward"
        );
    }
    svc.shutdown();
    // Engine-level check on the same policy: forward == forward_batch.
    let refs: Vec<&Tensor> = imgs.iter().collect();
    let batched = engine.forward_batch(&refs, &policy_opts).unwrap();
    for (img, got) in imgs.iter().zip(&batched) {
        assert_eq!(*got, engine.forward(img, &policy_opts).unwrap());
    }
    println!("bit-identity: pool replies == forward == forward_batch (16 images)");

    let cfg = ServiceConfig {
        policy: Some(policy.clone()),
        n_array: N_ARRAY,
        workers,
        batch_size,
        batch_timeout: Duration::from_millis(1),
        ..Default::default()
    };
    let (rps, mean_ms, p95_ms) = serve(&model, &ds, cfg, n_req);
    rows.push(Measured {
        label: format!("policy {}", policy.describe()),
        acc: mixed_acc,
        power_norm: mixed_power,
        images_s: rps,
        mean_ms,
        p95_ms,
        json: Json::obj()
            .field("kind", "policy")
            .field("policy", policy.describe())
            .field("layers", policy.to_json())
            .field("acc", mixed_acc)
            .field("acc_loss_pct", 100.0 * (exact_acc - mixed_acc))
            .field("power_norm", mixed_power)
            .field("images_s", rps)
            .field("mean_ms", mean_ms)
            .field("p95_ms", p95_ms),
    });

    // ---- report + the dominance claim -----------------------------------
    println!(
        "\n{:<34} {:>8} {:>8} {:>9} {:>9} {:>9}",
        "config", "acc", "power", "img/s", "mean ms", "~p95 ms"
    );
    for r in &rows {
        println!(
            "{:<34} {:>8.4} {:>8.3} {:>9.1} {:>9.2} {:>9.2}",
            r.label, r.acc, r.power_norm, r.images_s, r.mean_ms, r.p95_ms
        );
    }
    let mixed_loss = exact_acc - mixed_acc;
    let mut dominates = true;
    for r in rows.iter().filter(|r| r.label.starts_with("uniform")) {
        let loss = exact_acc - r.acc;
        if loss <= mixed_loss + 1e-9 && r.power_norm <= mixed_power {
            println!(
                "NOT dominated: {} (loss {:.4} <= {:.4}, power {:.3} <= {:.3})",
                r.label, loss, mixed_loss, r.power_norm, mixed_power
            );
            dominates = false;
        }
    }
    println!(
        "\nmixed policy loss {:.4}, power {:.3}x -> {}",
        mixed_loss,
        mixed_power,
        if dominates {
            "beats every uniform point at equal-or-lower loss"
        } else {
            "does NOT dominate the uniform grid"
        }
    );

    let json = Json::obj()
        .field("bench", "policy_serving")
        .field("model", "hermnet_hsynth (hermetic)")
        .field("model_macs", model.macs() as i64)
        .field("eval_images", n_eval)
        .field("requests_per_config", n_req)
        .field("workers", workers)
        .field("batch_size", batch_size)
        .field("quick", quick)
        .field("exact_acc", exact_acc)
        .field("greedy", Json::obj()
            .field("family", fam_hi.name())
            .field("m_hi", m_hi as i64)
            .field("budget_pct", budget_pct)
            .field("policy_file", policy_path))
        .field("mixed_dominates_uniform", dominates)
        .field("results", Json::Arr(rows.into_iter().map(|r| r.json).collect()));
    let path = cvapprox::util::bench::artifact_path("BENCH_policy.json");
    match std::fs::write(&path, json.render()) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => println!("(could not write {}: {e})", path.display()),
    }
    // The acceptance gate: on the hermetic set the greedy mixed policy must
    // strictly dominate (deterministic data + deterministic arithmetic, so
    // this cannot flake).
    assert!(dominates, "mixed policy failed to dominate the uniform grid");
}
