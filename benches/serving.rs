//! Serving-path bench: end-to-end images/s and latency through the
//! multi-worker batching coordinator, swept across
//! `{workers} × {batch_size} × {family}` — the measurement the ROADMAP's
//! production-serving trajectory drives on.
//!
//! Uses a synthetic conv net (no artifacts needed, so CI always runs it)
//! and emits `BENCH_serving.json` next to the stdout report: one record per
//! configuration with images/s, mean/~p95 latency, batch statistics,
//! per-worker occupancy and per-tenant-class rows (name, completed, p99,
//! throughput). Acceptance signal across PRs: at a fixed batch size,
//! `images_s` should increase with `workers`.
//!
//! Two PR 9 sections ride along:
//!
//! * **queue scaling** — the same load pushed by 4 concurrent producer
//!   threads through shards ∈ {1, 4} at 4 workers. shards=1 is the legacy
//!   single-mutex queue; the sharded work-stealing layout must not lose
//!   throughput to it (asserted with a 15% noise floor).
//! * **mixed tenants** — a heavy flood and a light trickle on separate
//!   tenant classes over one pool, recording per-class p99 so the
//!   class-isolation claim has a serving-plane row (the governed rung
//!   isolation itself is asserted by `qos_adaptive`).
//!
//! Env knobs: `CVAPPROX_BENCH_QUICK=1` (short CI budgets);
//! `CVAPPROX_THREADS` is pinned to 1 (unless already set) so the sweep
//! measures worker-level scaling, not intra-GEMM threading.

use std::time::Duration;

use cvapprox::approx::Family;
use cvapprox::coordinator::{InferenceService, MetricsSnapshot, ServiceConfig, TenantClass};
use cvapprox::nn::graph::Weights;
use cvapprox::nn::{Engine, Model, Node, Op, Tensor};
use cvapprox::util::json::Json;
use cvapprox::util::rng::Rng;

/// Per-tenant-class rows for the JSON artifact (one even for the default
/// single-class configs, so downstream tooling can always key on it).
fn class_rows(snap: &MetricsSnapshot) -> Json {
    Json::Arr(
        snap.classes
            .iter()
            .map(|c| {
                Json::obj()
                    .field("name", c.name.as_str())
                    .field("completed", c.completed as i64)
                    .field("p50_ms", c.p50_latency.as_secs_f64() * 1e3)
                    .field("p99_ms", c.p99_latency.as_secs_f64() * 1e3)
                    .field("images_s", c.throughput_rps)
                    .field("rejected_overload", c.rejected_overload as i64)
                    .field("expired_deadline", c.expired_deadline as i64)
            })
            .collect(),
    )
}

/// Synthetic serving model (~2.2 MMAC/img): 16x16x3 input → conv3x3(24)
/// → maxpool → conv3x3(48) → conv3x3(48) → gap → dense(10). Shapes are
/// sized so a per-image GEMM is narrow (n = 64..256 columns) and batching
/// visibly widens it; quantization scales only need to keep values finite.
fn bench_model() -> Model {
    let mut rng = Rng::new(0x5E12);
    let conv = |input: usize,
                in_c: usize,
                out: (usize, usize, usize),
                rng: &mut Rng| {
        let kdim = 3 * 3 * in_c;
        Node {
            op: Op::Conv,
            relu: true,
            inputs: vec![input],
            out_shape: out,
            out_scale: 4096.0,
            cout: out.2,
            ksize: 3,
            pad: 1,
            weights: Some(Weights {
                w_q: (0..out.2 * kdim).map(|_| rng.u8()).collect(),
                k_dim: kdim,
                b_q: vec![0; out.2],
                s_w: 1.0,
                zp_w: 7,
            }),
            ..Node::default()
        }
    };
    let input = Node { out_shape: (16, 16, 3), ..Node::default() };
    let c1 = conv(0, 3, (16, 16, 24), &mut rng);
    let pool = Node {
        op: Op::Maxpool,
        inputs: vec![1],
        out_shape: (8, 8, 24),
        out_scale: 4096.0,
        ..Node::default()
    };
    let c2 = conv(2, 24, (8, 8, 48), &mut rng);
    let c3 = conv(3, 48, (8, 8, 48), &mut rng);
    let gap = Node {
        op: Op::Gap,
        inputs: vec![4],
        out_shape: (1, 1, 48),
        out_scale: 4096.0,
        ..Node::default()
    };
    let dense = Node {
        op: Op::Dense,
        inputs: vec![5],
        out_shape: (1, 1, 10),
        out_scale: 7.0e7,
        out_zp: 128,
        cout: 10,
        weights: Some(Weights {
            w_q: (0..10 * 48).map(|_| rng.u8()).collect(),
            k_dim: 48,
            b_q: vec![0; 10],
            s_w: 1.0,
            zp_w: 3,
        }),
        ..Node::default()
    };
    Model {
        name: "serving-synth".into(),
        n_classes: 10,
        nodes: vec![input, c1, pool, c2, c3, gap, dense],
    }
}

fn main() {
    // Pin intra-GEMM threading to 1 (unless explicitly overridden) so the
    // workers axis measures pool scaling, not nested parallelism. Must run
    // before the first configured_workers() call caches the value.
    if std::env::var("CVAPPROX_THREADS").is_err() {
        std::env::set_var("CVAPPROX_THREADS", "1");
    }
    println!("== bench: serving ==");
    let quick = std::env::var("CVAPPROX_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let gemm_threads = cvapprox::util::threadpool::configured_workers();
    let n_images = if quick { 64 } else { 256 };
    let macs = bench_model().macs();
    println!(
        "(synthetic model, {:.2} MMAC/img, {n_images} requests per config, \
         CVAPPROX_THREADS={gemm_threads})",
        macs as f64 / 1e6
    );

    let mut rng = Rng::new(0x1A6E);
    let imgs: Vec<Tensor> = (0..n_images)
        .map(|_| {
            Tensor::from_data(16, 16, 3, (0..16 * 16 * 3).map(|_| rng.u8()).collect())
        })
        .collect();

    let families: &[(Family, u32, bool)] = &[
        (Family::Exact, 0, false),
        (Family::Perforated, 2, true),
        (Family::Truncated, 6, true),
    ];
    let workers_list: &[usize] = &[1, 2, 4];
    let batch_list: &[usize] = &[1, 8];

    println!(
        "{:<14} {:>7} {:>6} {:>10} {:>10} {:>10} {:>9} {:>9}",
        "family", "workers", "batch", "img/s", "mean ms", "~p95 ms", "batches", "avg b"
    );
    let mut records: Vec<Json> = Vec::new();
    for &(family, m, use_cv) in families {
        for &workers in workers_list {
            for &batch_size in batch_list {
                let cfg = ServiceConfig {
                    family,
                    m,
                    use_cv,
                    n_array: 64,
                    workers,
                    batch_size,
                    batch_timeout: Duration::from_millis(1),
                    ..Default::default()
                };
                let svc = InferenceService::start(Engine::new(bench_model()), cfg)
                    .expect("service starts");
                let shards = svc.n_shards();
                let pending: Vec<_> = imgs
                    .iter()
                    .map(|im| svc.submit(im.clone()).expect("service accepting"))
                    .collect();
                for p in pending {
                    p.wait().expect("reply");
                }
                let snap = svc.shutdown();
                println!(
                    "{:<14} {:>7} {:>6} {:>10.1} {:>10.2} {:>10.2} {:>9} {:>9.1}",
                    family.name(),
                    workers,
                    batch_size,
                    snap.throughput_rps,
                    snap.mean_latency.as_secs_f64() * 1e3,
                    snap.p95_latency.as_secs_f64() * 1e3,
                    snap.batches,
                    snap.mean_batch_size
                );
                records.push(
                    Json::obj()
                        .field("section", "sweep")
                        .field("family", family.name())
                        .field("m", m as i64)
                        .field("use_cv", use_cv)
                        .field("workers", workers)
                        .field("shards", shards)
                        .field("batch_size", batch_size)
                        .field("requests", n_images)
                        .field("images_s", snap.throughput_rps)
                        .field("mean_ms", snap.mean_latency.as_secs_f64() * 1e3)
                        .field("p95_ms", snap.p95_latency.as_secs_f64() * 1e3)
                        .field("mean_queue_ms", snap.mean_queue.as_secs_f64() * 1e3)
                        .field("batches", snap.batches as i64)
                        .field("mean_batch_size", snap.mean_batch_size)
                        .field(
                            "worker_occupancy",
                            Json::arr(snap.worker_occupancy.clone()),
                        )
                        .field("energy_vs_exact", snap.energy_vs_exact)
                        .field("classes", class_rows(&snap)),
                );
            }
        }
    }

    // ---- queue scaling: sharded work-stealing vs the legacy single queue.
    // 4 producer threads hammer the admission path concurrently (the
    // per-client submit loop above never contends on push), so this is the
    // contention-wall measurement: shards=1 is the old single-mutex queue
    // bit-for-bit, shards=4 the work-stealing layout.
    println!("\n-- queue scaling: 4 workers, 4 producer threads --");
    println!("{:<8} {:>10} {:>10} {:>10}", "shards", "img/s", "p99 ms", "steals?");
    let producers = 4usize;
    let per_producer = n_images.div_ceil(2);
    let mut tput = [0.0f64; 2];
    for (idx, &shards) in [1usize, 4].iter().enumerate() {
        let cfg = ServiceConfig {
            n_array: 64,
            workers: 4,
            shards,
            batch_size: 8,
            batch_timeout: Duration::from_millis(1),
            ..Default::default()
        };
        let svc =
            InferenceService::start(Engine::new(bench_model()), cfg).expect("service starts");
        std::thread::scope(|s| {
            for p in 0..producers {
                let svc = &svc;
                let imgs = &imgs;
                s.spawn(move || {
                    let pending: Vec<_> = (0..per_producer)
                        .map(|i| {
                            svc.submit(imgs[(p + i) % imgs.len()].clone())
                                .expect("service accepting")
                        })
                        .collect();
                    for pend in pending {
                        pend.wait().expect("reply");
                    }
                });
            }
        });
        let snap = svc.shutdown();
        assert_eq!(snap.completed, (producers * per_producer) as u64);
        tput[idx] = snap.throughput_rps;
        println!(
            "{:<8} {:>10.1} {:>10.2} {:>10}",
            shards,
            snap.throughput_rps,
            snap.p99_latency.as_secs_f64() * 1e3,
            if shards > 1 { "yes" } else { "-" }
        );
        records.push(
            Json::obj()
                .field("section", "queue_scaling")
                .field("family", "exact")
                .field("workers", 4)
                .field("shards", shards)
                .field("batch_size", 8)
                .field("producer_threads", producers)
                .field("requests", producers * per_producer)
                .field("images_s", snap.throughput_rps)
                .field("p99_ms", snap.p99_latency.as_secs_f64() * 1e3)
                .field("mean_queue_ms", snap.mean_queue.as_secs_f64() * 1e3)
                .field("mean_batch_size", snap.mean_batch_size)
                .field("classes", class_rows(&snap)),
        );
    }
    assert!(
        tput[1] >= tput[0] * 0.85,
        "sharded queue ({:.1}/s) fell more than 15% below the single-queue \
         baseline ({:.1}/s) at 4 workers",
        tput[1],
        tput[0]
    );

    // ---- mixed tenants: heavy flood + light trickle on one pool ----------
    // Two classes share the workers but never share a batch; the per-class
    // rows land in BENCH_serving.json so the isolation claim is tracked
    // across PRs (rung isolation under governors is qos_adaptive's assert).
    println!("\n-- mixed tenants: light trickle + heavy flood, 4 workers --");
    let light_n = 32usize;
    let heavy_n = n_images * 2;
    let cfg = ServiceConfig {
        n_array: 64,
        workers: 4,
        batch_size: 8,
        batch_timeout: Duration::from_millis(1),
        tenants: vec![TenantClass::new("light"), TenantClass::new("heavy")],
        ..Default::default()
    };
    let svc = InferenceService::start(Engine::new(bench_model()), cfg).expect("service starts");
    let mt_shards = svc.n_shards();
    std::thread::scope(|s| {
        let svc_ref = &svc;
        let imgs_ref = &imgs;
        s.spawn(move || {
            let pending: Vec<_> = (0..heavy_n)
                .map(|i| {
                    svc_ref
                        .submit_for(1, imgs_ref[i % imgs_ref.len()].clone())
                        .expect("heavy accepted")
                })
                .collect();
            for pend in pending {
                pend.wait().expect("heavy reply");
            }
        });
        s.spawn(move || {
            for i in 0..light_n {
                let reply = svc_ref
                    .submit_for(0, imgs_ref[i % imgs_ref.len()].clone())
                    .expect("light accepted")
                    .wait()
                    .expect("light reply");
                assert_eq!(reply.tenant, 0);
                std::thread::sleep(Duration::from_millis(2));
            }
        });
    });
    let snap = svc.shutdown();
    assert_eq!(snap.classes.len(), 2);
    assert_eq!(snap.classes[0].completed, light_n as u64);
    assert_eq!(snap.classes[1].completed, heavy_n as u64);
    for c in &snap.classes {
        println!(
            "{:<8} completed {:>6}  p99 {:>8.2} ms  {:>8.1} img/s",
            c.name,
            c.completed,
            c.p99_latency.as_secs_f64() * 1e3,
            c.throughput_rps
        );
    }
    records.push(
        Json::obj()
            .field("section", "mixed_tenants")
            .field("workers", 4)
            .field("shards", mt_shards)
            .field("batch_size", 8)
            .field("light_requests", light_n)
            .field("heavy_requests", heavy_n)
            .field("images_s", snap.throughput_rps)
            .field("classes", class_rows(&snap)),
    );

    let json = Json::obj()
        .field("bench", "serving")
        .field("model_mmacs", macs as f64 / 1e6)
        .field("requests_per_config", n_images)
        .field("quick", quick)
        .field("gemm_threads", gemm_threads)
        .field("results", Json::Arr(records));
    let path = cvapprox::util::bench::artifact_path("BENCH_serving.json");
    match std::fs::write(&path, json.render()) {
        Ok(()) => println!("\nwrote {}", path.display()),
        Err(e) => println!("\n(could not write {}: {e})", path.display()),
    }
}
