//! Bench for **Tables 2-4** (reduced): one representative accuracy cell per
//! family — regenerates the Ours/w-o-V comparison on a test subset and
//! times full-model inference per engine configuration.

use cvapprox::approx::Family;
use cvapprox::datasets::Dataset;
use cvapprox::nn::{loader, Engine, ForwardOpts};
use cvapprox::report::accuracy::sweep_net;
use cvapprox::util::bench::Bencher;

fn main() {
    println!("== bench: accuracy_sweep ==");
    let art = cvapprox::artifacts_dir();
    if !art.join("models").is_dir() {
        println!("(skipped: run `make artifacts` first)");
        return;
    }
    let b = Bencher::default();

    // Single-inference latency per configuration (mininet).
    let model = loader::load_model(&art.join("models/mininet_synth10.cvm")).unwrap();
    let macs = model.macs() as f64;
    let ds = Dataset::load(&art.join("data/synth10_test.cvd")).unwrap();
    let engine = Engine::new(model);
    let img = ds.image(0);
    for (label, opts) in [
        ("exact", ForwardOpts::exact()),
        ("perforated m=3 +V", ForwardOpts::approx(Family::Perforated, 3, true)),
        ("truncated m=7 +V", ForwardOpts::approx(Family::Truncated, 7, true)),
        ("recursive m=4 +V", ForwardOpts::approx(Family::Recursive, 4, true)),
    ] {
        let r = b.run(&format!("mininet inference {label}"), macs, || {
            std::hint::black_box(engine.forward(&img, &opts).unwrap());
        });
        println!("{}", r.report());
    }
    println!();

    // Regenerate one reduced table cell per family (60 images).
    let mut log = |s: &str| println!("{s}");
    for family in Family::APPROX {
        let cells =
            sweep_net(&art, "resnet8", "synth10", family, 60, 1, false, &mut log)
                .unwrap();
        for c in &cells {
            assert!(c.exact_acc > 0.5, "sanity: model learned");
        }
    }
}
