//! Integration suite for the co-design search subsystem (`search/`).
//!
//! Pins the ISSUE-level guarantees end to end on the hermetic
//! mini-artifacts: the same seed produces a byte-identical
//! `SEARCH_pareto.json` at any worker count; every front member's genome
//! re-validates against the structural bitmodel and the model's K-depths;
//! no front member is dominated by any other (exact re-check); an
//! infeasible-K genome dies with a typed error at evaluation — provably
//! before any GEMM, because the model it runs against carries a reduction
//! depth its weight buffer cannot serve (a forward would panic); and the
//! NSGA machinery agrees number-for-number with the checked-in fixture
//! that `scripts/search_mirror.py` cross-checks from Python.

use cvapprox::datasets::Dataset;
use cvapprox::hermetic_dir;
use cvapprox::nn::gemm::MAX_K_POS;
use cvapprox::nn::{loader, Engine};
use cvapprox::search::{
    self, check_feasible, dominates, nsga, EvalError, Evaluator, Gene, Genome,
    Objectives, SearchConfig, Shape,
};
use cvapprox::util::json::Json;

fn hermetic_engine_and_ds() -> (Engine, Dataset) {
    let root = hermetic_dir();
    let model = loader::load_model(&root.join("models/hermnet_hsynth.cvm")).unwrap();
    let ds = Dataset::load(&root.join("data/hsynth_test.cvd")).unwrap();
    (Engine::new(model), ds)
}

fn small_cfg(n_images: usize, seed: u64, workers: usize) -> SearchConfig {
    let mut cfg = SearchConfig::new(n_images);
    cfg.generations = 2;
    cfg.pop = 8;
    cfg.seed = seed;
    cfg.workers = workers;
    cfg
}

/// Same seed ⇒ byte-identical SEARCH_pareto.json at 1, 2 and 4 workers.
#[test]
fn seeded_front_is_byte_identical_across_thread_counts() {
    let (engine, ds) = hermetic_engine_and_ds();
    let render = |workers: usize| {
        let cfg = small_cfg(32, 42, workers);
        search::run_search(&engine, &ds, &cfg).unwrap().to_json().render()
    };
    let one = render(1);
    assert_eq!(one, render(2), "1 vs 2 workers");
    assert_eq!(one, render(4), "1 vs 4 workers");
    assert_eq!(one, render(1), "repeat run from the same seed");
    // a different seed explores differently (provenance at minimum)
    let other = {
        let cfg = small_cfg(32, 43, 1);
        search::run_search(&engine, &ds, &cfg).unwrap().to_json().render()
    };
    assert_ne!(one, other);
}

/// Every front member re-validates (mask + structural bitmodel + K-depth
/// feasibility) and no member is dominated by any other.
#[test]
fn front_members_revalidate_and_are_mutually_nondominated() {
    let (engine, ds) = hermetic_engine_and_ds();
    let cfg = small_cfg(32, 7, 2);
    let result = search::run_search(&engine, &ds, &cfg).unwrap();
    assert!(!result.front.is_empty());
    let kdims = engine.model.mac_layer_kdims();
    for m in &result.front {
        m.genome.validate().unwrap();
        m.genome.structural_check().unwrap();
        check_feasible(&m.genome, &kdims).unwrap();
        assert_eq!(m.hash, m.genome.hash());
        assert!(m.est_loss >= 0.0 && m.est_loss.is_finite());
        assert!(m.power_norm > 0.0 && m.power_norm.is_finite());
    }
    for (i, a) in result.front.iter().enumerate() {
        for (j, b) in result.front.iter().enumerate() {
            if i == j {
                continue;
            }
            let (oa, ob) = (
                Objectives { est_loss: a.est_loss, power_norm: a.power_norm },
                Objectives { est_loss: b.est_loss, power_norm: b.power_norm },
            );
            assert!(
                !dominates(oa, ob),
                "front member {j} is dominated by {i}: {oa:?} < {ob:?}"
            );
        }
    }
    // power-descending artifact order
    for w in result.front.windows(2) {
        assert!(w[1].power_norm <= w[0].power_norm + 1e-12);
    }
    // the artifact parses back and survives its own integrity checks
    let back =
        search::parse_front(&Json::parse(&result.to_json().render()).unwrap()).unwrap();
    assert_eq!(back.len(), result.front.len());
}

/// An infeasible-K genome is rejected with a typed error AT EVALUATION.
/// The engine here carries a doctored reduction depth its weight buffer
/// cannot serve, so any GEMM on that layer would panic on a slice
/// overrun — the clean typed error therefore proves the K gate fires
/// before any GEMM is reached.
#[test]
fn infeasible_k_genome_rejected_at_evaluation_not_mid_gemm() {
    let root = hermetic_dir();
    let mut model = loader::load_model(&root.join("models/hermnet_hsynth.cvm")).unwrap();
    let ds = Dataset::load(&root.join("data/hsynth_test.cvd")).unwrap();
    // Doctor one MAC layer's reduction depth past the Pos-polarity i32
    // headroom ceiling without growing its weights.
    let mac_node = model
        .nodes
        .iter()
        .position(|n| n.weights.is_some())
        .expect("hermetic model has MAC layers");
    model.nodes[mac_node].weights.as_mut().unwrap().k_dim = MAX_K_POS + 1;
    let n_layers = model.mac_layers();
    let engine = Engine::new(model);
    let ev = Evaluator::with_exact_acc(&engine, &ds, ds.n, 64, 1.0);
    let mut genome = Genome::exact(n_layers);
    genome.genes[0] = Gene::approx(
        Shape::Cols,
        2,
        cvapprox::approx::Polarity::Pos,
        true,
        false,
    );
    match ev.evaluate_genome(&genome) {
        Err(EvalError::InfeasibleK { layer, k, max_k }) => {
            assert_eq!(layer, 0);
            assert_eq!(k, MAX_K_POS + 1);
            assert_eq!(max_k, MAX_K_POS);
        }
        other => panic!("expected typed InfeasibleK, got {other:?}"),
    }
    // a mirrored pairing inherits the Pos half's ceiling — same typed path
    let mut paired = Genome::exact(n_layers);
    paired.genes[0] =
        Gene::approx(Shape::Rows, 1, cvapprox::approx::Polarity::Neg, true, true);
    assert!(matches!(
        ev.evaluate_genome(&paired),
        Err(EvalError::InfeasibleK { layer: 0, .. })
    ));
    // and the search as a whole survives the poisoned space: infeasible
    // candidates rank behind every feasible front instead of aborting.
    let objs = vec![
        Some(Objectives { est_loss: 0.0, power_norm: 1.0 }),
        None,
    ];
    assert_eq!(nsga::fast_nondominated_sort(&objs), vec![vec![0], vec![1]]);
}

/// The NSGA machinery agrees number-for-number with the checked-in
/// fixture — the same file `scripts/search_mirror.py` checks from Python.
#[test]
fn nsga_matches_checked_in_fixture() {
    let text = std::fs::read_to_string(
        hermetic_dir().parent().unwrap().join("fixtures/search_front.json"),
    )
    .unwrap();
    let j = Json::parse(&text).unwrap();
    let objs: Vec<Option<Objectives>> = j
        .get("candidates")
        .and_then(|c| c.as_arr())
        .unwrap()
        .iter()
        .map(|e| match e {
            Json::Null => None,
            e => Some(Objectives {
                est_loss: e.get("est_loss").and_then(|v| v.as_f64()).unwrap(),
                power_norm: e.get("power_norm").and_then(|v| v.as_f64()).unwrap(),
            }),
        })
        .collect();
    let want_fronts: Vec<Vec<usize>> = j
        .get("expected_fronts")
        .and_then(|f| f.as_arr())
        .unwrap()
        .iter()
        .map(|f| {
            f.as_arr().unwrap().iter().map(|v| v.as_f64().unwrap() as usize).collect()
        })
        .collect();
    let fronts = nsga::fast_nondominated_sort(&objs);
    assert_eq!(fronts, want_fronts);
    let want_crowding: Vec<Vec<Option<f64>>> = j
        .get("expected_crowding")
        .and_then(|c| c.as_arr())
        .unwrap()
        .iter()
        .map(|f| f.as_arr().unwrap().iter().map(|v| v.as_f64()).collect())
        .collect();
    for (front, want) in fronts.iter().zip(&want_crowding) {
        let d = nsga::crowding_distance(&objs, front);
        assert_eq!(d.len(), want.len());
        for (got, want) in d.iter().zip(want) {
            match want {
                None => assert_eq!(*got, f64::INFINITY),
                Some(w) => assert_eq!(got, w, "crowding must be bit-exact"),
            }
        }
    }
    let survivors_of = |n: usize, key: &str| {
        let want: Vec<usize> = j
            .get(key)
            .and_then(|s| s.as_arr())
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as usize)
            .collect();
        assert_eq!(nsga::survivors(&objs, n), want, "{key}");
    };
    survivors_of(4, "expected_survivors_4");
    survivors_of(7, "expected_survivors_7");
    let front0: Vec<Objectives> = fronts[0].iter().map(|&i| objs[i].unwrap()).collect();
    let ref_point = j.get("ref_point").unwrap();
    let hv = nsga::hypervolume(
        &front0,
        ref_point.get("est_loss").and_then(|v| v.as_f64()).unwrap(),
        ref_point.get("power_norm").and_then(|v| v.as_f64()).unwrap(),
    );
    assert_eq!(
        hv,
        j.get("expected_hypervolume_front0").and_then(|v| v.as_f64()).unwrap(),
        "hypervolume must be bit-exact"
    );
}
