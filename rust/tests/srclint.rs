//! End-to-end self-tests for `srclint` (`cvapprox::analyze`).
//!
//! Each rule gets a minimal on-disk fixture tree that trips it exactly
//! once, so a rule that silently stops firing fails here before it lets a
//! real violation into the tree. The suite also locks in the two
//! properties the CI gate depends on: the *real* repo tree lints clean
//! (the same check `scripts/verify.sh` runs), and the CLI exits non-zero
//! when findings survive.

use std::fs;
use std::path::PathBuf;

use cvapprox::analyze::{repo_root, run_lint};

/// README stub with an (empty) env-var registry, so fixture trees only
/// report the findings their source snippet plants.
const README_STUB: &str = "# fixture\n\n\
    <!-- srclint:env-registry:begin -->\n\
    <!-- srclint:env-registry:end -->\n";

/// A throwaway repo root under the system temp dir. Dropped = deleted.
struct Fixture {
    root: PathBuf,
}

impl Fixture {
    fn new(name: &str) -> Self {
        let root = std::env::temp_dir()
            .join(format!("cvapprox_srclint_{name}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&root);
        fs::create_dir_all(root.join("rust/src")).unwrap();
        fs::write(root.join("README.md"), README_STUB).unwrap();
        Fixture { root }
    }

    fn write(&self, rel: &str, content: &str) -> &Self {
        let path = self.root.join(rel);
        fs::create_dir_all(path.parent().unwrap()).unwrap();
        fs::write(path, content).unwrap();
        self
    }

    /// Lint the fixture and return `(rule, file, line)` per finding.
    fn lint(&self) -> Vec<(String, String, u32)> {
        run_lint(&self.root)
            .unwrap()
            .findings
            .iter()
            .map(|f| (f.rule.to_string(), f.file.clone(), f.line))
            .collect()
    }

    /// Drive the real CLI (`cvapprox srclint --root=...`) over the fixture.
    fn cli(&self, extra: &[String]) -> anyhow::Result<()> {
        let mut argv = vec![
            "srclint".to_string(),
            format!("--root={}", self.root.display()),
        ];
        argv.extend_from_slice(extra);
        cvapprox::report::run(argv)
    }
}

impl Drop for Fixture {
    fn drop(&mut self) {
        let _ = fs::remove_dir_all(&self.root);
    }
}

/// Assert the fixture produces exactly one finding, with this shape.
fn expect_one(fx: &Fixture, rule: &str, file: &str, line: u32) {
    let findings = fx.lint();
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0], (rule.to_string(), file.to_string(), line));
}

#[test]
fn r1_bare_lock_unwrap_trips_once_and_fails_the_cli() {
    let fx = Fixture::new("r1");
    fx.write(
        "rust/src/demo.rs",
        "pub fn f(m: &std::sync::Mutex<u32>) -> u32 { *m.lock().unwrap() }\n",
    );
    expect_one(&fx, "R1", "rust/src/demo.rs", 1);
    assert!(fx.cli(&[]).is_err(), "CLI must exit non-zero on an R1 finding");
}

#[test]
fn r2_off_contract_atomic_trips_once_and_fails_the_cli() {
    let fx = Fixture::new("r2");
    fx.write(
        "rust/src/demo.rs",
        "use std::sync::atomic::{AtomicU64, Ordering};\n\
         pub fn f(a: &AtomicU64) -> u64 { a.load(Ordering::SeqCst) }\n",
    );
    expect_one(&fx, "R2", "rust/src/demo.rs", 2);
    assert!(fx.cli(&[]).is_err(), "CLI must exit non-zero on an R2 finding");
}

#[test]
fn r3_hot_path_unwrap_trips_once_and_fails_the_cli() {
    let fx = Fixture::new("r3");
    fx.write("rust/src/coordinator/demo.rs", "pub fn f(x: Option<u32>) -> u32 { x.unwrap() }\n");
    expect_one(&fx, "R3", "rust/src/coordinator/demo.rs", 1);
    assert!(fx.cli(&[]).is_err(), "CLI must exit non-zero on an R3 finding");
}

#[test]
fn r4_wall_clock_in_deterministic_module_trips_once_and_fails_the_cli() {
    let fx = Fixture::new("r4");
    // util/rng.rs is on the contract's deterministic-modules list.
    fx.write(
        "rust/src/util/rng.rs",
        "pub fn f() -> u128 { std::time::Instant::now().elapsed().as_nanos() }\n",
    );
    expect_one(&fx, "R4", "rust/src/util/rng.rs", 1);
    assert!(fx.cli(&[]).is_err(), "CLI must exit non-zero on an R4 finding");
}

#[test]
fn r5_unregistered_env_var_trips_once_and_fails_the_cli() {
    let fx = Fixture::new("r5");
    fx.write(
        "rust/src/demo.rs",
        "pub fn f() -> Option<String> { \
         std::env::var(\"CVAPPROX_NOT_IN_REGISTRY\").ok() }\n",
    );
    expect_one(&fx, "R5", "rust/src/demo.rs", 1);
    assert!(fx.cli(&[]).is_err(), "CLI must exit non-zero on an R5 finding");
}

#[test]
fn r5_stale_registry_entry_is_the_reverse_direction() {
    let fx = Fixture::new("r5_stale");
    fx.write(
        "README.md",
        "# fixture\n\n\
         <!-- srclint:env-registry:begin -->\n\
         | `CVAPPROX_STALE_ONLY` | nothing reads this |\n\
         <!-- srclint:env-registry:end -->\n",
    );
    fx.write("rust/src/demo.rs", "pub fn f() {}\n");
    let findings = fx.lint();
    assert_eq!(findings.len(), 1);
    assert_eq!(findings[0].0, "R5");
    assert_eq!(findings[0].1, "README.md");
}

#[test]
fn suppression_round_trips_through_the_tree_walk() {
    let fx = Fixture::new("sup_ok");
    fx.write(
        "rust/src/demo.rs",
        "pub fn f(m: &std::sync::Mutex<u32>) -> u32 {\n\
         // srclint: allow(R1, fixture exercising the suppression path)\n\
         *m.lock().unwrap()\n\
         }\n",
    );
    let report = run_lint(&fx.root).unwrap();
    assert!(report.clean(), "suppressed finding must not surface: {:?}", report.findings);
    assert_eq!(report.suppressed, 1);
    assert_eq!(report.suppressions.len(), 1);
    assert_eq!(report.suppressions[0].rule, "R1");
    assert!(fx.cli(&[]).is_ok(), "a fully suppressed tree is clean");
}

#[test]
fn malformed_suppression_is_a_sup_finding() {
    let fx = Fixture::new("sup_bad");
    // Missing reason: the escape hatch itself is linted.
    fx.write("rust/src/demo.rs", "// srclint: allow(R1)\npub fn f() {}\n");
    expect_one(&fx, "SUP", "rust/src/demo.rs", 1);
}

#[test]
fn cli_writes_the_json_artifact_even_when_findings_fail_the_run() {
    let fx = Fixture::new("json");
    fx.write(
        "rust/src/demo.rs",
        "pub fn f(m: &std::sync::Mutex<u32>) -> u32 { *m.lock().unwrap() }\n",
    );
    let json_path = fx.root.join("LINT_report.json");
    let res = fx.cli(&[format!("--json={}", json_path.display())]);
    assert!(res.is_err(), "findings must still fail the run");
    let body = fs::read_to_string(&json_path).unwrap();
    assert!(body.contains("\"tool\": \"srclint\""), "{body}");
    assert!(body.contains("\"rule\": \"R1\""), "{body}");
}

#[test]
fn clean_fixture_passes_the_cli() {
    let fx = Fixture::new("clean");
    fx.write("rust/src/demo.rs", "pub fn double(x: u32) -> u32 { x * 2 }\n");
    assert!(fx.lint().is_empty());
    assert!(fx.cli(&[]).is_ok());
}

/// The gate itself: the real tree must lint clean. This is the same check
/// `scripts/verify.sh` runs via the CLI, kept here too so plain
/// `cargo test` catches an invariant violation without the script.
#[test]
fn real_tree_lints_clean() {
    let report = run_lint(&repo_root()).unwrap();
    assert!(
        report.clean(),
        "srclint findings in the real tree:\n{}",
        report.render()
    );
    // The chaos-injection panic in service.rs carries the one expected
    // (reasoned) suppression; if this drops to zero the lint is probably
    // not scanning what we think it scans.
    assert!(report.suppressed >= 1, "expected at least one live suppression");
    assert!(report.files_scanned > 50, "tree walk looks truncated");
}
