//! Differential test harness: **every engine tier, bit-identical, on every
//! multiplier point** — the class of silent-engine-swap bug fixed ad hoc in
//! PR 1 (Lut falling back to Identity) and PR 3 (m > 7 silently masked)
//! becomes structurally impossible to reintroduce unnoticed.
//!
//! For every family × m ≤ 7 × polarity, on the checked-in hermetic model,
//! the following must produce bit-identical logits:
//!
//! * the planned blocked GEMM (Identity engine — the serving fast path),
//! * the LUT engine (prepared 256×256 tables),
//! * **direct structural-bitmodel evaluation** — a table generated from
//!   `approx::bitmodel`'s partial-product circuit models drives every
//!   product, so the forward is the circuit, product for product,
//! * the batched forward (`forward_batch`, one wide GEMM per layer),
//! * the cycle-level systolic simulator.
//!
//! A paired tier runs the same ladder for even/odd paired assignments.

use std::sync::Arc;

use cvapprox::approx::{bitmodel, Family, MulLut, Polarity};
use cvapprox::datasets::Dataset;
use cvapprox::hermetic_dir;
use cvapprox::nn::{
    loader, Engine, ForwardOpts, LayerAssignment, LayerPoint, LayerPolicy, Model,
    PairedPoint, Tensor,
};

fn hermetic() -> (Model, Dataset) {
    let root = hermetic_dir();
    let model = loader::load_model(&root.join("models/hermnet_hsynth.cvm"))
        .expect("hermetic model (regenerate with scripts/gen_hermetic_golden.py)");
    let ds = Dataset::load(&root.join("data/hsynth_test.cvd")).expect("hermetic dataset");
    (model, ds)
}

/// Every approximate point of the differential sweep: family × m ∈ [1, 7]
/// × polarity.
fn all_points() -> Vec<(Family, u32, Polarity)> {
    let mut pts = Vec::new();
    for family in Family::APPROX {
        for m in 1..=7u32 {
            for pol in Polarity::ALL {
                pts.push((family, m, pol));
            }
        }
    }
    pts
}

fn uniform_opts(model: &Model, family: Family, m: u32, pol: Polarity) -> ForwardOpts {
    let policy = LayerPolicy::new(vec![
        LayerPoint::new_pol(family, m, pol, true);
        model.mac_layers()
    ])
    .unwrap();
    ForwardOpts::with_policy(Arc::new(policy))
}

/// A LUT whose every entry comes from the structural partial-product
/// circuit model — attaching it makes the engine a bitmodel evaluator.
fn bitmodel_lut(family: Family, m: u32, pol: Polarity) -> MulLut {
    MulLut::from_fn(family, m, pol, |w, a| bitmodel::am_bits_pol(family, pol, w, a, m))
}

#[test]
fn every_point_identity_lut_bitmodel_and_batch_agree() {
    let (model, ds) = hermetic();
    let imgs = [ds.image(0), ds.image(1)];
    let refs: Vec<&Tensor> = imgs.iter().collect();
    for (family, m, pol) in all_points() {
        let opts = uniform_opts(&model, family, m, pol);
        // Tier 1: planned blocked GEMM (identity expansion).
        let engine = Engine::new(model.clone());
        let identity: Vec<Vec<f64>> = imgs
            .iter()
            .map(|im| engine.forward(im, &opts).unwrap())
            .collect();
        // Tier 2: LUT engine (closed-form tables).
        let mut e_lut = Engine::new(model.clone());
        e_lut.prepare_lut_pol(family, m, pol);
        // Tier 3: direct structural-bitmodel evaluation.
        let mut e_bits = Engine::new(model.clone());
        e_bits.attach_lut(bitmodel_lut(family, m, pol));
        for (i, im) in imgs.iter().enumerate() {
            let label = format!("{} m={m} {} img {i}", family.name(), pol.name());
            assert_eq!(e_lut.forward(im, &opts).unwrap(), identity[i], "lut {label}");
            assert_eq!(
                e_bits.forward(im, &opts).unwrap(),
                identity[i],
                "bitmodel {label}"
            );
        }
        // Tier 4: batched forward, one wide GEMM per layer.
        let batched = engine.forward_batch(&refs, &opts).unwrap();
        assert_eq!(batched, identity, "{} m={m} {} batched", family.name(), pol.name());
    }
}

#[test]
fn every_point_systolic_simulator_agrees() {
    // The cycle-level array on one image per point (slower tier).
    let (model, ds) = hermetic();
    let img = ds.image(0);
    for (family, m, pol) in all_points() {
        let opts = uniform_opts(&model, family, m, pol);
        let reference = Engine::new(model.clone()).forward(&img, &opts).unwrap();
        let mut engine = Engine::new(model.clone());
        engine.prepare_systolic_pol(family, m, pol, 64);
        let (logits, stats) = engine.forward_systolic(&img, &opts).unwrap();
        assert_eq!(logits, reference, "{} m={m} {}", family.name(), pol.name());
        assert!(stats.cycles > 0);
    }
}

#[test]
fn exact_baseline_agrees_across_engines() {
    let (model, ds) = hermetic();
    let img = ds.image(0);
    let opts = ForwardOpts::exact();
    let reference = Engine::new(model.clone()).forward(&img, &opts).unwrap();
    // LUT kind falls back to the identity core for exact (no table exists).
    let mut lut_opts = ForwardOpts::exact();
    lut_opts.kind = cvapprox::nn::GemmKind::Lut;
    assert_eq!(Engine::new(model.clone()).forward(&img, &lut_opts).unwrap(), reference);
    let batched =
        Engine::new(model.clone()).forward_batch(&[&img], &opts).unwrap();
    assert_eq!(batched[0], reference);
    let mut e_sys = Engine::new(model.clone());
    e_sys.prepare_systolic(Family::Exact, 0, 64);
    let (sys, _) = e_sys.forward_systolic(&img, &opts).unwrap();
    assert_eq!(sys, reference);
}

#[test]
fn paired_assignments_agree_across_engines() {
    // The paired tier of the harness: mirrored, cross-point and half-exact
    // pairings through identity, prepared-LUT, bitmodel-LUT, batched and
    // paired-systolic engines.
    let (model, ds) = hermetic();
    let imgs = [ds.image(0), ds.image(1)];
    let refs: Vec<&Tensor> = imgs.iter().collect();
    let pairings: Vec<PairedPoint> = vec![
        PairedPoint::mirrored(Family::Perforated, 2, true),
        PairedPoint::mirrored(Family::Truncated, 6, true),
        PairedPoint::mirrored(Family::Recursive, 3, false),
        PairedPoint::new(
            LayerPoint::new(Family::Truncated, 6, false),
            LayerPoint::new_pol(Family::Truncated, 5, Polarity::Pos, true),
        ),
        PairedPoint::new(
            LayerPoint::EXACT,
            LayerPoint::new_pol(Family::Perforated, 2, Polarity::Pos, true),
        ),
    ];
    for pair in pairings {
        let policy = LayerPolicy::from_assignments(vec![
            LayerAssignment::Paired(pair);
            model.mac_layers()
        ])
        .unwrap();
        let describe = policy.describe();
        let policy = Arc::new(policy);
        let opts = ForwardOpts::with_policy(policy.clone());
        let engine = Engine::new(model.clone());
        let identity: Vec<Vec<f64>> = imgs
            .iter()
            .map(|im| engine.forward(im, &opts).unwrap())
            .collect();
        // Prepared closed-form LUTs for both halves.
        let mut e_lut = Engine::new(model.clone());
        e_lut.prepare_luts_for_policy(&policy);
        // Structural bitmodel tables for both halves.
        let mut e_bits = Engine::new(model.clone());
        for pt in [pair.even.normalized(), pair.odd.normalized()] {
            if pt != LayerPoint::EXACT {
                e_bits.attach_lut(bitmodel_lut(pt.family, pt.m, pt.polarity));
            }
        }
        for (i, im) in imgs.iter().enumerate() {
            assert_eq!(
                e_lut.forward(im, &opts).unwrap(),
                identity[i],
                "lut {describe} img {i}"
            );
            assert_eq!(
                e_bits.forward(im, &opts).unwrap(),
                identity[i],
                "bitmodel {describe} img {i}"
            );
        }
        let batched = engine.forward_batch(&refs, &opts).unwrap();
        assert_eq!(batched, identity, "batched {describe}");
        // Cycle-level array with alternating multiplier columns.
        let mut e_sys = Engine::new(model.clone());
        e_sys.prepare_systolic_paired(pair, 64);
        let (sys, stats) = e_sys.forward_systolic(&imgs[0], &opts).unwrap();
        assert_eq!(sys, identity[0], "systolic {describe}");
        assert!(stats.cycles > 0);
    }
}
