//! Differential test harness: **every engine tier, bit-identical, on every
//! multiplier point** — the class of silent-engine-swap bug fixed ad hoc in
//! PR 1 (Lut falling back to Identity) and PR 3 (m > 7 silently masked)
//! becomes structurally impossible to reintroduce unnoticed.
//!
//! For every family × m ≤ 7 × polarity, on the checked-in hermetic model,
//! the following must produce bit-identical logits:
//!
//! * the planned blocked GEMM (Identity engine — the serving fast path),
//! * the LUT engine (prepared 256×256 tables),
//! * **direct structural-bitmodel evaluation** — a table generated from
//!   `approx::bitmodel`'s partial-product circuit models drives every
//!   product, so the forward is the circuit, product for product,
//! * the batched forward (`forward_batch`, one wide GEMM per layer),
//! * the cycle-level systolic simulator.
//!
//! A paired tier runs the same ladder for even/odd paired assignments.

use std::sync::Arc;

use cvapprox::approx::{bitmodel, Family, MulLut, Polarity};
use cvapprox::datasets::Dataset;
use cvapprox::hermetic_dir;
use cvapprox::nn::gemm::{
    approx_gemm_planned_with_kernel, paired_gemm_planned_with_kernel, GemmCtx, GemmKind,
};
use cvapprox::nn::kernel;
use cvapprox::nn::{
    loader, Engine, ForwardOpts, Kernel, LayerAssignment, LayerPlan, LayerPoint,
    LayerPolicy, Model, PairedPlan, PairedPoint, Scratch, Tensor,
};
use cvapprox::util::rng::Rng;

fn hermetic() -> (Model, Dataset) {
    let root = hermetic_dir();
    let model = loader::load_model(&root.join("models/hermnet_hsynth.cvm"))
        .expect("hermetic model (regenerate with scripts/gen_hermetic_golden.py)");
    let ds = Dataset::load(&root.join("data/hsynth_test.cvd")).expect("hermetic dataset");
    (model, ds)
}

/// Every approximate point of the differential sweep: family × m ∈ [1, 7]
/// × polarity.
fn all_points() -> Vec<(Family, u32, Polarity)> {
    let mut pts = Vec::new();
    for family in Family::APPROX {
        for m in 1..=7u32 {
            for pol in Polarity::ALL {
                pts.push((family, m, pol));
            }
        }
    }
    pts
}

fn uniform_opts(model: &Model, family: Family, m: u32, pol: Polarity) -> ForwardOpts {
    let policy = LayerPolicy::new(vec![
        LayerPoint::new_pol(family, m, pol, true);
        model.mac_layers()
    ])
    .unwrap();
    ForwardOpts::with_policy(Arc::new(policy))
}

/// A LUT whose every entry comes from the structural partial-product
/// circuit model — attaching it makes the engine a bitmodel evaluator.
fn bitmodel_lut(family: Family, m: u32, pol: Polarity) -> MulLut {
    MulLut::from_fn(family, m, pol, |w, a| bitmodel::am_bits_pol(family, pol, w, a, m))
}

#[test]
fn every_point_identity_lut_bitmodel_and_batch_agree() {
    let (model, ds) = hermetic();
    let imgs = [ds.image(0), ds.image(1)];
    let refs: Vec<&Tensor> = imgs.iter().collect();
    for (family, m, pol) in all_points() {
        let opts = uniform_opts(&model, family, m, pol);
        // Tier 1: planned blocked GEMM (identity expansion).
        let engine = Engine::new(model.clone());
        let identity: Vec<Vec<f64>> = imgs
            .iter()
            .map(|im| engine.forward(im, &opts).unwrap())
            .collect();
        // Tier 2: LUT engine (closed-form tables).
        let mut e_lut = Engine::new(model.clone());
        e_lut.prepare_lut_pol(family, m, pol);
        // Tier 3: direct structural-bitmodel evaluation.
        let mut e_bits = Engine::new(model.clone());
        e_bits.attach_lut(bitmodel_lut(family, m, pol));
        for (i, im) in imgs.iter().enumerate() {
            let label = format!("{} m={m} {} img {i}", family.name(), pol.name());
            assert_eq!(e_lut.forward(im, &opts).unwrap(), identity[i], "lut {label}");
            assert_eq!(
                e_bits.forward(im, &opts).unwrap(),
                identity[i],
                "bitmodel {label}"
            );
        }
        // Tier 4: batched forward, one wide GEMM per layer.
        let batched = engine.forward_batch(&refs, &opts).unwrap();
        assert_eq!(batched, identity, "{} m={m} {} batched", family.name(), pol.name());
    }
}

#[test]
fn every_point_systolic_simulator_agrees() {
    // The cycle-level array on one image per point (slower tier).
    let (model, ds) = hermetic();
    let img = ds.image(0);
    for (family, m, pol) in all_points() {
        let opts = uniform_opts(&model, family, m, pol);
        let reference = Engine::new(model.clone()).forward(&img, &opts).unwrap();
        let mut engine = Engine::new(model.clone());
        engine.prepare_systolic_pol(family, m, pol, 64);
        let (logits, stats) = engine.forward_systolic(&img, &opts).unwrap();
        assert_eq!(logits, reference, "{} m={m} {}", family.name(), pol.name());
        assert!(stats.cycles > 0);
    }
}

#[test]
fn exact_baseline_agrees_across_engines() {
    let (model, ds) = hermetic();
    let img = ds.image(0);
    let opts = ForwardOpts::exact();
    let reference = Engine::new(model.clone()).forward(&img, &opts).unwrap();
    // LUT kind falls back to the identity core for exact (no table exists).
    let mut lut_opts = ForwardOpts::exact();
    lut_opts.kind = cvapprox::nn::GemmKind::Lut;
    assert_eq!(Engine::new(model.clone()).forward(&img, &lut_opts).unwrap(), reference);
    let batched =
        Engine::new(model.clone()).forward_batch(&[&img], &opts).unwrap();
    assert_eq!(batched[0], reference);
    let mut e_sys = Engine::new(model.clone());
    e_sys.prepare_systolic(Family::Exact, 0, 64);
    let (sys, _) = e_sys.forward_systolic(&img, &opts).unwrap();
    assert_eq!(sys, reference);
}

#[test]
fn paired_assignments_agree_across_engines() {
    // The paired tier of the harness: mirrored, cross-point and half-exact
    // pairings through identity, prepared-LUT, bitmodel-LUT, batched and
    // paired-systolic engines.
    let (model, ds) = hermetic();
    let imgs = [ds.image(0), ds.image(1)];
    let refs: Vec<&Tensor> = imgs.iter().collect();
    let pairings: Vec<PairedPoint> = vec![
        PairedPoint::mirrored(Family::Perforated, 2, true),
        PairedPoint::mirrored(Family::Truncated, 6, true),
        PairedPoint::mirrored(Family::Recursive, 3, false),
        PairedPoint::new(
            LayerPoint::new(Family::Truncated, 6, false),
            LayerPoint::new_pol(Family::Truncated, 5, Polarity::Pos, true),
        ),
        PairedPoint::new(
            LayerPoint::EXACT,
            LayerPoint::new_pol(Family::Perforated, 2, Polarity::Pos, true),
        ),
    ];
    for pair in pairings {
        let policy = LayerPolicy::from_assignments(vec![
            LayerAssignment::Paired(pair);
            model.mac_layers()
        ])
        .unwrap();
        let describe = policy.describe();
        let policy = Arc::new(policy);
        let opts = ForwardOpts::with_policy(policy.clone());
        let engine = Engine::new(model.clone());
        let identity: Vec<Vec<f64>> = imgs
            .iter()
            .map(|im| engine.forward(im, &opts).unwrap())
            .collect();
        // Prepared closed-form LUTs for both halves.
        let mut e_lut = Engine::new(model.clone());
        e_lut.prepare_luts_for_policy(&policy);
        // Structural bitmodel tables for both halves.
        let mut e_bits = Engine::new(model.clone());
        for pt in [pair.even.normalized(), pair.odd.normalized()] {
            if pt != LayerPoint::EXACT {
                e_bits.attach_lut(bitmodel_lut(pt.family, pt.m, pt.polarity));
            }
        }
        for (i, im) in imgs.iter().enumerate() {
            assert_eq!(
                e_lut.forward(im, &opts).unwrap(),
                identity[i],
                "lut {describe} img {i}"
            );
            assert_eq!(
                e_bits.forward(im, &opts).unwrap(),
                identity[i],
                "bitmodel {describe} img {i}"
            );
        }
        let batched = engine.forward_batch(&refs, &opts).unwrap();
        assert_eq!(batched, identity, "batched {describe}");
        // Cycle-level array with alternating multiplier columns.
        let mut e_sys = Engine::new(model.clone());
        e_sys.prepare_systolic_paired(pair, 64);
        let (sys, stats) = e_sys.forward_systolic(&imgs[0], &opts).unwrap();
        assert_eq!(sys, identity[0], "systolic {describe}");
        assert!(stats.cycles > 0);
    }
}

// ---------------------------------------------------------------------------
// Kernel-backend axis: the pluggable compute backends (`nn::kernel`) must be
// bit-identical — scalar vs SIMD vs the LUT gather — at the planned-GEMM
// level over shapes the engine never produces (lane tails, tiny panels), on
// every approximate point.

/// The backends × GEMM kinds of the axis: both identity-expansion kernels
/// plus the LUT gather (whose inner loop is kernel-independent but shares
/// the packing and ΣX/Σa epilogues under test).
fn kernel_axis() -> [(&'static dyn Kernel, GemmKind, &'static str); 3] {
    [
        (kernel::scalar(), GemmKind::Identity, "scalar"),
        (kernel::simd(), GemmKind::Identity, "simd"),
        (kernel::scalar(), GemmKind::Lut, "lut"),
    ]
}

#[test]
fn kernel_backends_agree_on_random_shapes() {
    // Shapes straddle the SIMD geometry: 8-wide lanes and 4-row register
    // blocks, so prime / odd K and N exercise every tail path.
    let mut rng = Rng::new(0xD1FF);
    let shapes =
        [(1usize, 1usize, 1usize), (3, 7, 5), (4, 16, 8), (5, 33, 17), (9, 127, 31), (12, 258, 63)];
    for &(m_rows, k, n) in &shapes {
        let w: Vec<u8> = (0..m_rows * k).map(|_| rng.u8()).collect();
        let a: Vec<u8> = (0..k * n).map(|_| rng.u8()).collect();
        let bias: Vec<i32> = (0..m_rows).map(|_| rng.below(100) as i32 - 50).collect();
        for (family, m, pol) in all_points() {
            let ctx = GemmCtx { family, m, use_cv: true, zp_w: 9, zp_a: 101 };
            let plan = LayerPlan::build_pol(family, m, pol, &w, m_rows, k, k);
            let lut = MulLut::build_pol(family, m, pol);
            let mut outs: Vec<Vec<i64>> = Vec::new();
            for (kr, kind, _) in kernel_axis() {
                let mut scratch = Scratch::new();
                approx_gemm_planned_with_kernel(
                    kr, kind, &ctx, &plan, 0, Some(&lut), &w, &a, m_rows, k, n,
                    &bias, &mut scratch, 1,
                );
                outs.push(scratch.acc[..m_rows * n].to_vec());
            }
            let label = format!("{} m={m} {} {m_rows}x{k}x{n}", family.name(), pol.name());
            assert_eq!(outs[0], outs[1], "simd vs scalar {label}");
            assert_eq!(outs[0], outs[2], "lut vs scalar {label}");
        }
    }
}

#[test]
fn kernel_backends_agree_on_masked_partitions_and_odd_k_pairings() {
    let mut rng = Rng::new(0xC0DE);
    // k_valid-masked rows: a weight panel zeroed off an even partition,
    // with the plan's CV averages divided by the partition population —
    // exactly the panels paired plans build internally.
    let (m_rows, k, n) = (6usize, 51usize, 19usize);
    let a: Vec<u8> = (0..k * n).map(|_| rng.u8()).collect();
    let bias: Vec<i32> = (0..m_rows).map(|_| rng.below(100) as i32 - 50).collect();
    let mut w_even: Vec<u8> = (0..m_rows * k).map(|_| rng.u8()).collect();
    for (i, x) in w_even.iter_mut().enumerate() {
        if (i % k) % 2 == 1 {
            *x = 0;
        }
    }
    let k_valid = k.div_ceil(2);
    for (family, m, pol) in all_points() {
        let ctx = GemmCtx { family, m, use_cv: true, zp_w: 0, zp_a: 101 };
        let plan = LayerPlan::build_pol(family, m, pol, &w_even, m_rows, k, k_valid);
        let lut = MulLut::build_pol(family, m, pol);
        let mut outs: Vec<Vec<i64>> = Vec::new();
        for (kr, kind, _) in kernel_axis() {
            let mut scratch = Scratch::new();
            approx_gemm_planned_with_kernel(
                kr, kind, &ctx, &plan, 0, Some(&lut), &w_even, &a, m_rows, k, n,
                &bias, &mut scratch, 1,
            );
            outs.push(scratch.acc[..m_rows * n].to_vec());
        }
        let label = format!("masked {} m={m} {}", family.name(), pol.name());
        assert_eq!(outs[0], outs[1], "simd vs scalar {label}");
        assert_eq!(outs[0], outs[2], "lut vs scalar {label}");
    }
    // Odd-k paired parity: the even partition owns one more reduction index
    // than the odd, on both kernels, through identity and LUT kinds.
    let w: Vec<u8> = (0..m_rows * k).map(|_| rng.u8()).collect();
    let pairings = [
        PairedPoint::mirrored(Family::Perforated, 2, true),
        PairedPoint::mirrored(Family::Truncated, 6, true),
        PairedPoint::new(
            LayerPoint::EXACT,
            LayerPoint::new_pol(Family::Recursive, 3, Polarity::Pos, true),
        ),
    ];
    for pair in pairings {
        let plan = PairedPlan::build(pair, &w, m_rows, k);
        let mut outs: Vec<Vec<i64>> = Vec::new();
        for (kr, kind, _) in kernel_axis() {
            let mut scratch = Scratch::new();
            paired_gemm_planned_with_kernel(
                kr, kind, &pair, 3, 101, &plan, 0, None, None, &w, &a, m_rows, k,
                n, &bias, &mut scratch, 1,
            );
            outs.push(scratch.acc[..m_rows * n].to_vec());
        }
        let label = pair.describe();
        assert_eq!(outs[0], outs[1], "simd vs scalar paired {label}");
        assert_eq!(outs[0], outs[2], "lut vs scalar paired {label}");
    }
}

#[test]
fn kernel_selection_is_reflected_in_replies_bit_identically() {
    // `CVAPPROX_KERNEL` resolves once per process; CI runs this suite under
    // `=scalar` and `=simd`. Whatever the ambient selection, engines pinned
    // to either backend must reply bit-identically to it — the env knob can
    // change speed, never logits.
    let (model, ds) = hermetic();
    let img = ds.image(0);
    let ambient = Engine::new(model.clone());
    let want = match std::env::var("CVAPPROX_KERNEL") {
        Ok(v) => kernel::select(v.trim()).name(),
        Err(_) => kernel::select("auto").name(),
    };
    assert_eq!(ambient.kernel_name(), want, "env selection must be honored");
    let probe_points = [
        (Family::Perforated, 2, Polarity::Neg),
        (Family::Truncated, 6, Polarity::Pos),
        (Family::Recursive, 3, Polarity::Neg),
    ];
    for (family, m, pol) in probe_points {
        let opts = uniform_opts(&model, family, m, pol);
        let reference = ambient.forward(&img, &opts).unwrap();
        for kr in [kernel::scalar(), kernel::simd()] {
            let pinned = Engine::with_kernel(model.clone(), kr);
            assert_eq!(pinned.kernel_name(), kr.name());
            assert_eq!(
                pinned.forward(&img, &opts).unwrap(),
                reference,
                "{} backend, {} m={m} {}",
                kr.name(),
                family.name(),
                pol.name()
            );
        }
    }
}
