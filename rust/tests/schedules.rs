//! Schedule-exploring race harness for the serving plane.
//!
//! Thread interleavings in the real coordinator depend on the OS scheduler,
//! so `cargo test` only ever sees a handful of them. This harness lifts the
//! two protocols whose correctness the serving plane leans on into explicit
//! state machines and drives them through *every* interleaving up to a step
//! bound (exhaustive DFS) plus seeded random walks for configurations too
//! large to enumerate:
//!
//! * **PolicySwitch install/read** (`nn/policy.rs`): installers bump the
//!   epoch and swap the policy under the mutex; readers snapshot
//!   `(epoch, policy)` pairs. Invariants: every observed pair was atomically
//!   installed (no torn reads) and epochs are unique and gap-free. A
//!   deliberately torn variant (epoch and policy written as two independent
//!   non-atomic steps) must be *caught* by the same invariants.
//! * **Worker request ledger** (`coordinator/service.rs` `run_batch`):
//!   workers pop batches, compute (with corrupt/replay/exhaust faults from
//!   the fault plane), reply, crash; the supervisor sweeps stranded entries
//!   and respawns; shutdown closes the queue and drains. Invariant: exactly
//!   one reply per request — never zero (a hang) and never two (a double
//!   send on a consumed channel). A buggy-sweep variant (sweeping the
//!   *original* batch instead of the ledger's not-yet-replied remainder,
//!   the exact bug the per-worker ledger exists to prevent) must violate.
//! * **Sharded steal queue** (`coordinator/service.rs` `ShardedQueue`,
//!   PR 9): round-robin pushes land on shards, workers take from their
//!   home shard and steal from the first non-empty shard in sweep order
//!   when home is empty. Invariant: across every steal interleaving no
//!   request is lost (stranded in a shard at shutdown) or double-popped.
//!   A racy variant (peek the victim's head, then commit without
//!   re-checking under the lock — the race the per-shard mutex closes)
//!   must be caught by the same invariants.
//!
//! A randomized *stress* tier drives the real `ShardedQueue` through the
//! public service API: multiple producer threads, mixed tight/generous/no
//! deadlines, shards = workers = 4, asserting exactly one typed reply per
//! request and bit-identical logits on every `Ok`.
//!
//! Exploration is deterministic: exhaustive DFS visits leaves in a fixed
//! order and random walks derive per-walk seeds with the same splitmix64
//! discipline as `fault/inject.rs`, so the leaf-trace digest (FNV-1a over
//! the action sequence) is stable run-to-run. A violation does NOT truncate
//! its schedule — the explorer carries a sticky flag to the leaf — so leaf
//! counts stay exact multinomials and are asserted exactly.
//!
//! `scripts/schedules_mirror.py` is an independent transliteration of these
//! models; the exact counts asserted below were cross-checked against it.

use cvapprox::util::hash::Hasher64;
use cvapprox::util::rng::Rng;

/// Per-walk seed derivation constant (splitmix64 increment), matching the
/// per-worker stream split in `fault/inject.rs`.
const SEED_SPLIT: u64 = 0x9E37_79B9_7F4A_7C15;

/// A protocol model: a finite state machine with explicit scheduler choice.
///
/// `actions` enumerates every enabled transition; `step` applies one;
/// `violated` is a sticky invariant-failure flag; `done` says whether a
/// state with no enabled actions is a clean terminal (anything else is a
/// deadlock and counts as a violation).
trait Model: Clone {
    fn actions(&self) -> Vec<u32>;
    fn step(&mut self, action: u32);
    fn violated(&self) -> bool;
    fn done(&self) -> bool;
}

/// Outcome of an exploration: schedule count, violation count, and an
/// order-sensitive digest of every leaf's action trace (determinism probe).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
struct Explored {
    schedules: u64,
    violated: u64,
    digest: u64,
}

impl Explored {
    fn leaf(&mut self, path: &[u32], bad: bool) {
        self.schedules += 1;
        if bad {
            self.violated += 1;
        }
        let mut h = Hasher64::new();
        for &a in path {
            h.word(a as u64);
        }
        h.word(bad as u64);
        self.digest = self.digest.rotate_left(1) ^ h.finish();
    }
}

/// Exhaustive DFS over every schedule (sequence of enabled actions).
fn explore<M: Model>(m0: &M) -> Explored {
    fn dfs<M: Model>(m: &M, path: &mut Vec<u32>, out: &mut Explored) {
        let acts = m.actions();
        if acts.is_empty() {
            out.leaf(path, m.violated() || !m.done());
            return;
        }
        for a in acts {
            let mut next = m.clone();
            next.step(a);
            path.push(a);
            dfs(&next, path, out);
            path.pop();
        }
    }
    let mut out = Explored::default();
    dfs(m0, &mut Vec::new(), &mut out);
    out
}

/// Seeded random walks for configurations too large to enumerate.
fn random_walks<M: Model>(m0: &M, walks: u64, seed: u64) -> Explored {
    let mut out = Explored::default();
    let mut path = Vec::new();
    for i in 0..walks {
        let mut rng = Rng::new(seed ^ i.wrapping_mul(SEED_SPLIT));
        let mut m = m0.clone();
        path.clear();
        loop {
            let acts = m.actions();
            if acts.is_empty() {
                break;
            }
            let a = acts[rng.below(acts.len() as u64) as usize];
            m.step(a);
            path.push(a);
            assert!(path.len() < 100_000, "schedule failed to terminate");
        }
        out.leaf(&path, m.violated() || !m.done());
    }
    out
}

// ---------------------------------------------------------------------------
// Model 1: PolicySwitch install/read under the mutex (correct protocol).
// ---------------------------------------------------------------------------

#[derive(Clone)]
struct SwitchThread {
    installer: bool,
    /// Completed critical sections.
    sec: u32,
    /// Program counter within the current section.
    step: u32,
    /// Installer's register: the epoch read under the lock.
    reg: u64,
}

/// Installers run `lock; read cur; write (epoch+1, pid); unlock` per
/// section; readers run `lock; read (epoch, policy); unlock`. This mirrors
/// `PolicySwitch::{install, current}` where the mutex makes the pair swap
/// atomic.
#[derive(Clone)]
struct LockedSwitch {
    threads: Vec<SwitchThread>,
    sections: u32,
    /// Which thread holds the mutex, if any.
    lock: Option<usize>,
    cur: (u64, u32),
    /// Every (epoch, policy_id) pair ever installed, seeded with the boot
    /// pair (0, 0). Readers must only ever observe members of this set.
    installed: Vec<(u64, u32)>,
    epochs: Vec<u64>,
    bad: bool,
}

impl LockedSwitch {
    fn new(installers: usize, readers: usize, sections: u32) -> Self {
        let mut threads = Vec::new();
        for _ in 0..installers {
            threads.push(SwitchThread { installer: true, sec: 0, step: 0, reg: 0 });
        }
        for _ in 0..readers {
            threads.push(SwitchThread { installer: false, sec: 0, step: 0, reg: 0 });
        }
        LockedSwitch {
            threads,
            sections,
            lock: None,
            cur: (0, 0),
            installed: vec![(0, 0)],
            epochs: vec![0],
            bad: false,
        }
    }

    fn install(&mut self, epoch: u64, pid: u32) {
        self.cur = (epoch, pid);
        if self.epochs.contains(&epoch) {
            self.bad = true; // duplicate epoch: two installers raced the bump
        }
        self.epochs.push(epoch);
        self.installed.push((epoch, pid));
    }
}

impl Model for LockedSwitch {
    fn actions(&self) -> Vec<u32> {
        let mut out = Vec::new();
        for (t, th) in self.threads.iter().enumerate() {
            if th.sec >= self.sections {
                continue;
            }
            let enabled = if th.step == 0 {
                self.lock.is_none()
            } else {
                self.lock == Some(t)
            };
            if enabled {
                out.push(t as u32);
            }
        }
        out
    }

    fn step(&mut self, action: u32) {
        let t = action as usize;
        let th = &mut self.threads[t];
        if th.step == 0 {
            self.lock = Some(t);
            th.step = 1;
            return;
        }
        if th.installer {
            match th.step {
                1 => {
                    th.reg = self.cur.0;
                    th.step = 2;
                }
                2 => {
                    let (epoch, pid) = (th.reg + 1, (t as u32) * 10 + th.sec + 1);
                    th.step = 3;
                    self.install(epoch, pid);
                }
                _ => {
                    self.lock = None;
                    th.sec += 1;
                    th.step = 0;
                }
            }
        } else if th.step == 1 {
            if !self.installed.contains(&self.cur) {
                self.bad = true; // torn read: pair never atomically installed
            }
            th.step = 2;
        } else {
            self.lock = None;
            th.sec += 1;
            th.step = 0;
        }
    }

    fn violated(&self) -> bool {
        self.bad
    }

    fn done(&self) -> bool {
        self.lock.is_none() && self.threads.iter().all(|th| th.sec >= self.sections)
    }
}

// ---------------------------------------------------------------------------
// Model 2: torn PolicySwitch — epoch and policy written as two independent
// steps with no lock. The invariants must catch it.
// ---------------------------------------------------------------------------

#[derive(Clone)]
struct TornThread {
    installer: bool,
    step: u32,
    reg: u64,
}

#[derive(Clone)]
struct TornSwitch {
    threads: Vec<TornThread>,
    epoch: u64,
    policy: u32,
    installed: Vec<(u64, u32)>,
    epochs: Vec<u64>,
    bad: bool,
}

impl TornSwitch {
    fn new(installers: usize, readers: usize) -> Self {
        let mut threads = Vec::new();
        for _ in 0..installers {
            threads.push(TornThread { installer: true, step: 0, reg: 0 });
        }
        for _ in 0..readers {
            threads.push(TornThread { installer: false, step: 0, reg: 0 });
        }
        TornSwitch {
            threads,
            epoch: 0,
            policy: 0,
            installed: vec![(0, 0)],
            epochs: vec![0],
            bad: false,
        }
    }

    fn steps(th: &TornThread) -> u32 {
        if th.installer {
            3 // read epoch; write policy; write epoch (the tear)
        } else {
            2 // read epoch; read policy + validate the pair
        }
    }
}

impl Model for TornSwitch {
    fn actions(&self) -> Vec<u32> {
        let mut out = Vec::new();
        for (t, th) in self.threads.iter().enumerate() {
            if th.step < Self::steps(th) {
                out.push(t as u32);
            }
        }
        out
    }

    fn step(&mut self, action: u32) {
        let t = action as usize;
        let th = &mut self.threads[t];
        let pid = (t as u32) * 10 + 1;
        if th.installer {
            match th.step {
                0 => th.reg = self.epoch,
                1 => self.policy = pid,
                _ => {
                    let e = th.reg + 1;
                    self.epoch = e;
                    if self.epochs.contains(&e) {
                        self.bad = true; // lost-update epoch collision
                    }
                    self.epochs.push(e);
                    self.installed.push((e, pid));
                }
            }
        } else if th.step == 0 {
            th.reg = self.epoch;
        } else {
            let obs = (th.reg, self.policy);
            if !self.installed.contains(&obs) {
                self.bad = true; // torn read observed
            }
        }
        self.threads[t].step += 1;
    }

    fn violated(&self) -> bool {
        self.bad
    }

    fn done(&self) -> bool {
        self.threads.iter().all(|th| th.step >= Self::steps(th))
    }
}

// ---------------------------------------------------------------------------
// Model 3: the worker request ledger (exactly-one-reply protocol).
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, PartialEq, Eq)]
enum WorkerState {
    Idle,
    Holding,
    Crashed,
    Retired,
}

#[derive(Clone)]
struct Worker {
    state: WorkerState,
    /// Not-yet-replied remainder of the current batch (the ledger).
    batch: Vec<u8>,
    /// Original batch as popped — what the buggy sweep wrongly consults.
    orig: Vec<u8>,
    computed: bool,
    attempts: u32,
    /// Entries the supervisor must sweep after a crash.
    stranded: Vec<u8>,
}

impl Worker {
    fn idle() -> Self {
        Worker {
            state: WorkerState::Idle,
            batch: Vec::new(),
            orig: Vec::new(),
            computed: false,
            attempts: 0,
            stranded: Vec::new(),
        }
    }
}

/// Faithful abstraction of `service.rs` `run_batch` + supervisor + close:
/// clients submit (post-close submits get the typed reject, which *is* the
/// request's one reply); workers pop FIFO batches, compute (integrity
/// faults replay up to `max_attempts`, then the whole batch gets typed
/// integrity replies), reply one-by-one, and may crash between any two
/// steps; the supervisor sweeps a crashed worker's stranded entries then
/// respawns (or retires it when the plane is closing); terminal drain
/// rejects whatever is left once every worker retired.
#[derive(Clone)]
struct LedgerModel {
    requests: u8,
    batch_cap: usize,
    max_attempts: u32,
    /// Sweep `orig` instead of `batch`: double-replies already-sent entries.
    buggy_sweep: bool,
    queue: Vec<u8>,
    next_submit: u8,
    replies: Vec<u8>,
    closed: bool,
    workers: Vec<Worker>,
    bad: bool,
}

const ACT_SUBMIT: u32 = 2000;
const ACT_CLOSE: u32 = 2001;
const ACT_DRAIN: u32 = 2002;

const OP_POP: u32 = 0;
const OP_RETIRE: u32 = 1;
const OP_COMPUTE_OK: u32 = 2;
const OP_CORRUPT_REPLAY: u32 = 3;
const OP_EXHAUST: u32 = 4;
const OP_REPLY_ONE: u32 = 5;
const OP_FINISH: u32 = 6;
const OP_CRASH: u32 = 7;
const OP_SWEEP_ONE: u32 = 8;
const OP_RESPAWN: u32 = 9;

impl LedgerModel {
    fn new(requests: u8, workers: usize, batch_cap: usize, max_attempts: u32) -> Self {
        LedgerModel {
            requests,
            batch_cap,
            max_attempts,
            buggy_sweep: false,
            queue: Vec::new(),
            next_submit: 0,
            replies: vec![0; requests as usize],
            closed: false,
            workers: vec![Worker::idle(); workers],
            bad: false,
        }
    }

    fn with_buggy_sweep(mut self) -> Self {
        self.buggy_sweep = true;
        self
    }

    fn reply(&mut self, k: u8) {
        let slot = &mut self.replies[k as usize];
        *slot += 1;
        if *slot > 1 {
            self.bad = true; // double reply
        }
    }
}

impl Model for LedgerModel {
    fn actions(&self) -> Vec<u32> {
        let mut out = Vec::new();
        if self.next_submit < self.requests {
            out.push(ACT_SUBMIT);
        }
        if !self.closed {
            out.push(ACT_CLOSE);
        }
        let all_retired = self.workers.iter().all(|w| w.state == WorkerState::Retired);
        if self.closed && !self.queue.is_empty() && all_retired {
            out.push(ACT_DRAIN);
        }
        for (i, w) in self.workers.iter().enumerate() {
            let base = (i as u32) * 10;
            match w.state {
                WorkerState::Idle => {
                    if !self.queue.is_empty() {
                        out.push(base + OP_POP);
                    } else if self.closed && self.next_submit >= self.requests {
                        out.push(base + OP_RETIRE);
                    }
                }
                WorkerState::Holding => {
                    if !w.computed {
                        out.push(base + OP_COMPUTE_OK);
                        if w.attempts < self.max_attempts {
                            out.push(base + OP_CORRUPT_REPLAY);
                        } else {
                            out.push(base + OP_EXHAUST);
                        }
                    } else if !w.batch.is_empty() {
                        out.push(base + OP_REPLY_ONE);
                    } else {
                        out.push(base + OP_FINISH);
                    }
                    if !w.batch.is_empty() {
                        out.push(base + OP_CRASH);
                    }
                }
                WorkerState::Crashed => {
                    if !w.stranded.is_empty() {
                        out.push(base + OP_SWEEP_ONE);
                    } else {
                        out.push(base + OP_RESPAWN);
                        if self.closed {
                            out.push(base + OP_RETIRE);
                        }
                    }
                }
                WorkerState::Retired => {}
            }
        }
        out
    }

    fn step(&mut self, action: u32) {
        match action {
            ACT_SUBMIT => {
                let k = self.next_submit;
                self.next_submit += 1;
                if self.closed {
                    self.reply(k); // typed reject is the one reply
                } else {
                    self.queue.push(k);
                }
                return;
            }
            ACT_CLOSE => {
                self.closed = true;
                return;
            }
            ACT_DRAIN => {
                let k = self.queue.remove(0);
                self.reply(k);
                return;
            }
            _ => {}
        }
        let (i, op) = ((action / 10) as usize, action % 10);
        match op {
            OP_POP => {
                let take = self.batch_cap.min(self.queue.len());
                let batch: Vec<u8> = self.queue.drain(..take).collect();
                let w = &mut self.workers[i];
                *w = Worker::idle();
                w.state = WorkerState::Holding;
                w.orig = batch.clone();
                w.batch = batch;
            }
            OP_RETIRE => self.workers[i].state = WorkerState::Retired,
            OP_COMPUTE_OK => self.workers[i].computed = true,
            OP_CORRUPT_REPLAY => self.workers[i].attempts += 1,
            OP_EXHAUST => {
                let batch = std::mem::take(&mut self.workers[i].batch);
                for k in batch {
                    self.reply(k); // typed integrity reply for the whole batch
                }
                self.workers[i] = Worker::idle();
            }
            OP_REPLY_ONE => {
                let k = self.workers[i].batch.remove(0);
                self.reply(k);
            }
            OP_FINISH => self.workers[i] = Worker::idle(),
            OP_CRASH => {
                let w = &mut self.workers[i];
                let stranded = if self.buggy_sweep {
                    w.orig.clone()
                } else {
                    w.batch.clone()
                };
                *w = Worker::idle();
                w.state = WorkerState::Crashed;
                w.stranded = stranded;
            }
            OP_SWEEP_ONE => {
                let k = self.workers[i].stranded.remove(0);
                self.reply(k); // WorkerCrashed reply from the supervisor sweep
            }
            _ => self.workers[i] = Worker::idle(), // OP_RESPAWN
        }
    }

    fn violated(&self) -> bool {
        self.bad
    }

    fn done(&self) -> bool {
        self.next_submit >= self.requests
            && self.closed
            && self.queue.is_empty()
            && self.workers.iter().all(|w| w.state == WorkerState::Retired)
            && self.replies.iter().all(|&r| r == 1)
    }
}

// ---------------------------------------------------------------------------
// Model 4: the sharded work-stealing queue (PR 9 `ShardedQueue`).
// ---------------------------------------------------------------------------

const OP_TAKE_HOME: u32 = 0;
const OP_STEAL: u32 = 1;
const OP_COMMIT: u32 = 2;
const OP_W_RETIRE: u32 = 3;

/// Round-robin pushes over `n_shards` shards; each worker's home shard is
/// `worker % n_shards`; a worker with an empty home steals from the first
/// non-empty shard in sweep order (matching `ShardedQueue::pop_batch`).
/// The correct variant's steal is one atomic action (the pop happens under
/// the victim shard's lock); the `racy` variant splits it into peek
/// (record the victim's head) and commit (serve the recorded id without
/// re-checking), so a schedule where another worker takes that request
/// between the two steps double-serves it. Requests left in a shard at
/// shutdown fail `done` — losses and double-pops are both caught.
#[derive(Clone)]
struct StealModel {
    requests: u8,
    n_shards: usize,
    racy: bool,
    shards: Vec<Vec<u8>>,
    /// Round-robin push cursor (the `rr` atomic).
    rr: usize,
    next_submit: u8,
    replies: Vec<u8>,
    closed: bool,
    /// Per worker: (retired, peeked (victim, id) if mid-racy-steal).
    workers: Vec<(bool, Option<(usize, u8)>)>,
    bad: bool,
}

impl StealModel {
    fn new(requests: u8, workers: usize, shards: usize, racy: bool) -> Self {
        StealModel {
            requests,
            n_shards: shards,
            racy,
            shards: vec![Vec::new(); shards],
            rr: 0,
            next_submit: 0,
            replies: vec![0; requests as usize],
            closed: false,
            workers: vec![(false, None); workers],
            bad: false,
        }
    }

    /// First non-empty shard in worker `i`'s sweep order, skipping home.
    fn victim(&self, i: usize) -> Option<usize> {
        let home = i % self.n_shards;
        (1..self.n_shards)
            .map(|k| (home + k) % self.n_shards)
            .find(|&j| !self.shards[j].is_empty())
    }

    fn reply(&mut self, k: u8) {
        let slot = &mut self.replies[k as usize];
        *slot += 1;
        if *slot > 1 {
            self.bad = true; // double-pop: one request served twice
        }
    }
}

impl Model for StealModel {
    fn actions(&self) -> Vec<u32> {
        let mut out = Vec::new();
        if self.next_submit < self.requests {
            out.push(ACT_SUBMIT);
        }
        if !self.closed {
            out.push(ACT_CLOSE);
        }
        let drained = self.shards.iter().all(|s| s.is_empty());
        for (i, &(retired, peek)) in self.workers.iter().enumerate() {
            if retired {
                continue;
            }
            let base = (i as u32) * 10;
            if peek.is_some() {
                out.push(base + OP_COMMIT);
                continue;
            }
            if !self.shards[i % self.n_shards].is_empty() {
                out.push(base + OP_TAKE_HOME);
            } else if self.victim(i).is_some() {
                out.push(base + OP_STEAL);
            } else if self.closed && self.next_submit >= self.requests && drained {
                out.push(base + OP_W_RETIRE);
            }
        }
        out
    }

    fn step(&mut self, action: u32) {
        match action {
            ACT_SUBMIT => {
                let k = self.next_submit;
                self.next_submit += 1;
                if self.closed {
                    self.reply(k); // typed Closed reject is the one reply
                } else {
                    let shard = self.rr % self.n_shards;
                    self.rr += 1;
                    self.shards[shard].push(k);
                }
                return;
            }
            ACT_CLOSE => {
                self.closed = true;
                return;
            }
            _ => {}
        }
        let (i, op) = ((action / 10) as usize, action % 10);
        match op {
            OP_TAKE_HOME => {
                let k = self.shards[i % self.n_shards].remove(0);
                self.reply(k);
            }
            OP_STEAL => {
                let j = self.victim(i).expect("steal only enabled with a victim");
                if self.racy {
                    self.workers[i].1 = Some((j, self.shards[j][0]));
                } else {
                    let k = self.shards[j].remove(0);
                    self.reply(k);
                }
            }
            OP_COMMIT => {
                let (j, k) = self.workers[i].1.take().expect("commit needs a peek");
                if let Some(pos) = self.shards[j].iter().position(|&q| q == k) {
                    self.shards[j].remove(pos);
                }
                self.reply(k); // served even when already taken: the race
            }
            _ => self.workers[i].0 = true, // OP_W_RETIRE
        }
    }

    fn violated(&self) -> bool {
        self.bad
    }

    fn done(&self) -> bool {
        self.next_submit >= self.requests
            && self.closed
            && self.shards.iter().all(|s| s.is_empty())
            && self.workers.iter().all(|&(retired, _)| retired)
            && self.replies.iter().all(|&r| r == 1)
    }
}

// ---------------------------------------------------------------------------
// Exhaustive tier. Leaf counts are exact: violations never truncate a
// schedule, so the totals are pure multinomials over the step sequences.
// ---------------------------------------------------------------------------

/// 2 installers x 2 sections + 2 readers x 2 sections under the lock:
/// 8 critical sections -> 8!/(2!^4) = 2520 grant orders, zero violations.
#[test]
fn exhaustive_locked_policy_switch_is_race_free() {
    let m = LockedSwitch::new(2, 2, 2);
    let a = explore(&m);
    assert_eq!(a.schedules, 2520);
    assert_eq!(a.violated, 0);
    let b = explore(&m);
    assert_eq!(a, b, "exhaustive exploration must be deterministic");
}

/// The torn variant interleaves freely: (3,3,2,2) steps -> 10!/(3!3!2!2!)
/// = 25200 schedules. The invariants must catch both failure modes (torn
/// pair reads and lost-update epoch collisions) — and in most schedules:
/// 25008 of 25200, cross-checked against scripts/schedules_mirror.py.
#[test]
fn exhaustive_torn_policy_switch_is_caught() {
    let a = explore(&TornSwitch::new(2, 2));
    assert_eq!(a.schedules, 25200);
    assert_eq!(a.violated, 25008);
    assert!(a.violated > 0 && a.violated < a.schedules);
}

/// 2 requests, 1 worker, batch cap 2, 1 replay attempt: 2899 schedules
/// covering submit/close races, corrupt->replay->exhaust, crash-with-
/// partial-replies, sweep, respawn-vs-retire, and terminal drain. The
/// correct ledger never double-replies or drops a request.
#[test]
fn exhaustive_ledger_exactly_one_reply() {
    let m = LedgerModel::new(2, 1, 2, 1);
    let a = explore(&m);
    assert_eq!(a.schedules, 2899);
    assert_eq!(a.violated, 0);
    let b = explore(&m);
    assert_eq!(a, b, "exhaustive exploration must be deterministic");
}

/// Same configuration with the sweep consulting the original batch instead
/// of the not-yet-replied remainder: every schedule that replies part of a
/// batch and then crashes double-replies the already-sent entries. 32 of
/// 2903 schedules violate — the harness proves the sweep must go through
/// the ledger.
#[test]
fn exhaustive_buggy_sweep_is_caught() {
    let a = explore(&LedgerModel::new(2, 1, 2, 1).with_buggy_sweep());
    assert_eq!(a.schedules, 2903);
    assert_eq!(a.violated, 32);
}

/// 3 requests through the same plane: 112269 schedules, still exactly one
/// reply each. Together the exhaustive tier enumerates 220662 schedules —
/// past the 10^4 coverage floor on exact counts alone.
#[test]
fn exhaustive_ledger_three_requests() {
    let a = explore(&LedgerModel::new(3, 1, 2, 1));
    assert_eq!(a.schedules, 112_269);
    assert_eq!(a.violated, 0);
    let total = 2520 + 25200 + 2899 + 2903 + 314 + 4722 + 1926 + 67909 + a.schedules;
    assert!(total >= 10_000, "exhaustive tier must cover >= 10^4 schedules");
}

/// Sharded steal queue, 3 then 4 requests round-robined over 2 shards with
/// 2 workers: every interleaving of home takes, steals, closes and late
/// submits serves each request exactly once — 314 and 1926 schedules, zero
/// violations (counts cross-checked against scripts/schedules_mirror.py).
#[test]
fn exhaustive_sharded_steal_no_loss_no_double_pop() {
    let m = StealModel::new(3, 2, 2, false);
    let a = explore(&m);
    assert_eq!(a.schedules, 314);
    assert_eq!(a.violated, 0);
    assert_eq!(a, explore(&m), "exhaustive exploration must be deterministic");
    let b = explore(&StealModel::new(4, 2, 2, false));
    assert_eq!(b.schedules, 1926);
    assert_eq!(b.violated, 0);
}

/// The racy steal (peek the victim's head, commit without re-checking)
/// must be caught: 4134 of 4722 schedules at 3 requests and 63549 of
/// 67909 at 4 requests double-serve a stolen request. This is exactly the
/// interleaving `ShardedQueue` closes by popping under the victim shard's
/// lock.
#[test]
fn exhaustive_racy_steal_is_caught() {
    let a = explore(&StealModel::new(3, 2, 2, true));
    assert_eq!(a.schedules, 4722);
    assert_eq!(a.violated, 4134);
    let b = explore(&StealModel::new(4, 2, 2, true));
    assert_eq!(b.schedules, 67_909);
    assert_eq!(b.violated, 63_549);
    assert!(a.violated < a.schedules && b.violated < b.schedules);
}

// ---------------------------------------------------------------------------
// Randomized tier: configurations too large to enumerate, driven by seeded
// walks. Two runs from the same seed must agree bit-for-bit.
// ---------------------------------------------------------------------------

/// 6 requests, 3 workers, batch cap 2, 2 replay attempts — far past the
/// exhaustive horizon. 3000 seeded walks, every one terminating cleanly
/// with exactly one reply per request.
#[test]
fn randomized_ledger_large_configuration() {
    let m = LedgerModel::new(6, 3, 2, 2);
    let a = random_walks(&m, 3000, 0xC0FF_EE00);
    assert_eq!(a.schedules, 3000);
    assert_eq!(a.violated, 0);
    let b = random_walks(&m, 3000, 0xC0FF_EE00);
    assert_eq!(a, b, "seeded walks must be deterministic");
    let c = random_walks(&m, 3000, 0xC0FF_EE01);
    assert_ne!(a.digest, c.digest, "a different seed must explore differently");
}

/// Random walks over a wider torn configuration (3 installers, 3 readers)
/// still catch the tear without exhaustive enumeration.
#[test]
fn randomized_torn_switch_finds_violations() {
    let a = random_walks(&TornSwitch::new(3, 3), 1000, 0xDECAF);
    assert_eq!(a.schedules, 1000);
    assert!(a.violated > 0, "random walks must surface the torn install");
}

/// The locked protocol stays clean under random scheduling of a bigger
/// thread set (3 installers x 2 sections, 3 readers x 2 sections).
#[test]
fn randomized_locked_switch_stays_clean() {
    let a = random_walks(&LockedSwitch::new(3, 3, 2), 1000, 0xBEEF);
    assert_eq!(a.schedules, 1000);
    assert_eq!(a.violated, 0);
}

/// Sharded steal queue past the exhaustive horizon: 6 requests over 3
/// shards with 3 workers, 2000 seeded walks, no loss and no double-pop.
#[test]
fn randomized_steal_large_configuration() {
    let m = StealModel::new(6, 3, 3, false);
    let a = random_walks(&m, 2000, 0x5EA1);
    assert_eq!(a.schedules, 2000);
    assert_eq!(a.violated, 0);
    let b = random_walks(&m, 2000, 0x5EA1);
    assert_eq!(a, b, "seeded walks must be deterministic");
}

/// Random walks over the racy steal still surface double-pops without
/// exhaustive enumeration.
#[test]
fn randomized_racy_steal_finds_double_pops() {
    let a = random_walks(&StealModel::new(6, 3, 3, true), 2000, 0xD05E);
    assert_eq!(a.schedules, 2000);
    assert!(a.violated > 0, "random walks must surface the stale commit");
}

// ---------------------------------------------------------------------------
// Stress tier: the real ShardedQueue through the public service API.
// ---------------------------------------------------------------------------

/// Minimal public-API model for the stress tier (input(1,1,16) →
/// dense(4)): `nn::testutil` is crate-private, so the integration test
/// builds its own graph the way the serving bench does.
fn stress_model() -> cvapprox::nn::Model {
    use cvapprox::nn::graph::Weights;
    use cvapprox::nn::{Model, Node, Op};
    let input = Node { out_shape: (1, 1, 16), ..Node::default() };
    let dense = Node {
        op: Op::Dense,
        inputs: vec![0],
        out_shape: (1, 1, 4),
        out_scale: 1.0e6,
        out_zp: 128,
        cout: 4,
        weights: Some(Weights {
            w_q: (0..4 * 16).map(|i| (i * 7 % 251) as u8).collect(),
            k_dim: 16,
            b_q: vec![0; 4],
            s_w: 1.0,
            zp_w: 3,
        }),
        ..Node::default()
    };
    Model { name: "steal-stress".into(), n_classes: 4, nodes: vec![input, dense] }
}

/// Multi-producer mixed-deadline stress over the real sharded queue:
/// 4 producer threads × 25 requests at shards = workers = 4, deadlines
/// cycling tight (200 µs, may expire) / generous (5 s) / none. Every
/// request gets exactly one reply — `Ok` (bit-identical to the exact
/// reference forward) or typed `Deadline`/`Overloaded` — and the pool
/// shuts down clean.
#[test]
fn stress_sharded_queue_multi_producer_mixed_deadlines() {
    use cvapprox::coordinator::{InferenceService, ReplyError, ServiceConfig};
    use cvapprox::nn::{Engine, ForwardOpts, Tensor};
    use std::time::{Duration, Instant};

    let model = stress_model();
    let reference = Engine::new(model.clone());
    let cfg = ServiceConfig {
        workers: 4,
        shards: 4,
        batch_size: 2,
        batch_timeout: Duration::from_millis(2),
        ..Default::default()
    };
    let svc = InferenceService::start(Engine::new(model), cfg).unwrap();
    let producers = 4usize;
    let per = 25usize;
    let mut ok = 0u64;
    let mut expired = 0u64;
    let mut overloaded = 0u64;
    let exact = ForwardOpts::default();
    let counts: Vec<(u64, u64, u64)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..producers)
            .map(|p| {
                let svc = &svc;
                let reference = &reference;
                let exact = &exact;
                s.spawn(move || {
                    let (mut ok, mut expired, mut overloaded) = (0u64, 0u64, 0u64);
                    for i in 0..per {
                        let seed = (p * per + i) as u8;
                        let img = Tensor::from_data(
                            1,
                            1,
                            16,
                            (0..16u8).map(|j| j.wrapping_mul(31).wrapping_add(seed)).collect(),
                        );
                        let deadline = match i % 3 {
                            0 => Some(Instant::now() + Duration::from_micros(200)),
                            1 => Some(Instant::now() + Duration::from_secs(5)),
                            _ => None,
                        };
                        match svc.try_submit(img.clone(), deadline) {
                            Ok(pending) => match pending.wait_reply() {
                                Ok(reply) => {
                                    let want = reference.forward(&img, exact).unwrap();
                                    assert_eq!(
                                        reply.logits, want,
                                        "producer {p} request {i}: stolen batch corrupted"
                                    );
                                    ok += 1;
                                }
                                Err(ReplyError::Deadline) => expired += 1,
                                Err(e) => panic!("producer {p} request {i}: {e}"),
                            },
                            // Admission reject is the request's one reply.
                            Err(ReplyError::Overloaded) => overloaded += 1,
                            Err(e) => panic!("producer {p} admission {i}: {e}"),
                        }
                    }
                    (ok, expired, overloaded)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for (o, e, v) in counts {
        ok += o;
        expired += e;
        overloaded += v;
    }
    assert_eq!(
        ok + expired + overloaded,
        (producers * per) as u64,
        "every request resolved exactly once"
    );
    assert!(ok > 0, "the pool must serve at least the generous/no-deadline mix");
    let snap = svc.shutdown();
    assert_eq!(snap.completed, ok);
    assert_eq!(snap.expired_deadline, expired);
}
