//! Golden-vector integration tests: the rust engine must reproduce the
//! python quantized reference **bit-exactly** (logits are dequantized from
//! identical uint8 outputs, so equality is exact, not approximate).

use cvapprox::artifacts_dir;
use cvapprox::datasets::{Dataset, Golden};
use cvapprox::nn::{loader, Engine, ForwardOpts, GemmKind};

fn have_artifacts() -> bool {
    artifacts_dir().join("golden").is_dir() && artifacts_dir().join("models").is_dir()
}

fn run_golden(g: &Golden, kind: GemmKind) -> Vec<f64> {
    let art = artifacts_dir();
    let model = loader::load_model(&art.join(format!("models/{}.cvm", g.model_name)))
        .expect("model loads");
    let ds_name = g.model_name.rsplit('_').next().unwrap();
    let ds = Dataset::load(&art.join(format!("data/{ds_name}_test.cvd"))).unwrap();
    let img = ds.image(g.img_index);
    let mut engine = Engine::new(model);
    let mut opts = ForwardOpts::approx(g.family, g.m, g.use_cv);
    opts.kind = kind;
    if kind == GemmKind::Lut {
        engine.prepare_lut(g.family, g.m);
    }
    engine.forward(&img, &opts).expect("forward runs")
}

#[test]
fn identity_engine_matches_python_reference_exactly() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let goldens = Golden::load_dir(&artifacts_dir().join("golden")).unwrap();
    assert!(goldens.len() >= 36);
    for g in &goldens {
        let got = run_golden(g, GemmKind::Identity);
        assert_eq!(
            got.len(),
            g.logits.len(),
            "{} {:?} m={} cv={}",
            g.model_name,
            g.family,
            g.m,
            g.use_cv
        );
        for (i, (a, b)) in got.iter().zip(&g.logits).enumerate() {
            assert!(
                (a - b).abs() < 1e-12,
                "{} {:?} m={} cv={} img={} logit[{i}]: rust {a} vs python {b}",
                g.model_name,
                g.family,
                g.m,
                g.use_cv,
                g.img_index
            );
        }
    }
}

#[test]
fn lut_engine_matches_python_reference_exactly() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let goldens = Golden::load_dir(&artifacts_dir().join("golden")).unwrap();
    // LUT path on the approximate subset (exact family has no LUT).
    for g in goldens.iter().filter(|g| g.family != cvapprox::approx::Family::Exact) {
        let got = run_golden(g, GemmKind::Lut);
        for (a, b) in got.iter().zip(&g.logits) {
            assert!(
                (a - b).abs() < 1e-12,
                "lut {} {:?} m={} cv={}: {a} vs {b}",
                g.model_name,
                g.family,
                g.m,
                g.use_cv
            );
        }
    }
}

#[test]
fn systolic_engine_matches_python_reference() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    // The cycle-level array on one golden per family (slower).
    let goldens = Golden::load_dir(&artifacts_dir().join("golden")).unwrap();
    let mut done = std::collections::BTreeSet::new();
    for g in &goldens {
        if g.model_name != "resnet8_synth10" || !done.insert((g.family.code(), g.use_cv)) {
            continue;
        }
        let art = artifacts_dir();
        let model =
            loader::load_model(&art.join(format!("models/{}.cvm", g.model_name))).unwrap();
        let ds = Dataset::load(&art.join("data/synth10_test.cvd")).unwrap();
        let img = ds.image(g.img_index);
        let mut engine = Engine::new(model);
        engine.prepare_systolic(g.family, g.m, 64);
        let opts = ForwardOpts::approx(g.family, g.m, g.use_cv);
        let (logits, stats) = engine.forward_systolic(&img, &opts).unwrap();
        for (a, b) in logits.iter().zip(&g.logits) {
            assert!(
                (a - b).abs() < 1e-12,
                "systolic {:?} m={} cv={}: {a} vs {b}",
                g.family,
                g.m,
                g.use_cv
            );
        }
        assert!(stats.cycles > 0);
    }
}
