//! Golden-vector integration tests: the rust engine must reproduce the
//! python quantized reference **bit-exactly** (logits are dequantized from
//! identical uint8 outputs, so equality is exact, not approximate).
//!
//! Two tiers:
//! * **Hermetic** (always runs — never skips, in CI too): the checked-in
//!   mini-artifacts under `rust/tests/hermetic/`, generated once from the
//!   python reference by `scripts/gen_hermetic_golden.py`. Covers the full
//!   (family, m, ±V) paper grid on a synthetic net through the identity,
//!   LUT, batched and systolic engines.
//! * **Artifact superset** (runs when `make artifacts` has been built):
//!   the six trained nets × 36+ golden vectors.

use std::path::Path;
use std::sync::Arc;

use cvapprox::approx::Family;
use cvapprox::datasets::{Dataset, Golden};
use cvapprox::nn::{loader, Engine, ForwardOpts, GemmKind, LayerPolicy, Tensor};
use cvapprox::util::json::Json;
use cvapprox::{artifacts_dir, hermetic_dir};

fn have_artifacts() -> bool {
    artifacts_dir().join("golden").is_dir() && artifacts_dir().join("models").is_dir()
}

/// Load the model + image a golden vector refers to, rooted at `root`
/// (the artifacts dir or the hermetic dir — same layout).
fn load_case(root: &Path, g: &Golden) -> (Engine, Tensor) {
    let model = loader::load_model(&root.join(format!("models/{}.cvm", g.model_name)))
        .expect("model loads");
    let ds_name = g.model_name.rsplit('_').next().unwrap();
    let ds = Dataset::load(&root.join(format!("data/{ds_name}_test.cvd"))).unwrap();
    let img = ds.image(g.img_index);
    (Engine::new(model), img)
}

fn run_golden(root: &Path, g: &Golden, kind: GemmKind) -> Vec<f64> {
    let (mut engine, img) = load_case(root, g);
    let mut opts = ForwardOpts::approx(g.family, g.m, g.use_cv);
    opts.kind = kind;
    if kind == GemmKind::Lut {
        engine.prepare_lut(g.family, g.m);
    }
    engine.forward(&img, &opts).expect("forward runs")
}

fn assert_logits_match(got: &[f64], g: &Golden, label: &str) {
    assert_eq!(
        got.len(),
        g.logits.len(),
        "{label} {} {:?} m={} cv={}",
        g.model_name,
        g.family,
        g.m,
        g.use_cv
    );
    for (i, (a, b)) in got.iter().zip(&g.logits).enumerate() {
        assert!(
            (a - b).abs() < 1e-12,
            "{label} {} {:?} m={} cv={} img={} logit[{i}]: rust {a} vs python {b}",
            g.model_name,
            g.family,
            g.m,
            g.use_cv,
            g.img_index
        );
    }
}

// ---------------------------------------------------------------------------
// Hermetic tier: always executes — a missing hermetic set is a FAILURE,
// not a skip (that silent skip was exactly the CI gap this suite closes).
// ---------------------------------------------------------------------------

fn hermetic_goldens() -> Vec<Golden> {
    let dir = hermetic_dir().join("golden");
    assert!(
        dir.is_dir(),
        "hermetic golden set missing at {} — regenerate with \
         scripts/gen_hermetic_golden.py",
        dir.display()
    );
    let goldens = Golden::load_dir(&dir).unwrap();
    assert!(
        goldens.len() >= 38,
        "hermetic set is incomplete: {} vectors",
        goldens.len()
    );
    // The grid must cover every family, both V modes, and 2 images.
    for fam in Family::ALL {
        assert!(goldens.iter().any(|g| g.family == fam), "{fam:?} missing");
    }
    assert!(goldens.iter().any(|g| g.use_cv));
    assert!(goldens.iter().any(|g| !g.use_cv && g.family != Family::Exact));
    goldens
}

#[test]
fn hermetic_identity_engine_matches_python_reference_exactly() {
    let root = hermetic_dir();
    for g in &hermetic_goldens() {
        let got = run_golden(&root, g, GemmKind::Identity);
        assert_logits_match(&got, g, "hermetic identity");
    }
}

#[test]
fn hermetic_lut_engine_matches_python_reference_exactly() {
    let root = hermetic_dir();
    for g in hermetic_goldens().iter().filter(|g| g.family != Family::Exact) {
        let got = run_golden(&root, g, GemmKind::Lut);
        assert_logits_match(&got, g, "hermetic lut");
    }
}

#[test]
fn hermetic_batched_forward_matches_python_reference_exactly() {
    // The batched serving path (one wide GEMM per layer) against the python
    // reference: for every (family, m, cv) config, fuse both golden images
    // into one batch and compare each reply to its golden vector.
    let root = hermetic_dir();
    let goldens = hermetic_goldens();
    let mut configs: Vec<(Family, u32, bool)> =
        goldens.iter().map(|g| (g.family, g.m, g.use_cv)).collect();
    configs.sort();
    configs.dedup();
    for (family, m, use_cv) in configs {
        let cases: Vec<&Golden> = goldens
            .iter()
            .filter(|g| (g.family, g.m, g.use_cv) == (family, m, use_cv))
            .collect();
        assert!(cases.len() >= 2, "{family:?} m={m} cv={use_cv}");
        let (engine, _) = load_case(&root, cases[0]);
        let ds = Dataset::load(&root.join("data/hsynth_test.cvd")).unwrap();
        let imgs: Vec<Tensor> = cases.iter().map(|g| ds.image(g.img_index)).collect();
        let refs: Vec<&Tensor> = imgs.iter().collect();
        let opts = ForwardOpts::approx(family, m, use_cv);
        let batched = engine.forward_batch(&refs, &opts).expect("batched forward");
        for (g, got) in cases.iter().zip(&batched) {
            assert_logits_match(got, g, "hermetic batched");
        }
    }
}

#[test]
fn hermetic_systolic_engine_matches_python_reference() {
    // The cycle-level array on one golden per (family, V) — slower, so a
    // subset; still hermetic and never skipped.
    let root = hermetic_dir();
    let mut done = std::collections::BTreeSet::new();
    for g in &hermetic_goldens() {
        if g.family == Family::Exact || !done.insert((g.family.code(), g.use_cv)) {
            continue;
        }
        let (mut engine, img) = load_case(&root, g);
        engine.prepare_systolic(g.family, g.m, 64);
        let opts = ForwardOpts::approx(g.family, g.m, g.use_cv);
        let (logits, stats) = engine.forward_systolic(&img, &opts).unwrap();
        assert_logits_match(&logits, g, "hermetic systolic");
        assert!(stats.cycles > 0);
    }
}

// ---------------------------------------------------------------------------
// Hermetic paired tier: positive/negative polarity + even/odd pairings,
// against the python mirror (scripts/gen_hermetic_golden.py). JSON sidecars
// because the .gv format encodes only a uniform (family, m, cv) triple.
// ---------------------------------------------------------------------------

struct PairedGolden {
    name: String,
    img_index: usize,
    policy: LayerPolicy,
    logits: Vec<f64>,
}

fn hermetic_paired_goldens() -> Vec<PairedGolden> {
    let dir = hermetic_dir().join("golden_paired");
    assert!(
        dir.is_dir(),
        "hermetic paired golden set missing at {} — regenerate with \
         scripts/gen_hermetic_golden.py",
        dir.display()
    );
    let mut entries: Vec<_> = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .filter(|e| e.path().extension().map(|x| x == "json").unwrap_or(false))
        .collect();
    entries.sort_by_key(|e| e.file_name());
    let goldens: Vec<PairedGolden> = entries
        .iter()
        .map(|e| {
            let text = std::fs::read_to_string(e.path()).unwrap();
            let j = Json::parse(&text).expect("paired golden JSON parses");
            let model = j.get("model").and_then(|v| v.as_str()).unwrap();
            assert_eq!(model, "hermnet_hsynth");
            let img_index =
                j.get("img_index").and_then(|v| v.as_f64()).unwrap() as usize;
            let policy = LayerPolicy::from_json(j.get("policy").unwrap())
                .expect("paired policy document parses");
            let logits: Vec<f64> = j
                .get("logits")
                .and_then(|v| v.as_arr())
                .unwrap()
                .iter()
                .map(|v| v.as_f64().unwrap())
                .collect();
            PairedGolden {
                name: e.file_name().to_string_lossy().into_owned(),
                img_index,
                policy,
                logits,
            }
        })
        .collect();
    assert!(goldens.len() >= 10, "paired set incomplete: {}", goldens.len());
    // The set must exercise pairings AND uniform positive polarity.
    assert!(goldens.iter().any(|g| g.policy.paired_layers() > 0));
    assert!(goldens
        .iter()
        .any(|g| g.policy.paired_layers() == 0 && g.policy.approx_layers() > 0));
    goldens
}

#[test]
fn hermetic_paired_policies_match_python_reference_exactly() {
    // Identity engine, prepared-LUT engine and the batched path must all
    // reproduce the python paired/polarity reference bit for bit.
    let root = hermetic_dir();
    let model = loader::load_model(&root.join("models/hermnet_hsynth.cvm")).unwrap();
    let ds = Dataset::load(&root.join("data/hsynth_test.cvd")).unwrap();
    for g in &hermetic_paired_goldens() {
        let policy = Arc::new(g.policy.clone());
        let opts = ForwardOpts::with_policy(policy.clone());
        let img = ds.image(g.img_index);
        let engine = Engine::new(model.clone());
        let ident = engine.forward(&img, &opts).expect("paired forward");
        assert_eq!(ident.len(), g.logits.len(), "{}", g.name);
        for (i, (a, b)) in ident.iter().zip(&g.logits).enumerate() {
            assert!(
                (a - b).abs() < 1e-12,
                "{} logit[{i}]: rust {a} vs python {b}",
                g.name
            );
        }
        let mut e_lut = Engine::new(model.clone());
        e_lut.prepare_luts_for_policy(&policy);
        assert_eq!(e_lut.forward(&img, &opts).unwrap(), ident, "{} lut", g.name);
        let batched = engine.forward_batch(&[&img], &opts).unwrap();
        assert_eq!(batched[0], ident, "{} batched", g.name);
    }
}

// ---------------------------------------------------------------------------
// Artifact superset tier: the trained nets, when `make artifacts` exists.
// ---------------------------------------------------------------------------

#[test]
fn identity_engine_matches_python_reference_exactly() {
    if !have_artifacts() {
        eprintln!("skipping artifact superset (hermetic tier still ran): run `make artifacts`");
        return;
    }
    let goldens = Golden::load_dir(&artifacts_dir().join("golden")).unwrap();
    assert!(goldens.len() >= 36);
    let root = artifacts_dir();
    for g in &goldens {
        let got = run_golden(&root, g, GemmKind::Identity);
        assert_logits_match(&got, g, "identity");
    }
}

#[test]
fn lut_engine_matches_python_reference_exactly() {
    if !have_artifacts() {
        eprintln!("skipping artifact superset (hermetic tier still ran): run `make artifacts`");
        return;
    }
    let goldens = Golden::load_dir(&artifacts_dir().join("golden")).unwrap();
    let root = artifacts_dir();
    // LUT path on the approximate subset (exact family has no LUT).
    for g in goldens.iter().filter(|g| g.family != Family::Exact) {
        let got = run_golden(&root, g, GemmKind::Lut);
        assert_logits_match(&got, g, "lut");
    }
}

#[test]
fn systolic_engine_matches_python_reference() {
    if !have_artifacts() {
        eprintln!("skipping artifact superset (hermetic tier still ran): run `make artifacts`");
        return;
    }
    // The cycle-level array on one golden per family (slower).
    let goldens = Golden::load_dir(&artifacts_dir().join("golden")).unwrap();
    let root = artifacts_dir();
    let mut done = std::collections::BTreeSet::new();
    for g in &goldens {
        if g.model_name != "resnet8_synth10" || !done.insert((g.family.code(), g.use_cv)) {
            continue;
        }
        let (mut engine, img) = load_case(&root, g);
        engine.prepare_systolic(g.family, g.m, 64);
        let opts = ForwardOpts::approx(g.family, g.m, g.use_cv);
        let (logits, stats) = engine.forward_systolic(&img, &opts).unwrap();
        assert_logits_match(&logits, g, "systolic");
        assert!(stats.cycles > 0);
    }
}
