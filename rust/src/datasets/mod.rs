//! Loaders for the synth10/synth100 dataset binaries (.cvd) and the golden
//! inference vectors (.gv) exported by `make artifacts`
//! (format spec: python/compile/export.py docstring).

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::approx::Family;
use crate::nn::Tensor;
use crate::util::io::ByteReader;

/// A quantized image dataset.
pub struct Dataset {
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    /// Input quantization (dequant: real = scale * (q - zp)).
    pub scale: f32,
    pub zero_point: i32,
    images: Vec<u8>,
    pub labels: Vec<u16>,
}

impl Dataset {
    pub fn load(path: &Path) -> Result<Dataset> {
        let buf = std::fs::read(path)
            .with_context(|| format!("reading dataset {}", path.display()))?;
        Self::parse(&buf).with_context(|| format!("parsing {}", path.display()))
    }

    pub fn parse(buf: &[u8]) -> Result<Dataset> {
        let mut r = ByteReader::new(buf);
        r.magic(b"CVD1")?;
        let n = r.u32()? as usize;
        let h = r.u32()? as usize;
        let w = r.u32()? as usize;
        let c = r.u32()? as usize;
        if n * h * w * c == 0 || n > 1_000_000 {
            bail!("implausible dataset dims {n}x{h}x{w}x{c}");
        }
        let scale = r.f32()?;
        let zero_point = r.i32()?;
        let images = r.bytes(n * h * w * c)?;
        let labels = r.vec_u16(n)?;
        if r.remaining() != 0 {
            bail!("{} trailing bytes", r.remaining());
        }
        Ok(Dataset { n, h, w, c, scale, zero_point, images, labels })
    }

    /// Image `i` as a tensor (borrows copy).
    pub fn image(&self, i: usize) -> Tensor {
        let sz = self.h * self.w * self.c;
        Tensor::from_data(self.h, self.w, self.c, self.images[i * sz..(i + 1) * sz].to_vec())
    }

    pub fn label(&self, i: usize) -> usize {
        self.labels[i] as usize
    }

    /// Number of distinct classes present.
    pub fn n_classes(&self) -> usize {
        self.labels.iter().copied().max().map(|m| m as usize + 1).unwrap_or(0)
    }
}

/// One golden inference vector (python reference logits).
#[derive(Clone, Debug)]
pub struct Golden {
    pub model_name: String,
    pub family: Family,
    pub m: u32,
    pub use_cv: bool,
    pub img_index: usize,
    pub logits: Vec<f64>,
}

impl Golden {
    pub fn load(path: &Path) -> Result<Golden> {
        let buf = std::fs::read(path)
            .with_context(|| format!("reading golden {}", path.display()))?;
        let mut r = ByteReader::new(&buf);
        r.magic(b"CVG1")?;
        let model_name = r.string()?;
        let family = Family::from_code(r.u8()?).context("bad family code")?;
        let m = r.u8()? as u32;
        let use_cv = r.u8()? != 0;
        let img_index = r.u32()? as usize;
        let n = r.u32()? as usize;
        let logits = r.vec_f64(n)?;
        Ok(Golden { model_name, family, m, use_cv, img_index, logits })
    }

    /// All golden vectors in a directory.
    pub fn load_dir(dir: &Path) -> Result<Vec<Golden>> {
        let mut out = Vec::new();
        let mut entries: Vec<_> = std::fs::read_dir(dir)?.filter_map(|e| e.ok()).collect();
        entries.sort_by_key(|e| e.file_name());
        for e in entries {
            if e.path().extension().map(|x| x == "gv").unwrap_or(false) {
                out.push(Golden::load(&e.path())?);
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::artifacts_dir;

    #[test]
    fn loads_exported_datasets() {
        let dir = artifacts_dir().join("data");
        if !dir.is_dir() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        for name in ["synth10_test", "synth100_test", "synth10_calib"] {
            let ds = Dataset::load(&dir.join(format!("{name}.cvd"))).unwrap();
            assert_eq!((ds.h, ds.w, ds.c), (32, 32, 3), "{name}");
            assert!(ds.n >= 256);
            assert_eq!(ds.labels.len(), ds.n);
            let img = ds.image(0);
            assert_eq!(img.data.len(), 32 * 32 * 3);
            // balanced-ish labels
            let classes = ds.n_classes();
            assert!(classes == 10 || classes == 100, "{name}: {classes}");
        }
    }

    #[test]
    fn loads_golden_vectors() {
        let dir = artifacts_dir().join("golden");
        if !dir.is_dir() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let gs = Golden::load_dir(&dir).unwrap();
        assert!(gs.len() >= 36, "{}", gs.len());
        assert!(gs.iter().any(|g| g.use_cv));
        assert!(gs.iter().any(|g| g.family == Family::Truncated));
        for g in &gs {
            assert!(!g.logits.is_empty());
            assert!(g.logits.iter().all(|x| x.is_finite()));
        }
    }

    #[test]
    fn parse_rejects_bad_magic() {
        assert!(Dataset::parse(b"NOPE").is_err());
    }
}
