//! Adaptive QoS: runtime policy ladders with hot-swap serving.
//!
//! The paper operates under a *tight accuracy-loss constraint* while
//! maximizing power savings — but a static deployment has to pick one
//! operating point at start-up and keep it whether the pool is drowning or
//! idle. This subsystem makes the approximation level a **governed runtime
//! quantity**, the DVFS analogy applied to approximation instead of
//! frequency:
//!
//! * [`ladder`] — an ordered, validated vector of named operating points
//!   (exact → greedy mixed → greedy paired → aggressive uniform), each a
//!   per-layer [`crate::nn::LayerPolicy`] tagged with offline-estimated
//!   loss and MAC-weighted normalized power; JSON artifact via
//!   `cvapprox qos-ladder`.
//! * [`telemetry`] — lock-light (all-atomic) serving signals, drained per
//!   decision window: latency percentiles over the window's completions,
//!   queue depth, batch occupancy, a live in-flight gauge, and the
//!   per-layer CV-magnitude error proxy (mean |V|/|G*| sampled from the
//!   epilogue — free, because V is already computed there).
//! * [`governor`] — the hysteresis controller thread that walks the ladder
//!   (step down under load within a loss bound, step back up when idle or
//!   when the measured error proxy crosses its ceiling) and installs rungs
//!   into the live pool through an epoch-stamped atomic policy swap — no
//!   drain, no stall, every reply attributable to exactly one rung.

pub mod governor;
pub mod ladder;
pub mod telemetry;

pub use governor::{Governor, GovernorReport, QosConfig, Transition};
pub use ladder::{Ladder, Rung};
pub use telemetry::{Telemetry, TelemetryWindow};
