//! Lock-light serving telemetry for the adaptive governors.
//!
//! A governor needs four live signals — queue depth, batch occupancy,
//! tail latency, and the CV-magnitude error proxy — sampled on the serving
//! hot path without adding a contended lock to it. Everything here is
//! atomics: workers `fetch_add` counters and overwrite a fixed ring of
//! recent latency samples; governor threads drain windows with `swap(0)`.
//! The only non-O(1) work is in the drain calls, which the governor pays,
//! not the pool.
//!
//! Every signal is **drain-on-read**: each drain covers exactly what
//! accumulated since the previous drain — including the latency
//! percentiles, which are computed over the samples recorded in the window
//! (capped at the ring size; a window that overflows the ring keeps its
//! most recent `window` samples). Stale burst latencies therefore cannot
//! leak into later decisions and pin a governor at a wrong rung — the
//! latency ring's head and slots use Release/Acquire so the drain actually
//! observes the stores behind the head it reads (see `record_latency`);
//! the commutative sums stay Relaxed because a sample landing on a window
//! boundary counts in one window or the next, never corrupts.
//!
//! **Poller contract (partitioned per class).** One `Telemetry` instance
//! serves a whole multi-tenant pool, but its counters are *partitioned by
//! tenant class*: workers record into the class a batch belongs to, and
//! each class's governor drains only its own partition via
//! [`Telemetry::window_for`]. N governors are therefore N single-pollers
//! over disjoint state — the "one poller assumed" caveat of the original
//! single-window design no longer stacks up with tenant count. The
//! un-suffixed [`Telemetry::window`] is the single-tenant convenience: it
//! drains and merges *every* class, so a deployment must use either one
//! global `window()` poller or one `window_for(c)` poller per class,
//! never both at once.
//!
//! The `in_flight` gauge is the exception to drain-on-read: it is a live
//! level, not a window aggregate — requests popped into executing batches
//! are invisible to both the queue depth and the completion count, and
//! without this gauge a saturated pool whose batches outlast a whole
//! window would be indistinguishable from an idle one.
//!
//! **Deadline-expired requests** are counted consistently (the PR 9
//! bugfix): a request screened out at dequeue because its deadline passed
//! executed no work, so it must not appear in the occupancy numerator *or*
//! inflate the batch denominator — a pop whose requests all expired
//! contributes **no** occupancy sample (it was never an executed batch)
//! while its queue-depth observation is still recorded via
//! [`Telemetry::record_depth_for`] (a deadline storm must not blind the
//! depth signal), and the drop itself lands in [`TelemetryWindow::expired`]
//! so governors see deadline pressure directly.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::nn::CvProxySampler;

/// Default sliding-window size for the latency percentile ring.
pub const DEFAULT_WINDOW: usize = 1024;

/// One tenant class's partition of the telemetry plane. Field names and
/// orderings are identical to the pre-sharding single-window design; the
/// atomics contract (srclint R2) applies per field across all cells.
#[derive(Debug)]
struct ClassCell {
    /// Ring of recent per-request latencies in µs (0 = never written).
    lat_us: Vec<AtomicU64>,
    /// Total latency samples ever pushed (ring slot = head % len).
    head: AtomicU64,
    /// `head` at the last drain (completion-rate bookkeeping).
    drained_head: AtomicU64,
    /// Σ queue depth observed at batch pop / number of observations.
    depth_sum: AtomicU64,
    depth_n: AtomicU64,
    /// Σ batch occupancy (executed requests / batch capacity) in per-mille.
    occ_pm_sum: AtomicU64,
    occ_n: AtomicU64,
    /// Deadline-expired requests dropped at dequeue (window counter).
    expired: AtomicU64,
    /// Requests currently inside executing batches (live level gauge).
    inflight: AtomicU64,
    /// Per-layer CV-magnitude error proxy for this class. Workers run each
    /// batch with a *batch-local* [`CvProxySampler`] so the fault plane can
    /// band-check that batch's raw sums in isolation
    /// (`fault::IntegrityMonitor`), then re-record the trusted sums here —
    /// keeping the governor's drain-on-read windows intact and untainted by
    /// batches that were rolled back and replayed after corruption.
    cv: Arc<CvProxySampler>,
}

impl ClassCell {
    fn new(window: usize, mac_layers: usize) -> ClassCell {
        ClassCell {
            lat_us: (0..window.max(1)).map(|_| AtomicU64::new(0)).collect(),
            head: AtomicU64::new(0),
            drained_head: AtomicU64::new(0),
            depth_sum: AtomicU64::new(0),
            depth_n: AtomicU64::new(0),
            occ_pm_sum: AtomicU64::new(0),
            occ_n: AtomicU64::new(0),
            expired: AtomicU64::new(0),
            inflight: AtomicU64::new(0),
            cv: Arc::new(CvProxySampler::new(mac_layers)),
        }
    }

    /// Drain this cell's window into raw parts (latency samples in µs,
    /// counters, and raw CV sums) so the caller can either report them
    /// directly or merge several cells into one aggregate window.
    fn drain_parts(&self) -> CellParts {
        let head = self.head.load(Ordering::Acquire);
        let prev = self.drained_head.swap(head, Ordering::Relaxed);
        let cap = self.lat_us.len() as u64;
        let take = head.saturating_sub(prev).min(cap);
        let lats: Vec<u64> = (head - take..head)
            .map(|j| self.lat_us[(j % cap) as usize].load(Ordering::Acquire))
            .filter(|&v| v > 0)
            .collect();
        CellParts {
            completions: head.saturating_sub(prev),
            lats,
            depth_sum: self.depth_sum.swap(0, Ordering::Relaxed),
            depth_n: self.depth_n.swap(0, Ordering::Relaxed),
            occ_pm_sum: self.occ_pm_sum.swap(0, Ordering::Relaxed),
            occ_n: self.occ_n.swap(0, Ordering::Relaxed),
            expired: self.expired.swap(0, Ordering::Relaxed),
            cv_raw: self.cv.drain_raw(),
        }
    }
}

/// Raw drained counters for one class (pre-percentile).
struct CellParts {
    completions: u64,
    lats: Vec<u64>,
    depth_sum: u64,
    depth_n: u64,
    occ_pm_sum: u64,
    occ_n: u64,
    expired: u64,
    cv_raw: Vec<(u64, u64, u64)>,
}

/// Shared serving telemetry: one instance per
/// [`crate::coordinator::InferenceService`], recorded into by every pool
/// worker, partitioned by tenant class, drained by per-class governors.
#[derive(Debug)]
pub struct Telemetry {
    classes: Vec<ClassCell>,
}

/// One drained telemetry window (a single class, or every class merged).
#[derive(Clone, Debug)]
pub struct TelemetryWindow {
    /// Requests completed since the previous drain.
    pub completions: u64,
    /// Batches *executed* since the previous drain. A pop whose requests
    /// all expired at the deadline screen is not an executed batch and
    /// does not count here (nor in the occupancy mean's denominator).
    pub batches: u64,
    /// Latency percentiles over THIS window's completions (up to the ring
    /// size; zero when nothing completed in the window).
    pub p50: Duration,
    pub p95: Duration,
    /// Mean queue depth observed at batch pop since the previous drain.
    /// Includes pops that went on to expire wholesale — queue pressure is
    /// real whether or not the work was ultimately executed.
    pub mean_queue_depth: f64,
    /// Mean batch occupancy (0..1) over *executed* batches since the
    /// previous drain; deadline-expired requests never contribute.
    pub mean_batch_occupancy: f64,
    /// Requests dropped at dequeue because their deadline had passed.
    pub expired: u64,
    /// Pooled CV error proxy Σ|V| / Σ|G*| since the previous drain.
    pub cv_proxy: f64,
    /// Per-MAC-layer error proxy (0 for layers that recorded nothing).
    pub cv_proxy_per_layer: Vec<f64>,
    /// Epilogue entries the proxy averaged over.
    pub cv_samples: u64,
}

fn window_from_parts(parts: Vec<CellParts>, mac_layers: usize) -> TelemetryWindow {
    let mut completions = 0u64;
    let mut lats: Vec<u64> = Vec::new();
    let (mut depth_sum, mut depth_n) = (0u64, 0u64);
    let (mut occ_pm, mut occ_n) = (0u64, 0u64);
    let mut expired = 0u64;
    let mut cv_raw = vec![(0u64, 0u64, 0u64); mac_layers];
    for p in parts {
        completions += p.completions;
        lats.extend(p.lats);
        depth_sum += p.depth_sum;
        depth_n += p.depth_n;
        occ_pm += p.occ_pm_sum;
        occ_n += p.occ_n;
        expired += p.expired;
        for (acc, raw) in cv_raw.iter_mut().zip(p.cv_raw) {
            acc.0 += raw.0;
            acc.1 += raw.1;
            acc.2 += raw.2;
        }
    }
    lats.sort_unstable();
    let pick = |q: f64| -> Duration {
        if lats.is_empty() {
            Duration::ZERO
        } else {
            let idx = ((lats.len() - 1) as f64 * q).round() as usize;
            Duration::from_micros(lats[idx])
        }
    };
    let (p50, p95) = (pick(0.50), pick(0.95));
    let (mut tn, mut td, mut ts) = (0u64, 0u64, 0u64);
    let per_layer: Vec<f64> = cv_raw
        .iter()
        .map(|&(num, den, n)| {
            tn += num;
            td += den;
            ts += n;
            if den > 0 { num as f64 / den as f64 } else { 0.0 }
        })
        .collect();
    TelemetryWindow {
        completions,
        batches: occ_n,
        p50,
        p95,
        mean_queue_depth: if depth_n > 0 {
            depth_sum as f64 / depth_n as f64
        } else {
            0.0
        },
        mean_batch_occupancy: if occ_n > 0 {
            occ_pm as f64 / (1000.0 * occ_n as f64)
        } else {
            0.0
        },
        expired,
        cv_proxy: if td > 0 { tn as f64 / td as f64 } else { 0.0 },
        cv_proxy_per_layer: per_layer,
        cv_samples: ts,
    }
}

impl Telemetry {
    /// Single-class telemetry for a model with `mac_layers` MAC layers,
    /// default window.
    pub fn new(mac_layers: usize) -> Telemetry {
        Telemetry::with_window(DEFAULT_WINDOW, mac_layers)
    }

    /// Single-class with an explicit ring size (tests shrink it to
    /// exercise wraparound).
    pub fn with_window(window: usize, mac_layers: usize) -> Telemetry {
        Telemetry::with_classes(1, window, mac_layers)
    }

    /// Telemetry partitioned into `classes` tenant classes, each with its
    /// own latency ring, counters, and CV sampler.
    pub fn with_classes(classes: usize, window: usize, mac_layers: usize) -> Telemetry {
        Telemetry {
            classes: (0..classes.max(1))
                .map(|_| ClassCell::new(window, mac_layers))
                .collect(),
        }
    }

    /// Number of tenant-class partitions.
    pub fn n_classes(&self) -> usize {
        self.classes.len()
    }

    fn cell(&self, class: usize) -> &ClassCell {
        // Out-of-range classes fold to class 0 rather than panicking on
        // the hot path; the service validates class ids at admission.
        self.classes.get(class).unwrap_or(&self.classes[0])
    }

    /// Class 0's error-proxy sampler (single-tenant convenience).
    pub fn cv_sampler(&self) -> Arc<CvProxySampler> {
        self.cv_sampler_for(0)
    }

    /// Class `class`'s error-proxy sampler (workers attach it to
    /// `ForwardOpts::cv_proxy`).
    pub fn cv_sampler_for(&self, class: usize) -> Arc<CvProxySampler> {
        self.cell(class).cv.clone()
    }

    /// Merge one batch's raw proxy sums (`(Σ|V|, Σ|G*|, n)` per layer, from
    /// `CvProxySampler::drain_raw`) into class 0's sampler.
    pub fn record_cv(&self, raw: &[(u64, u64, u64)]) {
        self.record_cv_for(0, raw);
    }

    /// Merge one batch's raw proxy sums into class `class`'s sampler.
    /// Workers call this only after the batch passed integrity checks.
    pub fn record_cv_for(&self, class: usize, raw: &[(u64, u64, u64)]) {
        let cv = &self.cell(class).cv;
        for (i, &(num, den, n)) in raw.iter().enumerate() {
            if n > 0 {
                cv.record(i, num, den, n);
            }
        }
    }

    /// Record one completed class-0 request's end-to-end latency.
    pub fn record_latency(&self, d: Duration) {
        self.record_latency_for(0, d);
    }

    /// Record one completed request's end-to-end latency for `class`.
    ///
    /// Publication order matters here: each Release fetch_add on `head`
    /// joins a release sequence, so the Acquire load in the drain makes
    /// every slot store from *earlier* increments visible. The one store
    /// that can still be in flight per worker is its own latest sample —
    /// bounded staleness, versus the unbounded leak an all-Relaxed scheme
    /// allows (head advanced, slots still stale).
    pub fn record_latency_for(&self, class: usize, d: Duration) {
        let cell = self.cell(class);
        let us = (d.as_secs_f64() * 1e6).round().max(1.0) as u64;
        let slot = cell.head.fetch_add(1, Ordering::Release) as usize % cell.lat_us.len();
        cell.lat_us[slot].store(us, Ordering::Release);
    }

    /// A worker is about to run a class-0 batch of `requests`.
    pub fn batch_started(&self, requests: usize) {
        self.batch_started_for(0, requests);
    }

    /// A worker is about to run a class-`class` batch of `requests`: raise
    /// the in-flight level ([`Telemetry::record_batch_for`] lowers it when
    /// the batch lands).
    pub fn batch_started_for(&self, class: usize, requests: usize) {
        self.cell(class)
            .inflight
            .fetch_add(requests as u64, Ordering::Relaxed);
    }

    /// Record one executed class-0 batch (single-tenant convenience).
    pub fn record_batch(&self, requests: usize, cap: usize, queue_depth: usize) {
        self.record_batch_for(0, requests, cap, queue_depth);
    }

    /// Record one *executed* batch for `class`: how many requests actually
    /// ran (of `cap` possible) and the queue depth left behind at pop
    /// time. Deadline-expired requests screened out before execution must
    /// not be in `executed` — report them via
    /// [`Telemetry::record_expired_for`] instead, and report an
    /// all-expired pop's depth via [`Telemetry::record_depth_for`] so the
    /// occupancy mean's denominator only ever counts executed batches.
    pub fn record_batch_for(&self, class: usize, executed: usize, cap: usize, queue_depth: usize) {
        let cell = self.cell(class);
        // Saturating decrement: a record_batch without a matching
        // batch_started (unit tests drive them independently) must not
        // wrap the gauge.
        let _ = cell.inflight.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
            Some(v.saturating_sub(executed as u64))
        });
        cell.depth_sum.fetch_add(queue_depth as u64, Ordering::Relaxed);
        cell.depth_n.fetch_add(1, Ordering::Relaxed);
        if executed > 0 {
            let pm = (1000 * executed / cap.max(1)).min(1000) as u64;
            cell.occ_pm_sum.fetch_add(pm, Ordering::Relaxed);
            cell.occ_n.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Record a queue-depth observation for a pop that executed nothing
    /// (every popped request expired at the deadline screen).
    pub fn record_depth_for(&self, class: usize, queue_depth: usize) {
        let cell = self.cell(class);
        cell.depth_sum.fetch_add(queue_depth as u64, Ordering::Relaxed);
        cell.depth_n.fetch_add(1, Ordering::Relaxed);
    }

    /// Count `n` requests dropped at dequeue because their deadline had
    /// already passed.
    pub fn record_expired_for(&self, class: usize, n: usize) {
        self.cell(class).expired.fetch_add(n as u64, Ordering::Relaxed);
    }

    /// Requests currently inside executing batches, summed over classes
    /// (live level, not a window aggregate).
    pub fn in_flight(&self) -> u64 {
        self.classes
            .iter()
            .map(|c| c.inflight.load(Ordering::Relaxed))
            .sum()
    }

    /// Requests of `class` currently inside executing batches.
    pub fn in_flight_for(&self, class: usize) -> u64 {
        self.cell(class).inflight.load(Ordering::Relaxed)
    }

    /// Drain **every** class's window and merge (single-tenant
    /// convenience; percentiles are computed over the merged samples).
    /// Must not race [`Telemetry::window_for`] pollers — a deployment uses
    /// one global poller or one per class, never both.
    pub fn window(&self) -> TelemetryWindow {
        let mac_layers = self.classes[0].cv.layers();
        let parts = self.classes.iter().map(|c| c.drain_parts()).collect();
        window_from_parts(parts, mac_layers)
    }

    /// Drain the window accumulated for `class` since its last drain:
    /// depth, occupancy, expired count, error proxy, completion count, AND
    /// the latency percentiles — which cover only the samples recorded in
    /// this window (most recent ring-size samples when the window
    /// overflowed the ring), so a past burst's tail cannot haunt later
    /// decisions. Partitioned: concurrent pollers on *different* classes
    /// never split each other's windows.
    pub fn window_for(&self, class: usize) -> TelemetryWindow {
        let cell = self.cell(class);
        let mac_layers = cell.cv.layers();
        window_from_parts(vec![cell.drain_parts()], mac_layers)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_drains_latency_and_counters() {
        let t = Telemetry::with_window(8, 2);
        for ms in [1u64, 2, 3, 4] {
            t.record_latency(Duration::from_millis(ms));
        }
        t.record_batch(4, 8, 10);
        t.record_batch(8, 8, 0);
        let w = t.window();
        assert_eq!(w.completions, 4);
        assert_eq!(w.batches, 2);
        assert_eq!(w.p50, Duration::from_millis(3)); // rank rounding picks idx 2
        assert_eq!(w.p95, Duration::from_millis(4));
        assert!((w.mean_queue_depth - 5.0).abs() < 1e-9);
        assert!((w.mean_batch_occupancy - 0.75).abs() < 1e-3);
        // Everything drains — including the latency percentiles: a stale
        // burst must not haunt the next decision window.
        let w2 = t.window();
        assert_eq!(w2.completions, 0);
        assert_eq!(w2.batches, 0);
        assert_eq!(w2.mean_queue_depth, 0.0);
        assert_eq!(w2.p95, Duration::ZERO, "p95 is per-window, not a sliding ring");
        // A window that overflows the 8-slot ring keeps its most recent
        // samples: 4 slow then 16 fast ones — the slow tail is gone.
        for _ in 0..4 {
            t.record_latency(Duration::from_millis(500));
        }
        for _ in 0..16 {
            t.record_latency(Duration::from_millis(100));
        }
        let w3 = t.window();
        assert_eq!(w3.completions, 20);
        assert_eq!(w3.p50, Duration::from_millis(100));
        assert_eq!(w3.p95, Duration::from_millis(100));
    }

    #[test]
    fn expired_requests_do_not_inflate_occupancy() {
        // The PR 9 accounting bugfix, pinned: deadline-expired requests are
        // dropped from a popped batch before execution, so they must not
        // count as executed batches (occupancy denominator) or occupancy
        // numerator — while their depth observation and expired count are
        // still visible in the window.
        let t = Telemetry::with_window(8, 1);
        // One clean batch: 4 of 8 slots, depth 6 behind it.
        t.record_batch_for(0, 4, 8, 6);
        // One pop where every request expired: no executed batch, but the
        // depth observation (2) and the expired count (3) must land.
        t.record_expired_for(0, 3);
        t.record_depth_for(0, 2);
        let w = t.window_for(0);
        assert_eq!(w.batches, 1, "all-expired pop is not an executed batch");
        assert!(
            (w.mean_batch_occupancy - 0.5).abs() < 1e-3,
            "occupancy counts the executed batch only, got {}",
            w.mean_batch_occupancy
        );
        assert_eq!(w.expired, 3);
        assert!((w.mean_queue_depth - 4.0).abs() < 1e-9, "both depth obs count");
        // Drained: the next window is clean.
        let w2 = t.window_for(0);
        assert_eq!(w2.expired, 0);
        assert_eq!(w2.batches, 0);
    }

    #[test]
    fn class_windows_are_partitioned() {
        // Two tenant classes, one Telemetry plane: each class's poller
        // sees only its own traffic, and polling one class does not drain
        // the other (the N-governor contract).
        let t = Telemetry::with_classes(2, 8, 2);
        t.record_latency_for(0, Duration::from_millis(2));
        t.record_latency_for(1, Duration::from_millis(40));
        t.record_batch_for(0, 2, 4, 1);
        t.record_batch_for(1, 4, 4, 9);
        t.cv_sampler_for(1).record(0, 30, 100, 4);
        let w0 = t.window_for(0);
        assert_eq!(w0.completions, 1);
        assert_eq!(w0.p95, Duration::from_millis(2));
        assert!((w0.mean_batch_occupancy - 0.5).abs() < 1e-3);
        assert_eq!(w0.cv_samples, 0);
        // Class 1 is untouched by class 0's drain.
        let w1 = t.window_for(1);
        assert_eq!(w1.completions, 1);
        assert_eq!(w1.p95, Duration::from_millis(40));
        assert!((w1.mean_queue_depth - 9.0).abs() < 1e-9);
        assert!((w1.cv_proxy - 0.3).abs() < 1e-12);
        assert_eq!(t.window_for(1).completions, 0, "drained");
    }

    #[test]
    fn merged_window_spans_all_classes() {
        let t = Telemetry::with_classes(2, 8, 1);
        t.record_latency_for(0, Duration::from_millis(1));
        t.record_latency_for(1, Duration::from_millis(3));
        t.record_batch_for(0, 1, 2, 0);
        t.record_batch_for(1, 2, 2, 4);
        t.cv_sampler_for(0).record(0, 10, 100, 2);
        t.cv_sampler_for(1).record(0, 30, 100, 2);
        let w = t.window();
        assert_eq!(w.completions, 2);
        assert_eq!(w.batches, 2);
        assert_eq!(w.p95, Duration::from_millis(3));
        assert!((w.mean_batch_occupancy - 0.75).abs() < 1e-3);
        assert!((w.cv_proxy - 40.0 / 200.0).abs() < 1e-12);
        assert_eq!(w.cv_samples, 4);
        // The merge drained every class.
        assert_eq!(t.window_for(0).completions, 0);
        assert_eq!(t.window_for(1).completions, 0);
    }

    #[test]
    fn in_flight_gauge_tracks_executing_batches() {
        let t = Telemetry::with_window(8, 1);
        assert_eq!(t.in_flight(), 0);
        t.batch_started(6);
        t.batch_started(2);
        assert_eq!(t.in_flight(), 8);
        t.record_batch(6, 8, 0);
        assert_eq!(t.in_flight(), 2);
        t.record_batch(2, 8, 0);
        assert_eq!(t.in_flight(), 0);
        // Unmatched record_batch saturates instead of wrapping.
        t.record_batch(4, 8, 0);
        assert_eq!(t.in_flight(), 0);
        // Per-class gauges are independent levels.
        let t2 = Telemetry::with_classes(2, 8, 1);
        t2.batch_started_for(0, 3);
        t2.batch_started_for(1, 5);
        assert_eq!(t2.in_flight_for(0), 3);
        assert_eq!(t2.in_flight_for(1), 5);
        assert_eq!(t2.in_flight(), 8);
    }

    #[test]
    fn empty_window_is_zeroed() {
        let t = Telemetry::new(3);
        let w = t.window();
        assert_eq!(w.completions, 0);
        assert_eq!(w.p95, Duration::ZERO);
        assert_eq!(w.expired, 0);
        assert_eq!(w.cv_proxy, 0.0);
        assert_eq!(w.cv_proxy_per_layer.len(), 3);
        assert_eq!(w.cv_samples, 0);
    }

    #[test]
    fn cv_sampler_flows_through_window() {
        let t = Telemetry::new(2);
        t.cv_sampler().record(0, 10, 100, 4);
        t.cv_sampler().record(1, 30, 100, 4);
        let w = t.window();
        assert!((w.cv_proxy - 40.0 / 200.0).abs() < 1e-12);
        assert!((w.cv_proxy_per_layer[0] - 0.1).abs() < 1e-12);
        assert!((w.cv_proxy_per_layer[1] - 0.3).abs() < 1e-12);
        assert_eq!(w.cv_samples, 8);
        assert_eq!(t.window().cv_samples, 0, "drained");
    }

    #[test]
    fn record_cv_merges_raw_batch_sums() {
        let t = Telemetry::new(3);
        // A worker's batch-local sampler drained to raw sums: layer 1
        // recorded nothing and must stay untouched.
        t.record_cv(&[(10, 100, 4), (0, 0, 0), (30, 100, 4)]);
        t.record_cv(&[(10, 100, 4), (0, 0, 0), (0, 0, 0)]);
        let w = t.window();
        assert!((w.cv_proxy_per_layer[0] - 0.1).abs() < 1e-12);
        assert_eq!(w.cv_proxy_per_layer[1], 0.0);
        assert!((w.cv_proxy_per_layer[2] - 0.3).abs() < 1e-12);
        assert_eq!(w.cv_samples, 12);
    }

    #[test]
    fn records_are_lock_free_across_threads() {
        let t = Telemetry::new(1);
        std::thread::scope(|s| {
            for _ in 0..4 {
                let t = &t;
                s.spawn(move || {
                    for i in 0..250 {
                        t.record_latency(Duration::from_micros(100 + i));
                        t.record_batch(2, 4, 1);
                    }
                });
            }
        });
        let w = t.window();
        assert_eq!(w.completions, 1000);
        assert_eq!(w.batches, 1000);
        assert!(w.p95 >= Duration::from_micros(100));
        assert!((w.mean_batch_occupancy - 0.5).abs() < 1e-9);
        assert!((w.mean_queue_depth - 1.0).abs() < 1e-9);
    }
}
