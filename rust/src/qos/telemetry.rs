//! Lock-light serving telemetry for the adaptive governor.
//!
//! The governor needs four live signals — queue depth, batch occupancy,
//! tail latency, and the CV-magnitude error proxy — sampled on the serving
//! hot path without adding a contended lock to it. Everything here is
//! atomics: workers `fetch_add` counters and overwrite a fixed ring of
//! recent latency samples; the (single) governor thread drains windows with
//! `swap(0)`. The only non-O(1) work is in [`Telemetry::window`], which the
//! governor pays, not the pool.
//!
//! Every signal is **drain-on-read**: each `window()` call covers exactly
//! what accumulated since the previous call — including the latency
//! percentiles, which are computed over the samples recorded in the window
//! (capped at the ring size; a window that overflows the ring keeps its
//! most recent `window` samples). Stale burst latencies therefore cannot
//! leak into later decisions and pin the governor at a wrong rung — the
//! latency ring's head and slots use Release/Acquire so the drain actually
//! observes the stores behind the head it reads (see `record_latency`);
//! the commutative sums stay Relaxed because a sample landing on a window
//! boundary counts in one window or the next, never corrupts. One poller
//! is assumed (the governor); a second concurrent poller would split
//! windows between them.
//! The `in_flight` gauge is the exception: it is a live level, not a
//! window aggregate — requests popped into executing batches are invisible
//! to both the queue depth and the completion count, and without this
//! gauge a saturated pool whose batches outlast a whole window would be
//! indistinguishable from an idle one.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::nn::CvProxySampler;

/// Default sliding-window size for the latency percentile ring.
pub const DEFAULT_WINDOW: usize = 1024;

/// Shared serving telemetry: one instance per [`crate::coordinator::InferenceService`],
/// recorded into by every pool worker, drained by the governor.
#[derive(Debug)]
pub struct Telemetry {
    /// Ring of recent per-request latencies in µs (0 = never written).
    lat_us: Vec<AtomicU64>,
    /// Total latency samples ever pushed (ring slot = head % len).
    head: AtomicU64,
    /// `head` at the last `window()` call (completion-rate bookkeeping).
    drained_head: AtomicU64,
    /// Σ queue depth observed at batch pop / number of observations.
    depth_sum: AtomicU64,
    depth_n: AtomicU64,
    /// Σ batch occupancy (fused requests / batch capacity) in per-mille.
    occ_pm_sum: AtomicU64,
    occ_n: AtomicU64,
    /// Requests currently inside executing batches (live level gauge).
    inflight: AtomicU64,
    /// Per-layer CV-magnitude error proxy. Workers run each batch with a
    /// *batch-local* [`CvProxySampler`] so the fault plane can band-check
    /// that batch's raw sums in isolation (`fault::IntegrityMonitor`), then
    /// re-record the trusted sums here via [`Telemetry::cv_sampler`] —
    /// keeping the governor's drain-on-read windows intact and untainted by
    /// batches that were rolled back and replayed after corruption.
    cv: Arc<CvProxySampler>,
}

/// One drained telemetry window.
#[derive(Clone, Debug)]
pub struct TelemetryWindow {
    /// Requests completed since the previous `window()` call.
    pub completions: u64,
    /// Batches executed since the previous call.
    pub batches: u64,
    /// Latency percentiles over THIS window's completions (up to the ring
    /// size; zero when nothing completed in the window).
    pub p50: Duration,
    pub p95: Duration,
    /// Mean queue depth observed at batch pop since the previous call.
    pub mean_queue_depth: f64,
    /// Mean batch occupancy (0..1) since the previous call.
    pub mean_batch_occupancy: f64,
    /// Pooled CV error proxy Σ|V| / Σ|G*| since the previous call.
    pub cv_proxy: f64,
    /// Per-MAC-layer error proxy (0 for layers that recorded nothing).
    pub cv_proxy_per_layer: Vec<f64>,
    /// Epilogue entries the proxy averaged over.
    pub cv_samples: u64,
}

impl Telemetry {
    /// Telemetry for a model with `mac_layers` MAC layers, default window.
    pub fn new(mac_layers: usize) -> Telemetry {
        Telemetry::with_window(DEFAULT_WINDOW, mac_layers)
    }

    /// Explicit ring size (tests shrink it to exercise wraparound).
    pub fn with_window(window: usize, mac_layers: usize) -> Telemetry {
        Telemetry {
            lat_us: (0..window.max(1)).map(|_| AtomicU64::new(0)).collect(),
            head: AtomicU64::new(0),
            drained_head: AtomicU64::new(0),
            depth_sum: AtomicU64::new(0),
            depth_n: AtomicU64::new(0),
            occ_pm_sum: AtomicU64::new(0),
            occ_n: AtomicU64::new(0),
            inflight: AtomicU64::new(0),
            cv: Arc::new(CvProxySampler::new(mac_layers)),
        }
    }

    /// The shared error-proxy sampler (workers attach it to
    /// `ForwardOpts::cv_proxy`).
    pub fn cv_sampler(&self) -> Arc<CvProxySampler> {
        self.cv.clone()
    }

    /// Merge one batch's raw proxy sums (`(Σ|V|, Σ|G*|, n)` per layer, from
    /// `CvProxySampler::drain_raw`) into the shared sampler. Workers call
    /// this only after the batch passed integrity checks.
    pub fn record_cv(&self, raw: &[(u64, u64, u64)]) {
        for (i, &(num, den, n)) in raw.iter().enumerate() {
            if n > 0 {
                self.cv.record(i, num, den, n);
            }
        }
    }

    /// Record one completed request's end-to-end latency.
    ///
    /// Publication order matters here: each Release fetch_add on `head`
    /// joins a release sequence, so the Acquire load in [`window`] makes
    /// every slot store from *earlier* increments visible. The one store
    /// that can still be in flight per worker is its own latest sample —
    /// bounded staleness, versus the unbounded leak an all-Relaxed scheme
    /// allows (head advanced, slots still stale).
    pub fn record_latency(&self, d: Duration) {
        let us = (d.as_secs_f64() * 1e6).round().max(1.0) as u64;
        let slot = self.head.fetch_add(1, Ordering::Release) as usize % self.lat_us.len();
        self.lat_us[slot].store(us, Ordering::Release);
    }

    /// A worker is about to run a batch of `requests`: raise the in-flight
    /// level ([`Telemetry::record_batch`] lowers it when the batch lands).
    pub fn batch_started(&self, requests: usize) {
        self.inflight.fetch_add(requests as u64, Ordering::Relaxed);
    }

    /// Record one executed batch: how many requests fused (of `cap`
    /// possible) and the queue depth left behind at pop time.
    pub fn record_batch(&self, requests: usize, cap: usize, queue_depth: usize) {
        // Saturating decrement: a record_batch without a matching
        // batch_started (unit tests drive them independently) must not
        // wrap the gauge.
        let _ = self.inflight.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
            Some(v.saturating_sub(requests as u64))
        });
        self.depth_sum.fetch_add(queue_depth as u64, Ordering::Relaxed);
        self.depth_n.fetch_add(1, Ordering::Relaxed);
        let pm = (1000 * requests / cap.max(1)).min(1000) as u64;
        self.occ_pm_sum.fetch_add(pm, Ordering::Relaxed);
        self.occ_n.fetch_add(1, Ordering::Relaxed);
    }

    /// Requests currently inside executing batches (live level, not a
    /// window aggregate).
    pub fn in_flight(&self) -> u64 {
        self.inflight.load(Ordering::Relaxed)
    }

    /// Drain the window accumulated since the last call: depth, occupancy,
    /// error proxy, completion count, AND the latency percentiles — which
    /// cover only the samples recorded in this window (most recent
    /// ring-size samples when the window overflowed the ring), so a past
    /// burst's tail cannot haunt later decisions.
    pub fn window(&self) -> TelemetryWindow {
        let head = self.head.load(Ordering::Acquire);
        let prev = self.drained_head.swap(head, Ordering::Relaxed);
        let cap = self.lat_us.len() as u64;
        let take = head.saturating_sub(prev).min(cap);
        let mut lats: Vec<u64> = (head - take..head)
            .map(|j| self.lat_us[(j % cap) as usize].load(Ordering::Acquire))
            .filter(|&v| v > 0)
            .collect();
        lats.sort_unstable();
        let pick = |q: f64| -> Duration {
            if lats.is_empty() {
                Duration::ZERO
            } else {
                let idx = ((lats.len() - 1) as f64 * q).round() as usize;
                Duration::from_micros(lats[idx])
            }
        };
        let (p50, p95) = (pick(0.50), pick(0.95));
        let depth_n = self.depth_n.swap(0, Ordering::Relaxed);
        let depth_sum = self.depth_sum.swap(0, Ordering::Relaxed);
        let occ_n = self.occ_n.swap(0, Ordering::Relaxed);
        let occ_pm = self.occ_pm_sum.swap(0, Ordering::Relaxed);
        let cvw = self.cv.drain();
        TelemetryWindow {
            completions: head.saturating_sub(prev),
            batches: occ_n,
            p50,
            p95,
            mean_queue_depth: if depth_n > 0 {
                depth_sum as f64 / depth_n as f64
            } else {
                0.0
            },
            mean_batch_occupancy: if occ_n > 0 {
                occ_pm as f64 / (1000.0 * occ_n as f64)
            } else {
                0.0
            },
            cv_proxy: cvw.aggregate,
            cv_proxy_per_layer: cvw.per_layer,
            cv_samples: cvw.samples,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_drains_latency_and_counters() {
        let t = Telemetry::with_window(8, 2);
        for ms in [1u64, 2, 3, 4] {
            t.record_latency(Duration::from_millis(ms));
        }
        t.record_batch(4, 8, 10);
        t.record_batch(8, 8, 0);
        let w = t.window();
        assert_eq!(w.completions, 4);
        assert_eq!(w.batches, 2);
        assert_eq!(w.p50, Duration::from_millis(3)); // rank rounding picks idx 2
        assert_eq!(w.p95, Duration::from_millis(4));
        assert!((w.mean_queue_depth - 5.0).abs() < 1e-9);
        assert!((w.mean_batch_occupancy - 0.75).abs() < 1e-3);
        // Everything drains — including the latency percentiles: a stale
        // burst must not haunt the next decision window.
        let w2 = t.window();
        assert_eq!(w2.completions, 0);
        assert_eq!(w2.batches, 0);
        assert_eq!(w2.mean_queue_depth, 0.0);
        assert_eq!(w2.p95, Duration::ZERO, "p95 is per-window, not a sliding ring");
        // A window that overflows the 8-slot ring keeps its most recent
        // samples: 4 slow then 16 fast ones — the slow tail is gone.
        for _ in 0..4 {
            t.record_latency(Duration::from_millis(500));
        }
        for _ in 0..16 {
            t.record_latency(Duration::from_millis(100));
        }
        let w3 = t.window();
        assert_eq!(w3.completions, 20);
        assert_eq!(w3.p50, Duration::from_millis(100));
        assert_eq!(w3.p95, Duration::from_millis(100));
    }

    #[test]
    fn in_flight_gauge_tracks_executing_batches() {
        let t = Telemetry::with_window(8, 1);
        assert_eq!(t.in_flight(), 0);
        t.batch_started(6);
        t.batch_started(2);
        assert_eq!(t.in_flight(), 8);
        t.record_batch(6, 8, 0);
        assert_eq!(t.in_flight(), 2);
        t.record_batch(2, 8, 0);
        assert_eq!(t.in_flight(), 0);
        // Unmatched record_batch saturates instead of wrapping.
        t.record_batch(4, 8, 0);
        assert_eq!(t.in_flight(), 0);
    }

    #[test]
    fn empty_window_is_zeroed() {
        let t = Telemetry::new(3);
        let w = t.window();
        assert_eq!(w.completions, 0);
        assert_eq!(w.p95, Duration::ZERO);
        assert_eq!(w.cv_proxy, 0.0);
        assert_eq!(w.cv_proxy_per_layer.len(), 3);
        assert_eq!(w.cv_samples, 0);
    }

    #[test]
    fn cv_sampler_flows_through_window() {
        let t = Telemetry::new(2);
        t.cv_sampler().record(0, 10, 100, 4);
        t.cv_sampler().record(1, 30, 100, 4);
        let w = t.window();
        assert!((w.cv_proxy - 40.0 / 200.0).abs() < 1e-12);
        assert!((w.cv_proxy_per_layer[0] - 0.1).abs() < 1e-12);
        assert!((w.cv_proxy_per_layer[1] - 0.3).abs() < 1e-12);
        assert_eq!(w.cv_samples, 8);
        assert_eq!(t.window().cv_samples, 0, "drained");
    }

    #[test]
    fn record_cv_merges_raw_batch_sums() {
        let t = Telemetry::new(3);
        // A worker's batch-local sampler drained to raw sums: layer 1
        // recorded nothing and must stay untouched.
        t.record_cv(&[(10, 100, 4), (0, 0, 0), (30, 100, 4)]);
        t.record_cv(&[(10, 100, 4), (0, 0, 0), (0, 0, 0)]);
        let w = t.window();
        assert!((w.cv_proxy_per_layer[0] - 0.1).abs() < 1e-12);
        assert_eq!(w.cv_proxy_per_layer[1], 0.0);
        assert!((w.cv_proxy_per_layer[2] - 0.3).abs() < 1e-12);
        assert_eq!(w.cv_samples, 12);
    }

    #[test]
    fn records_are_lock_free_across_threads() {
        let t = Telemetry::new(1);
        std::thread::scope(|s| {
            for _ in 0..4 {
                let t = &t;
                s.spawn(move || {
                    for i in 0..250 {
                        t.record_latency(Duration::from_micros(100 + i));
                        t.record_batch(2, 4, 1);
                    }
                });
            }
        });
        let w = t.window();
        assert_eq!(w.completions, 1000);
        assert_eq!(w.batches, 1000);
        assert!(w.p95 >= Duration::from_micros(100));
        assert!((w.mean_batch_occupancy - 0.5).abs() < 1e-9);
        assert!((w.mean_queue_depth - 1.0).abs() < 1e-9);
    }
}
