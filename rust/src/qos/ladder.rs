//! QoS ladders: ordered operating points for the adaptive governor.
//!
//! A [`Ladder`] is the offline half of the adaptive-QoS contract: an
//! ordered vector of named rungs, each a per-layer [`LayerPolicy`] tagged
//! with its offline-estimated accuracy loss (measured by the layerwise
//! greedy/paired searches) and its MAC-weighted normalized power (from the
//! hw cost model). Rung 0 is the most accurate operating point (normally
//! exact); every later rung must cost **no more power** than its
//! predecessor — the ladder descends the power axis, so "step down under
//! load" always trades accuracy for power/thermal headroom, never for
//! nothing. The governor walks this ladder at runtime exactly like a DVFS
//! driver walks its P-state table, scaling *approximation* instead of
//! frequency.
//!
//! Ladders serialize as a JSON artifact (`cvapprox qos-ladder --json`) in
//! the same dialect as policy files, so a deployment can version them:
//!
//! ```json
//! {"rungs": [{"name": "exact", "est_loss": 0, "power_norm": 1,
//!             "policy": {"layers": [...]}}, ...]}
//! ```

use std::fmt;
use std::path::Path;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::nn::{LayerPolicy, Model, SharedPolicy};
use crate::util::json::Json;

/// Typed ladder-construction failure. Callers feeding externally produced
/// rung sets (e.g. a `SEARCH_pareto.json` front) match on this instead of
/// string-scraping — a malformed artifact degrades to an error, never a
/// panic.
#[derive(Clone, Debug, PartialEq)]
pub enum LadderError {
    /// No rungs at all.
    Empty,
    /// Rung `index` has a blank name.
    EmptyName { index: usize },
    /// Rung `index` carries a negative / non-finite estimated loss.
    BadLoss { index: usize, name: String, est_loss: f64 },
    /// Rung `index` carries a non-positive / non-finite power.
    BadPower { index: usize, name: String, power_norm: f64 },
    /// Rung `index` costs more power than its predecessor.
    PowerRise { index: usize, name: String, power_norm: f64, prev: f64 },
    /// Two rungs share a name.
    DuplicateName { name: String },
}

impl fmt::Display for LadderError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            LadderError::Empty => write!(f, "a QoS ladder needs at least one rung"),
            LadderError::EmptyName { index } => {
                write!(f, "rung {index} has an empty name")
            }
            LadderError::BadLoss { index, name, est_loss } => {
                write!(f, "rung {index} ({name}) has invalid est_loss {est_loss}")
            }
            LadderError::BadPower { index, name, power_norm } => {
                write!(f, "rung {index} ({name}) has invalid power_norm {power_norm}")
            }
            LadderError::PowerRise { index, name, power_norm, prev } => write!(
                f,
                "rung {index} ({name}) raises power over its predecessor \
                 ({power_norm:.4} > {prev:.4}); a ladder must descend the power axis"
            ),
            LadderError::DuplicateName { name } => {
                write!(f, "duplicate rung name {name:?}")
            }
        }
    }
}

impl std::error::Error for LadderError {}

/// One operating point of the ladder.
#[derive(Clone, Debug)]
pub struct Rung {
    /// Human-readable label (`exact`, `greedy-mixed`, …), unique per ladder.
    pub name: String,
    /// Offline-estimated accuracy loss vs the exact design (fraction,
    /// ≥ 0) — what the governor checks against its loss bound.
    pub est_loss: f64,
    /// MAC-weighted normalized power of the rung's policy
    /// ([`LayerPolicy::power_norm`]).
    pub power_norm: f64,
    /// The per-layer policy the coordinator serves at this rung.
    pub policy: SharedPolicy,
}

/// An ordered, validated ladder of operating points (see module docs).
#[derive(Clone, Debug)]
pub struct Ladder {
    rungs: Vec<Rung>,
}

impl Ladder {
    /// Validate and build: at least one rung, unique nonempty names, finite
    /// nonnegative losses, positive power, and power nonincreasing down the
    /// ladder.
    pub fn new(rungs: Vec<Rung>) -> Result<Ladder> {
        Self::check(&rungs)?;
        Ok(Ladder { rungs })
    }

    /// Order-independent construction: sort rungs by power descending
    /// (ties broken by name, then est_loss — fully deterministic for any
    /// input order), then validate. This is how searched rungs merge into
    /// a ladder: callers never have to pre-sort, and a front that is
    /// *inherently* unladderable (duplicate names, bad numbers) comes back
    /// as a typed [`LadderError`] instead of a panic.
    pub fn sorted(mut rungs: Vec<Rung>) -> Result<Ladder, LadderError> {
        rungs.sort_by(|a, b| {
            b.power_norm
                .partial_cmp(&a.power_norm)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then_with(|| a.name.cmp(&b.name))
                .then_with(|| {
                    a.est_loss
                        .partial_cmp(&b.est_loss)
                        .unwrap_or(std::cmp::Ordering::Equal)
                })
        });
        Self::check(&rungs)?;
        Ok(Ladder { rungs })
    }

    /// The ladder invariants as a pure, typed check over a rung sequence.
    pub fn check(rungs: &[Rung]) -> Result<(), LadderError> {
        if rungs.is_empty() {
            return Err(LadderError::Empty);
        }
        for (i, r) in rungs.iter().enumerate() {
            if r.name.trim().is_empty() {
                return Err(LadderError::EmptyName { index: i });
            }
            if !(r.est_loss >= 0.0 && r.est_loss.is_finite()) {
                return Err(LadderError::BadLoss {
                    index: i,
                    name: r.name.clone(),
                    est_loss: r.est_loss,
                });
            }
            if !(r.power_norm > 0.0 && r.power_norm.is_finite()) {
                return Err(LadderError::BadPower {
                    index: i,
                    name: r.name.clone(),
                    power_norm: r.power_norm,
                });
            }
            if i > 0 && r.power_norm > rungs[i - 1].power_norm + 1e-9 {
                return Err(LadderError::PowerRise {
                    index: i,
                    name: r.name.clone(),
                    power_norm: r.power_norm,
                    prev: rungs[i - 1].power_norm,
                });
            }
            if rungs[..i].iter().any(|p| p.name == r.name) {
                return Err(LadderError::DuplicateName { name: r.name.clone() });
            }
        }
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.rungs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rungs.is_empty()
    }

    pub fn rung(&self, i: usize) -> &Rung {
        &self.rungs[i]
    }

    pub fn rungs(&self) -> &[Rung] {
        &self.rungs
    }

    /// Check every rung's policy against a concrete model's layer count.
    pub fn validate_for(&self, model: &Model) -> Result<()> {
        for r in &self.rungs {
            r.policy
                .validate_for(model)
                .with_context(|| format!("ladder rung {:?}", r.name))?;
        }
        Ok(())
    }

    /// Compact one-line summary, e.g.
    /// `exact(1.000x, -0.0%) → greedy-mixed(0.871x, -0.0%) → …`.
    pub fn describe(&self) -> String {
        self.rungs
            .iter()
            .map(|r| {
                format!("{}({:.3}x, -{:.2}%)", r.name, r.power_norm, 100.0 * r.est_loss)
            })
            .collect::<Vec<_>>()
            .join(" → ")
    }

    // ---- serialization -------------------------------------------------

    pub fn to_json(&self) -> Json {
        Json::obj().field(
            "rungs",
            Json::Arr(
                self.rungs
                    .iter()
                    .map(|r| {
                        Json::obj()
                            .field("name", r.name.as_str())
                            .field("est_loss", r.est_loss)
                            .field("power_norm", r.power_norm)
                            .field("policy", r.policy.to_json())
                    })
                    .collect(),
            ),
        )
    }

    pub fn from_json(j: &Json) -> Result<Ladder> {
        let rungs = j
            .get("rungs")
            .and_then(|r| r.as_arr())
            .context("ladder JSON missing \"rungs\" array")?;
        let rungs = rungs
            .iter()
            .enumerate()
            .map(|(i, e)| -> Result<Rung> {
                let name = e
                    .get("name")
                    .and_then(|n| n.as_str())
                    .with_context(|| format!("rung {i} missing \"name\""))?
                    .to_string();
                let est_loss = e
                    .get("est_loss")
                    .and_then(|v| v.as_f64())
                    .with_context(|| format!("rung {i} missing \"est_loss\""))?;
                let power_norm = e
                    .get("power_norm")
                    .and_then(|v| v.as_f64())
                    .with_context(|| format!("rung {i} missing \"power_norm\""))?;
                let policy = e
                    .get("policy")
                    .with_context(|| format!("rung {i} missing \"policy\""))
                    .and_then(LayerPolicy::from_json)
                    .with_context(|| format!("rung {i} policy"))?;
                Ok(Rung { name, est_loss, power_norm, policy: Arc::new(policy) })
            })
            .collect::<Result<Vec<_>>>()?;
        Ladder::new(rungs)
    }

    pub fn parse(text: &str) -> Result<Ladder> {
        Ladder::from_json(&Json::parse(text).context("ladder JSON")?)
    }

    pub fn load(path: &Path) -> Result<Ladder> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading ladder {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing ladder {}", path.display()))
    }

    pub fn save_json(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().render())
            .with_context(|| format!("writing ladder {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::Family;
    use crate::nn::testutil;
    use crate::nn::{LayerAssignment, PairedPoint};

    fn rung(name: &str, loss: f64, power: f64, policy: LayerPolicy) -> Rung {
        Rung { name: name.into(), est_loss: loss, power_norm: power, policy: Arc::new(policy) }
    }

    fn sample_ladder() -> Ladder {
        let exact = LayerPolicy::uniform(Family::Exact, 0, false, 2).unwrap();
        let mixed = LayerPolicy::from_ms(Family::Perforated, &[3, 0], true).unwrap();
        let paired = LayerPolicy::from_assignments(vec![
            LayerAssignment::Paired(PairedPoint::mirrored(
                Family::Perforated,
                3,
                true,
            ));
            2
        ])
        .unwrap();
        Ladder::new(vec![
            rung("exact", 0.0, 1.0, exact),
            rung("greedy-mixed", 0.0, 0.9, mixed),
            rung("aggressive", 0.05, 0.6, paired),
        ])
        .unwrap()
    }

    #[test]
    fn construction_validates_ordering_and_names() {
        let exact = LayerPolicy::uniform(Family::Exact, 0, false, 2).unwrap();
        let p = LayerPolicy::uniform(Family::Perforated, 3, true, 2).unwrap();
        assert!(Ladder::new(vec![]).is_err());
        // power must not rise down the ladder
        let err = Ladder::new(vec![
            rung("a", 0.0, 0.6, p.clone()),
            rung("b", 0.0, 0.9, exact.clone()),
        ])
        .unwrap_err();
        assert!(format!("{err:#}").contains("descend"), "{err:#}");
        // duplicate / empty names
        assert!(Ladder::new(vec![
            rung("a", 0.0, 1.0, exact.clone()),
            rung("a", 0.0, 0.9, p.clone()),
        ])
        .is_err());
        assert!(Ladder::new(vec![rung("  ", 0.0, 1.0, exact.clone())]).is_err());
        // invalid numbers
        assert!(Ladder::new(vec![rung("a", -0.1, 1.0, exact.clone())]).is_err());
        assert!(Ladder::new(vec![rung("a", f64::NAN, 1.0, exact.clone())]).is_err());
        assert!(Ladder::new(vec![rung("a", 0.0, 0.0, exact.clone())]).is_err());
        // equal power on consecutive rungs is allowed (within tolerance)
        assert!(Ladder::new(vec![
            rung("a", 0.0, 0.9, p.clone()),
            rung("b", 0.01, 0.9, p),
        ])
        .is_ok());
    }

    #[test]
    fn sorted_is_order_independent_and_typed() {
        let exact = LayerPolicy::uniform(Family::Exact, 0, false, 2).unwrap();
        let p = LayerPolicy::uniform(Family::Perforated, 3, true, 2).unwrap();
        // any insertion order yields the same ladder
        let mk = || {
            vec![
                rung("low", 0.05, 0.6, p.clone()),
                rung("exact", 0.0, 1.0, exact.clone()),
                rung("mid", 0.01, 0.8, p.clone()),
            ]
        };
        let a = Ladder::sorted(mk()).unwrap();
        let mut shuffled = mk();
        shuffled.reverse();
        let b = Ladder::sorted(shuffled).unwrap();
        assert_eq!(a.describe(), b.describe());
        assert_eq!(
            a.rungs().iter().map(|r| r.name.as_str()).collect::<Vec<_>>(),
            vec!["exact", "mid", "low"]
        );
        // equal power ties break by name, then est_loss — deterministically
        let t1 = Ladder::sorted(vec![
            rung("b", 0.02, 0.8, p.clone()),
            rung("a", 0.01, 0.8, p.clone()),
        ])
        .unwrap();
        assert_eq!(t1.rung(0).name, "a");
        // an unladderable front is a typed error, not a panic
        assert_eq!(Ladder::sorted(vec![]).unwrap_err(), LadderError::Empty);
        let dup = Ladder::sorted(vec![
            rung("x", 0.0, 1.0, exact.clone()),
            rung("x", 0.01, 0.9, p.clone()),
        ])
        .unwrap_err();
        assert!(matches!(dup, LadderError::DuplicateName { ref name } if name == "x"));
        let bad = Ladder::sorted(vec![rung("x", 0.0, f64::NAN, exact.clone())]).unwrap_err();
        assert!(matches!(bad, LadderError::BadPower { .. }));
        // the PowerRise display keeps the invariant's wording
        let rise = LadderError::PowerRise {
            index: 1,
            name: "x".into(),
            power_norm: 0.9,
            prev: 0.6,
        };
        assert!(rise.to_string().contains("descend"), "{rise}");
    }

    #[test]
    fn json_roundtrip_preserves_rungs_and_policies() {
        let ladder = sample_ladder();
        let text = ladder.to_json().render();
        let back = Ladder::parse(&text).unwrap();
        assert_eq!(back.len(), 3);
        for (a, b) in ladder.rungs().iter().zip(back.rungs()) {
            assert_eq!(a.name, b.name);
            assert!((a.est_loss - b.est_loss).abs() < 1e-12);
            assert!((a.power_norm - b.power_norm).abs() < 1e-12);
            assert_eq!(a.policy.describe(), b.policy.describe());
        }
        // Paired rungs survive the roundtrip intact.
        assert_eq!(back.rung(2).policy.paired_layers(), 2);
        assert!(text.contains("\"rungs\""), "{text}");
    }

    #[test]
    fn file_roundtrip_and_parse_errors() {
        let ladder = sample_ladder();
        let path = std::env::temp_dir()
            .join(format!("cvapprox_ladder_{}.json", std::process::id()));
        ladder.save_json(&path).unwrap();
        let back = Ladder::load(&path).unwrap();
        assert_eq!(back.describe(), ladder.describe());
        let _ = std::fs::remove_file(&path);
        assert!(Ladder::parse("{\"nope\": 1}").is_err());
        assert!(Ladder::parse("{\"rungs\": []}").is_err());
        assert!(Ladder::parse(
            "{\"rungs\": [{\"name\": \"x\", \"est_loss\": 0, \"power_norm\": 1}]}"
        )
        .is_err());
        assert!(Ladder::load(Path::new("/nonexistent/ladder.json")).is_err());
    }

    #[test]
    fn validate_for_checks_every_rung() {
        let ladder = sample_ladder();
        let model = testutil::tiny_model(); // 2 MAC layers
        assert!(ladder.validate_for(&model).is_ok());
        let three = LayerPolicy::uniform(Family::Perforated, 2, true, 3).unwrap();
        let bad = Ladder::new(vec![
            rung("exact", 0.0, 1.0, LayerPolicy::uniform(Family::Exact, 0, false, 2).unwrap()),
            rung("mismatched", 0.0, 0.7, three),
        ])
        .unwrap();
        let err = bad.validate_for(&model).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("mismatched"), "{msg}");
        assert!(msg.contains("MAC layers"), "{msg}");
    }

    #[test]
    fn describe_is_compact() {
        let d = sample_ladder().describe();
        assert!(d.contains("exact(1.000x"), "{d}");
        assert!(d.contains("→"), "{d}");
    }
}
