//! The adaptive approximation governor: a DVFS-analog hysteresis controller
//! that walks a [`Ladder`] at runtime, scaling *approximation* instead of
//! frequency.
//!
//! Control law (one decision per `min_dwell`, over the telemetry window
//! accumulated since the previous decision — `tick` only paces dwell
//! accounting and stop-responsiveness):
//!
//! 1. **Error guard** — if the measured CV error proxy (mean |V|/|G*| from
//!    the serving epilogues) exceeds `error_ceiling`, step UP toward exact
//!    regardless of latency. The proxy is the paper's control variate read
//!    as an online error estimate, so the governor bounds *actual incurred*
//!    approximation error, not just the offline estimate.
//! 2. **Overload** — if the sliding-window p95 latency exceeds
//!    `latency_target` (with at least `min_window` completions backing the
//!    estimate), step DOWN the ladder to the next rung whose offline
//!    `est_loss` fits `max_est_loss` — trading bounded accuracy for
//!    power/thermal headroom under load.
//! 3. **Idle recovery** — if the window is empty (no completions AND
//!    nothing outstanding, queued or inside an executing batch — a
//!    saturated pool mid-batch completes nothing too) or p95 is
//!    comfortably under `step_up_frac · latency_target`, step UP to the
//!    nearest in-bounds rung toward exact, one step per dwell
//!    (out-of-bounds rungs are skipped on the way up exactly as down).
//!
//! The two thresholds (`latency_target` for down, `step_up_frac · target`
//! for up) plus `min_dwell` form the hysteresis band that keeps the
//! governor from oscillating on noisy windows. Every installation goes
//! through [`PolicyInstaller::install`] — validate, warm the plan cache,
//! then an epoch-stamped atomic swap — so a step never stalls the pool and
//! every reply can be attributed to exactly one rung via its epoch.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::ladder::Ladder;
use super::telemetry::{Telemetry, TelemetryWindow};
use crate::coordinator::{InferenceService, PolicyInstaller};
use crate::util::sync::lock_clean;

/// Governor knobs. Every field has an env override (`CVAPPROX_QOS_*`, see
/// [`QosConfig::from_env`]) so deployments tune without recompiling.
#[derive(Clone, Debug)]
pub struct QosConfig {
    /// p95 latency the governor defends (step-down threshold).
    pub latency_target: Duration,
    /// Step back toward exact when p95 < `step_up_frac · latency_target`.
    pub step_up_frac: f64,
    /// Ceiling on the measured CV error proxy (mean |V|/|G*|); above it the
    /// governor steps toward exact even under load.
    pub error_ceiling: f64,
    /// Rungs whose offline `est_loss` exceeds this are never entered — by
    /// the step-down path or the step-up path.
    pub max_est_loss: f64,
    /// Decision cadence: one control decision (hence at most one rung
    /// change) per dwell, over the telemetry accumulated since the last.
    pub min_dwell: Duration,
    /// Sleep granularity of the governor thread (dwell accounting and
    /// stop-responsiveness; decisions happen at `min_dwell` cadence).
    pub tick: Duration,
    /// Minimum completions in a decision window before its p95 is trusted
    /// for a step-down decision.
    pub min_window: u64,
}

impl Default for QosConfig {
    fn default() -> Self {
        QosConfig {
            latency_target: Duration::from_millis(50),
            step_up_frac: 0.5,
            error_ceiling: 0.25,
            max_est_loss: 0.05,
            min_dwell: Duration::from_millis(500),
            tick: Duration::from_millis(100),
            min_window: 8,
        }
    }
}

impl QosConfig {
    /// Defaults overridden by the `CVAPPROX_QOS_*` environment:
    /// `TARGET_MS`, `STEP_UP_FRAC`, `ERROR_CEILING`, `MAX_LOSS` (fraction),
    /// `DWELL_MS`, `TICK_MS`, `MIN_WINDOW`.
    pub fn from_env() -> QosConfig {
        Self::from_lookup(|k| std::env::var(k).ok())
    }

    /// `from_env` over an injected lookup — tests exercise the parsing
    /// without mutating process-global env (set_var racing the getenv
    /// calls other parallel tests make is UB on glibc).
    fn from_lookup(get: impl Fn(&str) -> Option<String>) -> QosConfig {
        let num = |key: &str| -> Option<f64> { get(key)?.trim().parse().ok() };
        let mut c = QosConfig::default();
        if let Some(v) = num("CVAPPROX_QOS_TARGET_MS") {
            c.latency_target = Duration::from_secs_f64((v / 1e3).max(1e-6));
        }
        if let Some(v) = num("CVAPPROX_QOS_STEP_UP_FRAC") {
            c.step_up_frac = v.clamp(0.0, 1.0);
        }
        if let Some(v) = num("CVAPPROX_QOS_ERROR_CEILING") {
            c.error_ceiling = v.max(0.0);
        }
        if let Some(v) = num("CVAPPROX_QOS_MAX_LOSS") {
            c.max_est_loss = v.max(0.0);
        }
        if let Some(v) = num("CVAPPROX_QOS_DWELL_MS") {
            c.min_dwell = Duration::from_secs_f64((v / 1e3).max(1e-6));
        }
        if let Some(v) = num("CVAPPROX_QOS_TICK_MS") {
            c.tick = Duration::from_secs_f64((v / 1e3).max(1e-6));
        }
        if let Some(v) = num("CVAPPROX_QOS_MIN_WINDOW") {
            c.min_window = v.max(0.0) as u64;
        }
        c
    }
}

/// One rung change, recorded for reporting/benching.
#[derive(Clone, Debug)]
pub struct Transition {
    /// Offset from governor start.
    pub at: Duration,
    /// Epoch the new rung was installed under.
    pub epoch: u64,
    pub from: usize,
    pub to: usize,
    /// Window p95 that triggered the step.
    pub p95: Duration,
    /// Window error proxy at the step.
    pub cv_proxy: f64,
    pub reason: &'static str,
}

/// Everything the governor observed, returned by [`Governor::stop`].
#[derive(Clone, Debug, Default)]
pub struct GovernorReport {
    pub transitions: Vec<Transition>,
    /// Wall-clock seconds spent at each rung index.
    pub dwell_secs: Vec<f64>,
    /// Every installed generation: (epoch, rung index), including the
    /// initial rung-0 install — the reply-epoch → rung map the bit-identity
    /// checks join against.
    pub epoch_rungs: Vec<(u64, usize)>,
    pub final_rung: usize,
}

impl GovernorReport {
    /// Fraction of governed wall-clock spent at each rung.
    pub fn dwell_fractions(&self) -> Vec<f64> {
        let total: f64 = self.dwell_secs.iter().sum();
        if total <= 0.0 {
            return vec![0.0; self.dwell_secs.len()];
        }
        self.dwell_secs.iter().map(|&s| s / total).collect()
    }

    /// Rung that served a given reply epoch, if the governor installed it.
    pub fn rung_for_epoch(&self, epoch: u64) -> Option<usize> {
        self.epoch_rungs
            .iter()
            .rev()
            .find(|&&(e, _)| e == epoch)
            .map(|&(_, r)| r)
    }
}

#[derive(Default)]
struct GovInner {
    transitions: Vec<Transition>,
    dwell_secs: Vec<f64>,
    epoch_rungs: Vec<(u64, usize)>,
}

/// A running governor thread bound to one service's telemetry + installer.
pub struct Governor {
    stop: Arc<AtomicBool>,
    rung: Arc<AtomicUsize>,
    inner: Arc<Mutex<GovInner>>,
    handle: Option<JoinHandle<()>>,
}

impl Governor {
    /// Validate the ladder against the served model, install rung 0, and
    /// start governing the **default tenant** (class 0). The governor
    /// holds only `Arc` handles into the service (telemetry + installer),
    /// so the service can be shut down independently; an install into a
    /// torn-down pool simply has no one left to serve it.
    pub fn start(svc: &InferenceService, ladder: Ladder, cfg: QosConfig) -> Result<Governor> {
        Governor::start_for_class(svc, 0, ladder, cfg)
    }

    /// Start a governor bound to ONE tenant class: it polls that class's
    /// telemetry partition (`window_for`), its queue depth and in-flight
    /// gauge, and installs rungs into that class's policy plane only.
    /// Running one governor per class satisfies the telemetry poller
    /// contract — each class's window has exactly one drainer — and one
    /// tenant stepping down its ladder never moves another tenant's rung.
    pub fn start_for_class(
        svc: &InferenceService,
        class: usize,
        ladder: Ladder,
        cfg: QosConfig,
    ) -> Result<Governor> {
        let installer = svc
            .installer_for(class)
            .with_context(|| format!("unknown tenant class {class}"))?;
        ladder.validate_for(installer.model()).context("qos ladder")?;
        let telemetry = svc.telemetry.clone();
        let depth = svc.class_depth_probe(class);
        let stop = Arc::new(AtomicBool::new(false));
        let rung = Arc::new(AtomicUsize::new(0));
        let mut inner0 =
            GovInner { dwell_secs: vec![0.0; ladder.len()], ..GovInner::default() };
        let epoch = installer
            .install(ladder.rung(0).policy.clone())
            .context("installing initial rung")?;
        inner0.epoch_rungs.push((epoch, 0));
        let inner = Arc::new(Mutex::new(inner0));
        // Installing rung 0 may race telemetry left over from pre-governor
        // traffic; start from a clean window (this class's partition only —
        // other classes' governors own theirs).
        let _ = telemetry.window_for(class);
        let handle = {
            let (stop, rung, inner) = (stop.clone(), rung.clone(), inner.clone());
            std::thread::Builder::new()
                .name(format!("cvapprox-qos-governor-{class}"))
                .spawn(move || {
                    run_loop(installer, telemetry, class, depth, ladder, cfg, stop, rung, inner)
                })
                .context("spawning governor thread")?
        };
        Ok(Governor { stop, rung, inner, handle: Some(handle) })
    }

    /// Ladder rung currently installed (0 = most accurate).
    pub fn rung(&self) -> usize {
        self.rung.load(Ordering::Acquire)
    }

    /// Snapshot of transitions/dwell so far (the governor keeps running).
    pub fn report(&self) -> GovernorReport {
        let g = lock_clean(&self.inner);
        GovernorReport {
            transitions: g.transitions.clone(),
            dwell_secs: g.dwell_secs.clone(),
            epoch_rungs: g.epoch_rungs.clone(),
            final_rung: self.rung(),
        }
    }

    /// Stop governing (the pool keeps serving the last installed rung) and
    /// return the final report.
    pub fn stop(mut self) -> GovernorReport {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        self.report()
    }
}

impl Drop for Governor {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Next rung below `cur` whose offline loss estimate fits the bound (rungs
/// over the bound are skipped, not entered).
fn next_down(ladder: &Ladder, cur: usize, max_est_loss: f64) -> Option<usize> {
    (cur + 1..ladder.len()).find(|&j| ladder.rung(j).est_loss <= max_est_loss)
}

/// Nearest rung above `cur` that fits the loss bound — step-up paths skip
/// out-of-bounds rungs too (a ladder may interleave inadmissible rungs, and
/// "recovering" INTO one would violate the bound the down path honors).
/// Rung 0 is the accuracy anchor and is always reachable.
fn next_up(ladder: &Ladder, cur: usize, max_est_loss: f64) -> Option<usize> {
    if cur == 0 {
        return None;
    }
    Some(
        (0..cur)
            .rev()
            .find(|&j| ladder.rung(j).est_loss <= max_est_loss)
            .unwrap_or(0),
    )
}

/// Bound on the in-memory transition / epoch→rung logs: an oscillating
/// governor in a long-running service must not grow its report without
/// limit, so once a log reaches the cap its oldest half is dropped (recent
/// epochs — the only ones live batches can still carry — always survive).
const LOG_CAP: usize = 65_536;

#[allow(clippy::too_many_arguments)]
fn run_loop(
    installer: PolicyInstaller,
    telemetry: Arc<Telemetry>,
    class: usize,
    depth: Arc<dyn Fn() -> usize + Send + Sync>,
    ladder: Ladder,
    cfg: QosConfig,
    stop: Arc<AtomicBool>,
    rung_gauge: Arc<AtomicUsize>,
    inner: Arc<Mutex<GovInner>>,
) {
    let t0 = Instant::now();
    let mut cur = 0usize;
    let mut last_tick = Instant::now();
    // One decision per dwell, not per tick: telemetry windows accumulate
    // between decisions, so a sustained-but-slow overload still clears
    // `min_window` over the whole dwell (per-tick windows would gate it on
    // per-tick completions), and "no completions" means idle across the
    // entire dwell — one quiet tick amid a burst cannot read as idle.
    let mut last_eval = Instant::now();
    while !stop.load(Ordering::Acquire) {
        std::thread::sleep(cfg.tick);
        let now = Instant::now();
        lock_clean(&inner).dwell_secs[cur] += (now - last_tick).as_secs_f64();
        last_tick = now;
        if now.duration_since(last_eval) < cfg.min_dwell {
            continue;
        }
        let w = telemetry.window_for(class);
        last_eval = now;
        // Outstanding work = still queued + already inside executing
        // batches; either kind makes "no completions" mean saturation,
        // not idleness. Both signals are this class's own — another
        // tenant's backlog must not read as our load.
        let outstanding = depth() + telemetry.in_flight_for(class) as usize;
        if let Some((to, reason)) = decide(&ladder, cur, &w, outstanding, &cfg) {
            match installer.install(ladder.rung(to).policy.clone()) {
                Ok(epoch) => {
                    let mut g = lock_clean(&inner);
                    if g.transitions.len() >= LOG_CAP {
                        g.transitions.drain(..LOG_CAP / 2);
                    }
                    if g.epoch_rungs.len() >= LOG_CAP {
                        g.epoch_rungs.drain(..LOG_CAP / 2);
                    }
                    g.transitions.push(Transition {
                        at: t0.elapsed(),
                        epoch,
                        from: cur,
                        to,
                        p95: w.p95,
                        cv_proxy: w.cv_proxy,
                        reason,
                    });
                    g.epoch_rungs.push((epoch, to));
                    drop(g);
                    cur = to;
                    rung_gauge.store(cur, Ordering::Release);
                }
                // An install can only fail if the pool's model changed out
                // from under us — impossible for a live service — so treat
                // it as "stop governing" rather than spinning on errors.
                Err(_) => break,
            }
        }
    }
    let now = Instant::now();
    lock_clean(&inner).dwell_secs[cur] += (now - last_tick).as_secs_f64();
}

/// The pure control law (unit-tested without threads): given the current
/// rung, one telemetry window and the live outstanding-request count
/// (queued + in-flight), which rung to move to, if any.
fn decide(
    ladder: &Ladder,
    cur: usize,
    w: &TelemetryWindow,
    outstanding: usize,
    cfg: &QosConfig,
) -> Option<(usize, &'static str)> {
    let target = cfg.latency_target.as_secs_f64();
    let p95 = w.p95.as_secs_f64();
    if w.cv_proxy > cfg.error_ceiling {
        // Error pressure always vetoes descent: step toward exact, or —
        // already there — hold even if overloaded (accuracy outranks
        // latency, the paper's tight-loss constraint).
        return next_up(ladder, cur, cfg.max_est_loss).map(|to| (to, "error-ceiling"));
    }
    if w.completions >= cfg.min_window && p95 > target {
        return next_down(ladder, cur, cfg.max_est_loss).map(|to| (to, "latency-over-target"));
    }
    // "Nothing completed" only means idle when nothing is outstanding
    // either (queued OR already inside an executing batch): a saturated
    // pool whose in-flight batches outlast the decision window completes
    // nothing too, and stepping up there would raise the cost of exactly
    // the work that is drowning it. The fast-window step-up deliberately
    // has NO min_window gate: `min_window` protects the step-DOWN decision
    // from noisy p95 estimates, but stepping UP is the safe direction —
    // a trickle of fast completions must recover toward exact instead of
    // pinning the pool at a degraded rung forever.
    let idle = w.completions == 0 && outstanding == 0;
    if idle || (w.completions > 0 && p95 < target * cfg.step_up_frac) {
        return next_up(ladder, cur, cfg.max_est_loss).map(|to| (to, "idle-recovery"));
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::Family;
    use crate::coordinator::ServiceConfig;
    use crate::nn::{testutil, Engine, LayerPolicy};
    use std::sync::Arc;

    fn tiny_ladder() -> Ladder {
        use super::super::ladder::Rung;
        let mk = |name: &str, loss: f64, power: f64, p: LayerPolicy| Rung {
            name: name.into(),
            est_loss: loss,
            power_norm: power,
            policy: Arc::new(p),
        };
        Ladder::new(vec![
            mk("exact", 0.0, 1.0, LayerPolicy::uniform(Family::Exact, 0, false, 2).unwrap()),
            mk(
                "mixed",
                0.01,
                0.9,
                LayerPolicy::from_ms(Family::Perforated, &[2, 0], true).unwrap(),
            ),
            mk("lossy", 0.9, 0.8, LayerPolicy::uniform(Family::Perforated, 2, true, 2).unwrap()),
            mk(
                "aggressive",
                0.02,
                0.6,
                LayerPolicy::uniform(Family::Perforated, 3, true, 2).unwrap(),
            ),
        ])
        .unwrap()
    }

    fn window(completions: u64, p95: Duration, cv_proxy: f64) -> TelemetryWindow {
        TelemetryWindow {
            completions,
            batches: completions,
            p50: p95 / 2,
            p95,
            mean_queue_depth: 0.0,
            mean_batch_occupancy: 0.5,
            expired: 0,
            cv_proxy,
            cv_proxy_per_layer: vec![],
            cv_samples: completions,
        }
    }

    #[test]
    fn control_law_hysteresis_and_bounds() {
        let ladder = tiny_ladder();
        let cfg = QosConfig {
            latency_target: Duration::from_millis(10),
            step_up_frac: 0.5,
            error_ceiling: 0.25,
            max_est_loss: 0.05,
            min_window: 4,
            ..QosConfig::default()
        };
        // Overloaded at rung 0: step down — skipping the out-of-bounds
        // "lossy" rung is the max_est_loss guard when coming from rung 1.
        let over = window(32, Duration::from_millis(50), 0.01);
        assert_eq!(decide(&ladder, 0, &over, 99, &cfg), Some((1, "latency-over-target")));
        assert_eq!(decide(&ladder, 1, &over, 99, &cfg), Some((3, "latency-over-target")));
        // Already at the bottom: nothing below fits.
        assert_eq!(decide(&ladder, 3, &over, 99, &cfg), None);
        // Too few completions: the p95 estimate is not trusted.
        let thin = window(2, Duration::from_millis(50), 0.01);
        assert_eq!(decide(&ladder, 0, &thin, 99, &cfg), None);
        // In the hysteresis band (between up and down thresholds): hold.
        let mid = window(32, Duration::from_millis(7), 0.01);
        assert_eq!(decide(&ladder, 1, &mid, 0, &cfg), None);
        // Comfortably fast: step up one rung.
        let fast = window(32, Duration::from_millis(2), 0.01);
        assert_eq!(decide(&ladder, 1, &fast, 0, &cfg), Some((0, "idle-recovery")));
        assert_eq!(decide(&ladder, 0, &fast, 0, &cfg), None);
        // Idle (no completions, empty queue): recover toward exact —
        // skipping the out-of-bounds "lossy" rung on the way UP too
        // (recovering INTO a 90%-loss rung would violate the bound the
        // down path honors).
        let idle = window(0, Duration::ZERO, 0.0);
        assert_eq!(decide(&ladder, 3, &idle, 0, &cfg), Some((1, "idle-recovery")));
        assert_eq!(decide(&ladder, 1, &idle, 0, &cfg), Some((0, "idle-recovery")));
        assert_eq!(decide(&ladder, 0, &idle, 0, &cfg), None);
        // Zero completions with a DEEP queue is saturation, not idleness:
        // in-flight batches outlasting the window must not trigger a step
        // up in the middle of the overload.
        assert_eq!(decide(&ladder, 3, &idle, 17, &cfg), None);
        // A trickle below min_window still recovers when it is fast —
        // min_window gates only the (unsafe) step-down direction; without
        // this, 1..min_window-1 completions per dwell would pin a degraded
        // rung forever.
        let trickle = window(2, Duration::from_millis(2), 0.01);
        assert_eq!(decide(&ladder, 1, &trickle, 0, &cfg), Some((0, "idle-recovery")));
        assert_eq!(decide(&ladder, 0, &trickle, 0, &cfg), None);
        // Error proxy over the ceiling beats the latency signal.
        let hot = window(32, Duration::from_millis(50), 0.4);
        assert_eq!(decide(&ladder, 2, &hot, 99, &cfg), Some((1, "error-ceiling")));
        assert_eq!(decide(&ladder, 0, &hot, 99, &cfg), None, "cannot go above exact");
    }

    #[test]
    fn qos_config_lookup_overrides() {
        // Exercised through the injected lookup, NOT set_var: mutating
        // process env would race the getenv calls of concurrently running
        // tests (UB on glibc).
        let vars: std::collections::HashMap<&str, &str> = [
            ("CVAPPROX_QOS_TARGET_MS", "12.5"),
            ("CVAPPROX_QOS_STEP_UP_FRAC", "0.25"),
            ("CVAPPROX_QOS_ERROR_CEILING", "0.5"),
            ("CVAPPROX_QOS_MAX_LOSS", "0.02"),
            ("CVAPPROX_QOS_DWELL_MS", "40"),
            ("CVAPPROX_QOS_TICK_MS", "5"),
            ("CVAPPROX_QOS_MIN_WINDOW", "3"),
        ]
        .into_iter()
        .collect();
        let c = QosConfig::from_lookup(|k| vars.get(k).map(|v| v.to_string()));
        assert_eq!(c.latency_target, Duration::from_micros(12_500));
        assert_eq!(c.step_up_frac, 0.25);
        assert_eq!(c.error_ceiling, 0.5);
        assert_eq!(c.max_est_loss, 0.02);
        assert_eq!(c.min_dwell, Duration::from_millis(40));
        assert_eq!(c.tick, Duration::from_millis(5));
        assert_eq!(c.min_window, 3);
        // Bad values fall back to defaults; absent keys keep defaults.
        let d = QosConfig::from_lookup(|k| {
            (k == "CVAPPROX_QOS_TARGET_MS").then(|| "bogus".to_string())
        });
        assert_eq!(d.latency_target, QosConfig::default().latency_target);
        let e = QosConfig::from_lookup(|_| None);
        assert_eq!(e.min_dwell, QosConfig::default().min_dwell);
    }

    #[test]
    fn governor_steps_down_under_load_and_recovers_when_idle() {
        // End-to-end miniature of the bench acceptance: a real pool, a real
        // governor, a synthetic burst. The governor must step down while
        // the burst is queued, recover to rung 0 when traffic stops, and
        // every reply must be bit-identical to a static forward under its
        // epoch's rung.
        let model = testutil::tiny_model();
        let ladder = tiny_ladder();
        let svc = crate::coordinator::InferenceService::start(
            Engine::new(model.clone()),
            ServiceConfig {
                workers: 1,
                batch_size: 2,
                batch_timeout: Duration::from_micros(200),
                ..Default::default()
            },
        )
        .unwrap();
        let cfg = QosConfig {
            latency_target: Duration::from_millis(1),
            min_dwell: Duration::from_millis(20),
            tick: Duration::from_millis(5),
            min_window: 4,
            max_est_loss: 0.05,
            error_ceiling: f64::INFINITY, // isolate the latency signal
            ..QosConfig::default()
        };
        let gov = Governor::start(&svc, ladder.clone(), cfg).unwrap();
        let mut replies = Vec::new();
        // Burst until the governor leaves rung 0 (bounded): each wave
        // floods the single worker so queueing pushes the tail latency far
        // over the 1 ms target. Then push one more wave so some replies are
        // actually served at the approximate rung.
        let wave = 512usize;
        let mut run_wave = |replies: &mut Vec<(u64, crate::coordinator::service::Reply)>| {
            let pend: Vec<_> = (0..wave)
                .map(|i| svc.submit(testutil::tiny_image((i % 32) as u64)).unwrap())
                .collect();
            replies.extend(
                pend.into_iter()
                    .enumerate()
                    .map(|(i, p)| ((i % 32) as u64, p.wait().unwrap())),
            );
        };
        let mut waves = 0;
        while gov.rung() == 0 && waves < 100 {
            run_wave(&mut replies);
            waves += 1;
        }
        assert!(gov.rung() > 0, "governor never stepped down after {waves} waves");
        run_wave(&mut replies);
        // Go idle; the governor must walk back up to rung 0.
        let t0 = Instant::now();
        while gov.rung() != 0 && t0.elapsed() < Duration::from_secs(10) {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(gov.rung(), 0, "governor did not recover to exact when idle");
        let report = gov.stop();
        assert!(
            report.transitions.len() >= 2,
            "expected a down + an up transition, got {:?}",
            report.transitions
        );
        assert!(report.transitions.iter().any(|t| t.reason == "latency-over-target"));
        assert!(report.transitions.iter().any(|t| t.reason == "idle-recovery"));
        // The out-of-bounds "lossy" rung (est_loss 0.9 > max 0.05) must
        // never have been entered.
        assert!(report.epoch_rungs.iter().all(|&(_, r)| r != 2));
        assert!(report.dwell_fractions()[0] > 0.0);
        // Bit-identity per epoch: each reply equals the static forward of
        // the rung its epoch installed, for the exact image it answered.
        let reference = Engine::new(model);
        let mut cache: std::collections::HashMap<(usize, u64), Vec<f64>> =
            std::collections::HashMap::new();
        for (img, r) in &replies {
            let rung = report
                .rung_for_epoch(r.epoch)
                .unwrap_or_else(|| panic!("reply epoch {} unknown to governor", r.epoch));
            let want = cache.entry((rung, *img)).or_insert_with(|| {
                let opts = crate::nn::ForwardOpts::with_policy(ladder.rung(rung).policy.clone());
                reference.forward(&testutil::tiny_image(*img), &opts).unwrap()
            });
            assert_eq!(
                &r.logits, want,
                "reply (epoch {}, rung {rung}, img {img}) is not bit-identical \
                 to the static forward of its rung",
                r.epoch
            );
        }
        svc.shutdown();
    }
}
