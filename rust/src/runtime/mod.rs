//! PJRT runtime: loads the AOT-compiled XLA tile kernels and runs them from
//! the rust request path (python never runs at inference time).
//!
//! `make artifacts` lowers, per multiplier family, one fixed-shape tile GEMM
//! (TM×TK×TN = 64×64×256, see python/compile/kernels/gemm.py) in two
//! variants: `pallas` (the L1 Pallas kernel, interpret-mode lowering) and
//! `fast` (identity-based jnp lowering). Interchange is **HLO text** — the
//! crate's xla_extension 0.5.1 rejects jax≥0.5 serialized protos (64-bit
//! instruction ids); the text parser reassigns ids.
//!
//! [`TileGemm`] compiles each needed executable once and caches it;
//! [`TileGemm::am_acc`] tiles an arbitrary GEMM over the fixed shape with
//! zero padding (exact: ε(w,0) = ε(0,a) = 0 and x(0) = 0 — asserted by the
//! python property tests) and accumulates the partial outputs in i64.
//!
//! ## Feature gating
//!
//! The XLA dependency only exists behind the off-by-default **`pjrt`**
//! feature. Without it this module still exports the same `TileGemm` API,
//! but `TileGemm::new` returns an error — every caller (engine, CLI,
//! benches, examples) already treats PJRT as optional, so the default build
//! is fully functional on the native engines alone.

/// Tile shape baked into the artifacts (keep in sync with kernels/gemm.py).
pub const TM: usize = 64;
pub const TK: usize = 64;
pub const TN: usize = 256;

/// True when this build can actually execute HLO (feature `pjrt`).
pub const PJRT_COMPILED: bool = cfg!(feature = "pjrt");

/// Which lowering variant to execute.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Variant {
    /// The L1 Pallas kernel lowering (interpret-mode).
    Pallas,
    /// The identity-based jnp lowering (XLA-fused fast path).
    Fast,
}

impl Variant {
    pub fn name(self) -> &'static str {
        match self {
            Variant::Pallas => "pallas",
            Variant::Fast => "fast",
        }
    }
}

/// Raw tile outputs (mirrors the kernel's 4-tuple).
pub struct TileOut {
    pub am_acc: Vec<i32>, // [TM*TN]
    pub sum_x: Vec<i32>,  // [TN]
    pub sum_a: Vec<i32>,  // [TN]
    pub sum_w: Vec<i32>,  // [TM]
}

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::TileGemm;

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::TileGemm;
