//! No-op [`TileGemm`] used when the crate is built **without** the `pjrt`
//! feature. Same API as the XLA-backed one; `new` fails with a clear
//! message, so every PJRT-optional caller degrades gracefully.

use std::path::Path;

use anyhow::{bail, Result};

use super::{TileOut, Variant};
use crate::approx::Family;

const MSG: &str = "cvapprox was built without the `pjrt` feature — \
                   rebuild with `cargo build --release --features pjrt` \
                   (and the real xla crate, see rust/vendor/xla-stub) \
                   to run the AOT XLA tile kernels";

/// Placeholder runtime handle; construction always fails.
pub struct TileGemm {
    _private: (),
}

impl TileGemm {
    pub fn new(_artifacts: &Path) -> Result<TileGemm> {
        bail!("{MSG}")
    }

    pub fn platform(&self) -> String {
        "unavailable (built without pjrt)".to_string()
    }

    pub fn warmup(&self, _family: Family, _variant: Variant) -> Result<()> {
        bail!("{MSG}")
    }

    pub fn run_tile(
        &self,
        _family: Family,
        _variant: Variant,
        _m: u32,
        _w_tile: &[i32],
        _a_tile: &[i32],
    ) -> Result<TileOut> {
        bail!("{MSG}")
    }

    #[allow(clippy::too_many_arguments)]
    pub fn am_acc(
        &self,
        _family: Family,
        _variant: Variant,
        _m: u32,
        _w: &[u8],
        _a: &[u8],
        _m_rows: usize,
        _k: usize,
        _n: usize,
    ) -> Result<(Vec<i64>, Vec<i64>)> {
        bail!("{MSG}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_reports_missing_feature() {
        let err = TileGemm::new(Path::new("/nonexistent")).err().unwrap();
        assert!(format!("{err:#}").contains("pjrt"), "{err:#}");
    }
}
