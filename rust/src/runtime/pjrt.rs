//! The XLA-backed [`TileGemm`] (feature `pjrt`). See the module docs in
//! `runtime` for the artifact/interchange story.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use super::{TileOut, Variant, TK, TM, TN};
use crate::approx::Family;
use crate::util::sync::lock_clean;

/// PJRT client + per-(family, variant) executable cache.
pub struct TileGemm {
    client: xla::PjRtClient,
    hlo_dir: PathBuf,
    cache: Mutex<HashMap<(Family, Variant), xla::PjRtLoadedExecutable>>,
}

// The PJRT CPU client/executables are driven behind &self; calls from the
// coordinator are serialized per executable by the cache Mutex.
unsafe impl Send for TileGemm {}
unsafe impl Sync for TileGemm {}

impl TileGemm {
    /// Create from the artifacts directory (expects `hlo/gemm_*.hlo.txt`).
    pub fn new(artifacts: &Path) -> Result<TileGemm> {
        let hlo_dir = artifacts.join("hlo");
        if !hlo_dir.is_dir() {
            bail!(
                "HLO artifact dir {} missing — run `make artifacts`",
                hlo_dir.display()
            );
        }
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(TileGemm { client, hlo_dir, cache: Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (and cache) the executable for one (family, variant).
    pub fn warmup(&self, family: Family, variant: Variant) -> Result<()> {
        let mut cache = lock_clean(&self.cache);
        if cache.contains_key(&(family, variant)) {
            return Ok(());
        }
        let path = self
            .hlo_dir
            .join(format!("gemm_{}_{}.hlo.txt", family.name(), variant.name()));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        cache.insert((family, variant), exe);
        Ok(())
    }

    /// Execute one padded tile. `w_tile` is [TM*TK], `a_tile` is [TK*TN].
    pub fn run_tile(
        &self,
        family: Family,
        variant: Variant,
        m: u32,
        w_tile: &[i32],
        a_tile: &[i32],
    ) -> Result<TileOut> {
        assert_eq!(w_tile.len(), TM * TK);
        assert_eq!(a_tile.len(), TK * TN);
        self.warmup(family, variant)?;
        let cache = lock_clean(&self.cache);
        let exe = cache.get(&(family, variant)).unwrap();
        let m_lit = xla::Literal::vec1(&[m as i32]);
        let w_lit = xla::Literal::vec1(w_tile).reshape(&[TM as i64, TK as i64])?;
        let a_lit = xla::Literal::vec1(a_tile).reshape(&[TK as i64, TN as i64])?;
        let result = exe.execute::<xla::Literal>(&[m_lit, w_lit, a_lit])?[0][0]
            .to_literal_sync()?;
        let parts = result.to_tuple()?;
        if parts.len() != 4 {
            bail!("expected 4 outputs, got {}", parts.len());
        }
        let mut it = parts.into_iter();
        Ok(TileOut {
            am_acc: it.next().unwrap().to_vec::<i32>()?,
            sum_x: it.next().unwrap().to_vec::<i32>()?,
            sum_a: it.next().unwrap().to_vec::<i32>()?,
            sum_w: it.next().unwrap().to_vec::<i32>()?,
        })
    }

    /// Full AM-accumulation GEMM over arbitrary shapes by tiling + padding.
    ///
    /// Returns (am_acc [m_rows*n], sum_x [n]) in i64 — the same quantities
    /// the native engines produce, so the caller's epilogue is shared.
    #[allow(clippy::too_many_arguments)]
    pub fn am_acc(
        &self,
        family: Family,
        variant: Variant,
        m: u32,
        w: &[u8],
        a: &[u8],
        m_rows: usize,
        k: usize,
        n: usize,
    ) -> Result<(Vec<i64>, Vec<i64>)> {
        let mut am_acc = vec![0i64; m_rows * n];
        let mut sum_x = vec![0i64; n];
        let mut w_tile = vec![0i32; TM * TK];
        let mut a_tile = vec![0i32; TK * TN];
        for n0 in (0..n).step_by(TN) {
            let nlen = TN.min(n - n0);
            for k0 in (0..k).step_by(TK) {
                let klen = TK.min(k - k0);
                // pack A tile (zero-padded; padding is error-free)
                a_tile.fill(0);
                for kk in 0..klen {
                    let src = &a[(k0 + kk) * n + n0..(k0 + kk) * n + n0 + nlen];
                    for (j, &v) in src.iter().enumerate() {
                        a_tile[kk * TN + j] = v as i32;
                    }
                }
                for f0 in (0..m_rows).step_by(TM) {
                    let flen = TM.min(m_rows - f0);
                    w_tile.fill(0);
                    for f in 0..flen {
                        let src = &w[(f0 + f) * k + k0..(f0 + f) * k + k0 + klen];
                        for (j, &v) in src.iter().enumerate() {
                            w_tile[f * TK + j] = v as i32;
                        }
                    }
                    let out = self.run_tile(family, variant, m, &w_tile, &a_tile)?;
                    for f in 0..flen {
                        let orow =
                            &mut am_acc[(f0 + f) * n + n0..(f0 + f) * n + n0 + nlen];
                        let trow = &out.am_acc[f * TN..f * TN + nlen];
                        for (o, &t) in orow.iter_mut().zip(trow) {
                            *o += t as i64;
                        }
                    }
                    if f0 == 0 {
                        for j in 0..nlen {
                            sum_x[n0 + j] += out.sum_x[j] as i64;
                        }
                    }
                }
            }
        }
        Ok((am_acc, sum_x))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::artifacts_dir;
    use crate::nn::gemm::am_acc_identity;
    use crate::util::rng::Rng;

    fn runtime() -> Option<TileGemm> {
        let art = artifacts_dir();
        if !art.join("hlo").is_dir() {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        match TileGemm::new(&art) {
            Ok(rt) => Some(rt),
            Err(e) => {
                // With the vendored xla stub the client cannot start; that is
                // an environment limitation, not a test failure.
                eprintln!("skipping: PJRT client unavailable ({e:#})");
                None
            }
        }
    }

    #[test]
    fn fast_variant_matches_native_identity_engine() {
        let Some(rt) = runtime() else { return };
        let mut rng = Rng::new(0xF00D);
        for family in Family::ALL {
            let m = *family.paper_levels().last().unwrap();
            // deliberately non-tile-aligned shapes
            let (m_rows, k, n) = (10, 70, 33);
            let w: Vec<u8> = (0..m_rows * k).map(|_| rng.u8()).collect();
            let a: Vec<u8> = (0..k * n).map(|_| rng.u8()).collect();
            let (got, sum_x) = rt
                .am_acc(family, Variant::Fast, m, &w, &a, m_rows, k, n)
                .expect("pjrt gemm");
            let want = am_acc_identity(family, m, &w, &a, m_rows, k, n);
            assert_eq!(got, want, "{} m={m}", family.name());
            let want_sx: i64 = a
                .chunks(n)
                .map(|row| {
                    row.iter()
                        .map(|&v| crate::approx::xvar(family, v, m) as i64)
                        .sum::<i64>()
                })
                .sum();
            assert_eq!(sum_x.iter().sum::<i64>(), want_sx);
        }
    }

    #[test]
    fn pallas_variant_matches_fast_variant() {
        let Some(rt) = runtime() else { return };
        let mut rng = Rng::new(0xBA11);
        let (m_rows, k, n) = (TM, TK, TN); // one exact tile
        let w: Vec<u8> = (0..m_rows * k).map(|_| rng.u8()).collect();
        let a: Vec<u8> = (0..k * n).map(|_| rng.u8()).collect();
        for family in [Family::Perforated, Family::Truncated] {
            let m = family.paper_levels()[1];
            let (fast, sxf) =
                rt.am_acc(family, Variant::Fast, m, &w, &a, m_rows, k, n).unwrap();
            let (pallas, sxp) =
                rt.am_acc(family, Variant::Pallas, m, &w, &a, m_rows, k, n).unwrap();
            assert_eq!(fast, pallas, "{} m={m}", family.name());
            assert_eq!(sxf, sxp);
        }
    }

    #[test]
    fn one_executable_serves_all_m() {
        let Some(rt) = runtime() else { return };
        let mut rng = Rng::new(1);
        let (m_rows, k, n) = (4, 16, 8);
        let w: Vec<u8> = (0..m_rows * k).map(|_| rng.u8()).collect();
        let a: Vec<u8> = (0..k * n).map(|_| rng.u8()).collect();
        for m in [1u32, 2, 3] {
            let (got, _) = rt
                .am_acc(Family::Perforated, Variant::Fast, m, &w, &a, m_rows, k, n)
                .unwrap();
            let want = am_acc_identity(Family::Perforated, m, &w, &a, m_rows, k, n);
            assert_eq!(got, want, "m={m}");
        }
    }
}
