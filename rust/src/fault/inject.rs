//! Deterministic fault injection.
//!
//! A [`FaultPlan`] is the serving plane's chaos source: each batch a worker
//! picks up draws one [`BatchFaults`] decision from a PRNG seeded by
//! `seed ^ f(batch_seq)`, so a given seed produces the same fault schedule
//! across runs regardless of thread interleaving — the batch sequence
//! number, not wall-clock, indexes the schedule. Probabilities are per
//! mille per batch; everything is off (and free) when no plan is attached.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::util::rng::Rng;

/// Fault-injection configuration. All probabilities are per-mille (‰) per
/// batch; `0` everywhere still enables *chaos mode* in the service (per-
/// batch checksum verification) without spontaneous faults.
#[derive(Clone, Debug)]
pub struct FaultConfig {
    /// Schedule seed — the only mandatory knob.
    pub seed: u64,
    /// ‰ chance per batch of burst-flipping a bit range in a prepared LUT.
    pub lut_flip_per_mille: u32,
    /// ‰ chance per batch of flipping a bit in a cached plan panel.
    pub plan_flip_per_mille: u32,
    /// ‰ chance per batch of the worker panicking mid-batch.
    pub panic_per_mille: u32,
    /// ‰ chance per batch of an injected latency spike.
    pub spike_per_mille: u32,
    /// Duration of an injected spike.
    pub spike: Duration,
    /// ‰ chance per batch of dropping every reply of the batch (clients
    /// observe a closed channel, mapped to a typed error — never a hang).
    pub drop_per_mille: u32,
}

impl FaultConfig {
    /// The default chaos mix used by the chaos bench and `from_env`.
    pub fn chaos(seed: u64) -> FaultConfig {
        FaultConfig {
            seed,
            lut_flip_per_mille: 10,
            plan_flip_per_mille: 5,
            panic_per_mille: 10,
            spike_per_mille: 10,
            spike: Duration::from_millis(2),
            drop_per_mille: 5,
        }
    }

    /// Chaos mode on (per-batch integrity verification in the service) but
    /// no spontaneous faults — for targeted corruption tests.
    pub fn quiet(seed: u64) -> FaultConfig {
        FaultConfig {
            seed,
            lut_flip_per_mille: 0,
            plan_flip_per_mille: 0,
            panic_per_mille: 0,
            spike_per_mille: 0,
            spike: Duration::ZERO,
            drop_per_mille: 0,
        }
    }

    /// Build from `CVAPPROX_FAULT_*` env knobs. `None` unless
    /// `CVAPPROX_FAULT_SEED` is set (injection is strictly opt-in):
    ///
    /// * `CVAPPROX_FAULT_SEED` — schedule seed (enables injection)
    /// * `CVAPPROX_FAULT_LUT` / `_PLAN` / `_PANIC` / `_SPIKE` / `_DROP` —
    ///   per-mille rates (defaults: the [`FaultConfig::chaos`] mix)
    /// * `CVAPPROX_FAULT_SPIKE_MS` — spike length in ms (default 2)
    pub fn from_env() -> Option<FaultConfig> {
        let seed = env_u64("CVAPPROX_FAULT_SEED")?;
        let mut cfg = FaultConfig::chaos(seed);
        if let Some(v) = env_u64("CVAPPROX_FAULT_LUT") {
            cfg.lut_flip_per_mille = v.min(1000) as u32;
        }
        if let Some(v) = env_u64("CVAPPROX_FAULT_PLAN") {
            cfg.plan_flip_per_mille = v.min(1000) as u32;
        }
        if let Some(v) = env_u64("CVAPPROX_FAULT_PANIC") {
            cfg.panic_per_mille = v.min(1000) as u32;
        }
        if let Some(v) = env_u64("CVAPPROX_FAULT_SPIKE") {
            cfg.spike_per_mille = v.min(1000) as u32;
        }
        if let Some(v) = env_u64("CVAPPROX_FAULT_SPIKE_MS") {
            cfg.spike = Duration::from_millis(v);
        }
        if let Some(v) = env_u64("CVAPPROX_FAULT_DROP") {
            cfg.drop_per_mille = v.min(1000) as u32;
        }
        Some(cfg)
    }
}

fn env_u64(key: &str) -> Option<u64> {
    std::env::var(key).ok().and_then(|v| v.trim().parse().ok())
}

/// LUT burst fault: flip `bit` in `span` consecutive entries starting at
/// `entry` of the `pick`-th prepared table.
#[derive(Clone, Copy, Debug)]
pub struct LutFault {
    pub pick: u64,
    pub entry: usize,
    pub span: usize,
    pub bit: u32,
}

/// Plan panel fault: flip `bit` of byte `byte` in the `pick`-th cached plan.
#[derive(Clone, Copy, Debug)]
pub struct PlanFault {
    pub pick: u64,
    pub byte: usize,
    pub bit: u32,
}

/// The fault decision for one batch.
#[derive(Clone, Copy, Debug, Default)]
pub struct BatchFaults {
    pub lut: Option<LutFault>,
    pub plan: Option<PlanFault>,
    pub panic: bool,
    pub spike: Option<Duration>,
    pub drop_replies: bool,
}

impl BatchFaults {
    pub fn any(&self) -> bool {
        self.lut.is_some()
            || self.plan.is_some()
            || self.panic
            || self.spike.is_some()
            || self.drop_replies
    }
}

/// Seeded per-batch fault schedule, shared across a worker pool.
#[derive(Debug)]
pub struct FaultPlan {
    cfg: FaultConfig,
    seq: AtomicU64,
}

impl FaultPlan {
    pub fn new(cfg: FaultConfig) -> FaultPlan {
        FaultPlan { cfg, seq: AtomicU64::new(0) }
    }

    pub fn config(&self) -> &FaultConfig {
        &self.cfg
    }

    /// Batches drawn so far.
    pub fn batches(&self) -> u64 {
        self.seq.load(Ordering::Relaxed)
    }

    /// Draw the fault decision for the next batch. The decision depends
    /// only on `(seed, batch_seq)`, so schedules replay exactly.
    pub fn next_batch(&self) -> BatchFaults {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        self.for_seq(seq)
    }

    fn for_seq(&self, seq: u64) -> BatchFaults {
        let mut r = Rng::new(self.cfg.seed ^ seq.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut f = BatchFaults::default();
        if r.below(1000) < self.cfg.lut_flip_per_mille as u64 {
            f.lut = Some(LutFault {
                pick: r.next_u64(),
                entry: r.below(65536) as usize,
                // Burst of up to a full weight row: a single poisoned entry
                // may never be hit by live operands, a burst usually is.
                span: 1 + r.below(256) as usize,
                bit: 16 + r.below(14) as u32, // high bits => loud corruption
            });
        }
        if r.below(1000) < self.cfg.plan_flip_per_mille as u64 {
            f.plan = Some(PlanFault {
                pick: r.next_u64(),
                byte: r.below(1 << 20) as usize,
                bit: r.below(8) as u32,
            });
        }
        if r.below(1000) < self.cfg.panic_per_mille as u64 {
            f.panic = true;
        }
        if r.below(1000) < self.cfg.spike_per_mille as u64 {
            f.spike = Some(self.cfg.spike);
        }
        if r.below(1000) < self.cfg.drop_per_mille as u64 {
            f.drop_replies = true;
        }
        f
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_is_deterministic_per_seed() {
        let a = FaultPlan::new(FaultConfig::chaos(42));
        let b = FaultPlan::new(FaultConfig::chaos(42));
        for _ in 0..500 {
            let (fa, fb) = (a.next_batch(), b.next_batch());
            assert_eq!(fa.panic, fb.panic);
            assert_eq!(fa.drop_replies, fb.drop_replies);
            assert_eq!(fa.spike, fb.spike);
            let key = |l: LutFault| (l.entry, l.span, l.bit);
            assert_eq!(fa.lut.map(key), fb.lut.map(key));
            assert_eq!(fa.plan.map(|p| (p.byte, p.bit)), fb.plan.map(|p| (p.byte, p.bit)));
        }
        assert_eq!(a.batches(), 500);
    }

    #[test]
    fn chaos_mix_actually_fires_each_class() {
        let plan = FaultPlan::new(FaultConfig::chaos(7));
        let mut seen = (false, false, false, false, false);
        for _ in 0..4000 {
            let f = plan.next_batch();
            seen.0 |= f.lut.is_some();
            seen.1 |= f.plan.is_some();
            seen.2 |= f.panic;
            seen.3 |= f.spike.is_some();
            seen.4 |= f.drop_replies;
        }
        assert!(seen.0 && seen.1 && seen.2 && seen.3 && seen.4, "{seen:?}");
    }

    #[test]
    fn quiet_config_never_fires() {
        let plan = FaultPlan::new(FaultConfig::quiet(3));
        for _ in 0..1000 {
            assert!(!plan.next_batch().any());
        }
    }

    #[test]
    fn lut_faults_use_loud_high_bits() {
        let plan = FaultPlan::new(FaultConfig {
            lut_flip_per_mille: 1000,
            ..FaultConfig::quiet(11)
        });
        for _ in 0..200 {
            let f = plan.next_batch().lut.expect("rate 1000\u{2030} always fires");
            assert!((16..30).contains(&f.bit));
            assert!(f.span >= 1 && f.span <= 256);
            assert!(f.entry < 65536);
        }
    }

    #[test]
    fn env_config_requires_seed() {
        // No CVAPPROX_FAULT_SEED in the test environment => disabled.
        if std::env::var("CVAPPROX_FAULT_SEED").is_err() {
            assert!(FaultConfig::from_env().is_none());
        }
    }
}
