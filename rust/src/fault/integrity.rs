//! CV-residual integrity monitoring.
//!
//! The control variate V = C·ΣX + C₀ is the engine's *online estimate of
//! the accumulated multiplier error*; the QoS layer already samples mean
//! |V| / |G*| per layer as an error proxy. On healthy hardware that ratio
//! is pinned to the approximation point's offline error profile — the
//! exhaustive signed moments of `approx::stats` — so a live ratio that
//! leaves a (generous) band around the offline expectation is evidence
//! that the products feeding G* are *not* the products the profile was
//! computed for: a corrupted LUT or weight panel. The monitor is the cheap
//! always-on tier of detection (a few float ops per layer per batch);
//! checksum recomputation (`Engine::verify_integrity`) arbitrates every
//! alarm, so false positives cost one sweep and never a wrong heal.
//!
//! The band is deliberately wide (`slack` = 64× each way by default): live
//! activations are not the uniform operands of the offline profile, and
//! the denominator carries bias/zero-point mass. Sparse per-batch sampling
//! (a handful of epilogue entries per layer) plus burst corruption of high
//! LUT bits moves the ratio by *orders of magnitude*, so a wide band still
//! detects everything loud while staying quiet on healthy traffic.

use crate::approx::stats::signed_moments;
use crate::approx::Family;
use crate::nn::{LayerAssignment, LayerPoint};

/// Expected |w·a| of uniform u8 operands — the scale the offline moments
/// are normalized against.
const E_PROD: f64 = 127.5 * 127.5;

/// Acceptance band for one layer's live mean |V|/|G*| ratio.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ProxyBand {
    pub floor: f64,
    pub ceil: f64,
}

impl ProxyBand {
    pub fn contains(&self, ratio: f64) -> bool {
        ratio >= self.floor && ratio <= self.ceil
    }
}

/// Per-layer CV-residual band monitor.
#[derive(Clone, Debug)]
pub struct IntegrityMonitor {
    /// Multiplicative band width (each side) around the offline estimate.
    pub slack: f64,
    /// Minimum samples in a window before the band is enforced.
    pub min_samples: u64,
}

impl Default for IntegrityMonitor {
    fn default() -> Self {
        IntegrityMonitor { slack: 64.0, min_samples: 8 }
    }
}

impl IntegrityMonitor {
    pub fn new() -> IntegrityMonitor {
        IntegrityMonitor::default()
    }

    /// The acceptance band for one layer assignment, or `None` when the
    /// assignment yields no band-checkable signal:
    ///
    /// * exact layers and CV-off layers record no samples;
    /// * paired layers do sample, but their halves cancel by construction,
    ///   so only a ceiling is enforced (floor 0) — the checksum sweep
    ///   remains their corruption backstop.
    pub fn band_for(&self, assign: LayerAssignment) -> Option<ProxyBand> {
        match assign.normalized() {
            LayerAssignment::Point(p) => {
                if p == LayerPoint::EXACT || !p.use_cv || p.family == Family::Exact || p.m == 0 {
                    return None;
                }
                let est = point_ratio_estimate(p);
                Some(ProxyBand { floor: est / self.slack, ceil: est * self.slack })
            }
            LayerAssignment::Paired(pp) => {
                let (e, o) = (pp.even.normalized(), pp.odd.normalized());
                if !e.use_cv && !o.use_cv {
                    return None;
                }
                let est = point_ratio_estimate(e).max(point_ratio_estimate(o));
                if est == 0.0 {
                    return None;
                }
                Some(ProxyBand { floor: 0.0, ceil: est * self.slack })
            }
        }
    }

    /// Band-check one batch's raw proxy sums (`(Σ|V|, Σ|G*|, n)` per MAC
    /// layer, from `CvProxySampler::drain_raw`) against the policy of that
    /// batch; returns the indices of out-of-band (suspect) layers.
    pub fn suspects(
        &self,
        raw: &[(u64, u64, u64)],
        assign: impl Fn(usize) -> LayerAssignment,
    ) -> Vec<usize> {
        let mut out = Vec::new();
        for (i, &(num, den, n)) in raw.iter().enumerate() {
            if n < self.min_samples || den == 0 {
                continue;
            }
            if let Some(band) = self.band_for(assign(i)) {
                let ratio = num as f64 / den as f64;
                if !band.contains(ratio) {
                    out.push(i);
                }
            }
        }
        out
    }
}

/// Offline estimate of a point's |V|/|G*| scale: the magnitude of its
/// per-product error moments over the uniform-operand product scale.
fn point_ratio_estimate(p: LayerPoint) -> f64 {
    let p = p.normalized();
    if p.family == Family::Exact || p.m == 0 {
        return 0.0;
    }
    let sm = signed_moments(p.family, p.m, p.polarity);
    (sm.mean.abs() + sm.std) / E_PROD
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::Polarity;
    use crate::nn::PairedPoint;

    fn pt(family: Family, m: u32) -> LayerPoint {
        LayerPoint::new(family, m, true)
    }

    #[test]
    fn exact_and_cv_off_layers_have_no_band() {
        let mon = IntegrityMonitor::new();
        assert!(mon.band_for(LayerAssignment::Point(LayerPoint::EXACT)).is_none());
        let mut nocv = pt(Family::Perforated, 3);
        nocv.use_cv = false;
        assert!(mon.band_for(LayerAssignment::Point(nocv)).is_none());
    }

    #[test]
    fn band_brackets_the_offline_estimate() {
        let mon = IntegrityMonitor::new();
        let p = pt(Family::Perforated, 3);
        let band = mon.band_for(LayerAssignment::Point(p)).unwrap();
        let est = point_ratio_estimate(p);
        assert!(est > 0.0);
        assert!(band.floor < est && est < band.ceil);
        assert!(band.contains(est));
        assert!(!band.contains(est / (mon.slack * 10.0)), "collapse is out of band");
        assert!(!band.contains(est * mon.slack * 10.0), "blowup is out of band");
    }

    #[test]
    fn band_grows_with_m() {
        let mon = IntegrityMonitor::new();
        let lo = point_ratio_estimate(pt(Family::Perforated, 1));
        let hi = point_ratio_estimate(pt(Family::Perforated, 5));
        assert!(hi > lo, "more perforation => larger residual scale");
        let b = mon.band_for(LayerAssignment::Point(pt(Family::Perforated, 5))).unwrap();
        assert!(b.ceil > b.floor);
    }

    #[test]
    fn paired_band_is_ceiling_only() {
        let mon = IntegrityMonitor::new();
        let pair = PairedPoint::mirrored(Family::Perforated, 2, true);
        let band = mon.band_for(LayerAssignment::Paired(pair)).unwrap();
        assert_eq!(band.floor, 0.0);
        assert!(band.ceil > 0.0);
        assert!(band.contains(0.0), "cancelled residual is healthy for pairs");
    }

    #[test]
    fn suspects_flags_only_sampled_out_of_band_layers() {
        let mon = IntegrityMonitor::new();
        let p = pt(Family::Perforated, 3);
        let band = mon.band_for(LayerAssignment::Point(p)).unwrap();
        let healthy = (band.floor * 2.0 + band.ceil / 2.0) / 2.0;
        // Layer 0 healthy, layer 1 collapsed (den huge), layer 2 unsampled.
        let raw = vec![
            ((healthy * 1e9) as u64, 1_000_000_000, 16),
            (1, 1_000_000_000, 16),
            (0, 0, 0),
        ];
        let out = mon.suspects(&raw, |_| LayerAssignment::Point(p));
        assert_eq!(out, vec![1]);
        // Below min_samples nothing is flagged.
        let thin = vec![(1, 1_000_000_000, 2)];
        assert!(mon.suspects(&thin, |_| LayerAssignment::Point(p)).is_empty());
    }

    #[test]
    fn polarity_profiles_are_respected() {
        // Pos and Neg points of the same family/m can have different
        // moment profiles; the estimate must consult the right one.
        let neg = point_ratio_estimate(LayerPoint::new_pol(
            Family::Truncated,
            4,
            Polarity::Neg,
            true,
        ));
        let pos = point_ratio_estimate(LayerPoint::new_pol(
            Family::Truncated,
            4,
            Polarity::Pos,
            true,
        ));
        assert!(neg > 0.0 && pos > 0.0);
    }
}
