//! Supervision primitives: restart backoff and bounded retry.
//!
//! Used by the coordinator's supervisor thread (worker respawn pacing) and
//! by the client-side `infer_with_retry` helper. Kept deliberately tiny and
//! synchronous — the serving plane is plain threads, so the backoff is a
//! plain `thread::sleep`.

use std::thread;
use std::time::Duration;

/// Exponential backoff: starts at `base`, doubles per step, capped at `max`.
#[derive(Clone, Debug)]
pub struct Backoff {
    base: Duration,
    max: Duration,
    next: Duration,
}

impl Backoff {
    pub fn new(base: Duration, max: Duration) -> Backoff {
        Backoff { base, max, next: base }
    }

    /// The delay to apply for the current step; advances the schedule.
    pub fn next_delay(&mut self) -> Duration {
        let d = self.next;
        self.next = (self.next * 2).min(self.max);
        d
    }

    /// Reset back to the base delay (call after a success).
    pub fn reset(&mut self) {
        self.next = self.base;
    }
}

/// Run `f` up to `attempts` times, sleeping `backoff` between attempts.
/// Stops early on success or on an error `retryable` rejects; the last
/// error is returned when every attempt fails.
pub fn retry<T, E>(
    attempts: usize,
    backoff: &mut Backoff,
    retryable: impl Fn(&E) -> bool,
    mut f: impl FnMut() -> Result<T, E>,
) -> Result<T, E> {
    let attempts = attempts.max(1);
    let mut tried = 0usize;
    loop {
        match f() {
            Ok(v) => return Ok(v),
            Err(e) => {
                tried += 1;
                if !retryable(&e) || tried == attempts {
                    return Err(e);
                }
                thread::sleep(backoff.next_delay());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_doubles_and_caps() {
        let mut b = Backoff::new(Duration::from_millis(1), Duration::from_millis(5));
        assert_eq!(b.next_delay(), Duration::from_millis(1));
        assert_eq!(b.next_delay(), Duration::from_millis(2));
        assert_eq!(b.next_delay(), Duration::from_millis(4));
        assert_eq!(b.next_delay(), Duration::from_millis(5));
        assert_eq!(b.next_delay(), Duration::from_millis(5));
        b.reset();
        assert_eq!(b.next_delay(), Duration::from_millis(1));
    }

    #[test]
    fn retry_succeeds_after_transient_errors() {
        let mut b = Backoff::new(Duration::from_micros(10), Duration::from_micros(10));
        let mut calls = 0;
        let out: Result<u32, &str> = retry(5, &mut b, |_| true, || {
            calls += 1;
            if calls < 3 {
                Err("transient")
            } else {
                Ok(99)
            }
        });
        assert_eq!(out, Ok(99));
        assert_eq!(calls, 3);
    }

    #[test]
    fn retry_stops_on_non_retryable() {
        let mut b = Backoff::new(Duration::from_micros(10), Duration::from_micros(10));
        let mut calls = 0;
        let out: Result<u32, &str> = retry(5, &mut b, |e| *e != "fatal", || {
            calls += 1;
            Err("fatal")
        });
        assert_eq!(out, Err("fatal"));
        assert_eq!(calls, 1);
    }

    #[test]
    fn retry_exhausts_attempts() {
        let mut b = Backoff::new(Duration::from_micros(10), Duration::from_micros(10));
        let mut calls = 0;
        let out: Result<u32, &str> = retry(3, &mut b, |_| true, || {
            calls += 1;
            Err("transient")
        });
        assert_eq!(out, Err("transient"));
        assert_eq!(calls, 3);
    }
}
