//! Fault injection, integrity checking and supervision primitives.
//!
//! The paper's premise is that the multiplier hardware computes *wrong
//! products on purpose*; this module is about the products (and the serving
//! plane around them) going wrong **by accident** — a flipped SRAM bit in a
//! cached LUT or packed weight panel, a panicking worker, a latency spike, a
//! lost reply. Three pieces cooperate:
//!
//! * [`inject`] — a seeded, deterministic [`FaultPlan`] (the chaos analog of
//!   the hermetic golden generator): off by default, zero overhead when
//!   disabled, reproducible batch-by-batch fault schedules when enabled via
//!   builder or `CVAPPROX_FAULT_*` env knobs.
//! * [`integrity`] — the detection side: build-time checksums live on
//!   `MulLut` / `LayerPlan` (see `util::hash`), and the
//!   [`IntegrityMonitor`] turns the live CV-residual proxy (mean |V|/|G*|
//!   from `qos::Telemetry`) into a runtime integrity signature by banding it
//!   against the offline signed-moment profiles from `approx::stats` — the
//!   paper's accuracy mechanism reused as a fault detector.
//! * [`supervise`] — restart backoff and retry helpers used by the
//!   coordinator's supervisor thread and client-side retry path.
//!
//! Healing itself lives where the state lives: `Engine::heal_integrity`
//! rebuilds corrupt LUTs from the structural bitmodel and drops poisoned
//! plans for rebuild from pristine weights; `coordinator::service` replays
//! the affected batch so no silently-corrupted reply ever leaves the pool.

pub mod inject;
pub mod integrity;
pub mod supervise;

pub use inject::{BatchFaults, FaultConfig, FaultPlan, LutFault, PlanFault};
pub use integrity::{IntegrityMonitor, ProxyBand};
pub use supervise::{Backoff, retry};
