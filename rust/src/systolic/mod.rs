//! Cycle-level model of the paper's systolic MAC array (Figs 5-6).
//!
//! Weight-stationary N×N array + the extra MAC⁺ column:
//!
//! * each **MAC\*** in row f, column h holds weight W[f, h]; activation
//!   columns stream in skewed; partial sums flow left→right through the
//!   `sum` chain while the side `sumX` chain accumulates Σx(A) in parallel
//!   (eqs. 33-35);
//! * the **MAC⁺** column multiplies C_f·ΣX, adds C₀ and the bias LSBs via
//!   the {sum, B[m-1:0]} concatenation (eqs. 36-37).
//!
//! The simulator is bit-exact (drives the same [`crate::approx`] multiplier
//! models, via LUT, exactly like the RTL would) and counts **bit toggles**
//! on every register, which feeds the dynamic-power side of the
//! [`crate::hw`] cost model — our stand-in for the paper's Questasim
//! back-annotated switching activity (DESIGN.md §2). Functional equivalence
//! against the direct GEMM engine is asserted by tests, proving the
//! *hardware* computes exactly what the fast engine computes.

use crate::approx::{xvar_pol, Family, MulLut, Polarity};
use crate::cv::{self, CvConstants};

/// One multiplier configuration of an array column population.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MulPoint {
    pub family: Family,
    pub m: u32,
    pub pol: Polarity,
}

impl MulPoint {
    pub fn new(family: Family, m: u32, pol: Polarity) -> MulPoint {
        MulPoint { family, m, pol }
    }

    pub fn exact() -> MulPoint {
        MulPoint { family: Family::Exact, m: 0, pol: Polarity::Neg }
    }

    pub fn describe(self) -> String {
        if self.family == Family::Exact {
            "exact".to_string()
        } else {
            format!(
                "{} m={}{}",
                self.family.name(),
                self.m,
                if self.pol == Polarity::Pos { " pos" } else { "" }
            )
        }
    }
}

/// Per-run toggle/energy statistics from the simulator.
#[derive(Clone, Debug, Default)]
pub struct ToggleStats {
    /// Total bit flips in product/sum registers.
    pub datapath_toggles: u64,
    /// Total bit flips in the sumX side chain.
    pub sumx_toggles: u64,
    /// Total bit flips in the MAC+ column registers.
    pub mac_plus_toggles: u64,
    /// MAC cycles simulated.
    pub cycles: u64,
}

impl ToggleStats {
    pub fn merge(&mut self, o: &ToggleStats) {
        self.datapath_toggles += o.datapath_toggles;
        self.sumx_toggles += o.sumx_toggles;
        self.mac_plus_toggles += o.mac_plus_toggles;
        self.cycles += o.cycles;
    }

    /// Mean toggles per cycle (activity proxy for the power model).
    pub fn activity(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            (self.datapath_toggles + self.sumx_toggles + self.mac_plus_toggles) as f64
                / self.cycles as f64
        }
    }
}

fn popcount_diff(a: i64, b: i64) -> u32 {
    (a ^ b).count_ones()
}

/// The systolic array configured for one design point per column parity:
/// uniform arrays carry the same [`MulPoint`] in both populations; a
/// **paired** array alternates multipliers column by column (even columns =
/// `even`, odd = `odd`) — the positive/negative layout that cancels
/// accumulated column error in the sum chain itself.
pub struct SystolicArray {
    pub even: MulPoint,
    pub odd: MulPoint,
    /// Array dimension N (rows = filters, columns = reduction index).
    pub n: usize,
    lut_even: Option<MulLut>,
    lut_odd: Option<MulLut>,
}

impl SystolicArray {
    /// Uniform negative-polarity array (the paper's configuration).
    pub fn new(family: Family, m: u32, n: usize) -> SystolicArray {
        SystolicArray::new_pol(family, m, Polarity::Neg, n)
    }

    /// Uniform array at an explicit-polarity point.
    pub fn new_pol(family: Family, m: u32, pol: Polarity, n: usize) -> SystolicArray {
        let pt = MulPoint::new(family, m, pol);
        SystolicArray::new_paired(pt, pt, n)
    }

    /// Array with alternating even/odd multiplier columns.
    pub fn new_paired(even: MulPoint, odd: MulPoint, n: usize) -> SystolicArray {
        let build = |p: MulPoint| {
            if p.family == Family::Exact {
                None
            } else {
                Some(MulLut::build_pol(p.family, p.m, p.pol))
            }
        };
        let lut_even = build(even);
        let lut_odd = if odd == even { None } else { build(odd) };
        SystolicArray { even, odd, n, lut_even, lut_odd }
    }

    /// Do the two column populations differ?
    pub fn is_paired(&self) -> bool {
        self.even != self.odd
    }

    pub fn describe(&self) -> String {
        if self.is_paired() {
            format!("paired {} / {}", self.even.describe(), self.odd.describe())
        } else {
            self.even.describe()
        }
    }

    /// The point owning global reduction column `k_global`.
    #[inline]
    fn point_at(&self, k_global: usize) -> MulPoint {
        if k_global % 2 == 0 {
            self.even
        } else {
            self.odd
        }
    }

    #[inline]
    fn mul(&self, k_global: usize, w: u8, a: u8) -> i64 {
        let lut = if k_global % 2 == 0 || !self.is_paired() {
            &self.lut_even
        } else {
            &self.lut_odd
        };
        match lut {
            Some(l) => l.mul(w, a) as i64,
            None => (w as i64) * (a as i64),
        }
    }

    /// Run one weight tile against a stream of activation columns.
    ///
    /// * `weights`: row-major [rows][k] (rows ≤ N filters, k ≤ N reduction)
    /// * `act_cols`: each entry is one activation column `[k]` (a GEMM rhs
    ///   column, streamed over k cycles in hardware; simulated per-column)
    /// * `consts`: per-row CV constants (Q.4); `apply_cv` enables the MAC⁺
    ///   column (uniform arrays only — a paired array's per-partition V
    ///   terms are applied by the engine after all K tiles).
    /// * `k0`: global reduction offset of this tile — a paired array picks
    ///   each column's multiplier by the **global** parity `k0 + kk`, so
    ///   tiling never flips the column population.
    ///
    /// Returns (outputs[col][row] accumulators, toggle stats). Outputs
    /// exclude zero-point/bias handling — the engine layer owns those, same
    /// as for the fast GEMM, so equivalence can be asserted directly.
    pub fn run_tile(
        &self,
        weights: &[Vec<u8>],
        act_cols: &[Vec<u8>],
        consts: &[CvConstants],
        apply_cv: bool,
        k0: usize,
    ) -> (Vec<Vec<i64>>, ToggleStats) {
        let rows = weights.len();
        assert!(rows <= self.n, "more filter rows than array rows");
        assert!(
            !(apply_cv && self.is_paired()),
            "paired arrays apply their per-partition V outside run_tile"
        );
        let mut stats = ToggleStats::default();
        let mut outputs = Vec::with_capacity(act_cols.len());
        // Register state carried cycle to cycle (for toggle counting). A
        // paired array keeps one sumX side chain per column population
        // (each partition regresses on its own x), so toggles are counted
        // on two registers; a uniform array has the single chain of the
        // paper's design (lane 0).
        let mut prod_reg = vec![0i64; rows];
        let mut sum_reg = vec![0i64; rows];
        let mut sumx_reg = [0i64; 2];
        let mut v_reg: i64 = 0;
        for col in act_cols {
            assert!(col.len() <= self.n, "reduction dim exceeds array width");
            // One output column: each row's MAC chain accumulates over k.
            // (Hardware skews this over k cycles; dataflow-equivalent.)
            let mut out_col = vec![0i64; rows];
            let mut sumx = [0i64; 2];
            for (kk, &a) in col.iter().enumerate() {
                stats.cycles += 1;
                for (f, w_row) in weights.iter().enumerate() {
                    let p = self.mul(k0 + kk, w_row[kk], a);
                    let acc = out_col[f] + p;
                    stats.datapath_toggles += (popcount_diff(prod_reg[f], p)
                        + popcount_diff(sum_reg[f], acc))
                        as u64;
                    prod_reg[f] = p;
                    sum_reg[f] = acc;
                    out_col[f] = acc;
                }
                let pt = self.point_at(k0 + kk);
                let lane = if self.is_paired() { (k0 + kk) % 2 } else { 0 };
                let x = xvar_pol(pt.family, pt.pol, a, pt.m) as i64;
                let nx = sumx[lane] + x;
                stats.sumx_toggles += popcount_diff(sumx_reg[lane], nx) as u64;
                sumx_reg[lane] = nx;
                sumx[lane] = nx;
            }
            if apply_cv && self.even.family != Family::Exact {
                for (f, c) in consts.iter().take(rows).enumerate() {
                    let v = cv::v_term(c, sumx[0]);
                    stats.mac_plus_toggles += popcount_diff(v_reg, v) as u64;
                    v_reg = v;
                    out_col[f] += v;
                }
            }
            outputs.push(out_col);
        }
        (outputs, stats)
    }

    /// Latency in cycles to stream `n_cols` outputs through the array
    /// (paper §4.4: fill + drain + one extra cycle for the MAC⁺ column).
    pub fn latency_cycles(&self, k: usize, n_cols: usize) -> u64 {
        let fill = self.n as u64; // skew fill
        let stream = (k.max(1) as u64) * n_cols as u64;
        let exact = self.even.family == Family::Exact && self.odd.family == Family::Exact;
        let mac_plus = if exact { 0 } else { 1 };
        fill + stream + mac_plus
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::am;
    use crate::util::rng::Rng;

    fn direct_gemm(
        family: Family,
        m: u32,
        w: &[Vec<u8>],
        cols: &[Vec<u8>],
        consts: &[CvConstants],
        apply_cv: bool,
    ) -> Vec<Vec<i64>> {
        cols.iter()
            .map(|col| {
                let sumx = cv::sum_x(family, m, col);
                w.iter()
                    .enumerate()
                    .map(|(f, wr)| {
                        let acc: i64 = wr
                            .iter()
                            .zip(col)
                            .map(|(&w, &a)| am(family, w, a, m) as i64)
                            .sum();
                        if apply_cv && family != Family::Exact {
                            acc + cv::v_term(&consts[f], sumx)
                        } else {
                            acc
                        }
                    })
                    .collect()
            })
            .collect()
    }

    #[test]
    fn array_matches_direct_gemm_all_families() {
        let mut rng = Rng::new(0xA11);
        for family in Family::ALL {
            let m = family.paper_levels()[family.paper_levels().len() / 2];
            let arr = SystolicArray::new(family, m, 16);
            let rows = 5;
            let k = 12;
            let w: Vec<Vec<u8>> =
                (0..rows).map(|_| (0..k).map(|_| rng.u8()).collect()).collect();
            let cols: Vec<Vec<u8>> =
                (0..7).map(|_| (0..k).map(|_| rng.u8()).collect()).collect();
            let consts: Vec<CvConstants> =
                w.iter().map(|wr| cv::constants(family, m, wr, k)).collect();
            for apply_cv in [false, true] {
                let (got, _) = arr.run_tile(&w, &cols, &consts, apply_cv, 0);
                let want = direct_gemm(family, m, &w, &cols, &consts, apply_cv);
                assert_eq!(got, want, "{} cv={apply_cv}", family.name());
            }
        }
    }

    #[test]
    fn paired_array_alternates_columns_by_global_parity() {
        use crate::approx::am_pol;
        let mut rng = Rng::new(0xA12);
        let even = MulPoint::new(Family::Perforated, 2, Polarity::Neg);
        let odd = MulPoint::new(Family::Perforated, 2, Polarity::Pos);
        let arr = SystolicArray::new_paired(even, odd, 16);
        assert!(arr.is_paired());
        assert!(arr.describe().contains("paired"));
        let rows = 4;
        let k = 9; // odd, so the parity pattern is asymmetric
        let w: Vec<Vec<u8>> =
            (0..rows).map(|_| (0..k).map(|_| rng.u8()).collect()).collect();
        let cols: Vec<Vec<u8>> =
            (0..6).map(|_| (0..k).map(|_| rng.u8()).collect()).collect();
        for k0 in [0usize, 1, 16] {
            let (got, stats) = arr.run_tile(&w, &cols, &[], false, k0);
            assert!(stats.cycles > 0);
            for (p, col) in cols.iter().enumerate() {
                for (f, wr) in w.iter().enumerate() {
                    let want: i64 = wr
                        .iter()
                        .zip(col)
                        .enumerate()
                        .map(|(kk, (&wv, &av))| {
                            let pt = if (k0 + kk) % 2 == 0 { even } else { odd };
                            am_pol(pt.family, pt.pol, wv, av, pt.m) as i64
                        })
                        .sum();
                    assert_eq!(got[p][f], want, "k0={k0} col={p} row={f}");
                }
            }
        }
        // A half-exact pairing runs exact products on its exact columns.
        let half = SystolicArray::new_paired(MulPoint::exact(), odd, 16);
        let (got, _) = half.run_tile(&w, &cols, &[], false, 0);
        for (p, col) in cols.iter().enumerate() {
            for (f, wr) in w.iter().enumerate() {
                let want: i64 = wr
                    .iter()
                    .zip(col)
                    .enumerate()
                    .map(|(kk, (&wv, &av))| {
                        if kk % 2 == 0 {
                            (wv as i64) * (av as i64)
                        } else {
                            am_pol(odd.family, odd.pol, wv, av, odd.m) as i64
                        }
                    })
                    .sum();
                assert_eq!(got[p][f], want);
            }
        }
    }

    #[test]
    fn uniform_pos_array_matches_direct_gemm() {
        use crate::approx::am_pol;
        let mut rng = Rng::new(0xA13);
        let arr = SystolicArray::new_pol(Family::Truncated, 6, Polarity::Pos, 16);
        assert!(!arr.is_paired());
        let rows = 3;
        let k = 10;
        let w: Vec<Vec<u8>> =
            (0..rows).map(|_| (0..k).map(|_| rng.u8()).collect()).collect();
        let cols: Vec<Vec<u8>> =
            (0..5).map(|_| (0..k).map(|_| rng.u8()).collect()).collect();
        let (got, _) = arr.run_tile(&w, &cols, &[], false, 0);
        for (p, col) in cols.iter().enumerate() {
            for (f, wr) in w.iter().enumerate() {
                let want: i64 = wr
                    .iter()
                    .zip(col)
                    .map(|(&wv, &av)| {
                        am_pol(Family::Truncated, Polarity::Pos, wv, av, 6) as i64
                    })
                    .sum();
                assert_eq!(got[p][f], want);
            }
        }
    }

    #[test]
    fn toggle_counts_scale_with_data_activity() {
        let arr = SystolicArray::new(Family::Perforated, 2, 8);
        let w = vec![vec![200u8; 8]; 4];
        let hot: Vec<Vec<u8>> = (0..4)
            .map(|i| (0..8).map(|j| if (i + j) % 2 == 0 { 255 } else { 0 }).collect())
            .collect();
        let cold = vec![vec![0u8; 8]; 4];
        let c: Vec<CvConstants> =
            w.iter().map(|wr| cv::constants(Family::Perforated, 2, wr, 8)).collect();
        let (_, s_hot) = arr.run_tile(&w, &hot, &c, true, 0);
        let (_, s_cold) = arr.run_tile(&w, &cold, &c, true, 0);
        assert!(s_hot.datapath_toggles > s_cold.datapath_toggles * 2);
        assert!(s_hot.activity() > 0.0);
    }

    #[test]
    fn exact_array_has_no_sumx_or_v_activity() {
        let arr = SystolicArray::new(Family::Exact, 0, 8);
        let mut rng = Rng::new(2);
        let w: Vec<Vec<u8>> =
            (0..3).map(|_| (0..8).map(|_| rng.u8()).collect()).collect();
        let cols: Vec<Vec<u8>> =
            (0..5).map(|_| (0..8).map(|_| rng.u8()).collect()).collect();
        let c = vec![CvConstants::default(); 3];
        let (out, stats) = arr.run_tile(&w, &cols, &c, true, 0);
        assert_eq!(stats.sumx_toggles, 0);
        assert_eq!(stats.mac_plus_toggles, 0);
        // And it is the exact GEMM.
        for (col, oc) in cols.iter().zip(&out) {
            for (f, wr) in w.iter().enumerate() {
                let want: i64 =
                    wr.iter().zip(col).map(|(&w, &a)| (w as i64) * (a as i64)).sum();
                assert_eq!(oc[f], want);
            }
        }
    }

    #[test]
    fn latency_includes_mac_plus_cycle() {
        let exact = SystolicArray::new(Family::Exact, 0, 64);
        let approx = SystolicArray::new(Family::Truncated, 6, 64);
        assert_eq!(
            approx.latency_cycles(64, 100),
            exact.latency_cycles(64, 100) + 1
        );
    }

    #[test]
    fn approx_array_toggles_less_than_exact() {
        // The paper's power win, observed directly in switching activity.
        let mut rng = Rng::new(7);
        let k = 16;
        let w: Vec<Vec<u8>> =
            (0..8).map(|_| (0..k).map(|_| rng.u8()).collect()).collect();
        let cols: Vec<Vec<u8>> =
            (0..32).map(|_| (0..k).map(|_| rng.u8()).collect()).collect();
        let c = vec![CvConstants::default(); 8];
        let exact = SystolicArray::new(Family::Exact, 0, 16);
        let perf = SystolicArray::new(Family::Perforated, 3, 16);
        let (_, se) = exact.run_tile(&w, &cols, &c, false, 0);
        let (_, sp) = perf.run_tile(&w, &cols, &c, false, 0);
        assert!(
            sp.datapath_toggles < se.datapath_toggles,
            "{} !< {}",
            sp.datapath_toggles,
            se.datapath_toggles
        );
    }

    #[test]
    fn stats_merge() {
        let mut a = ToggleStats {
            datapath_toggles: 1,
            sumx_toggles: 2,
            mac_plus_toggles: 3,
            cycles: 4,
        };
        let b = ToggleStats {
            datapath_toggles: 10,
            sumx_toggles: 20,
            mac_plus_toggles: 30,
            cycles: 40,
        };
        a.merge(&b);
        assert_eq!(a.datapath_toggles, 11);
        assert_eq!(a.cycles, 44);
        assert!(a.activity() > 0.0);
    }
}
