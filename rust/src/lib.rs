//! # cvapprox — Control-Variate Approximation for DNN Inference
//!
//! Reproduction of *"Leveraging Highly Approximated Multipliers in DNN
//! Inference"* (Zervakis et al., 2024): a TPU-like systolic MAC array whose
//! exact 8×8 multipliers are replaced by highly approximate ones (perforated,
//! recursive, truncated), with a per-filter **control variate** V = C·ΣX + C₀
//! added by an extra MAC⁺ column to nullify the mean convolution error and
//! shrink its variance — no retraining.
//!
//! Layer map (DESIGN.md):
//! * [`approx`] — bit-exact approximate multipliers + error analysis (Table 1)
//! * [`cv`] — control-variate constants and epilogue (paper §3)
//! * [`hw`] — gate-level area/power cost model @ iso-delay (Figs 7–9, Table 5)
//! * [`systolic`] — cycle-level N×N array simulator with toggle counting
//! * [`nn`] — quantized inference engine (uint8, i64 accumulators)
//! * [`datasets`] — synth10/synth100 binary loaders
//! * [`runtime`] — PJRT client running the AOT-compiled XLA tile kernels
//! * [`coordinator`] — batching inference service + power/latency metrics
//! * [`qos`] — adaptive QoS: policy ladders, telemetry, hot-swap governor
//! * [`fault`] — fault injection, integrity checksums, self-healing helpers
//! * [`analyze`] — `srclint`: project-invariant static analysis (R1–R5)
//! * [`search`] — seeded Pareto co-design search over drop-mask genomes
//! * [`report`] — paper-style table/figure renderers
//!
//! Python (JAX + Pallas) exists only on the build path (`make artifacts`);
//! this crate is self-contained at inference time.

pub mod analyze;
pub mod approx;
pub mod coordinator;
pub mod cv;
pub mod datasets;
pub mod fault;
pub mod hw;
pub mod nn;
pub mod qos;
pub mod report;
pub mod runtime;
pub mod search;
pub mod systolic;
pub mod util;

/// Canonical artifact directory relative to the repo root.
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Locate the artifacts directory from the current working directory,
/// walking up so tests/examples work from any subdirectory.
pub fn artifacts_dir() -> std::path::PathBuf {
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = dir.join(ARTIFACTS_DIR);
        if cand.is_dir() {
            return cand;
        }
        if !dir.pop() {
            return std::path::PathBuf::from(ARTIFACTS_DIR);
        }
    }
}

/// The checked-in hermetic mini-artifacts (same `models/` + `data/` +
/// `golden/` layout as `artifacts_dir`, generated once by
/// `scripts/gen_hermetic_golden.py` from the python reference): a small
/// synthetic model, a 64-image dataset and 38 golden vectors that make the
/// golden/layerwise/policy test suites run everywhere — CI included —
/// without `make artifacts` or network access.
pub fn hermetic_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/hermetic")
}
