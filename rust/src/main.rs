fn main() {
    cvapprox::report::cli_main();
}
