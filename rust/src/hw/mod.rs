//! Gate-level hardware cost model — the stand-in for the paper's Synopsys DC
//! + 14 nm synthesis flow (DESIGN.md §2).
//!
//! The paper's area/power story is *structural*: how many partial-product
//! AND gates, Dadda-tree compressors, CPA bits and pipeline flip-flops each
//! MAC variant needs, plus the iso-delay slack that lets the synthesizer
//! downsize gates on the relaxed critical path. We model exactly those
//! quantities:
//!
//! * [`components`] — standard-cell library: area (gate equivalents) and
//!   switching energy per cell, generic 14 nm calibration.
//! * [`dadda`] — Dadda column-reduction calculator over arbitrary
//!   partial-product column heights (handles truncation/perforation holes).
//! * [`units`] — multiplier + MAC / MAC\* / MAC⁺ unit inventories, delay
//!   model, and iso-delay downsizing.
//! * [`array`] — N×N array aggregation; regenerates Figs 7–9 and Table 5.
//!
//! All reported numbers are *normalized to the accurate design* (as in the
//! paper), so only relative calibration matters.

pub mod array;
pub mod components;
pub mod dadda;
pub mod units;

pub use array::{array_cost, mac_plus_overhead, ArrayCost};
pub use units::{mac_exact, mac_plus, mac_star, UnitCost};
