//! N×N systolic-array aggregation: normalized area/power of the approximate
//! arrays (Figs 7-9) and the MAC⁺ overhead breakdown (Table 5).

use super::units::{mac_exact_sized, mac_plus, mac_star};
use crate::approx::Family;

/// Cost of one array configuration, normalized to the accurate N×N design.
#[derive(Clone, Debug)]
pub struct ArrayCost {
    pub family: Family,
    pub m: u32,
    pub n: u32,
    /// Normalized area (1.0 = accurate array).
    pub area_norm: f64,
    /// Normalized power (1.0 = accurate array).
    pub power_norm: f64,
    /// MAC⁺ share of the approximate array's total area (%).
    pub mac_plus_area_pct: f64,
    /// MAC⁺ share of the approximate array's total power (%).
    pub mac_plus_power_pct: f64,
}

/// Price an N×N approximate array (N² MAC\* + N MAC⁺) against the exact one.
pub fn array_cost(family: Family, m: u32, n: u32) -> ArrayCost {
    let base = mac_exact_sized(n);
    let nn = (n * n) as f64;
    let base_area = base.area * nn;
    let base_power = base.power * nn;
    if family == Family::Exact {
        return ArrayCost {
            family,
            m,
            n,
            area_norm: 1.0,
            power_norm: 1.0,
            mac_plus_area_pct: 0.0,
            mac_plus_power_pct: 0.0,
        };
    }
    let star = mac_star(family, m, n);
    let plus = mac_plus(family, m, n);
    let area = star.area * nn + plus.area * n as f64;
    let power = star.power * nn + plus.power * n as f64;
    ArrayCost {
        family,
        m,
        n,
        area_norm: area / base_area,
        power_norm: power / base_power,
        mac_plus_area_pct: 100.0 * plus.area * n as f64 / area,
        mac_plus_power_pct: 100.0 * plus.power * n as f64 / power,
    }
}

/// Table-5 style overhead rows for one family over m × N.
pub fn mac_plus_overhead(family: Family, ns: &[u32]) -> Vec<ArrayCost> {
    let mut rows = Vec::new();
    for &m in family.paper_levels() {
        for &n in ns {
            rows.push(array_cost(family, m, n));
        }
    }
    rows
}

/// The array sizes the paper sweeps.
pub const PAPER_NS: [u32; 4] = [16, 32, 48, 64];

#[cfg(test)]
mod tests {
    use super::*;

    /// Paper Figs 7-9 power-reduction bands (%) at the family's m levels,
    /// pooled over N: (family, m, min_reduction, max_reduction).
    const PAPER_POWER_BANDS: &[(Family, u32, f64, f64)] = &[
        (Family::Perforated, 1, 27.7, 29.2),
        (Family::Perforated, 2, 34.5, 35.7),
        (Family::Perforated, 3, 44.4, 46.1),
        (Family::Truncated, 5, 23.5, 25.4),
        (Family::Truncated, 6, 28.6, 35.0),
        (Family::Truncated, 7, 38.4, 41.9),
        (Family::Recursive, 2, 2.0, 12.0),
        (Family::Recursive, 3, 10.0, 20.0),
        (Family::Recursive, 4, 18.0, 27.0),
    ];

    #[test]
    fn calibration_matches_paper_bands() {
        // The cost model must land within (or near) each paper band — the
        // single calibration (components::CALIB) covers all three families.
        for &(family, m, lo, hi) in PAPER_POWER_BANDS {
            for n in PAPER_NS {
                let c = array_cost(family, m, n);
                let red = 100.0 * (1.0 - c.power_norm);
                let slack = 6.0; // percentage-point tolerance around the band
                assert!(
                    red > lo - slack && red < hi + slack,
                    "{} m={m} N={n}: power reduction {red:.1}% outside \
                     [{lo}-{slack}, {hi}+{slack}]",
                    family.name()
                );
            }
        }
    }

    #[test]
    fn power_reduction_monotone_in_m() {
        for family in Family::APPROX {
            for n in PAPER_NS {
                let mut last = f64::INFINITY;
                for &m in family.paper_levels() {
                    let p = array_cost(family, m, n).power_norm;
                    assert!(p < last, "{} m={m} N={n}", family.name());
                    last = p;
                }
            }
        }
    }

    #[test]
    fn power_insensitive_to_n() {
        // Paper §5.1.1: power reduction is almost insensitive to N.
        for family in Family::APPROX {
            for &m in family.paper_levels() {
                let reds: Vec<f64> = PAPER_NS
                    .iter()
                    .map(|&n| 1.0 - array_cost(family, m, n).power_norm)
                    .collect();
                let spread = reds.iter().cloned().fold(f64::MIN, f64::max)
                    - reds.iter().cloned().fold(f64::MAX, f64::min);
                assert!(spread < 0.05, "{} m={m}: spread {spread}", family.name());
            }
        }
    }

    #[test]
    fn family_ordering_matches_paper() {
        // At the most aggressive paper m: perforated saves most power,
        // recursive least (paper §5.1).
        let n = 64;
        let p = array_cost(Family::Perforated, 3, n).power_norm;
        let t = array_cost(Family::Truncated, 7, n).power_norm;
        let r = array_cost(Family::Recursive, 4, n).power_norm;
        assert!(p < t && t < r, "p={p} t={t} r={r}");
    }

    #[test]
    fn truncated_area_gain_exceeds_perforated() {
        // Paper: truncated avg area gain 31% vs perforated 10% — the sumX
        // path is 1-bit for truncated.
        let n = 48;
        let t: f64 = Family::Truncated.paper_levels().iter()
            .map(|&m| 1.0 - array_cost(Family::Truncated, m, n).area_norm)
            .sum::<f64>() / 3.0;
        let p: f64 = Family::Perforated.paper_levels().iter()
            .map(|&m| 1.0 - array_cost(Family::Perforated, m, n).area_norm)
            .sum::<f64>() / 3.0;
        assert!(t > p, "truncated {t} !> perforated {p}");
    }

    #[test]
    fn recursive_m2_small_n_has_area_overhead() {
        // Paper §5.1.3: 14% area overhead at m=2, N=16.
        let c = array_cost(Family::Recursive, 2, 16);
        assert!(c.area_norm > 1.0, "expected overhead, got {}", c.area_norm);
        assert!(c.area_norm < 1.25);
    }

    #[test]
    fn mac_plus_overhead_small_and_scales_like_table5() {
        for family in Family::APPROX {
            for &m in family.paper_levels() {
                let mut last = f64::INFINITY;
                for n in PAPER_NS {
                    let c = array_cost(family, m, n);
                    // overhead < ~6% everywhere (paper: < 1.6%; our MAC+
                    // inventory is coarser — same order, see EXPERIMENTS.md)
                    assert!(c.mac_plus_area_pct < 6.0,
                            "{} m={m} N={n}: {}", family.name(), c.mac_plus_area_pct);
                    // decreases as N grows (column vs square scaling)
                    assert!(c.mac_plus_area_pct < last);
                    last = c.mac_plus_area_pct;
                }
            }
        }
    }

    #[test]
    fn mac_plus_overhead_grows_with_m() {
        // Table 5: overhead increases with m (MAC* shrinks, MAC+ doesn't).
        for family in Family::APPROX {
            let levels = family.paper_levels();
            let n = 32;
            let lo = array_cost(family, levels[0], n).mac_plus_area_pct;
            let hi = array_cost(family, *levels.last().unwrap(), n).mac_plus_area_pct;
            assert!(hi >= lo, "{}: {lo} -> {hi}", family.name());
        }
    }

    #[test]
    fn exact_array_is_unity() {
        let c = array_cost(Family::Exact, 0, 64);
        assert_eq!(c.area_norm, 1.0);
        assert_eq!(c.power_norm, 1.0);
    }
}
