//! Dadda column-reduction calculator over arbitrary partial-product column
//! heights.
//!
//! Works for the full 8×8 array *and* for the holed arrays left by
//! truncation (columns 0..m removed) or perforation (rows removed), so a
//! single algorithm prices every multiplier variant. Returns the compressor
//! counts (FA/HA), the number of reduction stages (delay proxy), and the
//! final carry-propagate adder width.

/// Dadda height sequence d_1=2, d_{j+1} = floor(1.5 * d_j): 2,3,4,6,9,13,...
fn dadda_targets(max_height: u32) -> Vec<u32> {
    let mut seq = vec![2u32];
    while *seq.last().unwrap() < max_height {
        let next = (*seq.last().unwrap() as f64 * 1.5).floor() as u32;
        seq.push(next);
    }
    seq.pop(); // last one >= max_height is not a target
    seq.reverse(); // descending: ..., 6, 4, 3, 2
    seq
}

/// Result of reducing a partial-product array to two rows.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Reduction {
    pub full_adders: u32,
    pub half_adders: u32,
    pub stages: u32,
    /// Width of the final CPA (columns with >= 1 bit after reduction).
    pub cpa_width: u32,
    /// Total partial-product bits fed into the tree.
    pub pp_bits: u32,
}

/// Run Dadda reduction over `heights[c]` = number of pp bits in column c.
pub fn reduce(heights: &[u32]) -> Reduction {
    let mut h: Vec<u32> = heights.to_vec();
    let max = h.iter().copied().max().unwrap_or(0);
    let pp_bits = h.iter().sum();
    let mut fa = 0u32;
    let mut ha = 0u32;
    let mut stages = 0u32;
    if max > 2 {
        for target in dadda_targets(max) {
            if h.iter().all(|&x| x <= target) {
                continue; // already below this stage's target
            }
            stages += 1;
            let mut carry_in = vec![0u32; h.len() + 1];
            for c in 0..h.len() {
                let mut cur = h[c] + carry_in[c];
                // Dadda: compress just enough to reach `target`.
                while cur > target {
                    if cur >= target + 2 {
                        // FA: 3 bits -> 1 sum + 1 carry
                        fa += 1;
                        cur -= 2;
                        carry_in[c + 1] += 1;
                    } else {
                        // HA: 2 bits -> 1 sum + 1 carry
                        ha += 1;
                        cur -= 1;
                        carry_in[c + 1] += 1;
                    }
                }
                h[c] = cur;
            }
            if carry_in[h.len()] > 0 {
                h.push(carry_in[h.len()]);
            }
        }
    }
    // Final CPA over columns that still hold 2 bits (plus ripple to MSB).
    let first2 = h.iter().position(|&x| x >= 2);
    let cpa_width = match first2 {
        Some(lo) => (h.len() - lo) as u32,
        None => 0,
    };
    Reduction { full_adders: fa, half_adders: ha, stages, cpa_width, pp_bits }
}

/// Column heights of an exact n×n unsigned multiplier.
pub fn full_heights(n: u32) -> Vec<u32> {
    (0..2 * n - 1).map(|c| (c + 1).min(n).min(2 * n - 1 - c)).collect()
}

/// Column heights after truncating the `m` least-significant columns
/// (paper Fig. 3: bits with i + j < m never generated).
pub fn truncated_heights(n: u32, m: u32) -> Vec<u32> {
    full_heights(n)
        .into_iter()
        .enumerate()
        .map(|(c, h)| if (c as u32) < m { 0 } else { h })
        .collect()
}

/// Column heights after perforating the first `m` partial-product rows
/// (paper Fig. 1b: rows i in [0, m) never generated; row i spans columns
/// i..i+n).
pub fn perforated_heights(n: u32, m: u32) -> Vec<u32> {
    let mut h = vec![0u32; (2 * n - 1) as usize];
    for row in m..n {
        for j in 0..n {
            h[(row + j) as usize] += 1;
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_8x8_dadda_canonical_counts() {
        // Known result for the 8x8 Dadda multiplier: 35 FAs, 7 HAs, 4 stages.
        let r = reduce(&full_heights(8));
        assert_eq!(r.pp_bits, 64);
        assert_eq!(r.stages, 4);
        assert_eq!(r.full_adders, 35);
        assert_eq!(r.half_adders, 7);
        assert!(r.cpa_width >= 10 && r.cpa_width <= 14, "{}", r.cpa_width);
    }

    #[test]
    fn targets_sequence() {
        assert_eq!(dadda_targets(8), vec![6, 4, 3, 2]);
        assert_eq!(dadda_targets(3), vec![2]);
        assert_eq!(dadda_targets(2), Vec::<u32>::new());
    }

    #[test]
    fn truncation_reduces_compressors_monotonically() {
        let mut last = u32::MAX;
        for m in 0..=7 {
            let r = reduce(&truncated_heights(8, m));
            let total = r.full_adders + r.half_adders;
            assert!(total <= last, "m={m}");
            last = total;
        }
    }

    #[test]
    fn perforation_reduces_stages() {
        let exact = reduce(&full_heights(8));
        let perf3 = reduce(&perforated_heights(8, 3));
        assert!(perf3.stages < exact.stages);
        assert_eq!(perf3.pp_bits, 40); // (8-3) rows * 8 bits
    }

    #[test]
    fn truncated_pp_bits_match_bitmodel() {
        use crate::approx::bitmodel::truncated_kept_bits;
        for m in 0..=7 {
            let r = reduce(&truncated_heights(8, m));
            assert_eq!(r.pp_bits, truncated_kept_bits(m), "m={m}");
        }
    }

    #[test]
    fn degenerate_arrays() {
        assert_eq!(reduce(&[]).pp_bits, 0);
        let single = reduce(&[1, 1, 1]);
        assert_eq!(single.full_adders + single.half_adders, 0);
        assert_eq!(single.stages, 0);
    }

    #[test]
    fn reduction_conserves_bit_count() {
        // Each FA turns 3 bits into 2, each HA 2 into 2: final bit count =
        // pp_bits - fa (only FAs net-remove a bit per stage accounting).
        let h = truncated_heights(8, 5);
        let r = reduce(&h);
        let final_bits: u32 = r.pp_bits - r.full_adders;
        // after reduction every column holds <= 2 bits; total final bits
        // must fit in 2 * (#columns+possible growth)
        assert!(final_bits <= 2 * (h.len() as u32 + r.stages));
    }
}
