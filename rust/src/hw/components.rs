//! Standard-cell "library": area and switching energy per component.
//!
//! Areas are in **gate equivalents** (GE, 1 = NAND2) — the standard
//! technology-independent unit; energies are per-toggle in arbitrary units
//! proportional to GE (switched capacitance tracks cell size in a given
//! node). Activity factors are the fraction of cycles a cell toggles under
//! the uniform-ish operand streams the paper simulates (10k inference
//! cycles, Questasim back-annotation); ours are standard textbook values.
//!
//! Because every figure is normalized to the accurate array, only the
//! *ratios* between these constants matter. `CALIB` holds the two knobs the
//! calibration test tunes against the paper's reported reductions.

/// One combinational/sequential cell type.
#[derive(Clone, Copy, Debug)]
pub struct Cell {
    /// Area in gate equivalents.
    pub ge: f64,
    /// Mean switching activity (toggles per cycle) in a MAC datapath.
    pub activity: f64,
}

/// AND2 gate (partial-product generation).
pub const AND2: Cell = Cell { ge: 1.25, activity: 0.19 };
/// OR2 gate (truncated-family x_j reduction).
pub const OR2: Cell = Cell { ge: 1.25, activity: 0.20 };
/// Full adder (3:2 compressor).
pub const FA: Cell = Cell { ge: 5.0, activity: 0.42 };
/// Half adder (2:2 compressor).
pub const HA: Cell = Cell { ge: 2.5, activity: 0.32 };
/// CPA adder bit (carry-propagate stage; includes carry chain share).
pub const CPA_BIT: Cell = Cell { ge: 5.5, activity: 0.36 };
/// Ripple-carry adder bit — the sumX side accumulator is off the critical
/// path, so the paper uses "a slower and power-efficient ripple-carry adder"
/// (§4.4): min-area cells, lower effective activity.
pub const RCA_BIT: Cell = Cell { ge: 2.5, activity: 0.30 };
/// D flip-flop (pipeline registers; activity includes clock pin; the
/// weight register of a weight-stationary array barely toggles, which the
/// averaged factor reflects).
pub const DFF: Cell = Cell { ge: 4.5, activity: 0.30 };

/// Calibration knobs (fit once against the paper's Figs 7-9; see
/// `hw::array::tests::calibration_matches_paper_bands`).
///
/// The accurate array is synthesized *at its minimum clock period* (paper
/// §5), i.e. on the steep end of the synthesis power/delay curve; any slack
/// the approximate MAC\* gains lets the tool downsize gates and swap Vt
/// cells, cutting power far more than area. We model that conversion as a
/// **concave** relaxation `1 − γ · slack^κ` (steep for the first few percent
/// of slack, saturating after): the form and the two γ constants are fitted
/// once against the paper's reported reductions; the per-family *slack*
/// itself comes from the structural delay model in `units.rs`.
pub struct Calib {
    /// Slack→area conversion (gate downsizing shrinks cells modestly).
    pub gamma_area: f64,
    /// Slack→power conversion (downsizing + Vt swaps hit power hard).
    pub gamma_power: f64,
    /// Concavity exponent of the relaxation curve.
    pub kappa: f64,
    /// Leakage share of total power at the 14 nm operating point.
    pub leakage_frac: f64,
}

pub const CALIB: Calib = Calib {
    gamma_area: 0.12,
    gamma_power: 1.05,
    kappa: 0.42,
    leakage_frac: 0.08,
};

/// The relaxation factor for a given relative slack in [0, 1].
pub fn relax(gamma: f64, slack: f64) -> f64 {
    (1.0 - gamma * slack.clamp(0.0, 1.0).powf(CALIB.kappa)).max(0.2)
}

/// Inventory of cells -> (area_GE, dynamic_energy_units).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Cost {
    pub area: f64,
    pub dyn_energy: f64,
}

impl Cost {
    pub fn zero() -> Cost {
        Cost::default()
    }

    /// Add `n` instances of `cell`.
    pub fn add(&mut self, cell: Cell, n: f64) {
        self.area += cell.ge * n;
        self.dyn_energy += cell.ge * cell.activity * n;
    }

    pub fn plus(mut self, other: Cost) -> Cost {
        self.area += other.area;
        self.dyn_energy += other.dyn_energy;
        self
    }

    /// Scale both area and energy (gate downsizing).
    pub fn scaled(mut self, f: f64) -> Cost {
        self.area *= f;
        self.dyn_energy *= f;
        self
    }

    /// Total power = dynamic + leakage (leakage tracks area).
    pub fn power(&self) -> f64 {
        self.dyn_energy + CALIB.leakage_frac * self.area
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_accumulates() {
        let mut c = Cost::zero();
        c.add(FA, 10.0);
        c.add(DFF, 2.0);
        assert!((c.area - (50.0 + 9.0)).abs() < 1e-9);
        assert!(c.dyn_energy > 0.0);
    }

    #[test]
    fn scaling_is_linear() {
        let mut c = Cost::zero();
        c.add(FA, 4.0);
        let s = c.scaled(0.5);
        assert!((s.area - c.area * 0.5).abs() < 1e-12);
    }

    #[test]
    fn power_includes_leakage() {
        let mut c = Cost::zero();
        c.add(FA, 100.0);
        assert!(c.power() > c.dyn_energy);
    }
}
