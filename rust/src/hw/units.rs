//! Cost + delay models for multipliers and the MAC / MAC\* / MAC⁺ units
//! (paper §4, Figs 5-6).

use super::components::{relax, Cost, AND2, CALIB, CPA_BIT, DFF, FA, HA, OR2, RCA_BIT};
use super::dadda::{self, Reduction};
use crate::approx::Family;

/// Accumulator width of the paper's MAC: ceil(log2(N * (2^16 - 1))).
pub fn acc_width(n_array: u32) -> u32 {
    (((n_array as f64) * (65536.0 - 1.0)).log2()).ceil() as u32
}

/// Width of the sumX side accumulator (paper §4.1-4.3).
pub fn sumx_width(family: Family, m: u32, n_array: u32) -> u32 {
    match family {
        Family::Exact => 0,
        // x_j is m bits wide -> ceil(log2(N * (2^m - 1)))
        Family::Perforated | Family::Recursive => {
            (((n_array as f64) * (((1u32 << m) - 1) as f64)).log2().ceil() as u32).max(1)
        }
        // x_j is 1 bit -> ceil(log2 N)
        Family::Truncated => ((n_array as f64).log2().ceil() as u32).max(1),
    }
}

/// Structural cost + delay of one multiplier datapath.
#[derive(Clone, Debug)]
pub struct MulCost {
    pub cost: Cost,
    /// Delay in "logic levels": pp-AND + compressor stages + CPA levels.
    pub delay: f64,
    pub reduction: Reduction,
}

/// Price a multiplier from its partial-product column heights.
fn mul_from_heights(heights: &[u32]) -> MulCost {
    let red = dadda::reduce(heights);
    let mut cost = Cost::zero();
    cost.add(AND2, red.pp_bits as f64);
    cost.add(FA, red.full_adders as f64);
    cost.add(HA, red.half_adders as f64);
    cost.add(CPA_BIT, red.cpa_width as f64);
    // 1 level for pp generation, ~1 per compressor stage, log2 for the CPA
    // (synthesized carry-lookahead/parallel-prefix).
    let delay = 1.0
        + red.stages as f64
        + if red.cpa_width > 0 { (red.cpa_width as f64).log2() } else { 0.0 };
    MulCost { cost, delay, reduction: red }
}

/// The 8×8 multiplier of each family at approximation level m.
pub fn multiplier(family: Family, m: u32) -> MulCost {
    match family {
        Family::Exact => mul_from_heights(&dadda::full_heights(8)),
        Family::Perforated => mul_from_heights(&dadda::perforated_heights(8, m)),
        Family::Truncated => mul_from_heights(&dadda::truncated_heights(8, m)),
        Family::Recursive => {
            // W_H·A_H (n-m)², plus W_H·A_L and W_L·A_H ((n-m)×m each); the
            // W_L·A_L block is pruned (eq. 5). Accumulation of the three
            // sub-products reuses the reduction-tree model: total pp bits =
            // sum over sub-multipliers; heights approximated by stacking at
            // the right offsets.
            let n = 8u32;
            let hi = n - m;
            let mut heights = vec![0u32; (2 * n) as usize];
            // W_H·A_H at offset 2m
            for c in 0..(2 * hi - 1) {
                heights[(c + 2 * m) as usize] += (c + 1).min(hi).min(2 * hi - 1 - c);
            }
            if m > 0 {
                // W_H·A_L and W_L·A_H at offset m (each hi×m)
                for c in 0..(hi + m - 1) {
                    let h = (c + 1).min(hi).min(m).min(hi + m - 1 - c);
                    heights[(c + m) as usize] += 2 * h;
                }
            }
            mul_from_heights(&heights)
        }
    }
}

/// A generic exact w1×w2 multiplier (the MAC⁺ V-multiplier).
pub fn exact_mul(w1: u32, w2: u32) -> MulCost {
    if w1 == 0 || w2 == 0 {
        return MulCost { cost: Cost::zero(), delay: 0.0, reduction: Reduction::default() };
    }
    let (a, b) = (w1.min(w2), w1.max(w2));
    let mut heights = vec![0u32; (a + b - 1) as usize];
    for (c, h) in heights.iter_mut().enumerate() {
        *h = (c as u32 + 1).min(a).min(a + b - 1 - c as u32);
    }
    mul_from_heights(&heights)
}

/// Fully-priced pipeline unit.
#[derive(Clone, Debug)]
pub struct UnitCost {
    pub cost: Cost,
    /// Pre-downsizing critical-path delay (logic levels).
    pub delay: f64,
}

/// The accurate MAC (Fig. 5b): 8×8 exact multiplier + acc-width adder +
/// pipeline registers (two 8-bit operand regs, product reg, accumulator reg).
pub fn mac_exact(n_array: u32) -> UnitCost {
    let aw = acc_width(n_array);
    let mul = multiplier(Family::Exact, 0);
    let mut cost = mul.cost;
    cost.add(CPA_BIT, aw as f64); // main accumulate adder
    cost.add(DFF, (8 + 8 + 16 + aw) as f64); // W, A, product, sum regs
    let delay = mul.delay.max(1.0 + (aw as f64).log2());
    UnitCost { cost, delay }
}

/// MAC-level critical-path model in logic levels.
///
/// DesignWare-style multiplier arrays accumulate rows CSA-chain-wise: the
/// path scales with the number of partial-product *rows* plus the final
/// CPA. This is what gives perforation (which removes whole rows) its large
/// iso-delay slack while truncation (which only narrows columns) gains
/// almost none — exactly the asymmetry visible in the paper's Figs 7 vs 8.
fn mac_delay(family: Family, m: u32, aw: u32) -> f64 {
    let rows = match family {
        Family::Exact | Family::Truncated => 8,
        Family::Perforated => 8 - m,
        // sub-products of the high part accumulate in (8-m) rows, then a
        // ~3-level merge combines the three blocks (eq. 4).
        Family::Recursive => 8 - m + 3,
    } as f64;
    let adder = ((aw - m.min(aw)) as f64).max(2.0).log2();
    rows + adder
}

/// Split cost of a unit into (area_view, power_view) after iso-delay sizing.
#[derive(Clone, Debug)]
pub struct SizedUnit {
    pub area: f64,
    pub power: f64,
}

/// Iso-delay sizing: the combinational logic's slack relative to the
/// accurate MAC's clock is converted into area/power relaxation
/// (components::relax); FFs are unaffected by downsizing.
fn size_unit(comb: Cost, ffs: Cost, delay: f64, budget: f64) -> SizedUnit {
    let slack = ((budget - delay) / budget).max(0.0);
    let comb_a = comb.scaled(relax(CALIB.gamma_area, slack));
    let comb_p = comb.scaled(relax(CALIB.gamma_power, slack));
    SizedUnit {
        area: comb_a.area + ffs.area,
        power: comb_p.power() + ffs.power(),
    }
}

/// The accurate MAC sized at its own critical path (the array's clock).
pub fn mac_exact_sized(n_array: u32) -> SizedUnit {
    let aw = acc_width(n_array);
    let mul = multiplier(Family::Exact, 0);
    let mut comb = mul.cost;
    comb.add(CPA_BIT, aw as f64);
    let mut ffs = Cost::zero();
    ffs.add(DFF, (8 + 8 + 16 + aw) as f64);
    let delay = mac_delay(Family::Exact, 0, aw);
    size_unit(comb, ffs, delay, delay) // zero slack: synthesized at min period
}

/// MAC\* (Fig. 6b/c): approximate multiplier, main adder narrowed by m bits,
/// plus the sumX side path (ripple-carry adder + pipeline FF; truncated adds
/// the m-input OR tree). Sized against the accurate MAC's clock (iso-delay).
pub fn mac_star(family: Family, m: u32, n_array: u32) -> SizedUnit {
    let aw = acc_width(n_array);
    let budget = mac_delay(Family::Exact, 0, aw);
    let mul = multiplier(family, m);
    let main_aw = aw - m.min(aw); // product is 16-m bits; adder shrinks by m
    let mut comb = mul.cost;
    comb.add(CPA_BIT, main_aw as f64);
    let sxw = sumx_width(family, m, n_array);
    comb.add(RCA_BIT, sxw as f64); // sumX adder: slow RCA off the crit path
    if family == Family::Truncated && m > 1 {
        comb.add(OR2, (m - 1) as f64); // m-input OR as OR2 tree
    }
    let mut ffs = Cost::zero();
    let prod_w = if family == Family::Exact { 16 } else { 16 - m };
    ffs.add(DFF, (8 + 8) as f64 + prod_w as f64 + main_aw as f64);
    ffs.add(DFF, sxw as f64); // sumX pipeline register
    let delay = mac_delay(family, m, aw);
    size_unit(comb, ffs, delay, budget)
}

/// MAC⁺ (Fig. 6d): the V = C·ΣX multiplier plus the final add that merges V
/// into {sum_N, B[m-1:0]}.
///
/// Accounting note (DESIGN.md §2): the *overhead* charged to MAC⁺ is the V
/// datapath only — the exact array also needs an output-drain column with an
/// accumulator-width register, so that part is common to both designs and
/// cancels in the normalized figures. This reproduces Table 5's sub-2%
/// overheads; charging the full drain column would roughly triple them.
pub fn mac_plus(family: Family, m: u32, n_array: u32) -> SizedUnit {
    if family == Family::Exact {
        return SizedUnit { area: 0.0, power: 0.0 };
    }
    let aw = acc_width(n_array);
    let budget = mac_delay(Family::Exact, 0, aw);
    let sxw = sumx_width(family, m, n_array);
    let mul = exact_mul(sxw, 8); // C is 8-bit (+Q.4 handled by shift wiring)
    let mut comb = mul.cost;
    comb.add(CPA_BIT, aw as f64); // final G* = {sum,B} + V adder
    let mut ffs = Cost::zero();
    ffs.add(DFF, (sxw + 8) as f64); // V input regs (sumX, C)
    let delay = mac_plus_delay(family, m, n_array);
    size_unit(comb, ffs, delay, budget)
}

/// MAC⁺ critical path: V-multiplier rows (sumX width, CSA-chain) + final CPA.
fn mac_plus_delay(family: Family, m: u32, n_array: u32) -> f64 {
    let aw = acc_width(n_array);
    let sxw = sumx_width(family, m, n_array);
    sxw.min(8) as f64 + (aw as f64).log2()
}

/// MAC⁺ critical path never exceeds the exact MAC's (paper §5.1 observes the
/// same); exposed for the tests.
pub fn mac_plus_fits_clock(family: Family, m: u32, n_array: u32) -> bool {
    let aw = acc_width(n_array);
    mac_plus_delay(family, m, n_array) <= mac_delay(Family::Exact, 0, aw) + 1e-9
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acc_width_matches_paper_example() {
        // Paper §4: for a 64x64 array the adder is 22 bits.
        assert_eq!(acc_width(64), 22);
        assert_eq!(acc_width(16), 20);
    }

    #[test]
    fn sumx_width_matches_paper_example() {
        // Paper §4.1: N=64, m=2 -> 8-bit sumX adder.
        assert_eq!(sumx_width(Family::Perforated, 2, 64), 8);
        // Truncated: ceil(log2 N).
        assert_eq!(sumx_width(Family::Truncated, 6, 64), 6);
        assert_eq!(sumx_width(Family::Truncated, 6, 16), 4);
    }

    #[test]
    fn approximate_multipliers_are_smaller() {
        let exact = multiplier(Family::Exact, 0).cost.area;
        for family in Family::APPROX {
            for &m in family.paper_levels() {
                let a = multiplier(family, m).cost.area;
                assert!(a < exact, "{} m={m}: {a} !< {exact}", family.name());
            }
        }
    }

    #[test]
    fn multiplier_cost_monotone_in_m() {
        for family in Family::APPROX {
            let mut last = f64::INFINITY;
            for m in family.paper_levels() {
                let a = multiplier(family, *m).cost.area;
                assert!(a < last, "{} m={m}", family.name());
                last = a;
            }
        }
    }

    #[test]
    fn perforated_gains_delay_slack() {
        let exact = multiplier(Family::Exact, 0).delay;
        assert!(multiplier(Family::Perforated, 3).delay < exact);
    }

    #[test]
    fn mac_star_cheaper_than_mac_for_aggressive_m() {
        for n in [16, 32, 48, 64] {
            let base = mac_exact_sized(n);
            for (family, m) in [(Family::Perforated, 3), (Family::Truncated, 7)] {
                let star = mac_star(family, m, n);
                assert!(star.power < base.power, "{} m={m} N={n}", family.name());
                assert!(star.area < base.area, "{} m={m} N={n}", family.name());
            }
        }
    }

    #[test]
    fn recursive_m2_star_can_exceed_exact_area() {
        // Paper §5.1.3: m=2, N=16 shows an area overhead (CV logic dominates
        // the tiny pruning gain).
        let base = mac_exact_sized(16);
        let star = mac_star(Family::Recursive, 2, 16);
        assert!(star.area > 0.95 * base.area);
    }

    #[test]
    fn mac_plus_meets_clock_everywhere() {
        for family in Family::APPROX {
            for &m in family.paper_levels() {
                for n in [16, 32, 48, 64] {
                    assert!(mac_plus_fits_clock(family, m, n),
                            "{} m={m} N={n}", family.name());
                }
            }
        }
    }

    #[test]
    fn exact_unit_has_zero_slack_sizing() {
        let u = mac_exact(64);
        let s = mac_exact_sized(64);
        // sized at own delay -> no downsizing: area equals raw inventory
        assert!((s.area - u.cost.area).abs() < 1e-9);
    }
}
