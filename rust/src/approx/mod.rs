//! Bit-exact models of the paper's approximate 8×8 unsigned multipliers.
//!
//! Three families (paper §2), each with knob `m`:
//! * **perforated** [22]: drop the `m` least-significant partial products
//!   (s = 0) — error ε = W·(A mod 2^m) (eq. 3).
//! * **recursive** [23,24]: split each operand into m-bit low / (8−m)-bit
//!   high parts and drop the W_L·A_L sub-product — ε = W_L·A_L (eq. 6).
//! * **truncated** [17-19]: remove all partial-product bits in the `m`
//!   least-significant columns — ε = Σ_{i<m} (W mod 2^{m−i})·a_i·2^i (eq. 8).
//!
//! Everything downstream (GEMM engines, systolic simulator, Pallas kernels)
//! uses the closed-form identities; [`bitmodel`] re-derives the products
//! from the partial-product array structure and the exhaustive tests prove
//! the two agree for **all 2^16 operand pairs and every m** — so the fast
//! identity path *is* the hardware behaviour.

pub mod bitmodel;
pub mod stats;

/// Approximate-multiplier family. `Exact` is the baseline (m ignored).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Family {
    Exact,
    Perforated,
    Recursive,
    Truncated,
}

impl Family {
    pub const ALL: [Family; 4] =
        [Family::Exact, Family::Perforated, Family::Recursive, Family::Truncated];

    /// The three approximate families (everything but `Exact`).
    pub const APPROX: [Family; 3] =
        [Family::Perforated, Family::Recursive, Family::Truncated];

    pub fn name(self) -> &'static str {
        match self {
            Family::Exact => "exact",
            Family::Perforated => "perforated",
            Family::Recursive => "recursive",
            Family::Truncated => "truncated",
        }
    }

    pub fn from_name(s: &str) -> Option<Family> {
        Family::ALL.into_iter().find(|f| f.name() == s)
    }

    /// Byte code used by the .gv golden-vector format.
    pub fn code(self) -> u8 {
        match self {
            Family::Exact => 0,
            Family::Perforated => 1,
            Family::Recursive => 2,
            Family::Truncated => 3,
        }
    }

    pub fn from_code(c: u8) -> Option<Family> {
        Family::ALL.into_iter().find(|f| f.code() == c)
    }

    /// The approximation levels the paper evaluates for this family
    /// (Tables 2-4 / Figs 7-9).
    pub fn paper_levels(self) -> &'static [u32] {
        match self {
            Family::Exact => &[0],
            Family::Perforated => &[1, 2, 3],
            Family::Recursive => &[2, 3, 4],
            Family::Truncated => &[5, 6, 7],
        }
    }

    /// Extended levels used by the error analysis (Table 1).
    pub fn table1_levels(self) -> &'static [u32] {
        match self {
            Family::Exact => &[0],
            Family::Perforated => &[1, 2, 3],
            Family::Recursive => &[2, 3, 4, 5],
            Family::Truncated => &[4, 5, 6, 7],
        }
    }
}

/// Multiplication error ε(W, A) = W·A − AM(W, A) ≥ 0 via the closed forms.
#[inline]
pub fn err(family: Family, w: u8, a: u8, m: u32) -> i32 {
    debug_assert!(m <= 7);
    let (w, a) = (w as i32, a as i32);
    let mask = (1i32 << m) - 1;
    match family {
        Family::Exact => 0,
        Family::Perforated => w * (a & mask),
        Family::Recursive => (w & mask) * (a & mask),
        Family::Truncated => {
            let mut e = 0i32;
            for i in 0..m {
                let sub = w & ((1 << (m - i)) - 1);
                e += sub * ((a >> i) & 1) << i;
            }
            e
        }
    }
}

/// Approximate product AM(W, A) = W·A − ε(W, A).
#[inline]
pub fn am(family: Family, w: u8, a: u8, m: u32) -> i32 {
    (w as i32) * (a as i32) - err(family, w, a, m)
}

/// Control-variate input x_j (eqs. 18/25/29):
/// perforated/recursive → A mod 2^m; truncated → OR(A[m−1:0]) ∈ {0,1}.
#[inline]
pub fn xvar(family: Family, a: u8, m: u32) -> i32 {
    let low = (a as i32) & ((1i32 << m) - 1);
    match family {
        Family::Exact => 0,
        Family::Perforated | Family::Recursive => low,
        Family::Truncated => (low != 0) as i32,
    }
}

/// 2·Ŵ (eq. 24 scaled to stay integral): the mean truncation error of
/// AM_T(W, ·) over uniform A, in Q.1 fixed point.
#[inline]
pub fn w_hat_q1(w: u8, m: u32) -> i32 {
    let w = w as i32;
    let mut acc = 0i32;
    for i in 0..m {
        acc += (w & ((1 << (m - i)) - 1)) << i;
    }
    acc
}

/// 256×256 lookup table of AM products for one (family, m) — the
/// hardware-faithful path used by the systolic simulator (TFApprox-style).
pub struct MulLut {
    pub family: Family,
    pub m: u32,
    table: Vec<i32>, // [w * 256 + a]
}

impl MulLut {
    pub fn build(family: Family, m: u32) -> MulLut {
        let mut table = vec![0i32; 65536];
        for w in 0..256usize {
            for a in 0..256usize {
                table[w * 256 + a] = am(family, w as u8, a as u8, m);
            }
        }
        MulLut { family, m, table }
    }

    #[inline]
    pub fn mul(&self, w: u8, a: u8) -> i32 {
        self.table[(w as usize) * 256 + a as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn exhaustive_identity_vs_bitmodel_all_m() {
        // The cornerstone: closed forms == structural partial-product models
        // for ALL operand pairs and every m in 0..=7.
        for family in Family::APPROX {
            for m in 0..=7u32 {
                for w in 0..=255u8 {
                    for a in 0..=255u8 {
                        let fast = am(family, w, a, m);
                        let slow = bitmodel::am_bits(family, w, a, m);
                        assert_eq!(
                            fast, slow,
                            "{} m={m} w={w} a={a}", family.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn error_nonnegative_and_bounded() {
        prop::check(
            "0 <= eps <= w*a",
            2000,
            0xE44,
            |r| (r.u8(), r.u8(), r.below(8) as u32),
            |&(w, a, m)| {
                Family::APPROX.into_iter().all(|f| {
                    let e = err(f, w, a, m);
                    0 <= e && e <= (w as i32) * (a as i32)
                })
            },
        );
    }

    #[test]
    fn m_zero_is_exact() {
        for f in Family::ALL {
            for (w, a) in [(0u8, 0u8), (255, 255), (17, 203), (1, 128)] {
                assert_eq!(am(f, w, a, 0), (w as i32) * (a as i32));
            }
        }
    }

    #[test]
    fn truncated_error_le_perforated() {
        // Truncation keeps a superset of perforation's partial-product bits.
        prop::check(
            "eps_T <= eps_P",
            2000,
            0xBEE,
            |r| (r.u8(), r.u8(), 1 + r.below(7) as u32),
            |&(w, a, m)| err(Family::Truncated, w, a, m) <= err(Family::Perforated, w, a, m),
        );
    }

    #[test]
    fn recursive_error_symmetric() {
        prop::check(
            "eps_R(w,a) == eps_R(a,w)",
            1000,
            0x5EC,
            |r| (r.u8(), r.u8(), 1 + r.below(7) as u32),
            |&(w, a, m)| err(Family::Recursive, w, a, m) == err(Family::Recursive, a, w, m),
        );
    }

    #[test]
    fn w_hat_is_mean_truncation_error() {
        // Ŵ (eq. 24) equals the empirical mean of ε_T over all 256 A values.
        for m in 1..=7u32 {
            let mut r = Rng::new(m as u64);
            for _ in 0..64 {
                let w = r.u8();
                let sum: i64 =
                    (0..=255u8).map(|a| err(Family::Truncated, w, a, m) as i64).sum();
                // mean * 2 * 256 == w_hat_q1 * 256  <=>  sum*2 == w_hat_q1*256
                assert_eq!(sum * 2, (w_hat_q1(w, m) as i64) * 256, "w={w} m={m}");
            }
        }
    }

    #[test]
    fn xvar_matches_or_reduction() {
        for a in 0..=255u8 {
            for m in 1..=7u32 {
                let low = (a as i32) & ((1 << m) - 1);
                assert_eq!(xvar(Family::Truncated, a, m), (low != 0) as i32);
                assert_eq!(xvar(Family::Perforated, a, m), low);
                // x == 0 iff the truncated multiplication is error-free for all w
                let always_exact =
                    (0..=255u8).all(|w| err(Family::Truncated, w, a, m) == 0);
                assert_eq!(always_exact, xvar(Family::Truncated, a, m) == 0);
            }
        }
    }

    #[test]
    fn lut_matches_direct() {
        for family in Family::APPROX {
            let m = family.paper_levels()[1];
            let lut = MulLut::build(family, m);
            let mut r = Rng::new(99);
            for _ in 0..2000 {
                let (w, a) = (r.u8(), r.u8());
                assert_eq!(lut.mul(w, a), am(family, w, a, m));
            }
        }
    }

    #[test]
    fn family_name_roundtrip() {
        for f in Family::ALL {
            assert_eq!(Family::from_name(f.name()), Some(f));
            assert_eq!(Family::from_code(f.code()), Some(f));
        }
        assert_eq!(Family::from_name("bogus"), None);
    }
}
