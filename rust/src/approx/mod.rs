//! Bit-exact models of the paper's approximate 8×8 unsigned multipliers.
//!
//! Three families (paper §2), each with knob `m`:
//! * **perforated** [22]: drop the `m` least-significant partial products
//!   (s = 0) — error ε = W·(A mod 2^m) (eq. 3).
//! * **recursive** [23,24]: split each operand into m-bit low / (8−m)-bit
//!   high parts and drop the W_L·A_L sub-product — ε = W_L·A_L (eq. 6).
//! * **truncated** [17-19]: remove all partial-product bits in the `m`
//!   least-significant columns — ε = Σ_{i<m} (W mod 2^{m−i})·a_i·2^i (eq. 8).
//!
//! Everything downstream (GEMM engines, systolic simulator, Pallas kernels)
//! uses the closed-form identities; [`bitmodel`] re-derives the products
//! from the partial-product array structure and the exhaustive tests prove
//! the two agree for **all 2^16 operand pairs and every m** — so the fast
//! identity path *is* the hardware behaviour.

pub mod bitmodel;
pub mod stats;

/// Signed-error direction of an approximate multiplier point (the
/// positive/negative pairing axis of Spantidi et al.).
///
/// * `Neg` — the paper's original designs: dropped partial products make
///   AM(W, A) ≤ W·A, so ε = W·A − AM ≥ 0 (the error *underestimates*).
/// * `Pos` — the round-up-compensated counterpart: the dropped low part is
///   replaced by its modular complement, so AM(W, A) ≥ W·A. The modular
///   complement is a bijection on the dropped-bit domain, which makes the
///   Pos error distribution the **exact mirror** of the Neg one — equal σ,
///   mean exactly negated (asserted over the full 2^16 operand grid in
///   [`stats`]).
///
/// Pairing one point of each polarity across the reduction dimension of a
/// layer (even/odd systolic columns) cancels the accumulated column error
/// in expectation *before* the control-variate epilogue runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Polarity {
    Neg,
    Pos,
}

impl Polarity {
    pub const ALL: [Polarity; 2] = [Polarity::Neg, Polarity::Pos];

    pub fn name(self) -> &'static str {
        match self {
            Polarity::Neg => "neg",
            Polarity::Pos => "pos",
        }
    }

    pub fn from_name(s: &str) -> Option<Polarity> {
        Polarity::ALL.into_iter().find(|p| p.name() == s)
    }

    /// Byte code used by serialized artifacts.
    pub fn code(self) -> u8 {
        match self {
            Polarity::Neg => 0,
            Polarity::Pos => 1,
        }
    }

    pub fn from_code(c: u8) -> Option<Polarity> {
        Polarity::ALL.into_iter().find(|p| p.code() == c)
    }
}

/// Approximate-multiplier family. `Exact` is the baseline (m ignored).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Family {
    Exact,
    Perforated,
    Recursive,
    Truncated,
}

impl Family {
    pub const ALL: [Family; 4] =
        [Family::Exact, Family::Perforated, Family::Recursive, Family::Truncated];

    /// The three approximate families (everything but `Exact`).
    pub const APPROX: [Family; 3] =
        [Family::Perforated, Family::Recursive, Family::Truncated];

    pub fn name(self) -> &'static str {
        match self {
            Family::Exact => "exact",
            Family::Perforated => "perforated",
            Family::Recursive => "recursive",
            Family::Truncated => "truncated",
        }
    }

    pub fn from_name(s: &str) -> Option<Family> {
        Family::ALL.into_iter().find(|f| f.name() == s)
    }

    /// Byte code used by the .gv golden-vector format.
    pub fn code(self) -> u8 {
        match self {
            Family::Exact => 0,
            Family::Perforated => 1,
            Family::Recursive => 2,
            Family::Truncated => 3,
        }
    }

    pub fn from_code(c: u8) -> Option<Family> {
        Family::ALL.into_iter().find(|f| f.code() == c)
    }

    /// The approximation levels the paper evaluates for this family
    /// (Tables 2-4 / Figs 7-9).
    pub fn paper_levels(self) -> &'static [u32] {
        match self {
            Family::Exact => &[0],
            Family::Perforated => &[1, 2, 3],
            Family::Recursive => &[2, 3, 4],
            Family::Truncated => &[5, 6, 7],
        }
    }

    /// Extended levels used by the error analysis (Table 1).
    pub fn table1_levels(self) -> &'static [u32] {
        match self {
            Family::Exact => &[0],
            Family::Perforated => &[1, 2, 3],
            Family::Recursive => &[2, 3, 4, 5],
            Family::Truncated => &[4, 5, 6, 7],
        }
    }
}

/// Multiplication error ε(W, A) = W·A − AM(W, A) ≥ 0 via the closed forms.
#[inline]
pub fn err(family: Family, w: u8, a: u8, m: u32) -> i32 {
    debug_assert!(m <= 7);
    let (w, a) = (w as i32, a as i32);
    let mask = (1i32 << m) - 1;
    match family {
        Family::Exact => 0,
        Family::Perforated => w * (a & mask),
        Family::Recursive => (w & mask) * (a & mask),
        Family::Truncated => {
            let mut e = 0i32;
            for i in 0..m {
                let sub = w & ((1 << (m - i)) - 1);
                e += sub * ((a >> i) & 1) << i;
            }
            e
        }
    }
}

/// Approximate product AM(W, A) = W·A − ε(W, A).
#[inline]
pub fn am(family: Family, w: u8, a: u8, m: u32) -> i32 {
    (w as i32) * (a as i32) - err(family, w, a, m)
}

/// Modular complement of the `m` low bits: `(2^m − (x mod 2^m)) mod 2^m`.
///
/// The construction behind every `Pos` variant: `comp_low` is a bijection
/// on `[0, 2^m)` (0 ↔ 0, l ↔ 2^m − l), so any error term built from it has
/// exactly the distribution of the matching low-bits term — mirrored.
#[inline]
pub fn comp_low(x: i32, m: u32) -> i32 {
    let mask = (1i32 << m) - 1;
    ((1i32 << m) - (x & mask)) & mask
}

/// Signed multiplication error ε(W, A) = W·A − AM(W, A) of a `(family, m,
/// polarity)` point. `Neg` is [`err`] (ε ≥ 0); `Pos` is the round-up
/// counterpart (ε ≤ 0), built from modular complements of the dropped bits:
///
/// * perforated: the high part rounds up on OR of the dropped A rows —
///   ε = −W · comp(A)
/// * recursive: the pruned W_L·A_L sub-product mirrors to its complement —
///   ε = −comp(W_L) · comp(A_L)
/// * truncated: each dropped column rounds W's kept low bits up —
///   ε = −Σ_{i<m} comp_{m−i}(W) · a_i · 2^i
#[inline]
pub fn err_pol(family: Family, pol: Polarity, w: u8, a: u8, m: u32) -> i32 {
    match pol {
        Polarity::Neg => err(family, w, a, m),
        Polarity::Pos => {
            debug_assert!(m <= 7);
            let (w, a) = (w as i32, a as i32);
            match family {
                Family::Exact => 0,
                Family::Perforated => -(w * comp_low(a, m)),
                Family::Recursive => -(comp_low(w, m) * comp_low(a, m)),
                Family::Truncated => {
                    let mut e = 0i32;
                    for i in 0..m {
                        e += comp_low(w, m - i) * ((a >> i) & 1) << i;
                    }
                    -e
                }
            }
        }
    }
}

/// Approximate product of a `(family, m, polarity)` point:
/// AM(W, A) = W·A − ε. `Pos` points overestimate (AM ≥ W·A).
#[inline]
pub fn am_pol(family: Family, pol: Polarity, w: u8, a: u8, m: u32) -> i32 {
    (w as i32) * (a as i32) - err_pol(family, pol, w, a, m)
}

/// Control-variate input x_j (eqs. 18/25/29):
/// perforated/recursive → A mod 2^m; truncated → OR(A[m−1:0]) ∈ {0,1}.
#[inline]
pub fn xvar(family: Family, a: u8, m: u32) -> i32 {
    let low = (a as i32) & ((1i32 << m) - 1);
    match family {
        Family::Exact => 0,
        Family::Perforated | Family::Recursive => low,
        Family::Truncated => (low != 0) as i32,
    }
}

/// Control-variate input x_j of a `(family, m, polarity)` point. `Neg` is
/// [`xvar`]; `Pos` regresses on the mirrored quantity: perforated /
/// recursive → comp(A mod 2^m) (the round-up residue), truncated → the same
/// OR(A[m−1:0]) indicator (a dropped column is compensated iff a_i fires,
/// exactly when the Neg design truncates).
#[inline]
pub fn xvar_pol(family: Family, pol: Polarity, a: u8, m: u32) -> i32 {
    match pol {
        Polarity::Neg => xvar(family, a, m),
        Polarity::Pos => match family {
            Family::Exact => 0,
            Family::Perforated | Family::Recursive => comp_low(a as i32, m),
            Family::Truncated => (((a as i32) & ((1i32 << m) - 1)) != 0) as i32,
        },
    }
}

/// 2·Ŵ (eq. 24 scaled to stay integral): the mean truncation error of
/// AM_T(W, ·) over uniform A, in Q.1 fixed point.
#[inline]
pub fn w_hat_q1(w: u8, m: u32) -> i32 {
    let w = w as i32;
    let mut acc = 0i32;
    for i in 0..m {
        acc += (w & ((1 << (m - i)) - 1)) << i;
    }
    acc
}

/// Positive-polarity counterpart of [`w_hat_q1`]: 2·Ŵ⁺, the mean *magnitude*
/// of the round-up truncation error of AM_T⁺(W, ·) over uniform A, in Q.1.
#[inline]
pub fn w_hat_pos_q1(w: u8, m: u32) -> i32 {
    let w = w as i32;
    let mut acc = 0i32;
    for i in 0..m {
        acc += comp_low(w, m - i) << i;
    }
    acc
}

/// 256×256 lookup table of AM products for one (family, m, polarity) — the
/// hardware-faithful path used by the systolic simulator (TFApprox-style).
///
/// Each table carries a build-time content checksum so runtime corruption
/// (an SRAM bit-flip, a chaos injection from `fault::FaultPlan`) can be
/// detected by recomputation and healed by rebuilding the table from the
/// closed-form / structural product functions.
pub struct MulLut {
    pub family: Family,
    pub m: u32,
    pub polarity: Polarity,
    table: Vec<i32>, // [w * 256 + a]
    checksum: u64,   // digest of `table` at construction
}

impl MulLut {
    /// Build the negative-polarity (paper-original) table.
    pub fn build(family: Family, m: u32) -> MulLut {
        MulLut::build_pol(family, m, Polarity::Neg)
    }

    /// Build the table for one (family, m, polarity) point.
    pub fn build_pol(family: Family, m: u32, pol: Polarity) -> MulLut {
        MulLut::from_fn(family, m, pol, |w, a| am_pol(family, pol, w, a, m))
    }

    /// Build a table from an arbitrary product function — the differential
    /// harness injects the *structural* [`bitmodel`] products here, so a
    /// forward pass can be driven product-for-product by the circuit model.
    pub fn from_fn(
        family: Family,
        m: u32,
        polarity: Polarity,
        f: impl Fn(u8, u8) -> i32,
    ) -> MulLut {
        let mut table = vec![0i32; 65536];
        for w in 0..256usize {
            for a in 0..256usize {
                table[w * 256 + a] = f(w as u8, a as u8);
            }
        }
        let checksum = crate::util::hash::checksum_i32s(&table);
        MulLut { family, m, polarity, table, checksum }
    }

    #[inline]
    pub fn mul(&self, w: u8, a: u8) -> i32 {
        self.table[(w as usize) * 256 + a as usize]
    }

    /// Content digest stamped at construction.
    pub fn checksum(&self) -> u64 {
        self.checksum
    }

    /// Recompute the digest; `false` means the table bits no longer match
    /// what was built (corruption).
    pub fn verify(&self) -> bool {
        crate::util::hash::checksum_i32s(&self.table) == self.checksum
    }

    /// Chaos helper: a copy with `bit` flipped in each of `span` consecutive
    /// entries starting at `entry` (wrapping), keeping the *original*
    /// checksum — so [`MulLut::verify`] on the copy fails, modelling an
    /// undetected in-place memory fault.
    pub fn with_flipped_bits(&self, entry: usize, span: usize, bit: u32) -> MulLut {
        let mut table = self.table.clone();
        let n = table.len();
        for i in 0..span.max(1) {
            table[(entry + i) % n] ^= 1i32 << (bit % 31);
        }
        MulLut {
            family: self.family,
            m: self.m,
            polarity: self.polarity,
            table,
            checksum: self.checksum,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn exhaustive_identity_vs_bitmodel_all_m() {
        // The cornerstone: closed forms == structural partial-product models
        // for ALL operand pairs and every m in 0..=7.
        for family in Family::APPROX {
            for m in 0..=7u32 {
                for w in 0..=255u8 {
                    for a in 0..=255u8 {
                        let fast = am(family, w, a, m);
                        let slow = bitmodel::am_bits(family, w, a, m);
                        assert_eq!(
                            fast, slow,
                            "{} m={m} w={w} a={a}", family.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn exhaustive_pos_identity_vs_bitmodel_all_m() {
        // The positive-polarity cornerstone: the Pos closed forms equal the
        // structural round-up circuit models for ALL operand pairs and m.
        for family in Family::APPROX {
            for m in 0..=7u32 {
                for w in 0..=255u8 {
                    for a in 0..=255u8 {
                        let fast = am_pol(family, Polarity::Pos, w, a, m);
                        let slow = bitmodel::am_bits_pol(family, Polarity::Pos, w, a, m);
                        assert_eq!(fast, slow, "{} m={m} w={w} a={a}", family.name());
                    }
                }
            }
        }
    }

    #[test]
    fn pos_error_nonpositive_and_bounded() {
        // Pos points overestimate: ε ≤ 0, |ε| bounded by the complement of
        // the dropped low part (≤ W·(2^m − 1) for perforated, the analogous
        // caps for the others).
        prop::check(
            "-w*(2^m-1) <= eps_pos <= 0",
            2000,
            0xE45,
            |r| (r.u8(), r.u8(), r.below(8) as u32),
            |&(w, a, m)| {
                Family::APPROX.into_iter().all(|f| {
                    let e = err_pol(f, Polarity::Pos, w, a, m);
                    let cap = 255i32 * ((1i32 << m) - 1);
                    -cap <= e && e <= 0
                })
            },
        );
    }

    #[test]
    fn neg_polarity_is_the_original_error() {
        let mut r = Rng::new(0xD1FF);
        for _ in 0..500 {
            let (w, a) = (r.u8(), r.u8());
            let m = 1 + r.below(7) as u32;
            for f in Family::ALL {
                assert_eq!(err_pol(f, Polarity::Neg, w, a, m), err(f, w, a, m));
                assert_eq!(am_pol(f, Polarity::Neg, w, a, m), am(f, w, a, m));
                assert_eq!(xvar_pol(f, Polarity::Neg, a, m), xvar(f, a, m));
            }
        }
    }

    #[test]
    fn pos_m_zero_is_exact() {
        for f in Family::ALL {
            for (w, a) in [(0u8, 0u8), (255, 255), (17, 203), (1, 128)] {
                assert_eq!(am_pol(f, Polarity::Pos, w, a, 0), (w as i32) * (a as i32));
            }
        }
    }

    #[test]
    fn comp_low_is_a_bijection_on_the_low_bits() {
        for m in 0..=7u32 {
            let l = 1i32 << m;
            let mut seen = vec![false; l as usize];
            for x in 0..l {
                let c = comp_low(x, m);
                assert!((0..l).contains(&c), "m={m} x={x} c={c}");
                assert!(!seen[c as usize], "m={m}: comp not injective at {x}");
                seen[c as usize] = true;
                // involution: comp(comp(x)) == x
                assert_eq!(comp_low(c, m), x, "m={m} x={x}");
            }
        }
    }

    #[test]
    fn w_hat_pos_is_mean_roundup_error_magnitude() {
        // Ŵ⁺ equals the empirical mean of |ε⁺_T| over all 256 A values.
        for m in 1..=7u32 {
            let mut r = Rng::new(0x700 + m as u64);
            for _ in 0..64 {
                let w = r.u8();
                let sum: i64 = (0..=255u8)
                    .map(|a| -err_pol(Family::Truncated, Polarity::Pos, w, a, m) as i64)
                    .sum();
                assert_eq!(sum * 2, (w_hat_pos_q1(w, m) as i64) * 256, "w={w} m={m}");
            }
        }
    }

    #[test]
    fn pos_xvar_tracks_error_support() {
        for a in 0..=255u8 {
            for m in 1..=7u32 {
                let low = (a as i32) & ((1 << m) - 1);
                assert_eq!(
                    xvar_pol(Family::Perforated, Polarity::Pos, a, m),
                    comp_low(low, m)
                );
                // x⁺ == 0 iff the positive perforated point is error-free
                // for every w (no round-up fires).
                let always_exact = (0..=255u8)
                    .all(|w| err_pol(Family::Perforated, Polarity::Pos, w, a, m) == 0);
                assert_eq!(
                    always_exact,
                    xvar_pol(Family::Perforated, Polarity::Pos, a, m) == 0
                );
                assert_eq!(
                    xvar_pol(Family::Truncated, Polarity::Pos, a, m),
                    (low != 0) as i32
                );
            }
        }
    }

    #[test]
    fn lut_pol_matches_direct() {
        for family in Family::APPROX {
            let m = family.paper_levels()[1];
            for pol in Polarity::ALL {
                let lut = MulLut::build_pol(family, m, pol);
                assert_eq!(lut.polarity, pol);
                let mut r = Rng::new(0x99 + pol.code() as u64);
                for _ in 0..2000 {
                    let (w, a) = (r.u8(), r.u8());
                    assert_eq!(lut.mul(w, a), am_pol(family, pol, w, a, m));
                }
            }
        }
    }

    #[test]
    fn lut_checksum_detects_bit_flips() {
        let lut = MulLut::build_pol(Family::Perforated, 2, Polarity::Neg);
        assert!(lut.verify());
        let twin = MulLut::build_pol(Family::Perforated, 2, Polarity::Neg);
        assert_eq!(lut.checksum(), twin.checksum());
        let bad = lut.with_flipped_bits(1234, 1, 22);
        assert!(!bad.verify(), "single flipped bit must break verification");
        assert_eq!(bad.checksum(), lut.checksum(), "copy keeps the build-time digest");
        assert_eq!(bad.mul(4, 210), lut.mul(4, 210) ^ (1 << 22), "entry 4*256+210");
        let burst = lut.with_flipped_bits(65_530, 16, 24);
        assert!(!burst.verify(), "wrapping burst must break verification");
    }

    #[test]
    fn polarity_name_roundtrip() {
        for p in Polarity::ALL {
            assert_eq!(Polarity::from_name(p.name()), Some(p));
            assert_eq!(Polarity::from_code(p.code()), Some(p));
        }
        assert_eq!(Polarity::from_name("bogus"), None);
        assert_eq!(Polarity::from_code(9), None);
    }

    #[test]
    fn error_nonnegative_and_bounded() {
        prop::check(
            "0 <= eps <= w*a",
            2000,
            0xE44,
            |r| (r.u8(), r.u8(), r.below(8) as u32),
            |&(w, a, m)| {
                Family::APPROX.into_iter().all(|f| {
                    let e = err(f, w, a, m);
                    0 <= e && e <= (w as i32) * (a as i32)
                })
            },
        );
    }

    #[test]
    fn m_zero_is_exact() {
        for f in Family::ALL {
            for (w, a) in [(0u8, 0u8), (255, 255), (17, 203), (1, 128)] {
                assert_eq!(am(f, w, a, 0), (w as i32) * (a as i32));
            }
        }
    }

    #[test]
    fn truncated_error_le_perforated() {
        // Truncation keeps a superset of perforation's partial-product bits.
        prop::check(
            "eps_T <= eps_P",
            2000,
            0xBEE,
            |r| (r.u8(), r.u8(), 1 + r.below(7) as u32),
            |&(w, a, m)| err(Family::Truncated, w, a, m) <= err(Family::Perforated, w, a, m),
        );
    }

    #[test]
    fn recursive_error_symmetric() {
        prop::check(
            "eps_R(w,a) == eps_R(a,w)",
            1000,
            0x5EC,
            |r| (r.u8(), r.u8(), 1 + r.below(7) as u32),
            |&(w, a, m)| err(Family::Recursive, w, a, m) == err(Family::Recursive, a, w, m),
        );
    }

    #[test]
    fn w_hat_is_mean_truncation_error() {
        // Ŵ (eq. 24) equals the empirical mean of ε_T over all 256 A values.
        for m in 1..=7u32 {
            let mut r = Rng::new(m as u64);
            for _ in 0..64 {
                let w = r.u8();
                let sum: i64 =
                    (0..=255u8).map(|a| err(Family::Truncated, w, a, m) as i64).sum();
                // mean * 2 * 256 == w_hat_q1 * 256  <=>  sum*2 == w_hat_q1*256
                assert_eq!(sum * 2, (w_hat_q1(w, m) as i64) * 256, "w={w} m={m}");
            }
        }
    }

    #[test]
    fn xvar_matches_or_reduction() {
        for a in 0..=255u8 {
            for m in 1..=7u32 {
                let low = (a as i32) & ((1 << m) - 1);
                assert_eq!(xvar(Family::Truncated, a, m), (low != 0) as i32);
                assert_eq!(xvar(Family::Perforated, a, m), low);
                // x == 0 iff the truncated multiplication is error-free for all w
                let always_exact =
                    (0..=255u8).all(|w| err(Family::Truncated, w, a, m) == 0);
                assert_eq!(always_exact, xvar(Family::Truncated, a, m) == 0);
            }
        }
    }

    #[test]
    fn lut_matches_direct() {
        for family in Family::APPROX {
            let m = family.paper_levels()[1];
            let lut = MulLut::build(family, m);
            let mut r = Rng::new(99);
            for _ in 0..2000 {
                let (w, a) = (r.u8(), r.u8());
                assert_eq!(lut.mul(w, a), am(family, w, a, m));
            }
        }
    }

    #[test]
    fn family_name_roundtrip() {
        for f in Family::ALL {
            assert_eq!(Family::from_name(f.name()), Some(f));
            assert_eq!(Family::from_code(f.code()), Some(f));
        }
        assert_eq!(Family::from_name("bogus"), None);
    }
}
