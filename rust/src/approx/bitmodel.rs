//! Structural (partial-product-level) models of the approximate multipliers.
//!
//! These build each product the way the *circuit* does — by generating the
//! AND-array of partial-product bits and summing only the ones the
//! approximate hardware keeps (paper Figs 1-3). They are deliberately slow
//! and obvious; the exhaustive test in `approx::tests` proves the fast
//! closed-form identities equal these for every operand pair and m.

use super::{Family, Polarity};

/// Perforated multiplier, eq. (2) with s = 0: partial products i ∈ [0, m)
/// are never generated.
pub fn am_perforated_bits(w: u8, a: u8, m: u32) -> i32 {
    let mut acc = 0i32;
    for i in m..8 {
        let ai = ((a >> i) & 1) as i32;
        acc += (w as i32) * ai << i;
    }
    acc
}

/// Recursive multiplier, eq. (5): 2^m-split sub-products with W_L·A_L pruned.
pub fn am_recursive_bits(w: u8, a: u8, m: u32) -> i32 {
    let mask = (1u32 << m) - 1;
    let (wh, wl) = ((w as u32) >> m, (w as u32) & mask);
    let (ah, al) = ((a as u32) >> m, (a as u32) & mask);
    // (W_H·A_H·2^m + W_H·A_L + W_L·A_H) · 2^m  — eq. (5)
    (((wh * ah) << m) + wh * al + wl * ah << m) as i32
}

/// Truncated multiplier, eq. (7): AND gates w_j·a_i with i + j < m are not
/// implemented; every kept partial-product bit is summed individually.
pub fn am_truncated_bits(w: u8, a: u8, m: u32) -> i32 {
    let mut acc = 0i32;
    for i in 0..8u32 {
        for j in 0..8u32 {
            if i + j >= m {
                let bit = (((w >> j) & 1) & ((a >> i) & 1)) as i32;
                acc += bit << (i + j);
            }
        }
    }
    acc
}

/// Structural AM for any family (Exact sums the full partial-product array).
pub fn am_bits(family: Family, w: u8, a: u8, m: u32) -> i32 {
    match family {
        Family::Exact => am_truncated_bits(w, a, 0),
        Family::Perforated => am_perforated_bits(w, a, m),
        Family::Recursive => am_recursive_bits(w, a, m),
        Family::Truncated => am_truncated_bits(w, a, m),
    }
}

/// Positive (round-up) perforated multiplier: the kept rows i ≥ m, plus a
/// conditional W·2^m carry-in when any dropped row of A fires — the high
/// part of A rounds *up* instead of truncating, so AM ≥ W·A.
pub fn am_perforated_bits_pos(w: u8, a: u8, m: u32) -> i32 {
    let mut acc = 0i32;
    for i in m..8 {
        let ai = ((a >> i) & 1) as i32;
        acc += (w as i32) * ai << i;
    }
    // OR over the dropped rows gates one extra W row at weight 2^m.
    let dropped_or = ((a as i32) & ((1i32 << m) - 1) != 0) as i32;
    acc + ((w as i32) * dropped_or << m)
}

/// Positive recursive multiplier: the exact sub-product recombination plus
/// the *complement* sub-product comp(W_L)·comp(A_L) injected — the mirrored
/// twin of pruning W_L·A_L, built sub-product by sub-product like eq. (5).
pub fn am_recursive_bits_pos(w: u8, a: u8, m: u32) -> i32 {
    let mask = (1u32 << m) - 1;
    let (wh, wl) = ((w as u32) >> m, (w as u32) & mask);
    let (ah, al) = ((a as u32) >> m, (a as u32) & mask);
    let cw = ((1u32 << m) - wl) & mask;
    let ca = ((1u32 << m) - al) & mask;
    // exact recombination (all four sub-products) + the complement product
    ((((wh * ah) << m) + wh * al + wl * ah << m) + wl * al + cw * ca) as i32
}

/// Positive truncated multiplier: the kept partial-product bits plus, for
/// each row i < m whose dropped group W mod 2^{m−i} is nonzero, one 2^m
/// carry-in gated by a_i — each truncated row product rounds *up* to the
/// next multiple of 2^{m−i} instead of down. The "dropped group nonzero"
/// flag is a function of the *stationary* weight, so the hardware computes
/// it once at weight-load time; per cycle the compensation is one AND gate
/// per row feeding the 2^m column.
pub fn am_truncated_bits_pos(w: u8, a: u8, m: u32) -> i32 {
    let mut acc = am_truncated_bits(w, a, m);
    for i in 0..m {
        let ai = ((a >> i) & 1) as i32;
        let dropped_nonzero = ((w as i32) & ((1i32 << (m - i)) - 1) != 0) as i32;
        acc += dropped_nonzero * ai << m;
    }
    acc
}

/// Structural AM for any (family, polarity) point.
pub fn am_bits_pol(family: Family, pol: Polarity, w: u8, a: u8, m: u32) -> i32 {
    match (pol, family) {
        (Polarity::Neg, _) | (_, Family::Exact) => am_bits(family, w, a, m),
        (Polarity::Pos, Family::Perforated) => am_perforated_bits_pos(w, a, m),
        (Polarity::Pos, Family::Recursive) => am_recursive_bits_pos(w, a, m),
        (Polarity::Pos, Family::Truncated) => am_truncated_bits_pos(w, a, m),
    }
}

/// Count of partial-product bits the truncated multiplier keeps — drives the
/// hardware cost model (compressor count scales with kept bits).
pub fn truncated_kept_bits(m: u32) -> u32 {
    let mut kept = 0;
    for i in 0..8u32 {
        for j in 0..8u32 {
            if i + j >= m {
                kept += 1;
            }
        }
    }
    kept
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_via_full_array() {
        for (w, a) in [(0u8, 0u8), (255, 255), (200, 3), (13, 77)] {
            assert_eq!(am_truncated_bits(w, a, 0), (w as i32) * (a as i32));
        }
    }

    #[test]
    fn kulkarni_style_recursive_prunes_low_product() {
        // m=4: AM_R(W,A) misses exactly W_L*A_L.
        let (w, a) = (0xAB_u8, 0xCD_u8);
        let wl = (w & 0xF) as i32;
        let al = (a & 0xF) as i32;
        assert_eq!(
            am_recursive_bits(w, a, 4),
            (w as i32) * (a as i32) - wl * al
        );
    }

    #[test]
    fn truncated_kept_bits_counts() {
        assert_eq!(truncated_kept_bits(0), 64);
        // m=1 drops exactly the single (0,0) bit
        assert_eq!(truncated_kept_bits(1), 63);
        // m=7 drops 1+2+...+7 = 28 bits
        assert_eq!(truncated_kept_bits(7), 36);
    }

    #[test]
    fn perforation_is_row_removal() {
        // Perforating m rows == zeroing the m low bits of A before multiplying.
        for m in 0..8u32 {
            for (w, a) in [(255u8, 255u8), (170, 85), (9, 250)] {
                let expect = (w as i32) * (((a as u32) >> m << m) as i32);
                assert_eq!(am_perforated_bits(w, a, m), expect);
            }
        }
    }
}
