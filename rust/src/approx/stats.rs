//! Error analysis of the approximate multipliers — regenerates **Table 1**
//! and characterizes the signed-error profile of every (family, m,
//! polarity) point.
//!
//! μ and σ of ε over 1M operand pairs for uniform U(0,255) and normal
//! N(125, 24²) input distributions, per family and m; plus
//! [`signed_moments`]: exact mean/σ/sign of ε = W·A − AM over the **full
//! 2^16 operand grid**, computed from the closed forms (proven equal to the
//! [`super::bitmodel`] circuits) and cached process-wide like the LUTs —
//! the quantity the paired-policy search consults to predict cancellation.

use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};

use super::{err, err_pol, Family, Polarity};
use crate::util::rng::Rng;
use crate::util::sync::lock_clean;
use crate::util::stats::Welford;

/// Input operand distribution used by the paper's Table 1.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dist {
    Uniform,
    /// N(125, 24²), rounded + clamped to [0, 255].
    Normal,
}

impl Dist {
    pub fn name(self) -> &'static str {
        match self {
            Dist::Uniform => "U(0,255)",
            Dist::Normal => "N(125,24^2)",
        }
    }

    fn sample(self, rng: &mut Rng) -> u8 {
        match self {
            Dist::Uniform => rng.u8(),
            Dist::Normal => rng.u8_normal(125.0, 24.0),
        }
    }
}

/// One Table-1 row: error moments for (family, m, dist).
#[derive(Clone, Debug)]
pub struct ErrorRow {
    pub family: Family,
    pub m: u32,
    pub dist: Dist,
    pub mean: f64,
    pub std: f64,
}

/// Monte-Carlo error moments over `n` operand pairs (paper uses 1M).
pub fn error_moments(family: Family, m: u32, dist: Dist, n: u64, seed: u64) -> ErrorRow {
    let mut rng = Rng::new(seed);
    let mut acc = Welford::new();
    for _ in 0..n {
        let w = dist.sample(&mut rng);
        let a = dist.sample(&mut rng);
        acc.push(err(family, w, a, m) as f64);
    }
    ErrorRow { family, m, dist, mean: acc.mean(), std: acc.std() }
}

/// Exact (closed-form, full 2^16 enumeration) moments for the uniform case —
/// used to validate the Monte-Carlo within tolerance.
pub fn error_moments_exhaustive_uniform(family: Family, m: u32) -> (f64, f64) {
    let mut acc = Welford::new();
    for w in 0..=255u8 {
        for a in 0..=255u8 {
            acc.push(err(family, w, a, m) as f64);
        }
    }
    (acc.mean(), acc.std())
}

/// Signed-error profile of one (family, m, polarity) multiplier point:
/// exact moments of ε = W·A − AM(W, A) over the full uniform 2^16 operand
/// grid. `Neg` points have `mean ≥ 0` (underestimate), `Pos` points
/// `mean ≤ 0` (overestimate) — and the two are exact mirrors (equal σ,
/// negated mean), which is what makes even/odd pairing cancel.
#[derive(Clone, Copy, Debug)]
pub struct SignedMoments {
    pub family: Family,
    pub m: u32,
    pub polarity: Polarity,
    pub mean: f64,
    pub std: f64,
}

impl SignedMoments {
    /// −1, 0 or +1: the direction this point biases an accumulator.
    pub fn sign(&self) -> i32 {
        if self.mean > 0.0 {
            1
        } else if self.mean < 0.0 {
            -1
        } else {
            0
        }
    }
}

fn signed_moments_exhaustive(family: Family, m: u32, pol: Polarity) -> SignedMoments {
    let mut acc = Welford::new();
    for w in 0..=255u8 {
        for a in 0..=255u8 {
            acc.push(err_pol(family, pol, w, a, m) as f64);
        }
    }
    SignedMoments { family, m, polarity: pol, mean: acc.mean(), std: acc.std() }
}

/// Exact signed-error moments for a (family, m, polarity) point, computed
/// over the full 2^16 grid on first use and cached process-wide (like the
/// product LUTs: one build, shared by every engine/search that asks).
pub fn signed_moments(family: Family, m: u32, pol: Polarity) -> SignedMoments {
    static CACHE: OnceLock<Mutex<HashMap<(Family, u32, Polarity), SignedMoments>>> =
        OnceLock::new();
    let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    let mut map = lock_clean(cache);
    *map.entry((family, m, pol))
        .or_insert_with(|| signed_moments_exhaustive(family, m, pol))
}

/// Expected per-MAC accumulator bias of splitting a reduction evenly
/// between two multiplier points (the even/odd column pairing): the mean of
/// the two signed means. A well-chosen Neg/Pos pair drives this to ~0 —
/// pairing a point with its own mirror drives it to *exactly* 0.
pub fn pairing_residual(
    a: (Family, u32, Polarity),
    b: (Family, u32, Polarity),
) -> f64 {
    let ma = signed_moments(a.0, a.1, a.2).mean;
    let mb = signed_moments(b.0, b.1, b.2).mean;
    (ma + mb) / 2.0
}

/// All Table-1 rows (both distributions, table1 m-levels).
pub fn table1(n: u64, seed: u64) -> Vec<ErrorRow> {
    let mut rows = Vec::new();
    for family in Family::APPROX {
        for &m in family.table1_levels() {
            for dist in [Dist::Uniform, Dist::Normal] {
                rows.push(error_moments(family, m, dist, n, seed ^ (m as u64) << 8));
            }
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Paper Table 1, uniform columns: (family, m, mu, sigma).
    const PAPER_UNIFORM: &[(Family, u32, f64, f64)] = &[
        (Family::Perforated, 1, 63.7, 82.0),
        (Family::Perforated, 2, 191.0, 198.0),
        (Family::Perforated, 3, 447.0, 425.0),
        (Family::Recursive, 2, 2.24, 2.67),
        (Family::Recursive, 3, 12.26, 12.51),
        (Family::Recursive, 4, 56.0, 53.4),
        (Family::Recursive, 5, 239.0, 219.0),
        (Family::Truncated, 4, 12.0, 9.9),
        (Family::Truncated, 5, 32.0, 23.0),
        (Family::Truncated, 6, 80.0, 52.0),
        (Family::Truncated, 7, 192.0, 115.0),
    ];

    #[test]
    fn uniform_moments_match_paper_table1() {
        for &(family, m, mu, sigma) in PAPER_UNIFORM {
            let (got_mu, got_sigma) = error_moments_exhaustive_uniform(family, m);
            // Paper reports ~3 significant digits.
            assert!(
                (got_mu - mu).abs() / mu.max(1.0) < 0.03,
                "{} m={m}: mu {got_mu} vs paper {mu}", family.name()
            );
            assert!(
                (got_sigma - sigma).abs() / sigma.max(1.0) < 0.05,
                "{} m={m}: sigma {got_sigma} vs paper {sigma}", family.name()
            );
        }
    }

    #[test]
    fn monte_carlo_converges_to_exhaustive() {
        let (mu_ex, sd_ex) =
            error_moments_exhaustive_uniform(Family::Truncated, 6);
        let row = error_moments(Family::Truncated, 6, Dist::Uniform, 200_000, 7);
        assert!((row.mean - mu_ex).abs() / mu_ex < 0.02);
        assert!((row.std - sd_ex).abs() / sd_ex < 0.02);
    }

    #[test]
    fn recursive_and_truncated_insensitive_to_distribution() {
        // Paper §2.4: their error moments barely change under N(125,24²).
        for (family, m) in [(Family::Recursive, 3), (Family::Truncated, 5)] {
            let u = error_moments(family, m, Dist::Uniform, 150_000, 3);
            let n = error_moments(family, m, Dist::Normal, 150_000, 4);
            assert!(
                (u.mean - n.mean).abs() / u.mean < 0.08,
                "{} m={m}: {} vs {}", family.name(), u.mean, n.mean
            );
        }
    }

    #[test]
    fn perforated_has_highest_dispersion() {
        // Paper §2.4: perforated shows the highest μ and σ at comparable m.
        let p = error_moments_exhaustive_uniform(Family::Perforated, 3);
        let r = error_moments_exhaustive_uniform(Family::Recursive, 3);
        let t = error_moments_exhaustive_uniform(Family::Truncated, 3);
        assert!(p.0 > r.0 && p.0 > t.0);
        assert!(p.1 > r.1 && p.1 > t.1);
    }

    #[test]
    fn truncated_lowest_coefficient_of_variation() {
        // σ/μ: truncated < recursive, perforated at the paper's m points.
        let t = error_moments_exhaustive_uniform(Family::Truncated, 6);
        let p = error_moments_exhaustive_uniform(Family::Perforated, 2);
        let r = error_moments_exhaustive_uniform(Family::Recursive, 4);
        let cv = |x: (f64, f64)| x.1 / x.0;
        assert!(cv(t) < cv(p));
        assert!(cv(t) < cv(r));
    }

    #[test]
    fn table1_has_all_rows() {
        let rows = table1(1000, 1);
        // 3+4+4 m-levels × 2 distributions
        assert_eq!(rows.len(), (3 + 4 + 4) * 2);
    }

    #[test]
    fn signed_means_pinned_against_brute_force() {
        // Perforated: ε = W·(A mod 2^m) with independent uniform operands,
        // so the full-grid mean is exactly E[W]·E[A mod 2^m]
        // = 127.5 · (2^m − 1)/2 — derived independently of err_pol.
        for m in 1..=3u32 {
            let want = 127.5 * ((1u32 << m) - 1) as f64 / 2.0;
            let neg = signed_moments(Family::Perforated, m, Polarity::Neg);
            assert!((neg.mean - want).abs() < 1e-9, "m={m}: {} vs {want}", neg.mean);
            assert_eq!(neg.sign(), 1);
            let pos = signed_moments(Family::Perforated, m, Polarity::Pos);
            assert!((pos.mean + want).abs() < 1e-9, "m={m}: {} vs -{want}", pos.mean);
            assert_eq!(pos.sign(), -1);
        }
        // Truncated: ε = Σ_{i<m} (W mod 2^{m−i})·a_i·2^i, so the mean is
        // exactly Σ_i ((2^{m−i} − 1)/2) · (1/2) · 2^i.
        for m in [4u32, 6] {
            let want: f64 = (0..m)
                .map(|i| ((1u64 << (m - i)) - 1) as f64 / 2.0 * 0.5 * (1u64 << i) as f64)
                .sum();
            let neg = signed_moments(Family::Truncated, m, Polarity::Neg);
            assert!((neg.mean - want).abs() < 1e-9, "m={m}: {} vs {want}", neg.mean);
            let pos = signed_moments(Family::Truncated, m, Polarity::Pos);
            assert!((pos.mean + want).abs() < 1e-9, "m={m}: {} vs -{want}", pos.mean);
        }
    }

    #[test]
    fn pos_profile_is_the_exact_mirror_of_neg() {
        // The modular-complement construction is a bijection on the dropped
        // bits, so over the full grid the Pos error distribution is the
        // mirrored Neg one: mean exactly negated, σ exactly equal.
        for family in Family::APPROX {
            for &m in family.paper_levels() {
                let neg = signed_moments(family, m, Polarity::Neg);
                let pos = signed_moments(family, m, Polarity::Pos);
                let scale = neg.mean.abs().max(1.0);
                assert!(
                    (neg.mean + pos.mean).abs() / scale < 1e-9,
                    "{} m={m}: {} vs {}",
                    family.name(),
                    neg.mean,
                    pos.mean
                );
                assert!(
                    (neg.std - pos.std).abs() / neg.std.max(1.0) < 1e-9,
                    "{} m={m}: std {} vs {}",
                    family.name(),
                    neg.std,
                    pos.std
                );
                let resid = pairing_residual(
                    (family, m, Polarity::Neg),
                    (family, m, Polarity::Pos),
                );
                assert!(resid.abs() < 1e-9 * scale, "{} m={m}: {resid}", family.name());
            }
        }
    }

    #[test]
    fn signed_moments_cache_is_stable() {
        let a = signed_moments(Family::Recursive, 3, Polarity::Pos);
        let b = signed_moments(Family::Recursive, 3, Polarity::Pos);
        assert_eq!(a.mean, b.mean);
        assert_eq!(a.std, b.std);
        // Exact point has a degenerate profile.
        let e = signed_moments(Family::Exact, 0, Polarity::Neg);
        assert_eq!(e.mean, 0.0);
        assert_eq!(e.sign(), 0);
    }

    #[test]
    fn paired_column_error_cancels_below_either_constituent() {
        // The pairing claim, measured: split a k-long reduction between a
        // Neg and a Pos point (even/odd), accumulate the signed column
        // error over many random activation columns, and compare against
        // running the whole column uniformly at either constituent. The
        // paired mean must be strictly smaller in magnitude than both.
        let mut rng = Rng::new(0xA17D);
        for (family, m) in
            [(Family::Perforated, 2), (Family::Truncated, 6), (Family::Recursive, 3)]
        {
            let k = 64usize;
            let w: Vec<u8> = (0..k).map(|_| rng.u8_normal(128.0, 22.0)).collect();
            let mut paired = Welford::new();
            let mut neg_only = Welford::new();
            let mut pos_only = Welford::new();
            for _ in 0..4000 {
                let a: Vec<u8> = (0..k).map(|_| rng.u8()).collect();
                let mut e_pair = 0i64;
                let mut e_neg = 0i64;
                let mut e_pos = 0i64;
                for (j, (&wj, &aj)) in w.iter().zip(&a).enumerate() {
                    let en = err_pol(family, Polarity::Neg, wj, aj, m) as i64;
                    let ep = err_pol(family, Polarity::Pos, wj, aj, m) as i64;
                    e_pair += if j % 2 == 0 { en } else { ep };
                    e_neg += en;
                    e_pos += ep;
                }
                paired.push(e_pair as f64);
                neg_only.push(e_neg as f64);
                pos_only.push(e_pos as f64);
            }
            assert!(
                paired.mean().abs() < neg_only.mean().abs(),
                "{} m={m}: paired {} !< neg {}",
                family.name(),
                paired.mean(),
                neg_only.mean()
            );
            assert!(
                paired.mean().abs() < pos_only.mean().abs(),
                "{} m={m}: paired {} !< pos {}",
                family.name(),
                paired.mean(),
                pos_only.mean()
            );
        }
    }
}
