//! Error analysis of the approximate multipliers — regenerates **Table 1**.
//!
//! μ and σ of ε over 1M operand pairs for uniform U(0,255) and normal
//! N(125, 24²) input distributions, per family and m.

use super::{err, Family};
use crate::util::rng::Rng;
use crate::util::stats::Welford;

/// Input operand distribution used by the paper's Table 1.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dist {
    Uniform,
    /// N(125, 24²), rounded + clamped to [0, 255].
    Normal,
}

impl Dist {
    pub fn name(self) -> &'static str {
        match self {
            Dist::Uniform => "U(0,255)",
            Dist::Normal => "N(125,24^2)",
        }
    }

    fn sample(self, rng: &mut Rng) -> u8 {
        match self {
            Dist::Uniform => rng.u8(),
            Dist::Normal => rng.u8_normal(125.0, 24.0),
        }
    }
}

/// One Table-1 row: error moments for (family, m, dist).
#[derive(Clone, Debug)]
pub struct ErrorRow {
    pub family: Family,
    pub m: u32,
    pub dist: Dist,
    pub mean: f64,
    pub std: f64,
}

/// Monte-Carlo error moments over `n` operand pairs (paper uses 1M).
pub fn error_moments(family: Family, m: u32, dist: Dist, n: u64, seed: u64) -> ErrorRow {
    let mut rng = Rng::new(seed);
    let mut acc = Welford::new();
    for _ in 0..n {
        let w = dist.sample(&mut rng);
        let a = dist.sample(&mut rng);
        acc.push(err(family, w, a, m) as f64);
    }
    ErrorRow { family, m, dist, mean: acc.mean(), std: acc.std() }
}

/// Exact (closed-form, full 2^16 enumeration) moments for the uniform case —
/// used to validate the Monte-Carlo within tolerance.
pub fn error_moments_exhaustive_uniform(family: Family, m: u32) -> (f64, f64) {
    let mut acc = Welford::new();
    for w in 0..=255u8 {
        for a in 0..=255u8 {
            acc.push(err(family, w, a, m) as f64);
        }
    }
    (acc.mean(), acc.std())
}

/// All Table-1 rows (both distributions, table1 m-levels).
pub fn table1(n: u64, seed: u64) -> Vec<ErrorRow> {
    let mut rows = Vec::new();
    for family in Family::APPROX {
        for &m in family.table1_levels() {
            for dist in [Dist::Uniform, Dist::Normal] {
                rows.push(error_moments(family, m, dist, n, seed ^ (m as u64) << 8));
            }
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Paper Table 1, uniform columns: (family, m, mu, sigma).
    const PAPER_UNIFORM: &[(Family, u32, f64, f64)] = &[
        (Family::Perforated, 1, 63.7, 82.0),
        (Family::Perforated, 2, 191.0, 198.0),
        (Family::Perforated, 3, 447.0, 425.0),
        (Family::Recursive, 2, 2.24, 2.67),
        (Family::Recursive, 3, 12.26, 12.51),
        (Family::Recursive, 4, 56.0, 53.4),
        (Family::Recursive, 5, 239.0, 219.0),
        (Family::Truncated, 4, 12.0, 9.9),
        (Family::Truncated, 5, 32.0, 23.0),
        (Family::Truncated, 6, 80.0, 52.0),
        (Family::Truncated, 7, 192.0, 115.0),
    ];

    #[test]
    fn uniform_moments_match_paper_table1() {
        for &(family, m, mu, sigma) in PAPER_UNIFORM {
            let (got_mu, got_sigma) = error_moments_exhaustive_uniform(family, m);
            // Paper reports ~3 significant digits.
            assert!(
                (got_mu - mu).abs() / mu.max(1.0) < 0.03,
                "{} m={m}: mu {got_mu} vs paper {mu}", family.name()
            );
            assert!(
                (got_sigma - sigma).abs() / sigma.max(1.0) < 0.05,
                "{} m={m}: sigma {got_sigma} vs paper {sigma}", family.name()
            );
        }
    }

    #[test]
    fn monte_carlo_converges_to_exhaustive() {
        let (mu_ex, sd_ex) =
            error_moments_exhaustive_uniform(Family::Truncated, 6);
        let row = error_moments(Family::Truncated, 6, Dist::Uniform, 200_000, 7);
        assert!((row.mean - mu_ex).abs() / mu_ex < 0.02);
        assert!((row.std - sd_ex).abs() / sd_ex < 0.02);
    }

    #[test]
    fn recursive_and_truncated_insensitive_to_distribution() {
        // Paper §2.4: their error moments barely change under N(125,24²).
        for (family, m) in [(Family::Recursive, 3), (Family::Truncated, 5)] {
            let u = error_moments(family, m, Dist::Uniform, 150_000, 3);
            let n = error_moments(family, m, Dist::Normal, 150_000, 4);
            assert!(
                (u.mean - n.mean).abs() / u.mean < 0.08,
                "{} m={m}: {} vs {}", family.name(), u.mean, n.mean
            );
        }
    }

    #[test]
    fn perforated_has_highest_dispersion() {
        // Paper §2.4: perforated shows the highest μ and σ at comparable m.
        let p = error_moments_exhaustive_uniform(Family::Perforated, 3);
        let r = error_moments_exhaustive_uniform(Family::Recursive, 3);
        let t = error_moments_exhaustive_uniform(Family::Truncated, 3);
        assert!(p.0 > r.0 && p.0 > t.0);
        assert!(p.1 > r.1 && p.1 > t.1);
    }

    #[test]
    fn truncated_lowest_coefficient_of_variation() {
        // σ/μ: truncated < recursive, perforated at the paper's m points.
        let t = error_moments_exhaustive_uniform(Family::Truncated, 6);
        let p = error_moments_exhaustive_uniform(Family::Perforated, 2);
        let r = error_moments_exhaustive_uniform(Family::Recursive, 4);
        let cv = |x: (f64, f64)| x.1 / x.0;
        assert!(cv(t) < cv(p));
        assert!(cv(t) < cv(r));
    }

    #[test]
    fn table1_has_all_rows() {
        let rows = table1(1000, 1);
        // 3+4+4 m-levels × 2 distributions
        assert_eq!(rows.len(), (3 + 4 + 4) * 2);
    }
}
