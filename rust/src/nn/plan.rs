//! Precomputed layer plans + reusable scratch for the GEMM hot path.
//!
//! Everything in [`LayerPlan`] is a pure function of the **static weights**
//! of one MAC layer and the (family, m) design point:
//!
//! * the masked weight panels the identity expansion needs (recursive:
//!   `w & (2^m−1)`; truncated: one panel per bit plane),
//! * per-filter `Σw` for the zero-point epilogue,
//! * per-filter control-variate constants C/C₀ (Q.4).
//!
//! The seed recomputed all of these inside `approx_gemm` on **every
//! image**; with plans they are built at most once per (layer, family, m)
//! and shared across the whole batch stream ([`PlanCache`]). [`Scratch`]
//! complements the plans on the activation side: it owns every
//! per-image buffer (im2col staging, widened/masked panels, bit planes,
//! `Σa`/`Σx`, accumulators), so a steady-state `Engine::forward` performs
//! no weight-side recomputation and no per-GEMM heap allocation once the
//! buffers have grown to the largest layer.
//!
//! Plans and scratch are **kernel-backend neutral** (see
//! [`crate::nn::kernel`]): panels and buffers are plain contiguous
//! row-major slices with no alignment or padding contract, so the scalar
//! reference and the SIMD backend consume the same plan bit-for-bit —
//! the backend choice (`CVAPPROX_KERNEL`) changes how a panel is
//! traversed, never what is stored in it. Oversized reduction depths are
//! rejected before any plan is built
//! ([`crate::nn::gemm::max_k_for_point`]), so a cached plan always
//! describes a layer every backend can accumulate in i32.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use super::policy::{LayerPoint, PairedPoint};
use crate::approx::{comp_low, Family, Polarity};
use crate::cv::{self, CvConstants};
use crate::util::hash::Hasher64;
use crate::util::sync::lock_clean;

/// Weight-side precomputation for one MAC layer at one (family, m,
/// polarity) point.
pub struct LayerPlan {
    pub family: Family,
    pub m: u32,
    pub pol: Polarity,
    /// Total filter rows in the layer (across all conv groups).
    pub rows: usize,
    /// Reduction length per filter row.
    pub k: usize,
    /// Recursive family: `w & (2^m − 1)` (Neg) or its modular complement
    /// (Pos), same layout as `w` (else empty).
    w_low: Vec<u8>,
    /// Truncated family: `m` bit-plane panels, plane `i` (at offset
    /// `i * rows * k`) holds `w & (2^(m−i) − 1)` (Neg) or its modular
    /// complement (Pos) (else empty).
    w_planes: Vec<u8>,
    /// Per-row Σw for the zero-point epilogue.
    pub sum_w: Vec<i64>,
    /// Per-row control-variate constants (zeroes for the exact family).
    pub consts: Vec<CvConstants>,
    /// Build-time digest of every derived table above (panels, Σw, C/C₀) —
    /// the fault subsystem recomputes it to detect runtime corruption.
    checksum: u64,
}

impl LayerPlan {
    /// Build the negative-polarity plan for a full layer weight panel `w`
    /// ([rows × k]).
    pub fn build(family: Family, m: u32, w: &[u8], rows: usize, k: usize) -> LayerPlan {
        LayerPlan::build_pol(family, m, Polarity::Neg, w, rows, k, k)
    }

    /// Build the plan at one (family, m, polarity) point. `k_valid` is the
    /// population the CV averages divide by — `k` for a whole layer;
    /// paired partition plans pass the partition size, because their
    /// weight panels are zero off-partition and comp/low masks of zero are
    /// zero, so the sums are right but the averages must not be diluted.
    pub fn build_pol(
        family: Family,
        m: u32,
        pol: Polarity,
        w: &[u8],
        rows: usize,
        k: usize,
        k_valid: usize,
    ) -> LayerPlan {
        assert_eq!(w.len(), rows * k, "weight panel shape");
        let approx = family != Family::Exact && m > 0;
        let mask = if approx { ((1u32 << m) - 1) as u8 } else { 0 };
        let w_low = if approx && family == Family::Recursive {
            match pol {
                Polarity::Neg => w.iter().map(|&x| x & mask).collect(),
                Polarity::Pos => {
                    w.iter().map(|&x| comp_low(x as i32, m) as u8).collect()
                }
            }
        } else {
            Vec::new()
        };
        let w_planes = if approx && family == Family::Truncated {
            let mut planes = Vec::with_capacity(m as usize * rows * k);
            for i in 0..m {
                match pol {
                    Polarity::Neg => {
                        let wm = ((1u32 << (m - i)) - 1) as u8;
                        planes.extend(w.iter().map(|&x| x & wm));
                    }
                    Polarity::Pos => {
                        planes.extend(
                            w.iter().map(|&x| comp_low(x as i32, m - i) as u8),
                        );
                    }
                }
            }
            planes
        } else {
            Vec::new()
        };
        let sum_w: Vec<i64> =
            (0..rows).map(|f| w[f * k..(f + 1) * k].iter().map(|&x| x as i64).sum()).collect();
        let consts = cv::constants_pol_for_rows(family, pol, m, w, rows, k, k_valid);
        let checksum = plan_digest(&w_low, &w_planes, &sum_w, &consts);
        LayerPlan { family, m, pol, rows, k, w_low, w_planes, sum_w, consts, checksum }
    }

    /// Masked weights (recursive family) for rows `row0..row0+nrows`.
    pub fn w_low(&self, row0: usize, nrows: usize) -> &[u8] {
        &self.w_low[row0 * self.k..(row0 + nrows) * self.k]
    }

    /// Bit-plane panel `plane` (truncated family) for rows `row0..row0+nrows`.
    pub fn w_plane(&self, plane: usize, row0: usize, nrows: usize) -> &[u8] {
        let base = plane * self.rows * self.k;
        &self.w_planes[base + row0 * self.k..base + (row0 + nrows) * self.k]
    }

    /// Approximate heap footprint (diagnostics).
    pub fn bytes(&self) -> usize {
        self.w_low.len()
            + self.w_planes.len()
            + self.sum_w.len() * 8
            + self.consts.len() * std::mem::size_of::<CvConstants>()
    }

    /// Content digest stamped at construction.
    pub fn checksum(&self) -> u64 {
        self.checksum
    }

    /// Recompute the digest; `false` means some derived table no longer
    /// matches what was built from the weights (corruption).
    pub fn verify(&self) -> bool {
        plan_digest(&self.w_low, &self.w_planes, &self.sum_w, &self.consts) == self.checksum
    }

    /// Chaos helper: a copy with one bit flipped in the most load-bearing
    /// derived table (bit-plane panel > masked panel > Σw, whichever this
    /// plan actually carries), keeping the *original* checksum so
    /// [`LayerPlan::verify`] on the copy fails.
    pub fn with_flipped_bit(&self, byte: usize, bit: u32) -> LayerPlan {
        let mut w_low = self.w_low.clone();
        let mut w_planes = self.w_planes.clone();
        let mut sum_w = self.sum_w.clone();
        if !w_planes.is_empty() {
            let i = byte % w_planes.len();
            w_planes[i] ^= 1u8 << (bit % 8);
        } else if !w_low.is_empty() {
            let i = byte % w_low.len();
            w_low[i] ^= 1u8 << (bit % 8);
        } else if !sum_w.is_empty() {
            let i = byte % sum_w.len();
            sum_w[i] ^= 1i64 << (8 + bit % 24);
        }
        LayerPlan {
            family: self.family,
            m: self.m,
            pol: self.pol,
            rows: self.rows,
            k: self.k,
            w_low,
            w_planes,
            sum_w,
            consts: self.consts.clone(),
            checksum: self.checksum,
        }
    }

    /// Field-for-field copy (no `Clone` derive: plans are normally shared
    /// by `Arc`, copies exist only for the chaos helpers above).
    fn duplicate(&self) -> LayerPlan {
        LayerPlan {
            family: self.family,
            m: self.m,
            pol: self.pol,
            rows: self.rows,
            k: self.k,
            w_low: self.w_low.clone(),
            w_planes: self.w_planes.clone(),
            sum_w: self.sum_w.clone(),
            consts: self.consts.clone(),
            checksum: self.checksum,
        }
    }
}

/// Digest of every derived table a [`LayerPlan`] carries.
fn plan_digest(w_low: &[u8], w_planes: &[u8], sum_w: &[i64], consts: &[CvConstants]) -> u64 {
    let mut h = Hasher64::new();
    h.bytes(w_low);
    h.bytes(w_planes);
    h.i64s(sum_w);
    for c in consts {
        h.word(c.c_q4 as u64);
        h.word(c.c0_q4 as u64);
    }
    h.word(consts.len() as u64);
    h.finish()
}

/// Weight-side precomputation for one MAC layer running an even/odd
/// [`PairedPoint`]: parity-masked copies of the weight panel (the other
/// parity zeroed) plus one per-partition [`LayerPlan`] built from each —
/// masked/complement panels and CV constants included, with the averages
/// divided by the partition population. The full-row Σw stays at this
/// level for the shared zero-point epilogue.
pub struct PairedPlan {
    pub rows: usize,
    pub k: usize,
    /// Per-row Σw over the **full** panel (zero-point epilogue).
    pub sum_w: Vec<i64>,
    /// Weight panel with odd-parity columns zeroed.
    pub w_even: Vec<u8>,
    /// Weight panel with even-parity columns zeroed.
    pub w_odd: Vec<u8>,
    /// Partition plan for even reduction indices (its `family`/`m`/`pol`
    /// are the even half's point; `use_cv` stays with the assignment).
    pub even: LayerPlan,
    /// Partition plan for odd reduction indices.
    pub odd: LayerPlan,
    /// Build-time digest of the parity-masked panels + full-row Σw (the
    /// sub-plans carry their own digests).
    checksum: u64,
}

impl PairedPlan {
    /// Build the paired plan for a full layer weight panel `w` ([rows × k]).
    pub fn build(pair: PairedPoint, w: &[u8], rows: usize, k: usize) -> PairedPlan {
        assert_eq!(w.len(), rows * k, "weight panel shape");
        let (even_pt, odd_pt) = (pair.even.normalized(), pair.odd.normalized());
        let mut w_even = w.to_vec();
        let mut w_odd = w.to_vec();
        for (i, (we, wo)) in w_even.iter_mut().zip(w_odd.iter_mut()).enumerate() {
            if (i % k) % 2 == 0 {
                *wo = 0;
            } else {
                *we = 0;
            }
        }
        let (k_even, k_odd) = (k.div_ceil(2), k / 2);
        let even = LayerPlan::build_pol(
            even_pt.family, even_pt.m, even_pt.polarity, &w_even, rows, k, k_even,
        );
        let odd = LayerPlan::build_pol(
            odd_pt.family, odd_pt.m, odd_pt.polarity, &w_odd, rows, k, k_odd,
        );
        // The masked panels partition the full panel, so the full-row Σw is
        // the sum of the partition sums the sub-plans already computed.
        let sum_w: Vec<i64> =
            even.sum_w.iter().zip(&odd.sum_w).map(|(a, b)| a + b).collect();
        let checksum = paired_digest(&w_even, &w_odd, &sum_w);
        PairedPlan { rows, k, sum_w, w_even, w_odd, even, odd, checksum }
    }

    /// Approximate heap footprint (diagnostics).
    pub fn bytes(&self) -> usize {
        self.w_even.len()
            + self.w_odd.len()
            + self.sum_w.len() * 8
            + self.even.bytes()
            + self.odd.bytes()
    }

    /// Recompute all three digests (top-level panels plus both partition
    /// plans); `false` means corruption somewhere in the paired state.
    pub fn verify(&self) -> bool {
        paired_digest(&self.w_even, &self.w_odd, &self.sum_w) == self.checksum
            && self.even.verify()
            && self.odd.verify()
    }

    /// Chaos helper: a copy with one bit flipped in the even parity panel,
    /// keeping the original checksum (see [`LayerPlan::with_flipped_bit`]).
    pub fn with_flipped_bit(&self, byte: usize, bit: u32) -> PairedPlan {
        let mut w_even = self.w_even.clone();
        if !w_even.is_empty() {
            let i = byte % w_even.len();
            w_even[i] ^= 1u8 << (bit % 8);
        }
        PairedPlan {
            rows: self.rows,
            k: self.k,
            sum_w: self.sum_w.clone(),
            w_even,
            w_odd: self.w_odd.clone(),
            even: self.even.duplicate(),
            odd: self.odd.duplicate(),
            checksum: self.checksum,
        }
    }
}

/// Digest of a [`PairedPlan`]'s own tables (sub-plans hash themselves).
fn paired_digest(w_even: &[u8], w_odd: &[u8], sum_w: &[i64]) -> u64 {
    let mut h = Hasher64::new();
    h.bytes(w_even);
    h.bytes(w_odd);
    h.i64s(sum_w);
    h.finish()
}

/// Cache key: the plan-relevant part of a layer assignment — `(family, m,
/// polarity)` per constituent point. `use_cv` is *not* part of the key:
/// plans carry the CV constants unconditionally and the epilogue decides
/// whether to apply them.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PlanKey {
    Point(Family, u32, Polarity),
    Paired((Family, u32, Polarity), (Family, u32, Polarity)),
}

impl PlanKey {
    pub fn point(p: LayerPoint) -> PlanKey {
        let p = p.normalized();
        PlanKey::Point(p.family, p.m, p.polarity)
    }

    pub fn paired(pp: PairedPoint) -> PlanKey {
        let (e, o) = (pp.even.normalized(), pp.odd.normalized());
        PlanKey::Paired((e.family, e.m, e.polarity), (o.family, o.m, o.polarity))
    }
}

enum CachedPlan {
    Point(Arc<LayerPlan>),
    Paired(Arc<PairedPlan>),
}

/// Engine-wide plan store, keyed by (node index, [`PlanKey`]).
///
/// Interior-mutable so `Engine::forward(&self)` can populate it lazily; the
/// lock is held during builds, which keeps the build counter exact even when
/// sweep harnesses drive one engine from many threads.
///
/// The cache doubles as the plan-side integrity domain: `verify_all` sweeps
/// every cached digest, `invalidate` heals by dropping poisoned entries
/// (the next `get_or_build*` rebuilds from the model's pristine weights),
/// and `generation` counts runtime mutations so a worker can tell whether
/// any cached table changed under a forward it just ran. Ordinary inserts
/// do **not** bump the generation — only corruption and healing do.
#[derive(Default)]
pub struct PlanCache {
    map: Mutex<HashMap<(usize, PlanKey), CachedPlan>>,
    builds: AtomicUsize,
    generation: AtomicU64,
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache::default()
    }

    /// Fetch the negative-polarity plan for `(node, family, m)`, building
    /// it on first use.
    pub fn get_or_build<F: FnOnce() -> LayerPlan>(
        &self,
        node: usize,
        family: Family,
        m: u32,
        build: F,
    ) -> Arc<LayerPlan> {
        self.get_or_build_pol(node, family, m, Polarity::Neg, build)
    }

    /// Fetch the plan for `(node, family, m, polarity)`, building it on
    /// first use.
    pub fn get_or_build_pol<F: FnOnce() -> LayerPlan>(
        &self,
        node: usize,
        family: Family,
        m: u32,
        pol: Polarity,
        build: F,
    ) -> Arc<LayerPlan> {
        let key = (node, PlanKey::Point(family, m, pol));
        let mut map = lock_clean(&self.map);
        if let Some(CachedPlan::Point(p)) = map.get(&key) {
            return p.clone();
        }
        let plan = Arc::new(build());
        self.builds.fetch_add(1, Ordering::Relaxed);
        map.insert(key, CachedPlan::Point(plan.clone()));
        plan
    }

    /// Fetch the paired plan for `(node, pairing)`, building it on first
    /// use.
    pub fn get_or_build_paired<F: FnOnce() -> PairedPlan>(
        &self,
        node: usize,
        pair: PairedPoint,
        build: F,
    ) -> Arc<PairedPlan> {
        let key = (node, PlanKey::paired(pair));
        let mut map = lock_clean(&self.map);
        if let Some(CachedPlan::Paired(p)) = map.get(&key) {
            return p.clone();
        }
        let plan = Arc::new(build());
        self.builds.fetch_add(1, Ordering::Relaxed);
        map.insert(key, CachedPlan::Paired(plan.clone()));
        plan
    }

    /// How many plans have been built since engine creation (tests assert
    /// this stays flat across repeated forwards).
    pub fn builds(&self) -> usize {
        self.builds.load(Ordering::Relaxed)
    }

    /// Number of cached plans.
    pub fn cached(&self) -> usize {
        lock_clean(&self.map).len()
    }

    /// Monotone count of runtime mutations (corruptions + invalidations).
    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::SeqCst)
    }

    /// Recompute every cached digest; returns the keys whose contents no
    /// longer match their build-time checksum.
    pub fn verify_all(&self) -> Vec<(usize, PlanKey)> {
        let map = lock_clean(&self.map);
        let mut bad: Vec<(usize, PlanKey)> = map
            .iter()
            .filter(|(_, v)| match v {
                CachedPlan::Point(p) => !p.verify(),
                CachedPlan::Paired(p) => !p.verify(),
            })
            .map(|(k, _)| *k)
            .collect();
        bad.sort_by_key(|k| (k.0, format!("{:?}", k.1)));
        bad
    }

    /// Heal by dropping the listed entries: the next `get_or_build*`
    /// rebuilds them from the model's pristine weights. Returns how many
    /// entries were actually removed; bumps the generation when > 0.
    pub fn invalidate(&self, keys: &[(usize, PlanKey)]) -> usize {
        let mut map = lock_clean(&self.map);
        let mut n = 0;
        for k in keys {
            if map.remove(k).is_some() {
                n += 1;
            }
        }
        if n > 0 {
            self.generation.fetch_add(1, Ordering::SeqCst);
        }
        n
    }

    /// Chaos helper: replace one cached entry (picked deterministically by
    /// `pick` over a sorted key list) with a bit-flipped copy that keeps its
    /// build-time checksum. Returns the poisoned key, or `None` when the
    /// cache is empty. Bumps the generation.
    pub fn corrupt_one(&self, pick: u64, byte: usize, bit: u32) -> Option<(usize, PlanKey)> {
        let mut map = lock_clean(&self.map);
        if map.is_empty() {
            return None;
        }
        let mut keys: Vec<(usize, PlanKey)> = map.keys().copied().collect();
        keys.sort_by_key(|k| (k.0, format!("{:?}", k.1)));
        let key = keys[(pick % keys.len() as u64) as usize];
        let poisoned = match map.get(&key).expect("key just listed") {
            CachedPlan::Point(p) => CachedPlan::Point(Arc::new(p.with_flipped_bit(byte, bit))),
            CachedPlan::Paired(p) => {
                CachedPlan::Paired(Arc::new(p.with_flipped_bit(byte, bit)))
            }
        };
        map.insert(key, poisoned);
        self.generation.fetch_add(1, Ordering::SeqCst);
        Some(key)
    }
}

/// Zero out and size a buffer without shrinking its capacity.
#[inline]
pub(crate) fn reset<T: Copy + Default>(v: &mut Vec<T>, len: usize) {
    v.clear();
    v.resize(len, T::default());
}

/// Reusable per-worker buffers for the forward pass. All fields grow to the
/// largest layer once and are then reused allocation-free; one `Scratch` per
/// thread (each coordinator pool worker keeps a single long-lived instance,
/// sized for its batch via [`Scratch::reserve`] — batched forwards widen
/// every activation-side buffer by the batch factor, so reserve with
/// `panel·batch` / `acc·batch` from `Model::max_gemm_footprint`).
#[derive(Default)]
pub struct Scratch {
    /// im2col staging buffer [kdim × n_cols] — `n_cols` spans the whole
    /// batch (`batch·oh·ow`) on the batched path (engine layer).
    pub a_cols: Vec<u8>,
    /// Widened activation panel (u8 → i32) for the vectorized core.
    pub(crate) a_wide: Vec<i32>,
    /// Masked / bit-plane activation panel.
    pub(crate) a_mask: Vec<i32>,
    /// Per-bit-plane partial output (truncated family).
    pub(crate) term: Vec<i32>,
    /// i32 accumulator of the identity expansion.
    pub(crate) acc32: Vec<i32>,
    /// Σa per output column (zero-point epilogue).
    pub(crate) sum_a: Vec<i64>,
    /// Σx per output column (control variate).
    pub(crate) sum_x: Vec<i64>,
    /// Second Σx per output column — the odd partition of a paired layer
    /// (each half of a pairing regresses on its own x over its own columns).
    pub(crate) sum_x2: Vec<i64>,
    /// Final i64 accumulator [m_rows × n] — the GEMM output the engine
    /// requantizes from.
    pub acc: Vec<i64>,
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch::default()
    }

    /// Pre-grow the arena to a model's worst-case GEMM footprint
    /// (`panel` = max k·n_cols activation panel, `acc` = max rows·n_cols
    /// accumulator — see `Model::max_gemm_footprint`), so even the first
    /// forward allocates nothing on the GEMM path.
    pub fn reserve(&mut self, panel: usize, acc: usize) {
        self.a_cols.reserve(panel);
        self.a_wide.reserve(panel);
        self.a_mask.reserve(panel);
        self.term.reserve(acc);
        self.acc32.reserve(acc);
        self.acc.reserve(acc);
        self.sum_a.reserve(acc);
        self.sum_x.reserve(acc);
        self.sum_x2.reserve(acc);
    }

    /// Total capacity currently held (diagnostics).
    pub fn bytes(&self) -> usize {
        self.a_cols.capacity()
            + 4 * (self.a_wide.capacity()
                + self.a_mask.capacity()
                + self.term.capacity()
                + self.acc32.capacity())
            + 8 * (self.sum_a.capacity()
                + self.sum_x.capacity()
                + self.sum_x2.capacity()
                + self.acc.capacity())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn plan_masks_match_definitions() {
        let mut rng = Rng::new(0x9A);
        let (rows, k) = (6, 20);
        let w: Vec<u8> = (0..rows * k).map(|_| rng.u8()).collect();

        let rec = LayerPlan::build(Family::Recursive, 3, &w, rows, k);
        for (i, &x) in w.iter().enumerate() {
            assert_eq!(rec.w_low(0, rows)[i], x & 0b111);
        }
        assert!(rec.w_planes.is_empty());

        let m = 4u32;
        let tr = LayerPlan::build(Family::Truncated, m, &w, rows, k);
        assert!(tr.w_low.is_empty());
        for plane in 0..m as usize {
            let wm = ((1u32 << (m as usize - plane)) - 1) as u8;
            let p = tr.w_plane(plane, 0, rows);
            for (i, &x) in w.iter().enumerate() {
                assert_eq!(p[i], x & wm, "plane {plane} idx {i}");
            }
        }

        let perf = LayerPlan::build(Family::Perforated, 2, &w, rows, k);
        assert!(perf.w_low.is_empty() && perf.w_planes.is_empty());
    }

    #[test]
    fn plan_sums_and_consts_match_direct() {
        let mut rng = Rng::new(0x9B);
        let (rows, k) = (4, 33);
        let w: Vec<u8> = (0..rows * k).map(|_| rng.u8()).collect();
        let plan = LayerPlan::build(Family::Perforated, 2, &w, rows, k);
        for f in 0..rows {
            let want: i64 = w[f * k..(f + 1) * k].iter().map(|&x| x as i64).sum();
            assert_eq!(plan.sum_w[f], want);
            assert_eq!(
                plan.consts[f],
                crate::cv::constants(Family::Perforated, 2, &w[f * k..(f + 1) * k], k)
            );
        }
    }

    #[test]
    fn row_slicing_addresses_group_panels() {
        let mut rng = Rng::new(0x9C);
        let (rows, k) = (8, 5);
        let w: Vec<u8> = (0..rows * k).map(|_| rng.u8()).collect();
        let plan = LayerPlan::build(Family::Recursive, 2, &w, rows, k);
        // group 1 of 2: rows 4..8
        let g = plan.w_low(4, 4);
        for i in 0..4 * k {
            assert_eq!(g[i], w[4 * k + i] & 0b11);
        }
    }

    #[test]
    fn pos_plan_masks_are_modular_complements() {
        let mut rng = Rng::new(0x9D);
        let (rows, k) = (5, 14);
        let w: Vec<u8> = (0..rows * k).map(|_| rng.u8()).collect();

        let rec = LayerPlan::build_pol(
            Family::Recursive, 3, Polarity::Pos, &w, rows, k, k,
        );
        assert_eq!(rec.pol, Polarity::Pos);
        for (i, &x) in w.iter().enumerate() {
            assert_eq!(rec.w_low(0, rows)[i], comp_low(x as i32, 3) as u8);
        }

        let m = 4u32;
        let tr = LayerPlan::build_pol(
            Family::Truncated, m, Polarity::Pos, &w, rows, k, k,
        );
        for plane in 0..m as usize {
            let p = tr.w_plane(plane, 0, rows);
            for (i, &x) in w.iter().enumerate() {
                assert_eq!(
                    p[i],
                    comp_low(x as i32, m - plane as u32) as u8,
                    "plane {plane} idx {i}"
                );
            }
        }
        // Neg delegation: build() == build_pol(Neg).
        let a = LayerPlan::build(Family::Recursive, 3, &w, rows, k);
        let b = LayerPlan::build_pol(Family::Recursive, 3, Polarity::Neg, &w, rows, k, k);
        assert_eq!(a.w_low(0, rows), b.w_low(0, rows));
        assert_eq!(a.consts, b.consts);
    }

    #[test]
    fn paired_plan_partitions_by_parity() {
        use crate::nn::policy::{LayerPoint, PairedPoint};
        let mut rng = Rng::new(0x9E);
        let (rows, k) = (4, 11); // odd k: even partition is one larger
        let w: Vec<u8> = (0..rows * k).map(|_| rng.u8().max(1)).collect();
        let pair = PairedPoint::mirrored(Family::Perforated, 2, true);
        let pp = PairedPlan::build(pair, &w, rows, k);
        assert_eq!((pp.rows, pp.k), (rows, k));
        for f in 0..rows {
            for kk in 0..k {
                let i = f * k + kk;
                if kk % 2 == 0 {
                    assert_eq!(pp.w_even[i], w[i]);
                    assert_eq!(pp.w_odd[i], 0);
                } else {
                    assert_eq!(pp.w_even[i], 0);
                    assert_eq!(pp.w_odd[i], w[i]);
                }
            }
            // full-row Σw regardless of the split
            let want: i64 = w[f * k..(f + 1) * k].iter().map(|&x| x as i64).sum();
            assert_eq!(pp.sum_w[f], want);
        }
        assert_eq!(pp.even.pol, Polarity::Neg);
        assert_eq!(pp.odd.pol, Polarity::Pos);
        // Partition CV constants average over the partition population:
        // even row 0 has ceil(11/2) = 6 live weights.
        let even_row: Vec<u8> =
            (0..k).map(|kk| if kk % 2 == 0 { w[kk] } else { 0 }).collect();
        let want_c = crate::cv::constants_pol(
            Family::Perforated, Polarity::Neg, 2, &even_row, k.div_ceil(2),
        );
        assert_eq!(pp.even.consts[0], want_c);
        assert!(pp.bytes() > 0);
    }

    #[test]
    fn cache_distinguishes_polarity_and_pairing() {
        use crate::nn::policy::PairedPoint;
        let cache = PlanCache::new();
        let w = vec![7u8; 12];
        let neg = cache.get_or_build_pol(0, Family::Perforated, 2, Polarity::Neg, || {
            LayerPlan::build_pol(Family::Perforated, 2, Polarity::Neg, &w, 3, 4, 4)
        });
        let pos = cache.get_or_build_pol(0, Family::Perforated, 2, Polarity::Pos, || {
            LayerPlan::build_pol(Family::Perforated, 2, Polarity::Pos, &w, 3, 4, 4)
        });
        assert_eq!(cache.builds(), 2, "polarities are distinct keys");
        assert_eq!(neg.pol, Polarity::Neg);
        assert_eq!(pos.pol, Polarity::Pos);
        let pair = PairedPoint::mirrored(Family::Perforated, 2, true);
        for _ in 0..3 {
            let pp = cache
                .get_or_build_paired(0, pair, || PairedPlan::build(pair, &w, 3, 4));
            assert_eq!(pp.rows, 3);
        }
        assert_eq!(cache.builds(), 3, "paired plan built once");
        assert_eq!(cache.cached(), 3);
        // use_cv is NOT part of the key: the nocv twin hits the same entry.
        let mut nocv = pair;
        nocv.even.use_cv = false;
        nocv.odd.use_cv = false;
        cache.get_or_build_paired(0, nocv, || PairedPlan::build(nocv, &w, 3, 4));
        assert_eq!(cache.builds(), 3, "cv-stripped key must hit the cache");
    }

    #[test]
    fn cache_builds_once_per_key() {
        let cache = PlanCache::new();
        let w = vec![7u8; 12];
        for _ in 0..3 {
            let p = cache.get_or_build(0, Family::Perforated, 2, || {
                LayerPlan::build(Family::Perforated, 2, &w, 3, 4)
            });
            assert_eq!(p.rows, 3);
        }
        assert_eq!(cache.builds(), 1);
        assert_eq!(cache.cached(), 1);
        cache.get_or_build(0, Family::Perforated, 3, || {
            LayerPlan::build(Family::Perforated, 3, &w, 3, 4)
        });
        assert_eq!(cache.builds(), 2);
        assert_eq!(cache.cached(), 2);
    }

    #[test]
    fn plan_checksums_cover_every_family_shape() {
        let mut rng = Rng::new(0xF1);
        let (rows, k) = (4, 16);
        let w: Vec<u8> = (0..rows * k).map(|_| rng.u8()).collect();
        for family in [Family::Perforated, Family::Recursive, Family::Truncated] {
            let plan = LayerPlan::build(family, 3, &w, rows, k);
            assert!(plan.verify(), "{family:?} fresh plan verifies");
            let bad = plan.with_flipped_bit(7, 3);
            assert!(!bad.verify(), "{family:?} flipped plan must fail");
            assert_eq!(bad.checksum(), plan.checksum());
        }
        // Deterministic: same weights => same digest.
        let a = LayerPlan::build(Family::Recursive, 2, &w, rows, k);
        let b = LayerPlan::build(Family::Recursive, 2, &w, rows, k);
        assert_eq!(a.checksum(), b.checksum());
    }

    #[test]
    fn paired_plan_checksum_covers_partitions() {
        use crate::nn::policy::PairedPoint;
        let mut rng = Rng::new(0xF2);
        let (rows, k) = (3, 10);
        let w: Vec<u8> = (0..rows * k).map(|_| rng.u8()).collect();
        let pair = PairedPoint::mirrored(Family::Recursive, 2, true);
        let pp = PairedPlan::build(pair, &w, rows, k);
        assert!(pp.verify());
        let bad = pp.with_flipped_bit(5, 6);
        assert!(!bad.verify(), "flipped even panel must fail verification");
    }

    #[test]
    fn cache_corruption_heals_by_invalidation() {
        let cache = PlanCache::new();
        let w = vec![9u8; 24];
        cache.get_or_build(0, Family::Recursive, 2, || {
            LayerPlan::build(Family::Recursive, 2, &w, 4, 6)
        });
        cache.get_or_build(1, Family::Perforated, 3, || {
            LayerPlan::build(Family::Perforated, 3, &w, 4, 6)
        });
        assert_eq!(cache.generation(), 0, "warming does not bump the generation");
        assert!(cache.verify_all().is_empty());

        let hit = cache.corrupt_one(0, 3, 2).expect("cache nonempty");
        assert_eq!(cache.generation(), 1);
        let dirty = cache.verify_all();
        assert_eq!(dirty, vec![hit], "exactly the poisoned key is dirty");

        let healed = cache.invalidate(&dirty);
        assert_eq!(healed, 1);
        assert_eq!(cache.generation(), 2);
        assert!(cache.verify_all().is_empty(), "dropped entries cannot be dirty");
        assert_eq!(cache.cached(), 1, "the poisoned entry is gone");
        // Rebuild on next fetch is a fresh, verifying plan.
        let again = cache.get_or_build(hit.0, Family::Recursive, 2, || {
            LayerPlan::build(Family::Recursive, 2, &w, 4, 6)
        });
        assert!(again.verify());
        assert_eq!(cache.builds(), 3, "heal costs exactly one rebuild");
    }

    #[test]
    fn scratch_reset_grows_and_zeroes() {
        let mut s = Scratch::new();
        reset(&mut s.acc32, 16);
        s.acc32.iter_mut().for_each(|x| *x = 7);
        reset(&mut s.acc32, 8);
        assert_eq!(s.acc32, vec![0; 8]);
        reset(&mut s.acc32, 32);
        assert!(s.acc32.iter().all(|&x| x == 0));
        assert!(s.bytes() > 0);
    }

    #[test]
    fn reserve_pregrows_without_resizing() {
        let mut s = Scratch::new();
        s.reserve(1000, 400);
        assert!(s.a_wide.capacity() >= 1000);
        assert!(s.acc.capacity() >= 400);
        assert!(s.a_wide.is_empty(), "reserve must not change lengths");
    }
}
