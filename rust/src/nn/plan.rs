//! Precomputed layer plans + reusable scratch for the GEMM hot path.
//!
//! Everything in [`LayerPlan`] is a pure function of the **static weights**
//! of one MAC layer and the (family, m) design point:
//!
//! * the masked weight panels the identity expansion needs (recursive:
//!   `w & (2^m−1)`; truncated: one panel per bit plane),
//! * per-filter `Σw` for the zero-point epilogue,
//! * per-filter control-variate constants C/C₀ (Q.4).
//!
//! The seed recomputed all of these inside `approx_gemm` on **every
//! image**; with plans they are built at most once per (layer, family, m)
//! and shared across the whole batch stream ([`PlanCache`]). [`Scratch`]
//! complements the plans on the activation side: it owns every
//! per-image buffer (im2col staging, widened/masked panels, bit planes,
//! `Σa`/`Σx`, accumulators), so a steady-state `Engine::forward` performs
//! no weight-side recomputation and no per-GEMM heap allocation once the
//! buffers have grown to the largest layer.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::approx::Family;
use crate::cv::{self, CvConstants};

/// Weight-side precomputation for one MAC layer at one (family, m) point.
pub struct LayerPlan {
    pub family: Family,
    pub m: u32,
    /// Total filter rows in the layer (across all conv groups).
    pub rows: usize,
    /// Reduction length per filter row.
    pub k: usize,
    /// Recursive family: `w & (2^m − 1)`, same layout as `w` (else empty).
    w_low: Vec<u8>,
    /// Truncated family: `m` bit-plane panels, plane `i` (at offset
    /// `i * rows * k`) holds `w & (2^(m−i) − 1)` (else empty).
    w_planes: Vec<u8>,
    /// Per-row Σw for the zero-point epilogue.
    pub sum_w: Vec<i64>,
    /// Per-row control-variate constants (zeroes for the exact family).
    pub consts: Vec<CvConstants>,
}

impl LayerPlan {
    /// Build the plan for a full layer weight panel `w` ([rows × k]).
    pub fn build(family: Family, m: u32, w: &[u8], rows: usize, k: usize) -> LayerPlan {
        assert_eq!(w.len(), rows * k, "weight panel shape");
        let approx = family != Family::Exact && m > 0;
        let mask = if approx { ((1u32 << m) - 1) as u8 } else { 0 };
        let w_low = if approx && family == Family::Recursive {
            w.iter().map(|&x| x & mask).collect()
        } else {
            Vec::new()
        };
        let w_planes = if approx && family == Family::Truncated {
            let mut planes = Vec::with_capacity(m as usize * rows * k);
            for i in 0..m {
                let wm = ((1u32 << (m - i)) - 1) as u8;
                planes.extend(w.iter().map(|&x| x & wm));
            }
            planes
        } else {
            Vec::new()
        };
        let sum_w =
            (0..rows).map(|f| w[f * k..(f + 1) * k].iter().map(|&x| x as i64).sum()).collect();
        let consts = cv::constants_for_rows(family, m, w, rows, k);
        LayerPlan { family, m, rows, k, w_low, w_planes, sum_w, consts }
    }

    /// Masked weights (recursive family) for rows `row0..row0+nrows`.
    pub fn w_low(&self, row0: usize, nrows: usize) -> &[u8] {
        &self.w_low[row0 * self.k..(row0 + nrows) * self.k]
    }

    /// Bit-plane panel `plane` (truncated family) for rows `row0..row0+nrows`.
    pub fn w_plane(&self, plane: usize, row0: usize, nrows: usize) -> &[u8] {
        let base = plane * self.rows * self.k;
        &self.w_planes[base + row0 * self.k..base + (row0 + nrows) * self.k]
    }

    /// Approximate heap footprint (diagnostics).
    pub fn bytes(&self) -> usize {
        self.w_low.len()
            + self.w_planes.len()
            + self.sum_w.len() * 8
            + self.consts.len() * std::mem::size_of::<CvConstants>()
    }
}

/// Engine-wide plan store, keyed by (node index, family, m).
///
/// Interior-mutable so `Engine::forward(&self)` can populate it lazily; the
/// lock is held during builds, which keeps the build counter exact even when
/// sweep harnesses drive one engine from many threads.
#[derive(Default)]
pub struct PlanCache {
    map: Mutex<HashMap<(usize, Family, u32), Arc<LayerPlan>>>,
    builds: AtomicUsize,
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache::default()
    }

    /// Fetch the plan for `(node, family, m)`, building it on first use.
    pub fn get_or_build<F: FnOnce() -> LayerPlan>(
        &self,
        node: usize,
        family: Family,
        m: u32,
        build: F,
    ) -> Arc<LayerPlan> {
        let mut map = self.map.lock().unwrap();
        if let Some(p) = map.get(&(node, family, m)) {
            return p.clone();
        }
        let plan = Arc::new(build());
        self.builds.fetch_add(1, Ordering::Relaxed);
        map.insert((node, family, m), plan.clone());
        plan
    }

    /// How many plans have been built since engine creation (tests assert
    /// this stays flat across repeated forwards).
    pub fn builds(&self) -> usize {
        self.builds.load(Ordering::Relaxed)
    }

    /// Number of cached plans.
    pub fn cached(&self) -> usize {
        self.map.lock().unwrap().len()
    }
}

/// Zero out and size a buffer without shrinking its capacity.
#[inline]
pub(crate) fn reset<T: Copy + Default>(v: &mut Vec<T>, len: usize) {
    v.clear();
    v.resize(len, T::default());
}

/// Reusable per-worker buffers for the forward pass. All fields grow to the
/// largest layer once and are then reused allocation-free; one `Scratch` per
/// thread (each coordinator pool worker keeps a single long-lived instance,
/// sized for its batch via [`Scratch::reserve`] — batched forwards widen
/// every activation-side buffer by the batch factor, so reserve with
/// `panel·batch` / `acc·batch` from `Model::max_gemm_footprint`).
#[derive(Default)]
pub struct Scratch {
    /// im2col staging buffer [kdim × n_cols] — `n_cols` spans the whole
    /// batch (`batch·oh·ow`) on the batched path (engine layer).
    pub a_cols: Vec<u8>,
    /// Widened activation panel (u8 → i32) for the vectorized core.
    pub(crate) a_wide: Vec<i32>,
    /// Masked / bit-plane activation panel.
    pub(crate) a_mask: Vec<i32>,
    /// Per-bit-plane partial output (truncated family).
    pub(crate) term: Vec<i32>,
    /// i32 accumulator of the identity expansion.
    pub(crate) acc32: Vec<i32>,
    /// Σa per output column (zero-point epilogue).
    pub(crate) sum_a: Vec<i64>,
    /// Σx per output column (control variate).
    pub(crate) sum_x: Vec<i64>,
    /// Final i64 accumulator [m_rows × n] — the GEMM output the engine
    /// requantizes from.
    pub acc: Vec<i64>,
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch::default()
    }

    /// Pre-grow the arena to a model's worst-case GEMM footprint
    /// (`panel` = max k·n_cols activation panel, `acc` = max rows·n_cols
    /// accumulator — see `Model::max_gemm_footprint`), so even the first
    /// forward allocates nothing on the GEMM path.
    pub fn reserve(&mut self, panel: usize, acc: usize) {
        self.a_cols.reserve(panel);
        self.a_wide.reserve(panel);
        self.a_mask.reserve(panel);
        self.term.reserve(acc);
        self.acc32.reserve(acc);
        self.acc.reserve(acc);
        self.sum_a.reserve(acc);
        self.sum_x.reserve(acc);
    }

    /// Total capacity currently held (diagnostics).
    pub fn bytes(&self) -> usize {
        self.a_cols.capacity()
            + 4 * (self.a_wide.capacity()
                + self.a_mask.capacity()
                + self.term.capacity()
                + self.acc32.capacity())
            + 8 * (self.sum_a.capacity() + self.sum_x.capacity() + self.acc.capacity())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn plan_masks_match_definitions() {
        let mut rng = Rng::new(0x9A);
        let (rows, k) = (6, 20);
        let w: Vec<u8> = (0..rows * k).map(|_| rng.u8()).collect();

        let rec = LayerPlan::build(Family::Recursive, 3, &w, rows, k);
        for (i, &x) in w.iter().enumerate() {
            assert_eq!(rec.w_low(0, rows)[i], x & 0b111);
        }
        assert!(rec.w_planes.is_empty());

        let m = 4u32;
        let tr = LayerPlan::build(Family::Truncated, m, &w, rows, k);
        assert!(tr.w_low.is_empty());
        for plane in 0..m as usize {
            let wm = ((1u32 << (m as usize - plane)) - 1) as u8;
            let p = tr.w_plane(plane, 0, rows);
            for (i, &x) in w.iter().enumerate() {
                assert_eq!(p[i], x & wm, "plane {plane} idx {i}");
            }
        }

        let perf = LayerPlan::build(Family::Perforated, 2, &w, rows, k);
        assert!(perf.w_low.is_empty() && perf.w_planes.is_empty());
    }

    #[test]
    fn plan_sums_and_consts_match_direct() {
        let mut rng = Rng::new(0x9B);
        let (rows, k) = (4, 33);
        let w: Vec<u8> = (0..rows * k).map(|_| rng.u8()).collect();
        let plan = LayerPlan::build(Family::Perforated, 2, &w, rows, k);
        for f in 0..rows {
            let want: i64 = w[f * k..(f + 1) * k].iter().map(|&x| x as i64).sum();
            assert_eq!(plan.sum_w[f], want);
            assert_eq!(
                plan.consts[f],
                crate::cv::constants(Family::Perforated, 2, &w[f * k..(f + 1) * k], k)
            );
        }
    }

    #[test]
    fn row_slicing_addresses_group_panels() {
        let mut rng = Rng::new(0x9C);
        let (rows, k) = (8, 5);
        let w: Vec<u8> = (0..rows * k).map(|_| rng.u8()).collect();
        let plan = LayerPlan::build(Family::Recursive, 2, &w, rows, k);
        // group 1 of 2: rows 4..8
        let g = plan.w_low(4, 4);
        for i in 0..4 * k {
            assert_eq!(g[i], w[4 * k + i] & 0b11);
        }
    }

    #[test]
    fn cache_builds_once_per_key() {
        let cache = PlanCache::new();
        let w = vec![7u8; 12];
        for _ in 0..3 {
            let p = cache.get_or_build(0, Family::Perforated, 2, || {
                LayerPlan::build(Family::Perforated, 2, &w, 3, 4)
            });
            assert_eq!(p.rows, 3);
        }
        assert_eq!(cache.builds(), 1);
        assert_eq!(cache.cached(), 1);
        cache.get_or_build(0, Family::Perforated, 3, || {
            LayerPlan::build(Family::Perforated, 3, &w, 3, 4)
        });
        assert_eq!(cache.builds(), 2);
        assert_eq!(cache.cached(), 2);
    }

    #[test]
    fn scratch_reset_grows_and_zeroes() {
        let mut s = Scratch::new();
        reset(&mut s.acc32, 16);
        s.acc32.iter_mut().for_each(|x| *x = 7);
        reset(&mut s.acc32, 8);
        assert_eq!(s.acc32, vec![0; 8]);
        reset(&mut s.acc32, 32);
        assert!(s.acc32.iter().all(|&x| x == 0));
        assert!(s.bytes() > 0);
    }

    #[test]
    fn reserve_pregrows_without_resizing() {
        let mut s = Scratch::new();
        s.reserve(1000, 400);
        assert!(s.a_wide.capacity() >= 1000);
        assert!(s.acc.capacity() >= 400);
        assert!(s.a_wide.is_empty(), "reserve must not change lengths");
    }
}
