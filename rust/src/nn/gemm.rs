//! Approximate quantized GEMM engines.
//!
//! Computes, for one layer GEMM W[M,K] × A[K,N] (uint8 operands):
//!
//! ```text
//! acc[f,p] = CV( Σ_k AM(W[f,k], A[k,p]) )
//!          − zp_w·Σ_k A[k,p] − zp_a·Σ_k W[f,k] + K·zp_w·zp_a + bias[f]
//! ```
//!
//! Engines (all bit-identical; equivalence asserted by tests):
//! * **Identity** — fast path: the error identities turn each family into
//!   1..m extra exact GEMMs over masked operands (AM = W·A − ε); this is
//!   what the accuracy sweeps run, and what the Pallas kernel computes on
//!   the PJRT path.
//! * **Lut** — hardware-faithful path: every product is a 256×256 table
//!   lookup (TFApprox-style), exactly what the RTL multiplier emits.
//! * the systolic simulator ([`crate::systolic`]) is the third, cycle-level
//!   engine, wired in by the engine layer for power measurements.
//!
//! ## §Perf (EXPERIMENTS.md)
//!
//! The hot path is organized around three ideas, all preserving bit
//! exactness (integer adds over disjoint output rows are order-free):
//!
//! 1. **Layer plans** ([`LayerPlan`]): masked weight panels, per-row Σw and
//!    CV constants are functions of static weights — built once per
//!    (layer, family, m) and reused for every image, instead of being
//!    recomputed inside each GEMM call as the seed did.
//! 2. **Scratch reuse** ([`Scratch`]): widened/masked activation panels,
//!    bit planes, Σa/Σx and both accumulators live in a caller-owned arena,
//!    so steady-state forwards make no per-GEMM heap allocations.
//! 3. **Blocked multithreaded core**: `gemm_core_i32` tiles N (`NC`) and
//!    K (`KC`) for L1/L2 residency around the 4-row register blocking, and
//!    fans output-row blocks out over `CVAPPROX_THREADS` scoped threads
//!    (shared by the Identity, LUT and epilogue paths). Small GEMMs stay
//!    single-threaded (`PAR_THRESHOLD`) so spawn cost never dominates.
//! 4. **Kernel backends** ([`super::kernel`]): the inner compute — operand
//!    packing, masked transforms, the blocked i32 chunk, ΣA/ΣX column
//!    reductions — runs behind the [`Kernel`] trait. This module keeps the
//!    orchestration (plans, LUT dispatch, threading, the V epilogue); the
//!    bare `approx_gemm_planned` / `paired_gemm_planned` entry points run
//!    the process-wide [`kernel::active`] backend, and the `_with_kernel`
//!    variants pin one explicitly (differential tests, bench rows).

use crate::approx::{Family, MulLut, Polarity};
use crate::cv;
use crate::util::threadpool::configured_workers;

use super::kernel::{self, Kernel};
use super::plan::{reset, LayerPlan, PairedPlan, Scratch};
use super::policy::{LayerPoint, PairedPoint};

/// Which GEMM engine to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GemmKind {
    Identity,
    Lut,
}

/// Layer-level GEMM descriptor (quantization + CV context).
#[derive(Clone, Debug)]
pub struct GemmCtx {
    pub family: Family,
    pub m: u32,
    pub use_cv: bool,
    pub zp_w: i64,
    pub zp_a: i64,
}

/// Column-block width: `NC` i32 accumulator lanes per output row stay L1
/// resident while activation rows stream.
pub(crate) const NC: usize = 256;
/// Reduction-block depth: one `KC × NC` activation block (~128 KiB) stays L2
/// resident across all row quads of a thread's chunk.
pub(crate) const KC: usize = 128;
/// MAC count below which a GEMM runs single-threaded — scoped-thread spawn
/// costs ~10–20 µs each, which only amortizes on non-trivial layers.
const PAR_THRESHOLD: usize = 1 << 18;

/// i32-headroom ceiling on the reduction depth K of one planned GEMM:
/// |Σ_k w·a| ≤ K·255² must stay inside i32.
pub const MAX_K_NEG: usize = 33_000;
/// Tighter ceiling for positive-polarity approximate points: the exact
/// pass (≤ K·255²) plus the upward compensation (≤ K·255·127) share one
/// i32 accumulator.
pub const MAX_K_POS: usize = 20_000;

/// The K-headroom ceiling of one multiplier point. Enforced with a typed
/// error at plan/policy-validation time (`LayerPolicy::validate_for`,
/// `Engine::validate_opts`, `InferenceService::start`) so the asserts in
/// the core below stay unreachable backstops — never a mid-batch panic
/// inside a serving worker for a valid-but-large model.
pub fn max_k_for_point(pt: LayerPoint) -> usize {
    if pt.family == Family::Exact || pt.m == 0 {
        MAX_K_NEG
    } else {
        match pt.polarity {
            Polarity::Neg => MAX_K_NEG,
            Polarity::Pos => MAX_K_POS,
        }
    }
}

/// Split `out` (an [rows × n] row-major panel) into contiguous row blocks
/// (multiples of 4 rows, matching the register blocking) and run
/// `f(row0, chunk)` for each block on up to `threads` scoped threads.
///
/// With `threads == 1` (or fewer than `min_rows` rows) this degenerates to a
/// single inline call — the parallel and serial paths execute the *same*
/// per-row arithmetic, so results are bit-identical for every thread count.
fn par_row_blocks<T, F>(out: &mut [T], n: usize, threads: usize, min_rows: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    if n == 0 || out.is_empty() {
        return;
    }
    let m_rows = out.len() / n;
    let threads = threads.max(1).min((m_rows + 3) / 4);
    if threads == 1 || m_rows < min_rows {
        f(0, out);
        return;
    }
    let blocks = (m_rows + 3) / 4;
    let rows_per = ((blocks + threads - 1) / threads) * 4;
    std::thread::scope(|s| {
        let fr = &f;
        let mut rest: &mut [T] = out;
        let mut row0 = 0usize;
        while row0 < m_rows {
            let take = rows_per.min(m_rows - row0);
            let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(take * n);
            rest = tail;
            s.spawn(move || fr(row0, chunk));
            row0 += take;
        }
    });
}

/// Exact u8×u8 GEMM core with **i32 accumulation** (`sign` = ±1 folds the
/// error-term subtraction into the same kernel), blocked + multithreaded.
/// The per-chunk compute is the backend's [`Kernel::gemm_chunk`]; this
/// shell owns the row-block fan-out, which is backend-independent.
///
/// Overflow safety: |Σ_k w·a| ≤ K·255² < 2^31 for K ≤ [`MAX_K_NEG`].
/// Oversized layers are rejected with a typed error at plan/policy
/// validation time (see [`max_k_for_point`]); the assert here is the
/// unreachable backstop.
#[allow(clippy::too_many_arguments)]
fn gemm_core_i32(
    kr: &dyn Kernel,
    w: &[u8],
    a_i32: &[i32],
    m_rows: usize,
    k: usize,
    n: usize,
    sign: i32,
    out: &mut [i32],
    threads: usize,
) {
    debug_assert_eq!(w.len(), m_rows * k);
    debug_assert_eq!(a_i32.len(), k * n);
    debug_assert_eq!(out.len(), m_rows * n);
    assert!(k <= MAX_K_NEG, "K too large for i32 accumulation — tile it");
    let threads = if m_rows * k * n < PAR_THRESHOLD { 1 } else { threads };
    par_row_blocks(out, n, threads, 8, |row0, chunk| {
        let rows = chunk.len() / n;
        kr.gemm_chunk(&w[row0 * k..(row0 + rows) * k], a_i32, rows, k, n, sign, chunk);
    });
}

/// Apply the signed-error expansion of `plan`'s (family, m, polarity)
/// point to `scratch.acc32`, which must already hold the exact Σ W·A —
/// afterwards acc32 = Σ AM(W, A). `w` is the raw weight window matching
/// `row0` (the perforated expansion streams it directly; paired partitions
/// pass their parity-masked panel, whose zeros contribute nothing to any
/// family's ε term).
#[allow(clippy::too_many_arguments)]
fn eps_identity_into(
    kr: &dyn Kernel,
    plan: &LayerPlan,
    row0: usize,
    w: &[u8],
    a: &[u8],
    m_rows: usize,
    k: usize,
    n: usize,
    scratch: &mut Scratch,
    threads: usize,
) {
    let (family, m, pol) = (plan.family, plan.m, plan.pol);
    if family == Family::Exact || m == 0 {
        return;
    }
    // ε-term direction in the accumulator: Neg points drop value (subtract
    // the ε GEMM), Pos points compensate upward (add it).
    let sign = match pol {
        Polarity::Neg => -1,
        Polarity::Pos => 1,
    };
    if pol == Polarity::Pos {
        // i32 headroom: exact (≤ K·255²) plus the compensation (≤ K·255·127)
        // must stay inside i32 — tighter than the Neg bound. Validated with
        // a typed error at plan/policy time; unreachable backstop here.
        assert!(
            k <= MAX_K_POS,
            "K too large for i32 accumulation with positive-polarity \
             compensation — tile it"
        );
    }
    match family {
        Family::Perforated | Family::Recursive => {
            // Shared activation transform (low bits for Neg, their modular
            // complement for Pos); only the weight operand differs per
            // family — raw weights for perforated, the plan's prebuilt
            // low/complement panel for recursive.
            reset(&mut scratch.a_mask, k * n);
            kr.mask_low(pol, m, a, &mut scratch.a_mask);
            let w_panel =
                if family == Family::Recursive { plan.w_low(row0, m_rows) } else { w };
            gemm_core_i32(
                kr,
                w_panel,
                &scratch.a_mask,
                m_rows,
                k,
                n,
                sign,
                &mut scratch.acc32,
                threads,
            );
        }
        Family::Truncated => {
            // ε = Σ_{i<m} (W mod 2^{m−i}) · a_i · 2^i (Neg) or its modular
            // complement (Pos): m bit-plane GEMMs over the plan's
            // precomputed weight planes. Each term fits i32 (≤ K·127·2^i ≤
            // K·2^13); the weighted merge happens per plane with the shift
            // folded into the i32 domain.
            reset(&mut scratch.a_mask, k * n);
            reset(&mut scratch.term, m_rows * n);
            for i in 0..m {
                kr.bit_plane(i, a, &mut scratch.a_mask);
                scratch.term.fill(0);
                gemm_core_i32(
                    kr,
                    plan.w_plane(i as usize, row0, m_rows),
                    &scratch.a_mask,
                    m_rows,
                    k,
                    n,
                    1,
                    &mut scratch.term,
                    threads,
                );
                kr.merge_shifted(sign, i, &scratch.term, &mut scratch.acc32);
            }
        }
        Family::Exact => unreachable!(),
    }
}

/// Σ_k AM(W,A) via the closed-form identities into `scratch.acc` (fast
/// path). `plan` supplies the precomputed masked weight panels; `row0`
/// selects the filter-row window within the plan (conv groups) and `w` is
/// the matching window of the raw weights.
#[allow(clippy::too_many_arguments)]
fn am_acc_identity_into(
    kr: &dyn Kernel,
    plan: &LayerPlan,
    row0: usize,
    w: &[u8],
    a: &[u8],
    m_rows: usize,
    k: usize,
    n: usize,
    scratch: &mut Scratch,
    threads: usize,
) {
    reset(&mut scratch.acc32, m_rows * n);
    reset(&mut scratch.a_wide, k * n);
    kr.widen_u8(a, &mut scratch.a_wide);
    gemm_core_i32(kr, w, &scratch.a_wide, m_rows, k, n, 1, &mut scratch.acc32, threads);
    eps_identity_into(kr, plan, row0, w, a, m_rows, k, n, scratch, threads);
    reset(&mut scratch.acc, m_rows * n);
    kr.widen_acc(&scratch.acc32, &mut scratch.acc);
}

/// Σ_k AM(W,A) via the closed-form identities (fast path). Compatibility
/// wrapper over the planned path: builds a transient plan + scratch.
pub fn am_acc_identity(
    family: Family,
    m: u32,
    w: &[u8],
    a: &[u8],
    m_rows: usize,
    k: usize,
    n: usize,
) -> Vec<i64> {
    let plan = LayerPlan::build(family, m, w, m_rows, k);
    let mut scratch = Scratch::new();
    am_acc_identity_into(
        kernel::active(),
        &plan,
        0,
        w,
        a,
        m_rows,
        k,
        n,
        &mut scratch,
        configured_workers(),
    );
    std::mem::take(&mut scratch.acc)
}

/// N-blocked LUT accumulation over one contiguous row chunk.
fn lut_chunk(
    lut: &MulLut,
    w: &[u8],
    a: &[u8],
    rows: usize,
    k: usize,
    n: usize,
    out: &mut [i64],
) {
    let mut n0 = 0;
    while n0 < n {
        let nc = NC.min(n - n0);
        for f in 0..rows {
            let wrow = &w[f * k..(f + 1) * k];
            let orow = &mut out[f * n + n0..f * n + n0 + nc];
            for (kk, &wv) in wrow.iter().enumerate() {
                let arow = &a[kk * n + n0..kk * n + n0 + nc];
                for (o, &av) in orow.iter_mut().zip(arow) {
                    *o += lut.mul(wv, av) as i64;
                }
            }
        }
        n0 += nc;
    }
}

/// Σ_k AM(W,A) via 256×256 lookup into a caller-owned accumulator
/// (hardware-faithful path), parallelized over output-row blocks.
fn am_acc_lut_into(
    lut: &MulLut,
    w: &[u8],
    a: &[u8],
    m_rows: usize,
    k: usize,
    n: usize,
    threads: usize,
    out: &mut [i64],
) {
    debug_assert_eq!(out.len(), m_rows * n);
    let threads = if m_rows * k * n < PAR_THRESHOLD { 1 } else { threads };
    par_row_blocks(out, n, threads, 8, |row0, chunk| {
        let rows = chunk.len() / n;
        lut_chunk(lut, &w[row0 * k..(row0 + rows) * k], a, rows, k, n, chunk);
    });
}

/// Σ_k AM(W,A) via 256×256 lookup (hardware-faithful path).
pub fn am_acc_lut(
    lut: &MulLut,
    w: &[u8],
    a: &[u8],
    m_rows: usize,
    k: usize,
    n: usize,
) -> Vec<i64> {
    let mut acc = vec![0i64; m_rows * n];
    am_acc_lut_into(lut, w, a, m_rows, k, n, configured_workers(), &mut acc);
    acc
}

/// N-blocked paired LUT accumulation over one contiguous row chunk: even
/// reduction indices look up `even`, odd ones `odd` (`None` = an exact
/// partition, plain product) — exactly what an array with alternating
/// multiplier columns computes.
fn lut_paired_chunk(
    even: Option<&MulLut>,
    odd: Option<&MulLut>,
    w: &[u8],
    a: &[u8],
    rows: usize,
    k: usize,
    n: usize,
    out: &mut [i64],
) {
    let mut n0 = 0;
    while n0 < n {
        let nc = NC.min(n - n0);
        for f in 0..rows {
            let wrow = &w[f * k..(f + 1) * k];
            let orow = &mut out[f * n + n0..f * n + n0 + nc];
            for (kk, &wv) in wrow.iter().enumerate() {
                let arow = &a[kk * n + n0..kk * n + n0 + nc];
                match if kk % 2 == 0 { even } else { odd } {
                    Some(l) => {
                        for (o, &av) in orow.iter_mut().zip(arow) {
                            *o += l.mul(wv, av) as i64;
                        }
                    }
                    None => {
                        for (o, &av) in orow.iter_mut().zip(arow) {
                            *o += (wv as i64) * (av as i64);
                        }
                    }
                }
            }
        }
        n0 += nc;
    }
}

/// Σ_k AM(W,A) of a paired layer via per-parity lookup, parallelized over
/// output-row blocks.
#[allow(clippy::too_many_arguments)]
fn am_acc_lut_paired_into(
    even: Option<&MulLut>,
    odd: Option<&MulLut>,
    w: &[u8],
    a: &[u8],
    m_rows: usize,
    k: usize,
    n: usize,
    threads: usize,
    out: &mut [i64],
) {
    debug_assert_eq!(out.len(), m_rows * n);
    let threads = if m_rows * k * n < PAR_THRESHOLD { 1 } else { threads };
    par_row_blocks(out, n, threads, 8, |row0, chunk| {
        let rows = chunk.len() / n;
        lut_paired_chunk(even, odd, &w[row0 * k..(row0 + rows) * k], a, rows, k, n, chunk);
    });
}

/// Resolve the LUT for one partition point: an attached table matching
/// (family, m, polarity) is used as-is; a missing or mismatched one is
/// built on demand (correctness fallback — steady-state callers prepare
/// their tables). Exact partitions have no table: plain products.
fn lut_for_point<'l>(
    pt: LayerPoint,
    attached: Option<&'l MulLut>,
    built: &'l mut Option<MulLut>,
) -> Option<&'l MulLut> {
    if pt.family == Family::Exact || pt.m == 0 {
        return None;
    }
    match attached {
        Some(l) if l.family == pt.family && l.m == pt.m && l.polarity == pt.polarity => {
            Some(l)
        }
        _ => Some(
            built.get_or_insert_with(|| MulLut::build_pol(pt.family, pt.m, pt.polarity)),
        ),
    }
}

/// Full layer GEMM for an even/odd **paired** layer against a prebuilt
/// [`PairedPlan`]: AM accumulation with the reduction dimension split by
/// parity between the pair's two points, per-partition CV epilogues (each
/// half regresses on its own ΣX over its own columns, with constants
/// averaged over its partition), and the shared zero-point/bias epilogue —
/// written into `scratch.acc` ([m_rows × n] i64).
///
/// `row0`/`m_rows` select a filter-row window (conv groups); `w` and
/// `bias` are the matching windows of the raw weights/bias. Identity kind
/// runs one exact pass plus each partition's signed ε expansion over its
/// parity-masked panel; Lut kind streams every product through the
/// partition's table — bit-identical by the error identities (tested).
#[allow(clippy::too_many_arguments)]
pub fn paired_gemm_planned(
    kind: GemmKind,
    pair: &PairedPoint,
    zp_w: i64,
    zp_a: i64,
    plan: &PairedPlan,
    row0: usize,
    lut_even: Option<&MulLut>,
    lut_odd: Option<&MulLut>,
    w: &[u8],
    a: &[u8],
    m_rows: usize,
    k: usize,
    n: usize,
    bias: &[i32],
    scratch: &mut Scratch,
    threads: usize,
) {
    paired_gemm_planned_with_kernel(
        kernel::active(),
        kind,
        pair,
        zp_w,
        zp_a,
        plan,
        row0,
        lut_even,
        lut_odd,
        w,
        a,
        m_rows,
        k,
        n,
        bias,
        scratch,
        threads,
    );
}

/// [`paired_gemm_planned`] with an explicitly pinned compute backend (the
/// bare entry point runs the process-wide [`kernel::active`] one).
#[allow(clippy::too_many_arguments)]
pub fn paired_gemm_planned_with_kernel(
    kr: &dyn Kernel,
    kind: GemmKind,
    pair: &PairedPoint,
    zp_w: i64,
    zp_a: i64,
    plan: &PairedPlan,
    row0: usize,
    lut_even: Option<&MulLut>,
    lut_odd: Option<&MulLut>,
    w: &[u8],
    a: &[u8],
    m_rows: usize,
    k: usize,
    n: usize,
    bias: &[i32],
    scratch: &mut Scratch,
    threads: usize,
) {
    debug_assert!(row0 + m_rows <= plan.rows);
    debug_assert_eq!(k, plan.k);
    let even_pt = pair.even.normalized();
    let odd_pt = pair.odd.normalized();
    match kind {
        GemmKind::Identity => {
            reset(&mut scratch.acc32, m_rows * n);
            reset(&mut scratch.a_wide, k * n);
            kr.widen_u8(a, &mut scratch.a_wide);
            gemm_core_i32(
                kr,
                w,
                &scratch.a_wide,
                m_rows,
                k,
                n,
                1,
                &mut scratch.acc32,
                threads,
            );
            let w_even = &plan.w_even[row0 * k..(row0 + m_rows) * k];
            let w_odd = &plan.w_odd[row0 * k..(row0 + m_rows) * k];
            eps_identity_into(
                kr, &plan.even, row0, w_even, a, m_rows, k, n, scratch, threads,
            );
            eps_identity_into(
                kr, &plan.odd, row0, w_odd, a, m_rows, k, n, scratch, threads,
            );
            reset(&mut scratch.acc, m_rows * n);
            kr.widen_acc(&scratch.acc32, &mut scratch.acc);
        }
        GemmKind::Lut => {
            let mut built_even: Option<MulLut> = None;
            let mut built_odd: Option<MulLut> = None;
            let le = lut_for_point(even_pt, lut_even, &mut built_even);
            let lo = lut_for_point(odd_pt, lut_odd, &mut built_odd);
            reset(&mut scratch.acc, m_rows * n);
            am_acc_lut_paired_into(le, lo, w, a, m_rows, k, n, threads, &mut scratch.acc);
        }
    }
    // Per-partition ΣX (each CV half sums its own x over its own columns).
    let cv_even = even_pt.use_cv && even_pt != LayerPoint::EXACT;
    let cv_odd = odd_pt.use_cv && odd_pt != LayerPoint::EXACT;
    if cv_even {
        reset(&mut scratch.sum_x, n);
        kr.col_sum_x(
            even_pt.family,
            even_pt.polarity,
            even_pt.m,
            0,
            2,
            a,
            k,
            n,
            &mut scratch.sum_x,
        );
    }
    if cv_odd {
        reset(&mut scratch.sum_x2, n);
        kr.col_sum_x(
            odd_pt.family,
            odd_pt.polarity,
            odd_pt.m,
            1,
            2,
            a,
            k,
            n,
            &mut scratch.sum_x2,
        );
    }
    reset(&mut scratch.sum_a, n);
    kr.col_sum_a(a, k, n, &mut scratch.sum_a);
    // Fused per-partition V + shared zero-point/bias epilogue, parallelized
    // over the same row blocks as the core. Σw (full-row) and each half's
    // C/C₀ come from the paired plan.
    let kzz = k as i64 * zp_w * zp_a;
    let sum_a = &scratch.sum_a;
    let sum_x = &scratch.sum_x;
    let sum_x2 = &scratch.sum_x2;
    let (even_plan, odd_plan) = (&plan.even, &plan.odd);
    let epi_threads = if m_rows * n < PAR_THRESHOLD / 16 { 1 } else { threads };
    par_row_blocks(&mut scratch.acc, n, epi_threads, 8, |r0, chunk| {
        for (fi, orow) in chunk.chunks_mut(n).enumerate() {
            let f = r0 + fi;
            let base = -zp_a * plan.sum_w[row0 + f] + kzz + bias[f] as i64;
            for (p, o) in orow.iter_mut().enumerate() {
                let mut add = base - zp_w * sum_a[p];
                if cv_even {
                    add += cv::v_term(&even_plan.consts[row0 + f], sum_x[p]);
                }
                if cv_odd {
                    add += cv::v_term(&odd_plan.consts[row0 + f], sum_x2[p]);
                }
                *o += add;
            }
        }
    });
}

/// Full layer GEMM against a prebuilt [`LayerPlan`]: AM accumulation (+V) +
/// zero-point/bias epilogue, written into `scratch.acc` ([m_rows × n] i64).
///
/// `row0`/`m_rows` select a filter-row window of the plan (conv groups run
/// one window per group); `w` and `bias` are the matching windows of the
/// raw weights/bias. No weight-side quantity is recomputed here: masked
/// panels, Σw and CV constants all come from the plan.
///
/// LUT-kind dispatch: a `lut` matching (family, m) is used as-is; for an
/// approximate family with no (matching) LUT attached one is built on
/// demand — the hardware-faithful request is honored rather than silently
/// answered by the Identity engine (the seed's behavior). The on-demand
/// build prices a full 256×256 table **per call**, so steady-state callers
/// must attach a prepared LUT (`Engine::prepare_lut` does); the fallback
/// exists for correctness, not speed. For the exact family the Identity
/// path *is* the exact GEMM, so Lut falls back to it by design (no
/// approximate table exists for an exact multiplier).
#[allow(clippy::too_many_arguments)]
pub fn approx_gemm_planned(
    kind: GemmKind,
    ctx: &GemmCtx,
    plan: &LayerPlan,
    row0: usize,
    lut: Option<&MulLut>,
    w: &[u8],
    a: &[u8],
    m_rows: usize,
    k: usize,
    n: usize,
    bias: &[i32],
    scratch: &mut Scratch,
    threads: usize,
) {
    approx_gemm_planned_with_kernel(
        kernel::active(),
        kind,
        ctx,
        plan,
        row0,
        lut,
        w,
        a,
        m_rows,
        k,
        n,
        bias,
        scratch,
        threads,
    );
}

/// [`approx_gemm_planned`] with an explicitly pinned compute backend (the
/// bare entry point runs the process-wide [`kernel::active`] one).
#[allow(clippy::too_many_arguments)]
pub fn approx_gemm_planned_with_kernel(
    kr: &dyn Kernel,
    kind: GemmKind,
    ctx: &GemmCtx,
    plan: &LayerPlan,
    row0: usize,
    lut: Option<&MulLut>,
    w: &[u8],
    a: &[u8],
    m_rows: usize,
    k: usize,
    n: usize,
    bias: &[i32],
    scratch: &mut Scratch,
    threads: usize,
) {
    debug_assert_eq!(plan.family, ctx.family, "plan/ctx family mismatch");
    debug_assert_eq!(plan.m, ctx.m, "plan/ctx m mismatch");
    debug_assert!(row0 + m_rows <= plan.rows);
    debug_assert_eq!(k, plan.k);
    // AM accumulation.
    let mut built: Option<MulLut> = None;
    match kind {
        GemmKind::Identity => {
            am_acc_identity_into(kr, plan, row0, w, a, m_rows, k, n, scratch, threads);
        }
        GemmKind::Lut => {
            if ctx.family == Family::Exact || ctx.m == 0 {
                am_acc_identity_into(
                    kr, plan, row0, w, a, m_rows, k, n, scratch, threads,
                );
            } else {
                let l: &MulLut = match lut {
                    Some(l)
                        if l.family == ctx.family
                            && l.m == ctx.m
                            && l.polarity == plan.pol =>
                    {
                        l
                    }
                    _ => built.get_or_insert_with(|| {
                        MulLut::build_pol(ctx.family, ctx.m, plan.pol)
                    }),
                };
                reset(&mut scratch.acc, m_rows * n);
                am_acc_lut_into(l, w, a, m_rows, k, n, threads, &mut scratch.acc);
            }
        }
    }
    // Activation-side column sums (the only per-image reductions).
    let use_cv = ctx.use_cv && ctx.family != Family::Exact && ctx.m > 0;
    if use_cv {
        reset(&mut scratch.sum_x, n);
        kr.col_sum_x(ctx.family, plan.pol, ctx.m, 0, 1, a, k, n, &mut scratch.sum_x);
    }
    reset(&mut scratch.sum_a, n);
    kr.col_sum_a(a, k, n, &mut scratch.sum_a);
    // Control variate (MAC+ column) + zero-point/bias epilogue, fused into
    // one pass over the accumulator and parallelized over the same row
    // blocks as the core. Σw and C/C₀ come from the plan.
    let kzz = k as i64 * ctx.zp_w * ctx.zp_a;
    let sum_a = &scratch.sum_a;
    let sum_x = &scratch.sum_x;
    let epi_threads = if m_rows * n < PAR_THRESHOLD / 16 { 1 } else { threads };
    par_row_blocks(&mut scratch.acc, n, epi_threads, 8, |r0, chunk| {
        for (fi, orow) in chunk.chunks_mut(n).enumerate() {
            let f = r0 + fi;
            let base = -ctx.zp_a * plan.sum_w[row0 + f] + kzz + bias[f] as i64;
            if use_cv {
                let c = &plan.consts[row0 + f];
                for ((o, &sa), &sx) in orow.iter_mut().zip(sum_a).zip(sum_x) {
                    *o += cv::v_term(c, sx) - ctx.zp_w * sa + base;
                }
            } else {
                for (o, &sa) in orow.iter_mut().zip(sum_a) {
                    *o += base - ctx.zp_w * sa;
                }
            }
        }
    });
}

/// Full layer GEMM: AM accumulation (+V) + zero-point/bias epilogue.
///
/// Mirrors python `model.approx_gemm` exactly. Returns [m_rows, n] i64.
/// Compatibility wrapper: builds a transient plan + scratch per call; hot
/// paths (the engine, the coordinator) use [`approx_gemm_planned`] with a
/// cached plan and a reused scratch instead.
#[allow(clippy::too_many_arguments)]
pub fn approx_gemm(
    kind: GemmKind,
    ctx: &GemmCtx,
    lut: Option<&MulLut>,
    w: &[u8],
    a: &[u8],
    m_rows: usize,
    k: usize,
    n: usize,
    bias: &[i32],
) -> Vec<i64> {
    let plan = LayerPlan::build(ctx.family, ctx.m, w, m_rows, k);
    let mut scratch = Scratch::new();
    approx_gemm_planned(
        kind,
        ctx,
        &plan,
        0,
        lut,
        w,
        a,
        m_rows,
        k,
        n,
        bias,
        &mut scratch,
        configured_workers(),
    );
    std::mem::take(&mut scratch.acc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::{am_pol, xvar_pol};
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn naive_am_acc_pol(
        family: Family,
        pol: Polarity,
        m: u32,
        w: &[u8],
        a: &[u8],
        m_rows: usize,
        k: usize,
        n: usize,
    ) -> Vec<i64> {
        let mut out = vec![0i64; m_rows * n];
        for f in 0..m_rows {
            for p in 0..n {
                let mut s = 0i64;
                for kk in 0..k {
                    s += am_pol(family, pol, w[f * k + kk], a[kk * n + p], m) as i64;
                }
                out[f * n + p] = s;
            }
        }
        out
    }

    fn naive_am_acc(
        family: Family,
        m: u32,
        w: &[u8],
        a: &[u8],
        m_rows: usize,
        k: usize,
        n: usize,
    ) -> Vec<i64> {
        naive_am_acc_pol(family, Polarity::Neg, m, w, a, m_rows, k, n)
    }

    /// Scalar reference for the *full* layer GEMM (AM + V + epilogue),
    /// mirroring the python reference term by term.
    fn naive_full_gemm_pol(
        ctx: &GemmCtx,
        pol: Polarity,
        w: &[u8],
        a: &[u8],
        m_rows: usize,
        k: usize,
        n: usize,
        bias: &[i32],
    ) -> Vec<i64> {
        let mut out = naive_am_acc_pol(ctx.family, pol, ctx.m, w, a, m_rows, k, n);
        if ctx.use_cv && ctx.family != Family::Exact && ctx.m > 0 {
            for f in 0..m_rows {
                let c =
                    cv::constants_pol(ctx.family, pol, ctx.m, &w[f * k..(f + 1) * k], k);
                for p in 0..n {
                    let sx: i64 = (0..k)
                        .map(|kk| xvar_pol(ctx.family, pol, a[kk * n + p], ctx.m) as i64)
                        .sum();
                    out[f * n + p] += cv::v_term(&c, sx);
                }
            }
        }
        let kzz = k as i64 * ctx.zp_w * ctx.zp_a;
        for f in 0..m_rows {
            let sum_w: i64 = w[f * k..(f + 1) * k].iter().map(|&x| x as i64).sum();
            for p in 0..n {
                let sum_a: i64 = (0..k).map(|kk| a[kk * n + p] as i64).sum();
                out[f * n + p] +=
                    -ctx.zp_w * sum_a - ctx.zp_a * sum_w + kzz + bias[f] as i64;
            }
        }
        out
    }

    fn naive_full_gemm(
        ctx: &GemmCtx,
        w: &[u8],
        a: &[u8],
        m_rows: usize,
        k: usize,
        n: usize,
        bias: &[i32],
    ) -> Vec<i64> {
        naive_full_gemm_pol(ctx, Polarity::Neg, w, a, m_rows, k, n, bias)
    }

    /// Scalar reference for a paired layer: per-product AM by reduction
    /// parity, per-partition CV (constants from the parity-masked rows with
    /// partition-sized averages), shared zero-point epilogue.
    #[allow(clippy::too_many_arguments)]
    fn naive_paired_gemm(
        pair: &PairedPoint,
        zp_w: i64,
        zp_a: i64,
        w: &[u8],
        a: &[u8],
        m_rows: usize,
        k: usize,
        n: usize,
        bias: &[i32],
    ) -> Vec<i64> {
        let even = pair.even.normalized();
        let odd = pair.odd.normalized();
        let mut out = vec![0i64; m_rows * n];
        for f in 0..m_rows {
            for p in 0..n {
                let mut s = 0i64;
                for kk in 0..k {
                    let pt = if kk % 2 == 0 { even } else { odd };
                    s += am_pol(pt.family, pt.polarity, w[f * k + kk], a[kk * n + p], pt.m)
                        as i64;
                }
                out[f * n + p] = s;
            }
        }
        for (parity, pt) in [(0usize, even), (1usize, odd)] {
            if !pt.use_cv || pt == LayerPoint::EXACT {
                continue;
            }
            let k_valid = if parity == 0 { k.div_ceil(2) } else { k / 2 };
            for f in 0..m_rows {
                let wp: Vec<u8> = (0..k)
                    .map(|kk| if kk % 2 == parity { w[f * k + kk] } else { 0 })
                    .collect();
                let c = cv::constants_pol(pt.family, pt.polarity, pt.m, &wp, k_valid);
                for p in 0..n {
                    let sx: i64 = (parity..k)
                        .step_by(2)
                        .map(|kk| {
                            xvar_pol(pt.family, pt.polarity, a[kk * n + p], pt.m) as i64
                        })
                        .sum();
                    out[f * n + p] += cv::v_term(&c, sx);
                }
            }
        }
        let kzz = k as i64 * zp_w * zp_a;
        for f in 0..m_rows {
            let sum_w: i64 = w[f * k..(f + 1) * k].iter().map(|&x| x as i64).sum();
            for p in 0..n {
                let sum_a: i64 = (0..k).map(|kk| a[kk * n + p] as i64).sum();
                out[f * n + p] += -zp_w * sum_a - zp_a * sum_w + kzz + bias[f] as i64;
            }
        }
        out
    }

    #[test]
    fn identity_and_lut_match_naive() {
        prop::check_msg(
            "gemm engines agree",
            40,
            0x6E,
            |r| {
                let m_rows = 1 + r.below(6) as usize;
                let k = 1 + r.below(40) as usize;
                let n = 1 + r.below(10) as usize;
                let w: Vec<u8> = (0..m_rows * k).map(|_| r.u8()).collect();
                let a: Vec<u8> = (0..k * n).map(|_| r.u8()).collect();
                let fam = Family::ALL[r.below(4) as usize];
                let m = if fam == Family::Exact { 0 } else { 1 + r.below(7) as u32 };
                (fam, m, w, a, m_rows, k, n)
            },
            |(fam, m, w, a, m_rows, k, n)| {
                let want = naive_am_acc(*fam, *m, w, a, *m_rows, *k, *n);
                let ident = am_acc_identity(*fam, *m, w, a, *m_rows, *k, *n);
                if ident != want {
                    return Err("identity != naive".into());
                }
                if *fam != Family::Exact {
                    let lut = MulLut::build(*fam, *m);
                    let l = am_acc_lut(&lut, w, a, *m_rows, *k, *n);
                    if l != want {
                        return Err("lut != naive".into());
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn planned_gemm_matches_reference_across_threads() {
        // The tentpole invariant: the planned + blocked + threaded engine is
        // bit-identical to the scalar reference for every family, kind,
        // CV setting and thread count — including shapes with row/col
        // remainders around the 4-row and NC/KC block edges.
        prop::check_msg(
            "planned gemm bit-exact",
            24,
            0x91AA,
            |r| {
                let m_rows = 1 + r.below(13) as usize;
                let k = 1 + r.below(48) as usize;
                let n = 1 + r.below(12) as usize;
                let w: Vec<u8> = (0..m_rows * k).map(|_| r.u8()).collect();
                let a: Vec<u8> = (0..k * n).map(|_| r.u8()).collect();
                let bias: Vec<i32> =
                    (0..m_rows).map(|_| r.range_i64(-500, 500) as i32).collect();
                let fam = Family::ALL[r.below(4) as usize];
                let m = if fam == Family::Exact { 0 } else { 1 + r.below(7) as u32 };
                let use_cv = r.below(2) == 1;
                let zp_w = r.range_i64(0, 40);
                let zp_a = r.range_i64(0, 120);
                (fam, m, use_cv, zp_w, zp_a, w, a, bias, m_rows, k, n)
            },
            |(fam, m, use_cv, zp_w, zp_a, w, a, bias, m_rows, k, n)| {
                let ctx = GemmCtx {
                    family: *fam,
                    m: *m,
                    use_cv: *use_cv,
                    zp_w: *zp_w,
                    zp_a: *zp_a,
                };
                let want = naive_full_gemm(&ctx, w, a, *m_rows, *k, *n, bias);
                let plan = LayerPlan::build(*fam, *m, w, *m_rows, *k);
                let mut scratch = Scratch::new();
                for kr in [kernel::scalar(), kernel::simd()] {
                    for kind in [GemmKind::Identity, GemmKind::Lut] {
                        for threads in [1usize, 2, 3, 8] {
                            approx_gemm_planned_with_kernel(
                                kr, kind, &ctx, &plan, 0, None, w, a, *m_rows, *k,
                                *n, bias, &mut scratch, threads,
                            );
                            if scratch.acc != want {
                                return Err(format!(
                                    "{} m={m} cv={use_cv} {kind:?} kernel={} \
                                     threads={threads}: planned != naive",
                                    fam.name(),
                                    kr.name()
                                ));
                            }
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn pos_polarity_planned_gemm_matches_reference() {
        // The uniform positive-polarity path: pos plans (complement panels)
        // + pos activation transforms + negated CV constants must equal the
        // scalar reference for every family, kind and thread count.
        prop::check_msg(
            "pos planned gemm bit-exact",
            16,
            0x91AB,
            |r| {
                let m_rows = 1 + r.below(10) as usize;
                let k = 1 + r.below(40) as usize;
                let n = 1 + r.below(10) as usize;
                let w: Vec<u8> = (0..m_rows * k).map(|_| r.u8()).collect();
                let a: Vec<u8> = (0..k * n).map(|_| r.u8()).collect();
                let bias: Vec<i32> =
                    (0..m_rows).map(|_| r.range_i64(-500, 500) as i32).collect();
                let fam = Family::APPROX[r.below(3) as usize];
                let m = 1 + r.below(7) as u32;
                let use_cv = r.below(2) == 1;
                let zp_w = r.range_i64(0, 40);
                let zp_a = r.range_i64(0, 120);
                (fam, m, use_cv, zp_w, zp_a, w, a, bias, m_rows, k, n)
            },
            |(fam, m, use_cv, zp_w, zp_a, w, a, bias, m_rows, k, n)| {
                let ctx = GemmCtx {
                    family: *fam,
                    m: *m,
                    use_cv: *use_cv,
                    zp_w: *zp_w,
                    zp_a: *zp_a,
                };
                let want =
                    naive_full_gemm_pol(&ctx, Polarity::Pos, w, a, *m_rows, *k, *n, bias);
                let plan =
                    LayerPlan::build_pol(*fam, *m, Polarity::Pos, w, *m_rows, *k, *k);
                let mut scratch = Scratch::new();
                for kind in [GemmKind::Identity, GemmKind::Lut] {
                    for threads in [1usize, 3] {
                        approx_gemm_planned(
                            kind, &ctx, &plan, 0, None, w, a, *m_rows, *k, *n, bias,
                            &mut scratch, threads,
                        );
                        if scratch.acc != want {
                            return Err(format!(
                                "{} m={m} cv={use_cv} {kind:?} threads={threads}: \
                                 pos planned != naive",
                                fam.name()
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn paired_gemm_matches_scalar_reference() {
        // The pairing tentpole: identity (exact pass + per-partition signed
        // ε over parity-masked panels) and LUT (per-parity tables) engines
        // both equal the scalar per-product reference — for arbitrary
        // point pairs (mirrored, cross-family, half-exact), CV settings,
        // shapes with odd k, and thread counts.
        prop::check_msg(
            "paired gemm bit-exact",
            16,
            0x91AC,
            |r| {
                let m_rows = 1 + r.below(9) as usize;
                let k = 1 + r.below(40) as usize;
                let n = 1 + r.below(9) as usize;
                let w: Vec<u8> = (0..m_rows * k).map(|_| r.u8()).collect();
                let a: Vec<u8> = (0..k * n).map(|_| r.u8()).collect();
                let bias: Vec<i32> =
                    (0..m_rows).map(|_| r.range_i64(-300, 300) as i32).collect();
                let mut point = |r: &mut Rng| {
                    let fam = Family::ALL[r.below(4) as usize];
                    let m = if fam == Family::Exact { 0 } else { r.below(8) as u32 };
                    let pol = if fam == Family::Exact {
                        Polarity::Neg
                    } else {
                        Polarity::ALL[r.below(2) as usize]
                    };
                    LayerPoint::new_pol(fam, m, pol, r.below(2) == 1)
                };
                let pair = PairedPoint::new(point(r), point(r));
                let zp_w = r.range_i64(0, 40);
                let zp_a = r.range_i64(0, 120);
                (pair, zp_w, zp_a, w, a, bias, m_rows, k, n)
            },
            |(pair, zp_w, zp_a, w, a, bias, m_rows, k, n)| {
                let want =
                    naive_paired_gemm(pair, *zp_w, *zp_a, w, a, *m_rows, *k, *n, bias);
                let plan = PairedPlan::build(*pair, w, *m_rows, *k);
                let mut scratch = Scratch::new();
                for kr in [kernel::scalar(), kernel::simd()] {
                    for kind in [GemmKind::Identity, GemmKind::Lut] {
                        for threads in [1usize, 2, 5] {
                            paired_gemm_planned_with_kernel(
                                kr, kind, pair, *zp_w, *zp_a, &plan, 0, None, None,
                                w, a, *m_rows, *k, *n, bias, &mut scratch, threads,
                            );
                            if scratch.acc != want {
                                return Err(format!(
                                    "{} {kind:?} kernel={} threads={threads}: \
                                     paired != naive",
                                    pair.describe(),
                                    kr.name()
                                ));
                            }
                        }
                    }
                }
                // Prepared (matching) LUTs take the fast lookup path and
                // must agree too.
                let le = (pair.even.normalized() != LayerPoint::EXACT).then(|| {
                    MulLut::build_pol(
                        pair.even.family,
                        pair.even.m,
                        pair.even.polarity,
                    )
                });
                let lo = (pair.odd.normalized() != LayerPoint::EXACT).then(|| {
                    MulLut::build_pol(pair.odd.family, pair.odd.m, pair.odd.polarity)
                });
                paired_gemm_planned(
                    GemmKind::Lut, pair, *zp_w, *zp_a, &plan, 0, le.as_ref(),
                    lo.as_ref(), w, a, *m_rows, *k, *n, bias, &mut scratch, 1,
                );
                if scratch.acc != want {
                    return Err("paired lut with prepared tables != naive".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn paired_group_row_windows_match_whole_panel() {
        // Conv groups run paired_gemm_planned over row windows of one
        // shared paired plan; each window must equal the same rows of the
        // full run.
        let mut rng = Rng::new(0x6007);
        let (rows, k, n) = (12usize, 27usize, 9usize);
        let w: Vec<u8> = (0..rows * k).map(|_| rng.u8()).collect();
        let a: Vec<u8> = (0..k * n).map(|_| rng.u8()).collect();
        let bias: Vec<i32> = (0..rows).map(|_| rng.range_i64(-50, 50) as i32).collect();
        let pair = PairedPoint::mirrored(Family::Truncated, 6, true);
        let plan = PairedPlan::build(pair, &w, rows, k);
        let mut scratch = Scratch::new();
        paired_gemm_planned(
            GemmKind::Identity, &pair, 7, 31, &plan, 0, None, None, &w, &a, rows, k,
            n, &bias, &mut scratch, 1,
        );
        let full = scratch.acc.clone();
        let g = 3usize;
        let rpg = rows / g;
        for gi in 0..g {
            let row0 = gi * rpg;
            paired_gemm_planned(
                GemmKind::Identity,
                &pair,
                7,
                31,
                &plan,
                row0,
                None,
                None,
                &w[row0 * k..(row0 + rpg) * k],
                &a,
                rpg,
                k,
                n,
                &bias[row0..row0 + rpg],
                &mut scratch,
                1,
            );
            assert_eq!(
                scratch.acc[..],
                full[row0 * n..(row0 + rpg) * n],
                "group {gi}"
            );
        }
    }

    #[test]
    fn mirrored_pair_cancels_accumulator_bias() {
        // The headline property at GEMM level: a mirrored Neg/Pos pairing
        // leaves the raw accumulator (no CV) much closer to exact than the
        // uniform Neg point — the column error cancels inside the sum.
        let mut rng = Rng::new(0x6008);
        let (m_rows, k, n) = (4usize, 64usize, 24usize);
        let w: Vec<u8> = (0..m_rows * k).map(|_| rng.u8_normal(128.0, 22.0)).collect();
        let a: Vec<u8> = (0..k * n).map(|_| rng.u8()).collect();
        let bias = vec![0i32; m_rows];
        for (family, m) in [(Family::Perforated, 2), (Family::Truncated, 6)] {
            let exact_ctx =
                GemmCtx { family: Family::Exact, m: 0, use_cv: false, zp_w: 0, zp_a: 0 };
            let ex = approx_gemm(
                GemmKind::Identity, &exact_ctx, None, &w, &a, m_rows, k, n, &bias,
            );
            let raw_ctx = GemmCtx { family, m, use_cv: false, zp_w: 0, zp_a: 0 };
            let raw = approx_gemm(
                GemmKind::Identity, &raw_ctx, None, &w, &a, m_rows, k, n, &bias,
            );
            let pair = PairedPoint::mirrored(family, m, false);
            let plan = PairedPlan::build(pair, &w, m_rows, k);
            let mut scratch = Scratch::new();
            paired_gemm_planned(
                GemmKind::Identity, &pair, 0, 0, &plan, 0, None, None, &w, &a,
                m_rows, k, n, &bias, &mut scratch, 1,
            );
            let bias_of = |x: &[i64]| -> f64 {
                x.iter().zip(&ex).map(|(a, b)| (a - b) as f64).sum::<f64>()
                    / x.len() as f64
            };
            let b_raw = bias_of(&raw).abs();
            let b_pair = bias_of(&scratch.acc).abs();
            assert!(
                b_pair < b_raw * 0.2,
                "{} m={m}: paired bias {b_pair} !<< uniform bias {b_raw}",
                family.name()
            );
        }
    }

    #[test]
    fn threading_kicks_in_above_threshold_and_stays_bit_exact() {
        // Shape large enough that gemm_core_i32 actually splits across
        // threads (m_rows*k*n > PAR_THRESHOLD); every thread count must
        // produce the same bytes as the single-threaded run.
        let mut rng = Rng::new(0x7777);
        let (m_rows, k, n) = (64usize, 64usize, 96usize);
        assert!(m_rows * k * n >= super::PAR_THRESHOLD);
        let w: Vec<u8> = (0..m_rows * k).map(|_| rng.u8()).collect();
        let a: Vec<u8> = (0..k * n).map(|_| rng.u8()).collect();
        let bias: Vec<i32> = (0..m_rows).map(|_| rng.range_i64(-9, 9) as i32).collect();
        for family in [Family::Perforated, Family::Truncated, Family::Recursive] {
            let m = *family.paper_levels().last().unwrap();
            let ctx = GemmCtx { family, m, use_cv: true, zp_w: 12, zp_a: 99 };
            let plan = LayerPlan::build(family, m, &w, m_rows, k);
            let mut scratch = Scratch::new();
            approx_gemm_planned(
                GemmKind::Identity, &ctx, &plan, 0, None, &w, &a, m_rows, k, n, &bias,
                &mut scratch, 1,
            );
            let single = scratch.acc.clone();
            for threads in [2usize, 4, 7, 16] {
                approx_gemm_planned(
                    GemmKind::Identity, &ctx, &plan, 0, None, &w, &a, m_rows, k, n,
                    &bias, &mut scratch, threads,
                );
                assert_eq!(
                    scratch.acc, single,
                    "{} m={m} threads={threads}", family.name()
                );
            }
        }
    }

    #[test]
    fn group_row_windows_match_whole_panel() {
        // Conv groups run approx_gemm_planned over row windows of one shared
        // layer plan; each window must equal the same rows of the full run.
        let mut rng = Rng::new(0x6006);
        let (rows, k, n) = (12usize, 27usize, 9usize);
        let w: Vec<u8> = (0..rows * k).map(|_| rng.u8()).collect();
        let a: Vec<u8> = (0..k * n).map(|_| rng.u8()).collect();
        let bias: Vec<i32> = (0..rows).map(|_| rng.range_i64(-50, 50) as i32).collect();
        for family in [Family::Recursive, Family::Truncated] {
            let m = family.paper_levels()[1];
            let ctx = GemmCtx { family, m, use_cv: true, zp_w: 7, zp_a: 31 };
            let plan = LayerPlan::build(family, m, &w, rows, k);
            let mut scratch = Scratch::new();
            approx_gemm_planned(
                GemmKind::Identity, &ctx, &plan, 0, None, &w, &a, rows, k, n, &bias,
                &mut scratch, 1,
            );
            let full = scratch.acc.clone();
            let g = 3usize; // 3 groups of 4 rows
            let rpg = rows / g;
            for gi in 0..g {
                let row0 = gi * rpg;
                approx_gemm_planned(
                    GemmKind::Identity,
                    &ctx,
                    &plan,
                    row0,
                    None,
                    &w[row0 * k..(row0 + rpg) * k],
                    &a,
                    rpg,
                    k,
                    n,
                    &bias[row0..row0 + rpg],
                    &mut scratch,
                    1,
                );
                assert_eq!(
                    scratch.acc[..],
                    full[row0 * n..(row0 + rpg) * n],
                    "{} group {gi}",
                    family.name()
                );
            }
        }
    }

    #[test]
    fn lut_kind_without_table_builds_real_lut() {
        // The seed silently fell back to the Identity engine here; both are
        // bit-identical, so equality with the explicit-LUT run is the
        // observable contract (and the on-demand build keeps the
        // hardware-faithful path honest for callers that forget prepare_lut).
        let mut rng = Rng::new(0x10D);
        let (m_rows, k, n) = (3usize, 20usize, 5usize);
        let w: Vec<u8> = (0..m_rows * k).map(|_| rng.u8()).collect();
        let a: Vec<u8> = (0..k * n).map(|_| rng.u8()).collect();
        let bias = vec![0i32; m_rows];
        let ctx =
            GemmCtx { family: Family::Truncated, m: 6, use_cv: true, zp_w: 3, zp_a: 5 };
        let lut = MulLut::build(Family::Truncated, 6);
        let with_lut =
            approx_gemm(GemmKind::Lut, &ctx, Some(&lut), &w, &a, m_rows, k, n, &bias);
        let on_demand =
            approx_gemm(GemmKind::Lut, &ctx, None, &w, &a, m_rows, k, n, &bias);
        // A *mismatched* attached LUT must also trigger the on-demand build,
        // not silently answer with the wrong table.
        let wrong = MulLut::build(Family::Perforated, 2);
        let mismatched =
            approx_gemm(GemmKind::Lut, &ctx, Some(&wrong), &w, &a, m_rows, k, n, &bias);
        assert_eq!(with_lut, on_demand);
        assert_eq!(with_lut, mismatched);
    }

    #[test]
    fn zero_point_epilogue_matches_definition() {
        // approx_gemm(exact) == Σ (W-zw)(A-za) + bias
        let mut rng = Rng::new(3);
        let (m_rows, k, n) = (4, 18, 5);
        let w: Vec<u8> = (0..m_rows * k).map(|_| rng.u8()).collect();
        let a: Vec<u8> = (0..k * n).map(|_| rng.u8()).collect();
        let bias: Vec<i32> = (0..m_rows).map(|_| rng.range_i64(-1000, 1000) as i32).collect();
        let ctx = GemmCtx { family: Family::Exact, m: 0, use_cv: false, zp_w: 13, zp_a: 97 };
        let got = approx_gemm(GemmKind::Identity, &ctx, None, &w, &a, m_rows, k, n, &bias);
        for f in 0..m_rows {
            for p in 0..n {
                let mut want = bias[f] as i64;
                for kk in 0..k {
                    want += (w[f * k + kk] as i64 - 13) * (a[kk * n + p] as i64 - 97);
                }
                assert_eq!(got[f * n + p], want);
            }
        }
    }

    #[test]
    fn cv_moves_accumulator_toward_exact() {
        let mut rng = Rng::new(8);
        let (m_rows, k, n) = (3, 64, 16);
        let w: Vec<u8> = (0..m_rows * k).map(|_| rng.u8_normal(120.0, 30.0)).collect();
        let a: Vec<u8> = (0..k * n).map(|_| rng.u8()).collect();
        let bias = vec![0i32; m_rows];
        for family in Family::APPROX {
            let m = *family.paper_levels().last().unwrap();
            let exact_ctx =
                GemmCtx { family: Family::Exact, m: 0, use_cv: false, zp_w: 10, zp_a: 5 };
            let raw_ctx = GemmCtx { family, m, use_cv: false, zp_w: 10, zp_a: 5 };
            let cv_ctx = GemmCtx { family, m, use_cv: true, zp_w: 10, zp_a: 5 };
            let ex = approx_gemm(GemmKind::Identity, &exact_ctx, None, &w, &a, m_rows, k, n, &bias);
            let raw = approx_gemm(GemmKind::Identity, &raw_ctx, None, &w, &a, m_rows, k, n, &bias);
            let cvv = approx_gemm(GemmKind::Identity, &cv_ctx, None, &w, &a, m_rows, k, n, &bias);
            let err = |x: &[i64]| -> f64 {
                x.iter().zip(&ex).map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>()
            };
            assert!(
                err(&cvv) < err(&raw) * 0.6,
                "{}: cv {} raw {}", family.name(), err(&cvv), err(&raw)
            );
        }
    }

    #[test]
    fn lut_falls_back_to_identity_for_exact() {
        let w = vec![7u8; 4];
        let a = vec![9u8; 4];
        let ctx = GemmCtx { family: Family::Exact, m: 0, use_cv: false, zp_w: 0, zp_a: 0 };
        let got = approx_gemm(GemmKind::Lut, &ctx, None, &w, &a, 2, 2, 2, &[0, 0]);
        assert_eq!(got, vec![126i64; 4]);
    }
}
