//! Approximate quantized GEMM engines.
//!
//! Computes, for one layer GEMM W[M,K] × A[K,N] (uint8 operands):
//!
//! ```text
//! acc[f,p] = CV( Σ_k AM(W[f,k], A[k,p]) )
//!          − zp_w·Σ_k A[k,p] − zp_a·Σ_k W[f,k] + K·zp_w·zp_a + bias[f]
//! ```
//!
//! Engines (all bit-identical; equivalence asserted by tests):
//! * **Identity** — fast path: the error identities turn each family into
//!   1..m extra exact GEMMs over masked operands (AM = W·A − ε); this is
//!   what the accuracy sweeps run, and what the Pallas kernel computes on
//!   the PJRT path.
//! * **Lut** — hardware-faithful path: every product is a 256×256 table
//!   lookup (TFApprox-style), exactly what the RTL multiplier emits.
//! * the systolic simulator ([`crate::systolic`]) is the third, cycle-level
//!   engine, wired in by the engine layer for power measurements.

use crate::approx::{Family, MulLut};
use crate::cv::{self, CvConstants};

/// Which GEMM engine to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GemmKind {
    Identity,
    Lut,
}

/// Layer-level GEMM descriptor (quantization + CV context).
#[derive(Clone, Debug)]
pub struct GemmCtx {
    pub family: Family,
    pub m: u32,
    pub use_cv: bool,
    pub zp_w: i64,
    pub zp_a: i64,
}

/// Exact u8×u8 GEMM core with **i32 accumulation** (`sign` = ±1 folds the
/// error-term subtraction into the same kernel).
///
/// Overflow safety: |Σ_k w·a| ≤ K·255² < 2^31 for K ≤ 33 000 — far beyond
/// any layer this engine sees (max K here is 3×3×64 = 576; the coordinator
/// would tile anything larger). Asserted below.
///
/// §Perf note (EXPERIMENTS.md): accumulating in i32 with a pre-widened A
/// panel lets LLVM vectorize the inner loop (u8→i64 per element in the
/// original version blocked it): 1.95 → ~6 GMAC/s on the bench shape.
fn gemm_core_i32(
    w: &[u8],
    a_i32: &[i32],
    m_rows: usize,
    k: usize,
    n: usize,
    sign: i32,
    out: &mut [i32],
) {
    debug_assert_eq!(w.len(), m_rows * k);
    debug_assert_eq!(a_i32.len(), k * n);
    debug_assert_eq!(out.len(), m_rows * n);
    assert!(k <= 33_000, "K too large for i32 accumulation — tile it");
    // 4-row register blocking: one pass over the A panel feeds 4 output
    // rows, cutting A-panel memory traffic 4× (§Perf iteration 2).
    let mut f = 0;
    while f + 4 <= m_rows {
        let (w0, w1, w2, w3) = (
            &w[f * k..(f + 1) * k],
            &w[(f + 1) * k..(f + 2) * k],
            &w[(f + 2) * k..(f + 3) * k],
            &w[(f + 3) * k..(f + 4) * k],
        );
        let (head, rest) = out[f * n..].split_at_mut(n);
        let (r1, rest) = rest.split_at_mut(n);
        let (r2, r3full) = rest.split_at_mut(n);
        let r3 = &mut r3full[..n];
        for kk in 0..k {
            let arow = &a_i32[kk * n..(kk + 1) * n];
            let v0 = sign * w0[kk] as i32;
            let v1 = sign * w1[kk] as i32;
            let v2 = sign * w2[kk] as i32;
            let v3 = sign * w3[kk] as i32;
            if v0 | v1 | v2 | v3 == 0 {
                continue;
            }
            for (j, &av) in arow.iter().enumerate() {
                head[j] += v0 * av;
                r1[j] += v1 * av;
                r2[j] += v2 * av;
                r3[j] += v3 * av;
            }
        }
        f += 4;
    }
    while f < m_rows {
        let wrow = &w[f * k..(f + 1) * k];
        let orow = &mut out[f * n..(f + 1) * n];
        for (kk, &wv) in wrow.iter().enumerate() {
            if wv == 0 {
                continue;
            }
            let wv = sign * wv as i32;
            let arow = &a_i32[kk * n..(kk + 1) * n];
            for (o, &av) in orow.iter_mut().zip(arow) {
                *o += wv * av;
            }
        }
        f += 1;
    }
}

/// Widen a u8 panel to i32 (hoisted out of the inner loop so it vectorizes).
fn widen(a: &[u8]) -> Vec<i32> {
    a.iter().map(|&x| x as i32).collect()
}

/// Widen with a mask applied (the error-term operand transforms).
fn widen_mask(a: &[u8], mask: u8) -> Vec<i32> {
    a.iter().map(|&x| (x & mask) as i32).collect()
}

/// Σ_k AM(W,A) via the closed-form identities (fast path).
pub fn am_acc_identity(
    family: Family,
    m: u32,
    w: &[u8],
    a: &[u8],
    m_rows: usize,
    k: usize,
    n: usize,
) -> Vec<i64> {
    let mut acc = vec![0i32; m_rows * n];
    let a_wide = widen(a);
    gemm_core_i32(w, &a_wide, m_rows, k, n, 1, &mut acc);
    if family == Family::Exact || m == 0 {
        return acc.into_iter().map(|x| x as i64).collect();
    }
    let mask = ((1u32 << m) - 1) as u8;
    match family {
        Family::Perforated => {
            let a_low = widen_mask(a, mask);
            gemm_core_i32(w, &a_low, m_rows, k, n, -1, &mut acc);
        }
        Family::Recursive => {
            let w_low: Vec<u8> = w.iter().map(|&x| x & mask).collect();
            let a_low = widen_mask(a, mask);
            gemm_core_i32(&w_low, &a_low, m_rows, k, n, -1, &mut acc);
        }
        Family::Truncated => {
            // ε = Σ_{i<m} (W mod 2^{m−i}) · a_i · 2^i: m bit-plane GEMMs.
            // Each term fits i32 (≤ K·127·2^i ≤ K·2^13); the weighted merge
            // happens per plane with the shift folded into the i32 domain.
            let mut a_bit = vec![0i32; k * n];
            let mut term = vec![0i32; m_rows * n];
            for i in 0..m {
                let wm = ((1u32 << (m - i)) - 1) as u8;
                let w_sub: Vec<u8> = w.iter().map(|&x| x & wm).collect();
                for (dst, &src) in a_bit.iter_mut().zip(a) {
                    *dst = ((src >> i) & 1) as i32;
                }
                term.fill(0);
                gemm_core_i32(&w_sub, &a_bit, m_rows, k, n, 1, &mut term);
                for (o, &t) in acc.iter_mut().zip(&term) {
                    *o -= t << i;
                }
            }
        }
        Family::Exact => unreachable!(),
    }
    acc.into_iter().map(|x| x as i64).collect()
}

/// Σ_k AM(W,A) via 256×256 lookup (hardware-faithful path).
pub fn am_acc_lut(
    lut: &MulLut,
    w: &[u8],
    a: &[u8],
    m_rows: usize,
    k: usize,
    n: usize,
) -> Vec<i64> {
    let mut acc = vec![0i64; m_rows * n];
    for f in 0..m_rows {
        let wrow = &w[f * k..(f + 1) * k];
        let orow = &mut acc[f * n..(f + 1) * n];
        for (kk, &wv) in wrow.iter().enumerate() {
            let arow = &a[kk * n..(kk + 1) * n];
            for (o, &av) in orow.iter_mut().zip(arow) {
                *o += lut.mul(wv, av) as i64;
            }
        }
    }
    acc
}

/// Full layer GEMM: AM accumulation (+V) + zero-point/bias epilogue.
///
/// Mirrors python `model.approx_gemm` exactly. Returns [m_rows, n] i64.
#[allow(clippy::too_many_arguments)]
pub fn approx_gemm(
    kind: GemmKind,
    ctx: &GemmCtx,
    lut: Option<&MulLut>,
    w: &[u8],
    a: &[u8],
    m_rows: usize,
    k: usize,
    n: usize,
    bias: &[i32],
) -> Vec<i64> {
    let mut acc = match kind {
        GemmKind::Identity => am_acc_identity(ctx.family, ctx.m, w, a, m_rows, k, n),
        GemmKind::Lut => match lut {
            Some(l) => am_acc_lut(l, w, a, m_rows, k, n),
            None => am_acc_identity(ctx.family, ctx.m, w, a, m_rows, k, n),
        },
    };
    // Control variate (MAC+ column).
    if ctx.use_cv && ctx.family != Family::Exact && ctx.m > 0 {
        let consts: Vec<CvConstants> = (0..m_rows)
            .map(|f| cv::constants(ctx.family, ctx.m, &w[f * k..(f + 1) * k], k))
            .collect();
        // sum_x per output column
        let mut sum_x = vec![0i64; n];
        for kk in 0..k {
            let arow = &a[kk * n..(kk + 1) * n];
            for (sx, &av) in sum_x.iter_mut().zip(arow) {
                *sx += crate::approx::xvar(ctx.family, av, ctx.m) as i64;
            }
        }
        for f in 0..m_rows {
            let c = &consts[f];
            let orow = &mut acc[f * n..(f + 1) * n];
            for (o, &sx) in orow.iter_mut().zip(&sum_x) {
                *o += cv::v_term(c, sx);
            }
        }
    }
    // Zero-point + bias epilogue.
    let mut sum_a = vec![0i64; n];
    for kk in 0..k {
        let arow = &a[kk * n..(kk + 1) * n];
        for (sa, &av) in sum_a.iter_mut().zip(arow) {
            *sa += av as i64;
        }
    }
    let kzz = k as i64 * ctx.zp_w * ctx.zp_a;
    for f in 0..m_rows {
        let sum_w: i64 = w[f * k..(f + 1) * k].iter().map(|&x| x as i64).sum();
        let b = bias[f] as i64;
        let orow = &mut acc[f * n..(f + 1) * n];
        for (o, &sa) in orow.iter_mut().zip(&sum_a) {
            *o += -ctx.zp_w * sa - ctx.zp_a * sum_w + kzz + b;
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::am;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn naive_am_acc(
        family: Family,
        m: u32,
        w: &[u8],
        a: &[u8],
        m_rows: usize,
        k: usize,
        n: usize,
    ) -> Vec<i64> {
        let mut out = vec![0i64; m_rows * n];
        for f in 0..m_rows {
            for p in 0..n {
                let mut s = 0i64;
                for kk in 0..k {
                    s += am(family, w[f * k + kk], a[kk * n + p], m) as i64;
                }
                out[f * n + p] = s;
            }
        }
        out
    }

    #[test]
    fn identity_and_lut_match_naive() {
        prop::check_msg(
            "gemm engines agree",
            40,
            0x6E,
            |r| {
                let m_rows = 1 + r.below(6) as usize;
                let k = 1 + r.below(40) as usize;
                let n = 1 + r.below(10) as usize;
                let w: Vec<u8> = (0..m_rows * k).map(|_| r.u8()).collect();
                let a: Vec<u8> = (0..k * n).map(|_| r.u8()).collect();
                let fam = Family::ALL[r.below(4) as usize];
                let m = if fam == Family::Exact { 0 } else { 1 + r.below(7) as u32 };
                (fam, m, w, a, m_rows, k, n)
            },
            |(fam, m, w, a, m_rows, k, n)| {
                let want = naive_am_acc(*fam, *m, w, a, *m_rows, *k, *n);
                let ident = am_acc_identity(*fam, *m, w, a, *m_rows, *k, *n);
                if ident != want {
                    return Err("identity != naive".into());
                }
                if *fam != Family::Exact {
                    let lut = MulLut::build(*fam, *m);
                    let l = am_acc_lut(&lut, w, a, *m_rows, *k, *n);
                    if l != want {
                        return Err("lut != naive".into());
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn zero_point_epilogue_matches_definition() {
        // approx_gemm(exact) == Σ (W-zw)(A-za) + bias
        let mut rng = Rng::new(3);
        let (m_rows, k, n) = (4, 18, 5);
        let w: Vec<u8> = (0..m_rows * k).map(|_| rng.u8()).collect();
        let a: Vec<u8> = (0..k * n).map(|_| rng.u8()).collect();
        let bias: Vec<i32> = (0..m_rows).map(|_| rng.range_i64(-1000, 1000) as i32).collect();
        let ctx = GemmCtx { family: Family::Exact, m: 0, use_cv: false, zp_w: 13, zp_a: 97 };
        let got = approx_gemm(GemmKind::Identity, &ctx, None, &w, &a, m_rows, k, n, &bias);
        for f in 0..m_rows {
            for p in 0..n {
                let mut want = bias[f] as i64;
                for kk in 0..k {
                    want += (w[f * k + kk] as i64 - 13) * (a[kk * n + p] as i64 - 97);
                }
                assert_eq!(got[f * n + p], want);
            }
        }
    }

    #[test]
    fn cv_moves_accumulator_toward_exact() {
        let mut rng = Rng::new(8);
        let (m_rows, k, n) = (3, 64, 16);
        let w: Vec<u8> = (0..m_rows * k).map(|_| rng.u8_normal(120.0, 30.0)).collect();
        let a: Vec<u8> = (0..k * n).map(|_| rng.u8()).collect();
        let bias = vec![0i32; m_rows];
        for family in Family::APPROX {
            let m = *family.paper_levels().last().unwrap();
            let exact_ctx =
                GemmCtx { family: Family::Exact, m: 0, use_cv: false, zp_w: 10, zp_a: 5 };
            let raw_ctx = GemmCtx { family, m, use_cv: false, zp_w: 10, zp_a: 5 };
            let cv_ctx = GemmCtx { family, m, use_cv: true, zp_w: 10, zp_a: 5 };
            let ex = approx_gemm(GemmKind::Identity, &exact_ctx, None, &w, &a, m_rows, k, n, &bias);
            let raw = approx_gemm(GemmKind::Identity, &raw_ctx, None, &w, &a, m_rows, k, n, &bias);
            let cvv = approx_gemm(GemmKind::Identity, &cv_ctx, None, &w, &a, m_rows, k, n, &bias);
            let err = |x: &[i64]| -> f64 {
                x.iter().zip(&ex).map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>()
            };
            assert!(
                err(&cvv) < err(&raw) * 0.6,
                "{}: cv {} raw {}", family.name(), err(&cvv), err(&raw)
            );
        }
    }

    #[test]
    fn lut_falls_back_to_identity_for_exact() {
        let w = vec![7u8; 4];
        let a = vec![9u8; 4];
        let ctx = GemmCtx { family: Family::Exact, m: 0, use_cv: false, zp_w: 0, zp_a: 0 };
        let got = approx_gemm(GemmKind::Lut, &ctx, None, &w, &a, 2, 2, 2, &[0, 0]);
        assert_eq!(got, vec![126i64; 4]);
    }
}
