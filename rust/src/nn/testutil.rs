//! Shared synthetic-model builders for unit tests (engine + coordinator).
//!
//! Everything here is artifact-free: the models are generated from a seeded
//! RNG, so service/concurrency tests run on every machine instead of
//! skipping when `make artifacts` has not been run.

use crate::nn::graph::{Model, Node, Op, Tensor, Weights};
use crate::util::rng::Rng;

/// Tiny but non-trivial net: input(6,6,3) → conv3x3(8, relu) → dense(10).
/// Output scales are chosen so requantized values stay inside the u8 range;
/// 10 classes match the synth10 label space used by the service tests.
pub fn tiny_model() -> Model {
    let mut rng = Rng::new(0x71E5);
    let input = Node { out_shape: (6, 6, 3), ..Node::default() };
    let conv = Node {
        op: Op::Conv,
        relu: true,
        inputs: vec![0],
        out_shape: (6, 6, 8),
        out_scale: 4096.0,
        cout: 8,
        ksize: 3,
        pad: 1,
        weights: Some(Weights {
            w_q: (0..8 * 27).map(|_| rng.u8()).collect(),
            k_dim: 27,
            b_q: vec![0; 8],
            s_w: 1.0,
            zp_w: 7,
        }),
        ..Node::default()
    };
    let dense = Node {
        op: Op::Dense,
        inputs: vec![1],
        out_shape: (1, 1, 10),
        // mult = s_w * s_in / s_out keeps the dense accumulators inside the
        // u8 range around zp = 128 (same sizing rationale as the engine's
        // toy model, scaled to the 6x6x8 = 288-wide reduction).
        out_scale: 1.6e8,
        out_zp: 128,
        cout: 10,
        weights: Some(Weights {
            w_q: (0..10 * 6 * 6 * 8).map(|_| rng.u8()).collect(),
            k_dim: 6 * 6 * 8,
            b_q: vec![0; 10],
            s_w: 1.0,
            zp_w: 3,
        }),
        ..Node::default()
    };
    Model { name: "tiny".into(), n_classes: 10, nodes: vec![input, conv, dense] }
}

/// [`tiny_model`] whose final dequant scale is NaN, so every logit comes out
/// NaN — the adversarial input for the service's NaN-hardening tests (the
/// requantize path saturates NaN to 0 without panicking; the NaN appears in
/// the dequantized logits).
pub fn nan_logit_model() -> Model {
    let mut m = tiny_model();
    let last = m.nodes.last_mut().unwrap();
    last.out_scale = f32::NAN;
    m
}

/// Deterministic random image matching [`tiny_model`]'s input shape.
pub fn tiny_image(seed: u64) -> Tensor {
    let mut rng = Rng::new(seed);
    Tensor::from_data(6, 6, 3, (0..6 * 6 * 3).map(|_| rng.u8()).collect())
}
