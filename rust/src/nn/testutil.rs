//! Shared synthetic-model builders for unit tests (engine + coordinator).
//!
//! Everything here is artifact-free: the models are generated from a seeded
//! RNG, so service/concurrency tests run on every machine instead of
//! skipping when `make artifacts` has not been run.

use crate::nn::graph::{Model, Node, Op, Tensor, Weights};
use crate::util::rng::Rng;

/// Tiny but non-trivial net: input(6,6,3) → conv3x3(8, relu) → dense(10).
/// Output scales are chosen so requantized values stay inside the u8 range;
/// 10 classes match the synth10 label space used by the service tests.
pub fn tiny_model() -> Model {
    let mut rng = Rng::new(0x71E5);
    let input = Node { out_shape: (6, 6, 3), ..Node::default() };
    let conv = Node {
        op: Op::Conv,
        relu: true,
        inputs: vec![0],
        out_shape: (6, 6, 8),
        out_scale: 4096.0,
        cout: 8,
        ksize: 3,
        pad: 1,
        weights: Some(Weights {
            w_q: (0..8 * 27).map(|_| rng.u8()).collect(),
            k_dim: 27,
            b_q: vec![0; 8],
            s_w: 1.0,
            zp_w: 7,
        }),
        ..Node::default()
    };
    let dense = Node {
        op: Op::Dense,
        inputs: vec![1],
        out_shape: (1, 1, 10),
        // mult = s_w * s_in / s_out keeps the dense accumulators inside the
        // u8 range around zp = 128 (same sizing rationale as the engine's
        // toy model, scaled to the 6x6x8 = 288-wide reduction).
        out_scale: 1.6e8,
        out_zp: 128,
        cout: 10,
        weights: Some(Weights {
            w_q: (0..10 * 6 * 6 * 8).map(|_| rng.u8()).collect(),
            k_dim: 6 * 6 * 8,
            b_q: vec![0; 10],
            s_w: 1.0,
            zp_w: 3,
        }),
        ..Node::default()
    };
    Model { name: "tiny".into(), n_classes: 10, nodes: vec![input, conv, dense] }
}

/// [`tiny_model`] whose final dequant scale is NaN, so every logit comes out
/// NaN — the adversarial input for the service's NaN-hardening tests (the
/// requantize path saturates NaN to 0 without panicking; the NaN appears in
/// the dequantized logits).
pub fn nan_logit_model() -> Model {
    let mut m = tiny_model();
    let last = m.nodes.last_mut().unwrap();
    last.out_scale = f32::NAN;
    m
}

/// input(1,1,k) → dense(2): one MAC layer with reduction depth `k`.
/// The K-headroom regression tests (engine + service) size `k` just past
/// [`crate::nn::gemm::MAX_K_POS`] / [`crate::nn::gemm::MAX_K_NEG`] to
/// assert oversized layers are typed errors, not worker panics.
pub fn big_k_model(k: usize) -> Model {
    let input = Node { out_shape: (1, 1, k), ..Node::default() };
    let dense = Node {
        op: Op::Dense,
        inputs: vec![0],
        out_shape: (1, 1, 2),
        out_scale: 1.0e9,
        out_zp: 128,
        cout: 2,
        weights: Some(Weights {
            w_q: vec![1u8; 2 * k],
            k_dim: k,
            b_q: vec![0; 2],
            s_w: 1.0,
            zp_w: 0,
        }),
        ..Node::default()
    };
    Model { name: "bigk".into(), n_classes: 2, nodes: vec![input, dense] }
}

/// All-ones image matching [`big_k_model`]'s input shape.
pub fn big_k_image(k: usize) -> Tensor {
    Tensor::from_data(1, 1, k, vec![1u8; k])
}

/// Deterministic random image matching [`tiny_model`]'s input shape.
pub fn tiny_image(seed: u64) -> Tensor {
    let mut rng = Rng::new(seed);
    Tensor::from_data(6, 6, 3, (0..6 * 6 * 3).map(|_| rng.u8()).collect())
}

/// Random tiny conv net: input → conv (random ksize/stride/pad, relu)
/// → grouped 1×1/3×3 conv → dense. Exercises pad/stride/group edges and
/// nonzero input zero-points; scale choices are uncritical for the
/// bit-identity properties (batched vs per-image, policy vs uniform) —
/// both paths share them bit for bit. Shared by the engine and policy
/// property suites (3 MAC layers).
pub fn rand_model(rng: &mut Rng) -> Model {
    let h = 4 + rng.below(5) as usize;
    let w = 4 + rng.below(5) as usize;
    let c = 1 + rng.below(3) as usize;
    let input = Node {
        op: Op::Input,
        relu: false,
        inputs: vec![],
        out_shape: (h, w, c),
        out_scale: 1.0,
        out_zp: rng.below(12) as i32,
        cout: 0,
        ksize: 0,
        stride: 1,
        pad: 0,
        groups: 1,
        weights: None,
    };
    let k1 = if rng.below(2) == 0 { 1 } else { 3 };
    let pad1 = if k1 == 3 { rng.below(2) as usize } else { 0 };
    let s1 = 1 + rng.below(2) as usize;
    let cout1 = 4 + 2 * rng.below(3) as usize; // 4, 6, 8 (even for groups)
    let oh1 = (h + 2 * pad1 - k1) / s1 + 1;
    let ow1 = (w + 2 * pad1 - k1) / s1 + 1;
    let kdim1 = k1 * k1 * c;
    let conv1 = Node {
        op: Op::Conv,
        relu: rng.below(2) == 1,
        inputs: vec![0],
        out_shape: (oh1, ow1, cout1),
        out_scale: 4096.0,
        out_zp: rng.below(4) as i32,
        cout: cout1,
        ksize: k1,
        stride: s1,
        pad: pad1,
        groups: 1,
        weights: Some(Weights {
            w_q: (0..cout1 * kdim1).map(|_| rng.u8()).collect(),
            k_dim: kdim1,
            b_q: (0..cout1).map(|_| rng.range_i64(-300, 300) as i32).collect(),
            s_w: 1.0,
            zp_w: rng.below(20) as i32,
        }),
    };
    let k2 = if rng.below(2) == 0 { 1 } else { 3 };
    let pad2 = if k2 == 3 { 1 } else { 0 };
    let g2 = 2usize;
    let cout2 = 8usize;
    let kdim2 = k2 * k2 * (cout1 / g2);
    let conv2 = Node {
        op: Op::Conv,
        relu: rng.below(2) == 1,
        inputs: vec![1],
        out_shape: (oh1, ow1, cout2),
        out_scale: 4.0e7,
        out_zp: 128,
        cout: cout2,
        ksize: k2,
        stride: 1,
        pad: pad2,
        groups: g2,
        weights: Some(Weights {
            w_q: (0..cout2 * kdim2).map(|_| rng.u8()).collect(),
            k_dim: kdim2,
            b_q: (0..cout2).map(|_| rng.range_i64(-300, 300) as i32).collect(),
            s_w: 1.0,
            zp_w: rng.below(20) as i32,
        }),
    };
    let kdim3 = oh1 * ow1 * cout2;
    let dense = Node {
        op: Op::Dense,
        relu: false,
        inputs: vec![2],
        out_shape: (1, 1, 5),
        out_scale: 7.0e7,
        out_zp: 128,
        cout: 5,
        ksize: 0,
        stride: 1,
        pad: 0,
        groups: 1,
        weights: Some(Weights {
            w_q: (0..5 * kdim3).map(|_| rng.u8()).collect(),
            k_dim: kdim3,
            b_q: vec![0; 5],
            s_w: 1.0,
            zp_w: rng.below(10) as i32,
        }),
    };
    Model {
        name: "rand".into(),
        n_classes: 5,
        nodes: vec![input, conv1, conv2, dense],
    }
}

/// A random image matching `model`'s input shape.
pub fn rand_image(model: &Model, rng: &mut Rng) -> Tensor {
    let (h, w, c) = model.nodes[0].out_shape;
    Tensor::from_data(h, w, c, (0..h * w * c).map(|_| rng.u8()).collect())
}
