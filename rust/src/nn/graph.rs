//! Network IR: a flat topological list of nodes (mirror of python nets.py)
//! plus the quantized-tensor type.

/// Operator kind (byte codes fixed by the .cvm format).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    Input,
    Conv,
    Maxpool,
    Gap,
    Dense,
    Add,
    Concat,
    Shuffle,
}

impl Op {
    pub fn from_code(c: u8) -> Option<Op> {
        Some(match c {
            0 => Op::Input,
            1 => Op::Conv,
            2 => Op::Maxpool,
            3 => Op::Gap,
            4 => Op::Dense,
            5 => Op::Add,
            6 => Op::Concat,
            7 => Op::Shuffle,
            _ => return None,
        })
    }
}

/// Per-node weight payload (conv/dense only).
#[derive(Clone, Debug, Default)]
pub struct Weights {
    /// Quantized weights, row-major [cout][k*k*cin_per_group] (conv) or
    /// [nout][nin] (dense).
    pub w_q: Vec<u8>,
    /// Reduction length per output row.
    pub k_dim: usize,
    /// Bias in the i32 accumulator domain.
    pub b_q: Vec<i32>,
    pub s_w: f32,
    pub zp_w: i32,
}

/// One graph node.
///
/// `Default` gives a bare Input node (unit scale, zero zero-point, no
/// weights) so synthetic-model builders in tests/benches can spell out
/// only the fields that matter: `Node { op: Op::Maxpool, inputs: vec![1],
/// out_shape, ..Node::default() }`.
#[derive(Clone, Debug)]
pub struct Node {
    pub op: Op,
    pub relu: bool,
    pub inputs: Vec<usize>,
    /// Output shape (h, w, c); dense = (1, 1, nout).
    pub out_shape: (usize, usize, usize),
    /// Output quantization.
    pub out_scale: f32,
    pub out_zp: i32,
    // conv params
    pub cout: usize,
    pub ksize: usize,
    pub stride: usize,
    pub pad: usize,
    pub groups: usize,
    pub weights: Option<Weights>,
}

impl Default for Node {
    fn default() -> Node {
        Node {
            op: Op::Input,
            relu: false,
            inputs: vec![],
            out_shape: (0, 0, 0),
            out_scale: 1.0,
            out_zp: 0,
            cout: 0,
            ksize: 0,
            stride: 1,
            pad: 0,
            groups: 1,
            weights: None,
        }
    }
}

/// A loaded quantized model.
#[derive(Clone, Debug)]
pub struct Model {
    pub name: String,
    pub n_classes: usize,
    pub nodes: Vec<Node>,
}

impl Model {
    /// Total multiply-accumulate count for one inference (conv + dense).
    pub fn macs(&self) -> u64 {
        self.nodes
            .iter()
            .filter_map(|n| {
                let w = n.weights.as_ref()?;
                let (h, ww, c) = n.out_shape;
                Some((h * ww * c) as u64 * w.k_dim as u64)
            })
            .sum()
    }

    /// Parameter count (weights + biases).
    pub fn params(&self) -> u64 {
        self.nodes
            .iter()
            .filter_map(|n| n.weights.as_ref())
            .map(|w| (w.w_q.len() + 4 * w.b_q.len()) as u64)
            .sum()
    }

    /// Number of MAC layers (conv + dense).
    pub fn mac_layers(&self) -> usize {
        self.nodes.iter().filter(|n| n.weights.is_some()).count()
    }

    /// MAC count of each MAC layer, in the same topological order as
    /// `mac_node_indices` — the weights for policy-level power estimates
    /// (`LayerPolicy::power_norm`, the layerwise greedy search).
    pub fn mac_layer_macs(&self) -> Vec<u64> {
        self.nodes
            .iter()
            .filter_map(|n| {
                let w = n.weights.as_ref()?;
                let (h, ww, c) = n.out_shape;
                Some((h * ww * c) as u64 * w.k_dim as u64)
            })
            .collect()
    }

    /// Reduction length (`k_dim`) of each MAC layer, in the same topological
    /// order as `mac_node_indices` — what the paired power estimate weighs
    /// its even/odd partitions by (an odd k gives the even partition
    /// `ceil(k/2)` of the layer's MACs, not half).
    pub fn mac_layer_kdims(&self) -> Vec<usize> {
        self.nodes
            .iter()
            .filter_map(|n| n.weights.as_ref().map(|w| w.k_dim))
            .collect()
    }

    /// Node indices of the MAC layers in topological order — the key space
    /// of the engine's [`crate::nn::plan::PlanCache`] (plan `i` of a
    /// layerwise config belongs to node `mac_node_indices()[i]`).
    pub fn mac_node_indices(&self) -> Vec<usize> {
        self.nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| n.weights.is_some())
            .map(|(i, _)| i)
            .collect()
    }

    /// Input tensor shape (h, w, c) — the out_shape of the graph's Input
    /// node. Serving workers validate each request against this *before*
    /// fusing it into a batch, so one malformed image fails alone instead
    /// of poisoning the whole batched forward.
    pub fn input_shape(&self) -> (usize, usize, usize) {
        self.nodes
            .iter()
            .find(|n| n.op == Op::Input)
            .map(|n| n.out_shape)
            .unwrap_or((0, 0, 0))
    }

    /// Upper bound on the scratch-arena sizes any layer of this model needs:
    /// (max k_dim × n_cols panel, max rows × n_cols accumulator). Lets
    /// serving loops pre-grow a [`crate::nn::plan::Scratch`] so even the
    /// first request allocates nothing on the GEMM path.
    pub fn max_gemm_footprint(&self) -> (usize, usize) {
        let mut panel = 0usize;
        let mut acc = 0usize;
        for n in &self.nodes {
            let Some(w) = &n.weights else { continue };
            let (oh, ow, _) = n.out_shape;
            let n_cols = if n.op == Op::Dense { 1 } else { oh * ow };
            panel = panel.max(w.k_dim * n_cols);
            let rows_per_group = n.cout.max(1) / n.groups.max(1);
            acc = acc.max(rows_per_group * n_cols);
        }
        (panel, acc)
    }
}

/// A quantized activation tensor, HWC row-major.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Tensor {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub data: Vec<u8>,
}

impl Tensor {
    pub fn new(h: usize, w: usize, c: usize) -> Tensor {
        Tensor { h, w, c, data: vec![0; h * w * c] }
    }

    pub fn from_data(h: usize, w: usize, c: usize, data: Vec<u8>) -> Tensor {
        assert_eq!(data.len(), h * w * c);
        Tensor { h, w, c, data }
    }

    #[inline]
    pub fn at(&self, y: usize, x: usize, ch: usize) -> u8 {
        self.data[(y * self.w + x) * self.c + ch]
    }

    #[inline]
    pub fn set(&mut self, y: usize, x: usize, ch: usize, v: u8) {
        self.data[(y * self.w + x) * self.c + ch] = v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn op_codes_roundtrip() {
        for c in 0..8u8 {
            assert!(Op::from_code(c).is_some());
        }
        assert!(Op::from_code(99).is_none());
    }

    #[test]
    fn tensor_indexing_is_hwc_row_major() {
        let mut t = Tensor::new(2, 3, 4);
        t.set(1, 2, 3, 42);
        assert_eq!(t.data[(1 * 3 + 2) * 4 + 3], 42);
        assert_eq!(t.at(1, 2, 3), 42);
    }

    #[test]
    fn macs_counts_conv_work() {
        let node = Node {
            op: Op::Conv,
            relu: true,
            inputs: vec![0],
            out_shape: (4, 4, 8),
            out_scale: 1.0,
            out_zp: 0,
            cout: 8,
            ksize: 3,
            stride: 1,
            pad: 1,
            groups: 1,
            weights: Some(Weights {
                w_q: vec![0; 8 * 27],
                k_dim: 27,
                b_q: vec![0; 8],
                s_w: 1.0,
                zp_w: 0,
            }),
        };
        let input = Node {
            op: Op::Input,
            relu: false,
            inputs: vec![],
            out_shape: (4, 4, 3),
            out_scale: 1.0,
            out_zp: 0,
            cout: 0,
            ksize: 0,
            stride: 1,
            pad: 0,
            groups: 1,
            weights: None,
        };
        let m = Model { name: "t".into(), n_classes: 2, nodes: vec![input, node] };
        assert_eq!(m.macs(), 4 * 4 * 8 * 27);
        assert_eq!(m.mac_layer_macs(), vec![4 * 4 * 8 * 27]);
        assert_eq!(m.mac_layers(), 1);
        assert_eq!(m.params(), (8 * 27 + 32) as u64);
        assert_eq!(m.mac_node_indices(), vec![1]);
        let (panel, acc) = m.max_gemm_footprint();
        assert_eq!(panel, 27 * 16);
        assert_eq!(acc, 8 * 16);
        assert_eq!(m.input_shape(), (4, 4, 3));
    }
}
