//! .cvm model binary parser (format: python/compile/export.py docstring).

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::graph::{Model, Node, Op, Weights};
use crate::util::io::ByteReader;

/// Load a quantized model from a .cvm file.
pub fn load_model(path: &Path) -> Result<Model> {
    let buf = std::fs::read(path)
        .with_context(|| format!("reading model {}", path.display()))?;
    parse_model(&buf).with_context(|| format!("parsing model {}", path.display()))
}

pub fn parse_model(buf: &[u8]) -> Result<Model> {
    let mut r = ByteReader::new(buf);
    r.magic(b"CVM1")?;
    let name = r.string()?;
    let n_classes = r.u16()? as usize;
    let n_nodes = r.u32()? as usize;
    if n_nodes > 10_000 {
        bail!("implausible node count {n_nodes}");
    }
    let mut nodes: Vec<Node> = Vec::with_capacity(n_nodes);
    for idx in 0..n_nodes {
        let op = Op::from_code(r.u8()?)
            .with_context(|| format!("node {idx}: bad op code"))?;
        let relu = r.u8()? != 0;
        let n_in = r.u16()? as usize;
        let inputs: Vec<usize> =
            r.vec_u32(n_in)?.into_iter().map(|x| x as usize).collect();
        for &i in &inputs {
            if i >= idx {
                bail!("node {idx}: input {i} not topologically earlier");
            }
        }
        let oh = r.u32()? as usize;
        let ow = r.u32()? as usize;
        let oc = r.u32()? as usize;
        let out_scale = r.f32()?;
        let out_zp = r.i32()?;
        let mut node = Node {
            op,
            relu,
            inputs,
            out_shape: (oh, ow, oc),
            out_scale,
            out_zp,
            cout: 0,
            ksize: 0,
            stride: 1,
            pad: 0,
            groups: 1,
            weights: None,
        };
        match op {
            Op::Conv => {
                node.cout = r.u16()? as usize;
                node.ksize = r.u8()? as usize;
                node.stride = r.u8()? as usize;
                node.pad = r.u8()? as usize;
                let _rsv = r.u8()?;
                node.groups = r.u16()? as usize;
                let s_w = r.f32()?;
                let zp_w = r.i32()?;
                // cin_per_group from the producing node's channel count
                let cin = nodes[node.inputs[0]]
                    .out_shape
                    .2
                    / node.groups;
                let k_dim = node.ksize * node.ksize * cin;
                let w_q = r.bytes(node.cout * k_dim)?;
                let b_q = r.vec_i32(node.cout)?;
                node.weights = Some(Weights { w_q, k_dim, b_q, s_w, zp_w });
            }
            Op::Dense => {
                let nout = r.u32()? as usize;
                let nin = r.u32()? as usize;
                let s_w = r.f32()?;
                let zp_w = r.i32()?;
                let w_q = r.bytes(nout * nin)?;
                let b_q = r.vec_i32(nout)?;
                node.cout = nout;
                node.weights = Some(Weights { w_q, k_dim: nin, b_q, s_w, zp_w });
            }
            Op::Shuffle => {
                node.groups = r.u16()? as usize;
            }
            _ => {}
        }
        nodes.push(node);
    }
    if r.remaining() != 0 {
        bail!("{} trailing bytes after last node", r.remaining());
    }
    Ok(Model { name, n_classes, nodes })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::artifacts_dir;

    fn models_available() -> bool {
        artifacts_dir().join("models").is_dir()
    }

    #[test]
    fn loads_all_exported_models() {
        if !models_available() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let dir = artifacts_dir().join("models");
        let mut count = 0;
        for entry in std::fs::read_dir(&dir).unwrap() {
            let path = entry.unwrap().path();
            if path.extension().map(|e| e == "cvm").unwrap_or(false) {
                let m = load_model(&path).unwrap();
                assert!(m.nodes.len() > 3, "{}", m.name);
                assert!(m.macs() > 100_000, "{}: {} MACs", m.name, m.macs());
                assert!(m.n_classes == 10 || m.n_classes == 100);
                // every conv/dense got weights; shapes sane
                for n in &m.nodes {
                    if let Some(w) = &n.weights {
                        assert_eq!(w.b_q.len(), n.cout.max(n.out_shape.2));
                        assert!(!w.w_q.is_empty());
                        assert!(w.s_w > 0.0);
                    }
                }
                count += 1;
            }
        }
        assert_eq!(count, 12, "expected 12 exported models");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_model(b"XXXX").is_err());
        assert!(parse_model(b"CVM1\x00").is_err());
    }

    #[test]
    fn rejects_forward_references() {
        // Construct a minimal model whose node 0 references node 5.
        let mut buf = Vec::new();
        buf.extend_from_slice(b"CVM1");
        buf.extend_from_slice(&1u16.to_le_bytes());
        buf.push(b't');
        buf.extend_from_slice(&10u16.to_le_bytes());
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.push(5); // op add
        buf.push(0);
        buf.extend_from_slice(&1u16.to_le_bytes());
        buf.extend_from_slice(&5u32.to_le_bytes()); // bad input
        buf.extend_from_slice(&[0u8; 20]);
        assert!(parse_model(&buf).is_err());
    }
}
