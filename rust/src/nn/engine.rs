//! Graph executor: runs a loaded [`Model`] on quantized images.
//!
//! Bit-exact mirror of python `model.QuantModel.forward` — identical
//! rounding (`round-half-away-from-zero` on f64), identical integer
//! arithmetic, asserted by golden-vector integration tests
//! (rust/tests/golden.rs). The engine also exposes a systolic-array mode
//! that routes every MAC GEMM through the cycle-level simulator and returns
//! aggregate toggle statistics.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use super::gemm::{
    approx_gemm_planned_with_kernel, paired_gemm_planned_with_kernel, GemmCtx, GemmKind,
};
use super::graph::{Model, Node, Op, Tensor, Weights};
use super::kernel::{self, Kernel};
use super::plan::{LayerPlan, PairedPlan, PlanCache, PlanKey, Scratch};
use super::policy::{
    LayerAssignment, LayerPoint, LayerPolicy, PairedPoint, SharedPolicy, MAX_M,
};
use crate::approx::{bitmodel, Family, MulLut, Polarity};
use crate::cv::{self, CvConstants};
use crate::runtime::{TileGemm, Variant};
use crate::systolic::{MulPoint, SystolicArray, ToggleStats};
use crate::util::sync::lock_clean;
use crate::util::threadpool::configured_workers;

/// Forward-pass configuration.
#[derive(Clone, Debug)]
pub struct ForwardOpts {
    pub family: Family,
    pub m: u32,
    pub use_cv: bool,
    pub kind: GemmKind,
    /// Layer-wise approximation (ALWANN-style extension, DESIGN.md §12):
    /// per-MAC-layer m override. Because `m` is a *runtime* input of both
    /// the engines and the AOT artifacts, mixed-m operation needs no
    /// recompilation — unlike heterogeneous-hardware approaches [9].
    /// `None` entries (or a missing vec) fall back to `self.m`;
    /// m = 0 runs that layer exact.
    pub m_per_layer: Option<std::sync::Arc<Vec<u32>>>,
    /// Fully heterogeneous per-layer policy: when set, each MAC layer
    /// resolves its own `(family, m, use_cv)` from the policy and every
    /// uniform field above (plus `m_per_layer`) is ignored. Validated
    /// against the model's layer count at forward entry — a mismatched
    /// policy returns `Err` instead of running a wrong configuration.
    pub policy: Option<SharedPolicy>,
    /// Optional error-proxy sink: when set, every CV-running MAC layer
    /// samples mean |V| / |G*| magnitudes out of the epilogue into the
    /// sampler (V is already computed there, so the probe is a handful of
    /// reads per GEMM). Strictly read-only on the accumulator — outputs
    /// are bit-identical with or without a sampler attached (tested). The
    /// QoS telemetry attaches one shared sampler across the worker pool.
    pub cv_proxy: Option<Arc<CvProxySampler>>,
}

impl Default for ForwardOpts {
    fn default() -> Self {
        ForwardOpts {
            family: Family::Exact,
            m: 0,
            use_cv: false,
            kind: GemmKind::Identity,
            m_per_layer: None,
            policy: None,
            cv_proxy: None,
        }
    }
}

/// Per-layer accumulator cell of a [`CvProxySampler`] (all-atomic: workers
/// record lock-free, the governor drains with `swap`).
#[derive(Debug, Default)]
struct ProxyCell {
    /// Σ |V| over the sampled epilogue entries.
    num: AtomicU64,
    /// Σ |G*| (final integer accumulator magnitude) over the same entries.
    den: AtomicU64,
    /// Sample count.
    n: AtomicU64,
}

/// Lock-free per-layer CV-magnitude error proxy: mean |V| / |G*| sampled
/// from the CV epilogue of each approximate layer. Because the control
/// variate V = C·ΣX + C₀ is the *online estimate of the accumulated
/// multiplier error* (the quantity the MAC⁺ column cancels), its magnitude
/// relative to the final accumulator G* is a free per-inference error
/// signal: it grows with the approximation level m and with how much error
/// the live activations actually excite — exactly what an adaptive
/// governor needs to bound, without any labeled data at serving time.
///
/// One sampler is shared across a whole worker pool (attach via
/// [`ForwardOpts::cv_proxy`]); `drain` returns the window since the last
/// drain and resets, so a polling governor sees sliding-window ratios.
/// Exact layers record nothing (their error is identically zero).
#[derive(Debug)]
pub struct CvProxySampler {
    layers: Vec<ProxyCell>,
}

/// One drained proxy window.
#[derive(Clone, Debug)]
pub struct CvProxyWindow {
    /// Mean |V|/|G*| per MAC layer (0.0 for layers that recorded nothing —
    /// exact layers, or layers outside the sampled batches).
    pub per_layer: Vec<f64>,
    /// Pooled ratio across every layer (Σ|V| / Σ|G*| over all samples).
    pub aggregate: f64,
    /// Total epilogue entries sampled in this window.
    pub samples: u64,
}

impl CvProxySampler {
    /// Sampler for a model with `n_layers` MAC layers.
    pub fn new(n_layers: usize) -> CvProxySampler {
        CvProxySampler {
            layers: (0..n_layers).map(|_| ProxyCell::default()).collect(),
        }
    }

    /// Number of per-layer cells.
    pub fn layers(&self) -> usize {
        self.layers.len()
    }

    /// Accumulate `n` sampled entries for MAC layer `layer` (out-of-range
    /// layers are ignored — the sampler stays safe across model mixups).
    pub fn record(&self, layer: usize, abs_v: u64, abs_acc: u64, n: u64) {
        if let Some(cell) = self.layers.get(layer) {
            cell.num.fetch_add(abs_v, Ordering::Relaxed);
            cell.den.fetch_add(abs_acc, Ordering::Relaxed);
            cell.n.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Take the raw per-layer sums accumulated since the last drain and
    /// reset them: `(Σ|V|, Σ|G*|, n)` per MAC layer. The fault monitor uses
    /// this on a batch-local sampler so it can band-check one batch and then
    /// re-`record` the same sums into the pool-shared telemetry sampler
    /// without disturbing the governor's window.
    pub fn drain_raw(&self) -> Vec<(u64, u64, u64)> {
        self.layers
            .iter()
            .map(|c| {
                (
                    c.num.swap(0, Ordering::Relaxed),
                    c.den.swap(0, Ordering::Relaxed),
                    c.n.swap(0, Ordering::Relaxed),
                )
            })
            .collect()
    }

    /// Take the window accumulated since the last drain and reset it.
    pub fn drain(&self) -> CvProxyWindow {
        let (mut tn, mut td, mut ts) = (0u64, 0u64, 0u64);
        let per_layer = self
            .layers
            .iter()
            .map(|c| {
                let num = c.num.swap(0, Ordering::Relaxed);
                let den = c.den.swap(0, Ordering::Relaxed);
                ts += c.n.swap(0, Ordering::Relaxed);
                tn += num;
                td += den;
                if den > 0 {
                    num as f64 / den as f64
                } else {
                    0.0
                }
            })
            .collect();
        CvProxyWindow {
            per_layer,
            aggregate: if td > 0 { tn as f64 / td as f64 } else { 0.0 },
            samples: ts,
        }
    }
}

impl ForwardOpts {
    pub fn exact() -> Self {
        Self::default()
    }

    pub fn approx(family: Family, m: u32, use_cv: bool) -> Self {
        ForwardOpts { family, m, use_cv, ..Self::default() }
    }

    /// Layer-wise configuration: `ms[i]` is the approximation level of the
    /// i-th MAC layer (conv/dense, in topological order).
    pub fn layerwise(family: Family, ms: Vec<u32>, use_cv: bool) -> Self {
        ForwardOpts {
            family,
            use_cv,
            m_per_layer: Some(std::sync::Arc::new(ms)),
            ..Self::default()
        }
    }

    /// Fully heterogeneous configuration from a [`LayerPolicy`]: layer `i`
    /// runs at `policy.assignment(i)` — a single point or an even/odd
    /// pairing. A policy whose every layer carries the same point is
    /// bit-identical to the uniform [`ForwardOpts::approx`] path
    /// (property-tested in the engine suite).
    pub fn with_policy(policy: SharedPolicy) -> Self {
        ForwardOpts { policy: Some(policy), ..Self::default() }
    }

    /// Effective m for MAC layer ordinal `mac_idx`.
    pub fn m_for(&self, mac_idx: usize) -> u32 {
        match &self.m_per_layer {
            Some(ms) => ms.get(mac_idx).copied().unwrap_or(self.m),
            None => self.m,
        }
    }

    /// Effective assignment for MAC layer ordinal `mac_idx` (normalized:
    /// `m == 0` collapses to the exact point) — the single source of truth
    /// both forward paths resolve plans, LUTs and the CV epilogue from.
    /// Uniform opts are the trivial single-point policy (negative
    /// polarity); paired layers only ever come from a [`LayerPolicy`].
    pub fn assignment_for(&self, mac_idx: usize) -> LayerAssignment {
        match &self.policy {
            Some(p) => p.assignment(mac_idx),
            None => LayerAssignment::Point(
                LayerPoint::new(self.family, self.m_for(mac_idx), self.use_cv)
                    .normalized(),
            ),
        }
    }
}

/// Deterministic round-half-away-from-zero (mirror of quant.round_half_away).
#[inline]
pub fn round_half_away(x: f64) -> f64 {
    x.signum() * (x.abs() + 0.5).floor()
}

/// i64 accumulator -> uint8: clamp(round(acc*mult) + zp, 0, 255).
#[inline]
fn requantize(acc: i64, mult: f64, zp: i32) -> u8 {
    let q = round_half_away(acc as f64 * mult) + zp as f64;
    q.clamp(0.0, 255.0) as u8
}

/// The inference engine for one model. Holds per-(family, m) LUTs lazily
/// plus the [`PlanCache`] of per-layer weight-side precomputations: masked
/// panels, Σw and CV constants are built at most once per (layer, family, m)
/// and reused across every image (tested by `plan_built_once_across_forwards`).
/// With a heterogeneous [`LayerPolicy`] every layer resolves its own plan
/// (and LUT, when one is prepared) from the same caches — mixed-m serving
/// shares them exactly like uniform serving does.
pub struct Engine {
    pub model: Model,
    /// Prepared LUTs, one per distinct (family, m, polarity) — a mixed or
    /// paired policy can route every approximate point through its own
    /// table. Registry-style (interior-mutable, `Arc`-shared tables) so the
    /// fault subsystem can verify, corrupt (chaos) and heal tables on a
    /// shared engine while workers keep serving.
    luts: LutRegistry,
    systolic: Option<SystolicArray>,
    pjrt: Option<(Arc<TileGemm>, Variant)>,
    plans: PlanCache,
    /// The compute backend every native GEMM on this engine runs — captured
    /// at construction from [`kernel::active`] (`CVAPPROX_KERNEL`), or
    /// pinned explicitly via [`Engine::with_kernel`] (what the differential
    /// kernel axis and the bench scalar-vs-SIMD rows use).
    kernel: &'static dyn Kernel,
}

/// Interior-mutable LUT store. The generation counter has the same contract
/// as `PlanCache::generation`: bumped on runtime *mutations* of table
/// contents (corruption injection, healing, replacement via `attach_lut`),
/// never on first-insert warming — so a serving worker can snapshot
/// `Engine::integrity_generation` around a forward and know whether any
/// table it may have read changed underneath it.
#[derive(Default)]
struct LutRegistry {
    tables: Mutex<Vec<Arc<MulLut>>>,
    generation: AtomicU64,
}

impl LutRegistry {
    fn lookup(&self, family: Family, m: u32, pol: Polarity) -> Option<Arc<MulLut>> {
        lock_clean(&self.tables)
            .iter()
            .find(|l| l.family == family && l.m == m && l.polarity == pol)
            .cloned()
    }

    fn insert_if_absent(&self, family: Family, m: u32, pol: Polarity) {
        if family == Family::Exact {
            return;
        }
        let mut tables = lock_clean(&self.tables);
        if tables.iter().any(|l| l.family == family && l.m == m && l.polarity == pol) {
            return;
        }
        tables.push(Arc::new(MulLut::build_pol(family, m, pol)));
    }

    /// Replace (or add) the table for `lut`'s point; bumps the generation.
    fn replace(&self, lut: MulLut) {
        let mut tables = lock_clean(&self.tables);
        tables.retain(|l| (l.family, l.m, l.polarity) != (lut.family, lut.m, lut.polarity));
        tables.push(Arc::new(lut));
        self.generation.fetch_add(1, Ordering::SeqCst);
    }

    fn snapshot(&self) -> Vec<Arc<MulLut>> {
        lock_clean(&self.tables).clone()
    }

    fn generation(&self) -> u64 {
        self.generation.load(Ordering::SeqCst)
    }
}

/// Result of an engine-wide checksum sweep ([`Engine::verify_integrity`]):
/// the (family, m, polarity) of every corrupt LUT and the (node, key) of
/// every corrupt cached plan. Empty on a healthy engine.
#[derive(Clone, Debug, Default)]
pub struct IntegrityReport {
    pub luts: Vec<(Family, u32, Polarity)>,
    pub plans: Vec<(usize, PlanKey)>,
}

impl IntegrityReport {
    pub fn is_clean(&self) -> bool {
        self.luts.is_empty() && self.plans.is_empty()
    }

    /// Total number of corrupt cached items.
    pub fn dirty(&self) -> usize {
        self.luts.len() + self.plans.len()
    }
}

/// A MAC layer resolved to its executable form: the quantization context
/// plus the cached weight-side plan(s) for its assignment.
enum LayerExec {
    Uniform { ctx: GemmCtx, plan: Arc<LayerPlan> },
    Paired { pair: PairedPoint, zp_w: i64, zp_a: i64, plan: Arc<PairedPlan> },
}

impl Engine {
    pub fn new(model: Model) -> Engine {
        Engine::with_kernel(model, kernel::active())
    }

    /// Engine with an explicitly pinned compute backend (see
    /// [`kernel::scalar`] / [`kernel::simd`]). [`Engine::new`] is this with
    /// the process-wide [`kernel::active`] selection.
    pub fn with_kernel(model: Model, kr: &'static dyn Kernel) -> Engine {
        Engine {
            model,
            luts: LutRegistry::default(),
            systolic: None,
            pjrt: None,
            plans: PlanCache::new(),
            kernel: kr,
        }
    }

    /// Name of the compute backend this engine's native GEMMs run
    /// (`scalar` / `simd`).
    pub fn kernel_name(&self) -> &'static str {
        self.kernel.name()
    }

    /// Route MAC GEMMs through the PJRT runtime (the AOT XLA kernels).
    pub fn attach_pjrt(&mut self, rt: Arc<TileGemm>, variant: Variant) {
        self.pjrt = Some((rt, variant));
    }

    /// Pre-build the negative-polarity LUT for a (family, m) pair (Lut
    /// engine only). Tables accumulate — preparing several points lets a
    /// heterogeneous policy serve every layer from its matching LUT.
    pub fn prepare_lut(&mut self, family: Family, m: u32) {
        self.prepare_lut_pol(family, m, Polarity::Neg);
    }

    /// Pre-build the LUT for a (family, m, polarity) point.
    pub fn prepare_lut_pol(&mut self, family: Family, m: u32, pol: Polarity) {
        self.luts.insert_if_absent(family, m, pol);
    }

    /// Attach an externally built table — e.g. one generated from the
    /// structural [`crate::approx::bitmodel`] by the differential harness —
    /// replacing any prepared table for the same (family, m, polarity).
    /// Counts as a runtime mutation (bumps the integrity generation).
    pub fn attach_lut(&mut self, lut: MulLut) {
        self.luts.replace(lut);
    }

    /// Prepare a LUT for every distinct approximate constituent point of
    /// `policy` (both halves of each pairing).
    pub fn prepare_luts_for_policy(&mut self, policy: &LayerPolicy) {
        let points: Vec<LayerPoint> = policy.points().collect();
        for p in points {
            if p.normalized() != LayerPoint::EXACT {
                self.prepare_lut_pol(p.family, p.m, p.polarity);
            }
        }
    }

    fn lut_lookup(&self, family: Family, m: u32, pol: Polarity) -> Option<Arc<MulLut>> {
        self.luts.lookup(family, m, pol)
    }

    /// Sum of the LUT and plan mutation generations — a cheap fingerprint a
    /// worker snapshots around a forward: unchanged means no cached table
    /// the forward may have read was corrupted or healed mid-flight, so a
    /// clean checksum sweep makes the result trustworthy.
    pub fn integrity_generation(&self) -> u64 {
        self.luts.generation() + self.plans.generation()
    }

    /// Recompute every build-time checksum over the prepared LUTs and
    /// cached plans. O(cached tables); runs at batch granularity, never on
    /// the per-MAC path.
    pub fn verify_integrity(&self) -> IntegrityReport {
        let luts = self
            .luts
            .snapshot()
            .iter()
            .filter(|l| !l.verify())
            .map(|l| (l.family, l.m, l.polarity))
            .collect();
        IntegrityReport { luts, plans: self.plans.verify_all() }
    }

    /// Heal everything `verify_integrity` flags: corrupt LUTs are rebuilt
    /// from the structural bitmodel (`am_bits_pol`, proven equal to the
    /// closed forms) and replaced; poisoned plans are dropped from the
    /// cache so the next fetch rebuilds them from the model's pristine
    /// weights. Returns the number of healed items; each heal bumps the
    /// integrity generation, which forces in-flight batches to replay.
    pub fn heal_integrity(&self) -> usize {
        let report = self.verify_integrity();
        let mut healed = 0;
        for &(family, m, pol) in &report.luts {
            let fresh =
                MulLut::from_fn(family, m, pol, |w, a| bitmodel::am_bits_pol(family, pol, w, a, m));
            debug_assert!(fresh.verify());
            self.luts.replace(fresh);
            healed += 1;
        }
        healed += self.plans.invalidate(&report.plans);
        healed
    }

    /// Chaos helper: flip `bit` in `span` consecutive entries of one
    /// prepared LUT (picked deterministically by `pick`). Returns the
    /// poisoned point, or `None` when no LUTs are prepared. Bumps the
    /// integrity generation.
    pub fn corrupt_lut(
        &self,
        pick: u64,
        entry: usize,
        span: usize,
        bit: u32,
    ) -> Option<(Family, u32, Polarity)> {
        let tables = self.luts.snapshot();
        if tables.is_empty() {
            return None;
        }
        let victim = &tables[(pick % tables.len() as u64) as usize];
        let key = (victim.family, victim.m, victim.polarity);
        self.luts.replace(victim.with_flipped_bits(entry, span, bit));
        Some(key)
    }

    /// Chaos helper: bit-flip one cached plan (see `PlanCache::corrupt_one`).
    pub fn corrupt_plan(&self, pick: u64, byte: usize, bit: u32) -> Option<(usize, PlanKey)> {
        self.plans.corrupt_one(pick, byte, bit)
    }

    /// Attach a systolic array simulator (enables `forward_systolic`) at a
    /// uniform negative-polarity (family, m) point.
    pub fn prepare_systolic(&mut self, family: Family, m: u32, n: usize) {
        self.systolic = Some(SystolicArray::new(family, m, n));
    }

    /// Systolic simulator at an explicit-polarity point.
    pub fn prepare_systolic_pol(&mut self, family: Family, m: u32, pol: Polarity, n: usize) {
        self.systolic = Some(SystolicArray::new_pol(family, m, pol, n));
    }

    /// Systolic simulator with alternating even/odd multiplier columns —
    /// the hardware realization of a paired layer.
    pub fn prepare_systolic_paired(&mut self, pair: PairedPoint, n: usize) {
        let e = pair.even.normalized();
        let o = pair.odd.normalized();
        self.systolic = Some(SystolicArray::new_paired(
            MulPoint::new(e.family, e.m, e.polarity),
            MulPoint::new(o.family, o.m, o.polarity),
            n,
        ));
    }

    /// Eagerly build the layer plans for a uniform (family, m) design point
    /// (they are otherwise built lazily on the first forward). The
    /// coordinator warms plans here so request latency never pays the
    /// one-time cost.
    pub fn prepare_plans(&self, family: Family, m: u32) {
        for idx in self.model.mac_node_indices() {
            let node = &self.model.nodes[idx];
            let wrec = node.weights.as_ref().expect("mac node has weights");
            let (fam_eff, m_eff) =
                if m == 0 { (Family::Exact, 0) } else { (family, m) };
            self.plans.get_or_build(idx, fam_eff, m_eff, || {
                LayerPlan::build(fam_eff, m_eff, &wrec.w_q, wrec.b_q.len(), wrec.k_dim)
            });
        }
    }

    /// Eagerly build each layer's plan at its policy assignment (the
    /// coordinator warms mixed-m and paired serving here). Fails — without
    /// building anything — on a policy/model layer-count mismatch.
    pub fn prepare_plans_policy(&self, policy: &LayerPolicy) -> Result<()> {
        policy.validate_for(&self.model)?;
        for (mac_idx, idx) in self.model.mac_node_indices().into_iter().enumerate() {
            let node = &self.model.nodes[idx];
            let wrec = node.weights.as_ref().expect("mac node has weights");
            match policy.assignment(mac_idx) {
                LayerAssignment::Point(p) => {
                    self.plans.get_or_build_pol(idx, p.family, p.m, p.polarity, || {
                        LayerPlan::build_pol(
                            p.family,
                            p.m,
                            p.polarity,
                            &wrec.w_q,
                            wrec.b_q.len(),
                            wrec.k_dim,
                            wrec.k_dim,
                        )
                    });
                }
                LayerAssignment::Paired(pair) => {
                    self.plans.get_or_build_paired(idx, pair, || {
                        PairedPlan::build(pair, &wrec.w_q, wrec.b_q.len(), wrec.k_dim)
                    });
                }
            }
        }
        Ok(())
    }

    /// Validate the per-layer configuration against this model before any
    /// GEMM runs: a policy must match the MAC layer count, uniform /
    /// `m_per_layer` levels must be in range, and every layer's reduction
    /// depth must fit its assignment's i32-headroom ceiling
    /// ([`super::gemm::max_k_for_point`]). Returning `Err` here is what
    /// keeps a bad policy from poisoning a serving worker mid-batch — the
    /// asserts in the GEMM core are unreachable backstops once this passes.
    fn check_opts(&self, opts: &ForwardOpts) -> Result<()> {
        match &opts.policy {
            Some(p) => p.validate_for(&self.model)?,
            None => {
                for (i, k) in self.model.mac_layer_kdims().into_iter().enumerate() {
                    let m = opts.m_for(i);
                    if m > MAX_M {
                        bail!(
                            "m = {m} out of range at MAC layer {i} (max {MAX_M} \
                             for 8-bit operands)"
                        );
                    }
                    let assignment = opts.assignment_for(i);
                    let cap = assignment.max_k();
                    if k > cap {
                        bail!(
                            "MAC layer {i} has K = {k}, above the i32-headroom \
                             ceiling {cap} of {} — run this layer exact or at \
                             negative polarity",
                            assignment.describe()
                        );
                    }
                }
            }
        }
        Ok(())
    }

    /// Public entry to the same validation every forward runs at entry, so
    /// policy installers and service start-up can reject an out-of-range or
    /// oversized-K configuration with a typed error *before* any worker
    /// picks up a batch.
    pub fn validate_opts(&self, opts: &ForwardOpts) -> Result<()> {
        self.check_opts(opts)
    }

    /// How many layer plans have been built so far (a steady-state serving
    /// loop must not grow this).
    pub fn plan_builds(&self) -> usize {
        self.plans.builds()
    }

    /// Run one quantized image; returns dequantized logits.
    ///
    /// Allocates a fresh [`Scratch`] — batch/serving loops should hold one
    /// scratch per worker and call [`Engine::forward_with_scratch`] instead.
    pub fn forward(&self, img: &Tensor, opts: &ForwardOpts) -> Result<Vec<f64>> {
        let mut scratch = Scratch::new();
        self.forward_with_scratch(img, opts, &mut scratch)
    }

    /// Run one quantized image reusing a caller-owned scratch arena; the
    /// steady-state hot path (no per-GEMM heap allocations once the arena
    /// has grown to the largest layer).
    pub fn forward_with_scratch(
        &self,
        img: &Tensor,
        opts: &ForwardOpts,
        scratch: &mut Scratch,
    ) -> Result<Vec<f64>> {
        let (logits, _) = self.forward_inner(img, opts, false, scratch)?;
        Ok(logits)
    }

    /// Run a batch of images, fusing each MAC layer into **one wide GEMM**:
    /// the im2col panels of the whole batch are laid side by side into a
    /// [k × batch·oh·ow] panel and multiplied against the layer's prebuilt
    /// weight-side [`LayerPlan`] in a single planned call, so masked panels,
    /// Σw and CV constants are paid once per layer for the entire batch.
    ///
    /// Every column of the GEMM (and of the Σa/Σx/CV/zero-point epilogue) is
    /// computed independently with the same integer arithmetic as the
    /// per-image path, so the result is **bit-identical** to calling
    /// [`Engine::forward`] on each image (property-tested across families,
    /// engines and thread counts). Returns one logits vector per image.
    ///
    /// Allocates a fresh [`Scratch`]; serving workers hold one arena and
    /// call [`Engine::forward_batch_with_scratch`].
    pub fn forward_batch(
        &self,
        imgs: &[&Tensor],
        opts: &ForwardOpts,
    ) -> Result<Vec<Vec<f64>>> {
        let mut scratch = Scratch::new();
        self.forward_batch_with_scratch(imgs, opts, &mut scratch)
    }

    /// Batched forward reusing a caller-owned scratch arena (the serving hot
    /// path — no per-GEMM heap allocations once the arena has grown to the
    /// largest layer at this batch size).
    pub fn forward_batch_with_scratch(
        &self,
        imgs: &[&Tensor],
        opts: &ForwardOpts,
        scratch: &mut Scratch,
    ) -> Result<Vec<Vec<f64>>> {
        self.forward_batch_with_threads(imgs, opts, scratch, configured_workers())
    }

    /// Batched forward with an explicit GEMM worker count. Tests sweep this
    /// to assert bit-exactness across thread counts; production callers use
    /// [`Engine::forward_batch_with_scratch`], which reads
    /// `CVAPPROX_THREADS`.
    pub fn forward_batch_with_threads(
        &self,
        imgs: &[&Tensor],
        opts: &ForwardOpts,
        scratch: &mut Scratch,
        threads: usize,
    ) -> Result<Vec<Vec<f64>>> {
        self.check_opts(opts)?;
        if imgs.is_empty() {
            return Ok(Vec::new());
        }
        let nodes = &self.model.nodes;
        let mut outs: Vec<Vec<Tensor>> = Vec::with_capacity(nodes.len());
        let mut mac_idx = 0usize;
        for (i, node) in nodes.iter().enumerate() {
            let ts: Vec<Tensor> = match node.op {
                Op::Input => {
                    let (h, w, c) = node.out_shape;
                    for img in imgs {
                        if (img.h, img.w, img.c) != (h, w, c) {
                            bail!("input shape mismatch");
                        }
                    }
                    imgs.iter().map(|&t| t.clone()).collect()
                }
                Op::Conv | Op::Dense => {
                    let t = self.mac_layer_batch(
                        i, mac_idx, node, &outs, opts, scratch, threads,
                    )?;
                    mac_idx += 1;
                    t
                }
                Op::Maxpool => outs[node.inputs[0]].iter().map(maxpool2).collect(),
                Op::Gap => outs[node.inputs[0]].iter().map(gap).collect(),
                Op::Add => {
                    let (s1, z1) = out_q(nodes, node.inputs[0]);
                    let (s2, z2) = out_q(nodes, node.inputs[1]);
                    outs[node.inputs[0]]
                        .iter()
                        .zip(&outs[node.inputs[1]])
                        .map(|(a, b)| add(a, b, s1, z1, s2, z2, node))
                        .collect()
                }
                Op::Concat => (0..imgs.len())
                    .map(|b| {
                        let parts: Vec<(&Tensor, f64, i32)> = node
                            .inputs
                            .iter()
                            .map(|&j| {
                                let (s, z) = out_q(nodes, j);
                                (&outs[j][b], s, z)
                            })
                            .collect();
                        concat(&parts, node)
                    })
                    .collect(),
                Op::Shuffle => outs[node.inputs[0]]
                    .iter()
                    .map(|t| shuffle(t, node.groups))
                    .collect(),
            };
            for t in &ts {
                debug_assert_eq!(
                    (t.h, t.w, t.c),
                    node.out_shape,
                    "node {i} {:?} shape mismatch",
                    node.op
                );
            }
            outs.push(ts);
        }
        let n = nodes.last().unwrap();
        Ok(outs
            .last()
            .unwrap()
            .iter()
            .map(|t| {
                t.data
                    .iter()
                    .map(|&q| (q as f64 - n.out_zp as f64) * n.out_scale as f64)
                    .collect()
            })
            .collect())
    }

    /// One MAC layer over the whole batch: a single planned GEMM per conv
    /// group with `batch·oh·ow` output columns (dense: `batch` columns), the
    /// weight side amortized across every image via the shared [`LayerPlan`].
    #[allow(clippy::too_many_arguments)]
    fn mac_layer_batch(
        &self,
        idx: usize,
        mac_idx: usize,
        node: &Node,
        outs: &[Vec<Tensor>],
        opts: &ForwardOpts,
        scratch: &mut Scratch,
        threads: usize,
    ) -> Result<Vec<Tensor>> {
        let wrec = node.weights.as_ref().expect("mac layer has weights");
        let xs = &outs[node.inputs[0]];
        let batch = xs.len();
        let (s_in, zp_in) = out_q(&self.model.nodes, node.inputs[0]);
        let (s_out, zp_out) = (node.out_scale as f64, node.out_zp);
        let mult = wrec.s_w as f64 * s_in / s_out;
        // Each layer resolves its own assignment (uniform opts are the
        // trivial single-point policy) — and from it its own plan(s) and
        // CV epilogue.
        let exec = self.resolve_layer(idx, mac_idx, wrec, opts, zp_in);
        // The batched path never routes through the systolic simulator
        // (that is a per-image measurement mode), so toggles are discarded.
        let mut toggles = ToggleStats::default();
        let relu_floor = zp_out.clamp(0, 255) as u8;
        if node.op == Op::Dense {
            let k = wrec.k_dim;
            let nout = node.cout;
            let mut a_cols = std::mem::take(&mut scratch.a_cols);
            a_cols.clear();
            a_cols.resize(k * batch, 0);
            for (b, x) in xs.iter().enumerate() {
                debug_assert_eq!(x.data.len(), k, "dense input size");
                for (kk, &v) in x.data.iter().enumerate() {
                    a_cols[kk * batch + b] = v;
                }
            }
            let gemm_status = self.dispatch_gemm(
                &exec, 0, &wrec.w_q, &a_cols, nout, k, batch, &wrec.b_q, false,
                &mut toggles, scratch, threads,
            );
            // Return the arena before propagating any backend error, so a
            // transient failure does not throw away the grown buffer.
            scratch.a_cols = a_cols;
            gemm_status?;
            self.sample_cv_proxy(opts, &exec, mac_idx, 0, nout, batch, scratch);
            let mut res = Vec::with_capacity(batch);
            for b in 0..batch {
                let mut data = Vec::with_capacity(nout);
                for f in 0..nout {
                    let mut q = requantize(scratch.acc[f * batch + b], mult, zp_out);
                    if node.relu {
                        q = q.max(relu_floor);
                    }
                    data.push(q);
                }
                res.push(Tensor::from_data(1, 1, nout, data));
            }
            return Ok(res);
        }
        // conv (possibly grouped): one [kdim × batch·oh·ow] panel per group.
        let (oh, ow, cout) = node.out_shape;
        let g = node.groups;
        let cin = xs[0].c;
        let (cpg_in, cpg_out) = (cin / g, cout / g);
        let kdim = wrec.k_dim;
        let n_cols = oh * ow;
        let n_total = batch * n_cols;
        let mut res: Vec<Tensor> = (0..batch).map(|_| Tensor::new(oh, ow, cout)).collect();
        let mut a_cols = std::mem::take(&mut scratch.a_cols);
        a_cols.clear();
        a_cols.resize(kdim * n_total, 0);
        let mut gemm_status = Ok(());
        for gi in 0..g {
            for (b, x) in xs.iter().enumerate() {
                im2col_group(
                    x, node, gi * cpg_in, cpg_in, zp_in, n_total, b * n_cols,
                    &mut a_cols,
                );
            }
            let row0 = gi * cpg_out;
            let w_g = &wrec.w_q[row0 * kdim..(row0 + cpg_out) * kdim];
            let b_g = &wrec.b_q[row0..row0 + cpg_out];
            gemm_status = self.dispatch_gemm(
                &exec, row0, w_g, &a_cols, cpg_out, kdim, n_total, b_g, false,
                &mut toggles, scratch, threads,
            );
            if gemm_status.is_err() {
                break;
            }
            self.sample_cv_proxy(opts, &exec, mac_idx, row0, cpg_out, n_total, scratch);
            for f in 0..cpg_out {
                let ch = gi * cpg_out + f;
                for (b, out) in res.iter_mut().enumerate() {
                    let base = f * n_total + b * n_cols;
                    let arow = &scratch.acc[base..base + n_cols];
                    for (p, &acc) in arow.iter().enumerate() {
                        let mut q = requantize(acc, mult, zp_out);
                        if node.relu {
                            q = q.max(relu_floor);
                        }
                        out.data[p * cout + ch] = q;
                    }
                }
            }
        }
        // Return the arena before propagating any backend error (see dense).
        scratch.a_cols = a_cols;
        gemm_status?;
        Ok(res)
    }

    /// Run one image through the systolic simulator (hardware-faithful),
    /// returning logits and toggle statistics.
    pub fn forward_systolic(
        &self,
        img: &Tensor,
        opts: &ForwardOpts,
    ) -> Result<(Vec<f64>, ToggleStats)> {
        if self.systolic.is_none() {
            bail!("call prepare_systolic first");
        }
        let mut scratch = Scratch::new();
        self.forward_inner(img, opts, true, &mut scratch)
    }

    fn forward_inner(
        &self,
        img: &Tensor,
        opts: &ForwardOpts,
        systolic: bool,
        scratch: &mut Scratch,
    ) -> Result<(Vec<f64>, ToggleStats)> {
        self.check_opts(opts)?;
        let nodes = &self.model.nodes;
        let mut outs: Vec<Tensor> = Vec::with_capacity(nodes.len());
        let mut toggles = ToggleStats::default();
        let mut mac_idx = 0usize;
        for (i, node) in nodes.iter().enumerate() {
            let t = match node.op {
                Op::Input => {
                    let (h, w, c) = node.out_shape;
                    if (img.h, img.w, img.c) != (h, w, c) {
                        bail!("input shape mismatch");
                    }
                    img.clone()
                }
                Op::Conv | Op::Dense => {
                    let t = self.mac_layer(
                        i, mac_idx, node, &outs, opts, systolic, &mut toggles, scratch,
                    )?;
                    mac_idx += 1;
                    t
                }
                Op::Maxpool => maxpool2(&outs[node.inputs[0]]),
                Op::Gap => gap(&outs[node.inputs[0]]),
                Op::Add => {
                    let a = &outs[node.inputs[0]];
                    let b = &outs[node.inputs[1]];
                    let (s1, z1) = out_q(nodes, node.inputs[0]);
                    let (s2, z2) = out_q(nodes, node.inputs[1]);
                    add(a, b, s1, z1, s2, z2, node)
                }
                Op::Concat => {
                    let parts: Vec<(&Tensor, f64, i32)> = node
                        .inputs
                        .iter()
                        .map(|&j| {
                            let (s, z) = out_q(nodes, j);
                            (&outs[j], s, z)
                        })
                        .collect();
                    concat(&parts, node)
                }
                Op::Shuffle => shuffle(&outs[node.inputs[0]], node.groups),
            };
            debug_assert_eq!(
                (t.h, t.w, t.c),
                node.out_shape,
                "node {i} {:?} shape mismatch",
                node.op
            );
            outs.push(t);
        }
        let last = outs.last().unwrap();
        let n = nodes.last().unwrap();
        let logits = last
            .data
            .iter()
            .map(|&q| (q as f64 - n.out_zp as f64) * n.out_scale as f64)
            .collect();
        Ok((logits, toggles))
    }

    #[allow(clippy::too_many_arguments)]
    fn mac_layer(
        &self,
        idx: usize,
        mac_idx: usize,
        node: &Node,
        outs: &[Tensor],
        opts: &ForwardOpts,
        systolic: bool,
        toggles: &mut ToggleStats,
        scratch: &mut Scratch,
    ) -> Result<Tensor> {
        let wrec = node.weights.as_ref().expect("mac layer has weights");
        let x = &outs[node.inputs[0]];
        let (s_in, zp_in) = out_q(&self.model.nodes, node.inputs[0]);
        let (s_out, zp_out) = (node.out_scale as f64, node.out_zp);
        let mult = wrec.s_w as f64 * s_in / s_out;
        // Each layer resolves its own assignment (uniform opts are the
        // trivial single-point policy) and from it its own plan(s) —
        // fetched (or lazily built) once; subsequent images reuse them.
        let exec = self.resolve_layer(idx, mac_idx, wrec, opts, zp_in);
        if node.op == Op::Dense {
            let k = wrec.k_dim;
            let nout = node.cout;
            debug_assert_eq!(x.data.len(), k, "dense input size");
            self.dispatch_gemm(
                &exec, 0, &wrec.w_q, &x.data, nout, k, 1, &wrec.b_q, systolic,
                toggles, scratch, configured_workers(),
            )?;
            if !systolic {
                self.sample_cv_proxy(opts, &exec, mac_idx, 0, nout, 1, scratch);
            }
            let mut data = Vec::with_capacity(nout);
            for &a in scratch.acc.iter() {
                let mut q = requantize(a, mult, zp_out);
                if node.relu {
                    q = q.max(zp_out.clamp(0, 255) as u8);
                }
                data.push(q);
            }
            return Ok(Tensor::from_data(1, 1, nout, data));
        }
        // conv (possibly grouped)
        let (oh, ow, cout) = node.out_shape;
        let g = node.groups;
        let cin = x.c;
        let (cpg_in, cpg_out) = (cin / g, cout / g);
        let kdim = wrec.k_dim;
        let n_cols = oh * ow;
        let mut out = Tensor::new(oh, ow, cout);
        // The im2col buffer lives in the scratch arena; it is taken out for
        // the duration of the layer so the GEMM can borrow scratch mutably.
        let mut a_cols = std::mem::take(&mut scratch.a_cols);
        a_cols.clear();
        a_cols.resize(kdim * n_cols, 0);
        let mut gemm_status = Ok(());
        for gi in 0..g {
            im2col_group(x, node, gi * cpg_in, cpg_in, zp_in, n_cols, 0, &mut a_cols);
            let row0 = gi * cpg_out;
            let w_g = &wrec.w_q[row0 * kdim..(row0 + cpg_out) * kdim];
            let b_g = &wrec.b_q[row0..row0 + cpg_out];
            gemm_status = self.dispatch_gemm(
                &exec, row0, w_g, &a_cols, cpg_out, kdim, n_cols, b_g, systolic,
                toggles, scratch, configured_workers(),
            );
            if gemm_status.is_err() {
                break;
            }
            if !systolic {
                self.sample_cv_proxy(opts, &exec, mac_idx, row0, cpg_out, n_cols, scratch);
            }
            for f in 0..cpg_out {
                let ch = gi * cpg_out + f;
                for p in 0..n_cols {
                    let mut q = requantize(scratch.acc[f * n_cols + p], mult, zp_out);
                    if node.relu {
                        q = q.max(zp_out.clamp(0, 255) as u8);
                    }
                    out.data[p * cout + ch] = q;
                }
            }
        }
        // Return the arena before propagating any backend error, so a
        // transient failure does not throw away the grown buffer.
        scratch.a_cols = a_cols;
        gemm_status?;
        Ok(out)
    }

    /// Resolve one MAC layer's assignment to its executable form: the
    /// quantization context plus the cached weight-side plan(s), built on
    /// first use and shared by every subsequent image/batch.
    fn resolve_layer(
        &self,
        idx: usize,
        mac_idx: usize,
        wrec: &Weights,
        opts: &ForwardOpts,
        zp_in: i32,
    ) -> LayerExec {
        let (zp_w, zp_a) = (wrec.zp_w as i64, zp_in as i64);
        match opts.assignment_for(mac_idx) {
            LayerAssignment::Point(pt) => {
                let ctx = GemmCtx {
                    family: pt.family,
                    m: pt.m,
                    use_cv: pt.use_cv,
                    zp_w,
                    zp_a,
                };
                let plan =
                    self.plans.get_or_build_pol(idx, pt.family, pt.m, pt.polarity, || {
                        LayerPlan::build_pol(
                            pt.family,
                            pt.m,
                            pt.polarity,
                            &wrec.w_q,
                            wrec.b_q.len(),
                            wrec.k_dim,
                            wrec.k_dim,
                        )
                    });
                LayerExec::Uniform { ctx, plan }
            }
            LayerAssignment::Paired(pair) => {
                let plan = self.plans.get_or_build_paired(idx, pair, || {
                    PairedPlan::build(pair, &wrec.w_q, wrec.b_q.len(), wrec.k_dim)
                });
                LayerExec::Paired { pair, zp_w, zp_a, plan }
            }
        }
    }

    /// Sample the CV-magnitude error proxy out of the just-run epilogue:
    /// mean |V| / |G*| over a few (filter, column) probes, accumulated into
    /// `opts.cv_proxy` under this layer's MAC ordinal. Reads
    /// `scratch.sum_x`/`sum_x2` (the per-column ΣX the epilogue already
    /// computed) and `scratch.acc`; never writes, so the forward result is
    /// bit-identical with or without a sampler. Only valid right after a
    /// native (non-systolic, non-PJRT) [`Engine::dispatch_gemm`] — those
    /// backends do not populate the scratch sums.
    fn sample_cv_proxy(
        &self,
        opts: &ForwardOpts,
        exec: &LayerExec,
        mac_idx: usize,
        row0: usize,
        rows: usize,
        n: usize,
        scratch: &Scratch,
    ) {
        const MAX_ROWS: usize = 2;
        const MAX_COLS: usize = 8;
        let Some(proxy) = &opts.cv_proxy else { return };
        if self.pjrt.is_some() || rows == 0 || n == 0 {
            return;
        }
        let col_step = n.div_ceil(MAX_COLS).max(1);
        let (mut num, mut den, mut cnt) = (0u64, 0u64, 0u64);
        match exec {
            LayerExec::Uniform { ctx, plan } => {
                if !(ctx.use_cv && ctx.family != Family::Exact && ctx.m > 0) {
                    return;
                }
                for f in 0..rows.min(MAX_ROWS) {
                    let c = &plan.consts[row0 + f];
                    for p in (0..n).step_by(col_step) {
                        num += cv::v_term(c, scratch.sum_x[p]).unsigned_abs();
                        den += scratch.acc[f * n + p].unsigned_abs().max(1);
                        cnt += 1;
                    }
                }
            }
            LayerExec::Paired { pair, plan, .. } => {
                let even = pair.even.normalized();
                let odd = pair.odd.normalized();
                let cv_even = even.use_cv && even != LayerPoint::EXACT;
                let cv_odd = odd.use_cv && odd != LayerPoint::EXACT;
                if !cv_even && !cv_odd {
                    return;
                }
                for f in 0..rows.min(MAX_ROWS) {
                    for p in (0..n).step_by(col_step) {
                        if cv_even {
                            num += cv::v_term(&plan.even.consts[row0 + f], scratch.sum_x[p])
                                .unsigned_abs();
                        }
                        if cv_odd {
                            num += cv::v_term(&plan.odd.consts[row0 + f], scratch.sum_x2[p])
                                .unsigned_abs();
                        }
                        den += scratch.acc[f * n + p].unsigned_abs().max(1);
                        cnt += 1;
                    }
                }
            }
        }
        if cnt > 0 {
            proxy.record(mac_idx, num, den, cnt);
        }
    }

    /// Route one GEMM to the configured backend, leaving the [m_rows × n]
    /// i64 accumulator in `scratch.acc`. A backend failure (PJRT execution
    /// error) surfaces as `Err` so a serving worker can answer the request
    /// instead of panicking.
    #[allow(clippy::too_many_arguments)]
    fn dispatch_gemm(
        &self,
        exec: &LayerExec,
        row0: usize,
        w: &[u8],
        a: &[u8],
        m_rows: usize,
        k: usize,
        n: usize,
        bias: &[i32],
        systolic: bool,
        toggles: &mut ToggleStats,
        scratch: &mut Scratch,
        threads: usize,
    ) -> Result<()> {
        if systolic {
            if let Some(arr) = &self.systolic {
                return self.systolic_route(
                    arr, exec, row0, w, a, m_rows, k, n, bias, toggles, scratch,
                );
            }
        }
        if let Some((rt, variant)) = &self.pjrt {
            // The AOT kernels implement only the negative-polarity closed
            // forms; routing anything else through them would silently run
            // the wrong multiplier — reject instead (the native engines
            // serve every point).
            return match exec {
                LayerExec::Uniform { ctx, plan } if plan.pol == Polarity::Neg => {
                    scratch.acc =
                        pjrt_gemm(rt, *variant, ctx, plan, row0, w, a, m_rows, k, n, bias)?;
                    Ok(())
                }
                LayerExec::Uniform { .. } => bail!(
                    "positive-polarity points are not supported on the PJRT \
                     path — use the native engines"
                ),
                LayerExec::Paired { .. } => bail!(
                    "paired layers are not supported on the PJRT path — use \
                     the native engines"
                ),
            };
        }
        match exec {
            LayerExec::Uniform { ctx, plan } => {
                let lut = self.lut_lookup(ctx.family, ctx.m, plan.pol);
                approx_gemm_planned_with_kernel(
                    self.kernel,
                    if lut.is_some() { GemmKind::Lut } else { GemmKind::Identity },
                    ctx,
                    plan,
                    row0,
                    lut.as_deref(),
                    w,
                    a,
                    m_rows,
                    k,
                    n,
                    bias,
                    scratch,
                    threads,
                );
            }
            LayerExec::Paired { pair, zp_w, zp_a, plan } => {
                let even = pair.even.normalized();
                let odd = pair.odd.normalized();
                let le = self.lut_lookup(even.family, even.m, even.polarity);
                let lo = self.lut_lookup(odd.family, odd.m, odd.polarity);
                // Hardware-faithful lookup only when every approximate
                // half has its prepared table (same rule as the uniform
                // path: no silent on-demand builds on the hot path).
                let have_all = (even == LayerPoint::EXACT || le.is_some())
                    && (odd == LayerPoint::EXACT || lo.is_some());
                let kind = if have_all && (le.is_some() || lo.is_some()) {
                    GemmKind::Lut
                } else {
                    GemmKind::Identity
                };
                paired_gemm_planned_with_kernel(
                    self.kernel,
                    kind,
                    pair,
                    *zp_w,
                    *zp_a,
                    plan,
                    row0,
                    le.as_deref(),
                    lo.as_deref(),
                    w,
                    a,
                    m_rows,
                    k,
                    n,
                    bias,
                    scratch,
                    threads,
                );
            }
        }
        Ok(())
    }

    /// Route one GEMM through the cycle-level simulator, checking that the
    /// array was prepared for exactly this layer's resolved assignment — a
    /// mismatch would silently run the wrong multiplier columns, so it is
    /// an error (per-layer policies on the simulator need every layer at
    /// the prepared configuration).
    #[allow(clippy::too_many_arguments)]
    fn systolic_route(
        &self,
        arr: &SystolicArray,
        exec: &LayerExec,
        row0: usize,
        w: &[u8],
        a: &[u8],
        m_rows: usize,
        k: usize,
        n: usize,
        bias: &[i32],
        toggles: &mut ToggleStats,
        scratch: &mut Scratch,
    ) -> Result<()> {
        match exec {
            LayerExec::Uniform { ctx, plan } => {
                let want = MulPoint::new(ctx.family, ctx.m, plan.pol);
                if arr.is_paired() || arr.even != want {
                    bail!(
                        "systolic array prepared for {} but this layer resolves \
                         to {} — mixed per-layer configurations are not \
                         supported by the cycle-level simulator",
                        arr.describe(),
                        want.describe()
                    );
                }
                scratch.acc =
                    systolic_gemm(arr, ctx, plan.pol, w, a, m_rows, k, n, bias, toggles);
            }
            LayerExec::Paired { pair, zp_w, zp_a, plan } => {
                let even = pair.even.normalized();
                let odd = pair.odd.normalized();
                let want_e = MulPoint::new(even.family, even.m, even.polarity);
                let want_o = MulPoint::new(odd.family, odd.m, odd.polarity);
                if arr.even != want_e || arr.odd != want_o {
                    bail!(
                        "systolic array prepared for {} but this layer resolves \
                         to a {}/{} pairing — prepare_systolic_paired must \
                         match the layer's assignment",
                        arr.describe(),
                        want_e.describe(),
                        want_o.describe()
                    );
                }
                scratch.acc = systolic_gemm_paired(
                    arr, pair, *zp_w, *zp_a, plan, row0, w, a, m_rows, k, n, bias,
                    toggles,
                );
            }
        }
        Ok(())
    }
}

/// Route one GEMM through the PJRT runtime; the CV + zero-point epilogue is
/// applied here (shared semantics with the native engines). Per-filter Σw
/// and CV constants come from the prebuilt [`LayerPlan`] (`row0` selects the
/// conv-group window) — nothing weight-side is recomputed per image.
#[allow(clippy::too_many_arguments)]
fn pjrt_gemm(
    rt: &TileGemm,
    variant: Variant,
    ctx: &GemmCtx,
    plan: &LayerPlan,
    row0: usize,
    w: &[u8],
    a: &[u8],
    m_rows: usize,
    k: usize,
    n: usize,
    bias: &[i32],
) -> Result<Vec<i64>> {
    let (mut acc, sum_x) = rt
        .am_acc(ctx.family, variant, ctx.m, w, a, m_rows, k, n)
        .context("pjrt gemm execution")?;
    if ctx.use_cv && ctx.family != Family::Exact && ctx.m > 0 {
        for f in 0..m_rows {
            let c = &plan.consts[row0 + f];
            let orow = &mut acc[f * n..(f + 1) * n];
            for (o, &sx) in orow.iter_mut().zip(&sum_x) {
                *o += cv::v_term(c, sx);
            }
        }
    }
    let mut sum_a = vec![0i64; n];
    for kk in 0..k {
        let arow = &a[kk * n..(kk + 1) * n];
        for (sa, &av) in sum_a.iter_mut().zip(arow) {
            *sa += av as i64;
        }
    }
    let kzz = k as i64 * ctx.zp_w * ctx.zp_a;
    for f in 0..m_rows {
        let sum_w = plan.sum_w[row0 + f];
        let b = bias[f] as i64;
        let orow = &mut acc[f * n..(f + 1) * n];
        for (o, &sa) in orow.iter_mut().zip(&sum_a) {
            *o += -ctx.zp_w * sa - ctx.zp_a * sum_w + kzz + b;
        }
    }
    Ok(acc)
}

fn out_q(nodes: &[Node], i: usize) -> (f64, i32) {
    (nodes[i].out_scale as f64, nodes[i].out_zp)
}

/// im2col for one channel group of one image: fills columns
/// `col0..col0+oh·ow` of `cols` (a row-major [kdim × n_stride] panel),
/// (ky, kx, c) minor ordering, zero-point padding. `n_stride` is the
/// panel's total column count — `oh·ow` for a single image, `batch·oh·ow`
/// when a batch is fused into one wide panel. Mirrors python im2col.
#[allow(clippy::too_many_arguments)]
fn im2col_group(
    x: &Tensor,
    node: &Node,
    c0: usize,
    cpg: usize,
    zp_in: i32,
    n_stride: usize,
    col0: usize,
    cols: &mut [u8],
) {
    let k = node.ksize;
    let stride = node.stride;
    let pad = node.pad as isize;
    let (oh, ow, _) = node.out_shape;
    let zp = zp_in.clamp(0, 255) as u8;
    for ky in 0..k {
        for kx in 0..k {
            for c in 0..cpg {
                let row = ((ky * k + kx) * cpg + c) * n_stride + col0;
                for oy in 0..oh {
                    let iy = (oy * stride) as isize + ky as isize - pad;
                    for ox in 0..ow {
                        let ix = (ox * stride) as isize + kx as isize - pad;
                        let v = if iy >= 0
                            && iy < x.h as isize
                            && ix >= 0
                            && ix < x.w as isize
                        {
                            x.at(iy as usize, ix as usize, c0 + c)
                        } else {
                            zp
                        };
                        cols[row + oy * ow + ox] = v;
                    }
                }
            }
        }
    }
}

/// Route one GEMM through the cycle-level systolic simulator, tiling the
/// reduction dimension to the array width and accumulating partial results
/// (exact: all outputs are k-sums; CV is applied once on the final sumX).
#[allow(clippy::too_many_arguments)]
fn systolic_gemm(
    arr: &SystolicArray,
    ctx: &GemmCtx,
    pol: Polarity,
    w: &[u8],
    a: &[u8],
    m_rows: usize,
    k: usize,
    n: usize,
    bias: &[i32],
    toggles: &mut ToggleStats,
) -> Vec<i64> {
    let nn = arr.n;
    let consts: Vec<CvConstants> = (0..m_rows)
        .map(|f| cv::constants_pol(ctx.family, pol, ctx.m, &w[f * k..(f + 1) * k], k))
        .collect();
    let mut acc = vec![0i64; m_rows * n];
    let mut sum_x = vec![0i64; n];
    for k0 in (0..k).step_by(nn) {
        let klen = nn.min(k - k0);
        for f0 in (0..m_rows).step_by(nn) {
            let flen = nn.min(m_rows - f0);
            let w_tile: Vec<Vec<u8>> = (0..flen)
                .map(|f| w[(f0 + f) * k + k0..(f0 + f) * k + k0 + klen].to_vec())
                .collect();
            let cols: Vec<Vec<u8>> = (0..n)
                .map(|p| (0..klen).map(|kk| a[(k0 + kk) * n + p]).collect())
                .collect();
            // raw accumulation; V applied after all K tiles.
            let (tile_out, stats) = arr.run_tile(&w_tile, &cols, &consts, false, k0);
            toggles.merge(&stats);
            for (p, col_out) in tile_out.iter().enumerate() {
                for (f, &v) in col_out.iter().enumerate() {
                    acc[(f0 + f) * n + p] += v;
                }
            }
            if f0 == 0 {
                for (p, col) in cols.iter().enumerate() {
                    sum_x[p] += cv::sum_x_pol(ctx.family, pol, ctx.m, col);
                }
            }
        }
    }
    if ctx.use_cv && ctx.family != Family::Exact {
        for f in 0..m_rows {
            for p in 0..n {
                acc[f * n + p] += cv::v_term(&consts[f], sum_x[p]);
            }
        }
    }
    // zero-point + bias epilogue (same as fast path)
    let mut sum_a = vec![0i64; n];
    for kk in 0..k {
        for p in 0..n {
            sum_a[p] += a[kk * n + p] as i64;
        }
    }
    let kzz = k as i64 * ctx.zp_w * ctx.zp_a;
    for f in 0..m_rows {
        let sum_w: i64 = w[f * k..(f + 1) * k].iter().map(|&x| x as i64).sum();
        for p in 0..n {
            acc[f * n + p] += -ctx.zp_w * sum_a[p] - ctx.zp_a * sum_w + kzz + bias[f] as i64;
        }
    }
    acc
}

/// Route one **paired** GEMM through the cycle-level simulator: the array
/// multiplies each reduction column through its parity's multiplier (the
/// alternating-column hardware layout), and the per-partition V terms come
/// from the paired plan's constants (`row0` selects the conv-group window).
#[allow(clippy::too_many_arguments)]
fn systolic_gemm_paired(
    arr: &SystolicArray,
    pair: &PairedPoint,
    zp_w: i64,
    zp_a: i64,
    plan: &PairedPlan,
    row0: usize,
    w: &[u8],
    a: &[u8],
    m_rows: usize,
    k: usize,
    n: usize,
    bias: &[i32],
    toggles: &mut ToggleStats,
) -> Vec<i64> {
    let nn = arr.n;
    let even = pair.even.normalized();
    let odd = pair.odd.normalized();
    let mut acc = vec![0i64; m_rows * n];
    let mut sum_x_e = vec![0i64; n];
    let mut sum_x_o = vec![0i64; n];
    for k0 in (0..k).step_by(nn) {
        let klen = nn.min(k - k0);
        for f0 in (0..m_rows).step_by(nn) {
            let flen = nn.min(m_rows - f0);
            let w_tile: Vec<Vec<u8>> = (0..flen)
                .map(|f| w[(f0 + f) * k + k0..(f0 + f) * k + k0 + klen].to_vec())
                .collect();
            let cols: Vec<Vec<u8>> = (0..n)
                .map(|p| (0..klen).map(|kk| a[(k0 + kk) * n + p]).collect())
                .collect();
            // raw accumulation; per-partition V applied after all K tiles.
            let (tile_out, stats) = arr.run_tile(&w_tile, &cols, &[], false, k0);
            toggles.merge(&stats);
            for (p, col_out) in tile_out.iter().enumerate() {
                for (f, &v) in col_out.iter().enumerate() {
                    acc[(f0 + f) * n + p] += v;
                }
            }
            if f0 == 0 {
                for (p, col) in cols.iter().enumerate() {
                    for (kk, &av) in col.iter().enumerate() {
                        if (k0 + kk) % 2 == 0 {
                            sum_x_e[p] +=
                                crate::approx::xvar_pol(even.family, even.polarity, av, even.m)
                                    as i64;
                        } else {
                            sum_x_o[p] +=
                                crate::approx::xvar_pol(odd.family, odd.polarity, av, odd.m)
                                    as i64;
                        }
                    }
                }
            }
        }
    }
    if even.use_cv && even != LayerPoint::EXACT {
        for f in 0..m_rows {
            for p in 0..n {
                acc[f * n + p] += cv::v_term(&plan.even.consts[row0 + f], sum_x_e[p]);
            }
        }
    }
    if odd.use_cv && odd != LayerPoint::EXACT {
        for f in 0..m_rows {
            for p in 0..n {
                acc[f * n + p] += cv::v_term(&plan.odd.consts[row0 + f], sum_x_o[p]);
            }
        }
    }
    // zero-point + bias epilogue (same as fast path)
    let mut sum_a = vec![0i64; n];
    for kk in 0..k {
        for p in 0..n {
            sum_a[p] += a[kk * n + p] as i64;
        }
    }
    let kzz = k as i64 * zp_w * zp_a;
    for f in 0..m_rows {
        let sum_w: i64 = w[f * k..(f + 1) * k].iter().map(|&x| x as i64).sum();
        for p in 0..n {
            acc[f * n + p] += -zp_w * sum_a[p] - zp_a * sum_w + kzz + bias[f] as i64;
        }
    }
    acc
}

fn maxpool2(x: &Tensor) -> Tensor {
    let (oh, ow) = (x.h / 2, x.w / 2);
    let mut out = Tensor::new(oh, ow, x.c);
    for oy in 0..oh {
        for ox in 0..ow {
            for c in 0..x.c {
                let v = x
                    .at(oy * 2, ox * 2, c)
                    .max(x.at(oy * 2, ox * 2 + 1, c))
                    .max(x.at(oy * 2 + 1, ox * 2, c))
                    .max(x.at(oy * 2 + 1, ox * 2 + 1, c));
                out.set(oy, ox, c, v);
            }
        }
    }
    out
}

fn gap(x: &Tensor) -> Tensor {
    let npix = (x.h * x.w) as i64;
    let mut out = Tensor::new(1, 1, x.c);
    for c in 0..x.c {
        let mut s = 0i64;
        for y in 0..x.h {
            for xx in 0..x.w {
                s += x.at(y, xx, c) as i64;
            }
        }
        // mirror python: (sum*2 + npix) // (2*npix)  (round-half-up, nonneg)
        out.data[c] = ((s * 2 + npix) / (2 * npix)) as u8;
    }
    out
}

fn add(a: &Tensor, b: &Tensor, s1: f64, z1: i32, s2: f64, z2: i32, node: &Node) -> Tensor {
    let s_out = node.out_scale as f64;
    let zp_out = node.out_zp;
    let lo = if node.relu { zp_out.clamp(0, 255) as f64 } else { 0.0 };
    let mut out = Tensor::new(a.h, a.w, a.c);
    for (o, (&qa, &qb)) in out.data.iter_mut().zip(a.data.iter().zip(&b.data)) {
        let acc = (qa as f64 - z1 as f64) * s1 + (qb as f64 - z2 as f64) * s2;
        let q = round_half_away(acc / s_out) + zp_out as f64;
        *o = q.clamp(lo, 255.0) as u8;
    }
    out
}

fn concat(parts: &[(&Tensor, f64, i32)], node: &Node) -> Tensor {
    let s_out = node.out_scale as f64;
    let zp_out = node.out_zp;
    let (h, w, c) = node.out_shape;
    let mut out = Tensor::new(h, w, c);
    let mut c_off = 0;
    for &(t, s_j, z_j) in parts {
        let ratio = s_j / s_out; // mirror python: (q - z) * (s_j / s_out)
        for y in 0..h {
            for x in 0..w {
                for cc in 0..t.c {
                    let q = round_half_away((t.at(y, x, cc) as f64 - z_j as f64) * ratio)
                        + zp_out as f64;
                    out.set(y, x, c_off + cc, q.clamp(0.0, 255.0) as u8);
                }
            }
        }
        c_off += t.c;
    }
    out
}

fn shuffle(x: &Tensor, groups: usize) -> Tensor {
    let cpg = x.c / groups;
    let mut out = Tensor::new(x.h, x.w, x.c);
    for y in 0..x.h {
        for xx in 0..x.w {
            for gi in 0..groups {
                for p in 0..cpg {
                    // python: out[.., p*g + gi] = in[.., gi*cpg + p]
                    out.set(y, xx, p * groups + gi, x.at(y, xx, gi * cpg + p));
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::graph::Weights;
    use crate::nn::testutil::{rand_image, rand_model};
    use crate::util::rng::Rng;

    /// Tiny synthetic model: input(4,4,3) -> conv3x3(8, relu) -> dense(5).
    /// Output scales are chosen so requantized values stay inside the u8
    /// range (non-saturating) while exercising both MAC layer kinds.
    fn toy_model() -> Model {
        let mut rng = Rng::new(0xE2E);
        let input = Node {
            op: Op::Input,
            relu: false,
            inputs: vec![],
            out_shape: (4, 4, 3),
            out_scale: 1.0,
            out_zp: 0,
            cout: 0,
            ksize: 0,
            stride: 1,
            pad: 0,
            groups: 1,
            weights: None,
        };
        let conv = Node {
            op: Op::Conv,
            relu: true,
            inputs: vec![0],
            out_shape: (4, 4, 8),
            out_scale: 4096.0,
            out_zp: 0,
            cout: 8,
            ksize: 3,
            stride: 1,
            pad: 1,
            groups: 1,
            weights: Some(Weights {
                w_q: (0..8 * 27).map(|_| rng.u8()).collect(),
                k_dim: 27,
                b_q: vec![0; 8],
                s_w: 1.0,
                zp_w: 7,
            }),
        };
        let dense = Node {
            op: Op::Dense,
            relu: false,
            inputs: vec![1],
            out_shape: (1, 1, 5),
            // mult = s_w * s_in / s_out = 4096 / 7e7 ≈ 5.9e-5: keeps the
            // ~±1.6M dense accumulators inside the u8 range around zp=128.
            out_scale: 7.0e7,
            out_zp: 128,
            cout: 5,
            ksize: 0,
            stride: 1,
            pad: 0,
            groups: 1,
            weights: Some(Weights {
                w_q: (0..5 * 4 * 4 * 8).map(|_| rng.u8()).collect(),
                k_dim: 4 * 4 * 8,
                b_q: vec![0; 5],
                s_w: 1.0,
                zp_w: 3,
            }),
        };
        Model { name: "toy".into(), n_classes: 5, nodes: vec![input, conv, dense] }
    }

    fn toy_image() -> Tensor {
        let mut rng = Rng::new(0x1136);
        Tensor::from_data(4, 4, 3, (0..4 * 4 * 3).map(|_| rng.u8()).collect())
    }

    #[test]
    fn cv_proxy_sampler_tracks_error_magnitude_without_changing_outputs() {
        let engine = Engine::new(toy_model());
        let img = toy_image();
        let mut ratios = Vec::new();
        for m in [1u32, 3] {
            let proxy = Arc::new(CvProxySampler::new(engine.model.mac_layers()));
            let mut opts = ForwardOpts::approx(Family::Perforated, m, true);
            opts.cv_proxy = Some(proxy.clone());
            let with = engine.forward(&img, &opts).unwrap();
            let without = engine
                .forward(&img, &ForwardOpts::approx(Family::Perforated, m, true))
                .unwrap();
            assert_eq!(with, without, "sampling must not change outputs");
            let w = proxy.drain();
            assert!(w.samples > 0, "m={m} recorded no samples");
            assert!(w.aggregate > 0.0);
            assert_eq!(w.per_layer.len(), 2);
            assert!(w.per_layer.iter().any(|&r| r > 0.0));
            ratios.push(w.aggregate);
            // drain is a window: a second drain with no traffic is empty.
            let empty = proxy.drain();
            assert_eq!(empty.samples, 0);
            assert_eq!(empty.aggregate, 0.0);
        }
        assert!(
            ratios[1] > ratios[0],
            "|V|/|G*| proxy must grow with approximation level: {ratios:?}"
        );
        // Exact forwards record nothing (their error is identically zero).
        let proxy = Arc::new(CvProxySampler::new(2));
        let mut opts = ForwardOpts::exact();
        opts.cv_proxy = Some(proxy.clone());
        engine.forward(&img, &opts).unwrap();
        assert_eq!(proxy.drain().samples, 0);
        // The batched path and paired policies feed the same sampler.
        let policy = Arc::new(
            crate::nn::LayerPolicy::paired_uniform(Family::Perforated, 2, true, 2)
                .unwrap(),
        );
        let proxy = Arc::new(CvProxySampler::new(2));
        let mut opts = ForwardOpts::with_policy(policy.clone());
        opts.cv_proxy = Some(proxy.clone());
        let imgs = [toy_image(), toy_image()];
        let refs: Vec<&Tensor> = imgs.iter().collect();
        let got = engine.forward_batch(&refs, &opts).unwrap();
        let want = engine.forward(&imgs[0], &ForwardOpts::with_policy(policy)).unwrap();
        assert_eq!(got[0], want, "paired batched forward unchanged by sampler");
        assert!(proxy.drain().samples > 0, "paired layers sample too");
    }

    #[test]
    fn plan_built_once_across_forwards() {
        let engine = Engine::new(toy_model());
        let img = toy_image();
        let opts = ForwardOpts::approx(Family::Perforated, 2, true);
        assert_eq!(engine.plan_builds(), 0);
        let first = engine.forward(&img, &opts).unwrap();
        assert_eq!(engine.plan_builds(), 2, "one plan per MAC layer");
        let second = engine.forward(&img, &opts).unwrap();
        let third = engine.forward(&img, &opts).unwrap();
        assert_eq!(engine.plan_builds(), 2, "steady state builds no plans");
        assert_eq!(first, second);
        assert_eq!(second, third);
        // A different design point builds its own plans once.
        let opts3 = ForwardOpts::approx(Family::Truncated, 6, true);
        engine.forward(&img, &opts3).unwrap();
        assert_eq!(engine.plan_builds(), 4);
        engine.forward(&img, &opts3).unwrap();
        assert_eq!(engine.plan_builds(), 4);
    }

    #[test]
    fn prepare_plans_prewarms_the_cache() {
        let engine = Engine::new(toy_model());
        engine.prepare_plans(Family::Recursive, 3);
        assert_eq!(engine.plan_builds(), 2);
        engine
            .forward(&toy_image(), &ForwardOpts::approx(Family::Recursive, 3, true))
            .unwrap();
        assert_eq!(engine.plan_builds(), 2, "forward reuses prewarmed plans");
    }

    #[test]
    fn scratch_reuse_is_transparent() {
        let engine = Engine::new(toy_model());
        let img = toy_image();
        let mut scratch = Scratch::new();
        for family in [Family::Exact, Family::Perforated, Family::Truncated] {
            let m = *family.paper_levels().last().unwrap();
            let opts = ForwardOpts::approx(family, m, true);
            let fresh = engine.forward(&img, &opts).unwrap();
            let reused = engine.forward_with_scratch(&img, &opts, &mut scratch).unwrap();
            let reused2 = engine.forward_with_scratch(&img, &opts, &mut scratch).unwrap();
            assert_eq!(fresh, reused, "{}", family.name());
            assert_eq!(fresh, reused2, "{}", family.name());
        }
    }

    #[test]
    fn round_half_away_matches_python() {
        for (x, want) in [(0.5, 1.0), (1.5, 2.0), (-0.5, -1.0), (-1.5, -2.0), (2.4, 2.0)] {
            assert_eq!(round_half_away(x), want, "{x}");
        }
    }

    #[test]
    fn requantize_clamps_and_rounds() {
        assert_eq!(requantize(-100_000, 0.01, 128), 0);
        assert_eq!(requantize(0, 0.01, 128), 128);
        assert_eq!(requantize(100_000, 0.01, 128), 255);
        assert_eq!(requantize(50, 0.01, 128), 129); // 0.5 rounds away
    }

    #[test]
    fn maxpool_takes_window_max() {
        let t = Tensor::from_data(2, 2, 1, vec![1, 9, 3, 4]);
        let p = maxpool2(&t);
        assert_eq!(p.data, vec![9]);
    }

    #[test]
    fn gap_rounds_half_up() {
        // sum=3 over 2 pixels -> 1.5 -> 2
        let t = Tensor::from_data(1, 2, 1, vec![1, 2]);
        assert_eq!(gap(&t).data, vec![2]);
    }

    #[test]
    fn shuffle_permutes_channels() {
        // 4 channels, 2 groups: [a0 a1 | b0 b1] -> [a0 b0 a1 b1]
        let t = Tensor::from_data(1, 1, 4, vec![10, 11, 20, 21]);
        let s = shuffle(&t, 2);
        assert_eq!(s.data, vec![10, 20, 11, 21]);
    }

    #[test]
    fn shuffle_twice_with_transpose_groups_restores() {
        let t = Tensor::from_data(1, 1, 6, vec![0, 1, 2, 3, 4, 5]);
        let s = shuffle(&shuffle(&t, 2), 3);
        assert_eq!(s.data, t.data);
    }

    #[test]
    fn forward_batch_matches_per_image_forward() {
        // The tentpole invariant: fusing a batch into one wide GEMM per
        // layer is bit-identical to running each image alone — for random
        // model shapes, every family, both engines (native identity and
        // prepared LUT) and several GEMM thread counts.
        crate::util::prop::check_msg(
            "forward_batch bit-exact",
            10,
            0xBA7C,
            |r| {
                let model_seed = r.next_u64();
                let batch = 1 + r.below(5) as usize;
                let fam = Family::ALL[r.below(4) as usize];
                let m = if fam == Family::Exact { 0 } else { 1 + r.below(7) as u32 };
                let use_cv = r.below(2) == 1;
                let use_lut = r.below(2) == 1;
                (model_seed, batch, fam, m, use_cv, use_lut)
            },
            |&(model_seed, batch, fam, m, use_cv, use_lut)| {
                let mut rng = Rng::new(model_seed);
                let model = rand_model(&mut rng);
                let (h, w, c) = model.nodes[0].out_shape;
                let imgs: Vec<Tensor> = (0..batch)
                    .map(|_| {
                        Tensor::from_data(
                            h,
                            w,
                            c,
                            (0..h * w * c).map(|_| rng.u8()).collect(),
                        )
                    })
                    .collect();
                let mut engine = Engine::new(model);
                if use_lut {
                    engine.prepare_lut(fam, m);
                }
                let opts = ForwardOpts::approx(fam, m, use_cv);
                let per: Vec<Vec<f64>> = imgs
                    .iter()
                    .map(|img| engine.forward(img, &opts).unwrap())
                    .collect();
                let refs: Vec<&Tensor> = imgs.iter().collect();
                let mut scratch = Scratch::new();
                for threads in [1usize, 2, 5] {
                    let batched = engine
                        .forward_batch_with_threads(&refs, &opts, &mut scratch, threads)
                        .unwrap();
                    if batched != per {
                        return Err(format!(
                            "{} m={m} cv={use_cv} lut={use_lut} batch={batch} \
                             threads={threads}: batched != per-image",
                            fam.name()
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn forward_batch_layerwise_and_empty() {
        let engine = Engine::new(toy_model());
        let imgs: Vec<Tensor> = (0..3)
            .map(|i| {
                let mut r = Rng::new(0x500 + i);
                Tensor::from_data(4, 4, 3, (0..48).map(|_| r.u8()).collect())
            })
            .collect();
        let opts = ForwardOpts::layerwise(Family::Truncated, vec![6, 0], true);
        let per: Vec<Vec<f64>> = imgs
            .iter()
            .map(|im| engine.forward(im, &opts).unwrap())
            .collect();
        let refs: Vec<&Tensor> = imgs.iter().collect();
        let batched = engine.forward_batch(&refs, &opts).unwrap();
        assert_eq!(batched, per);
        assert!(engine.forward_batch(&[], &opts).unwrap().is_empty());
    }

    #[test]
    fn forward_batch_shares_plans_with_per_image() {
        let engine = Engine::new(toy_model());
        let img = toy_image();
        let opts = ForwardOpts::approx(Family::Perforated, 2, true);
        engine.forward(&img, &opts).unwrap();
        assert_eq!(engine.plan_builds(), 2);
        let imgs = [&img, &img, &img];
        engine.forward_batch(&imgs, &opts).unwrap();
        assert_eq!(
            engine.plan_builds(),
            2,
            "the batched path must reuse the per-image plans"
        );
    }

    #[test]
    fn uniform_policy_is_bit_identical_to_uniform_opts() {
        // Satellite property: a LayerPolicy with every layer at the same
        // (family, m, use_cv) must be bit-identical to the uniform
        // ForwardOpts path — across engines (identity / prepared LUT),
        // batch sizes and GEMM thread counts — and share its plan cache.
        crate::util::prop::check_msg(
            "uniform policy == uniform opts",
            8,
            0xB0C1,
            |r| {
                let model_seed = r.next_u64();
                let fam = Family::ALL[r.below(4) as usize];
                let m = if fam == Family::Exact { 0 } else { 1 + r.below(7) as u32 };
                let use_cv = r.below(2) == 1;
                let use_lut = r.below(2) == 1;
                let batch = 1 + r.below(4) as usize;
                (model_seed, fam, m, use_cv, use_lut, batch)
            },
            |&(model_seed, fam, m, use_cv, use_lut, batch)| {
                let mut rng = Rng::new(model_seed);
                let model = rand_model(&mut rng);
                let n_layers = model.mac_layers();
                let imgs: Vec<Tensor> =
                    (0..batch).map(|_| rand_image(&model, &mut rng)).collect();
                let mut engine = Engine::new(model);
                if use_lut {
                    engine.prepare_lut(fam, m);
                }
                let uniform = ForwardOpts::approx(fam, m, use_cv);
                let policy = std::sync::Arc::new(
                    LayerPolicy::uniform(fam, m, use_cv, n_layers).unwrap(),
                );
                let via_policy = ForwardOpts::with_policy(policy);
                let mut scratch = Scratch::new();
                for img in &imgs {
                    let a = engine.forward(img, &uniform).unwrap();
                    let b = engine.forward(img, &via_policy).unwrap();
                    if a != b {
                        return Err(format!(
                            "{} m={m} cv={use_cv} lut={use_lut}: per-image \
                             policy != uniform",
                            fam.name()
                        ));
                    }
                }
                let builds_after_both = engine.plan_builds();
                let refs: Vec<&Tensor> = imgs.iter().collect();
                let per: Vec<Vec<f64>> = imgs
                    .iter()
                    .map(|img| engine.forward(img, &uniform).unwrap())
                    .collect();
                for threads in [1usize, 3] {
                    let batched = engine
                        .forward_batch_with_threads(
                            &refs,
                            &via_policy,
                            &mut scratch,
                            threads,
                        )
                        .unwrap();
                    if batched != per {
                        return Err(format!(
                            "{} m={m} cv={use_cv} lut={use_lut} batch={batch} \
                             threads={threads}: batched policy != uniform",
                            fam.name()
                        ));
                    }
                }
                if engine.plan_builds() != builds_after_both {
                    return Err(
                        "policy path must share the uniform plan cache".into()
                    );
                }
                Ok(())
            },
        );
    }

    #[test]
    fn mixed_policy_forward_matches_forward_batch() {
        // Satellite property: for arbitrary heterogeneous policies (every
        // layer its own family/m/V), the batched path is bit-identical to
        // per-image forwards, across thread counts and with/without
        // per-point LUTs prepared.
        crate::util::prop::check_msg(
            "mixed policy forward == forward_batch",
            8,
            0xB0C2,
            |r| {
                let model_seed = r.next_u64();
                let policy_seed = r.next_u64();
                let batch = 1 + r.below(4) as usize;
                let use_luts = r.below(2) == 1;
                (model_seed, policy_seed, batch, use_luts)
            },
            |&(model_seed, policy_seed, batch, use_luts)| {
                let mut rng = Rng::new(model_seed);
                let model = rand_model(&mut rng);
                let n_layers = model.mac_layers();
                let imgs: Vec<Tensor> =
                    (0..batch).map(|_| rand_image(&model, &mut rng)).collect();
                let mut pr = Rng::new(policy_seed);
                let points: Vec<LayerPoint> = (0..n_layers)
                    .map(|_| {
                        let fam = Family::ALL[pr.below(4) as usize];
                        let m = if fam == Family::Exact {
                            0
                        } else {
                            pr.below(8) as u32 // 0 = exact layer, else 1..7
                        };
                        LayerPoint::new(fam, m, pr.below(2) == 1)
                    })
                    .collect();
                let policy =
                    std::sync::Arc::new(LayerPolicy::new(points).unwrap());
                let mut engine = Engine::new(model);
                if use_luts {
                    engine.prepare_luts_for_policy(&policy);
                }
                let opts = ForwardOpts::with_policy(policy.clone());
                let per: Vec<Vec<f64>> = imgs
                    .iter()
                    .map(|img| engine.forward(img, &opts).unwrap())
                    .collect();
                let refs: Vec<&Tensor> = imgs.iter().collect();
                let mut scratch = Scratch::new();
                for threads in [1usize, 2, 5] {
                    let batched = engine
                        .forward_batch_with_threads(&refs, &opts, &mut scratch, threads)
                        .unwrap();
                    if batched != per {
                        return Err(format!(
                            "policy {} luts={use_luts} batch={batch} \
                             threads={threads}: batched != per-image",
                            policy.describe()
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn paired_policy_forward_matches_forward_batch() {
        // The pairing tentpole at engine level: arbitrary mixes of paired,
        // positive-polarity and plain layers must be bit-identical between
        // per-image and batched forwards, across engines (identity /
        // prepared LUTs) and GEMM thread counts.
        use crate::nn::policy::{LayerAssignment, PairedPoint};
        crate::util::prop::check_msg(
            "paired policy forward == forward_batch",
            8,
            0xB0C3,
            |r| {
                let model_seed = r.next_u64();
                let policy_seed = r.next_u64();
                let batch = 1 + r.below(4) as usize;
                let use_luts = r.below(2) == 1;
                (model_seed, policy_seed, batch, use_luts)
            },
            |&(model_seed, policy_seed, batch, use_luts)| {
                let mut rng = Rng::new(model_seed);
                let model = rand_model(&mut rng);
                let n_layers = model.mac_layers();
                let imgs: Vec<Tensor> =
                    (0..batch).map(|_| rand_image(&model, &mut rng)).collect();
                let mut pr = Rng::new(policy_seed);
                let mut point = |pr: &mut Rng| {
                    let fam = Family::ALL[pr.below(4) as usize];
                    let m = if fam == Family::Exact { 0 } else { pr.below(8) as u32 };
                    let pol = if fam == Family::Exact {
                        Polarity::Neg
                    } else {
                        Polarity::ALL[pr.below(2) as usize]
                    };
                    LayerPoint::new_pol(fam, m, pol, pr.below(2) == 1)
                };
                let assignments: Vec<LayerAssignment> = (0..n_layers)
                    .map(|_| {
                        if pr.below(2) == 0 {
                            LayerAssignment::Point(point(&mut pr))
                        } else {
                            LayerAssignment::Paired(PairedPoint::new(
                                point(&mut pr),
                                point(&mut pr),
                            ))
                        }
                    })
                    .collect();
                let policy = std::sync::Arc::new(
                    LayerPolicy::from_assignments(assignments).unwrap(),
                );
                let mut engine = Engine::new(model);
                if use_luts {
                    engine.prepare_luts_for_policy(&policy);
                }
                let opts = ForwardOpts::with_policy(policy.clone());
                let per: Vec<Vec<f64>> = imgs
                    .iter()
                    .map(|img| engine.forward(img, &opts).unwrap())
                    .collect();
                let refs: Vec<&Tensor> = imgs.iter().collect();
                let mut scratch = Scratch::new();
                for threads in [1usize, 2, 5] {
                    let batched = engine
                        .forward_batch_with_threads(&refs, &opts, &mut scratch, threads)
                        .unwrap();
                    if batched != per {
                        return Err(format!(
                            "policy {} luts={use_luts} batch={batch} \
                             threads={threads}: batched != per-image",
                            policy.describe()
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn paired_plans_and_luts_are_cached_per_assignment() {
        use crate::nn::policy::PairedPoint;
        let engine = Engine::new(toy_model());
        let img = toy_image();
        let policy = std::sync::Arc::new(
            LayerPolicy::paired_uniform(Family::Perforated, 2, true, 2).unwrap(),
        );
        let opts = ForwardOpts::with_policy(policy.clone());
        assert_eq!(engine.plan_builds(), 0);
        let first = engine.forward(&img, &opts).unwrap();
        assert_eq!(engine.plan_builds(), 2, "one paired plan per MAC layer");
        let second = engine.forward(&img, &opts).unwrap();
        assert_eq!(engine.plan_builds(), 2, "steady state builds no plans");
        assert_eq!(first, second);
        // A nocv twin hits the same (cv-stripped) plan keys.
        let nocv = std::sync::Arc::new(
            LayerPolicy::paired_uniform(Family::Perforated, 2, false, 2).unwrap(),
        );
        engine.forward(&img, &ForwardOpts::with_policy(nocv)).unwrap();
        assert_eq!(engine.plan_builds(), 2, "cv-stripped pairing shares plans");
        // Prewarm path: a fresh engine warms the same two paired plans.
        let engine2 = Engine::new(toy_model());
        engine2.prepare_plans_policy(&policy).unwrap();
        assert_eq!(engine2.plan_builds(), 2);
        engine2.forward(&img, &opts).unwrap();
        assert_eq!(engine2.plan_builds(), 2, "forward reuses prewarmed plans");
        // And the paired systolic array computes the same logits.
        let mut engine3 = Engine::new(toy_model());
        engine3.prepare_systolic_paired(
            PairedPoint::mirrored(Family::Perforated, 2, true),
            16,
        );
        let (sys_logits, stats) = engine3.forward_systolic(&img, &opts).unwrap();
        assert_eq!(sys_logits, first, "paired systolic == paired fast path");
        assert!(stats.cycles > 0);
    }

    #[test]
    fn systolic_rejects_mismatched_pairing() {
        use crate::nn::policy::PairedPoint;
        let mut engine = Engine::new(toy_model());
        // Array prepared uniform, layer resolves paired -> error.
        engine.prepare_systolic(Family::Perforated, 2, 16);
        let policy = std::sync::Arc::new(
            LayerPolicy::paired_uniform(Family::Perforated, 2, true, 2).unwrap(),
        );
        let opts = ForwardOpts::with_policy(policy);
        let err = engine.forward_systolic(&toy_image(), &opts).unwrap_err();
        assert!(format!("{err:#}").contains("pairing"), "{err:#}");
        // Array prepared paired, layer resolves uniform -> error.
        let mut engine2 = Engine::new(toy_model());
        engine2.prepare_systolic_paired(
            PairedPoint::mirrored(Family::Perforated, 2, true),
            16,
        );
        let uni = ForwardOpts::approx(Family::Perforated, 2, true);
        let err2 = engine2.forward_systolic(&toy_image(), &uni).unwrap_err();
        assert!(format!("{err2:#}").contains("paired"), "{err2:#}");
    }

    #[test]
    fn pos_polarity_policy_runs_end_to_end() {
        // A uniform positive-polarity policy: runs, differs from the Neg
        // twin (errors now overestimate), and stays engine-consistent
        // (identity == prepared LUT == systolic).
        let img = toy_image();
        let pos_policy = std::sync::Arc::new(
            LayerPolicy::new(vec![
                LayerPoint::new_pol(
                    Family::Perforated,
                    2,
                    Polarity::Pos,
                    true,
                );
                2
            ])
            .unwrap(),
        );
        let opts = ForwardOpts::with_policy(pos_policy);
        let engine = Engine::new(toy_model());
        let ident = engine.forward(&img, &opts).unwrap();
        let mut engine_lut = Engine::new(toy_model());
        engine_lut.prepare_lut_pol(Family::Perforated, 2, Polarity::Pos);
        assert_eq!(engine_lut.forward(&img, &opts).unwrap(), ident);
        let mut engine_sys = Engine::new(toy_model());
        engine_sys.prepare_systolic_pol(Family::Perforated, 2, Polarity::Pos, 16);
        let (sys, _) = engine_sys.forward_systolic(&img, &opts).unwrap();
        assert_eq!(sys, ident);
    }

    #[test]
    fn policy_layer_count_mismatch_is_an_error() {
        let engine = Engine::new(toy_model()); // 2 MAC layers
        let img = toy_image();
        for n in [1usize, 3] {
            let policy = std::sync::Arc::new(
                LayerPolicy::uniform(Family::Perforated, 2, true, n).unwrap(),
            );
            let opts = ForwardOpts::with_policy(policy.clone());
            let err = engine.forward(&img, &opts).unwrap_err();
            assert!(format!("{err:#}").contains("MAC layers"), "{err:#}");
            let err = engine.forward_batch(&[&img], &opts).unwrap_err();
            assert!(format!("{err:#}").contains("MAC layers"), "{err:#}");
            assert!(engine.prepare_plans_policy(&policy).is_err());
        }
        // And nothing was cached by the failed attempts.
        assert_eq!(engine.plan_builds(), 0);
        // A matching policy then works.
        let ok = std::sync::Arc::new(
            LayerPolicy::uniform(Family::Perforated, 2, true, 2).unwrap(),
        );
        engine.forward(&img, &ForwardOpts::with_policy(ok)).unwrap();
    }

    #[test]
    fn m_out_of_range_is_an_error_not_garbage() {
        // The seed silently masked with a truncated shift for m > 7; now
        // both uniform and layerwise opts fail fast at forward entry.
        let engine = Engine::new(toy_model());
        let img = toy_image();
        let too_big = ForwardOpts::approx(Family::Perforated, 9, true);
        let err = engine.forward(&img, &too_big).unwrap_err();
        assert!(format!("{err:#}").contains("out of range"), "{err:#}");
        assert!(engine.forward_batch(&[&img], &too_big).is_err());
        let lw = ForwardOpts::layerwise(Family::Truncated, vec![6, 9], true);
        assert!(engine.forward(&img, &lw).is_err());
        // m = 7 is the last valid level.
        let edge = ForwardOpts::approx(Family::Perforated, 7, true);
        engine.forward(&img, &edge).unwrap();
    }

    #[test]
    fn oversized_k_is_a_typed_error_not_a_panic() {
        // Headline satellite: a positive-polarity point on a layer whose K
        // exceeds MAX_K_POS used to hit the i32-headroom assert mid-batch
        // inside a serving worker; it must now surface as Err at validation
        // time — forward entry, plan prewarm and policy install alike.
        use crate::nn::gemm::{MAX_K_NEG, MAX_K_POS};
        use crate::nn::testutil::{big_k_image, big_k_model};
        let k = MAX_K_POS + 1_000;
        let engine = Engine::new(big_k_model(k));
        let img = big_k_image(k);
        let pos = std::sync::Arc::new(
            LayerPolicy::new(vec![LayerPoint::new_pol(
                Family::Perforated,
                2,
                Polarity::Pos,
                true,
            )])
            .unwrap(),
        );
        let opts_pos = ForwardOpts::with_policy(pos.clone());
        let err = engine.forward(&img, &opts_pos).unwrap_err();
        assert!(format!("{err:#}").contains("i32-headroom"), "{err:#}");
        assert!(engine.forward_batch(&[&img], &opts_pos).is_err());
        assert!(engine.prepare_plans_policy(&pos).is_err());
        assert!(engine.validate_opts(&opts_pos).is_err());
        assert_eq!(engine.plan_builds(), 0, "rejected configs cache nothing");
        // The negative-polarity twin sits inside its larger ceiling and runs.
        let neg = std::sync::Arc::new(
            LayerPolicy::new(vec![LayerPoint::new_pol(
                Family::Perforated,
                2,
                Polarity::Neg,
                true,
            )])
            .unwrap(),
        );
        engine.forward(&img, &ForwardOpts::with_policy(neg)).unwrap();
        // Beyond the universal i32 ceiling even exact/uniform opts are
        // typed errors (the core would assert on any GEMM at this depth).
        let huge_k = MAX_K_NEG + 1_000;
        let huge = Engine::new(big_k_model(huge_k));
        let img2 = big_k_image(huge_k);
        let err2 = huge.forward(&img2, &ForwardOpts::exact()).unwrap_err();
        assert!(format!("{err2:#}").contains("i32-headroom"), "{err2:#}");
        assert!(huge
            .validate_opts(&ForwardOpts::approx(Family::Truncated, 4, true))
            .is_err());
    }

    #[test]
    fn pjrt_route_reuses_plans_across_forwards() {
        // The PJRT path consumes plan.consts / plan.sum_w from the prebuilt
        // LayerPlan; repeated forwards must not rebuild plans (the native
        // path's invariant, now shared). Skips — like all runtime tests —
        // when no PJRT client or no HLO artifacts are available.
        let art = crate::artifacts_dir();
        let rt = match crate::runtime::TileGemm::new(&art) {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("skipping: PJRT unavailable ({e:#})");
                return;
            }
        };
        let mut engine = Engine::new(toy_model());
        engine.attach_pjrt(std::sync::Arc::new(rt), crate::runtime::Variant::Fast);
        let img = toy_image();
        let opts = ForwardOpts::approx(Family::Perforated, 2, true);
        let first = match engine.forward(&img, &opts) {
            Ok(l) => l,
            Err(e) => {
                eprintln!("skipping: PJRT execution failed ({e:#})");
                return;
            }
        };
        assert_eq!(engine.plan_builds(), 2);
        let second = engine.forward(&img, &opts).unwrap();
        let third = engine.forward(&img, &opts).unwrap();
        assert_eq!(engine.plan_builds(), 2, "pjrt route must reuse plans");
        assert_eq!(first, second);
        assert_eq!(second, third);
    }

    #[test]
    fn lut_corruption_is_detected_and_healed_bit_exact() {
        let mut engine = Engine::new(toy_model());
        engine.prepare_lut(Family::Perforated, 2);
        let img = toy_image();
        let opts = ForwardOpts::approx(Family::Perforated, 2, true);
        let clean = engine.forward(&img, &opts).unwrap();
        assert!(engine.verify_integrity().is_clean());
        let gen0 = engine.integrity_generation();

        // Burst-corrupt a whole weight row of the LUT with a high bit: any
        // hit distorts the accumulator massively.
        let hit = engine.corrupt_lut(0, 0, 65536, 22).expect("one LUT prepared");
        assert_eq!(hit, (Family::Perforated, 2, Polarity::Neg));
        assert!(engine.integrity_generation() > gen0, "corruption bumps the generation");
        let report = engine.verify_integrity();
        assert_eq!(report.luts, vec![hit]);
        assert!(report.plans.is_empty());
        let poisoned = engine.forward(&img, &opts).unwrap();
        assert_ne!(poisoned, clean, "full-table corruption must reach the logits");

        // Heal: rebuilt from the structural bitmodel, bit-identical again.
        assert_eq!(engine.heal_integrity(), 1);
        assert!(engine.verify_integrity().is_clean());
        let healed = engine.forward(&img, &opts).unwrap();
        assert_eq!(healed, clean, "healed LUT restores bit-identical outputs");
    }

    #[test]
    fn plan_corruption_is_detected_and_healed_bit_exact() {
        let engine = Engine::new(toy_model());
        let img = toy_image();
        let opts = ForwardOpts::approx(Family::Recursive, 3, true);
        let clean = engine.forward(&img, &opts).unwrap();
        let builds = engine.plan_builds();

        let hit = engine.corrupt_plan(1, 5, 6).expect("plans cached by the forward");
        let report = engine.verify_integrity();
        assert_eq!(report.plans, vec![hit]);
        let poisoned = engine.forward(&img, &opts).unwrap();
        assert_ne!(poisoned, clean, "panel corruption must reach the logits");

        assert_eq!(engine.heal_integrity(), 1);
        assert!(engine.verify_integrity().is_clean());
        let healed = engine.forward(&img, &opts).unwrap();
        assert_eq!(healed, clean, "rebuilt plan restores bit-identical outputs");
        assert_eq!(engine.plan_builds(), builds + 1, "heal costs one plan rebuild");
    }
}
