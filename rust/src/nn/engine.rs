//! Graph executor: runs a loaded [`Model`] on quantized images.
//!
//! Bit-exact mirror of python `model.QuantModel.forward` — identical
//! rounding (`round-half-away-from-zero` on f64), identical integer
//! arithmetic, asserted by golden-vector integration tests
//! (rust/tests/golden.rs). The engine also exposes a systolic-array mode
//! that routes every MAC GEMM through the cycle-level simulator and returns
//! aggregate toggle statistics.

use std::sync::Arc;

use anyhow::{bail, Result};

use super::gemm::{approx_gemm_planned, GemmCtx, GemmKind};
use super::graph::{Model, Node, Op, Tensor};
use super::plan::{LayerPlan, PlanCache, Scratch};
use crate::approx::{Family, MulLut};
use crate::cv::{self, CvConstants};
use crate::runtime::{TileGemm, Variant};
use crate::systolic::{SystolicArray, ToggleStats};
use crate::util::threadpool::configured_workers;

/// Forward-pass configuration.
#[derive(Clone, Debug)]
pub struct ForwardOpts {
    pub family: Family,
    pub m: u32,
    pub use_cv: bool,
    pub kind: GemmKind,
    /// Layer-wise approximation (ALWANN-style extension, DESIGN.md §12):
    /// per-MAC-layer m override. Because `m` is a *runtime* input of both
    /// the engines and the AOT artifacts, mixed-m operation needs no
    /// recompilation — unlike heterogeneous-hardware approaches [9].
    /// `None` entries (or a missing vec) fall back to `self.m`;
    /// m = 0 runs that layer exact.
    pub m_per_layer: Option<std::sync::Arc<Vec<u32>>>,
}

impl Default for ForwardOpts {
    fn default() -> Self {
        ForwardOpts {
            family: Family::Exact,
            m: 0,
            use_cv: false,
            kind: GemmKind::Identity,
            m_per_layer: None,
        }
    }
}

impl ForwardOpts {
    pub fn exact() -> Self {
        Self::default()
    }

    pub fn approx(family: Family, m: u32, use_cv: bool) -> Self {
        ForwardOpts { family, m, use_cv, kind: GemmKind::Identity, m_per_layer: None }
    }

    /// Layer-wise configuration: `ms[i]` is the approximation level of the
    /// i-th MAC layer (conv/dense, in topological order).
    pub fn layerwise(family: Family, ms: Vec<u32>, use_cv: bool) -> Self {
        ForwardOpts {
            family,
            m: 0,
            use_cv,
            kind: GemmKind::Identity,
            m_per_layer: Some(std::sync::Arc::new(ms)),
        }
    }

    /// Effective m for MAC layer ordinal `mac_idx`.
    pub fn m_for(&self, mac_idx: usize) -> u32 {
        match &self.m_per_layer {
            Some(ms) => ms.get(mac_idx).copied().unwrap_or(self.m),
            None => self.m,
        }
    }
}

/// Deterministic round-half-away-from-zero (mirror of quant.round_half_away).
#[inline]
pub fn round_half_away(x: f64) -> f64 {
    x.signum() * (x.abs() + 0.5).floor()
}

/// i64 accumulator -> uint8: clamp(round(acc*mult) + zp, 0, 255).
#[inline]
fn requantize(acc: i64, mult: f64, zp: i32) -> u8 {
    let q = round_half_away(acc as f64 * mult) + zp as f64;
    q.clamp(0.0, 255.0) as u8
}

/// The inference engine for one model. Holds per-(family, m) LUTs lazily
/// plus the [`PlanCache`] of per-layer weight-side precomputations: masked
/// panels, Σw and CV constants are built at most once per (layer, family, m)
/// and reused across every image (tested by `plan_built_once_across_forwards`).
pub struct Engine {
    pub model: Model,
    lut: Option<MulLut>,
    systolic: Option<SystolicArray>,
    pjrt: Option<(Arc<TileGemm>, Variant)>,
    plans: PlanCache,
}

impl Engine {
    pub fn new(model: Model) -> Engine {
        Engine { model, lut: None, systolic: None, pjrt: None, plans: PlanCache::new() }
    }

    /// Route MAC GEMMs through the PJRT runtime (the AOT XLA kernels).
    pub fn attach_pjrt(&mut self, rt: Arc<TileGemm>, variant: Variant) {
        self.pjrt = Some((rt, variant));
    }

    /// Pre-build the LUT for a (family, m) pair (Lut engine only).
    pub fn prepare_lut(&mut self, family: Family, m: u32) {
        if family != Family::Exact {
            self.lut = Some(MulLut::build(family, m));
        }
    }

    /// Attach a systolic array simulator (enables `forward_systolic`).
    pub fn prepare_systolic(&mut self, family: Family, m: u32, n: usize) {
        self.systolic = Some(SystolicArray::new(family, m, n));
    }

    /// Eagerly build the layer plans for a uniform (family, m) design point
    /// (they are otherwise built lazily on the first forward). The
    /// coordinator warms plans here so request latency never pays the
    /// one-time cost.
    pub fn prepare_plans(&self, family: Family, m: u32) {
        for idx in self.model.mac_node_indices() {
            let node = &self.model.nodes[idx];
            let wrec = node.weights.as_ref().expect("mac node has weights");
            let (fam_eff, m_eff) =
                if m == 0 { (Family::Exact, 0) } else { (family, m) };
            self.plans.get_or_build(idx, fam_eff, m_eff, || {
                LayerPlan::build(fam_eff, m_eff, &wrec.w_q, wrec.b_q.len(), wrec.k_dim)
            });
        }
    }

    /// How many layer plans have been built so far (a steady-state serving
    /// loop must not grow this).
    pub fn plan_builds(&self) -> usize {
        self.plans.builds()
    }

    /// Run one quantized image; returns dequantized logits.
    ///
    /// Allocates a fresh [`Scratch`] — batch/serving loops should hold one
    /// scratch per worker and call [`Engine::forward_with_scratch`] instead.
    pub fn forward(&self, img: &Tensor, opts: &ForwardOpts) -> Result<Vec<f64>> {
        let mut scratch = Scratch::new();
        self.forward_with_scratch(img, opts, &mut scratch)
    }

    /// Run one quantized image reusing a caller-owned scratch arena; the
    /// steady-state hot path (no per-GEMM heap allocations once the arena
    /// has grown to the largest layer).
    pub fn forward_with_scratch(
        &self,
        img: &Tensor,
        opts: &ForwardOpts,
        scratch: &mut Scratch,
    ) -> Result<Vec<f64>> {
        let (logits, _) = self.forward_inner(img, opts, false, scratch)?;
        Ok(logits)
    }

    /// Run one image through the systolic simulator (hardware-faithful),
    /// returning logits and toggle statistics.
    pub fn forward_systolic(
        &self,
        img: &Tensor,
        opts: &ForwardOpts,
    ) -> Result<(Vec<f64>, ToggleStats)> {
        if self.systolic.is_none() {
            bail!("call prepare_systolic first");
        }
        let mut scratch = Scratch::new();
        self.forward_inner(img, opts, true, &mut scratch)
    }

    fn forward_inner(
        &self,
        img: &Tensor,
        opts: &ForwardOpts,
        systolic: bool,
        scratch: &mut Scratch,
    ) -> Result<(Vec<f64>, ToggleStats)> {
        let nodes = &self.model.nodes;
        let mut outs: Vec<Tensor> = Vec::with_capacity(nodes.len());
        let mut toggles = ToggleStats::default();
        let mut mac_idx = 0usize;
        for (i, node) in nodes.iter().enumerate() {
            let t = match node.op {
                Op::Input => {
                    let (h, w, c) = node.out_shape;
                    if (img.h, img.w, img.c) != (h, w, c) {
                        bail!("input shape mismatch");
                    }
                    img.clone()
                }
                Op::Conv | Op::Dense => {
                    let t = self.mac_layer(
                        i, mac_idx, node, &outs, opts, systolic, &mut toggles, scratch,
                    )?;
                    mac_idx += 1;
                    t
                }
                Op::Maxpool => maxpool2(&outs[node.inputs[0]]),
                Op::Gap => gap(&outs[node.inputs[0]]),
                Op::Add => {
                    let a = &outs[node.inputs[0]];
                    let b = &outs[node.inputs[1]];
                    let (s1, z1) = out_q(nodes, node.inputs[0]);
                    let (s2, z2) = out_q(nodes, node.inputs[1]);
                    add(a, b, s1, z1, s2, z2, node)
                }
                Op::Concat => {
                    let parts: Vec<(&Tensor, f64, i32)> = node
                        .inputs
                        .iter()
                        .map(|&j| {
                            let (s, z) = out_q(nodes, j);
                            (&outs[j], s, z)
                        })
                        .collect();
                    concat(&parts, node)
                }
                Op::Shuffle => shuffle(&outs[node.inputs[0]], node.groups),
            };
            debug_assert_eq!(
                (t.h, t.w, t.c),
                node.out_shape,
                "node {i} {:?} shape mismatch",
                node.op
            );
            outs.push(t);
        }
        let last = outs.last().unwrap();
        let n = nodes.last().unwrap();
        let logits = last
            .data
            .iter()
            .map(|&q| (q as f64 - n.out_zp as f64) * n.out_scale as f64)
            .collect();
        Ok((logits, toggles))
    }

    #[allow(clippy::too_many_arguments)]
    fn mac_layer(
        &self,
        idx: usize,
        mac_idx: usize,
        node: &Node,
        outs: &[Tensor],
        opts: &ForwardOpts,
        systolic: bool,
        toggles: &mut ToggleStats,
        scratch: &mut Scratch,
    ) -> Result<Tensor> {
        let wrec = node.weights.as_ref().expect("mac layer has weights");
        let x = &outs[node.inputs[0]];
        let (s_in, zp_in) = out_q(&self.model.nodes, node.inputs[0]);
        let (s_out, zp_out) = (node.out_scale as f64, node.out_zp);
        let mult = wrec.s_w as f64 * s_in / s_out;
        let m_eff = opts.m_for(mac_idx);
        let ctx = GemmCtx {
            family: if m_eff == 0 { Family::Exact } else { opts.family },
            m: m_eff,
            use_cv: opts.use_cv,
            zp_w: wrec.zp_w as i64,
            zp_a: zp_in as i64,
        };
        // Fetch (or lazily build) the weight-side plan for this layer at the
        // effective design point; subsequent images reuse it untouched.
        let plan = self.plans.get_or_build(idx, ctx.family, ctx.m, || {
            LayerPlan::build(ctx.family, ctx.m, &wrec.w_q, wrec.b_q.len(), wrec.k_dim)
        });
        if node.op == Op::Dense {
            let k = wrec.k_dim;
            let nout = node.cout;
            debug_assert_eq!(x.data.len(), k, "dense input size");
            self.dispatch_gemm(
                &ctx, &plan, 0, &wrec.w_q, &x.data, nout, k, 1, &wrec.b_q, systolic,
                toggles, scratch,
            );
            let mut data = Vec::with_capacity(nout);
            for &a in scratch.acc.iter() {
                let mut q = requantize(a, mult, zp_out);
                if node.relu {
                    q = q.max(zp_out.clamp(0, 255) as u8);
                }
                data.push(q);
            }
            return Ok(Tensor::from_data(1, 1, nout, data));
        }
        // conv (possibly grouped)
        let (oh, ow, cout) = node.out_shape;
        let g = node.groups;
        let cin = x.c;
        let (cpg_in, cpg_out) = (cin / g, cout / g);
        let kdim = wrec.k_dim;
        let n_cols = oh * ow;
        let mut out = Tensor::new(oh, ow, cout);
        // The im2col buffer lives in the scratch arena; it is taken out for
        // the duration of the layer so the GEMM can borrow scratch mutably.
        let mut a_cols = std::mem::take(&mut scratch.a_cols);
        a_cols.clear();
        a_cols.resize(kdim * n_cols, 0);
        for gi in 0..g {
            im2col_group(x, node, gi * cpg_in, cpg_in, zp_in, &mut a_cols);
            let row0 = gi * cpg_out;
            let w_g = &wrec.w_q[row0 * kdim..(row0 + cpg_out) * kdim];
            let b_g = &wrec.b_q[row0..row0 + cpg_out];
            self.dispatch_gemm(
                &ctx, &plan, row0, w_g, &a_cols, cpg_out, kdim, n_cols, b_g, systolic,
                toggles, scratch,
            );
            for f in 0..cpg_out {
                let ch = gi * cpg_out + f;
                for p in 0..n_cols {
                    let mut q = requantize(scratch.acc[f * n_cols + p], mult, zp_out);
                    if node.relu {
                        q = q.max(zp_out.clamp(0, 255) as u8);
                    }
                    out.data[p * cout + ch] = q;
                }
            }
        }
        scratch.a_cols = a_cols;
        Ok(out)
    }

    /// Route one GEMM to the configured backend, leaving the [m_rows × n]
    /// i64 accumulator in `scratch.acc`.
    #[allow(clippy::too_many_arguments)]
    fn dispatch_gemm(
        &self,
        ctx: &GemmCtx,
        plan: &LayerPlan,
        row0: usize,
        w: &[u8],
        a: &[u8],
        m_rows: usize,
        k: usize,
        n: usize,
        bias: &[i32],
        systolic: bool,
        toggles: &mut ToggleStats,
        scratch: &mut Scratch,
    ) {
        if systolic {
            if let Some(arr) = &self.systolic {
                scratch.acc = systolic_gemm(arr, ctx, w, a, m_rows, k, n, bias, toggles);
                return;
            }
        }
        if let Some((rt, variant)) = &self.pjrt {
            scratch.acc = pjrt_gemm(rt, *variant, ctx, w, a, m_rows, k, n, bias);
            return;
        }
        approx_gemm_planned(
            ctx_kind(self, ctx),
            ctx,
            plan,
            row0,
            self.lut.as_ref(),
            w,
            a,
            m_rows,
            k,
            n,
            bias,
            scratch,
            configured_workers(),
        );
    }
}

/// Route one GEMM through the PJRT runtime; the CV + zero-point epilogue is
/// applied here (shared semantics with the native engines).
#[allow(clippy::too_many_arguments)]
fn pjrt_gemm(
    rt: &TileGemm,
    variant: Variant,
    ctx: &GemmCtx,
    w: &[u8],
    a: &[u8],
    m_rows: usize,
    k: usize,
    n: usize,
    bias: &[i32],
) -> Vec<i64> {
    let (mut acc, sum_x) = rt
        .am_acc(ctx.family, variant, ctx.m, w, a, m_rows, k, n)
        .expect("pjrt gemm execution");
    if ctx.use_cv && ctx.family != Family::Exact && ctx.m > 0 {
        for f in 0..m_rows {
            let c = cv::constants(ctx.family, ctx.m, &w[f * k..(f + 1) * k], k);
            let orow = &mut acc[f * n..(f + 1) * n];
            for (o, &sx) in orow.iter_mut().zip(&sum_x) {
                *o += cv::v_term(&c, sx);
            }
        }
    }
    let mut sum_a = vec![0i64; n];
    for kk in 0..k {
        let arow = &a[kk * n..(kk + 1) * n];
        for (sa, &av) in sum_a.iter_mut().zip(arow) {
            *sa += av as i64;
        }
    }
    let kzz = k as i64 * ctx.zp_w * ctx.zp_a;
    for f in 0..m_rows {
        let sum_w: i64 = w[f * k..(f + 1) * k].iter().map(|&x| x as i64).sum();
        let b = bias[f] as i64;
        let orow = &mut acc[f * n..(f + 1) * n];
        for (o, &sa) in orow.iter_mut().zip(&sum_a) {
            *o += -ctx.zp_w * sa - ctx.zp_a * sum_w + kzz + b;
        }
    }
    acc
}

fn ctx_kind(e: &Engine, ctx: &GemmCtx) -> GemmKind {
    // Use the LUT when one matching the context is prepared.
    match &e.lut {
        Some(l) if l.family == ctx.family && l.m == ctx.m => GemmKind::Lut,
        _ => GemmKind::Identity,
    }
}

fn out_q(nodes: &[Node], i: usize) -> (f64, i32) {
    (nodes[i].out_scale as f64, nodes[i].out_zp)
}

/// im2col for one channel group: fills `cols` as [kdim, n_cols] row-major,
/// (ky, kx, c) minor ordering, zero-point padding. Mirrors python im2col.
fn im2col_group(
    x: &Tensor,
    node: &Node,
    c0: usize,
    cpg: usize,
    zp_in: i32,
    cols: &mut [u8],
) {
    let k = node.ksize;
    let stride = node.stride;
    let pad = node.pad as isize;
    let (oh, ow, _) = node.out_shape;
    let n_cols = oh * ow;
    let zp = zp_in.clamp(0, 255) as u8;
    for ky in 0..k {
        for kx in 0..k {
            for c in 0..cpg {
                let row = ((ky * k + kx) * cpg + c) * n_cols;
                for oy in 0..oh {
                    let iy = (oy * stride) as isize + ky as isize - pad;
                    for ox in 0..ow {
                        let ix = (ox * stride) as isize + kx as isize - pad;
                        let v = if iy >= 0
                            && iy < x.h as isize
                            && ix >= 0
                            && ix < x.w as isize
                        {
                            x.at(iy as usize, ix as usize, c0 + c)
                        } else {
                            zp
                        };
                        cols[row + oy * ow + ox] = v;
                    }
                }
            }
        }
    }
}

/// Route one GEMM through the cycle-level systolic simulator, tiling the
/// reduction dimension to the array width and accumulating partial results
/// (exact: all outputs are k-sums; CV is applied once on the final sumX).
#[allow(clippy::too_many_arguments)]
fn systolic_gemm(
    arr: &SystolicArray,
    ctx: &GemmCtx,
    w: &[u8],
    a: &[u8],
    m_rows: usize,
    k: usize,
    n: usize,
    bias: &[i32],
    toggles: &mut ToggleStats,
) -> Vec<i64> {
    let nn = arr.n;
    let consts: Vec<CvConstants> = (0..m_rows)
        .map(|f| cv::constants(ctx.family, ctx.m, &w[f * k..(f + 1) * k], k))
        .collect();
    let mut acc = vec![0i64; m_rows * n];
    let mut sum_x = vec![0i64; n];
    for k0 in (0..k).step_by(nn) {
        let klen = nn.min(k - k0);
        for f0 in (0..m_rows).step_by(nn) {
            let flen = nn.min(m_rows - f0);
            let w_tile: Vec<Vec<u8>> = (0..flen)
                .map(|f| w[(f0 + f) * k + k0..(f0 + f) * k + k0 + klen].to_vec())
                .collect();
            let cols: Vec<Vec<u8>> = (0..n)
                .map(|p| (0..klen).map(|kk| a[(k0 + kk) * n + p]).collect())
                .collect();
            // raw accumulation; V applied after all K tiles.
            let (tile_out, stats) = arr.run_tile(&w_tile, &cols, &consts, false);
            toggles.merge(&stats);
            for (p, col_out) in tile_out.iter().enumerate() {
                for (f, &v) in col_out.iter().enumerate() {
                    acc[(f0 + f) * n + p] += v;
                }
            }
            if f0 == 0 {
                for (p, col) in cols.iter().enumerate() {
                    sum_x[p] += cv::sum_x(ctx.family, ctx.m, col);
                }
            }
        }
    }
    if ctx.use_cv && ctx.family != Family::Exact {
        for f in 0..m_rows {
            for p in 0..n {
                acc[f * n + p] += cv::v_term(&consts[f], sum_x[p]);
            }
        }
    }
    // zero-point + bias epilogue (same as fast path)
    let mut sum_a = vec![0i64; n];
    for kk in 0..k {
        for p in 0..n {
            sum_a[p] += a[kk * n + p] as i64;
        }
    }
    let kzz = k as i64 * ctx.zp_w * ctx.zp_a;
    for f in 0..m_rows {
        let sum_w: i64 = w[f * k..(f + 1) * k].iter().map(|&x| x as i64).sum();
        for p in 0..n {
            acc[f * n + p] += -ctx.zp_w * sum_a[p] - ctx.zp_a * sum_w + kzz + bias[f] as i64;
        }
    }
    acc
}

fn maxpool2(x: &Tensor) -> Tensor {
    let (oh, ow) = (x.h / 2, x.w / 2);
    let mut out = Tensor::new(oh, ow, x.c);
    for oy in 0..oh {
        for ox in 0..ow {
            for c in 0..x.c {
                let v = x
                    .at(oy * 2, ox * 2, c)
                    .max(x.at(oy * 2, ox * 2 + 1, c))
                    .max(x.at(oy * 2 + 1, ox * 2, c))
                    .max(x.at(oy * 2 + 1, ox * 2 + 1, c));
                out.set(oy, ox, c, v);
            }
        }
    }
    out
}

fn gap(x: &Tensor) -> Tensor {
    let npix = (x.h * x.w) as i64;
    let mut out = Tensor::new(1, 1, x.c);
    for c in 0..x.c {
        let mut s = 0i64;
        for y in 0..x.h {
            for xx in 0..x.w {
                s += x.at(y, xx, c) as i64;
            }
        }
        // mirror python: (sum*2 + npix) // (2*npix)  (round-half-up, nonneg)
        out.data[c] = ((s * 2 + npix) / (2 * npix)) as u8;
    }
    out
}

fn add(a: &Tensor, b: &Tensor, s1: f64, z1: i32, s2: f64, z2: i32, node: &Node) -> Tensor {
    let s_out = node.out_scale as f64;
    let zp_out = node.out_zp;
    let lo = if node.relu { zp_out.clamp(0, 255) as f64 } else { 0.0 };
    let mut out = Tensor::new(a.h, a.w, a.c);
    for (o, (&qa, &qb)) in out.data.iter_mut().zip(a.data.iter().zip(&b.data)) {
        let acc = (qa as f64 - z1 as f64) * s1 + (qb as f64 - z2 as f64) * s2;
        let q = round_half_away(acc / s_out) + zp_out as f64;
        *o = q.clamp(lo, 255.0) as u8;
    }
    out
}

fn concat(parts: &[(&Tensor, f64, i32)], node: &Node) -> Tensor {
    let s_out = node.out_scale as f64;
    let zp_out = node.out_zp;
    let (h, w, c) = node.out_shape;
    let mut out = Tensor::new(h, w, c);
    let mut c_off = 0;
    for &(t, s_j, z_j) in parts {
        let ratio = s_j / s_out; // mirror python: (q - z) * (s_j / s_out)
        for y in 0..h {
            for x in 0..w {
                for cc in 0..t.c {
                    let q = round_half_away((t.at(y, x, cc) as f64 - z_j as f64) * ratio)
                        + zp_out as f64;
                    out.set(y, x, c_off + cc, q.clamp(0.0, 255.0) as u8);
                }
            }
        }
        c_off += t.c;
    }
    out
}

fn shuffle(x: &Tensor, groups: usize) -> Tensor {
    let cpg = x.c / groups;
    let mut out = Tensor::new(x.h, x.w, x.c);
    for y in 0..x.h {
        for xx in 0..x.w {
            for gi in 0..groups {
                for p in 0..cpg {
                    // python: out[.., p*g + gi] = in[.., gi*cpg + p]
                    out.set(y, xx, p * groups + gi, x.at(y, xx, gi * cpg + p));
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::graph::Weights;
    use crate::util::rng::Rng;

    /// Tiny synthetic model: input(4,4,3) -> conv3x3(8, relu) -> dense(5).
    /// Output scales are chosen so requantized values stay inside the u8
    /// range (non-saturating) while exercising both MAC layer kinds.
    fn toy_model() -> Model {
        let mut rng = Rng::new(0xE2E);
        let input = Node {
            op: Op::Input,
            relu: false,
            inputs: vec![],
            out_shape: (4, 4, 3),
            out_scale: 1.0,
            out_zp: 0,
            cout: 0,
            ksize: 0,
            stride: 1,
            pad: 0,
            groups: 1,
            weights: None,
        };
        let conv = Node {
            op: Op::Conv,
            relu: true,
            inputs: vec![0],
            out_shape: (4, 4, 8),
            out_scale: 4096.0,
            out_zp: 0,
            cout: 8,
            ksize: 3,
            stride: 1,
            pad: 1,
            groups: 1,
            weights: Some(Weights {
                w_q: (0..8 * 27).map(|_| rng.u8()).collect(),
                k_dim: 27,
                b_q: vec![0; 8],
                s_w: 1.0,
                zp_w: 7,
            }),
        };
        let dense = Node {
            op: Op::Dense,
            relu: false,
            inputs: vec![1],
            out_shape: (1, 1, 5),
            // mult = s_w * s_in / s_out = 4096 / 7e7 ≈ 5.9e-5: keeps the
            // ~±1.6M dense accumulators inside the u8 range around zp=128.
            out_scale: 7.0e7,
            out_zp: 128,
            cout: 5,
            ksize: 0,
            stride: 1,
            pad: 0,
            groups: 1,
            weights: Some(Weights {
                w_q: (0..5 * 4 * 4 * 8).map(|_| rng.u8()).collect(),
                k_dim: 4 * 4 * 8,
                b_q: vec![0; 5],
                s_w: 1.0,
                zp_w: 3,
            }),
        };
        Model { name: "toy".into(), n_classes: 5, nodes: vec![input, conv, dense] }
    }

    fn toy_image() -> Tensor {
        let mut rng = Rng::new(0x1136);
        Tensor::from_data(4, 4, 3, (0..4 * 4 * 3).map(|_| rng.u8()).collect())
    }

    #[test]
    fn plan_built_once_across_forwards() {
        let engine = Engine::new(toy_model());
        let img = toy_image();
        let opts = ForwardOpts::approx(Family::Perforated, 2, true);
        assert_eq!(engine.plan_builds(), 0);
        let first = engine.forward(&img, &opts).unwrap();
        assert_eq!(engine.plan_builds(), 2, "one plan per MAC layer");
        let second = engine.forward(&img, &opts).unwrap();
        let third = engine.forward(&img, &opts).unwrap();
        assert_eq!(engine.plan_builds(), 2, "steady state builds no plans");
        assert_eq!(first, second);
        assert_eq!(second, third);
        // A different design point builds its own plans once.
        let opts3 = ForwardOpts::approx(Family::Truncated, 6, true);
        engine.forward(&img, &opts3).unwrap();
        assert_eq!(engine.plan_builds(), 4);
        engine.forward(&img, &opts3).unwrap();
        assert_eq!(engine.plan_builds(), 4);
    }

    #[test]
    fn prepare_plans_prewarms_the_cache() {
        let engine = Engine::new(toy_model());
        engine.prepare_plans(Family::Recursive, 3);
        assert_eq!(engine.plan_builds(), 2);
        engine
            .forward(&toy_image(), &ForwardOpts::approx(Family::Recursive, 3, true))
            .unwrap();
        assert_eq!(engine.plan_builds(), 2, "forward reuses prewarmed plans");
    }

    #[test]
    fn scratch_reuse_is_transparent() {
        let engine = Engine::new(toy_model());
        let img = toy_image();
        let mut scratch = Scratch::new();
        for family in [Family::Exact, Family::Perforated, Family::Truncated] {
            let m = *family.paper_levels().last().unwrap();
            let opts = ForwardOpts::approx(family, m, true);
            let fresh = engine.forward(&img, &opts).unwrap();
            let reused = engine.forward_with_scratch(&img, &opts, &mut scratch).unwrap();
            let reused2 = engine.forward_with_scratch(&img, &opts, &mut scratch).unwrap();
            assert_eq!(fresh, reused, "{}", family.name());
            assert_eq!(fresh, reused2, "{}", family.name());
        }
    }

    #[test]
    fn round_half_away_matches_python() {
        for (x, want) in [(0.5, 1.0), (1.5, 2.0), (-0.5, -1.0), (-1.5, -2.0), (2.4, 2.0)] {
            assert_eq!(round_half_away(x), want, "{x}");
        }
    }

    #[test]
    fn requantize_clamps_and_rounds() {
        assert_eq!(requantize(-100_000, 0.01, 128), 0);
        assert_eq!(requantize(0, 0.01, 128), 128);
        assert_eq!(requantize(100_000, 0.01, 128), 255);
        assert_eq!(requantize(50, 0.01, 128), 129); // 0.5 rounds away
    }

    #[test]
    fn maxpool_takes_window_max() {
        let t = Tensor::from_data(2, 2, 1, vec![1, 9, 3, 4]);
        let p = maxpool2(&t);
        assert_eq!(p.data, vec![9]);
    }

    #[test]
    fn gap_rounds_half_up() {
        // sum=3 over 2 pixels -> 1.5 -> 2
        let t = Tensor::from_data(1, 2, 1, vec![1, 2]);
        assert_eq!(gap(&t).data, vec![2]);
    }

    #[test]
    fn shuffle_permutes_channels() {
        // 4 channels, 2 groups: [a0 a1 | b0 b1] -> [a0 b0 a1 b1]
        let t = Tensor::from_data(1, 1, 4, vec![10, 11, 20, 21]);
        let s = shuffle(&t, 2);
        assert_eq!(s.data, vec![10, 20, 11, 21]);
    }

    #[test]
    fn shuffle_twice_with_transpose_groups_restores() {
        let t = Tensor::from_data(1, 1, 6, vec![0, 1, 2, 3, 4, 5]);
        let s = shuffle(&shuffle(&t, 2), 3);
        assert_eq!(s.data, t.data);
    }
}
