//! Per-layer heterogeneous approximation policies (ALWANN-style, runtime).
//!
//! The offline layerwise search (`report::layerwise`) shows that **mixed**
//! per-layer approximation levels dominate uniform ones on the
//! accuracy/power Pareto front. A [`LayerPolicy`] makes that result a
//! first-class runtime concept: one [`LayerAssignment`] per MAC layer
//! (conv/dense, topological order) — either a single [`LayerPoint`]
//! `(family, m, polarity, use_cv)` or a [`PairedPoint`] that splits the
//! layer's reduction dimension between two points by even/odd parity (the
//! positive/negative multiplier pairing of Spantidi et al.: opposite-signed
//! error distributions cancel the accumulated column error before the CV
//! epilogue runs). Because every knob is a *runtime* input of the GEMM
//! engines and the per-layer [`crate::nn::plan::LayerPlan`] cache, serving
//! a mixed or paired policy needs no recompilation: each layer simply
//! resolves its own plan, LUT(s) and CV epilogue from its assignment.
//!
//! Policies serialize two ways (both parsed back by [`LayerPolicy::load`]):
//!
//! * **JSON** — what the greedy searches emit and benches consume:
//!   `{"layers": [{"family": "perforated", "m": 2, "polarity": "neg",
//!   "use_cv": true}, {"paired": {"even": {...}, "odd": {...}}}, ...]}`
//! * **text** — one line per layer for hand-written files:
//!   `perforated 2 cv` / `truncated 6 pos nocv` / `exact` /
//!   `paired perforated 2 cv + perforated 2 pos cv`, with `#` comments.
//!
//! Validation is split so errors surface at the right level: structural
//! validity (`m ≤ 7`, approximate families need `m ≥ 1`) at parse/build
//! time, and the layer-count match against a concrete model
//! ([`LayerPolicy::validate_for`]) at engine / coordinator entry, where it
//! returns `Err` instead of poisoning a worker.

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::graph::Model;
use crate::approx::{Family, Polarity};
use crate::util::json::Json;
use crate::util::sync::lock_clean;

/// Highest meaningful approximation level for 8-bit operands.
pub const MAX_M: u32 = 7;

/// One multiplier design point: `(family, m, polarity, use_cv)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct LayerPoint {
    pub family: Family,
    pub m: u32,
    pub use_cv: bool,
    /// Signed-error direction; `Neg` is the paper-original design, `Pos`
    /// the round-up mirror (see [`crate::approx::Polarity`]).
    pub polarity: Polarity,
}

impl LayerPoint {
    /// The exact (baseline) point.
    pub const EXACT: LayerPoint = LayerPoint {
        family: Family::Exact,
        m: 0,
        use_cv: false,
        polarity: Polarity::Neg,
    };

    /// Negative-polarity (paper-original) point.
    pub fn new(family: Family, m: u32, use_cv: bool) -> LayerPoint {
        LayerPoint { family, m, use_cv, polarity: Polarity::Neg }
    }

    /// Point with an explicit polarity.
    pub fn new_pol(family: Family, m: u32, pol: Polarity, use_cv: bool) -> LayerPoint {
        LayerPoint { family, m, use_cv, polarity: pol }
    }

    /// Canonical form: `m == 0` or the exact family both mean "run exact"
    /// — collapse them to [`LayerPoint::EXACT`] so plan-cache keys and
    /// equality checks agree with the engine's effective behaviour.
    pub fn normalized(self) -> LayerPoint {
        if self.family == Family::Exact || self.m == 0 {
            LayerPoint::EXACT
        } else {
            self
        }
    }

    /// Structural validity: `m ≤ 7` always; approximate families need
    /// `m ≥ 1` unless the point normalizes to exact.
    pub fn validate(&self) -> Result<()> {
        if self.m > MAX_M {
            bail!(
                "m = {} out of range for {} (max {MAX_M} for 8-bit operands)",
                self.m,
                self.family.name()
            );
        }
        if self.family == Family::Exact && self.m != 0 {
            bail!("exact family takes m = 0, got m = {}", self.m);
        }
        if self.family == Family::Exact && self.polarity != Polarity::Neg {
            bail!("exact family has no positive-polarity variant");
        }
        Ok(())
    }

    fn to_json(self) -> Json {
        Json::obj()
            .field("family", self.family.name())
            .field("m", self.m as i64)
            .field("polarity", self.polarity.name())
            .field("use_cv", self.use_cv)
    }

    fn from_json(j: &Json) -> Result<LayerPoint> {
        let name = j
            .get("family")
            .and_then(|f| f.as_str())
            .context("layer entry missing \"family\"")?;
        let family = Family::from_name(name)
            .with_context(|| format!("unknown family name {name:?}"))?;
        let m = j.get("m").and_then(|m| m.as_f64()).context("layer entry missing \"m\"")?;
        if m < 0.0 || m.fract() != 0.0 || m > 255.0 {
            bail!("bad m {m} in layer entry");
        }
        // An omitted polarity means the paper-original negative design, so
        // every pre-pairing policy document parses unchanged.
        let polarity = match j.get("polarity") {
            None => Polarity::Neg,
            Some(p) => {
                let s = p.as_str().context("\"polarity\" must be a string")?;
                Polarity::from_name(s)
                    .with_context(|| format!("unknown polarity {s:?}"))?
            }
        };
        // An omitted use_cv defaults to ON for approximate points — the
        // same rule as the text format (`perforated 3` == `perforated 3
        // cv`), so a hand-written policy behaves identically in either
        // serialization. (What the search emits always writes it.)
        let use_cv = j
            .get("use_cv")
            .and_then(|c| c.as_bool())
            .unwrap_or(family != Family::Exact);
        let p = LayerPoint { family, m: m as u32, use_cv, polarity };
        p.validate()?;
        Ok(p)
    }

    /// One point spec from text tokens: `exact` or
    /// `<family> <m> [pos|neg] [cv|nocv]` (option order free).
    fn parse_tokens<'a>(mut parts: impl Iterator<Item = &'a str>) -> Result<LayerPoint> {
        let name = parts.next().context("empty point spec")?;
        let family =
            Family::from_name(name).with_context(|| format!("unknown family name {name:?}"))?;
        let point = if family == Family::Exact {
            LayerPoint::EXACT
        } else {
            let m: u32 = parts.next().context("missing m")?.parse().context("bad m")?;
            let mut polarity = None;
            let mut use_cv = None;
            for tok in parts.by_ref() {
                match tok {
                    "pos" | "neg" if polarity.is_none() => {
                        polarity = Polarity::from_name(tok);
                    }
                    "cv" if use_cv.is_none() => use_cv = Some(true),
                    "nocv" if use_cv.is_none() => use_cv = Some(false),
                    other => bail!("unexpected token {other:?} in point spec"),
                }
            }
            LayerPoint::new_pol(
                family,
                m,
                polarity.unwrap_or(Polarity::Neg),
                use_cv.unwrap_or(true),
            )
        };
        if let Some(extra) = parts.next() {
            bail!("trailing token {extra:?}");
        }
        point.validate()?;
        Ok(point)
    }

    fn to_text(self) -> String {
        let p = self.normalized();
        if p == LayerPoint::EXACT {
            "exact".to_string()
        } else {
            let pol = if p.polarity == Polarity::Pos { " pos" } else { "" };
            format!(
                "{} {}{pol} {}",
                p.family.name(),
                p.m,
                if p.use_cv { "cv" } else { "nocv" }
            )
        }
    }

    /// Compact human-readable form, e.g. `perforated:3+V` / `truncated:6:pos`.
    pub fn describe(self) -> String {
        let p = self.normalized();
        if p == LayerPoint::EXACT {
            "exact".to_string()
        } else {
            format!(
                "{}:{}{}{}",
                p.family.name(),
                p.m,
                if p.polarity == Polarity::Pos { ":pos" } else { "" },
                if p.use_cv { "+V" } else { "" }
            )
        }
    }
}

/// A positive/negative multiplier pairing for one layer: even reduction
/// indices (even systolic columns) run `even`, odd ones run `odd`. Pairing
/// a point with its polarity mirror cancels the accumulated column error in
/// expectation *before* the CV epilogue.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PairedPoint {
    pub even: LayerPoint,
    pub odd: LayerPoint,
}

impl PairedPoint {
    pub fn new(even: LayerPoint, odd: LayerPoint) -> PairedPoint {
        PairedPoint { even, odd }
    }

    /// The canonical cancelling pair at one `(family, m)`: Neg on even
    /// columns, its Pos mirror on odd ones.
    pub fn mirrored(family: Family, m: u32, use_cv: bool) -> PairedPoint {
        PairedPoint {
            even: LayerPoint::new_pol(family, m, Polarity::Neg, use_cv),
            odd: LayerPoint::new_pol(family, m, Polarity::Pos, use_cv),
        }
    }

    pub fn normalized(self) -> PairedPoint {
        PairedPoint { even: self.even.normalized(), odd: self.odd.normalized() }
    }

    pub fn validate(&self) -> Result<()> {
        self.even.validate().context("even half")?;
        self.odd.validate().context("odd half")?;
        Ok(())
    }

    pub fn describe(self) -> String {
        format!("pair({} / {})", self.even.describe(), self.odd.describe())
    }
}

/// What one MAC layer runs: a single point, or an even/odd pairing.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LayerAssignment {
    Point(LayerPoint),
    Paired(PairedPoint),
}

impl LayerAssignment {
    /// Canonical form: points normalize as usual; a pairing whose both
    /// halves normalize to exact *is* the exact layer (bit-identical — no
    /// error term, no V — so collapsing keeps plan-cache keys honest).
    pub fn normalized(self) -> LayerAssignment {
        match self {
            LayerAssignment::Point(p) => LayerAssignment::Point(p.normalized()),
            LayerAssignment::Paired(pp) => {
                let pp = pp.normalized();
                if pp.even == LayerPoint::EXACT && pp.odd == LayerPoint::EXACT {
                    LayerAssignment::Point(LayerPoint::EXACT)
                } else {
                    LayerAssignment::Paired(pp)
                }
            }
        }
    }

    pub fn validate(&self) -> Result<()> {
        match self {
            LayerAssignment::Point(p) => p.validate(),
            LayerAssignment::Paired(pp) => pp.validate(),
        }
    }

    /// Does this layer effectively run exact?
    pub fn is_exact(self) -> bool {
        self.normalized() == LayerAssignment::Point(LayerPoint::EXACT)
    }

    /// The single point, when this is not a pairing.
    pub fn as_point(self) -> Option<LayerPoint> {
        match self {
            LayerAssignment::Point(p) => Some(p),
            LayerAssignment::Paired(_) => None,
        }
    }

    /// The i32-headroom ceiling on the reduction depth K of a layer running
    /// this assignment: the tightest [`super::gemm::max_k_for_point`] over
    /// the constituents (a pairing is as constrained as its tighter half —
    /// both halves accumulate over the full-K panel layout).
    pub fn max_k(self) -> usize {
        self.constituents()
            .map(|p| super::gemm::max_k_for_point(p.normalized()))
            .min()
            .expect("an assignment has at least one constituent")
    }

    /// The constituent points (one for a plain layer, two for a pairing) —
    /// what LUT preparation and power labeling iterate over.
    pub fn constituents(self) -> impl Iterator<Item = LayerPoint> {
        let (a, b) = match self {
            LayerAssignment::Point(p) => (p, None),
            LayerAssignment::Paired(pp) => (pp.even, Some(pp.odd)),
        };
        std::iter::once(a).chain(b)
    }

    fn to_json(self) -> Json {
        match self {
            LayerAssignment::Point(p) => p.to_json(),
            LayerAssignment::Paired(pp) => Json::obj().field(
                "paired",
                Json::obj()
                    .field("even", pp.even.to_json())
                    .field("odd", pp.odd.to_json()),
            ),
        }
    }

    fn from_json(j: &Json) -> Result<LayerAssignment> {
        match j.get("paired") {
            Some(pj) => {
                let even = pj
                    .get("even")
                    .context("paired entry missing \"even\"")
                    .and_then(LayerPoint::from_json)
                    .context("even half")?;
                let odd = pj
                    .get("odd")
                    .context("paired entry missing \"odd\"")
                    .and_then(LayerPoint::from_json)
                    .context("odd half")?;
                Ok(LayerAssignment::Paired(PairedPoint { even, odd }))
            }
            None => Ok(LayerAssignment::Point(LayerPoint::from_json(j)?)),
        }
    }

    pub fn describe(self) -> String {
        match self.normalized() {
            LayerAssignment::Point(p) => p.describe(),
            LayerAssignment::Paired(pp) => pp.describe(),
        }
    }
}

/// A per-MAC-layer approximation assignment: entry `i` configures the i-th
/// conv/dense layer in topological order (the ordinal the engine's plan
/// cache and `Model::mac_node_indices` use).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LayerPolicy {
    layers: Vec<LayerAssignment>,
}

impl LayerPolicy {
    /// Build from explicit points; structurally validates every entry.
    pub fn new(layers: Vec<LayerPoint>) -> Result<LayerPolicy> {
        LayerPolicy::from_assignments(
            layers.into_iter().map(LayerAssignment::Point).collect(),
        )
    }

    /// Build from explicit assignments (points and/or pairings).
    pub fn from_assignments(layers: Vec<LayerAssignment>) -> Result<LayerPolicy> {
        if layers.is_empty() {
            bail!("a layer policy needs at least one layer");
        }
        for (i, a) in layers.iter().enumerate() {
            a.validate().with_context(|| format!("layer {i}"))?;
        }
        Ok(LayerPolicy { layers })
    }

    /// The trivial policy: every one of `n_layers` at the same point.
    pub fn uniform(family: Family, m: u32, use_cv: bool, n_layers: usize) -> Result<LayerPolicy> {
        LayerPolicy::new(vec![LayerPoint::new(family, m, use_cv); n_layers.max(1)])
    }

    /// Every one of `n_layers` at the mirrored Neg/Pos pairing of one
    /// `(family, m)` — the canonical cancelling configuration.
    pub fn paired_uniform(
        family: Family,
        m: u32,
        use_cv: bool,
        n_layers: usize,
    ) -> Result<LayerPolicy> {
        LayerPolicy::from_assignments(vec![
            LayerAssignment::Paired(PairedPoint::mirrored(
                family, m, use_cv
            ));
            n_layers.max(1)
        ])
    }

    /// A per-layer-m policy at one family (the layerwise-search shape):
    /// `ms[i] == 0` runs layer `i` exact.
    pub fn from_ms(family: Family, ms: &[u32], use_cv: bool) -> Result<LayerPolicy> {
        LayerPolicy::new(
            ms.iter()
                .map(|&m| LayerPoint::new(family, m, use_cv).normalized())
                .collect(),
        )
    }

    pub fn len(&self) -> usize {
        self.layers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }

    /// The assignment for MAC layer ordinal `mac_idx` (normalized).
    pub fn assignment(&self, mac_idx: usize) -> LayerAssignment {
        self.layers[mac_idx].normalized()
    }

    /// The single point for MAC layer ordinal `mac_idx` (normalized).
    /// Panics on a paired layer — callers that may see pairings use
    /// [`LayerPolicy::assignment`].
    pub fn point(&self, mac_idx: usize) -> LayerPoint {
        self.assignment(mac_idx)
            .as_point()
            .expect("point() on a paired layer — use assignment()")
    }

    /// Normalized assignments, one per layer.
    pub fn assignments(&self) -> impl Iterator<Item = LayerAssignment> + '_ {
        self.layers.iter().map(|a| a.normalized())
    }

    /// Every constituent point of the policy (paired layers contribute
    /// both halves) — the set LUT preparation and power labeling walk.
    pub fn points(&self) -> impl Iterator<Item = LayerPoint> + '_ {
        self.assignments().flat_map(|a| a.constituents())
    }

    /// `Some(point)` when every layer is the same single (non-paired)
    /// point — such a policy is semantically identical to uniform
    /// `ForwardOpts` (property-tested bit-identical in the engine suite).
    pub fn as_uniform(&self) -> Option<LayerPoint> {
        let first = self.assignment(0).as_point()?;
        self.assignments()
            .all(|a| a == LayerAssignment::Point(first))
            .then_some(first)
    }

    /// Number of layers that actually run approximate.
    pub fn approx_layers(&self) -> usize {
        self.assignments().filter(|a| !a.is_exact()).count()
    }

    /// Number of layers running an even/odd pairing.
    pub fn paired_layers(&self) -> usize {
        self.assignments()
            .filter(|a| matches!(a, LayerAssignment::Paired(_)))
            .count()
    }

    /// Check this policy against a concrete model: one entry per MAC layer,
    /// and every layer's reduction depth K inside the i32-headroom ceiling
    /// of its assignment ([`LayerAssignment::max_k`]). Rejecting oversized
    /// K here — at engine entry, plan prewarm and policy install — is what
    /// keeps the accumulation asserts in `nn/gemm.rs` unreachable backstops
    /// instead of mid-batch panics inside a serving worker.
    pub fn validate_for(&self, model: &Model) -> Result<()> {
        let want = model.mac_layers();
        if self.layers.len() != want {
            bail!(
                "policy has {} layers but model {:?} has {} MAC layers",
                self.layers.len(),
                model.name,
                want
            );
        }
        for (i, (assignment, k)) in
            self.assignments().zip(model.mac_layer_kdims()).enumerate()
        {
            let cap = assignment.max_k();
            if k > cap {
                bail!(
                    "MAC layer {i} has K = {k}, above the i32-headroom \
                     ceiling {cap} of {} — run this layer exact or at \
                     negative polarity",
                    assignment.describe()
                );
            }
        }
        Ok(())
    }

    /// MAC-weighted normalized power of this policy on `model` at array
    /// size `n_array`: approximate layers cost their family's
    /// `array_cost(m).power_norm`, exact layers cost 1.0, and a paired
    /// layer blends its two halves **by partition population** — the even
    /// half owns `ceil(k/2)` of the layer's reduction indices (hence of its
    /// MACs), the odd half `floor(k/2)`, so an odd reduction length weighs
    /// the even point heavier instead of the naive 50/50 split. (`Pos`
    /// variants are costed at their `Neg` point — the round-up compensation
    /// is a handful of gates against the pruned columns, see README
    /// §Pairing.) This is the serving metrics' estimated-power quantity
    /// (and the layerwise report's, and the QoS ladder's per-rung cost).
    pub fn power_norm(&self, model: &Model, n_array: u32) -> f64 {
        fn point_power(p: LayerPoint, n_array: u32) -> f64 {
            if p == LayerPoint::EXACT {
                1.0
            } else {
                crate::hw::array_cost(p.family, p.m, n_array).power_norm
            }
        }
        let macs = model.mac_layer_macs();
        let kdims = model.mac_layer_kdims();
        debug_assert_eq!(macs.len(), self.layers.len(), "call validate_for first");
        let total: u64 = macs.iter().sum();
        if total == 0 {
            return 1.0;
        }
        let weighted: f64 = self
            .assignments()
            .zip(macs.iter().zip(&kdims))
            .map(|(a, (&w, &k))| {
                let pn = match a {
                    LayerAssignment::Point(p) => point_power(p, n_array),
                    LayerAssignment::Paired(pp) => {
                        // Even columns are reduction indices 0, 2, 4, … —
                        // ceil(k/2) of the k MACs each output accumulates.
                        let k = k.max(1) as f64;
                        let k_even = (k / 2.0).ceil();
                        (k_even * point_power(pp.even, n_array)
                            + (k - k_even) * point_power(pp.odd, n_array))
                            / k
                    }
                };
                pn * w as f64
            })
            .sum();
        weighted / total as f64
    }

    // ---- serialization -------------------------------------------------

    pub fn to_json(&self) -> Json {
        Json::obj()
            .field("n_layers", self.layers.len())
            .field(
                "layers",
                Json::Arr(self.layers.iter().map(|a| a.to_json()).collect()),
            )
    }

    pub fn from_json(j: &Json) -> Result<LayerPolicy> {
        let layers = j
            .get("layers")
            .and_then(|l| l.as_arr())
            .context("policy JSON missing \"layers\" array")?;
        let assignments = layers
            .iter()
            .enumerate()
            .map(|(i, e)| {
                LayerAssignment::from_json(e).with_context(|| format!("layer {i}"))
            })
            .collect::<Result<Vec<_>>>()?;
        LayerPolicy::from_assignments(assignments)
    }

    /// One line per layer: `<family> <m> [pos] <cv|nocv>`, bare `exact`, or
    /// `paired <spec> + <spec>`.
    pub fn to_text(&self) -> String {
        let mut s = String::from("# per-layer approximation policy: one MAC layer per line\n");
        for a in &self.layers {
            match a.normalized() {
                LayerAssignment::Point(p) => {
                    s.push_str(&p.to_text());
                }
                LayerAssignment::Paired(pp) => {
                    s.push_str(&format!(
                        "paired {} + {}",
                        pp.even.to_text(),
                        pp.odd.to_text()
                    ));
                }
            }
            s.push('\n');
        }
        s
    }

    pub fn parse_text(text: &str) -> Result<LayerPolicy> {
        let mut assignments = Vec::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let ctx = |e: anyhow::Error| e.context(format!("line {}", lineno + 1));
            let assignment = if let Some(rest) = line.strip_prefix("paired ") {
                let halves: Vec<&str> = rest.split('+').map(str::trim).collect();
                if halves.len() != 2 {
                    bail!(
                        "line {}: paired spec needs exactly two '+'-separated halves",
                        lineno + 1
                    );
                }
                let even =
                    LayerPoint::parse_tokens(halves[0].split_whitespace()).map_err(ctx)?;
                let odd =
                    LayerPoint::parse_tokens(halves[1].split_whitespace()).map_err(ctx)?;
                LayerAssignment::Paired(PairedPoint { even, odd })
            } else {
                LayerAssignment::Point(
                    LayerPoint::parse_tokens(line.split_whitespace()).map_err(ctx)?,
                )
            };
            assignments.push(assignment);
        }
        LayerPolicy::from_assignments(assignments)
    }

    /// Parse either serialization (sniffed: JSON starts with `{`).
    pub fn parse(text: &str) -> Result<LayerPolicy> {
        if text.trim_start().starts_with('{') {
            LayerPolicy::from_json(&Json::parse(text).context("policy JSON")?)
        } else {
            LayerPolicy::parse_text(text)
        }
    }

    /// Load a policy file (JSON or text — see [`LayerPolicy::parse`]).
    pub fn load(path: &Path) -> Result<LayerPolicy> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading policy {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing policy {}", path.display()))
    }

    /// Write the JSON form (what `cvapprox layerwise` emits).
    pub fn save_json(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().render())
            .with_context(|| format!("writing policy {}", path.display()))
    }

    /// Compact human-readable summary, e.g.
    /// `[perforated:3+V, pair(perforated:3+V / perforated:3:pos+V), exact]`.
    pub fn describe(&self) -> String {
        let parts: Vec<String> = self.assignments().map(|a| a.describe()).collect();
        format!("[{}]", parts.join(", "))
    }
}

/// Shared-ownership alias — the engine, coordinator and every worker hold
/// the same immutable policy.
pub type SharedPolicy = Arc<LayerPolicy>;

/// An epoch-stamped policy generation: what a serving worker captures at
/// batch start. `policy == None` means "run the service's uniform
/// (family, m, use_cv) configuration". Epochs are totally ordered and
/// unique per [`PolicySwitch`], so a reply stamped with `epoch` identifies
/// exactly one installed generation — the anchor for the hot-swap
/// bit-identity property (no batch ever mixes two generations: the stamp
/// and the policy travel together in one `Arc`).
#[derive(Clone, Debug)]
pub struct StampedPolicy {
    pub epoch: u64,
    pub policy: Option<SharedPolicy>,
}

/// Hot-swappable policy slot shared by a worker pool and its governor.
///
/// `load` is what every worker calls once per batch: it clones the current
/// `Arc` under a Mutex held for nanoseconds (no allocation, no wait on
/// installs beyond that clone), so a swap never stalls the pool — in-flight
/// batches complete on the stamped generation they captured, new batches
/// pick up the new one. `install` bumps the epoch and publishes atomically
/// (same lock), so no two generations ever share a stamp.
#[derive(Debug)]
pub struct PolicySwitch {
    cur: std::sync::Mutex<Arc<StampedPolicy>>,
}

impl PolicySwitch {
    /// Slot holding generation 0 (the configuration the service started
    /// with).
    pub fn new(policy: Option<SharedPolicy>) -> PolicySwitch {
        PolicySwitch {
            cur: std::sync::Mutex::new(Arc::new(StampedPolicy { epoch: 0, policy })),
        }
    }

    /// The current stamped generation (workers call this per batch).
    pub fn load(&self) -> Arc<StampedPolicy> {
        lock_clean(&self.cur).clone()
    }

    /// Publish a new generation; returns its (fresh, unique) epoch.
    pub fn install(&self, policy: Option<SharedPolicy>) -> u64 {
        let mut g = lock_clean(&self.cur);
        let epoch = g.epoch + 1;
        *g = Arc::new(StampedPolicy { epoch, policy });
        epoch
    }

    /// Epoch of the current generation.
    pub fn epoch(&self) -> u64 {
        lock_clean(&self.cur).epoch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::testutil;

    #[test]
    fn uniform_policy_is_uniform() {
        let p = LayerPolicy::uniform(Family::Perforated, 2, true, 3).unwrap();
        assert_eq!(p.len(), 3);
        assert_eq!(
            p.as_uniform(),
            Some(LayerPoint::new(Family::Perforated, 2, true))
        );
        assert_eq!(p.approx_layers(), 3);
        assert_eq!(p.paired_layers(), 0);
    }

    #[test]
    fn m_zero_normalizes_to_exact() {
        let p = LayerPolicy::from_ms(Family::Truncated, &[6, 0], true).unwrap();
        assert_eq!(p.point(0), LayerPoint::new(Family::Truncated, 6, true));
        assert_eq!(p.point(1), LayerPoint::EXACT);
        assert_eq!(p.approx_layers(), 1);
        assert!(p.as_uniform().is_none());
        // all-zero ms normalize to a uniform exact policy
        let z = LayerPolicy::from_ms(Family::Perforated, &[0, 0], true).unwrap();
        assert_eq!(z.as_uniform(), Some(LayerPoint::EXACT));
    }

    #[test]
    fn structural_validation_rejects_bad_points() {
        assert!(LayerPolicy::uniform(Family::Perforated, 8, true, 2).is_err());
        assert!(LayerPoint::new(Family::Exact, 3, false).validate().is_err());
        assert!(LayerPolicy::new(vec![]).is_err());
        assert!(LayerPoint::new(Family::Recursive, 7, true).validate().is_ok());
        // exact family has no positive variant
        assert!(LayerPoint::new_pol(Family::Exact, 0, Polarity::Pos, false)
            .validate()
            .is_err());
        // paired halves are validated individually
        let bad = PairedPoint::new(
            LayerPoint::new(Family::Perforated, 9, true),
            LayerPoint::new(Family::Perforated, 2, true),
        );
        assert!(bad.validate().is_err());
        assert!(LayerPolicy::from_assignments(vec![LayerAssignment::Paired(bad)]).is_err());
    }

    #[test]
    fn paired_assignment_normalizes() {
        // both halves exact -> the exact point
        let pp = PairedPoint::new(
            LayerPoint::new(Family::Perforated, 0, true),
            LayerPoint::new(Family::Exact, 0, false),
        );
        assert!(LayerAssignment::Paired(pp).is_exact());
        assert_eq!(
            LayerAssignment::Paired(pp).normalized(),
            LayerAssignment::Point(LayerPoint::EXACT)
        );
        // half-exact pairings stay paired (half the columns run approximate)
        let half = PairedPoint::new(
            LayerPoint::new(Family::Perforated, 2, true),
            LayerPoint::new(Family::Exact, 0, false),
        );
        assert!(!LayerAssignment::Paired(half).is_exact());
        // a mirrored pairing keeps both halves
        let m = PairedPoint::mirrored(Family::Truncated, 6, true);
        assert_eq!(m.even.polarity, Polarity::Neg);
        assert_eq!(m.odd.polarity, Polarity::Pos);
        assert_eq!((m.even.family, m.even.m), (m.odd.family, m.odd.m));
    }

    #[test]
    fn paired_uniform_policy_counts() {
        let p = LayerPolicy::paired_uniform(Family::Perforated, 3, true, 4).unwrap();
        assert_eq!(p.len(), 4);
        assert_eq!(p.approx_layers(), 4);
        assert_eq!(p.paired_layers(), 4);
        assert!(p.as_uniform().is_none());
        // constituent points: 2 per layer, both polarities present
        assert_eq!(p.points().count(), 8);
        assert!(p.points().any(|pt| pt.polarity == Polarity::Pos));
        assert!(p.points().any(|pt| pt.polarity == Polarity::Neg));
    }

    #[test]
    fn json_roundtrip_preserves_points() {
        let p = LayerPolicy::new(vec![
            LayerPoint::new(Family::Perforated, 3, true),
            LayerPoint::EXACT,
            LayerPoint::new(Family::Truncated, 6, false),
        ])
        .unwrap();
        let j = p.to_json().render();
        let back = LayerPolicy::parse(&j).unwrap();
        assert_eq!(back, p);
        assert_eq!(
            j.contains("\"family\": \"perforated\""),
            true,
            "stable field names: {j}"
        );
    }

    #[test]
    fn json_roundtrip_preserves_polarity_and_pairing() {
        let p = LayerPolicy::from_assignments(vec![
            LayerAssignment::Point(LayerPoint::new_pol(
                Family::Recursive,
                3,
                Polarity::Pos,
                true,
            )),
            LayerAssignment::Paired(PairedPoint::mirrored(Family::Perforated, 2, true)),
            LayerAssignment::Paired(PairedPoint::new(
                LayerPoint::new(Family::Truncated, 6, false),
                LayerPoint::new_pol(Family::Truncated, 5, Polarity::Pos, true),
            )),
            LayerAssignment::Point(LayerPoint::EXACT),
        ])
        .unwrap();
        let j = p.to_json().render();
        assert!(j.contains("\"polarity\": \"pos\""), "{j}");
        assert!(j.contains("\"paired\""), "{j}");
        let back = LayerPolicy::parse(&j).unwrap();
        assert_eq!(back, p);
        // And through the text form too.
        let back_text = LayerPolicy::parse(&p.to_text()).unwrap();
        assert_eq!(back_text.describe(), p.describe());
    }

    #[test]
    fn text_roundtrip_preserves_points() {
        let p = LayerPolicy::new(vec![
            LayerPoint::new(Family::Recursive, 4, false),
            LayerPoint::EXACT,
            LayerPoint::new(Family::Perforated, 1, true),
        ])
        .unwrap();
        let back = LayerPolicy::parse(&p.to_text()).unwrap();
        assert_eq!(back, p);
    }

    #[test]
    fn text_parser_accepts_comments_and_defaults_cv() {
        let p = LayerPolicy::parse_text(
            "# header\nperforated 2   # inline comment, cv defaults on\n\nexact\n",
        )
        .unwrap();
        assert_eq!(p.point(0), LayerPoint::new(Family::Perforated, 2, true));
        assert_eq!(p.point(1), LayerPoint::EXACT);
    }

    #[test]
    fn text_parser_accepts_polarity_and_paired_lines() {
        let p = LayerPolicy::parse_text(
            "truncated 6 pos nocv\n\
             paired perforated 2 cv + perforated 2 pos cv  # mirror pair\n\
             paired exact + recursive 3 pos\n",
        )
        .unwrap();
        assert_eq!(
            p.point(0),
            LayerPoint::new_pol(Family::Truncated, 6, Polarity::Pos, false)
        );
        assert_eq!(
            p.assignment(1),
            LayerAssignment::Paired(PairedPoint::mirrored(Family::Perforated, 2, true))
        );
        match p.assignment(2) {
            LayerAssignment::Paired(pp) => {
                assert_eq!(pp.even, LayerPoint::EXACT);
                assert_eq!(
                    pp.odd,
                    LayerPoint::new_pol(Family::Recursive, 3, Polarity::Pos, true)
                );
            }
            other => panic!("expected paired, got {other:?}"),
        }
    }

    #[test]
    fn json_omitted_use_cv_defaults_on_like_text() {
        // Both serializations must agree on what an omitted use_cv means:
        // ON for approximate points. An omitted polarity means Neg.
        let p = LayerPolicy::parse(
            "{\"layers\": [{\"family\": \"perforated\", \"m\": 3}, \
             {\"family\": \"exact\", \"m\": 0}]}",
        )
        .unwrap();
        assert_eq!(p.point(0), LayerPoint::new(Family::Perforated, 3, true));
        assert_eq!(p.point(0).polarity, Polarity::Neg);
        assert_eq!(p.point(1), LayerPoint::EXACT);
    }

    #[test]
    fn parsers_reject_malformed_policies() {
        // unknown family name (both formats)
        assert!(LayerPolicy::parse_text("bogus 2 cv").is_err());
        assert!(LayerPolicy::parse(
            "{\"layers\": [{\"family\": \"bogus\", \"m\": 2}]}"
        )
        .is_err());
        // m out of range
        assert!(LayerPolicy::parse_text("perforated 9 cv").is_err());
        assert!(LayerPolicy::parse(
            "{\"layers\": [{\"family\": \"perforated\", \"m\": 9}]}"
        )
        .is_err());
        // structural garbage
        assert!(LayerPolicy::parse_text("perforated two cv").is_err());
        assert!(LayerPolicy::parse_text("perforated 2 maybe").is_err());
        assert!(LayerPolicy::parse_text("perforated 2 cv extra").is_err());
        assert!(LayerPolicy::parse_text("perforated 2 cv nocv").is_err());
        assert!(LayerPolicy::parse_text("perforated 2 pos neg").is_err());
        assert!(LayerPolicy::parse_text("").is_err());
        assert!(LayerPolicy::parse("{\"layers\": []}").is_err());
        assert!(LayerPolicy::parse("{\"nope\": 1}").is_err());
        assert!(LayerPolicy::parse("{\"layers\": [{\"m\": 2}]}").is_err());
        // bad polarity value
        assert!(LayerPolicy::parse(
            "{\"layers\": [{\"family\": \"perforated\", \"m\": 2, \
             \"polarity\": \"sideways\"}]}"
        )
        .is_err());
        // malformed paired specs
        assert!(LayerPolicy::parse_text("paired perforated 2 cv").is_err());
        assert!(LayerPolicy::parse_text(
            "paired perforated 2 cv + perforated 2 cv + exact"
        )
        .is_err());
        assert!(LayerPolicy::parse("{\"layers\": [{\"paired\": {\"even\": \
             {\"family\": \"perforated\", \"m\": 2}}}]}")
        .is_err());
    }

    #[test]
    fn validate_for_checks_layer_count() {
        let model = testutil::tiny_model(); // 2 MAC layers
        let ok = LayerPolicy::uniform(Family::Perforated, 2, true, 2).unwrap();
        assert!(ok.validate_for(&model).is_ok());
        let bad = LayerPolicy::uniform(Family::Perforated, 2, true, 3).unwrap();
        let err = bad.validate_for(&model).unwrap_err();
        assert!(format!("{err:#}").contains("MAC layers"), "{err:#}");
    }

    #[test]
    fn power_norm_is_mac_weighted() {
        let model = testutil::tiny_model();
        let exact = LayerPolicy::uniform(Family::Exact, 0, false, 2).unwrap();
        assert!((exact.power_norm(&model, 64) - 1.0).abs() < 1e-12);
        let uni = LayerPolicy::uniform(Family::Perforated, 3, true, 2).unwrap();
        let p_uni = uni.power_norm(&model, 64);
        let cost = crate::hw::array_cost(Family::Perforated, 3, 64).power_norm;
        assert!((p_uni - cost).abs() < 1e-12, "uniform == array cost");
        // Mixed: strictly between exact and uniform.
        let mixed = LayerPolicy::from_ms(Family::Perforated, &[3, 0], true).unwrap();
        let p_mixed = mixed.power_norm(&model, 64);
        assert!(p_uni < p_mixed && p_mixed < 1.0, "{p_uni} < {p_mixed} < 1");
        // And MAC-weighted: approximating the big layer saves more.
        let macs = model.mac_layer_macs();
        let big_first = macs[0] > macs[1];
        let other = LayerPolicy::from_ms(Family::Perforated, &[0, 3], true).unwrap();
        let p_other = other.power_norm(&model, 64);
        if big_first {
            assert!(p_mixed < p_other);
        } else {
            assert!(p_other < p_mixed);
        }
    }

    #[test]
    fn paired_power_averages_the_halves() {
        let model = testutil::tiny_model();
        // A mirrored pairing costs exactly the uniform point (both halves
        // carry the same (family, m) cost, so the partition weighting
        // cancels).
        let uni = LayerPolicy::uniform(Family::Perforated, 3, true, 2).unwrap();
        let pair = LayerPolicy::paired_uniform(Family::Perforated, 3, true, 2).unwrap();
        let p_uni = uni.power_norm(&model, 64);
        let p_pair = pair.power_norm(&model, 64);
        assert!((p_uni - p_pair).abs() < 1e-12, "{p_uni} vs {p_pair}");
        // A half-exact pairing blends by partition population: the even
        // (approximate) half owns ceil(k/2) of each layer's k reduction
        // indices — 14/27 for tiny_model's conv, 144/288 for its dense —
        // not a flat one half.
        let half = LayerPolicy::from_assignments(vec![
            LayerAssignment::Paired(PairedPoint::new(
                LayerPoint::new(Family::Perforated, 3, true),
                LayerPoint::EXACT,
            ));
            2
        ])
        .unwrap();
        let p_half = half.power_norm(&model, 64);
        let cost = crate::hw::array_cost(Family::Perforated, 3, 64).power_norm;
        let macs = model.mac_layer_macs();
        let kdims = model.mac_layer_kdims();
        let want: f64 = macs
            .iter()
            .zip(&kdims)
            .map(|(&w, &k)| {
                let ke = k.div_ceil(2) as f64;
                w as f64 * (ke * cost + (k as f64 - ke)) / k as f64
            })
            .sum::<f64>()
            / macs.iter().sum::<u64>() as f64;
        assert!((p_half - want).abs() < 1e-12, "{p_half} vs {want}");
        // tiny_model's conv k = 27 is odd, so the blend must sit strictly
        // on the approximate side of the naive 50/50 split.
        assert!(kdims.contains(&27), "test premise: odd-k layer present");
        let naive: f64 = macs
            .iter()
            .map(|&w| w as f64 * 0.5 * (cost + 1.0))
            .sum::<f64>()
            / macs.iter().sum::<u64>() as f64;
        assert!(p_half < naive, "{p_half} !< naive {naive}");
    }

    #[test]
    fn paired_vs_uniform_power_ratio_pinned_on_odd_k() {
        // Regression pin for the paired power-costing bug: on a single
        // conv3x3(cin=1) layer — k = 9, even partition 5/9 — a pairing of
        // (perforated m=3) with an exact odd half must cost exactly
        //   (5·cost(perforated,3) + 4·1.0) / 9
        // of the exact array, i.e. the paired-vs-uniform power ratio is
        //   (5·c + 4) / (9·c).
        let mut model = testutil::tiny_model();
        model.nodes.truncate(2); // input + conv only
        {
            let w = model.nodes[1].weights.as_mut().unwrap();
            w.k_dim = 9;
            w.w_q.truncate(8 * 9);
        }
        assert_eq!(model.mac_layer_kdims(), vec![9]);
        let c = crate::hw::array_cost(Family::Perforated, 3, 64).power_norm;
        let paired = LayerPolicy::from_assignments(vec![LayerAssignment::Paired(
            PairedPoint::new(
                LayerPoint::new(Family::Perforated, 3, true),
                LayerPoint::EXACT,
            ),
        )])
        .unwrap();
        let uniform = LayerPolicy::uniform(Family::Perforated, 3, true, 1).unwrap();
        let p_paired = paired.power_norm(&model, 64);
        let p_uniform = uniform.power_norm(&model, 64);
        assert!((p_paired - (5.0 * c + 4.0) / 9.0).abs() < 1e-12, "{p_paired}");
        assert!((p_uniform - c).abs() < 1e-12);
        let ratio = p_paired / p_uniform;
        assert!(
            (ratio - (5.0 * c + 4.0) / (9.0 * c)).abs() < 1e-12,
            "paired/uniform ratio {ratio}"
        );
        // And the ratio is > 1: half the columns running exact costs more
        // power than the uniform approximate point.
        assert!(ratio > 1.0);
    }

    #[test]
    fn policy_switch_stamps_unique_epochs() {
        let p2 = Arc::new(LayerPolicy::uniform(Family::Perforated, 2, true, 2).unwrap());
        let p6 = Arc::new(LayerPolicy::uniform(Family::Truncated, 6, true, 2).unwrap());
        let sw = PolicySwitch::new(None);
        assert_eq!(sw.epoch(), 0);
        assert!(sw.load().policy.is_none());
        let e1 = sw.install(Some(p2.clone()));
        assert_eq!(e1, 1);
        let got = sw.load();
        assert_eq!(got.epoch, 1);
        assert_eq!(got.policy.as_deref(), Some(&*p2));
        let e2 = sw.install(Some(p6));
        assert_eq!(e2, 2);
        assert_eq!(sw.epoch(), 2);
        // Re-installing a previous policy still gets a FRESH epoch — the
        // stamp identifies the installation, not the policy value.
        let e3 = sw.install(Some(p2));
        assert_eq!(e3, 3);
        let e4 = sw.install(None);
        assert_eq!(e4, 4);
        assert!(sw.load().policy.is_none());
    }

    #[test]
    fn policy_switch_loads_are_consistent_under_concurrent_installs() {
        // Every load must observe a (epoch, policy) pair that was actually
        // installed — never a torn combination — and epochs never repeat.
        let rungs: Vec<SharedPolicy> = (1..=4)
            .map(|m| Arc::new(LayerPolicy::uniform(Family::Perforated, m, true, 2).unwrap()))
            .collect();
        let sw = PolicySwitch::new(Some(rungs[0].clone()));
        std::thread::scope(|s| {
            let sw = &sw;
            let rungs = &rungs;
            let installer = s.spawn(move || {
                for i in 0..200 {
                    sw.install(Some(rungs[i % rungs.len()].clone()));
                }
            });
            for _ in 0..4 {
                s.spawn(move || {
                    let mut last = 0u64;
                    for _ in 0..500 {
                        let st = sw.load();
                        assert!(st.epoch >= last, "epochs are monotone per observer");
                        last = st.epoch;
                        let p = st.policy.as_ref().expect("always Some here");
                        if st.epoch == 0 {
                            assert_eq!(p.as_ref(), rungs[0].as_ref());
                        } else {
                            assert!(rungs.iter().any(|r| r.as_ref() == p.as_ref()));
                        }
                    }
                });
            }
            installer.join().unwrap();
        });
        assert_eq!(sw.epoch(), 200);
    }

    #[test]
    fn describe_is_compact() {
        let p = LayerPolicy::from_ms(Family::Perforated, &[2, 0], true).unwrap();
        assert_eq!(p.describe(), "[perforated:2+V, exact]");
        let q = LayerPolicy::from_assignments(vec![
            LayerAssignment::Paired(PairedPoint::mirrored(Family::Perforated, 2, true)),
            LayerAssignment::Point(LayerPoint::new_pol(
                Family::Truncated,
                6,
                Polarity::Pos,
                false,
            )),
        ])
        .unwrap();
        assert_eq!(
            q.describe(),
            "[pair(perforated:2+V / perforated:2:pos+V), truncated:6:pos]"
        );
    }
}
