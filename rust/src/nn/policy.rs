//! Per-layer heterogeneous approximation policies (ALWANN-style, runtime).
//!
//! The offline layerwise search (`report::layerwise`) shows that **mixed**
//! per-layer approximation levels dominate uniform ones on the
//! accuracy/power Pareto front. A [`LayerPolicy`] makes that result a
//! first-class runtime concept: one [`LayerPoint`] — `(family, m, use_cv)`
//! — per MAC layer (conv/dense, topological order). Because `m` and the
//! family are *runtime* inputs of every GEMM engine and of the per-layer
//! [`crate::nn::plan::LayerPlan`] cache, serving a mixed policy needs no
//! recompilation: each layer simply resolves its own plan, LUT and CV
//! epilogue from its point.
//!
//! Policies serialize two ways (both parsed back by [`LayerPolicy::load`]):
//!
//! * **JSON** — what the greedy search emits and benches consume:
//!   `{"layers": [{"family": "perforated", "m": 2, "use_cv": true}, ...]}`
//! * **text** — one line per layer for hand-written files:
//!   `perforated 2 cv` / `truncated 6 nocv` / `exact`, with `#` comments.
//!
//! Validation is split so errors surface at the right level: structural
//! validity (`m ≤ 7`, approximate families need `m ≥ 1`) at parse/build
//! time, and the layer-count match against a concrete model
//! ([`LayerPolicy::validate_for`]) at engine / coordinator entry, where it
//! returns `Err` instead of poisoning a worker.

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::graph::Model;
use crate::approx::Family;
use crate::util::json::Json;

/// Highest meaningful approximation level for 8-bit operands.
pub const MAX_M: u32 = 7;

/// One MAC layer's design point.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LayerPoint {
    pub family: Family,
    pub m: u32,
    pub use_cv: bool,
}

impl LayerPoint {
    /// The exact (baseline) point.
    pub const EXACT: LayerPoint =
        LayerPoint { family: Family::Exact, m: 0, use_cv: false };

    pub fn new(family: Family, m: u32, use_cv: bool) -> LayerPoint {
        LayerPoint { family, m, use_cv }
    }

    /// Canonical form: `m == 0` or the exact family both mean "run exact"
    /// — collapse them to [`LayerPoint::EXACT`] so plan-cache keys and
    /// equality checks agree with the engine's effective behaviour.
    pub fn normalized(self) -> LayerPoint {
        if self.family == Family::Exact || self.m == 0 {
            LayerPoint::EXACT
        } else {
            self
        }
    }

    /// Structural validity: `m ≤ 7` always; approximate families need
    /// `m ≥ 1` unless the point normalizes to exact.
    pub fn validate(&self) -> Result<()> {
        if self.m > MAX_M {
            bail!(
                "m = {} out of range for {} (max {MAX_M} for 8-bit operands)",
                self.m,
                self.family.name()
            );
        }
        if self.family == Family::Exact && self.m != 0 {
            bail!("exact family takes m = 0, got m = {}", self.m);
        }
        Ok(())
    }

    fn to_json(self) -> Json {
        Json::obj()
            .field("family", self.family.name())
            .field("m", self.m as i64)
            .field("use_cv", self.use_cv)
    }

    fn from_json(j: &Json) -> Result<LayerPoint> {
        let name = j
            .get("family")
            .and_then(|f| f.as_str())
            .context("layer entry missing \"family\"")?;
        let family = Family::from_name(name)
            .with_context(|| format!("unknown family name {name:?}"))?;
        let m = j.get("m").and_then(|m| m.as_f64()).context("layer entry missing \"m\"")?;
        if m < 0.0 || m.fract() != 0.0 || m > 255.0 {
            bail!("bad m {m} in layer entry");
        }
        // An omitted use_cv defaults to ON for approximate points — the
        // same rule as the text format (`perforated 3` == `perforated 3
        // cv`), so a hand-written policy behaves identically in either
        // serialization. (What the search emits always writes it.)
        let use_cv = j
            .get("use_cv")
            .and_then(|c| c.as_bool())
            .unwrap_or(family != Family::Exact);
        let p = LayerPoint { family, m: m as u32, use_cv };
        p.validate()?;
        Ok(p)
    }
}

/// A per-MAC-layer approximation assignment: entry `i` configures the i-th
/// conv/dense layer in topological order (the ordinal the engine's plan
/// cache and `Model::mac_node_indices` use).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LayerPolicy {
    layers: Vec<LayerPoint>,
}

impl LayerPolicy {
    /// Build from explicit points; structurally validates every entry.
    pub fn new(layers: Vec<LayerPoint>) -> Result<LayerPolicy> {
        if layers.is_empty() {
            bail!("a layer policy needs at least one layer");
        }
        for (i, p) in layers.iter().enumerate() {
            p.validate().with_context(|| format!("layer {i}"))?;
        }
        Ok(LayerPolicy { layers })
    }

    /// The trivial policy: every one of `n_layers` at the same point.
    pub fn uniform(family: Family, m: u32, use_cv: bool, n_layers: usize) -> Result<LayerPolicy> {
        LayerPolicy::new(vec![LayerPoint::new(family, m, use_cv); n_layers.max(1)])
    }

    /// A per-layer-m policy at one family (the layerwise-search shape):
    /// `ms[i] == 0` runs layer `i` exact.
    pub fn from_ms(family: Family, ms: &[u32], use_cv: bool) -> Result<LayerPolicy> {
        LayerPolicy::new(
            ms.iter()
                .map(|&m| LayerPoint::new(family, m, use_cv).normalized())
                .collect(),
        )
    }

    pub fn len(&self) -> usize {
        self.layers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }

    /// The point for MAC layer ordinal `mac_idx` (normalized).
    pub fn point(&self, mac_idx: usize) -> LayerPoint {
        self.layers[mac_idx].normalized()
    }

    pub fn points(&self) -> impl Iterator<Item = LayerPoint> + '_ {
        self.layers.iter().map(|p| p.normalized())
    }

    /// `Some(point)` when every layer normalizes to the same point — such a
    /// policy is semantically identical to uniform `ForwardOpts`
    /// (property-tested bit-identical in the engine suite).
    pub fn as_uniform(&self) -> Option<LayerPoint> {
        let first = self.point(0);
        self.points().all(|p| p == first).then_some(first)
    }

    /// Number of layers that actually run approximate.
    pub fn approx_layers(&self) -> usize {
        self.points().filter(|p| *p != LayerPoint::EXACT).count()
    }

    /// Check this policy against a concrete model: one entry per MAC layer.
    pub fn validate_for(&self, model: &Model) -> Result<()> {
        let want = model.mac_layers();
        if self.layers.len() != want {
            bail!(
                "policy has {} layers but model {:?} has {} MAC layers",
                self.layers.len(),
                model.name,
                want
            );
        }
        Ok(())
    }

    /// MAC-weighted normalized power of this policy on `model` at array
    /// size `n_array`: approximate layers cost their family's
    /// `array_cost(m).power_norm`, exact layers cost 1.0 — the serving
    /// metrics' estimated-power quantity (and the layerwise report's).
    pub fn power_norm(&self, model: &Model, n_array: u32) -> f64 {
        let macs = model.mac_layer_macs();
        debug_assert_eq!(macs.len(), self.layers.len(), "call validate_for first");
        let total: u64 = macs.iter().sum();
        if total == 0 {
            return 1.0;
        }
        let weighted: f64 = self
            .points()
            .zip(&macs)
            .map(|(p, &w)| {
                let pn = if p == LayerPoint::EXACT {
                    1.0
                } else {
                    crate::hw::array_cost(p.family, p.m, n_array).power_norm
                };
                pn * w as f64
            })
            .sum();
        weighted / total as f64
    }

    // ---- serialization -------------------------------------------------

    pub fn to_json(&self) -> Json {
        Json::obj()
            .field("n_layers", self.layers.len())
            .field(
                "layers",
                Json::Arr(self.layers.iter().map(|p| p.to_json()).collect()),
            )
    }

    pub fn from_json(j: &Json) -> Result<LayerPolicy> {
        let layers = j
            .get("layers")
            .and_then(|l| l.as_arr())
            .context("policy JSON missing \"layers\" array")?;
        let points = layers
            .iter()
            .enumerate()
            .map(|(i, e)| LayerPoint::from_json(e).with_context(|| format!("layer {i}")))
            .collect::<Result<Vec<_>>>()?;
        LayerPolicy::new(points)
    }

    /// One line per layer: `<family> <m> <cv|nocv>`, or bare `exact`.
    pub fn to_text(&self) -> String {
        let mut s = String::from("# per-layer approximation policy: one MAC layer per line\n");
        for p in &self.layers {
            let p = p.normalized();
            if p == LayerPoint::EXACT {
                s.push_str("exact\n");
            } else {
                s.push_str(&format!(
                    "{} {} {}\n",
                    p.family.name(),
                    p.m,
                    if p.use_cv { "cv" } else { "nocv" }
                ));
            }
        }
        s
    }

    pub fn parse_text(text: &str) -> Result<LayerPolicy> {
        let mut points = Vec::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let mut parts = line.split_whitespace();
            let name = parts.next().unwrap();
            let family = Family::from_name(name).with_context(|| {
                format!("line {}: unknown family name {name:?}", lineno + 1)
            })?;
            let point = if family == Family::Exact {
                LayerPoint::EXACT
            } else {
                let m: u32 = parts
                    .next()
                    .with_context(|| format!("line {}: missing m", lineno + 1))?
                    .parse()
                    .with_context(|| format!("line {}: bad m", lineno + 1))?;
                let use_cv = match parts.next() {
                    None | Some("cv") => true,
                    Some("nocv") => false,
                    Some(other) => {
                        bail!("line {}: expected cv|nocv, got {other:?}", lineno + 1)
                    }
                };
                LayerPoint::new(family, m, use_cv)
            };
            if let Some(extra) = parts.next() {
                bail!("line {}: trailing token {extra:?}", lineno + 1);
            }
            point.validate().with_context(|| format!("line {}", lineno + 1))?;
            points.push(point);
        }
        LayerPolicy::new(points)
    }

    /// Parse either serialization (sniffed: JSON starts with `{`).
    pub fn parse(text: &str) -> Result<LayerPolicy> {
        if text.trim_start().starts_with('{') {
            LayerPolicy::from_json(&Json::parse(text).context("policy JSON")?)
        } else {
            LayerPolicy::parse_text(text)
        }
    }

    /// Load a policy file (JSON or text — see [`LayerPolicy::parse`]).
    pub fn load(path: &Path) -> Result<LayerPolicy> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading policy {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing policy {}", path.display()))
    }

    /// Write the JSON form (what `cvapprox layerwise` emits).
    pub fn save_json(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().render())
            .with_context(|| format!("writing policy {}", path.display()))
    }

    /// Compact human-readable summary, e.g. `[perforated:3+V, exact, ...]`.
    pub fn describe(&self) -> String {
        let parts: Vec<String> = self
            .points()
            .map(|p| {
                if p == LayerPoint::EXACT {
                    "exact".to_string()
                } else {
                    format!(
                        "{}:{}{}",
                        p.family.name(),
                        p.m,
                        if p.use_cv { "+V" } else { "" }
                    )
                }
            })
            .collect();
        format!("[{}]", parts.join(", "))
    }
}

/// Shared-ownership alias — the engine, coordinator and every worker hold
/// the same immutable policy.
pub type SharedPolicy = Arc<LayerPolicy>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::testutil;

    #[test]
    fn uniform_policy_is_uniform() {
        let p = LayerPolicy::uniform(Family::Perforated, 2, true, 3).unwrap();
        assert_eq!(p.len(), 3);
        assert_eq!(
            p.as_uniform(),
            Some(LayerPoint::new(Family::Perforated, 2, true))
        );
        assert_eq!(p.approx_layers(), 3);
    }

    #[test]
    fn m_zero_normalizes_to_exact() {
        let p = LayerPolicy::from_ms(Family::Truncated, &[6, 0], true).unwrap();
        assert_eq!(p.point(0), LayerPoint::new(Family::Truncated, 6, true));
        assert_eq!(p.point(1), LayerPoint::EXACT);
        assert_eq!(p.approx_layers(), 1);
        assert!(p.as_uniform().is_none());
        // all-zero ms normalize to a uniform exact policy
        let z = LayerPolicy::from_ms(Family::Perforated, &[0, 0], true).unwrap();
        assert_eq!(z.as_uniform(), Some(LayerPoint::EXACT));
    }

    #[test]
    fn structural_validation_rejects_bad_points() {
        assert!(LayerPolicy::uniform(Family::Perforated, 8, true, 2).is_err());
        assert!(LayerPoint::new(Family::Exact, 3, false).validate().is_err());
        assert!(LayerPolicy::new(vec![]).is_err());
        assert!(LayerPoint::new(Family::Recursive, 7, true).validate().is_ok());
    }

    #[test]
    fn json_roundtrip_preserves_points() {
        let p = LayerPolicy::new(vec![
            LayerPoint::new(Family::Perforated, 3, true),
            LayerPoint::EXACT,
            LayerPoint::new(Family::Truncated, 6, false),
        ])
        .unwrap();
        let j = p.to_json().render();
        let back = LayerPolicy::parse(&j).unwrap();
        assert_eq!(back, p);
        assert_eq!(
            j.contains("\"family\": \"perforated\""),
            true,
            "stable field names: {j}"
        );
    }

    #[test]
    fn text_roundtrip_preserves_points() {
        let p = LayerPolicy::new(vec![
            LayerPoint::new(Family::Recursive, 4, false),
            LayerPoint::EXACT,
            LayerPoint::new(Family::Perforated, 1, true),
        ])
        .unwrap();
        let back = LayerPolicy::parse(&p.to_text()).unwrap();
        assert_eq!(back, p);
    }

    #[test]
    fn text_parser_accepts_comments_and_defaults_cv() {
        let p = LayerPolicy::parse_text(
            "# header\nperforated 2   # inline comment, cv defaults on\n\nexact\n",
        )
        .unwrap();
        assert_eq!(p.point(0), LayerPoint::new(Family::Perforated, 2, true));
        assert_eq!(p.point(1), LayerPoint::EXACT);
    }

    #[test]
    fn json_omitted_use_cv_defaults_on_like_text() {
        // Both serializations must agree on what an omitted use_cv means:
        // ON for approximate points.
        let p = LayerPolicy::parse(
            "{\"layers\": [{\"family\": \"perforated\", \"m\": 3}, \
             {\"family\": \"exact\", \"m\": 0}]}",
        )
        .unwrap();
        assert_eq!(p.point(0), LayerPoint::new(Family::Perforated, 3, true));
        assert_eq!(p.point(1), LayerPoint::EXACT);
    }

    #[test]
    fn parsers_reject_malformed_policies() {
        // unknown family name (both formats)
        assert!(LayerPolicy::parse_text("bogus 2 cv").is_err());
        assert!(LayerPolicy::parse(
            "{\"layers\": [{\"family\": \"bogus\", \"m\": 2}]}"
        )
        .is_err());
        // m out of range
        assert!(LayerPolicy::parse_text("perforated 9 cv").is_err());
        assert!(LayerPolicy::parse(
            "{\"layers\": [{\"family\": \"perforated\", \"m\": 9}]}"
        )
        .is_err());
        // structural garbage
        assert!(LayerPolicy::parse_text("perforated two cv").is_err());
        assert!(LayerPolicy::parse_text("perforated 2 maybe").is_err());
        assert!(LayerPolicy::parse_text("perforated 2 cv extra").is_err());
        assert!(LayerPolicy::parse_text("").is_err());
        assert!(LayerPolicy::parse("{\"layers\": []}").is_err());
        assert!(LayerPolicy::parse("{\"nope\": 1}").is_err());
        assert!(LayerPolicy::parse("{\"layers\": [{\"m\": 2}]}").is_err());
    }

    #[test]
    fn validate_for_checks_layer_count() {
        let model = testutil::tiny_model(); // 2 MAC layers
        let ok = LayerPolicy::uniform(Family::Perforated, 2, true, 2).unwrap();
        assert!(ok.validate_for(&model).is_ok());
        let bad = LayerPolicy::uniform(Family::Perforated, 2, true, 3).unwrap();
        let err = bad.validate_for(&model).unwrap_err();
        assert!(format!("{err:#}").contains("MAC layers"), "{err:#}");
    }

    #[test]
    fn power_norm_is_mac_weighted() {
        let model = testutil::tiny_model();
        let exact = LayerPolicy::uniform(Family::Exact, 0, false, 2).unwrap();
        assert!((exact.power_norm(&model, 64) - 1.0).abs() < 1e-12);
        let uni = LayerPolicy::uniform(Family::Perforated, 3, true, 2).unwrap();
        let p_uni = uni.power_norm(&model, 64);
        let cost = crate::hw::array_cost(Family::Perforated, 3, 64).power_norm;
        assert!((p_uni - cost).abs() < 1e-12, "uniform == array cost");
        // Mixed: strictly between exact and uniform.
        let mixed = LayerPolicy::from_ms(Family::Perforated, &[3, 0], true).unwrap();
        let p_mixed = mixed.power_norm(&model, 64);
        assert!(p_uni < p_mixed && p_mixed < 1.0, "{p_uni} < {p_mixed} < 1");
        // And MAC-weighted: approximating the big layer saves more.
        let macs = model.mac_layer_macs();
        let big_first = macs[0] > macs[1];
        let other = LayerPolicy::from_ms(Family::Perforated, &[0, 3], true).unwrap();
        let p_other = other.power_norm(&model, 64);
        if big_first {
            assert!(p_mixed < p_other);
        } else {
            assert!(p_other < p_mixed);
        }
    }

    #[test]
    fn describe_is_compact() {
        let p = LayerPolicy::from_ms(Family::Perforated, &[2, 0], true).unwrap();
        assert_eq!(p.describe(), "[perforated:2+V, exact]");
    }
}
