//! Quantized DNN inference engine (uint8 operands, i64 accumulators).
//!
//! Bit-exact mirror of the python reference (`python/compile/model.py`):
//! every rounding rule is identical, asserted end-to-end by the golden
//! vectors `make artifacts` exports. The approximate multipliers enter only
//! in conv/dense — the ops the paper's MAC array executes.
//!
//! * [`graph`] — the node IR (shared with python's nets.py) + model struct
//! * [`loader`] — .cvm binary parser/writer
//! * [`gemm`] — the approximate GEMM engines (identity / LUT / systolic)
//! * [`kernel`] — pluggable compute backends (scalar reference / SIMD)
//! * [`plan`] — precomputed layer plans + the reusable scratch arena
//! * [`policy`] — per-layer heterogeneous approximation policies
//! * [`engine`] — the graph executor

pub mod engine;
pub mod gemm;
pub mod graph;
pub mod kernel;
pub mod loader;
pub mod plan;
pub mod policy;
#[cfg(test)]
pub(crate) mod testutil;

pub use engine::{CvProxySampler, CvProxyWindow, Engine, ForwardOpts, IntegrityReport};
pub use gemm::GemmKind;
pub use graph::{Model, Node, Op, Tensor};
pub use kernel::Kernel;
pub use plan::{LayerPlan, PairedPlan, PlanKey, Scratch};
pub use policy::{
    LayerAssignment, LayerPoint, LayerPolicy, PairedPoint, PolicySwitch, SharedPolicy,
    StampedPolicy,
};
