//! Pluggable GEMM kernel backends (§Perf, ROADMAP item 1).
//!
//! [`Kernel`] abstracts the inner planned-GEMM compute that
//! [`super::gemm`] orchestrates: operand packing (u8 → i32), the
//! masked-operand transforms of the error identities (low bits / modular
//! complements / bit planes), the blocked i32 multiply-accumulate chunk
//! that runs under `par_row_blocks`, and the per-image ΣA/ΣX column
//! reductions that feed the CV + zero-point epilogue. Everything above the
//! trait — layer plans, LUT dispatch, threading, the V epilogue — is
//! backend-independent.
//!
//! Two implementations ship:
//!
//! * [`ScalarKernel`] — the PR-1 blocked scalar loops, unchanged: the
//!   portable reference every other backend must match bit for bit.
//! * [`SimdKernel`] — closed-form lanes: AVX2 via `std::arch` where the
//!   cpu has it (probed once at construction), an autovectorizer-friendly
//!   chunked-i32 path elsewhere. Approximate products never touch a
//!   256×256 LUT gather here — the masked-operand GEMMs *are* the closed
//!   form of the bitmodel (`approx::err_pol` / `approx::xvar_pol`).
//!
//! Bit-exactness argument: every backend computes the **same i32 term per
//! (row, column, k) triple** — only the association of the wrapping
//! integer additions differs, and wrapping addition is associative and
//! commutative, so any lane blocking or accumulation order produces
//! identical bytes. The differential harness
//! (`rust/tests/differential.rs`) enforces this across every family ×
//! m ≤ 7 × polarity × paired assignment; unit tests below pin each op on
//! ragged tails.
//!
//! Selection: `CVAPPROX_KERNEL` ∈ {`auto`, `scalar`, `simd`} resolved once
//! per process ([`active`]); `auto` picks the SIMD backend exactly when
//! its AVX2 lanes are live, otherwise the scalar fallback. Engines capture
//! the active kernel at construction (`Engine::with_kernel` pins one
//! explicitly — what the differential kernel axis and the bench rows use).

use std::sync::OnceLock;

use crate::approx::{comp_low, xvar_pol, Family, Polarity};

/// The inner planned-GEMM compute surface. All methods are exact integer
/// transforms: implementations may reorder additions freely (wrapping i32
/// adds commute) but must produce the same per-element terms as
/// [`ScalarKernel`].
pub trait Kernel: Send + Sync {
    /// Backend name (`scalar` / `simd`) — what benches and replies report.
    fn name(&self) -> &'static str;

    /// Widen u8 operands to i32 (the packing step of the identity core).
    fn widen_u8(&self, src: &[u8], dst: &mut [i32]);

    /// Masked-operand transform of the ε identities: `dst = src & (2^m−1)`
    /// for `Neg`, its modular complement (`comp_low`) for `Pos`.
    fn mask_low(&self, pol: Polarity, m: u32, src: &[u8], dst: &mut [i32]);

    /// Bit-plane extract for the truncated expansion: `dst = (src>>bit)&1`.
    fn bit_plane(&self, bit: u32, src: &[u8], dst: &mut [i32]);

    /// Cache-blocked i32 GEMM over one contiguous row chunk (the body run
    /// under `par_row_blocks`): `out[f,j] += sign · w[f,kk] · a[kk,j]`.
    /// Additions per output element must run in ascending `kk` within the
    /// same NC/KC tile walk as the scalar core (debug builds check
    /// overflow on the scalar path; identical order keeps both in the same
    /// headroom envelope).
    fn gemm_chunk(
        &self,
        w: &[u8],
        a: &[i32],
        rows: usize,
        k: usize,
        n: usize,
        sign: i32,
        out: &mut [i32],
    );

    /// Merge one truncated bit-plane term: `out += sign · (t << shift)`.
    fn merge_shifted(&self, sign: i32, shift: u32, t: &[i32], out: &mut [i32]);

    /// Widen the i32 accumulator into the i64 epilogue accumulator.
    fn widen_acc(&self, src: &[i32], dst: &mut [i64]) {
        for (o, &v) in dst.iter_mut().zip(src) {
            *o = v as i64;
        }
    }

    /// Activation column sums: `sums[j] += Σ_k a[k,j]` (zero-point term).
    fn col_sum_a(&self, a: &[u8], k: usize, n: usize, sums: &mut [i64]);

    /// CV regressor column sums over one reduction-parity partition:
    /// `sums[j] += Σ_{kk = start, start+step, …} xvar_pol(family, pol,
    /// a[kk,j], m)`. Uniform layers pass `(0, 1)`; paired layers `(0, 2)`
    /// and `(1, 2)`.
    #[allow(clippy::too_many_arguments)]
    fn col_sum_x(
        &self,
        family: Family,
        pol: Polarity,
        m: u32,
        start: usize,
        step: usize,
        a: &[u8],
        k: usize,
        n: usize,
        sums: &mut [i64],
    );
}

// ---------------------------------------------------------------------------
// Scalar backend (the reference).

/// The PR-1 blocked scalar kernel, moved verbatim out of `gemm.rs` — the
/// portable reference every other backend must match bit for bit.
#[derive(Debug)]
pub struct ScalarKernel;

impl Kernel for ScalarKernel {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn widen_u8(&self, src: &[u8], dst: &mut [i32]) {
        for (dst, &src) in dst.iter_mut().zip(src) {
            *dst = src as i32;
        }
    }

    fn mask_low(&self, pol: Polarity, m: u32, src: &[u8], dst: &mut [i32]) {
        let mask = ((1u32 << m) - 1) as u8;
        match pol {
            Polarity::Neg => {
                for (dst, &src) in dst.iter_mut().zip(src) {
                    *dst = (src & mask) as i32;
                }
            }
            Polarity::Pos => {
                for (dst, &src) in dst.iter_mut().zip(src) {
                    *dst = comp_low(src as i32, m);
                }
            }
        }
    }

    fn bit_plane(&self, bit: u32, src: &[u8], dst: &mut [i32]) {
        for (dst, &src) in dst.iter_mut().zip(src) {
            *dst = ((src >> bit) & 1) as i32;
        }
    }

    fn gemm_chunk(
        &self,
        w: &[u8],
        a: &[i32],
        rows: usize,
        k: usize,
        n: usize,
        sign: i32,
        out: &mut [i32],
    ) {
        scalar_gemm_chunk(w, a, rows, k, n, sign, out);
    }

    fn merge_shifted(&self, sign: i32, shift: u32, t: &[i32], out: &mut [i32]) {
        for (o, &t) in out.iter_mut().zip(t) {
            *o += sign * (t << shift);
        }
    }

    fn col_sum_a(&self, a: &[u8], k: usize, n: usize, sums: &mut [i64]) {
        for kk in 0..k {
            let arow = &a[kk * n..(kk + 1) * n];
            for (sa, &av) in sums.iter_mut().zip(arow) {
                *sa += av as i64;
            }
        }
    }

    fn col_sum_x(
        &self,
        family: Family,
        pol: Polarity,
        m: u32,
        start: usize,
        step: usize,
        a: &[u8],
        k: usize,
        n: usize,
        sums: &mut [i64],
    ) {
        for kk in (start..k).step_by(step) {
            let arow = &a[kk * n..(kk + 1) * n];
            for (sx, &av) in sums.iter_mut().zip(arow) {
                *sx += xvar_pol(family, pol, av, m) as i64;
            }
        }
    }
}

/// Cache-blocked scalar GEMM chunk (`w` rows correspond 1:1 to `out` rows;
/// the caller offsets both). 4-row register blocking: one pass over an
/// activation block feeds 4 output rows, cutting A-panel traffic 4×; N/K
/// blocking keeps the hot working set (4×NC out lanes + the streamed A
/// rows) inside L1/L2. This is the PR-1 loop nest, unchanged.
fn scalar_gemm_chunk(
    w: &[u8],
    a: &[i32],
    rows: usize,
    k: usize,
    n: usize,
    sign: i32,
    out: &mut [i32],
) {
    let mut n0 = 0;
    while n0 < n {
        let nc = NC.min(n - n0);
        let mut k0 = 0;
        while k0 < k {
            let kc = KC.min(k - k0);
            let mut f = 0;
            while f + 4 <= rows {
                let w0 = &w[f * k..(f + 1) * k];
                let w1 = &w[(f + 1) * k..(f + 2) * k];
                let w2 = &w[(f + 2) * k..(f + 3) * k];
                let w3 = &w[(f + 3) * k..(f + 4) * k];
                let (r0, rest) = out[f * n..].split_at_mut(n);
                let (r1, rest) = rest.split_at_mut(n);
                let (r2, r3full) = rest.split_at_mut(n);
                let r0 = &mut r0[n0..n0 + nc];
                let r1 = &mut r1[n0..n0 + nc];
                let r2 = &mut r2[n0..n0 + nc];
                let r3 = &mut r3full[n0..n0 + nc];
                for kk in k0..k0 + kc {
                    let v0 = sign * w0[kk] as i32;
                    let v1 = sign * w1[kk] as i32;
                    let v2 = sign * w2[kk] as i32;
                    let v3 = sign * w3[kk] as i32;
                    if (v0 | v1 | v2 | v3) == 0 {
                        continue;
                    }
                    let arow = &a[kk * n + n0..kk * n + n0 + nc];
                    for (j, &av) in arow.iter().enumerate() {
                        r0[j] += v0 * av;
                        r1[j] += v1 * av;
                        r2[j] += v2 * av;
                        r3[j] += v3 * av;
                    }
                }
                f += 4;
            }
            while f < rows {
                let wrow = &w[f * k..(f + 1) * k];
                let orow = &mut out[f * n + n0..f * n + n0 + nc];
                for kk in k0..k0 + kc {
                    if wrow[kk] == 0 {
                        continue;
                    }
                    let wv = sign * wrow[kk] as i32;
                    let arow = &a[kk * n + n0..kk * n + n0 + nc];
                    for (o, &av) in orow.iter_mut().zip(arow) {
                        *o += wv * av;
                    }
                }
                f += 1;
            }
            k0 += kc;
        }
        n0 += nc;
    }
}

// ---------------------------------------------------------------------------
// Closed-form operand transforms shared by the SIMD lanes and their tails.

/// Branch-free shape of [`xvar_pol`] — resolved once per GEMM call so the
/// per-element work is a couple of and/sub/cmp lane ops.
#[derive(Clone, Copy, Debug)]
enum XForm {
    /// Exact family or m = 0: the regressor is identically zero.
    Zero,
    /// `a & mask` (Neg perforated/recursive).
    Low(i32),
    /// `(2^m − (a & mask)) & mask` (Pos perforated/recursive).
    Comp(i32, i32),
    /// `((a & mask) != 0) as i32` (truncated, either polarity).
    Indicator(i32),
}

fn xform_for(family: Family, pol: Polarity, m: u32) -> XForm {
    if family == Family::Exact || m == 0 {
        return XForm::Zero;
    }
    let mask = (1i32 << m) - 1;
    match (family, pol) {
        (Family::Truncated, _) => XForm::Indicator(mask),
        (_, Polarity::Neg) => XForm::Low(mask),
        (_, Polarity::Pos) => XForm::Comp(1i32 << m, mask),
    }
}

fn xform_eval(xf: XForm, a: u8) -> i32 {
    let a = a as i32;
    match xf {
        XForm::Zero => 0,
        XForm::Low(mask) => a & mask,
        XForm::Comp(pow, mask) => (pow - (a & mask)) & mask,
        XForm::Indicator(mask) => ((a & mask) != 0) as i32,
    }
}

// ---------------------------------------------------------------------------
// SIMD backend.

/// SIMD kernel: AVX2 lanes when the cpu reports them (cpuid probed once at
/// construction), the portable chunked-i32 path otherwise. Either way the
/// per-element terms equal the scalar kernel's, so outputs are
/// bit-identical (see the module docs for the argument; the differential
/// harness for the proof-by-test).
#[derive(Debug)]
pub struct SimdKernel {
    avx2: bool,
}

impl SimdKernel {
    fn detect() -> Self {
        #[cfg(target_arch = "x86_64")]
        let avx2 = is_x86_feature_detected!("avx2");
        #[cfg(not(target_arch = "x86_64"))]
        let avx2 = false;
        SimdKernel { avx2 }
    }

    /// True when the AVX2 lanes are live (false = portable chunked path).
    pub fn is_accelerated(&self) -> bool {
        self.avx2
    }
}

impl Kernel for SimdKernel {
    fn name(&self) -> &'static str {
        "simd"
    }

    fn widen_u8(&self, src: &[u8], dst: &mut [i32]) {
        #[cfg(target_arch = "x86_64")]
        if self.avx2 {
            unsafe { avx2::widen_u8(src, dst) };
            return;
        }
        portable::widen_u8(src, dst);
    }

    fn mask_low(&self, pol: Polarity, m: u32, src: &[u8], dst: &mut [i32]) {
        // mask_low is xform Low/Comp applied over the full operand range.
        let xf = match pol {
            Polarity::Neg => XForm::Low((1i32 << m) - 1),
            Polarity::Pos => XForm::Comp(1i32 << m, (1i32 << m) - 1),
        };
        #[cfg(target_arch = "x86_64")]
        if self.avx2 {
            unsafe { avx2::transform(xf, src, dst) };
            return;
        }
        portable::transform(xf, src, dst);
    }

    fn bit_plane(&self, bit: u32, src: &[u8], dst: &mut [i32]) {
        #[cfg(target_arch = "x86_64")]
        if self.avx2 {
            unsafe { avx2::bit_plane(bit, src, dst) };
            return;
        }
        portable::bit_plane(bit, src, dst);
    }

    fn gemm_chunk(
        &self,
        w: &[u8],
        a: &[i32],
        rows: usize,
        k: usize,
        n: usize,
        sign: i32,
        out: &mut [i32],
    ) {
        #[cfg(target_arch = "x86_64")]
        if self.avx2 {
            unsafe { avx2::gemm_chunk(w, a, rows, k, n, sign, out) };
            return;
        }
        portable::gemm_chunk(w, a, rows, k, n, sign, out);
    }

    fn merge_shifted(&self, sign: i32, shift: u32, t: &[i32], out: &mut [i32]) {
        #[cfg(target_arch = "x86_64")]
        if self.avx2 {
            unsafe { avx2::merge_shifted(sign, shift, t, out) };
            return;
        }
        portable::merge_shifted(sign, shift, t, out);
    }

    fn col_sum_a(&self, a: &[u8], k: usize, n: usize, sums: &mut [i64]) {
        #[cfg(target_arch = "x86_64")]
        if self.avx2 {
            unsafe { avx2::col_sum_a(a, k, n, sums) };
            return;
        }
        portable::col_sum_a(a, k, n, sums);
    }

    fn col_sum_x(
        &self,
        family: Family,
        pol: Polarity,
        m: u32,
        start: usize,
        step: usize,
        a: &[u8],
        k: usize,
        n: usize,
        sums: &mut [i64],
    ) {
        let xf = xform_for(family, pol, m);
        #[cfg(target_arch = "x86_64")]
        if self.avx2 {
            unsafe { avx2::col_sum_x(xf, start, step, a, k, n, sums) };
            return;
        }
        portable::col_sum_x(xf, start, step, a, k, n, sums);
    }
}

/// Portable chunked-i32 lanes: fixed 8-wide column blocks over local
/// arrays — the shape LLVM keeps autovectorized on targets without the
/// AVX2 path. Per-element terms and per-element add order match the
/// scalar kernel exactly.
mod portable {
    use super::{xform_eval, XForm};
    use crate::nn::gemm::{KC, NC};

    const LANES: usize = 8;

    pub fn widen_u8(src: &[u8], dst: &mut [i32]) {
        for (d, s) in dst.chunks_exact_mut(LANES).zip(src.chunks_exact(LANES)) {
            for i in 0..LANES {
                d[i] = s[i] as i32;
            }
        }
        let done = (src.len() / LANES) * LANES;
        for (d, &s) in dst[done..].iter_mut().zip(&src[done..]) {
            *d = s as i32;
        }
    }

    pub fn transform(xf: XForm, src: &[u8], dst: &mut [i32]) {
        for (d, s) in dst.chunks_exact_mut(LANES).zip(src.chunks_exact(LANES)) {
            for i in 0..LANES {
                d[i] = xform_eval(xf, s[i]);
            }
        }
        let done = (src.len() / LANES) * LANES;
        for (d, &s) in dst[done..].iter_mut().zip(&src[done..]) {
            *d = xform_eval(xf, s);
        }
    }

    pub fn bit_plane(bit: u32, src: &[u8], dst: &mut [i32]) {
        for (d, s) in dst.chunks_exact_mut(LANES).zip(src.chunks_exact(LANES)) {
            for i in 0..LANES {
                d[i] = ((s[i] >> bit) & 1) as i32;
            }
        }
        let done = (src.len() / LANES) * LANES;
        for (d, &s) in dst[done..].iter_mut().zip(&src[done..]) {
            *d = ((s >> bit) & 1) as i32;
        }
    }

    pub fn gemm_chunk(
        w: &[u8],
        a: &[i32],
        rows: usize,
        k: usize,
        n: usize,
        sign: i32,
        out: &mut [i32],
    ) {
        // Same NC/KC tile walk as the scalar core, j-blocked: 8 column
        // accumulators live in a local array across the kk loop, so every
        // output element still sums ascending kk within each tile.
        let mut n0 = 0;
        while n0 < n {
            let nc = NC.min(n - n0);
            let mut k0 = 0;
            while k0 < k {
                let kc = KC.min(k - k0);
                for f in 0..rows {
                    let wrow = &w[f * k..(f + 1) * k];
                    let mut j = 0;
                    while j + LANES <= nc {
                        let p = n0 + j;
                        let mut acc = [0i32; LANES];
                        acc.copy_from_slice(&out[f * n + p..f * n + p + LANES]);
                        for kk in k0..k0 + kc {
                            let wv = wrow[kk];
                            if wv == 0 {
                                continue;
                            }
                            let v = sign * wv as i32;
                            let arow = &a[kk * n + p..kk * n + p + LANES];
                            for i in 0..LANES {
                                acc[i] += v * arow[i];
                            }
                        }
                        out[f * n + p..f * n + p + LANES].copy_from_slice(&acc);
                        j += LANES;
                    }
                    while j < nc {
                        let p = n0 + j;
                        let mut acc = out[f * n + p];
                        for kk in k0..k0 + kc {
                            let wv = wrow[kk];
                            if wv == 0 {
                                continue;
                            }
                            acc += sign * wv as i32 * a[kk * n + p];
                        }
                        out[f * n + p] = acc;
                        j += 1;
                    }
                }
                k0 += kc;
            }
            n0 += nc;
        }
    }

    pub fn merge_shifted(sign: i32, shift: u32, t: &[i32], out: &mut [i32]) {
        for (o, s) in out.chunks_exact_mut(LANES).zip(t.chunks_exact(LANES)) {
            for i in 0..LANES {
                o[i] += sign * (s[i] << shift);
            }
        }
        let done = (t.len() / LANES) * LANES;
        for (o, &s) in out[done..].iter_mut().zip(&t[done..]) {
            *o += sign * (s << shift);
        }
    }

    pub fn col_sum_a(a: &[u8], k: usize, n: usize, sums: &mut [i64]) {
        // i32 partials per column block: K ≤ 33 000 (asserted by the core
        // that runs first in every GEMM call) keeps Σ ≤ K·255 < 2^31.
        let mut j = 0;
        while j + LANES <= n {
            let mut acc = [0i32; LANES];
            for kk in 0..k {
                let arow = &a[kk * n + j..kk * n + j + LANES];
                for i in 0..LANES {
                    acc[i] += arow[i] as i32;
                }
            }
            for i in 0..LANES {
                sums[j + i] += acc[i] as i64;
            }
            j += LANES;
        }
        while j < n {
            let mut s = 0i64;
            for kk in 0..k {
                s += a[kk * n + j] as i64;
            }
            sums[j] += s;
            j += 1;
        }
    }

    pub fn col_sum_x(
        xf: XForm,
        start: usize,
        step: usize,
        a: &[u8],
        k: usize,
        n: usize,
        sums: &mut [i64],
    ) {
        // i32 partials: xvar ≤ 2^m − 1 ≤ 127, so K ≤ 33 000 keeps the
        // block sums far inside i32 (same envelope as col_sum_a).
        let mut j = 0;
        while j + LANES <= n {
            let mut acc = [0i32; LANES];
            let mut kk = start;
            while kk < k {
                let arow = &a[kk * n + j..kk * n + j + LANES];
                for i in 0..LANES {
                    acc[i] += xform_eval(xf, arow[i]);
                }
                kk += step;
            }
            for i in 0..LANES {
                sums[j + i] += acc[i] as i64;
            }
            j += LANES;
        }
        while j < n {
            let mut s = 0i64;
            let mut kk = start;
            while kk < k {
                s += xform_eval(xf, a[kk * n + j]) as i64;
                kk += step;
            }
            sums[j] += s;
            j += 1;
        }
    }
}

/// AVX2 lanes. Every fn is `#[target_feature(enable = "avx2")]` and only
/// reachable through `SimdKernel` after its constructor observed a true
/// `is_x86_feature_detected!("avx2")`; all vector memory ops are unaligned
/// intrinsics over in-bounds slice ranges (8-lane main loops, scalar
/// tails).
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    use super::{xform_eval, XForm};
    use crate::nn::gemm::{KC, NC};

    #[target_feature(enable = "avx2")]
    unsafe fn loadu(s: &[i32], at: usize) -> __m256i {
        debug_assert!(at + 8 <= s.len());
        _mm256_loadu_si256(s.as_ptr().add(at) as *const __m256i)
    }

    #[target_feature(enable = "avx2")]
    unsafe fn storeu(s: &mut [i32], at: usize, v: __m256i) {
        debug_assert!(at + 8 <= s.len());
        _mm256_storeu_si256(s.as_mut_ptr().add(at) as *mut __m256i, v)
    }

    /// 8 consecutive u8s widened to one i32×8 lane.
    #[target_feature(enable = "avx2")]
    unsafe fn widen8(p: *const u8) -> __m256i {
        _mm256_cvtepu8_epi32(_mm_loadl_epi64(p as *const __m128i))
    }

    #[target_feature(enable = "avx2")]
    unsafe fn apply(xf: XForm, v: __m256i) -> __m256i {
        match xf {
            XForm::Zero => _mm256_setzero_si256(),
            XForm::Low(mask) => _mm256_and_si256(v, _mm256_set1_epi32(mask)),
            XForm::Comp(pow, mask) => {
                let m = _mm256_set1_epi32(mask);
                let low = _mm256_and_si256(v, m);
                _mm256_and_si256(_mm256_sub_epi32(_mm256_set1_epi32(pow), low), m)
            }
            XForm::Indicator(mask) => {
                let low = _mm256_and_si256(v, _mm256_set1_epi32(mask));
                let eq0 = _mm256_cmpeq_epi32(low, _mm256_setzero_si256());
                _mm256_andnot_si256(eq0, _mm256_set1_epi32(1))
            }
        }
    }

    /// i32×8 partial sums widened and added into 8 consecutive i64 slots.
    #[target_feature(enable = "avx2")]
    unsafe fn add_i32x8_to_i64(sums: &mut [i64], at: usize, v: __m256i) {
        debug_assert!(at + 8 <= sums.len());
        let lo = _mm256_cvtepi32_epi64(_mm256_castsi256_si128(v));
        let hi = _mm256_cvtepi32_epi64(_mm256_extracti128_si256::<1>(v));
        let p = sums.as_mut_ptr().add(at) as *mut __m256i;
        let s0 = _mm256_loadu_si256(p as *const __m256i);
        let s1 = _mm256_loadu_si256(p.add(1) as *const __m256i);
        _mm256_storeu_si256(p, _mm256_add_epi64(s0, lo));
        _mm256_storeu_si256(p.add(1), _mm256_add_epi64(s1, hi));
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn widen_u8(src: &[u8], dst: &mut [i32]) {
        let len = src.len();
        let mut i = 0;
        while i + 8 <= len {
            storeu(dst, i, widen8(src.as_ptr().add(i)));
            i += 8;
        }
        while i < len {
            dst[i] = src[i] as i32;
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn transform(xf: XForm, src: &[u8], dst: &mut [i32]) {
        let len = src.len();
        let mut i = 0;
        while i + 8 <= len {
            storeu(dst, i, apply(xf, widen8(src.as_ptr().add(i))));
            i += 8;
        }
        while i < len {
            dst[i] = xform_eval(xf, src[i]);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn bit_plane(bit: u32, src: &[u8], dst: &mut [i32]) {
        let cnt = _mm_cvtsi32_si128(bit as i32);
        let one = _mm256_set1_epi32(1);
        let len = src.len();
        let mut i = 0;
        while i + 8 <= len {
            let v = widen8(src.as_ptr().add(i));
            storeu(dst, i, _mm256_and_si256(_mm256_srl_epi32(v, cnt), one));
            i += 8;
        }
        while i < len {
            dst[i] = ((src[i] >> bit) & 1) as i32;
            i += 1;
        }
    }

    /// Blocked GEMM chunk: the scalar core's NC/KC tile walk with 8-lane
    /// column blocks and 4-row register accumulators. Per output element
    /// the additions run in the same ascending-kk order per tile as the
    /// scalar kernel; `_mm256_mullo_epi32` is wrapping i32 multiply, the
    /// same operation the release-mode scalar core performs.
    #[target_feature(enable = "avx2")]
    pub unsafe fn gemm_chunk(
        w: &[u8],
        a: &[i32],
        rows: usize,
        k: usize,
        n: usize,
        sign: i32,
        out: &mut [i32],
    ) {
        let mut n0 = 0;
        while n0 < n {
            let nc = NC.min(n - n0);
            let mut k0 = 0;
            while k0 < k {
                let kc = KC.min(k - k0);
                let mut f = 0;
                while f + 4 <= rows {
                    let mut j = 0;
                    while j + 8 <= nc {
                        let p = n0 + j;
                        let mut acc0 = loadu(out, f * n + p);
                        let mut acc1 = loadu(out, (f + 1) * n + p);
                        let mut acc2 = loadu(out, (f + 2) * n + p);
                        let mut acc3 = loadu(out, (f + 3) * n + p);
                        for kk in k0..k0 + kc {
                            let w0 = w[f * k + kk];
                            let w1 = w[(f + 1) * k + kk];
                            let w2 = w[(f + 2) * k + kk];
                            let w3 = w[(f + 3) * k + kk];
                            if (w0 | w1 | w2 | w3) == 0 {
                                continue;
                            }
                            let av = loadu(a, kk * n + p);
                            if w0 != 0 {
                                let v = _mm256_set1_epi32(sign * w0 as i32);
                                acc0 = _mm256_add_epi32(acc0, _mm256_mullo_epi32(v, av));
                            }
                            if w1 != 0 {
                                let v = _mm256_set1_epi32(sign * w1 as i32);
                                acc1 = _mm256_add_epi32(acc1, _mm256_mullo_epi32(v, av));
                            }
                            if w2 != 0 {
                                let v = _mm256_set1_epi32(sign * w2 as i32);
                                acc2 = _mm256_add_epi32(acc2, _mm256_mullo_epi32(v, av));
                            }
                            if w3 != 0 {
                                let v = _mm256_set1_epi32(sign * w3 as i32);
                                acc3 = _mm256_add_epi32(acc3, _mm256_mullo_epi32(v, av));
                            }
                        }
                        storeu(out, f * n + p, acc0);
                        storeu(out, (f + 1) * n + p, acc1);
                        storeu(out, (f + 2) * n + p, acc2);
                        storeu(out, (f + 3) * n + p, acc3);
                        j += 8;
                    }
                    while j < nc {
                        let p = n0 + j;
                        for fr in f..f + 4 {
                            let mut acc = out[fr * n + p];
                            for kk in k0..k0 + kc {
                                let wv = w[fr * k + kk];
                                if wv == 0 {
                                    continue;
                                }
                                acc += sign * wv as i32 * a[kk * n + p];
                            }
                            out[fr * n + p] = acc;
                        }
                        j += 1;
                    }
                    f += 4;
                }
                while f < rows {
                    let mut j = 0;
                    while j + 8 <= nc {
                        let p = n0 + j;
                        let mut acc = loadu(out, f * n + p);
                        for kk in k0..k0 + kc {
                            let wv = w[f * k + kk];
                            if wv == 0 {
                                continue;
                            }
                            let v = _mm256_set1_epi32(sign * wv as i32);
                            let av = loadu(a, kk * n + p);
                            acc = _mm256_add_epi32(acc, _mm256_mullo_epi32(v, av));
                        }
                        storeu(out, f * n + p, acc);
                        j += 8;
                    }
                    while j < nc {
                        let p = n0 + j;
                        let mut acc = out[f * n + p];
                        for kk in k0..k0 + kc {
                            let wv = w[f * k + kk];
                            if wv == 0 {
                                continue;
                            }
                            acc += sign * wv as i32 * a[kk * n + p];
                        }
                        out[f * n + p] = acc;
                        j += 1;
                    }
                    f += 1;
                }
                k0 += kc;
            }
            n0 += nc;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn merge_shifted(sign: i32, shift: u32, t: &[i32], out: &mut [i32]) {
        let cnt = _mm_cvtsi32_si128(shift as i32);
        let len = t.len();
        let mut i = 0;
        if sign >= 0 {
            while i + 8 <= len {
                let v = _mm256_sll_epi32(loadu(t, i), cnt);
                storeu(out, i, _mm256_add_epi32(loadu(out, i), v));
                i += 8;
            }
        } else {
            while i + 8 <= len {
                let v = _mm256_sll_epi32(loadu(t, i), cnt);
                storeu(out, i, _mm256_sub_epi32(loadu(out, i), v));
                i += 8;
            }
        }
        while i < len {
            out[i] += sign * (t[i] << shift);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn col_sum_a(a: &[u8], k: usize, n: usize, sums: &mut [i64]) {
        // i32 block partials (K ≤ 33 000 · 255 < 2^31, see the core
        // assert), widened to i64 once per column block.
        let mut j = 0;
        while j + 8 <= n {
            let mut acc = _mm256_setzero_si256();
            for kk in 0..k {
                acc = _mm256_add_epi32(acc, widen8(a.as_ptr().add(kk * n + j)));
            }
            add_i32x8_to_i64(sums, j, acc);
            j += 8;
        }
        while j < n {
            let mut s = 0i64;
            for kk in 0..k {
                s += a[kk * n + j] as i64;
            }
            sums[j] += s;
            j += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn col_sum_x(
        xf: XForm,
        start: usize,
        step: usize,
        a: &[u8],
        k: usize,
        n: usize,
        sums: &mut [i64],
    ) {
        let mut j = 0;
        while j + 8 <= n {
            let mut acc = _mm256_setzero_si256();
            let mut kk = start;
            while kk < k {
                acc = _mm256_add_epi32(acc, apply(xf, widen8(a.as_ptr().add(kk * n + j))));
                kk += step;
            }
            add_i32x8_to_i64(sums, j, acc);
            j += 8;
        }
        while j < n {
            let mut s = 0i64;
            let mut kk = start;
            while kk < k {
                s += xform_eval(xf, a[kk * n + j]) as i64;
                kk += step;
            }
            sums[j] += s;
            j += 1;
        }
    }
}

// ---------------------------------------------------------------------------
// Selection.

static SCALAR: ScalarKernel = ScalarKernel;
static SIMD: OnceLock<SimdKernel> = OnceLock::new();
static ACTIVE: OnceLock<&'static dyn Kernel> = OnceLock::new();

/// The portable scalar reference kernel.
pub fn scalar() -> &'static dyn Kernel {
    &SCALAR
}

/// The SIMD kernel (AVX2 lanes when the cpu has them, portable chunked
/// lanes otherwise — cpuid probed once per process).
pub fn simd() -> &'static dyn Kernel {
    SIMD.get_or_init(SimdKernel::detect)
}

/// True when the SIMD kernel runs real AVX2 lanes on this host — what
/// benches and CI use to annotate speedup rows honestly.
pub fn simd_is_accelerated() -> bool {
    SIMD.get_or_init(SimdKernel::detect).is_accelerated()
}

/// Resolve a backend by name: `scalar` / `simd` pin that backend
/// (`simd` is valid on every host — without AVX2 it runs its portable
/// chunked lanes); `auto` and anything unrecognized pick simd exactly
/// when its AVX2 lanes are live, else the scalar fallback.
pub fn select(name: &str) -> &'static dyn Kernel {
    match name {
        "scalar" => scalar(),
        "simd" => simd(),
        _ => {
            if simd_is_accelerated() {
                simd()
            } else {
                scalar()
            }
        }
    }
}

/// The process-wide kernel: `CVAPPROX_KERNEL` (`auto` / `scalar` / `simd`)
/// resolved once on first use. Engines capture this at construction; the
/// transient gemm wrappers route through it on every call.
pub fn active() -> &'static dyn Kernel {
    *ACTIVE.get_or_init(|| match std::env::var("CVAPPROX_KERNEL") {
        Ok(v) => select(v.trim()),
        Err(_) => select("auto"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Every backend worth pinning against the scalar reference: the
    /// detected SIMD kernel plus a forced-portable one, so the chunked
    /// path is exercised even on AVX2 hosts (and vice versa the AVX2 path
    /// wherever CI has it).
    fn simd_variants() -> Vec<(&'static str, SimdKernel)> {
        vec![
            ("simd-detected", SimdKernel::detect()),
            ("simd-portable", SimdKernel { avx2: false }),
        ]
    }

    #[test]
    fn xform_matches_xvar_pol_exhaustively() {
        for family in Family::ALL {
            for pol in Polarity::ALL {
                for m in 0..=7u32 {
                    let xf = xform_for(family, pol, m);
                    for a in 0..=255u8 {
                        assert_eq!(
                            xform_eval(xf, a),
                            xvar_pol(family, pol, a, m),
                            "{} {} m={m} a={a}",
                            family.name(),
                            pol.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn elementwise_ops_match_scalar_on_ragged_lengths() {
        let mut rng = Rng::new(0x51D0);
        let sk = ScalarKernel;
        for len in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 64, 100] {
            let src: Vec<u8> = (0..len).map(|_| rng.u8()).collect();
            for (name, kr) in simd_variants() {
                let mut want = vec![0i32; len];
                let mut got = vec![0i32; len];
                sk.widen_u8(&src, &mut want);
                kr.widen_u8(&src, &mut got);
                assert_eq!(got, want, "{name} widen len={len}");
                for m in 1..=7u32 {
                    for pol in Polarity::ALL {
                        sk.mask_low(pol, m, &src, &mut want);
                        kr.mask_low(pol, m, &src, &mut got);
                        assert_eq!(got, want, "{name} mask_low m={m} len={len}");
                    }
                    let bit = m - 1;
                    sk.bit_plane(bit, &src, &mut want);
                    kr.bit_plane(bit, &src, &mut got);
                    assert_eq!(got, want, "{name} bit_plane bit={bit} len={len}");
                }
                let t: Vec<i32> = (0..len).map(|_| rng.range_i64(-9000, 9000) as i32).collect();
                let mut wo: Vec<i32> = (0..len).map(|_| rng.range_i64(-500, 500) as i32).collect();
                let mut go = wo.clone();
                for (sign, shift) in [(1i32, 0u32), (-1, 3), (1, 6), (-1, 7)] {
                    sk.merge_shifted(sign, shift, &t, &mut wo);
                    kr.merge_shifted(sign, shift, &t, &mut go);
                    assert_eq!(go, wo, "{name} merge sign={sign} shift={shift} len={len}");
                }
                let mut wacc = vec![0i64; len];
                let mut gacc = vec![0i64; len];
                sk.widen_acc(&t, &mut wacc);
                kr.widen_acc(&t, &mut gacc);
                assert_eq!(gacc, wacc, "{name} widen_acc len={len}");
            }
        }
    }

    #[test]
    fn column_sums_match_scalar_over_parities_and_tails() {
        let mut rng = Rng::new(0x51D1);
        let sk = ScalarKernel;
        for (k, n) in [(1usize, 1usize), (5, 7), (8, 8), (9, 17), (31, 24), (64, 33)] {
            let a: Vec<u8> = (0..k * n).map(|_| rng.u8()).collect();
            for (name, kr) in simd_variants() {
                let mut want = vec![0i64; n];
                let mut got = vec![0i64; n];
                sk.col_sum_a(&a, k, n, &mut want);
                kr.col_sum_a(&a, k, n, &mut got);
                assert_eq!(got, want, "{name} col_sum_a {k}x{n}");
                for family in Family::ALL {
                    for pol in Polarity::ALL {
                        let m = if family == Family::Exact { 0 } else { 1 + rng.below(7) as u32 };
                        for (start, step) in [(0usize, 1usize), (0, 2), (1, 2)] {
                            want.fill(0);
                            got.fill(0);
                            sk.col_sum_x(family, pol, m, start, step, &a, k, n, &mut want);
                            kr.col_sum_x(family, pol, m, start, step, &a, k, n, &mut got);
                            assert_eq!(
                                got, want,
                                "{name} col_sum_x {} {} m={m} {start}+{step} {k}x{n}",
                                family.name(),
                                pol.name()
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn gemm_chunk_matches_scalar_over_lane_tails() {
        // Shapes straddling the 8-lane and 4-row block edges, both signs,
        // with zero-heavy weights so the skip paths are exercised.
        let mut rng = Rng::new(0x51D2);
        let sk = ScalarKernel;
        for (rows, k, n) in
            [(1usize, 1usize, 1usize), (3, 7, 5), (4, 8, 8), (5, 9, 9), (7, 17, 23), (12, 33, 40)]
        {
            let w: Vec<u8> =
                (0..rows * k).map(|_| if rng.below(3) == 0 { 0 } else { rng.u8() }).collect();
            let a: Vec<i32> = (0..k * n).map(|_| rng.range_i64(-128, 255) as i32).collect();
            let init: Vec<i32> = (0..rows * n).map(|_| rng.range_i64(-99, 99) as i32).collect();
            for sign in [1i32, -1] {
                let mut want = init.clone();
                sk.gemm_chunk(&w, &a, rows, k, n, sign, &mut want);
                for (name, kr) in simd_variants() {
                    let mut got = init.clone();
                    kr.gemm_chunk(&w, &a, rows, k, n, sign, &mut got);
                    assert_eq!(got, want, "{name} gemm_chunk {rows}x{k}x{n} sign={sign}");
                }
            }
        }
    }

    #[test]
    fn selection_pins_names_and_auto_follows_cpuid() {
        assert_eq!(select("scalar").name(), "scalar");
        assert_eq!(select("simd").name(), "simd");
        let auto = select("auto");
        if simd_is_accelerated() {
            assert_eq!(auto.name(), "simd");
        } else {
            assert_eq!(auto.name(), "scalar");
        }
        // Unrecognized values degrade to auto, never to a panic.
        assert_eq!(select("???").name(), auto.name());
        // The process-wide choice is one of the two real backends.
        assert!(matches!(active().name(), "scalar" | "simd"));
    }
}
