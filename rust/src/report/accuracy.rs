//! Accuracy evaluation harness — regenerates **Tables 2-4** (accuracy loss
//! per net × family × m, with and without V) and **Fig. 10** (accuracy-loss
//! vs normalized-power Pareto space).

use std::path::Path;

use anyhow::{Context, Result};

use crate::approx::Family;
use crate::coordinator::service::argmax;
use crate::datasets::Dataset;
use crate::hw::array_cost;
use crate::nn::{loader, Engine, ForwardOpts};
use crate::util::threadpool::par_map;

/// The six nets and two datasets of the evaluation (§5.2).
pub const NETS: [&str; 6] =
    ["mininet", "vggnet11", "resnet8", "resnet14", "inceptionnet", "shufflenet"];
pub const DATASETS: [&str; 2] = ["synth10", "synth100"];

/// Accuracy of one configuration over the first `n` test images.
pub fn evaluate(
    engine: &Engine,
    ds: &Dataset,
    opts: &ForwardOpts,
    n: usize,
    workers: usize,
) -> Result<f64> {
    let n = n.min(ds.n);
    let correct: usize = par_map(n, workers, |i| {
        let img = ds.image(i);
        let logits = engine.forward(&img, opts).expect("forward");
        (argmax(&logits) == ds.label(i)) as usize
    })
    .into_iter()
    .sum();
    Ok(correct as f64 / n as f64)
}

/// One Table 2-4 row cell: accuracy losses for a (net, ds, family, m).
#[derive(Clone, Debug)]
pub struct AccuracyCell {
    pub net: String,
    pub dataset: String,
    pub family: Family,
    pub m: u32,
    pub exact_acc: f64,
    pub ours_acc: f64,
    pub raw_acc: f64,
}

impl AccuracyCell {
    /// Accuracy loss (%) vs the exact design — the paper's "Ours" column.
    pub fn ours_loss(&self) -> f64 {
        100.0 * (self.exact_acc - self.ours_acc)
    }

    /// Accuracy loss (%) without the control variate — "w/o V".
    pub fn raw_loss(&self) -> f64 {
        100.0 * (self.exact_acc - self.raw_acc)
    }
}

/// Evaluate one (net, dataset) across every m of `family`, with/without V.
#[allow(clippy::too_many_arguments)]
pub fn sweep_net(
    artifacts: &Path,
    net: &str,
    dataset: &str,
    family: Family,
    n_images: usize,
    workers: usize,
    lut: bool,
    log: &mut dyn FnMut(&str),
) -> Result<Vec<AccuracyCell>> {
    let model = loader::load_model(&artifacts.join(format!("models/{net}_{dataset}.cvm")))
        .with_context(|| format!("{net}_{dataset}"))?;
    let ds = Dataset::load(&artifacts.join(format!("data/{dataset}_test.cvd")))?;
    let mut engine = Engine::new(model);
    let exact = evaluate(&engine, &ds, &ForwardOpts::exact(), n_images, workers)?;
    let mut cells = Vec::new();
    for &m in family.paper_levels() {
        // The LUT engine is ~4x faster than the m bit-plane GEMMs of the
        // truncated identity path (EXPERIMENTS.md §Perf) — auto-select it.
        if lut || family == Family::Truncated {
            engine.prepare_lut(family, m);
        }
        let ours = evaluate(
            &engine,
            &ds,
            &ForwardOpts::approx(family, m, true),
            n_images,
            workers,
        )?;
        let raw = evaluate(
            &engine,
            &ds,
            &ForwardOpts::approx(family, m, false),
            n_images,
            workers,
        )?;
        let cell = AccuracyCell {
            net: net.into(),
            dataset: dataset.into(),
            family,
            m,
            exact_acc: exact,
            ours_acc: ours,
            raw_acc: raw,
        };
        log(&format!(
            "  {net}/{dataset} {} m={m}: exact {:.3} ours {:.3} (loss {:+.2}%) \
             w/oV {:.3} (loss {:+.2}%)",
            family.name(),
            exact,
            ours,
            cell.ours_loss(),
            raw,
            cell.raw_loss()
        ));
        cells.push(cell);
    }
    Ok(cells)
}

/// One Fig.-10 Pareto point.
#[derive(Clone, Debug)]
pub struct ParetoPoint {
    pub family: Family,
    pub m: u32,
    pub use_cv: bool,
    pub power_norm: f64,
    pub acc_loss_pct: f64,
}

/// Accuracy-vs-power points for one (net, dataset) over every family × m ×
/// {with V, without V} at array size `n_array` (Fig. 10 uses N=64).
pub fn pareto_points(
    artifacts: &Path,
    net: &str,
    dataset: &str,
    n_images: usize,
    n_array: u32,
    workers: usize,
) -> Result<Vec<ParetoPoint>> {
    let model =
        loader::load_model(&artifacts.join(format!("models/{net}_{dataset}.cvm")))?;
    let ds = Dataset::load(&artifacts.join(format!("data/{dataset}_test.cvd")))?;
    let mut engine = Engine::new(model);
    let exact = evaluate(&engine, &ds, &ForwardOpts::exact(), n_images, workers)?;
    let mut points = Vec::new();
    for family in Family::APPROX {
        for &m in family.paper_levels() {
            if family == Family::Truncated {
                engine.prepare_lut(family, m); // see sweep_net
            }
            let power = array_cost(family, m, n_array).power_norm;
            for use_cv in [true, false] {
                let acc = evaluate(
                    &engine,
                    &ds,
                    &ForwardOpts::approx(family, m, use_cv),
                    n_images,
                    workers,
                )?;
                points.push(ParetoPoint {
                    family,
                    m,
                    use_cv,
                    power_norm: power,
                    acc_loss_pct: 100.0 * (exact - acc),
                });
            }
        }
    }
    Ok(points)
}

/// Non-dominated subset (min power, min loss).
pub fn pareto_front(points: &[ParetoPoint]) -> Vec<ParetoPoint> {
    let mut front: Vec<ParetoPoint> = Vec::new();
    for p in points {
        let dominated = points.iter().any(|q| {
            (q.power_norm < p.power_norm && q.acc_loss_pct <= p.acc_loss_pct)
                || (q.power_norm <= p.power_norm && q.acc_loss_pct < p.acc_loss_pct)
        });
        if !dominated {
            front.push(p.clone());
        }
    }
    front.sort_by(|a, b| a.power_norm.partial_cmp(&b.power_norm).unwrap());
    front
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::artifacts_dir;

    #[test]
    fn cv_beats_raw_on_aggressive_approximation() {
        let art = artifacts_dir();
        if !art.join("models").is_dir() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut cells = Vec::new();
        let mut log = |_: &str| {};
        for family in [Family::Perforated, Family::Truncated] {
            cells.extend(
                sweep_net(&art, "mininet", "synth10", family, 60, 1, false, &mut log)
                    .unwrap(),
            );
        }
        // At the most aggressive m, ours must beat w/o V (the paper's claim).
        for family in [Family::Perforated, Family::Truncated] {
            let worst = cells
                .iter()
                .filter(|c| c.family == family)
                .max_by_key(|c| c.m)
                .unwrap();
            assert!(
                worst.ours_acc > worst.raw_acc,
                "{}: ours {} !> raw {}",
                family.name(),
                worst.ours_acc,
                worst.raw_acc
            );
        }
    }

    #[test]
    fn pareto_front_is_nondominated() {
        let pts = vec![
            ParetoPoint { family: Family::Perforated, m: 1, use_cv: true, power_norm: 0.7, acc_loss_pct: 1.0 },
            ParetoPoint { family: Family::Perforated, m: 2, use_cv: true, power_norm: 0.6, acc_loss_pct: 2.0 },
            ParetoPoint { family: Family::Recursive, m: 2, use_cv: true, power_norm: 0.8, acc_loss_pct: 3.0 }, // dominated
        ];
        let front = pareto_front(&pts);
        assert_eq!(front.len(), 2);
        assert!(front.iter().all(|p| p.family != Family::Recursive));
    }
}
