//! Report generation + the `cvapprox` CLI.
//!
//! Subcommands (one per paper artifact, see DESIGN.md §4):
//!
//! ```text
//! cvapprox table1   [--samples 1000000]           # Table 1 error moments
//! cvapprox figure7|figure8|figure9                # hw cost sweeps
//! cvapprox table5                                 # MAC+ overhead
//! cvapprox accuracy [--family F] [--nets a,b] [--datasets d] [--n 200]
//!                   [--lut] [--json out.json]     # Tables 2-4
//! cvapprox pareto   [--nets a,b] [--n 200]        # Fig 10
//! cvapprox e2e      [--net resnet8] [--n 200]     # end-to-end service demo
//! cvapprox qos-ladder [--hermetic] [--json l.json] [--search SEARCH_pareto.json]
//!                                                  # adaptive-QoS ladder artifact
//! cvapprox search   [--hermetic] [--generations N] [--pop N] [--seed S]
//!                   [--json [out.json]]            # co-design Pareto search
//! cvapprox srclint  [--json LINT_report.json] [--root PATH] # invariant linter
//! cvapprox info                                   # artifact inventory
//! ```

pub mod accuracy;
pub mod layerwise;
pub mod tables;

use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::approx::stats::table1;
use crate::approx::Family;
use crate::coordinator::{InferenceService, ServiceConfig};
use crate::datasets::Dataset;
use crate::nn::{loader, Engine};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::threadpool::default_workers;
use crate::{artifacts_dir, runtime};

const KNOWN_OPTS: &[&str] = &[
    "samples", "family", "nets", "datasets", "n", "lut", "json", "net", "batch",
    "array", "m", "cv", "engine", "variant", "workers", "max-loss", "budget",
    "policy", "paired", "hermetic", "root", "generations", "pop", "seed", "search",
];

pub fn cli_main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

pub fn run(argv: Vec<String>) -> Result<()> {
    let args = Args::parse(argv, KNOWN_OPTS)?;
    match args.command.as_deref() {
        Some("table1") => cmd_table1(&args),
        Some("figure7") => cmd_figure(Family::Perforated, &args),
        Some("figure8") => cmd_figure(Family::Truncated, &args),
        Some("figure9") => cmd_figure(Family::Recursive, &args),
        Some("table5") => {
            println!("{}", tables::render_table5());
            Ok(())
        }
        Some("accuracy") => cmd_accuracy(&args),
        Some("pareto") => cmd_pareto(&args),
        Some("e2e") => cmd_e2e(&args),
        Some("layerwise") => cmd_layerwise(&args),
        Some("qos-ladder") => cmd_qos_ladder(&args),
        Some("search") => cmd_search(&args),
        Some("figure4") => cmd_figure4(&args),
        Some("srclint") => cmd_srclint(&args),
        Some("info") => cmd_info(),
        other => {
            bail!(
                "unknown or missing subcommand {:?}; try: table1 figure7 figure8 \
                 figure9 table5 accuracy pareto e2e layerwise qos-ladder search \
                 figure4 srclint info",
                other
            )
        }
    }
}

fn write_json(args: &Args, j: &Json) -> Result<()> {
    if let Some(path) = args.get("json") {
        std::fs::write(path, j.render()).with_context(|| format!("writing {path}"))?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_table1(args: &Args) -> Result<()> {
    let n = args.get_usize("samples", 1_000_000)? as u64;
    let t0 = Instant::now();
    let rows = table1(n, 2024);
    println!("{}", tables::render_table1(&rows));
    println!("({n} samples per cell, {:.1}s)", t0.elapsed().as_secs_f64());
    write_json(args, &tables::table1_json(&rows))
}

fn cmd_figure(family: Family, args: &Args) -> Result<()> {
    println!("{}", tables::render_hw_figure(family));
    write_json(args, &tables::hw_figure_json(family))
}

fn parse_families(args: &Args) -> Result<Vec<Family>> {
    match args.get("family") {
        None | Some("all") => Ok(Family::APPROX.to_vec()),
        Some(name) => {
            let f = Family::from_name(name)
                .with_context(|| format!("unknown family {name}"))?;
            Ok(vec![f])
        }
    }
}

fn parse_list<'a>(args: &'a Args, key: &str, default: &[&'a str]) -> Vec<String> {
    match args.get(key) {
        Some(s) => s.split(',').map(|x| x.trim().to_string()).collect(),
        None => default.iter().map(|s| s.to_string()).collect(),
    }
}

fn cmd_accuracy(args: &Args) -> Result<()> {
    let art = artifacts_dir();
    let families = parse_families(args)?;
    let nets = parse_list(args, "nets", &accuracy::NETS);
    let datasets = parse_list(args, "datasets", &accuracy::DATASETS);
    let n = args.get_usize("n", 200)?;
    let workers = args.get_usize("workers", default_workers())?;
    let lut = args.flag("lut");
    let t0 = Instant::now();
    let mut all = Vec::new();
    for family in &families {
        let mut cells = Vec::new();
        for ds in &datasets {
            for net in &nets {
                let mut log = |s: &str| println!("{s}");
                cells.extend(accuracy::sweep_net(
                    &art, net, ds, *family, n, workers, lut, &mut log,
                )?);
            }
        }
        println!("\n{}", tables::render_accuracy_table(*family, &cells));
        all.extend(cells);
    }
    println!("({n} test images per cell, {:.1}s)", t0.elapsed().as_secs_f64());
    write_json(args, &tables::accuracy_json(&all))
}

fn cmd_pareto(args: &Args) -> Result<()> {
    let art = artifacts_dir();
    let nets = parse_list(args, "nets", &["resnet8", "shufflenet", "vggnet11"]);
    let n = args.get_usize("n", 200)?;
    let n_array = args.get_usize("array", 64)? as u32;
    let workers = args.get_usize("workers", default_workers())?;
    let max_loss: f64 = args.get_or("max-loss", "10").parse()?;
    let mut all_json = Vec::new();
    for net in &nets {
        let pts = accuracy::pareto_points(&art, net, "synth100", n, n_array, workers)?;
        let front = accuracy::pareto_front(&pts);
        println!("{}", tables::render_pareto(net, &pts, &front, max_loss));
        for p in &pts {
            all_json.push(
                Json::obj()
                    .field("net", net.as_str())
                    .field("family", p.family.name())
                    .field("m", p.m as i64)
                    .field("use_cv", p.use_cv)
                    .field("power_norm", p.power_norm)
                    .field("acc_loss_pct", p.acc_loss_pct),
            );
        }
    }
    write_json(args, &Json::Arr(all_json))
}

/// End-to-end demo: serve the test set through the coordinator on one
/// configuration and print the service metrics.
fn cmd_e2e(args: &Args) -> Result<()> {
    let art = artifacts_dir();
    let net = args.get_or("net", "resnet8");
    let ds_name = args.get_or("datasets", "synth10");
    let family = Family::from_name(args.get_or("family", "perforated"))
        .context("bad family")?;
    let m: u32 = args.get_or("m", "2").parse()?;
    let use_cv = args.get_or("cv", "true").parse::<bool>()?;
    let n = args.get_usize("n", 200)?;
    let batch = args.get_usize("batch", 8)?;
    let n_array = args.get_usize("array", 64)? as u32;

    let model = loader::load_model(&art.join(format!("models/{net}_{ds_name}.cvm")))?;
    let macs = model.macs();
    let ds = Dataset::load(&art.join(format!("data/{ds_name}_test.cvd")))?;
    let mut engine = Engine::new(model);
    match args.get_or("engine", "native") {
        "native" => {}
        "lut" => engine.prepare_lut(family, m),
        "pjrt" => {
            let variant = match args.get_or("variant", "fast") {
                "pallas" => runtime::Variant::Pallas,
                _ => runtime::Variant::Fast,
            };
            let rt = std::sync::Arc::new(runtime::TileGemm::new(&art)?);
            println!("PJRT platform: {}", rt.platform());
            engine.attach_pjrt(rt, variant);
        }
        other => bail!("unknown engine {other}"),
    }
    let workers =
        args.get_usize("workers", crate::coordinator::default_service_workers())?;
    // --policy FILE serves a per-layer heterogeneous policy (e.g. the one
    // `cvapprox layerwise --json` emits) instead of the uniform triple.
    let policy = match args.get("policy") {
        Some(path) => {
            let p = crate::nn::LayerPolicy::load(std::path::Path::new(path))?;
            println!("policy: {}", p.describe());
            Some(std::sync::Arc::new(p))
        }
        None => None,
    };
    let cfg = ServiceConfig {
        family,
        m,
        use_cv,
        policy,
        n_array,
        workers,
        batch_size: batch,
        ..Default::default()
    };
    println!(
        "e2e: {net}/{ds_name} {} m={m} cv={use_cv} engine={} n={n} workers={workers} \
         ({} MACs/img)",
        family.name(),
        args.get_or("engine", "native"),
        macs
    );
    let svc = InferenceService::start(engine, cfg)?;
    let n = n.min(ds.n);
    let pending = (0..n)
        .map(|i| svc.submit(ds.image(i)))
        .collect::<Result<Vec<_>>>()?;
    let mut correct = 0usize;
    for (i, p) in pending.into_iter().enumerate() {
        let r = p.wait()?;
        correct += (r.top1 == ds.label(i)) as usize;
    }
    let snap = svc.shutdown();
    println!("  accuracy:        {:.3} ({correct}/{n})", correct as f64 / n as f64);
    println!("  throughput:      {:.1} img/s", snap.throughput_rps);
    println!(
        "  latency:         mean {:.2} ms, p50/p95/p99 {:.2}/{:.2}/{:.2} ms \
         (histogram, incl. queueing)",
        snap.mean_latency.as_secs_f64() * 1e3,
        snap.p50_latency.as_secs_f64() * 1e3,
        snap.p95_latency.as_secs_f64() * 1e3,
        snap.p99_latency.as_secs_f64() * 1e3
    );
    println!(
        "  batches:         {} over {} workers (avg {:.1} img/batch)",
        snap.batches,
        snap.worker_batches.len(),
        snap.mean_batch_size
    );
    println!(
        "  modeled energy:  {:.3}x exact array ({:.1}% saving) on {}x{} MACs",
        snap.energy_vs_exact,
        100.0 * (1.0 - snap.energy_vs_exact),
        n_array,
        n_array
    );
    Ok(())
}

/// Fig. 4: weight distributions of trained filters — the "squeezed
/// dispersion" premise behind C = E[W] (eq. 21). Prints an ASCII histogram
/// of the uint8 weights of a few filters plus the per-filter coefficient of
/// variation summary.
fn cmd_figure4(args: &Args) -> Result<()> {
    let art = artifacts_dir();
    let net = args.get_or("net", "resnet8");
    let ds = args.get_or("datasets", "synth10");
    let model = loader::load_model(&art.join(format!("models/{net}_{ds}.cvm")))?;
    println!("FIG 4 — weight distributions, {net}/{ds} (uint8 domain)\n");
    let mut shown = 0;
    let mut cv_sum = 0.0;
    let mut cv_n = 0usize;
    for (i, node) in model.nodes.iter().enumerate() {
        let Some(w) = &node.weights else { continue };
        // per-filter stats across the whole layer
        for f in 0..(w.b_q.len()) {
            let row = &w.w_q[f * w.k_dim..(f + 1) * w.k_dim];
            let mean = row.iter().map(|&x| x as f64).sum::<f64>() / row.len() as f64;
            let var = row.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>()
                / row.len() as f64;
            if mean > 0.0 {
                cv_sum += var.sqrt() / mean;
                cv_n += 1;
            }
        }
        if shown < 3 && w.k_dim >= 27 {
            let row = &w.w_q[..w.k_dim];
            let mut hist = [0u32; 16];
            for &x in row {
                hist[(x >> 4) as usize] += 1;
            }
            let peak = *hist.iter().max().unwrap() as f64;
            println!("  node {i} filter 0 ({} weights):", w.k_dim);
            for (b, &h) in hist.iter().enumerate() {
                let bar = "#".repeat((h as f64 / peak * 40.0).round() as usize);
                println!("    [{:>3}-{:>3}] {bar}", b * 16, b * 16 + 15);
            }
            shown += 1;
        }
    }
    println!(
        "\n  mean per-filter coefficient of variation sigma/mu = {:.2} \
         (weights concentrate around E[W], which is what makes C = E[W] an \
         effective control-variate coefficient — paper Fig. 4)",
        cv_sum / cv_n as f64
    );
    Ok(())
}

/// Generate the adaptive-QoS ladder artifact (exact → greedy mixed →
/// greedy paired → aggressive uniform; see `qos::Ladder`). `--hermetic`
/// builds it on the checked-in hermetic mini-artifacts — deterministic and
/// artifact-free, which is what the CI smoke and `benches/qos_adaptive.rs`
/// use; otherwise `--net`/`--datasets` select from `artifacts/`.
fn cmd_qos_ladder(args: &Args) -> Result<()> {
    let hermetic = args.flag("hermetic");
    let (root, net, ds_name) = if hermetic {
        (crate::hermetic_dir(), "hermnet".to_string(), "hsynth".to_string())
    } else {
        (
            artifacts_dir(),
            args.get_or("net", "resnet8").to_string(),
            args.get_or("datasets", "synth10").to_string(),
        )
    };
    let family = Family::from_name(args.get_or("family", "perforated"))
        .context("bad family")?;
    let m_hi: u32 = args.get_or("m", "3").parse()?;
    let budget: f64 = args.get_or("budget", "0.8").parse()?;
    let n_array = args.get_usize("array", 64)? as u32;
    let model = loader::load_model(&root.join(format!("models/{net}_{ds_name}.cvm")))?;
    let ds = Dataset::load(&root.join(format!("data/{ds_name}_test.cvd")))?;
    let n = args.get_usize("n", 150)?.min(ds.n);
    let engine = Engine::new(model);
    println!(
        "QoS ladder: {net}/{ds_name}, {} m_hi={m_hi}, budget {budget}% \
         ({n} images, {n_array}x{n_array} array)\n",
        family.name()
    );
    // --search FILE merges a `cvapprox search` front into the greedy
    // ladder; its genomes re-validate on load, so a bad artifact is a
    // clean error here, never a panic or a crooked ladder.
    let ladder = match args.get("search") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading search front {path}"))?;
            let front = crate::search::parse_front(
                &Json::parse(&text).context("search artifact JSON")?,
            )?;
            println!("merging {} searched front member(s) from {path}\n", front.len());
            layerwise::qos_ladder_with_search(
                &engine, &ds, family, m_hi, budget, n, n_array, &front,
            )?
        }
        None => layerwise::qos_ladder(&engine, &ds, family, m_hi, budget, n, n_array)?,
    };
    println!(
        "{:<20} {:>10} {:>12}  policy",
        "rung", "power", "est_loss"
    );
    for r in ladder.rungs() {
        println!(
            "{:<20} {:>9.3}x {:>11.2}%  {}",
            r.name,
            r.power_norm,
            100.0 * r.est_loss,
            r.policy.describe()
        );
    }
    if let Some(path) = args.get("json") {
        ladder.save_json(std::path::Path::new(path))?;
        println!("\nwrote {path}");
    }
    Ok(())
}

/// `cvapprox search`: the seeded multiplier/assignment co-design search
/// (see `search/`). Evolves per-layer drop-mask genomes jointly with
/// assignment under (est. accuracy loss, MAC-weighted power) and emits the
/// Pareto front as `SEARCH_pareto.json` — the artifact `qos-ladder
/// --search` merges into the governor's ladder. The evolution is seeded
/// from the greedy ladder's own policies, so the search starts from the
/// baseline it must dominate. Reproducible from `--seed` at any
/// `--workers` count.
fn cmd_search(args: &Args) -> Result<()> {
    use crate::search::{self, SearchConfig};
    let hermetic = args.flag("hermetic");
    let (root, net, ds_name) = if hermetic {
        (crate::hermetic_dir(), "hermnet".to_string(), "hsynth".to_string())
    } else {
        (
            artifacts_dir(),
            args.get_or("net", "resnet8").to_string(),
            args.get_or("datasets", "synth10").to_string(),
        )
    };
    let family = Family::from_name(args.get_or("family", "perforated"))
        .context("bad family")?;
    let m_hi: u32 = args.get_or("m", "3").parse()?;
    let budget: f64 = args.get_or("budget", "0.8").parse()?;
    let model = loader::load_model(&root.join(format!("models/{net}_{ds_name}.cvm")))?;
    let ds = Dataset::load(&root.join(format!("data/{ds_name}_test.cvd")))?;
    let n = args.get_usize("n", if hermetic { 64 } else { 150 })?.min(ds.n);
    let engine = Engine::new(model);
    let mut cfg = SearchConfig::from_env(n);
    cfg.generations = args.get_usize("generations", cfg.generations)?;
    cfg.pop = args.get_usize("pop", cfg.pop)?.max(2);
    if let Some(s) = args.get("seed") {
        cfg.seed = s.parse().context("bad --seed")?;
    }
    cfg.n_array = args.get_usize("array", cfg.n_array as usize)? as u32;
    cfg.workers = args.get_usize("workers", cfg.workers)?;
    let base = layerwise::qos_ladder(&engine, &ds, family, m_hi, budget, n, cfg.n_array)?;
    for r in base.rungs() {
        if let Some(g) = search::Genome::from_policy(&r.policy) {
            cfg.seeds.push(g);
        }
    }
    println!(
        "co-design search: {net}/{ds_name}, seed {} gens {} pop {} \
         ({n} images, {}x{} array, {} workers)\n",
        cfg.seed, cfg.generations, cfg.pop, cfg.n_array, cfg.n_array, cfg.workers
    );
    let result = search::run_search(&engine, &ds, &cfg)?;
    println!(
        "{:<12} {:>10} {:>12}  genome",
        "member", "power", "est_loss"
    );
    for (i, m) in result.front.iter().enumerate() {
        println!(
            "{:<12} {:>9.3}x {:>11.2}%  {}",
            format!("search-{i}"),
            m.power_norm,
            100.0 * m.est_loss,
            m.genome.describe()
        );
    }
    println!(
        "\n{} front member(s) from {} evaluation(s) ({} memoized)",
        result.front.len(),
        result.evals,
        result.memo_hits
    );
    let json_path = args
        .get("json")
        .map(str::to_string)
        .or_else(|| args.flag("json").then(|| "SEARCH_pareto.json".to_string()));
    if let Some(path) = &json_path {
        std::fs::write(path, result.to_json().render())
            .with_context(|| format!("writing {path}"))?;
        println!("wrote {path}");
    }
    Ok(())
}

/// Layer-wise mixed-m search (the ALWANN-style extension, DESIGN.md §12).
fn cmd_layerwise(args: &Args) -> Result<()> {
    let art = artifacts_dir();
    let net = args.get_or("net", "resnet8");
    let ds = args.get_or("datasets", "synth10");
    let family = Family::from_name(args.get_or("family", "perforated"))
        .context("bad family")?;
    let m_hi: u32 = args.get_or("m", "3").parse()?;
    let budget: f64 = args.get_or("budget", "1.0").parse()?;
    let n = args.get_usize("n", 150)?;
    let out = args.get("json").map(std::path::Path::new);
    // --paired upgrades the mixed result into the positive/negative paired
    // space and emits the paired policy as the JSON artifact.
    layerwise::run(&art, net, ds, family, m_hi, budget, n, args.flag("paired"), out)
}

/// `cvapprox srclint`: run the project-invariant linter over the repo
/// tree (see `analyze/`). Exits non-zero (via the `Err` path of
/// `cli_main`) when any finding survives suppression, which is what lets
/// verify.sh and CI use it as a hard gate. `--json` (flag or
/// `--json PATH`) writes the `LINT_report.json` artifact.
fn cmd_srclint(args: &Args) -> Result<()> {
    let root = match args.get("root") {
        Some(p) => std::path::PathBuf::from(p),
        None => crate::analyze::repo_root(),
    };
    let report = crate::analyze::run_lint(&root)?;
    print!("{}", report.render());
    let json_path = args
        .get("json")
        .map(str::to_string)
        .or_else(|| args.flag("json").then(|| "LINT_report.json".to_string()));
    if let Some(path) = &json_path {
        std::fs::write(path, report.to_json().render())
            .with_context(|| format!("writing {path}"))?;
        println!("wrote {path}");
    }
    if !report.clean() {
        bail!("srclint: {} finding(s) — see output above", report.findings.len());
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    let art = artifacts_dir();
    println!("artifacts: {}", art.display());
    for sub in ["hlo", "models", "data", "golden"] {
        let dir = art.join(sub);
        let count = std::fs::read_dir(&dir).map(|d| d.count()).unwrap_or(0);
        println!("  {sub:<8} {count} files");
    }
    let models = art.join("models");
    if models.is_dir() {
        let mut entries: Vec<_> =
            std::fs::read_dir(&models)?.filter_map(|e| e.ok()).collect();
        entries.sort_by_key(|e| e.file_name());
        for e in entries {
            if let Ok(m) = loader::load_model(&e.path()) {
                println!(
                    "    {:<24} {:>3} nodes {:>2} MAC layers {:>9} params {:>10} MACs",
                    m.name,
                    m.nodes.len(),
                    m.mac_layers(),
                    m.params(),
                    m.macs()
                );
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_subcommand_errors() {
        assert!(run(vec!["bogus".into()]).is_err());
        assert!(run(vec![]).is_err());
    }

    #[test]
    fn table1_small_sample_runs() {
        run(vec!["table1".into(), "--samples".into(), "2000".into()]).unwrap();
    }

    #[test]
    fn hw_figures_run() {
        for cmd in ["figure7", "figure8", "figure9", "table5"] {
            run(vec![cmd.into()]).unwrap();
        }
    }

    #[test]
    fn search_cli_smoke_emits_valid_front() {
        // Tiny search on the hermetic set, then feed the artifact straight
        // back through `qos-ladder --search` — the full CLI loop.
        let tmp = std::env::temp_dir();
        let front_path =
            tmp.join(format!("cvapprox_search_{}.json", std::process::id()));
        run(vec![
            "search".into(),
            "--hermetic".into(),
            "--n".into(),
            "16".into(),
            "--generations".into(),
            "1".into(),
            "--pop".into(),
            "6".into(),
            "--seed".into(),
            "7".into(),
            "--workers".into(),
            "2".into(),
            "--json".into(),
            front_path.to_str().unwrap().into(),
        ])
        .unwrap();
        let text = std::fs::read_to_string(&front_path).unwrap();
        let front =
            crate::search::parse_front(&Json::parse(&text).unwrap()).unwrap();
        assert!(!front.is_empty());
        let ladder_path =
            tmp.join(format!("cvapprox_search_ladder_{}.json", std::process::id()));
        run(vec![
            "qos-ladder".into(),
            "--hermetic".into(),
            "--n".into(),
            "16".into(),
            "--search".into(),
            front_path.to_str().unwrap().into(),
            "--json".into(),
            ladder_path.to_str().unwrap().into(),
        ])
        .unwrap();
        let ladder = crate::qos::Ladder::load(&ladder_path).unwrap();
        assert!(ladder.len() >= 2, "{}", ladder.describe());
        let _ = std::fs::remove_file(&front_path);
        let _ = std::fs::remove_file(&ladder_path);
    }

    #[test]
    fn qos_ladder_cli_runs_on_hermetic() {
        let path = std::env::temp_dir()
            .join(format!("cvapprox_qos_ladder_{}.json", std::process::id()));
        run(vec![
            "qos-ladder".into(),
            "--hermetic".into(),
            "--n".into(),
            "32".into(),
            "--json".into(),
            path.to_str().unwrap().into(),
        ])
        .unwrap();
        let ladder = crate::qos::Ladder::load(&path).unwrap();
        assert!(ladder.len() >= 2, "{}", ladder.describe());
        assert_eq!(ladder.rung(0).name, "exact");
        let _ = std::fs::remove_file(&path);
    }
}
