//! Layer-wise approximation extension (DESIGN.md §12).
//!
//! The paper's related work (ALWANN [9], reconfigurable multipliers [10])
//! assigns *different* approximation levels per layer, but needs either a
//! heterogeneous accelerator per DNN or reconfigurable circuits. In this
//! design `m` is already a runtime input of every engine and of the AOT
//! XLA artifacts, so mixed-m operation costs nothing: the coordinator just
//! streams a different m with each layer's tile batch.
//!
//! This module implements the offline search: per-layer sensitivity
//! analysis (approximate one layer at a time at the family's most
//! aggressive m) and a greedy policy that raises m layer-by-layer, most
//! error-tolerant layer first, while measured accuracy stays within the
//! loss budget. The result frequently beats every uniform-m point: it
//! reaches power savings between the uniform grid points at lower loss.

use std::path::Path;

use anyhow::Result;

use super::accuracy::evaluate;
use crate::approx::Family;
use crate::datasets::Dataset;
use crate::hw::array_cost;
use crate::nn::{loader, Engine, ForwardOpts, LayerPolicy};

/// Sensitivity of each MAC layer: accuracy when ONLY that layer runs
/// approximate (at `m`, with V), everything else exact.
pub struct LayerSensitivity {
    pub layer: usize,
    pub macs: u64,
    pub acc: f64,
}

pub fn sensitivity(
    engine: &Engine,
    ds: &Dataset,
    family: Family,
    m: u32,
    n_images: usize,
) -> Result<Vec<LayerSensitivity>> {
    let n_layers = engine.model.mac_layers();
    let per_layer_macs = engine.model.mac_layer_macs();
    let mut out = Vec::new();
    for layer in 0..n_layers {
        let mut ms = vec![0u32; n_layers];
        ms[layer] = m;
        let opts = ForwardOpts::layerwise(family, ms, true);
        let acc = evaluate(engine, ds, &opts, n_images, 1)?;
        out.push(LayerSensitivity { layer, macs: per_layer_macs[layer], acc });
    }
    Ok(out)
}

/// Result of the greedy mixed-m search.
pub struct Policy {
    pub family: Family,
    pub ms: Vec<u32>,
    pub acc: f64,
    pub exact_acc: f64,
    /// MAC-weighted normalized power of the mixed design.
    pub power_norm: f64,
}

impl Policy {
    /// The runtime artifact: a [`LayerPolicy`] the engine / coordinator /
    /// benches execute directly (`ms[i] == 0` layers run exact; the greedy
    /// search always evaluates with V, so `use_cv = true`).
    pub fn layer_policy(&self) -> Result<LayerPolicy> {
        LayerPolicy::from_ms(self.family, &self.ms, true)
    }
}

/// Greedily raise each layer to `m_hi` (most tolerant first, by the
/// sensitivity pass), keeping measured accuracy within `budget_pct` of
/// exact. Layers that do not fit stay exact (m = 0).
#[allow(clippy::too_many_arguments)]
pub fn greedy_policy(
    engine: &Engine,
    ds: &Dataset,
    family: Family,
    m_hi: u32,
    budget_pct: f64,
    n_images: usize,
    n_array: u32,
    sens: &[LayerSensitivity],
) -> Result<Policy> {
    let exact_acc = evaluate(engine, ds, &ForwardOpts::exact(), n_images, 1)?;
    let floor = exact_acc - budget_pct / 100.0;
    let mut order: Vec<usize> = (0..sens.len()).collect();
    order.sort_by(|&a, &b| sens[b].acc.partial_cmp(&sens[a].acc).unwrap());
    let mut ms = vec![0u32; sens.len()];
    let mut acc = exact_acc;
    for &layer in &order {
        ms[layer] = m_hi;
        let trial = evaluate(
            engine,
            ds,
            &ForwardOpts::layerwise(family, ms.clone(), true),
            n_images,
            1,
        )?;
        if trial >= floor {
            acc = trial;
        } else {
            ms[layer] = 0; // revert
        }
    }
    // MAC-weighted power via the shared policy estimator (approximate
    // layers at array_cost(m_hi), exact layers at 1).
    let power_norm =
        LayerPolicy::from_ms(family, &ms, true)?.power_norm(&engine.model, n_array);
    Ok(Policy { family, ms, acc, exact_acc, power_norm })
}

/// CLI driver: sensitivity table + greedy policy for one (net, family).
/// When `policy_out` is set, the resulting mixed-m [`LayerPolicy`] is
/// written there as JSON — the artifact `ServiceConfig::policy` /
/// `CVAPPROX_SERVICE_POLICY`, `examples/design_space` and
/// `benches/policy_serving` consume.
#[allow(clippy::too_many_arguments)]
pub fn run(
    artifacts: &Path,
    net: &str,
    dataset: &str,
    family: Family,
    m_hi: u32,
    budget_pct: f64,
    n_images: usize,
    policy_out: Option<&Path>,
) -> Result<()> {
    let model =
        loader::load_model(&artifacts.join(format!("models/{net}_{dataset}.cvm")))?;
    let ds = Dataset::load(&artifacts.join(format!("data/{dataset}_test.cvd")))?;
    let mut engine = Engine::new(model);
    if family == Family::Truncated {
        engine.prepare_lut(family, m_hi);
    }
    println!(
        "Layer-wise approximation: {net}/{dataset}, {} m={m_hi}, budget {budget_pct}% \
         ({n_images} images)\n",
        family.name()
    );
    let sens = sensitivity(&engine, &ds, family, m_hi, n_images)?;
    println!("per-layer sensitivity (only that layer approximate, with V):");
    for s in &sens {
        println!(
            "  layer {:>2} ({:>9} MACs): acc {:.3}",
            s.layer, s.macs, s.acc
        );
    }
    let pol = greedy_policy(&engine, &ds, family, m_hi, budget_pct, n_images, 64, &sens)?;
    let n_on = pol.ms.iter().filter(|&&m| m != 0).count();
    println!(
        "\ngreedy mixed-m policy: {n_on}/{} layers at m={m_hi}, rest exact",
        pol.ms.len()
    );
    println!("  ms = {:?}", pol.ms);
    println!(
        "  accuracy {:.3} (exact {:.3}, loss {:+.2}%)",
        pol.acc,
        pol.exact_acc,
        100.0 * (pol.exact_acc - pol.acc)
    );
    println!(
        "  MAC-weighted power {:.3}x vs uniform-m {:.3}x (uniform loss would be higher)",
        pol.power_norm,
        array_cost(family, m_hi, 64).power_norm
    );
    if let Some(out) = policy_out {
        let lp = pol.layer_policy()?;
        lp.save_json(out)?;
        println!("  wrote policy {} -> {}", lp.describe(), out.display());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::{artifacts_dir, hermetic_dir};

    fn hermetic_engine_and_ds() -> (Engine, Dataset) {
        let root = hermetic_dir();
        let model =
            loader::load_model(&root.join("models/hermnet_hsynth.cvm")).unwrap();
        let ds = Dataset::load(&root.join("data/hsynth_test.cvd")).unwrap();
        (Engine::new(model), ds)
    }

    #[test]
    fn hermetic_greedy_policy_dominates_uniform_grid() {
        // The PR's acceptance anchor, fully deterministic (checked-in data,
        // integer arithmetic): labels are the exact argmax, every uniform
        // paper point loses accuracy, and the greedy search finds a mixed
        // policy with ZERO loss at sub-exact power — so the mixed policy
        // beats every uniform point at equal-or-lower accuracy loss.
        let (engine, ds) = hermetic_engine_and_ds();
        let n = ds.n;
        let exact = evaluate(&engine, &ds, &ForwardOpts::exact(), n, 1).unwrap();
        assert_eq!(exact, 1.0, "hermetic labels are the exact argmax");
        for family in Family::APPROX {
            for &m in family.paper_levels() {
                let acc = evaluate(
                    &engine,
                    &ds,
                    &ForwardOpts::approx(family, m, true),
                    n,
                    1,
                )
                .unwrap();
                assert!(
                    acc < exact,
                    "uniform {} m={m} must be lossy on the hermetic set, got {acc}",
                    family.name()
                );
            }
        }
        let sens = sensitivity(&engine, &ds, Family::Perforated, 3, n).unwrap();
        let pol =
            greedy_policy(&engine, &ds, Family::Perforated, 3, 0.8, n, 64, &sens)
                .unwrap();
        let lp = pol.layer_policy().unwrap();
        assert!(
            lp.approx_layers() > 0 && lp.approx_layers() < lp.len(),
            "greedy must yield a genuinely mixed policy, got {}",
            lp.describe()
        );
        assert_eq!(
            pol.acc, exact,
            "a 0.8% budget is below one accuracy quantum (1/64), so the \
             greedy policy must keep zero loss"
        );
        assert!(pol.power_norm < 1.0, "mixed power {}", pol.power_norm);
    }

    #[test]
    fn hermetic_single_layer_softer_than_uniform() {
        // Only the most tolerant layer approximate must be at least as
        // accurate as the uniform point at the same (family, m, V).
        let (engine, ds) = hermetic_engine_and_ds();
        let n = ds.n;
        let n_layers = engine.model.mac_layers();
        let uniform = evaluate(
            &engine,
            &ds,
            &ForwardOpts::approx(Family::Perforated, 3, true),
            n,
            1,
        )
        .unwrap();
        let mut ms = vec![0u32; n_layers];
        ms[0] = 3;
        let single = evaluate(
            &engine,
            &ds,
            &ForwardOpts::layerwise(Family::Perforated, ms, true),
            n,
            1,
        )
        .unwrap();
        assert!(single >= uniform, "single {single} < uniform {uniform}");
    }

    #[test]
    fn hermetic_all_zero_policy_runs_exact() {
        let (engine, ds) = hermetic_engine_and_ds();
        let n_layers = engine.model.mac_layers();
        let img = ds.image(0);
        let all_zero =
            ForwardOpts::layerwise(Family::Perforated, vec![0; n_layers], true);
        let a = engine.forward(&img, &all_zero).unwrap();
        let b = engine.forward(&img, &ForwardOpts::exact()).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn layerwise_single_layer_softer_than_uniform() {
        let art = artifacts_dir();
        if !art.join("models").is_dir() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let model = loader::load_model(&art.join("models/mininet_synth10.cvm")).unwrap();
        let n_layers = model.mac_layers();
        let ds = Dataset::load(&art.join("data/synth10_test.cvd")).unwrap();
        let engine = Engine::new(model);
        let n = 80;
        let uniform = evaluate(
            &engine,
            &ds,
            &ForwardOpts::approx(Family::Perforated, 3, false),
            n,
            1,
        )
        .unwrap();
        // only the first layer approximate: must be at least as accurate
        let mut ms = vec![0u32; n_layers];
        ms[0] = 3;
        let mut single = ForwardOpts::layerwise(Family::Perforated, ms, false);
        single.use_cv = false;
        let single_acc = evaluate(&engine, &ds, &single, n, 1).unwrap();
        assert!(
            single_acc >= uniform,
            "single-layer {single_acc} < uniform {uniform}"
        );
    }

    #[test]
    fn m_zero_layers_run_exact() {
        let art = artifacts_dir();
        if !art.join("models").is_dir() {
            return;
        }
        let model = loader::load_model(&art.join("models/mininet_synth10.cvm")).unwrap();
        let n_layers = model.mac_layers();
        let ds = Dataset::load(&art.join("data/synth10_test.cvd")).unwrap();
        let engine = Engine::new(model);
        let all_zero = ForwardOpts::layerwise(Family::Perforated, vec![0; n_layers], true);
        let img = ds.image(0);
        let a = engine.forward(&img, &all_zero).unwrap();
        let b = engine.forward(&img, &ForwardOpts::exact()).unwrap();
        assert_eq!(a, b);
    }
}
