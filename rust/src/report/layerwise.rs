//! Layer-wise approximation extension (DESIGN.md §12).
//!
//! The paper's related work (ALWANN [9], reconfigurable multipliers [10])
//! assigns *different* approximation levels per layer, but needs either a
//! heterogeneous accelerator per DNN or reconfigurable circuits. In this
//! design `m` is already a runtime input of every engine and of the AOT
//! XLA artifacts, so mixed-m operation costs nothing: the coordinator just
//! streams a different m with each layer's tile batch.
//!
//! This module implements the offline search: per-layer sensitivity
//! analysis (approximate one layer at a time at the family's most
//! aggressive m) and a greedy policy that raises m layer-by-layer, most
//! error-tolerant layer first, while measured accuracy stays within the
//! loss budget. The result frequently beats every uniform-m point: it
//! reaches power savings between the uniform grid points at lower loss.

use std::path::Path;

use anyhow::Result;

use super::accuracy::evaluate;
use crate::approx::Family;
use crate::datasets::Dataset;
use crate::hw::array_cost;
use crate::nn::{loader, Engine, ForwardOpts};

/// Sensitivity of each MAC layer: accuracy when ONLY that layer runs
/// approximate (at `m`, with V), everything else exact.
pub struct LayerSensitivity {
    pub layer: usize,
    pub macs: u64,
    pub acc: f64,
}

pub fn sensitivity(
    engine: &Engine,
    ds: &Dataset,
    family: Family,
    m: u32,
    n_images: usize,
) -> Result<Vec<LayerSensitivity>> {
    let n_layers = engine.model.mac_layers();
    let per_layer_macs: Vec<u64> = engine
        .model
        .nodes
        .iter()
        .filter_map(|n| {
            let w = n.weights.as_ref()?;
            let (h, ww, c) = n.out_shape;
            Some((h * ww * c) as u64 * w.k_dim as u64)
        })
        .collect();
    let mut out = Vec::new();
    for layer in 0..n_layers {
        let mut ms = vec![0u32; n_layers];
        ms[layer] = m;
        let opts = ForwardOpts::layerwise(family, ms, true);
        let acc = evaluate(engine, ds, &opts, n_images, 1)?;
        out.push(LayerSensitivity { layer, macs: per_layer_macs[layer], acc });
    }
    Ok(out)
}

/// Result of the greedy mixed-m search.
pub struct Policy {
    pub ms: Vec<u32>,
    pub acc: f64,
    pub exact_acc: f64,
    /// MAC-weighted normalized power of the mixed design.
    pub power_norm: f64,
}

/// Greedily raise each layer to `m_hi` (most tolerant first, by the
/// sensitivity pass), keeping measured accuracy within `budget_pct` of
/// exact. Layers that do not fit stay exact (m = 0).
#[allow(clippy::too_many_arguments)]
pub fn greedy_policy(
    engine: &Engine,
    ds: &Dataset,
    family: Family,
    m_hi: u32,
    budget_pct: f64,
    n_images: usize,
    n_array: u32,
    sens: &[LayerSensitivity],
) -> Result<Policy> {
    let exact_acc = evaluate(engine, ds, &ForwardOpts::exact(), n_images, 1)?;
    let floor = exact_acc - budget_pct / 100.0;
    let mut order: Vec<usize> = (0..sens.len()).collect();
    order.sort_by(|&a, &b| sens[b].acc.partial_cmp(&sens[a].acc).unwrap());
    let mut ms = vec![0u32; sens.len()];
    let mut acc = exact_acc;
    for &layer in &order {
        ms[layer] = m_hi;
        let trial = evaluate(
            engine,
            ds,
            &ForwardOpts::layerwise(family, ms.clone(), true),
            n_images,
            1,
        )?;
        if trial >= floor {
            acc = trial;
        } else {
            ms[layer] = 0; // revert
        }
    }
    // MAC-weighted power: approximate layers at array_cost(m_hi), exact at 1.
    let p_hi = array_cost(family, m_hi, n_array).power_norm;
    let total: u64 = sens.iter().map(|s| s.macs).sum();
    let approx_macs: u64 =
        sens.iter().filter(|s| ms[s.layer] != 0).map(|s| s.macs).sum();
    let power_norm =
        (approx_macs as f64 * p_hi + (total - approx_macs) as f64) / total as f64;
    Ok(Policy { ms, acc, exact_acc, power_norm })
}

/// CLI driver: sensitivity table + greedy policy for one (net, family).
pub fn run(
    artifacts: &Path,
    net: &str,
    dataset: &str,
    family: Family,
    m_hi: u32,
    budget_pct: f64,
    n_images: usize,
) -> Result<()> {
    let model =
        loader::load_model(&artifacts.join(format!("models/{net}_{dataset}.cvm")))?;
    let ds = Dataset::load(&artifacts.join(format!("data/{dataset}_test.cvd")))?;
    let mut engine = Engine::new(model);
    if family == Family::Truncated {
        engine.prepare_lut(family, m_hi);
    }
    println!(
        "Layer-wise approximation: {net}/{dataset}, {} m={m_hi}, budget {budget_pct}% \
         ({n_images} images)\n",
        family.name()
    );
    let sens = sensitivity(&engine, &ds, family, m_hi, n_images)?;
    println!("per-layer sensitivity (only that layer approximate, with V):");
    for s in &sens {
        println!(
            "  layer {:>2} ({:>9} MACs): acc {:.3}",
            s.layer, s.macs, s.acc
        );
    }
    let pol = greedy_policy(&engine, &ds, family, m_hi, budget_pct, n_images, 64, &sens)?;
    let n_on = pol.ms.iter().filter(|&&m| m != 0).count();
    println!(
        "\ngreedy mixed-m policy: {n_on}/{} layers at m={m_hi}, rest exact",
        pol.ms.len()
    );
    println!("  ms = {:?}", pol.ms);
    println!(
        "  accuracy {:.3} (exact {:.3}, loss {:+.2}%)",
        pol.acc,
        pol.exact_acc,
        100.0 * (pol.exact_acc - pol.acc)
    );
    println!(
        "  MAC-weighted power {:.3}x vs uniform-m {:.3}x (uniform loss would be higher)",
        pol.power_norm,
        array_cost(family, m_hi, 64).power_norm
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::artifacts_dir;

    #[test]
    fn layerwise_single_layer_softer_than_uniform() {
        let art = artifacts_dir();
        if !art.join("models").is_dir() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let model = loader::load_model(&art.join("models/mininet_synth10.cvm")).unwrap();
        let n_layers = model.mac_layers();
        let ds = Dataset::load(&art.join("data/synth10_test.cvd")).unwrap();
        let engine = Engine::new(model);
        let n = 80;
        let uniform = evaluate(
            &engine,
            &ds,
            &ForwardOpts::approx(Family::Perforated, 3, false),
            n,
            1,
        )
        .unwrap();
        // only the first layer approximate: must be at least as accurate
        let mut ms = vec![0u32; n_layers];
        ms[0] = 3;
        let mut single = ForwardOpts::layerwise(Family::Perforated, ms, false);
        single.use_cv = false;
        let single_acc = evaluate(&engine, &ds, &single, n, 1).unwrap();
        assert!(
            single_acc >= uniform,
            "single-layer {single_acc} < uniform {uniform}"
        );
    }

    #[test]
    fn m_zero_layers_run_exact() {
        let art = artifacts_dir();
        if !art.join("models").is_dir() {
            return;
        }
        let model = loader::load_model(&art.join("models/mininet_synth10.cvm")).unwrap();
        let n_layers = model.mac_layers();
        let ds = Dataset::load(&art.join("data/synth10_test.cvd")).unwrap();
        let engine = Engine::new(model);
        let all_zero = ForwardOpts::layerwise(Family::Perforated, vec![0; n_layers], true);
        let img = ds.image(0);
        let a = engine.forward(&img, &all_zero).unwrap();
        let b = engine.forward(&img, &ForwardOpts::exact()).unwrap();
        assert_eq!(a, b);
    }
}
