//! Layer-wise approximation extension (DESIGN.md §12).
//!
//! The paper's related work (ALWANN [9], reconfigurable multipliers [10])
//! assigns *different* approximation levels per layer, but needs either a
//! heterogeneous accelerator per DNN or reconfigurable circuits. In this
//! design `m` is already a runtime input of every engine and of the AOT
//! XLA artifacts, so mixed-m operation costs nothing: the coordinator just
//! streams a different m with each layer's tile batch.
//!
//! This module implements the offline search: per-layer sensitivity
//! analysis (approximate one layer at a time at the family's most
//! aggressive m) and a greedy policy that raises m layer-by-layer, most
//! error-tolerant layer first, while measured accuracy stays within the
//! loss budget. The result frequently beats every uniform-m point: it
//! reaches power savings between the uniform grid points at lower loss.

use std::path::Path;
use std::sync::Arc;

use anyhow::Result;

use super::accuracy::evaluate;
use crate::approx::stats::pairing_residual;
use crate::approx::{Family, Polarity};
use crate::datasets::Dataset;
use crate::hw::array_cost;
use crate::nn::{
    loader, Engine, ForwardOpts, LayerAssignment, LayerPoint, LayerPolicy, PairedPoint,
};

/// Sensitivity of each MAC layer: accuracy when ONLY that layer runs
/// approximate (at `m`, with V), everything else exact.
pub struct LayerSensitivity {
    pub layer: usize,
    pub macs: u64,
    pub acc: f64,
}

pub fn sensitivity(
    engine: &Engine,
    ds: &Dataset,
    family: Family,
    m: u32,
    n_images: usize,
) -> Result<Vec<LayerSensitivity>> {
    let n_layers = engine.model.mac_layers();
    let per_layer_macs = engine.model.mac_layer_macs();
    let mut out = Vec::new();
    for layer in 0..n_layers {
        let mut ms = vec![0u32; n_layers];
        ms[layer] = m;
        let opts = ForwardOpts::layerwise(family, ms, true);
        let acc = evaluate(engine, ds, &opts, n_images, 1)?;
        out.push(LayerSensitivity { layer, macs: per_layer_macs[layer], acc });
    }
    Ok(out)
}

/// Result of the greedy mixed-m search.
pub struct Policy {
    pub family: Family,
    pub ms: Vec<u32>,
    pub acc: f64,
    pub exact_acc: f64,
    /// MAC-weighted normalized power of the mixed design.
    pub power_norm: f64,
}

impl Policy {
    /// The runtime artifact: a [`LayerPolicy`] the engine / coordinator /
    /// benches execute directly (`ms[i] == 0` layers run exact; the greedy
    /// search always evaluates with V, so `use_cv = true`).
    pub fn layer_policy(&self) -> Result<LayerPolicy> {
        LayerPolicy::from_ms(self.family, &self.ms, true)
    }
}

/// Greedily raise each layer to `m_hi` (most tolerant first, by the
/// sensitivity pass), keeping measured accuracy within `budget_pct` of
/// exact. Layers that do not fit stay exact (m = 0).
#[allow(clippy::too_many_arguments)]
pub fn greedy_policy(
    engine: &Engine,
    ds: &Dataset,
    family: Family,
    m_hi: u32,
    budget_pct: f64,
    n_images: usize,
    n_array: u32,
    sens: &[LayerSensitivity],
) -> Result<Policy> {
    let exact_acc = evaluate(engine, ds, &ForwardOpts::exact(), n_images, 1)?;
    let floor = exact_acc - budget_pct / 100.0;
    let mut order: Vec<usize> = (0..sens.len()).collect();
    order.sort_by(|&a, &b| sens[b].acc.partial_cmp(&sens[a].acc).unwrap());
    let mut ms = vec![0u32; sens.len()];
    let mut acc = exact_acc;
    for &layer in &order {
        ms[layer] = m_hi;
        let trial = evaluate(
            engine,
            ds,
            &ForwardOpts::layerwise(family, ms.clone(), true),
            n_images,
            1,
        )?;
        if trial >= floor {
            acc = trial;
        } else {
            ms[layer] = 0; // revert
        }
    }
    // MAC-weighted power via the shared policy estimator (approximate
    // layers at array_cost(m_hi), exact layers at 1).
    let power_norm =
        LayerPolicy::from_ms(family, &ms, true)?.power_norm(&engine.model, n_array);
    Ok(Policy { family, ms, acc, exact_acc, power_norm })
}

/// Result of the paired greedy search.
pub struct PairedPolicyResult {
    pub policy: LayerPolicy,
    pub acc: f64,
    pub exact_acc: f64,
    /// Accuracy of the mixed `base` policy the search upgraded from.
    pub base_acc: f64,
    pub power_norm: f64,
}

/// Upgrade a mixed policy into the **paired** space: starting from `base`
/// (the mixed greedy result), walk the layers most-error-tolerant first and
/// try to replace each with a mirrored Neg/Pos pairing of `family`,
/// descending the m ladder from `m_hi` (most aggressive — biggest power win
/// — first). A candidate is kept only when (a) its array cost does not
/// exceed what the layer runs today (an exact layer accepts any m; an
/// approximate layer only the power-neutral `m_hi` mirror), and (b)
/// measured accuracy stays at or above the base policy's. Both guards
/// together make the result **dominate or match `base` on the
/// (power, loss) plane by construction** — and strictly dominate as soon
/// as one exact layer upgrades, which is what cancellation buys: pairs
/// tolerate approximation in layers whose uniform points did not.
#[allow(clippy::too_many_arguments)]
pub fn greedy_paired_policy(
    engine: &Engine,
    ds: &Dataset,
    family: Family,
    m_hi: u32,
    n_images: usize,
    n_array: u32,
    sens: &[LayerSensitivity],
    base: &LayerPolicy,
    exact_acc: f64,
) -> Result<PairedPolicyResult> {
    // The floor is re-measured (not trusted from the caller) so every
    // accept/revert decision compares numbers from the same evaluate path;
    // exact_acc is reporting-only and the caller already holds it.
    let base_acc = evaluate(
        engine,
        ds,
        &ForwardOpts::with_policy(Arc::new(base.clone())),
        n_images,
        1,
    )?;
    let mut assignments: Vec<LayerAssignment> = base.assignments().collect();
    let mut acc = base_acc;
    let mut order: Vec<usize> = (0..sens.len()).collect();
    order.sort_by(|&a, &b| sens[b].acc.partial_cmp(&sens[a].acc).unwrap());
    for &layer in &order {
        let prev = assignments[layer];
        // Never raise a layer's power: pairing at m must cost no more than
        // what the layer runs today. An exact layer may take any rung; an
        // already-approximate layer only the power-neutral m_hi mirror
        // (same rule as the python mirror in scripts/gen_hermetic_golden.py,
        // so the two searches stay comparable on any dataset).
        let (cur_cost, was_exact) = match prev {
            LayerAssignment::Point(p) if p == LayerPoint::EXACT => (1.0, true),
            LayerAssignment::Point(p) => {
                (array_cost(p.family, p.m, n_array).power_norm, false)
            }
            LayerAssignment::Paired(_) => continue,
        };
        for m in (1..=m_hi).rev() {
            if !was_exact && m != m_hi {
                continue;
            }
            if array_cost(family, m, n_array).power_norm > cur_cost + 1e-12 {
                continue;
            }
            assignments[layer] =
                LayerAssignment::Paired(PairedPoint::mirrored(family, m, true));
            let trial_policy = LayerPolicy::from_assignments(assignments.clone())?;
            let trial = evaluate(
                engine,
                ds,
                &ForwardOpts::with_policy(Arc::new(trial_policy)),
                n_images,
                1,
            )?;
            if trial >= base_acc {
                acc = trial;
                break;
            }
            assignments[layer] = prev; // revert, try the next rung
        }
    }
    let policy = LayerPolicy::from_assignments(assignments)?;
    let power_norm = policy.power_norm(&engine.model, n_array);
    Ok(PairedPolicyResult { policy, acc, exact_acc, base_acc, power_norm })
}

/// Build the adaptive-QoS [`crate::qos::Ladder`] for one engine/dataset:
/// exact → greedy mixed → greedy paired → aggressive uniform, each rung
/// tagged with its measured (synthetic) accuracy loss and MAC-weighted
/// normalized power. Candidate rungs that fail to *descend* the power axis
/// are dropped rather than reported twice — e.g. a paired search that found
/// no upgrade ties the mixed rung, and a greedy search that kept every
/// layer exact ties the exact rung — so the result is always a valid
/// ladder whatever the searches returned.
pub fn qos_ladder(
    engine: &Engine,
    ds: &Dataset,
    family: Family,
    m_hi: u32,
    budget_pct: f64,
    n_images: usize,
    n_array: u32,
) -> Result<crate::qos::Ladder> {
    use crate::qos::{Ladder, Rung};
    let n_layers = engine.model.mac_layers();
    let sens = sensitivity(engine, ds, family, m_hi, n_images)?;
    let pol = greedy_policy(engine, ds, family, m_hi, budget_pct, n_images, n_array, &sens)?;
    let mixed = pol.layer_policy()?;
    let pres = greedy_paired_policy(
        engine, ds, family, m_hi, n_images, n_array, &sens, &mixed, pol.exact_acc,
    )?;
    let uniform = LayerPolicy::uniform(family, m_hi, true, n_layers)?;
    let uni_acc = evaluate(engine, ds, &ForwardOpts::approx(family, m_hi, true), n_images, 1)?;
    let exact_policy = LayerPolicy::uniform(Family::Exact, 0, false, n_layers)?;
    let uniform_power = uniform.power_norm(&engine.model, n_array);
    let candidates = vec![
        Rung {
            name: "exact".into(),
            est_loss: 0.0,
            power_norm: 1.0,
            policy: Arc::new(exact_policy),
        },
        Rung {
            name: "greedy-mixed".into(),
            est_loss: (pol.exact_acc - pol.acc).max(0.0),
            power_norm: pol.power_norm,
            policy: Arc::new(mixed),
        },
        Rung {
            name: "greedy-paired".into(),
            est_loss: (pres.exact_acc - pres.acc).max(0.0),
            power_norm: pres.power_norm,
            policy: Arc::new(pres.policy),
        },
        Rung {
            name: "aggressive-uniform".into(),
            est_loss: (pol.exact_acc - uni_acc).max(0.0),
            power_norm: uniform_power,
            policy: Arc::new(uniform),
        },
    ];
    let mut rungs: Vec<Rung> = Vec::new();
    for r in candidates {
        let descends = match rungs.last() {
            None => true,
            Some(prev) => r.power_norm < prev.power_norm - 1e-12,
        };
        if descends {
            rungs.push(r);
        }
    }
    Ladder::new(rungs)
}

/// [`qos_ladder`] extended with a searched Pareto front: the co-design
/// search's nondominated candidates (`cvapprox search`) become `search-{i}`
/// rungs wherever no greedy rung already matches them on both axes.
///
/// Every searched rung is validated against the model first (a front from
/// the wrong network is a contextual error, not a panic), then dropped if
/// any rung kept so far — greedy or searched — weakly dominates it
/// (equal-or-lower power AND equal-or-lower est_loss), which also collapses
/// exact ties. The merge goes through the order-independent
/// [`crate::qos::Ladder::sorted`] constructor, so rung order in the
/// artifact never matters and an unladderable merge surfaces as a typed
/// [`crate::qos::LadderError`].
#[allow(clippy::too_many_arguments)]
pub fn qos_ladder_with_search(
    engine: &Engine,
    ds: &Dataset,
    family: Family,
    m_hi: u32,
    budget_pct: f64,
    n_images: usize,
    n_array: u32,
    front: &[crate::search::FrontMember],
) -> Result<crate::qos::Ladder> {
    use anyhow::Context;
    use crate::qos::Rung;
    let base = qos_ladder(engine, ds, family, m_hi, budget_pct, n_images, n_array)?;
    let searched = crate::search::to_rungs(front)?;
    let mut rungs: Vec<Rung> = base.rungs().to_vec();
    for r in searched {
        r.policy
            .validate_for(&engine.model)
            .with_context(|| format!("searched rung {:?} does not fit this model", r.name))?;
        let dominated = rungs.iter().any(|b| {
            b.power_norm <= r.power_norm + 1e-12 && b.est_loss <= r.est_loss + 1e-12
        });
        if !dominated {
            rungs.push(r);
        }
    }
    crate::qos::Ladder::sorted(rungs).map_err(anyhow::Error::from)
}

/// CLI driver: sensitivity table + greedy policy for one (net, family).
/// When `paired` is set, the mixed result seeds the paired greedy search
/// and the paired policy becomes the artifact. When `policy_out` is set,
/// the resulting [`LayerPolicy`] is written there as JSON — the artifact
/// `ServiceConfig::policy` / `CVAPPROX_SERVICE_POLICY`,
/// `examples/design_space` and the policy benches consume (paired layers
/// serialize in the same document).
#[allow(clippy::too_many_arguments)]
pub fn run(
    artifacts: &Path,
    net: &str,
    dataset: &str,
    family: Family,
    m_hi: u32,
    budget_pct: f64,
    n_images: usize,
    paired: bool,
    policy_out: Option<&Path>,
) -> Result<()> {
    let model =
        loader::load_model(&artifacts.join(format!("models/{net}_{dataset}.cvm")))?;
    let ds = Dataset::load(&artifacts.join(format!("data/{dataset}_test.cvd")))?;
    let mut engine = Engine::new(model);
    if family == Family::Truncated {
        engine.prepare_lut(family, m_hi);
    }
    println!(
        "Layer-wise approximation: {net}/{dataset}, {} m={m_hi}, budget {budget_pct}% \
         ({n_images} images)\n",
        family.name()
    );
    let sens = sensitivity(&engine, &ds, family, m_hi, n_images)?;
    println!("per-layer sensitivity (only that layer approximate, with V):");
    for s in &sens {
        println!(
            "  layer {:>2} ({:>9} MACs): acc {:.3}",
            s.layer, s.macs, s.acc
        );
    }
    let pol = greedy_policy(&engine, &ds, family, m_hi, budget_pct, n_images, 64, &sens)?;
    let n_on = pol.ms.iter().filter(|&&m| m != 0).count();
    println!(
        "\ngreedy mixed-m policy: {n_on}/{} layers at m={m_hi}, rest exact",
        pol.ms.len()
    );
    println!("  ms = {:?}", pol.ms);
    println!(
        "  accuracy {:.3} (exact {:.3}, loss {:+.2}%)",
        pol.acc,
        pol.exact_acc,
        100.0 * (pol.exact_acc - pol.acc)
    );
    println!(
        "  MAC-weighted power {:.3}x vs uniform-m {:.3}x (uniform loss would be higher)",
        pol.power_norm,
        array_cost(family, m_hi, 64).power_norm
    );
    let artifact_policy = if paired {
        // Prepare both polarity LUTs so truncated pairings also serve from
        // tables during the search.
        engine.prepare_lut_pol(family, m_hi, Polarity::Pos);
        let resid = pairing_residual(
            (family, m_hi, Polarity::Neg),
            (family, m_hi, Polarity::Pos),
        );
        println!(
            "\npaired search: mirrored {}/m={m_hi} pairing, predicted per-MAC \
             residual bias {resid:+.3} (vs {:+.1} uniform)",
            family.name(),
            crate::approx::stats::signed_moments(family, m_hi, Polarity::Neg).mean
        );
        let base = pol.layer_policy()?;
        let pres = greedy_paired_policy(
            &engine, &ds, family, m_hi, n_images, 64, &sens, &base, pol.exact_acc,
        )?;
        println!(
            "greedy paired policy: {} ({} paired layers)",
            pres.policy.describe(),
            pres.policy.paired_layers()
        );
        println!(
            "  accuracy {:.3} (mixed {:.3}, exact {:.3})",
            pres.acc, pres.base_acc, pres.exact_acc
        );
        println!(
            "  MAC-weighted power {:.3}x (mixed {:.3}x) — dominates or matches \
             the mixed policy by construction",
            pres.power_norm, pol.power_norm
        );
        pres.policy
    } else {
        pol.layer_policy()?
    };
    if let Some(out) = policy_out {
        artifact_policy.save_json(out)?;
        println!(
            "  wrote policy {} -> {}",
            artifact_policy.describe(),
            out.display()
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::{artifacts_dir, hermetic_dir};

    fn hermetic_engine_and_ds() -> (Engine, Dataset) {
        let root = hermetic_dir();
        let model =
            loader::load_model(&root.join("models/hermnet_hsynth.cvm")).unwrap();
        let ds = Dataset::load(&root.join("data/hsynth_test.cvd")).unwrap();
        (Engine::new(model), ds)
    }

    #[test]
    fn hermetic_greedy_policy_dominates_uniform_grid() {
        // The PR's acceptance anchor, fully deterministic (checked-in data,
        // integer arithmetic): labels are the exact argmax, every uniform
        // paper point loses accuracy, and the greedy search finds a mixed
        // policy with ZERO loss at sub-exact power — so the mixed policy
        // beats every uniform point at equal-or-lower accuracy loss.
        let (engine, ds) = hermetic_engine_and_ds();
        let n = ds.n;
        let exact = evaluate(&engine, &ds, &ForwardOpts::exact(), n, 1).unwrap();
        assert_eq!(exact, 1.0, "hermetic labels are the exact argmax");
        for family in Family::APPROX {
            for &m in family.paper_levels() {
                let acc = evaluate(
                    &engine,
                    &ds,
                    &ForwardOpts::approx(family, m, true),
                    n,
                    1,
                )
                .unwrap();
                assert!(
                    acc < exact,
                    "uniform {} m={m} must be lossy on the hermetic set, got {acc}",
                    family.name()
                );
            }
        }
        let sens = sensitivity(&engine, &ds, Family::Perforated, 3, n).unwrap();
        let pol =
            greedy_policy(&engine, &ds, Family::Perforated, 3, 0.8, n, 64, &sens)
                .unwrap();
        let lp = pol.layer_policy().unwrap();
        assert!(
            lp.approx_layers() > 0 && lp.approx_layers() < lp.len(),
            "greedy must yield a genuinely mixed policy, got {}",
            lp.describe()
        );
        assert_eq!(
            pol.acc, exact,
            "a 0.8% budget is below one accuracy quantum (1/64), so the \
             greedy policy must keep zero loss"
        );
        assert!(pol.power_norm < 1.0, "mixed power {}", pol.power_norm);
    }

    #[test]
    fn hermetic_paired_greedy_strictly_dominates_mixed() {
        // The pairing acceptance anchor, fully deterministic: the paired
        // ladder search, seeded from the mixed greedy result, must (a)
        // never be worse than the mixed policy on either axis — guaranteed
        // by construction — and (b) on the hermetic set, actually land an
        // upgrade: cancellation lets the previously exact conv1x1 layer
        // run a mirrored perforated m=1 pairing at zero loss (pinned
        // against the python mirror in scripts/gen_hermetic_golden.py),
        // i.e. strict dominance.
        let (engine, ds) = hermetic_engine_and_ds();
        let n = ds.n;
        let sens = sensitivity(&engine, &ds, Family::Perforated, 3, n).unwrap();
        let pol = greedy_policy(&engine, &ds, Family::Perforated, 3, 0.8, n, 64, &sens)
            .unwrap();
        let base = pol.layer_policy().unwrap();
        let base_power = base.power_norm(&engine.model, 64);
        let pres = greedy_paired_policy(
            &engine, &ds, Family::Perforated, 3, n, 64, &sens, &base, pol.exact_acc,
        )
        .unwrap();
        assert!(pres.acc >= pres.base_acc, "{} < {}", pres.acc, pres.base_acc);
        assert!(pres.power_norm <= base_power + 1e-12);
        assert_eq!(pres.policy.paired_layers(), 1, "{}", pres.policy.describe());
        assert_eq!(pres.acc, 1.0, "paired upgrade keeps zero loss");
        assert!(
            pres.power_norm < base_power,
            "strict dominance: {} !< {base_power}",
            pres.power_norm
        );
        // The artifact roundtrips with its paired layers intact.
        let back = LayerPolicy::parse(&pres.policy.to_json().render()).unwrap();
        assert_eq!(back.describe(), pres.policy.describe());
        assert_eq!(back.paired_layers(), 1);
    }

    #[test]
    fn hermetic_mirrored_pairing_accuracy_pinned() {
        // Cross-implementation anchor: the all-layers mirrored perforated
        // m=1 pairing scores exactly 60/64 on the hermetic set (python
        // mirror prints 0.9375).
        use crate::nn::LayerPolicy;
        let (engine, ds) = hermetic_engine_and_ds();
        let policy = std::sync::Arc::new(
            LayerPolicy::paired_uniform(Family::Perforated, 1, true, 4).unwrap(),
        );
        let acc = evaluate(
            &engine,
            &ds,
            &ForwardOpts::with_policy(policy),
            ds.n,
            1,
        )
        .unwrap();
        assert_eq!(acc, 60.0 / 64.0, "paired perforated m=1 mirror");
    }

    #[test]
    fn hermetic_qos_ladder_descends_power_at_bounded_loss() {
        // The QoS-ladder artifact on the hermetic set: four rungs (the
        // paired search strictly dominates the mixed policy there, so
        // nothing collapses), power strictly descending, the accurate end
        // lossless and the aggressive end genuinely lossy — exactly the
        // trade-off surface the governor walks.
        let (engine, ds) = hermetic_engine_and_ds();
        let ladder = qos_ladder(&engine, &ds, Family::Perforated, 3, 0.8, ds.n, 64).unwrap();
        assert_eq!(ladder.len(), 4, "{}", ladder.describe());
        assert_eq!(ladder.rung(0).name, "exact");
        assert_eq!(ladder.rung(1).name, "greedy-mixed");
        assert_eq!(ladder.rung(2).name, "greedy-paired");
        assert_eq!(ladder.rung(3).name, "aggressive-uniform");
        for w in ladder.rungs().windows(2) {
            assert!(
                w[1].power_norm < w[0].power_norm,
                "{} !< {}",
                w[1].power_norm,
                w[0].power_norm
            );
        }
        assert_eq!(ladder.rung(0).est_loss, 0.0);
        assert_eq!(ladder.rung(1).est_loss, 0.0, "greedy keeps zero loss here");
        assert_eq!(ladder.rung(2).est_loss, 0.0, "paired keeps zero loss here");
        assert!(ladder.rung(3).est_loss > 0.0, "uniform m=3 must be lossy");
        // The artifact roundtrips and validates against the model.
        let back = crate::qos::Ladder::parse(&ladder.to_json().render()).unwrap();
        assert_eq!(back.describe(), ladder.describe());
        back.validate_for(&engine.model).unwrap();
    }

    #[test]
    fn hermetic_search_merge_filters_dominated_and_stays_monotone() {
        use crate::search::{self, Evaluator, FrontMember, Gene, Genome, Shape};
        let (engine, ds) = hermetic_engine_and_ds();
        let n_layers = engine.model.mac_layers();
        // A front whose first member ties the base exact rung (weakly
        // dominated → dropped) and whose second is the pinned all-layers
        // mirrored perforated m=1 pairing — a point the greedy ladder never
        // emits, cheaper than exact at a small measured loss, that must
        // merge in whenever no greedy rung matches it on both axes.
        let ev = Evaluator::new(&engine, &ds, ds.n, 64).unwrap();
        let exact = Genome::exact(n_layers);
        let paired = Genome::uniform(
            Gene::approx(Shape::Rows, 1, crate::approx::Polarity::Neg, true, true),
            n_layers,
        );
        let member = |g: &Genome| {
            let o = ev.evaluate_genome(g).unwrap();
            FrontMember {
                genome: g.clone(),
                est_loss: o.est_loss,
                power_norm: o.power_norm,
                hash: g.hash(),
            }
        };
        let front = vec![member(&exact), member(&paired)];
        assert_eq!(front[1].est_loss, 4.0 / 64.0, "pinned paired-m1 loss");
        let merged = qos_ladder_with_search(
            &engine, &ds, Family::Perforated, 3, 0.8, ds.n, 64, &front,
        )
        .unwrap();
        let base =
            qos_ladder(&engine, &ds, Family::Perforated, 3, 0.8, ds.n, 64).unwrap();
        let names: Vec<&str> =
            merged.rungs().iter().map(|r| r.name.as_str()).collect();
        // the exact-tie searched rung is gone; every base rung survives
        assert!(!names.contains(&"search-0"), "{names:?}");
        for b in base.rungs() {
            assert!(names.contains(&b.name.as_str()), "{names:?}");
        }
        // whether the paired-m1 rung merges depends on base dominance; on
        // the hermetic set nothing on the base ladder weakly dominates it
        // unless a base rung reaches its power at no more loss.
        let kept = names.contains(&"search-1");
        let dominated = base.rungs().iter().any(|b| {
            b.power_norm <= front[1].power_norm + 1e-12
                && b.est_loss <= front[1].est_loss + 1e-12
        });
        assert_eq!(kept, !dominated, "{names:?}");
        // the merged ladder still descends the power axis
        for w in merged.rungs().windows(2) {
            assert!(w[1].power_norm <= w[0].power_norm + 1e-9);
        }
        // a front for the wrong model is a contextual error, not a panic
        let wrong = Genome::exact(n_layers + 1);
        let bad = vec![FrontMember {
            genome: wrong.clone(),
            est_loss: 0.0,
            power_norm: 1.0,
            hash: wrong.hash(),
        }];
        let err = qos_ladder_with_search(
            &engine, &ds, Family::Perforated, 3, 0.8, ds.n, 64, &bad,
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("does not fit"), "{err:#}");
        // unrelated: search::parse_front round-trips what to_rungs consumes
        let _ = search::to_rungs(&front).unwrap();
    }

    #[test]
    fn hermetic_single_layer_softer_than_uniform() {
        // Only the most tolerant layer approximate must be at least as
        // accurate as the uniform point at the same (family, m, V).
        let (engine, ds) = hermetic_engine_and_ds();
        let n = ds.n;
        let n_layers = engine.model.mac_layers();
        let uniform = evaluate(
            &engine,
            &ds,
            &ForwardOpts::approx(Family::Perforated, 3, true),
            n,
            1,
        )
        .unwrap();
        let mut ms = vec![0u32; n_layers];
        ms[0] = 3;
        let single = evaluate(
            &engine,
            &ds,
            &ForwardOpts::layerwise(Family::Perforated, ms, true),
            n,
            1,
        )
        .unwrap();
        assert!(single >= uniform, "single {single} < uniform {uniform}");
    }

    #[test]
    fn hermetic_all_zero_policy_runs_exact() {
        let (engine, ds) = hermetic_engine_and_ds();
        let n_layers = engine.model.mac_layers();
        let img = ds.image(0);
        let all_zero =
            ForwardOpts::layerwise(Family::Perforated, vec![0; n_layers], true);
        let a = engine.forward(&img, &all_zero).unwrap();
        let b = engine.forward(&img, &ForwardOpts::exact()).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn layerwise_single_layer_softer_than_uniform() {
        let art = artifacts_dir();
        if !art.join("models").is_dir() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let model = loader::load_model(&art.join("models/mininet_synth10.cvm")).unwrap();
        let n_layers = model.mac_layers();
        let ds = Dataset::load(&art.join("data/synth10_test.cvd")).unwrap();
        let engine = Engine::new(model);
        let n = 80;
        let uniform = evaluate(
            &engine,
            &ds,
            &ForwardOpts::approx(Family::Perforated, 3, false),
            n,
            1,
        )
        .unwrap();
        // only the first layer approximate: must be at least as accurate
        let mut ms = vec![0u32; n_layers];
        ms[0] = 3;
        let mut single = ForwardOpts::layerwise(Family::Perforated, ms, false);
        single.use_cv = false;
        let single_acc = evaluate(&engine, &ds, &single, n, 1).unwrap();
        assert!(
            single_acc >= uniform,
            "single-layer {single_acc} < uniform {uniform}"
        );
    }

    #[test]
    fn m_zero_layers_run_exact() {
        let art = artifacts_dir();
        if !art.join("models").is_dir() {
            return;
        }
        let model = loader::load_model(&art.join("models/mininet_synth10.cvm")).unwrap();
        let n_layers = model.mac_layers();
        let ds = Dataset::load(&art.join("data/synth10_test.cvd")).unwrap();
        let engine = Engine::new(model);
        let all_zero = ForwardOpts::layerwise(Family::Perforated, vec![0; n_layers], true);
        let img = ds.image(0);
        let a = engine.forward(&img, &all_zero).unwrap();
        let b = engine.forward(&img, &ForwardOpts::exact()).unwrap();
        assert_eq!(a, b);
    }
}
