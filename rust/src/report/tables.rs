//! Paper-style text renderers for Table 1, Figs 7-9, Table 5 and the
//! accuracy tables, plus JSON dumps for external plotting.

use crate::approx::stats::{Dist, ErrorRow};
use crate::approx::Family;
use crate::hw::array::{array_cost, ArrayCost, PAPER_NS};
use crate::util::json::Json;

use super::accuracy::{AccuracyCell, ParetoPoint};

/// Table 1: error μ/σ per multiplier/distribution.
pub fn render_table1(rows: &[ErrorRow]) -> String {
    let mut out = String::new();
    out.push_str("TABLE 1 — Error analysis of the approximate multipliers\n");
    for family in Family::APPROX {
        out.push_str(&format!("\n  {} multiplier\n", family.name()));
        out.push_str("    m   U(0,255)  mu      sigma   |  N(125,24^2) mu    sigma\n");
        for &m in family.table1_levels() {
            let u = rows
                .iter()
                .find(|r| r.family == family && r.m == m && r.dist == Dist::Uniform)
                .unwrap();
            let n = rows
                .iter()
                .find(|r| r.family == family && r.m == m && r.dist == Dist::Normal)
                .unwrap();
            out.push_str(&format!(
                "    {:<3} {:>12.2} {:>9.2}  | {:>12.2} {:>9.2}\n",
                m, u.mean, u.std, n.mean, n.std
            ));
        }
    }
    out
}

pub fn table1_json(rows: &[ErrorRow]) -> Json {
    Json::arr(rows.iter().map(|r| {
        Json::obj()
            .field("family", r.family.name())
            .field("m", r.m as i64)
            .field("dist", r.dist.name())
            .field("mu", r.mean)
            .field("sigma", r.std)
    }))
}

/// Figs 7-9: normalized power/area for one family across m × N.
pub fn render_hw_figure(family: Family) -> String {
    let fig = match family {
        Family::Perforated => "FIG 7",
        Family::Truncated => "FIG 8",
        Family::Recursive => "FIG 9",
        Family::Exact => "FIG -",
    };
    let mut out = format!(
        "{fig} — Normalized power/area, {} multipliers (1.0 = exact design)\n",
        family.name()
    );
    out.push_str("    m    N    power   (reduction)    area   (reduction)\n");
    for &m in family.paper_levels() {
        for &n in &PAPER_NS {
            let c = array_cost(family, m, n);
            out.push_str(&format!(
                "    {:<4} {:<4} {:.3}  ({:>5.1}%)       {:.3}  ({:>5.1}%)\n",
                m,
                n,
                c.power_norm,
                100.0 * (1.0 - c.power_norm),
                c.area_norm,
                100.0 * (1.0 - c.area_norm),
            ));
        }
    }
    out
}

pub fn hw_figure_json(family: Family) -> Json {
    let mut items = Vec::new();
    for &m in family.paper_levels() {
        for &n in &PAPER_NS {
            let c = array_cost(family, m, n);
            items.push(
                Json::obj()
                    .field("family", family.name())
                    .field("m", m as i64)
                    .field("n", n as i64)
                    .field("power_norm", c.power_norm)
                    .field("area_norm", c.area_norm),
            );
        }
    }
    Json::Arr(items)
}

/// Table 5: MAC+ overhead percentages.
pub fn render_table5() -> String {
    let mut out = String::new();
    out.push_str("TABLE 5 — MAC+ area/power overhead (% of approximate array total)\n");
    for family in Family::APPROX {
        out.push_str(&format!("\n  {} multiplier in MAC*\n", family.name()));
        out.push_str("    m    16x16   32x32   48x48   64x64   (area% | power%)\n");
        for &m in family.paper_levels() {
            let cells: Vec<ArrayCost> =
                PAPER_NS.iter().map(|&n| array_cost(family, m, n)).collect();
            let area: Vec<String> =
                cells.iter().map(|c| format!("{:.2}", c.mac_plus_area_pct)).collect();
            let power: Vec<String> =
                cells.iter().map(|c| format!("{:.2}", c.mac_plus_power_pct)).collect();
            out.push_str(&format!(
                "    {:<4} {}  |  {}\n",
                m,
                area.join("   "),
                power.join("   ")
            ));
        }
    }
    out
}

/// Tables 2-4 layout: one table per family, rows = nets, cols = m levels.
pub fn render_accuracy_table(family: Family, cells: &[AccuracyCell]) -> String {
    let table = match family {
        Family::Perforated => "TABLE 2",
        Family::Truncated => "TABLE 3",
        Family::Recursive => "TABLE 4",
        Family::Exact => "TABLE -",
    };
    let levels = family.paper_levels();
    let mut out = format!(
        "{table} — Accuracy loss (%) with the {} multiplier (Ours = with V)\n",
        family.name()
    );
    for ds in super::accuracy::DATASETS {
        out.push_str(&format!("\n  {} dataset\n", ds));
        out.push_str("    net            ");
        for m in levels {
            out.push_str(&format!("m={m}: Ours   w/o V   "));
        }
        out.push('\n');
        let mut net_order: Vec<&str> = Vec::new();
        for c in cells.iter().filter(|c| c.dataset == ds && c.family == family) {
            if !net_order.contains(&c.net.as_str()) {
                net_order.push(&c.net);
            }
        }
        for net in &net_order {
            out.push_str(&format!("    {:<14} ", net));
            for &m in levels {
                if let Some(c) = cells.iter().find(|c| {
                    c.net == *net && c.dataset == ds && c.m == m && c.family == family
                }) {
                    out.push_str(&format!(
                        "{:>+9.2} {:>+7.2}   ",
                        c.ours_loss(),
                        c.raw_loss()
                    ));
                } else {
                    out.push_str("        -       -   ");
                }
            }
            out.push('\n');
        }
        // averages
        let avg = |cv: bool| -> Option<Vec<f64>> {
            let mut v = Vec::new();
            for &m in levels {
                let xs: Vec<f64> = cells
                    .iter()
                    .filter(|c| c.dataset == ds && c.m == m && c.family == family)
                    .map(|c| if cv { c.ours_loss() } else { c.raw_loss() })
                    .collect();
                if xs.is_empty() {
                    return None;
                }
                v.push(xs.iter().sum::<f64>() / xs.len() as f64);
            }
            Some(v)
        };
        if let (Some(ours), Some(raw)) = (avg(true), avg(false)) {
            out.push_str("    Average        ");
            for i in 0..levels.len() {
                out.push_str(&format!("{:>+9.2} {:>+7.2}   ", ours[i], raw[i]));
            }
            out.push('\n');
        }
    }
    out
}

pub fn accuracy_json(cells: &[AccuracyCell]) -> Json {
    Json::arr(cells.iter().map(|c| {
        Json::obj()
            .field("net", c.net.as_str())
            .field("dataset", c.dataset.as_str())
            .field("family", c.family.name())
            .field("m", c.m as i64)
            .field("exact_acc", c.exact_acc)
            .field("ours_acc", c.ours_acc)
            .field("raw_acc", c.raw_acc)
            .field("ours_loss_pct", c.ours_loss())
            .field("raw_loss_pct", c.raw_loss())
    }))
}

/// Fig 10: Pareto space rendering (points ≤ max_loss, front marked).
pub fn render_pareto(
    net: &str,
    points: &[ParetoPoint],
    front: &[ParetoPoint],
    max_loss: f64,
) -> String {
    let mut out = format!(
        "FIG 10 — Accuracy loss vs normalized power, {net} (synth100, N=64)\n"
    );
    out.push_str("    family       m   V?   power    loss%   pareto\n");
    let mut sorted: Vec<&ParetoPoint> =
        points.iter().filter(|p| p.acc_loss_pct <= max_loss).collect();
    sorted.sort_by(|a, b| a.power_norm.partial_cmp(&b.power_norm).unwrap());
    for p in sorted {
        let on_front = front.iter().any(|f| {
            f.family == p.family && f.m == p.m && f.use_cv == p.use_cv
        });
        out.push_str(&format!(
            "    {:<12} {:<3} {:<4} {:.3}   {:>+7.2}  {}\n",
            p.family.name(),
            p.m,
            if p.use_cv { "yes" } else { "no" },
            p.power_norm,
            p.acc_loss_pct,
            if on_front { "*" } else { "" }
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_renders_all_families() {
        let rows = crate::approx::stats::table1(2_000, 42);
        let s = render_table1(&rows);
        for f in ["perforated", "recursive", "truncated"] {
            assert!(s.contains(f), "{s}");
        }
        let j = table1_json(&rows).render();
        assert!(j.contains("\"sigma\""));
    }

    #[test]
    fn hw_figures_render() {
        for f in Family::APPROX {
            let s = render_hw_figure(f);
            assert!(s.contains("power"));
            assert!(s.lines().count() > 10);
        }
        assert!(render_table5().contains("MAC+"));
    }

    #[test]
    fn accuracy_table_renders_with_averages() {
        let cells = vec![
            AccuracyCell {
                net: "mininet".into(),
                dataset: "synth10".into(),
                family: Family::Perforated,
                m: 1,
                exact_acc: 0.8,
                ours_acc: 0.79,
                raw_acc: 0.5,
            },
            AccuracyCell {
                net: "mininet".into(),
                dataset: "synth10".into(),
                family: Family::Perforated,
                m: 2,
                exact_acc: 0.8,
                ours_acc: 0.78,
                raw_acc: 0.4,
            },
        ];
        let s = render_accuracy_table(Family::Perforated, &cells);
        assert!(s.contains("TABLE 2"));
        assert!(s.contains("mininet"));
        // Not all m present -> no average row for incomplete sets is fine;
        // but m=1 and m=2 exist while m=3 is missing, so Average is absent.
        assert!(!s.contains("Average") || s.contains("+"));
    }
}
