//! Minimal JSON writer + parser (no serde offline). Reports and bench
//! outputs are emitted as JSON so they can be diffed / plotted outside the
//! binary; the parser exists for the small configuration artifacts the
//! binary reads back (per-layer approximation policies).

use std::fmt::Write as _;

use anyhow::{bail, Result};

/// A JSON value builder with ergonomic constructors.
#[derive(Clone, Debug)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Self {
        Json::Obj(Vec::new())
    }

    pub fn field(mut self, key: &str, val: impl Into<Json>) -> Self {
        if let Json::Obj(ref mut fields) = self {
            fields.push((key.to_string(), val.into()));
        }
        self
    }

    pub fn arr(items: impl IntoIterator<Item = impl Into<Json>>) -> Self {
        Json::Arr(items.into_iter().map(Into::into).collect())
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0);
        s
    }

    /// Parse a JSON document (strict enough for the artifacts this crate
    /// writes itself: no comments, no trailing commas).
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }

    /// Object field lookup (None for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push_str(", ");
                    }
                    item.write(out, indent);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push_str("{\n");
                let pad = "  ".repeat(indent + 1);
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    let _ = write!(out, "{pad}\"{k}\": ");
                    v.write(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push('}');
            }
        }
    }
}

/// Recursive-descent parser over the raw bytes (ASCII structure; string
/// contents pass through as UTF-8).
struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && self.b[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&mut self) -> Result<u8> {
        self.skip_ws();
        match self.b.get(self.pos) {
            Some(&c) => Ok(c),
            None => bail!("unexpected end of input"),
        }
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}", c as char, self.pos);
        }
        self.pos += 1;
        Ok(())
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut fields = Vec::new();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.eat(b':')?;
            fields.push((key, self.value()?));
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                c => bail!("expected ',' or '}}', got '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                c => bail!("expected ',' or ']', got '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let Some(&c) = self.b.get(self.pos) else { bail!("unterminated string") };
            self.pos += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let Some(&e) = self.b.get(self.pos) else {
                        bail!("unterminated escape")
                    };
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.pos..self.pos + 4)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .and_then(|h| u32::from_str_radix(h, 16).ok())
                                .and_then(char::from_u32);
                            match hex {
                                Some(ch) => {
                                    s.push(ch);
                                    self.pos += 4;
                                }
                                None => bail!("bad \\u escape at byte {}", self.pos),
                            }
                        }
                        _ => bail!("bad escape '\\{}'", e as char),
                    }
                }
                _ => {
                    // Re-assemble multi-byte UTF-8 sequences from raw bytes.
                    let start = self.pos - 1;
                    let len = match c {
                        0x00..=0x7F => 1,
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    match self
                        .b
                        .get(start..start + len)
                        .and_then(|x| std::str::from_utf8(x).ok())
                    {
                        Some(frag) => {
                            s.push_str(frag);
                            self.pos = start + len;
                        }
                        None => bail!("invalid utf8 in string at byte {start}"),
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        self.skip_ws();
        let start = self.pos;
        while self
            .b
            .get(self.pos)
            .map(|c| c.is_ascii_digit() || b"+-.eE".contains(c))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| anyhow::anyhow!("bad number at byte {start}"))
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Self {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Self {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested() {
        let j = Json::obj()
            .field("name", "table1")
            .field("n", 3i64)
            .field("rows", Json::arr([1.5f64, 2.0]))
            .field("ok", true);
        let s = j.render();
        assert!(s.contains("\"name\": \"table1\""));
        assert!(s.contains("\"rows\": [1.5, 2]"));
        assert!(s.contains("\"ok\": true"));
    }

    #[test]
    fn escapes_strings() {
        let s = Json::Str("a\"b\\c\nd".into()).render();
        assert_eq!(s, "\"a\\\"b\\\\c\\nd\"");
    }

    #[test]
    fn parse_roundtrips_writer_output() {
        let j = Json::obj()
            .field("name", "pölicy \"x\"\n")
            .field("n", 3i64)
            .field("pi", 3.25f64)
            .field("neg", -17i64)
            .field("rows", Json::arr([1.5f64, 2.0]))
            .field("ok", true)
            .field("nothing", Json::Null)
            .field("nested", Json::obj().field("deep", Json::arr(["a", "b"])));
        let parsed = Json::parse(&j.render()).unwrap();
        assert_eq!(parsed.get("name").unwrap().as_str().unwrap(), "pölicy \"x\"\n");
        assert_eq!(parsed.get("n").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(parsed.get("pi").unwrap().as_f64().unwrap(), 3.25);
        assert_eq!(parsed.get("neg").unwrap().as_f64().unwrap(), -17.0);
        assert_eq!(parsed.get("rows").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(parsed.get("ok").unwrap().as_bool(), Some(true));
        assert!(matches!(parsed.get("nothing"), Some(Json::Null)));
        let deep = parsed.get("nested").unwrap().get("deep").unwrap();
        assert_eq!(deep.as_arr().unwrap()[1].as_str(), Some("b"));
        // rendering the parse re-parses to the same shape
        assert!(Json::parse(&parsed.render()).is_ok());
    }

    #[test]
    fn parse_accepts_plain_scalars_and_empties() {
        assert_eq!(Json::parse("42").unwrap().as_f64(), Some(42.0));
        assert_eq!(Json::parse(" \"hi\" ").unwrap().as_str(), Some("hi"));
        assert!(Json::parse("[]").unwrap().as_arr().unwrap().is_empty());
        assert!(Json::parse("{}").unwrap().get("x").is_none());
        assert_eq!(
            Json::parse("[1, 2, 3]").unwrap().as_arr().unwrap().len(),
            3
        );
        assert_eq!(
            Json::parse("\"\\u0041\\t\"").unwrap().as_str(),
            Some("A\t")
        );
    }

    #[test]
    fn parse_rejects_malformed() {
        for bad in [
            "",
            "{",
            "[1,]",
            "{\"a\" 1}",
            "{\"a\": 1} x",
            "\"unterminated",
            "nul",
            "{1: 2}",
        ] {
            assert!(Json::parse(bad).is_err(), "{bad:?}");
        }
    }

    // ---- property / fuzz suite (the service-path hardening tier) --------

    use crate::approx::{Family, Polarity};
    use crate::nn::{LayerAssignment, LayerPoint, LayerPolicy, PairedPoint};
    use crate::util::rng::Rng;

    /// A random (possibly paired / positive-polarity) policy document —
    /// the artifact class the service parses from disk.
    fn random_policy(r: &mut Rng) -> LayerPolicy {
        let n_layers = 1 + r.below(6) as usize;
        let mut point = |r: &mut Rng| {
            let fam = Family::ALL[r.below(4) as usize];
            let m = if fam == Family::Exact { 0 } else { 1 + r.below(7) as u32 };
            let pol = if fam == Family::Exact {
                Polarity::Neg
            } else {
                Polarity::ALL[r.below(2) as usize]
            };
            LayerPoint::new_pol(fam, m, pol, r.below(2) == 1)
        };
        let assignments: Vec<LayerAssignment> = (0..n_layers)
            .map(|_| {
                if r.below(3) == 0 {
                    LayerAssignment::Paired(PairedPoint::new(point(r), point(r)))
                } else {
                    LayerAssignment::Point(point(r))
                }
            })
            .collect();
        LayerPolicy::from_assignments(assignments).unwrap()
    }

    #[test]
    fn property_policy_documents_roundtrip_to_a_fixpoint() {
        // emit -> parse -> emit must be a fixpoint (byte-identical second
        // render), and the parsed policy must equal the original.
        crate::util::prop::check_msg(
            "json policy roundtrip fixpoint",
            80,
            0x15A1,
            |r| random_policy(r),
            |policy| {
                let doc = policy.to_json().render();
                let parsed = Json::parse(&doc).map_err(|e| format!("parse: {e:#}"))?;
                if parsed.render() != doc {
                    return Err(format!("render not a fixpoint for {doc}"));
                }
                let back =
                    LayerPolicy::parse(&doc).map_err(|e| format!("policy: {e:#}"))?;
                if &back != policy {
                    return Err(format!("policy roundtrip mismatch for {doc}"));
                }
                Ok(())
            },
        );
    }

    /// Random nested JSON value (depth-bounded, no NaN/inf).
    fn random_json(r: &mut Rng, depth: u32) -> Json {
        match if depth == 0 { r.below(4) } else { r.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(r.below(2) == 1),
            2 => {
                if r.below(2) == 0 {
                    Json::Num(r.range_i64(-1_000_000, 1_000_000) as f64)
                } else {
                    Json::Num(r.range_i64(-4000, 4000) as f64 / 16.0)
                }
            }
            3 => {
                let len = r.below(8);
                Json::Str(
                    (0..len)
                        .map(|_| {
                            let c = r.below(96) as u8 + 32; // printable ascii
                            if c == b'\\' { '"' } else { c as char }
                        })
                        .collect(),
                )
            }
            4 => Json::Arr(
                (0..r.below(4)).map(|_| random_json(r, depth - 1)).collect(),
            ),
            _ => Json::Obj(
                (0..r.below(4))
                    .map(|i| (format!("k{i}"), random_json(r, depth - 1)))
                    .collect(),
            ),
        }
    }

    #[test]
    fn property_random_values_roundtrip_to_a_fixpoint() {
        crate::util::prop::check_msg(
            "json value roundtrip fixpoint",
            200,
            0x15A2,
            |r| random_json(r, 3).render(),
            |doc| {
                let parsed = Json::parse(doc).map_err(|e| format!("parse: {e:#}"))?;
                if &parsed.render() != doc {
                    return Err(format!("not a fixpoint: {doc}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn fuzz_mutated_policy_documents_never_panic() {
        // Byte-level mutations (substitute / delete / insert / swap) of
        // valid policy documents: the parser must return Ok or Err — never
        // panic — and so must the policy layer on top of it. ASCII
        // substitutions keep the buffer valid UTF-8, so every mutant
        // reaches the parser itself.
        let mut r = Rng::new(0xF022);
        for _case in 0..400u32 {
            let policy = random_policy(&mut r);
            let mut bytes = policy.to_json().render().into_bytes();
            for _ in 0..1 + r.below(8) {
                match r.below(4) {
                    0 => {
                        let i = r.below(bytes.len() as u64) as usize;
                        bytes[i] = r.below(95) as u8 + 32;
                    }
                    1 => {
                        let i = r.below(bytes.len() as u64) as usize;
                        bytes.remove(i);
                    }
                    2 => {
                        let i = r.below(bytes.len() as u64 + 1) as usize;
                        bytes.insert(i, r.below(95) as u8 + 32);
                    }
                    _ => {
                        let i = r.below(bytes.len() as u64) as usize;
                        let j = r.below(bytes.len() as u64) as usize;
                        bytes.swap(i, j);
                    }
                }
                if bytes.is_empty() {
                    bytes.push(b'{');
                }
            }
            let text = String::from_utf8(bytes).expect("ascii mutations stay utf8");
            // Must return (not panic); the result value is unconstrained.
            let _ = Json::parse(&text);
            let _ = LayerPolicy::parse(&text);
        }
    }

    #[test]
    fn fuzz_truncated_documents_are_errors_not_panics() {
        // Every proper prefix of a valid document must parse to Err (the
        // document is a single object, so no prefix is complete) without
        // panicking — the byte-starved service read path.
        let mut r = Rng::new(0xF023);
        let doc = random_policy(&mut r).to_json().render();
        for len in 0..doc.len() {
            let prefix = &doc[..len];
            assert!(
                Json::parse(prefix).is_err(),
                "prefix of len {len} unexpectedly parsed: {prefix:?}"
            );
            assert!(LayerPolicy::parse(prefix).is_err(), "len {len}");
        }
        // The full document still parses.
        assert!(Json::parse(&doc).is_ok());
        assert!(LayerPolicy::parse(&doc).is_ok());
    }
}
