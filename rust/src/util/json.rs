//! Minimal JSON writer (no serde offline). Reports and bench outputs are
//! emitted as JSON so they can be diffed / plotted outside the binary.

use std::fmt::Write as _;

/// A JSON value builder with ergonomic constructors.
#[derive(Clone, Debug)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Self {
        Json::Obj(Vec::new())
    }

    pub fn field(mut self, key: &str, val: impl Into<Json>) -> Self {
        if let Json::Obj(ref mut fields) = self {
            fields.push((key.to_string(), val.into()));
        }
        self
    }

    pub fn arr(items: impl IntoIterator<Item = impl Into<Json>>) -> Self {
        Json::Arr(items.into_iter().map(Into::into).collect())
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push_str(", ");
                    }
                    item.write(out, indent);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push_str("{\n");
                let pad = "  ".repeat(indent + 1);
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    let _ = write!(out, "{pad}\"{k}\": ");
                    v.write(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Self {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Self {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested() {
        let j = Json::obj()
            .field("name", "table1")
            .field("n", 3i64)
            .field("rows", Json::arr([1.5f64, 2.0]))
            .field("ok", true);
        let s = j.render();
        assert!(s.contains("\"name\": \"table1\""));
        assert!(s.contains("\"rows\": [1.5, 2]"));
        assert!(s.contains("\"ok\": true"));
    }

    #[test]
    fn escapes_strings() {
        let s = Json::Str("a\"b\\c\nd".into()).render();
        assert_eq!(s, "\"a\\\"b\\\\c\\nd\"");
    }
}
