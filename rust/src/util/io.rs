//! Little-endian binary reading for the .cvm / .cvd / .gv artifact formats
//! (spec: python/compile/export.py docstring — keep in lockstep).

use anyhow::{bail, Context, Result};

/// Cursor over a byte buffer with typed little-endian reads.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        ByteReader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("truncated buffer: need {n} bytes at offset {}", self.pos);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn magic(&mut self, expect: &[u8; 4]) -> Result<()> {
        let got = self.take(4)?;
        if got != expect {
            bail!("bad magic: expected {:?}, got {:?}", expect, got);
        }
        Ok(())
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn i32(&mut self) -> Result<i32> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn bytes(&mut self, n: usize) -> Result<Vec<u8>> {
        Ok(self.take(n)?.to_vec())
    }

    pub fn string(&mut self) -> Result<String> {
        let len = self.u16()? as usize;
        String::from_utf8(self.take(len)?.to_vec()).context("invalid utf8 string")
    }

    pub fn vec_u32(&mut self, n: usize) -> Result<Vec<u32>> {
        (0..n).map(|_| self.u32()).collect()
    }

    pub fn vec_i32(&mut self, n: usize) -> Result<Vec<i32>> {
        (0..n).map(|_| self.i32()).collect()
    }

    pub fn vec_u16(&mut self, n: usize) -> Result<Vec<u16>> {
        (0..n).map(|_| self.u16()).collect()
    }

    pub fn vec_f64(&mut self, n: usize) -> Result<Vec<f64>> {
        (0..n).map(|_| self.f64()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_primitives() {
        let mut buf = Vec::new();
        buf.extend_from_slice(b"CVD1");
        buf.extend_from_slice(&7u32.to_le_bytes());
        buf.extend_from_slice(&(-3i32).to_le_bytes());
        buf.extend_from_slice(&1.5f32.to_le_bytes());
        buf.extend_from_slice(&2u16.to_le_bytes());
        buf.extend_from_slice(b"hi");
        let mut r = ByteReader::new(&buf);
        r.magic(b"CVD1").unwrap();
        assert_eq!(r.u32().unwrap(), 7);
        assert_eq!(r.i32().unwrap(), -3);
        assert_eq!(r.f32().unwrap(), 1.5);
        assert_eq!(r.string().unwrap(), "hi");
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn truncation_errors() {
        let buf = [1u8, 2];
        let mut r = ByteReader::new(&buf);
        assert!(r.u32().is_err());
    }

    #[test]
    fn bad_magic_errors() {
        let mut r = ByteReader::new(b"XXXX");
        assert!(r.magic(b"CVM1").is_err());
    }
}
