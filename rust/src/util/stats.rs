//! Streaming statistics (Welford) + small helpers for the error analyses.

/// Online mean/variance accumulator (numerically stable Welford update).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance (the paper's σ² is over the full operand stream).
    pub fn variance(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.m2 / self.n as f64 }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Merge another accumulator (parallel reduction).
    pub fn merge(&mut self, o: &Welford) {
        if o.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = o.clone();
            return;
        }
        let n = self.n + o.n;
        let d = o.mean - self.mean;
        self.mean += d * o.n as f64 / n as f64;
        self.m2 += o.m2 + d * d * self.n as f64 * o.n as f64 / n as f64;
        self.n = n;
        self.min = self.min.min(o.min);
        self.max = self.max.max(o.max);
    }
}

/// Percentile of a *sorted* slice (linear interpolation).
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let idx = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = idx.floor() as usize;
    let hi = idx.ceil() as usize;
    let frac = idx - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() { 0.0 } else { xs.iter().sum::<f64>() / xs.len() as f64 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / 5.0;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / 5.0;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.variance() - var).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 10.0);
    }

    #[test]
    fn merge_equals_single_stream() {
        let mut a = Welford::new();
        let mut b = Welford::new();
        let mut all = Welford::new();
        for i in 0..100 {
            let x = (i * i % 37) as f64;
            if i % 2 == 0 { a.push(x) } else { b.push(x) }
            all.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.variance() - all.variance()).abs() < 1e-9);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (0..=100).map(|i| i as f64).collect();
        assert_eq!(percentile_sorted(&xs, 50.0), 50.0);
        assert_eq!(percentile_sorted(&xs, 0.0), 0.0);
        assert_eq!(percentile_sorted(&xs, 100.0), 100.0);
    }
}
