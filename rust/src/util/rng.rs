//! Deterministic PRNG (xoshiro256**) — no `rand` crate offline.
//!
//! Used by benches, property tests and workload generators. Seeded streams
//! are stable across runs so every experiment is reproducible.

/// xoshiro256** 1.0 by Blackman & Vigna (public domain reference algorithm).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create from a seed via splitmix64 expansion (seed 0 is fine).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, n) without modulo bias (Lemire's method).
    ///
    /// Contract: `below(0)` returns 0 — an empty range is "no choice", not
    /// UB. The guard is unconditional because release builds used to reach
    /// `0u64.wrapping_neg() % 0` (a divide-by-zero panic) on the rejection
    /// path; a `debug_assert!` alone would make the behaviour differ by
    /// profile.
    pub fn below(&mut self, n: u64) -> u64 {
        if n == 0 {
            return 0;
        }
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform u8 operand (the multipliers' input domain).
    pub fn u8(&mut self) -> u8 {
        self.below(256) as u8
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        mean + std * (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// u8 drawn from N(mean, std) clamped to [0, 255] (Table 1's N(125,24²)).
    pub fn u8_normal(&mut self, mean: f64, std: f64) -> u8 {
        self.normal(mean, std).round().clamp(0.0, 255.0) as u8
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(1);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn below_zero_is_zero_in_every_profile() {
        // Runs in release too (`cargo test --release`): before the
        // unconditional guard this divided by zero on the rejection path
        // once debug_assert! compiled out.
        let mut r = Rng::new(9);
        for _ in 0..64 {
            assert_eq!(r.below(0), 0);
        }
        // The stream is unperturbed: an empty range consumes no randomness.
        let mut a = Rng::new(11);
        let mut b = Rng::new(11);
        a.below(0);
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(7);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal(125.0, 24.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 125.0).abs() < 0.5, "mean {mean}");
        assert!((var.sqrt() - 24.0).abs() < 0.5, "std {}", var.sqrt());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
