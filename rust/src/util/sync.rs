//! Poison-tolerant lock helpers.
//!
//! `std::sync::Mutex` poisons itself when a holder panics, and every later
//! `lock().unwrap()` then panics too — one crashed worker wedges the whole
//! serving plane. None of our guarded state relies on panic-interrupted
//! invariants (queues of owned requests, counter structs, cache maps: each is
//! valid after any partial mutation), so the right policy is to *keep going*:
//! take the guard out of the `PoisonError` and continue. The supervisor layer
//! (`fault::supervise`, `coordinator::service`) owns crash recovery; locks
//! just stay usable.

use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::Duration;

/// Lock a mutex, recovering the guard if a previous holder panicked.
pub fn lock_clean<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// `Condvar::wait` that survives poisoning.
pub fn wait_clean<'a, T>(cv: &Condvar, g: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(g).unwrap_or_else(|e| e.into_inner())
}

/// `Condvar::wait_timeout` that survives poisoning; returns `(guard, timed_out)`.
pub fn wait_timeout_clean<'a, T>(
    cv: &Condvar,
    g: MutexGuard<'a, T>,
    dur: Duration,
) -> (MutexGuard<'a, T>, bool) {
    match cv.wait_timeout(g, dur) {
        Ok((g, to)) => (g, to.timed_out()),
        Err(e) => {
            let (g, to) = e.into_inner();
            (g, to.timed_out())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn lock_clean_recovers_from_poison() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert!(m.is_poisoned());
        let mut g = lock_clean(&m);
        *g += 1;
        assert_eq!(*g, 8);
    }

    #[test]
    fn wait_timeout_clean_times_out() {
        let m = Mutex::new(());
        let cv = Condvar::new();
        let g = lock_clean(&m);
        let (_g, timed_out) = wait_timeout_clean(&cv, g, Duration::from_millis(5));
        assert!(timed_out);
    }
}
