//! Small self-contained utilities (the offline vendor set has no tokio /
//! clap / criterion / proptest / serde — these fill the gaps).

pub mod bench;
pub mod cli;
pub mod hash;
pub mod io;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod sync;
pub mod threadpool;
