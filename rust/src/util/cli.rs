//! Tiny CLI argument parser (no clap offline): `--key value`,
//! `--key=value`, `--flag`, positional subcommand. Unknown flags are
//! errors so typos surface.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Parsed arguments: one positional subcommand + `--key value|flag` options.
#[derive(Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (without argv[0]).
    pub fn parse(args: impl IntoIterator<Item = String>, known: &[&str]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(key) = arg.strip_prefix("--") {
                // `--key=value` form, common in CI scripts.
                if let Some((k, v)) = key.split_once('=') {
                    if !known.contains(&k) {
                        bail!("unknown option --{k} (known: {})", known.join(", "));
                    }
                    out.opts.insert(k.to_string(), v.to_string());
                    continue;
                }
                if !known.contains(&key) {
                    bail!("unknown option --{key} (known: {})", known.join(", "));
                }
                match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        let v = it.next().unwrap();
                        out.opts.insert(key.to_string(), v);
                    }
                    _ => out.flags.push(key.to_string()),
                }
            } else if out.command.is_none() {
                out.command = Some(arg);
            } else {
                bail!("unexpected positional argument: {arg}");
            }
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<Args> {
        Args::parse(
            s.split_whitespace().map(String::from),
            &["family", "n", "verbose", "out"],
        )
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("accuracy --family perforated --n 100 --verbose").unwrap();
        assert_eq!(a.command.as_deref(), Some("accuracy"));
        assert_eq!(a.get("family"), Some("perforated"));
        assert_eq!(a.get_usize("n", 0).unwrap(), 100);
        assert!(a.flag("verbose"));
        assert!(!a.flag("out"));
    }

    #[test]
    fn unknown_option_is_error() {
        assert!(parse("run --bogus 1").is_err());
        assert!(parse("run --bogus=1").is_err());
    }

    #[test]
    fn key_equals_value_form() {
        let a = parse("accuracy --family=perforated --n=0 --out=a=b").unwrap();
        assert_eq!(a.get("family"), Some("perforated"));
        assert_eq!(a.get_usize("n", 7).unwrap(), 0);
        // Only the first `=` splits; values may contain `=`.
        assert_eq!(a.get("out"), Some("a=b"));
    }

    #[test]
    fn defaults() {
        let a = parse("table1").unwrap();
        assert_eq!(a.get_or("family", "all"), "all");
        assert_eq!(a.get_usize("n", 7).unwrap(), 7);
    }
}
