//! Content checksums for cached numeric tables (no `crc`/`xxhash` offline).
//!
//! The fault subsystem stamps every `MulLut` and packed `LayerPlan` with a
//! build-time checksum so runtime corruption (a flipped SRAM bit, a chaos
//! injection) is detectable by recomputation. The hash is FNV-1a folded at
//! u64-word granularity: position-sensitive (a swap of two words changes the
//! digest), branch-free, and fast enough to sweep a full 256×256 i32 LUT in
//! tens of microseconds — cheap at batch granularity, never on the per-MAC
//! path.

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Incremental 64-bit FNV-1a over u64 words.
#[derive(Clone, Debug)]
pub struct Hasher64 {
    h: u64,
}

impl Default for Hasher64 {
    fn default() -> Self {
        Self::new()
    }
}

impl Hasher64 {
    pub fn new() -> Self {
        Hasher64 { h: FNV_OFFSET }
    }

    #[inline]
    pub fn word(&mut self, x: u64) {
        self.h = (self.h ^ x).wrapping_mul(FNV_PRIME);
    }

    /// Fold a byte slice 8 bytes at a time (tail zero-padded into one word).
    pub fn bytes(&mut self, xs: &[u8]) {
        let mut it = xs.chunks_exact(8);
        for c in it.by_ref() {
            self.word(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rem = it.remainder();
        if !rem.is_empty() {
            let mut tail = [0u8; 8];
            tail[..rem.len()].copy_from_slice(rem);
            self.word(u64::from_le_bytes(tail));
        }
        // Length word so `[1,0]` and `[1]`+implicit-zero differ.
        self.word(xs.len() as u64);
    }

    pub fn i32s(&mut self, xs: &[i32]) {
        for &x in xs {
            self.word(x as u32 as u64);
        }
        self.word(xs.len() as u64);
    }

    pub fn i64s(&mut self, xs: &[i64]) {
        for &x in xs {
            self.word(x as u64);
        }
        self.word(xs.len() as u64);
    }

    pub fn finish(&self) -> u64 {
        self.h
    }
}

/// One-shot checksum of an i32 table (LUT contents).
pub fn checksum_i32s(xs: &[i32]) -> u64 {
    let mut h = Hasher64::new();
    h.i32s(xs);
    h.finish()
}

/// One-shot checksum of a byte panel (packed weight planes).
pub fn checksum_bytes(xs: &[u8]) -> u64 {
    let mut h = Hasher64::new();
    h.bytes(xs);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_content_sensitive() {
        let a: Vec<i32> = (0..1000).collect();
        let mut b = a.clone();
        assert_eq!(checksum_i32s(&a), checksum_i32s(&b));
        b[500] ^= 1 << 22; // single bit flip changes the digest
        assert_ne!(checksum_i32s(&a), checksum_i32s(&b));
    }

    #[test]
    fn position_sensitive() {
        let a = [1i32, 2, 3];
        let b = [3i32, 2, 1];
        assert_ne!(checksum_i32s(&a), checksum_i32s(&b));
    }

    #[test]
    fn byte_tail_and_length_matter() {
        assert_ne!(checksum_bytes(&[1, 0]), checksum_bytes(&[1]));
        assert_ne!(checksum_bytes(&[]), checksum_bytes(&[0]));
        let long: Vec<u8> = (0..=255).cycle().take(4096).collect();
        let mut flipped = long.clone();
        flipped[4095] ^= 0x80;
        assert_ne!(checksum_bytes(&long), checksum_bytes(&flipped));
    }

    #[test]
    fn incremental_matches_composition() {
        let mut h = Hasher64::new();
        h.bytes(&[9, 8, 7]);
        h.i64s(&[-1, 2]);
        let d1 = h.finish();
        let mut h2 = Hasher64::new();
        h2.bytes(&[9, 8, 7]);
        h2.i64s(&[-1, 2]);
        assert_eq!(d1, h2.finish());
        let mut h3 = Hasher64::new();
        h3.bytes(&[9, 8, 7]);
        h3.i64s(&[-1, 3]);
        assert_ne!(d1, h3.finish());
    }
}
