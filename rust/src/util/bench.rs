//! Micro-benchmark harness (no criterion offline).
//!
//! `Bencher::run` warms up, then samples the closure until a time budget is
//! hit, reporting median/mean/p95 per-iteration times. Used by the
//! `benches/*.rs` targets (`harness = false`) and the CLI perf commands.

use std::path::PathBuf;
use std::time::{Duration, Instant};

/// Repo-root path for a `BENCH_*.json` artifact.
///
/// The bench targets belong to the `cvapprox` package, so cargo runs them
/// with `rust/` as the working directory — a bare relative write lands the
/// JSON next to `Cargo.toml` instead of the repo root where the
/// perf-trajectory tooling (and `scripts/verify.sh`'s existence checks)
/// look. Anchoring on `CARGO_MANIFEST_DIR/..` is deterministic regardless
/// of invocation directory.
pub fn artifact_path(name: &str) -> PathBuf {
    PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/..")).join(name)
}

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub samples: usize,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
    /// Optional work units per iteration (e.g. MACs) for throughput lines.
    pub units_per_iter: f64,
}

impl BenchResult {
    /// Throughput in units/second if `units_per_iter` was set.
    pub fn throughput(&self) -> f64 {
        if self.units_per_iter > 0.0 && self.median_ns > 0.0 {
            self.units_per_iter / (self.median_ns * 1e-9)
        } else {
            0.0
        }
    }

    pub fn report(&self) -> String {
        let mut line = format!(
            "{:<44} {:>10}  median {:>12}  p95 {:>12}",
            self.name,
            format!("{}x", self.samples),
            fmt_ns(self.median_ns),
            fmt_ns(self.p95_ns),
        );
        if self.units_per_iter > 0.0 {
            line.push_str(&format!("  {:>12}/s", fmt_count(self.throughput())));
        }
        line
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

pub fn fmt_count(x: f64) -> String {
    if x >= 1e9 {
        format!("{:.2}G", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if x >= 1e3 {
        format!("{:.2}k", x / 1e3)
    } else {
        format!("{x:.0}")
    }
}

pub struct Bencher {
    pub warmup: Duration,
    pub budget: Duration,
    pub max_samples: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        // Modest budgets: the benches cover many configurations on one core.
        Bencher {
            warmup: Duration::from_millis(100),
            budget: Duration::from_millis(700),
            max_samples: 200,
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            warmup: Duration::from_millis(20),
            budget: Duration::from_millis(150),
            max_samples: 50,
        }
    }

    /// Benchmark `f`, which should perform one full iteration of the
    /// operation under test. `units` is the per-iteration work (0 = n/a).
    pub fn run(&self, name: &str, units: f64, mut f: impl FnMut()) -> BenchResult {
        // Warmup.
        let t0 = Instant::now();
        while t0.elapsed() < self.warmup {
            f();
        }
        // Sample.
        let mut samples_ns: Vec<f64> = Vec::new();
        let t0 = Instant::now();
        while t0.elapsed() < self.budget && samples_ns.len() < self.max_samples {
            let s = Instant::now();
            f();
            samples_ns.push(s.elapsed().as_nanos() as f64);
        }
        if samples_ns.is_empty() {
            samples_ns.push(0.0);
        }
        samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples_ns.len();
        BenchResult {
            name: name.to_string(),
            samples: n,
            median_ns: samples_ns[n / 2],
            mean_ns: samples_ns.iter().sum::<f64>() / n as f64,
            p95_ns: samples_ns[(n as f64 * 0.95) as usize % n],
            min_ns: samples_ns[0],
            units_per_iter: units,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bencher::quick();
        let r = b.run("spin", 1000.0, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.samples > 0);
        assert!(r.median_ns >= 0.0);
        assert!(r.throughput() > 0.0);
    }

    #[test]
    fn artifact_path_is_the_repo_root() {
        let p = artifact_path("BENCH_probe.json");
        assert_eq!(p.file_name().unwrap(), "BENCH_probe.json");
        // The repo root is the directory holding the crate (`rust/`).
        let root = p.parent().unwrap();
        assert!(root.join("rust/Cargo.toml").exists(), "{root:?}");
    }

    #[test]
    fn formats() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_count(2_500_000.0), "2.50M");
    }
}
