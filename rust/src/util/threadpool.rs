//! Scoped worker pool over std threads (no tokio/rayon offline).
//!
//! The coordinator and the sweep harnesses fan work out over
//! `available_parallelism` threads; `scope_chunks` is the core primitive:
//! split an indexed range into chunks and run a closure per chunk.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use (min 1).
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Worker count for the GEMM hot path: the `CVAPPROX_THREADS` environment
/// variable when set to a positive integer, else [`default_workers`].
/// Read once and cached — the engines consult this on every GEMM call.
pub fn configured_workers() -> usize {
    static CACHE: AtomicUsize = AtomicUsize::new(0);
    let cached = CACHE.load(Ordering::Relaxed);
    if cached != 0 {
        return cached;
    }
    let v = std::env::var("CVAPPROX_THREADS")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&v| v >= 1)
        .unwrap_or_else(default_workers)
        .clamp(1, 256);
    CACHE.store(v, Ordering::Relaxed);
    v
}

/// Run `f(i)` for every i in 0..n across `workers` threads (work stealing via
/// an atomic counter). `f` must be Sync; results are discarded.
pub fn for_each_index<F>(n: usize, workers: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if n == 0 {
        return;
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Map `f(i)` over 0..n in parallel, collecting results in index order.
pub fn par_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let results: Mutex<Vec<(usize, T)>> = Mutex::new(Vec::with_capacity(n));
    for_each_index(n, workers, |i| {
        let v = f(i);
        crate::util::sync::lock_clean(&results).push((i, v));
    });
    // A panicking `f` propagates out of the scoped join above, so the only
    // poison we can see here is already-unwound — recover the data.
    let mut pairs = results.into_inner().unwrap_or_else(|e| e.into_inner());
    pairs.sort_by_key(|(i, _)| *i);
    pairs.into_iter().map(|(_, v)| v).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn covers_all_indices() {
        let hits = AtomicU64::new(0);
        for_each_index(1000, 4, |i| {
            hits.fetch_add(i as u64 + 1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 500_500);
    }

    #[test]
    fn par_map_preserves_order() {
        let out = par_map(64, 4, |i| i * i);
        assert_eq!(out, (0..64).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn zero_items_is_noop() {
        for_each_index(0, 4, |_| panic!("should not run"));
    }

    #[test]
    fn configured_workers_is_positive_and_stable() {
        let a = configured_workers();
        let b = configured_workers();
        assert!(a >= 1);
        assert_eq!(a, b);
    }
}
