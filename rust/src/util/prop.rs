//! Seeded property-testing helper (no proptest offline).
//!
//! `check` runs a property over `n` generated cases; on failure it reports
//! the case index and seed so the exact input can be replayed. Shrinking is
//! replaced by deterministic replay — good enough for the integer domains
//! this crate works in (operands are u8, knobs are tiny enums).

use super::rng::Rng;

/// Run `prop` over `n` cases drawn from `gen`; panic with the replay seed on
/// the first failure.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    n: u64,
    seed: u64,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    for case in 0..n {
        let mut rng = Rng::new(seed ^ (case.wrapping_mul(0x9E3779B97F4A7C15)));
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed}): {input:?}"
            );
        }
    }
}

/// Like `check` but the property returns `Result` with a message.
pub fn check_msg<T: std::fmt::Debug>(
    name: &str,
    n: u64,
    seed: u64,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..n {
        let mut rng = Rng::new(seed ^ (case.wrapping_mul(0x9E3779B97F4A7C15)));
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed}): {msg}\ninput: {input:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true() {
        check("true", 50, 1, |r| r.u8(), |_| true);
    }

    #[test]
    #[should_panic(expected = "property 'even'")]
    fn reports_failure() {
        check("even", 50, 1, |r| r.u8(), |&x| x % 2 == 0);
    }

    #[test]
    fn generation_is_deterministic() {
        let mut a = Vec::new();
        let mut b = Vec::new();
        check("collect-a", 10, 9, |r| { let v = r.u8(); a.push(v); v }, |_| true);
        check("collect-b", 10, 9, |r| { let v = r.u8(); b.push(v); v }, |_| true);
        assert_eq!(a, b);
    }
}
