//! `srclint` — a project-invariant static-analysis pass.
//!
//! The repo's headline claims are concurrency invariants (bit-identical
//! engine tiers, exactly-one-reply, no silent corruption), and PRs 5–6
//! grew a hand-rolled concurrent surface whose rules were previously
//! enforced only by review. This module enforces them mechanically:
//!
//! | rule | invariant |
//! |------|-----------|
//! | R1   | locks/condvars go through `util::sync::*_clean` (poison tolerance) |
//! | R2   | every atomic `Ordering::` use matches [`contract::ATOMIC_CONTRACT`] |
//! | R3   | no panics / user-input indexing in the serving hot path |
//! | R4   | deterministic modules never read the wall clock |
//! | R5   | `CVAPPROX_*` env vars ⊆ README registry, both directions |
//!
//! Run as `cvapprox srclint [--json LINT_report.json] [--root PATH]`;
//! exits non-zero on any finding. Suppress a single site with
//! `// srclint: allow(Rn, reason)` — the reason is mandatory and the
//! comment itself is linted (rule `SUP`).
//!
//! Like `util::json`, everything here is hermetic: a hand-rolled
//! tokenizer ([`lexer`]) instead of `syn`, so the pass runs offline with
//! zero new dependencies. It lints a *token stream*, not an AST — rules
//! are written to be exact on this codebase's idioms and conservative
//! elsewhere.

pub mod contract;
pub mod lexer;
pub mod report;
pub mod rules;

use std::path::PathBuf;

pub use report::{run_lint, LintReport};
pub use rules::{Finding, Suppression};

/// The repo root (the directory holding `rust/`, `benches/`, `README.md`),
/// derived from the crate manifest dir so tests and the CLI agree.
pub fn repo_root() -> PathBuf {
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    match manifest.parent() {
        Some(p) => p.to_path_buf(),
        None => PathBuf::from("."),
    }
}
