//! The checked-in invariant tables `srclint` enforces.
//!
//! The heart of this module is the **atomics-ordering contract**: an
//! allowlist mapping (file, atomic field) → the memory orderings that
//! field is permitted to use, each with a one-line rationale. Rule R2
//! fails any `Ordering::` use that is not in this table, which turns
//! "why is this Relaxed?" from a review nitpick into a lint error with a
//! written-down answer. Adding an atomic to the codebase therefore
//! requires adding a row here — i.e. writing down *why* its orderings
//! are sufficient.

/// One allowlist row: `file` is the repo-relative path, `atomic` the
/// field/static name as it appears at the call site (`self.head.load(..)`
/// → `"head"`), `allowed` the permitted orderings, `rationale` the
/// one-line justification recorded in docs and `LINT_report.json`.
pub struct AtomicRule {
    pub file: &'static str,
    pub atomic: &'static str,
    pub allowed: &'static [&'static str],
    pub rationale: &'static str,
}

/// The atomics-ordering contract. Every non-test `Ordering::` use in
/// `rust/src` must match a row; `srclint` flags both unknown atomics and
/// disallowed orderings.
pub const ATOMIC_CONTRACT: &[AtomicRule] = &[
    // --- coordinator/service.rs: pool lifecycle flags -----------------
    AtomicRule {
        file: "rust/src/coordinator/service.rs",
        atomic: "alive",
        allowed: &["SeqCst"],
        rationale: "live-worker census read by supervisor respawn logic; \
                    SeqCst keeps it totally ordered with stopping/done",
    },
    AtomicRule {
        file: "rust/src/coordinator/service.rs",
        atomic: "stopping",
        allowed: &["SeqCst"],
        rationale: "shutdown latch raced by workers/supervisor/clients; \
                    SeqCst for a single total order with alive/done",
    },
    AtomicRule {
        file: "rust/src/coordinator/service.rs",
        atomic: "done",
        allowed: &["SeqCst"],
        rationale: "terminal latch observed by is_stopped(); SeqCst \
                    pairs with stopping for join-free polling",
    },
    AtomicRule {
        file: "rust/src/coordinator/service.rs",
        atomic: "next_id",
        allowed: &["SeqCst"],
        rationale: "unique request-id allocator; only uniqueness is \
                    required, SeqCst retained from the admission design",
    },
    AtomicRule {
        file: "rust/src/coordinator/service.rs",
        atomic: "batch_seq",
        allowed: &["Relaxed"],
        rationale: "monotonic batch counter feeding the fault injector's \
                    seeded schedule; no data is published through it",
    },
    AtomicRule {
        file: "rust/src/coordinator/service.rs",
        atomic: "class_queued",
        allowed: &["SeqCst"],
        rationale: "global per-class admission ticket: fetch_update CAS \
                    keeps the bound exact across shards, SeqCst for a \
                    single total order of admits vs. pops vs. close-drain",
    },
    AtomicRule {
        file: "rust/src/coordinator/service.rs",
        atomic: "rr",
        allowed: &["Relaxed"],
        rationale: "round-robin shard cursor for pushes; any interleaving \
                    is a valid placement, requests publish via the shard \
                    mutex",
    },
    AtomicRule {
        file: "rust/src/coordinator/service.rs",
        atomic: "idle_workers",
        allowed: &["Relaxed"],
        rationale: "advisory parked-worker gauge for the fill-wait skip; \
                    a stale read only costs one batch window, correctness \
                    never depends on it",
    },
    // --- fault/inject.rs: deterministic schedule cursor ---------------
    AtomicRule {
        file: "rust/src/fault/inject.rs",
        atomic: "seq",
        allowed: &["Relaxed"],
        rationale: "per-site draw counter; each draw reseeds splitmix from \
                    seed^seq so only atomicity matters, not ordering",
    },
    // --- util/threadpool.rs -------------------------------------------
    AtomicRule {
        file: "rust/src/util/threadpool.rs",
        atomic: "CACHE",
        allowed: &["Relaxed"],
        rationale: "idempotent memo of CVAPPROX_THREADS; racing writers \
                    store the same value, no ordering needed",
    },
    AtomicRule {
        file: "rust/src/util/threadpool.rs",
        atomic: "next",
        allowed: &["Relaxed"],
        rationale: "work-stealing chunk cursor; scope join provides the \
                    final happens-before edge for results",
    },
    // --- nn/engine.rs --------------------------------------------------
    AtomicRule {
        file: "rust/src/nn/engine.rs",
        atomic: "num",
        allowed: &["Relaxed"],
        rationale: "CvProxySampler commutative sum; swaps only snapshot, \
                    readers tolerate a torn window by design",
    },
    AtomicRule {
        file: "rust/src/nn/engine.rs",
        atomic: "den",
        allowed: &["Relaxed"],
        rationale: "CvProxySampler commutative sum; see `num`",
    },
    AtomicRule {
        file: "rust/src/nn/engine.rs",
        atomic: "n",
        allowed: &["Relaxed"],
        rationale: "CvProxySampler sample counter; see `num`",
    },
    AtomicRule {
        file: "rust/src/nn/engine.rs",
        atomic: "generation",
        allowed: &["SeqCst"],
        rationale: "engine cache generation; publishes rebuilt plan state, \
                    SeqCst for a total order with plan generation bumps",
    },
    // --- nn/plan.rs -----------------------------------------------------
    AtomicRule {
        file: "rust/src/nn/plan.rs",
        atomic: "builds",
        allowed: &["Relaxed"],
        rationale: "build-count statistic for tests/benches only; never \
                    guards data",
    },
    AtomicRule {
        file: "rust/src/nn/plan.rs",
        atomic: "generation",
        allowed: &["SeqCst"],
        rationale: "cache invalidation epoch; SeqCst so a bump is totally \
                    ordered with the engine-side generation check",
    },
    // --- qos/governor.rs ------------------------------------------------
    AtomicRule {
        file: "rust/src/qos/governor.rs",
        atomic: "rung",
        allowed: &["Acquire"],
        rationale: "reads the published rung index; pairs with the \
                    Release store in rung_gauge/PolicySwitch install",
    },
    AtomicRule {
        file: "rust/src/qos/governor.rs",
        atomic: "stop",
        allowed: &["Acquire", "Release"],
        rationale: "governor-thread stop latch: Release store in stop(), \
                    Acquire load in run_loop",
    },
    AtomicRule {
        file: "rust/src/qos/governor.rs",
        atomic: "rung_gauge",
        allowed: &["Release"],
        rationale: "publishes the rung decided this tick; Release pairs \
                    with the Acquire load in report()",
    },
    // --- qos/telemetry.rs -----------------------------------------------
    AtomicRule {
        file: "rust/src/qos/telemetry.rs",
        atomic: "head",
        allowed: &["Release", "Acquire"],
        rationale: "ring head: Release fetch_add forms a release sequence \
                    publishing prior slot stores to the Acquire load in \
                    window() (fix for the all-Relaxed leak, PR 7)",
    },
    AtomicRule {
        file: "rust/src/qos/telemetry.rs",
        atomic: "lat_us",
        allowed: &["Release", "Acquire"],
        rationale: "latency slots: Release store / Acquire load bound \
                    staleness to each worker's single in-flight sample",
    },
    AtomicRule {
        file: "rust/src/qos/telemetry.rs",
        atomic: "drained_head",
        allowed: &["Relaxed"],
        rationale: "single-consumer drain cursor; only the governor \
                    thread touches it, swap is for reentrancy safety",
    },
    AtomicRule {
        file: "rust/src/qos/telemetry.rs",
        atomic: "inflight",
        allowed: &["Relaxed"],
        rationale: "gauge; instantaneous value only, never guards data",
    },
    AtomicRule {
        file: "rust/src/qos/telemetry.rs",
        atomic: "depth_sum",
        allowed: &["Relaxed"],
        rationale: "commutative sum drained by swap(0); tolerates torn \
                    windows by design (documented in module doc)",
    },
    AtomicRule {
        file: "rust/src/qos/telemetry.rs",
        atomic: "depth_n",
        allowed: &["Relaxed"],
        rationale: "commutative count; see `depth_sum`",
    },
    AtomicRule {
        file: "rust/src/qos/telemetry.rs",
        atomic: "occ_pm_sum",
        allowed: &["Relaxed"],
        rationale: "commutative occupancy sum; see `depth_sum`",
    },
    AtomicRule {
        file: "rust/src/qos/telemetry.rs",
        atomic: "occ_n",
        allowed: &["Relaxed"],
        rationale: "commutative count; see `depth_sum`",
    },
    AtomicRule {
        file: "rust/src/qos/telemetry.rs",
        atomic: "expired",
        allowed: &["Relaxed"],
        rationale: "commutative deadline-expiry count drained by swap(0); \
                    see `depth_sum`",
    },
];

/// Files (repo-relative) that must stay wall-clock free (rule R4): their
/// outputs are replay-exact functions of a seed, and an `Instant`/
/// `SystemTime` read would silently break golden regeneration and fault
/// schedule replay.
pub const DETERMINISTIC_MODULES: &[&str] = &[
    "rust/src/fault/inject.rs",
    "rust/src/util/rng.rs",
    "rust/src/util/prop.rs",
    "rust/src/nn/testutil.rs",
    "rust/src/search/mod.rs",
    "rust/src/search/genome.rs",
    "rust/src/search/evaluate.rs",
    "rust/src/search/nsga.rs",
];

/// Directory prefixes (repo-relative) forming the serving hot path (rule
/// R3): a panic here either kills a worker (masked by the supervisor,
/// costing replays) or poisons shared state, so fallible paths must
/// return typed errors instead.
pub const HOT_PATH_DIRS: &[&str] = &["rust/src/coordinator/", "rust/src/fault/"];

/// The one file allowed to call bare `lock()/wait()` + `unwrap` (rule
/// R1): it is where the poison-tolerant wrappers live.
pub const SYNC_WRAPPER_FILE: &str = "rust/src/util/sync.rs";

/// Identifiers that hold request-derived data in the hot path; direct
/// `[]` indexing on them is an R3 finding (a malformed request must be a
/// typed `BadInput`, not a panic).
pub const USER_INPUT_RECEIVERS: &[&str] = &["image", "logits", "requests", "batch"];

/// Markers delimiting the env-var registry in README.md (rule R5 scans
/// between them).
pub const ENV_REGISTRY_BEGIN: &str = "<!-- srclint:env-registry:begin -->";
pub const ENV_REGISTRY_END: &str = "<!-- srclint:env-registry:end -->";

/// The five memory orderings of `std::sync::atomic::Ordering`. Note these
/// are disjoint from `std::cmp::Ordering`'s variants, which is what lets
/// R2 match on the token pattern `Ordering :: <variant>` alone.
pub const ATOMIC_ORDERINGS: &[&str] = &["Relaxed", "Acquire", "Release", "AcqRel", "SeqCst"];

/// Methods that take an `Ordering` argument; R2 requires the call
/// enclosing an `Ordering::` token to be one of these so the contract
/// lookup is anchored to a real atomic operation.
pub const ATOMIC_METHODS: &[&str] = &[
    "load",
    "store",
    "swap",
    "fetch_add",
    "fetch_sub",
    "fetch_and",
    "fetch_or",
    "fetch_xor",
    "fetch_min",
    "fetch_max",
    "fetch_update",
    "compare_exchange",
    "compare_exchange_weak",
];

/// Look up the contract row for (file, atomic).
pub fn lookup(file: &str, atomic: &str) -> Option<&'static AtomicRule> {
    ATOMIC_CONTRACT
        .iter()
        .find(|r| r.file == file && r.atomic == atomic)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contract_rows_are_unique_and_well_formed() {
        for (i, a) in ATOMIC_CONTRACT.iter().enumerate() {
            assert!(!a.allowed.is_empty(), "{}: empty allowlist", a.atomic);
            assert!(!a.rationale.trim().is_empty(), "{}: no rationale", a.atomic);
            for o in a.allowed {
                assert!(ATOMIC_ORDERINGS.contains(o), "{}: bad ordering {o}", a.atomic);
            }
            for b in &ATOMIC_CONTRACT[i + 1..] {
                assert!(
                    !(a.file == b.file && a.atomic == b.atomic),
                    "duplicate contract row {}:{}",
                    a.file,
                    a.atomic
                );
            }
        }
    }

    #[test]
    fn lookup_finds_rows() {
        let r = lookup("rust/src/qos/telemetry.rs", "head").unwrap();
        assert!(r.allowed.contains(&"Release"));
        assert!(lookup("rust/src/qos/telemetry.rs", "nope").is_none());
    }
}
